//! Bench: regenerate Fig 11 (8×8 mesh scaling).
use aimm::bench::fig11;

fn main() {
    let t0 = std::time::Instant::now(); // detlint: allow(wall-clock) — report timing only
    println!("{}", fig11(0.12, 2).expect("fig11").render());
    println!("fig11 regenerated in {:?}", t0.elapsed());
}
