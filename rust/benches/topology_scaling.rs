//! Bench: cube-network topology scaling study (EXPERIMENTS.md
//! §Topology). Sweeps {mesh, torus, ring} × {4x4, 8x8, 16x16} ×
//! {B, TOM, AIMM} on one workload (SPMV under BNMP), checks the
//! structural invariant — average hop count strictly orders
//! ring > mesh > torus on the 8x8 baseline cells, where the topologies
//! share node count and workload and differ only in their link sets —
//! and records `BENCH_topology.json` at the repository root (fixed key
//! order, so re-runs diff clean).
//!
//! Run with `cargo bench --bench topology_scaling` (release; ignore
//! debug numbers). CI's serial job executes this on every push.

use std::time::Instant;

use aimm::bench::sweep::{cell_json, default_threads, run_grid, CellResult, SweepGrid};
use aimm::bench::Table;
use aimm::config::TopologyKind;
use aimm::runtime::json::write as jw;
use aimm::workloads::Benchmark;

/// Small enough that the 16x16 ring cells (diameter 128) stay in CI
/// range, big enough that hop statistics are stable.
const SCALE: f64 = 0.03;

/// Mean steady-state average hop count over the cells matching a
/// (topology, mesh, baseline-mapping) slice.
fn mean_hops(results: &[CellResult], topology: TopologyKind, mesh: (usize, usize)) -> f64 {
    let picked: Vec<f64> = results
        .iter()
        .filter(|r| {
            r.cell.topology == topology
                && r.cell.mesh == mesh
                && r.cell.mapping == aimm::config::MappingScheme::Baseline
        })
        .map(|r| r.summary.last().avg_hops)
        .collect();
    assert!(!picked.is_empty(), "no {topology:?} {mesh:?} baseline cells in the grid");
    picked.iter().sum::<f64>() / picked.len() as f64
}

fn main() {
    let mut grid = SweepGrid::new(SCALE, 1);
    grid.benches = vec![vec![Benchmark::Spmv]];
    grid.meshes = vec![(4, 4), (8, 8), (16, 16)];
    grid.topologies = TopologyKind::ALL.to_vec();
    let cells = grid.cells();
    assert_eq!(cells.len(), 27, "3 mappings x 3 meshes x 3 topologies");
    let threads = default_threads();
    println!(
        "topology scaling study: {} cells (scale {SCALE}) on {threads} thread(s)",
        cells.len()
    );
    let t0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
    let results = run_grid(&cells, threads).expect("topology scaling grid");
    let wall = t0.elapsed();

    let mut t = Table::new(
        "Topology scaling (steady-state run per cell)",
        &["cell", "cycles", "opc", "avg hops", "avg pkt latency"],
    );
    for r in &results {
        let last = r.summary.last();
        t.row(vec![
            r.cell.name(),
            last.cycles.to_string(),
            format!("{:.4}", last.opc()),
            format!("{:.2}", last.avg_hops),
            format!("{:.1}", last.avg_packet_latency),
        ]);
    }
    println!("{}", t.render());

    // The acceptance invariant: on the 8x8 baseline slice the link sets
    // alone order the hop counts — the ring's n/2 diameter dominates the
    // mesh, and the torus wraps undercut it.
    let mesh_hops = mean_hops(&results, TopologyKind::Mesh, (8, 8));
    let torus_hops = mean_hops(&results, TopologyKind::Torus, (8, 8));
    let ring_hops = mean_hops(&results, TopologyKind::Ring, (8, 8));
    println!(
        "8x8 baseline average hops: ring {ring_hops:.3} > mesh {mesh_hops:.3} > \
         torus {torus_hops:.3}"
    );
    assert!(
        ring_hops > mesh_hops && mesh_hops > torus_hops,
        "expected strict hop ordering ring > mesh > torus at 8x8, got \
         ring {ring_hops:.3}, mesh {mesh_hops:.3}, torus {torus_hops:.3}"
    );

    let cells_json: Vec<String> = results.iter().map(cell_json).collect();
    let json = jw::obj(&[
        ("schema", jw::string("aimm-topology-v1")),
        (
            "grid",
            jw::string(&format!(
                "SPMV/BNMP x {{B,TOM,AIMM}} x {{4x4,8x8,16x16}} x \
                 {{mesh,torus,ring}} (scale {SCALE}, 1 run)"
            )),
        ),
        ("measured", "true".to_string()),
        (
            "avg_hops_8x8_baseline",
            jw::obj(&[
                ("mesh", jw::num(mesh_hops)),
                ("torus", jw::num(torus_hops)),
                ("ring", jw::num(ring_hops)),
            ]),
        ),
        ("hop_order_ring_gt_mesh_gt_torus", "true".to_string()),
        ("cells", format!("[{}]", cells_json.join(","))),
        ("regenerate", jw::string("cargo bench --bench topology_scaling")),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_topology.json");
    std::fs::write(path, &json).expect("write BENCH_topology.json");
    println!("wrote {path} ({} cells) in {wall:?}", results.len());
}
