//! Bench: regenerate Fig 12 (multi-program workloads with HOARD/AIMM).
use aimm::bench::fig12;

fn main() {
    let t0 = std::time::Instant::now(); // detlint: allow(wall-clock) — report timing only
    println!("{}", fig12(0.06, 2).expect("fig12").render());
    println!("fig12 regenerated in {:?}", t0.elapsed());
}
