//! Bench: regenerate Fig 6 (normalized execution time across the full
//! {BNMP,LDB,PEI} × {B,TOM,AIMM} × 9-benchmark grid) at bench scale.
use aimm::bench::fig6;

fn main() {
    let t0 = std::time::Instant::now(); // detlint: allow(wall-clock) — report timing only
    let table = fig6(0.12, 2).expect("fig6");
    println!("{}", table.render());
    println!("fig6 grid regenerated in {:?}", t0.elapsed());
}
