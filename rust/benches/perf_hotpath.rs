//! Bench: L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf) — the
//! end-to-end episode runner under both simulation engines, plus the
//! component-level hot loops.
use aimm::bench::bench_fn;
use aimm::config::{Engine, MappingScheme, SystemConfig};
use aimm::coordinator::System;
use aimm::cube::PhysAddr;
use aimm::noc::packet::{NodeId, Packet, Payload};
use aimm::noc::Mesh;
use aimm::workloads::{generate, Benchmark};

fn main() {
    // End-to-end episode (baseline, no PJRT) — the master hot loop,
    // timed under the polled reference loop and the next-event engine
    // (identical stats, DESIGN.md §8; the ratio is the engine speedup).
    let cfg = SystemConfig::default();
    let trace = generate(Benchmark::Spmv, 1, 0.12, cfg.seed);
    let mut polled_cfg = cfg.clone();
    polled_cfg.engine = Engine::Polled;
    let mut event_cfg = cfg.clone();
    event_cfg.engine = Engine::Event;
    let rp = bench_fn("episode SPMV scale=0.12 (baseline, polled)", 1, 5, || {
        System::new(polled_cfg.clone(), trace.ops.clone(), None).run().unwrap();
    });
    println!("{}", rp.report());
    let re = bench_fn("episode SPMV scale=0.12 (baseline, event)", 1, 5, || {
        System::new(event_cfg.clone(), trace.ops.clone(), None).run().unwrap();
    });
    println!("{}", re.report());
    {
        let mut sys = System::new(polled_cfg.clone(), trace.ops.clone(), None);
        let stats = sys.run().unwrap();
        let per_cycle = rp.median.as_nanos() as f64 / stats.cycles as f64;
        println!(
            "  -> {} sim cycles, {:.1} ns/cycle polled, {:.1} ns/cycle event, \
             event speedup {:.2}x",
            stats.cycles,
            per_cycle,
            re.median.as_nanos() as f64 / stats.cycles as f64,
            rp.median.as_secs_f64() / re.median.as_secs_f64().max(1e-12),
        );
    }

    // TOM variant (adds the remap machinery + epoch skips to the loop).
    let mut tom_polled = polled_cfg.clone();
    tom_polled.mapping = MappingScheme::Tom;
    let mut tom_event = event_cfg.clone();
    tom_event.mapping = MappingScheme::Tom;
    let rp = bench_fn("episode SPMV scale=0.12 (TOM, polled)", 1, 5, || {
        System::new(tom_polled.clone(), trace.ops.clone(), None).run().unwrap();
    });
    println!("{}", rp.report());
    let re = bench_fn("episode SPMV scale=0.12 (TOM, event)", 1, 5, || {
        System::new(tom_event.clone(), trace.ops.clone(), None).run().unwrap();
    });
    println!("{}", re.report());
    println!(
        "  -> TOM event speedup {:.2}x",
        rp.median.as_secs_f64() / re.median.as_secs_f64().max(1e-12)
    );

    // NoC saturation microbench: all-to-all packet storm.
    let r = bench_fn("mesh tick under storm (1000 cycles)", 1, 10, || {
        let mut mesh = Mesh::new(&cfg);
        let mut next = 0u64;
        for now in 0..1000u64 {
            for src in 0..16 {
                next += 1;
                let pk = Packet::new(
                    next,
                    NodeId::Cube(src),
                    NodeId::Cube((src * 7 + (now as usize)) % 16),
                    Payload::SourceReq { token: next, addr: PhysAddr::new(0, 0), reply_to: src },
                    now,
                );
                let _ = mesh.inject(pk);
            }
            mesh.tick(now);
        }
    });
    println!("{}", r.report());

    // Workload generation (build-time path, still worth tracking).
    let r = bench_fn("generate all registered traces scale=0.25", 1, 5, || {
        for b in Benchmark::ALL {
            let _ = generate(b, 1, 0.25, 7);
        }
    });
    println!("{}", r.report());
}
