//! Bench: L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf) — the
//! end-to-end episode runner plus the component-level hot loops.
use aimm::bench::bench_fn;
use aimm::config::{MappingScheme, SystemConfig};
use aimm::coordinator::System;
use aimm::noc::packet::{NodeId, Packet, Payload};
use aimm::noc::Mesh;
use aimm::cube::PhysAddr;
use aimm::workloads::{generate, Benchmark};

fn main() {
    // End-to-end episode (baseline, no PJRT) — the master hot loop.
    let cfg = SystemConfig::default();
    let trace = generate(Benchmark::Spmv, 1, 0.12, cfg.seed);
    let r = bench_fn("episode SPMV scale=0.12 (baseline)", 1, 5, || {
        let mut sys = System::new(cfg.clone(), trace.ops.clone(), None);
        sys.run().unwrap();
    });
    println!("{}", r.report());
    {
        let mut sys = System::new(cfg.clone(), trace.ops.clone(), None);
        let stats = sys.run().unwrap();
        let per_cycle = r.median.as_nanos() as f64 / stats.cycles as f64;
        println!("  -> {} sim cycles, {:.1} ns/cycle", stats.cycles, per_cycle);
    }

    // TOM variant (adds the remap machinery to the loop).
    let mut tom_cfg = cfg.clone();
    tom_cfg.mapping = MappingScheme::Tom;
    let r = bench_fn("episode SPMV scale=0.12 (TOM)", 1, 5, || {
        let mut sys = System::new(tom_cfg.clone(), trace.ops.clone(), None);
        sys.run().unwrap();
    });
    println!("{}", r.report());

    // NoC saturation microbench: all-to-all packet storm.
    let r = bench_fn("mesh tick under storm (1000 cycles)", 1, 10, || {
        let mut mesh = Mesh::new(&cfg);
        let mut next = 0u64;
        for now in 0..1000u64 {
            for src in 0..16 {
                next += 1;
                let pk = Packet::new(
                    next,
                    NodeId::Cube(src),
                    NodeId::Cube((src * 7 + (now as usize)) % 16),
                    Payload::SourceReq { token: next, addr: PhysAddr::new(0, 0), reply_to: src },
                    now,
                );
                let _ = mesh.inject(pk);
            }
            mesh.tick(now);
        }
    });
    println!("{}", r.report());

    // Workload generation (build-time path, still worth tracking).
    let r = bench_fn("generate all 9 traces scale=0.25", 1, 5, || {
        for b in Benchmark::ALL {
            let _ = generate(b, 1, 0.25, 7);
        }
    });
    println!("{}", r.report());
}
