//! Bench: regenerate Fig 9 (OPC timeline / learning convergence).
use aimm::bench::fig9;

fn main() {
    let t0 = std::time::Instant::now(); // detlint: allow(wall-clock) — report timing only
    println!("{}", fig9(0.12, 3, 16).expect("fig9").render());
    println!("fig9 regenerated in {:?}", t0.elapsed());
}
