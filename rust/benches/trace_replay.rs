//! Bench: trace capture/replay round-trip (EXPERIMENTS.md §Trace).
//! Captures three representative episodes (MAC, SPMV, GCM) to the
//! versioned trace format, replays each through the streaming
//! `FileProvider`, and checks the headline guarantee end to end:
//! replayed stats are byte-identical to the generated run's, and
//! re-rendering the parsed file reproduces the capture byte for byte.
//! A GCM face-off then replays the same pointer-chasing trace under
//! every paper mapping policy. Writes `BENCH_trace.json` at the
//! repository root (fixed key order, so re-runs diff clean — wall
//! times are printed, never serialized).
//!
//! Run with `cargo bench --bench trace_replay` (release; ignore debug
//! numbers). CI's serial job executes this on every push.

use std::path::PathBuf;
use std::time::Instant;

use aimm::bench::sweep::{atomic_write_text, stats_json};
use aimm::bench::Table;
use aimm::config::{MappingScheme, SystemConfig};
use aimm::coordinator::{episode_ops, fresh_agent, run_episode_with, run_traced_with};
use aimm::runtime::json::write as jw;
use aimm::workloads::{render_trace, Benchmark, FileTrace};

/// Big enough that the streaming reader's refill loop actually cycles,
/// small enough that 3 capture+replay pairs stay in CI range.
const SCALE: f64 = 0.05;
/// Two runs per episode: the second run exercises policy carryover
/// through the replay path too.
const RUNS: usize = 2;

const BENCHES: [Benchmark; 3] = [Benchmark::Mac, Benchmark::Spmv, Benchmark::Gcm];

fn temp_trace(bench: Benchmark) -> PathBuf {
    let name = format!("aimm_trace_bench_{}_{}.tr", std::process::id(), bench.name());
    std::env::temp_dir().join(name)
}

fn main() {
    let t0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
    let cfg = SystemConfig::default();

    let mut t = Table::new(
        "Trace capture/replay round-trip (baseline mapping)",
        &["bench", "ops", "bytes", "capture ms", "replay cycles", "bit-identical"],
    );
    let mut roundtrip_rows: Vec<(String, String)> = Vec::new();
    let mut paths: Vec<PathBuf> = Vec::new();
    for &b in &BENCHES {
        let (ops, name) = episode_ops(&cfg, &[b], SCALE).expect("episode ops");
        let c0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
        let text = render_trace(&name, SCALE, &ops).expect("render trace");
        let path = temp_trace(b);
        atomic_write_text(&path, &text).expect("write capture");
        let capture_ms = c0.elapsed().as_secs_f64() * 1e3;

        let file = FileTrace::open(&path).expect("open capture");
        let (generated, _) = run_episode_with(&cfg, &[b], SCALE, RUNS, None).expect("generated");
        let (replayed, _) = run_traced_with(&cfg, &file, RUNS, None).expect("replayed");
        assert_eq!(generated.runs.len(), replayed.runs.len(), "{}", b.name());
        for (g, r) in generated.runs.iter().zip(&replayed.runs) {
            assert_eq!(stats_json(g), stats_json(r), "replay diverged on {}", b.name());
        }
        let rerendered = file.render().expect("re-render");
        assert_eq!(rerendered, text, "write->parse->write drifted on {}", b.name());

        t.row(vec![
            b.name().into(),
            ops.len().to_string(),
            text.len().to_string(),
            format!("{capture_ms:.2}"),
            replayed.last().cycles.to_string(),
            "yes".into(),
        ]);
        roundtrip_rows.push((
            b.name().to_string(),
            jw::obj(&[
                ("ops", ops.len().to_string()),
                ("bytes", text.len().to_string()),
                ("cycles", replayed.last().cycles.to_string()),
                ("bit_identical", "true".to_string()),
            ]),
        ));
        paths.push(path);
    }
    println!("{}", t.render());

    // GCM face-off: the SAME captured pointer-chasing trace replayed
    // under every paper mapping policy — completion counts must agree
    // (the trace, not the policy, fixes the op stream).
    let gcm = FileTrace::open(&temp_trace(Benchmark::Gcm)).expect("gcm capture");
    let mut faceoff: Vec<(&str, String)> = Vec::new();
    let mut ft = Table::new(
        "GCM replay face-off (same capture, steady-state run)",
        &["mapping", "cycles", "opc", "avg hops"],
    );
    let mut ops_done: Vec<u64> = Vec::new();
    for mapping in MappingScheme::PAPER {
        let mut mcfg = cfg.clone();
        mcfg.mapping = mapping;
        let agent =
            if mapping.uses_agent() { Some(fresh_agent(&mcfg).expect("agent")) } else { None };
        let (s, _) = run_traced_with(&mcfg, &gcm, RUNS, agent).expect("gcm replay");
        let last = s.last();
        ops_done.push(last.ops_completed);
        ft.row(vec![
            mapping.name().into(),
            last.cycles.to_string(),
            format!("{:.4}", last.opc()),
            format!("{:.2}", last.avg_hops),
        ]);
        faceoff.push((mapping.name(), jw::num(last.opc())));
    }
    assert!(ops_done.windows(2).all(|w| w[0] == w[1]), "trace drift across GCM mappings");
    println!("{}", ft.render());

    let wall = t0.elapsed();
    let roundtrip_fields: Vec<(&str, String)> =
        roundtrip_rows.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let json = jw::obj(&[
        ("schema", jw::string("aimm-trace-bench-v1")),
        (
            "grid",
            jw::string(&format!(
                "{{MAC,SPMV,GCM}} capture->replay x {RUNS} runs (scale {SCALE}); \
                 GCM replay x {{B,TOM,AIMM}}"
            )),
        ),
        ("measured", "true".to_string()),
        ("replay_bit_identical", "true".to_string()),
        ("roundtrip", jw::obj(&roundtrip_fields)),
        ("gcm_opc_by_mapping", jw::obj(&faceoff)),
        ("regenerate", jw::string("cargo bench --bench trace_replay")),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_trace.json");
    std::fs::write(path, &json).expect("write BENCH_trace.json");
    println!("wrote {path} in {wall:?}");
    for p in paths {
        let _ = std::fs::remove_file(p);
    }
}
