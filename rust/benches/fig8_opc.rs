//! Bench: regenerate Fig 8 (normalized OPC).
use aimm::bench::fig8;

fn main() {
    let t0 = std::time::Instant::now(); // detlint: allow(wall-clock) — report timing only
    println!("{}", fig8(0.12, 2).expect("fig8").render());
    println!("fig8 regenerated in {:?}", t0.elapsed());
}
