//! Bench: regenerate Fig 13 (page-cache / NMP-table size sensitivity).
use aimm::bench::fig13;

fn main() {
    let t0 = std::time::Instant::now(); // detlint: allow(wall-clock) — report timing only
    println!("{}", fig13(0.12, 2).expect("fig13").render());
    println!("fig13 regenerated in {:?}", t0.elapsed());
}
