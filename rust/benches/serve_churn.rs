//! Bench: multi-tenant serve churn (EXPERIMENTS.md §Serve).
//! Runs the open-loop service once per arrival process — poisson,
//! bursty, diurnal — with the default serve knobs (12 tenants, 4 slots,
//! 2 rounds) under the AIMM mapping, and reports the tail of the
//! per-tenant slowdown distribution (residency / isolated run) plus the
//! Jain fairness index. Writes `BENCH_serve.json` at the repository
//! root (fixed key order, so re-runs diff clean).
//!
//! Run with `cargo bench --bench serve_churn` (release; ignore debug
//! numbers). CI's serial job executes this on every push.

use std::time::Instant;

use aimm::bench::sweep::default_threads;
use aimm::bench::Table;
use aimm::config::{MappingScheme, SystemConfig};
use aimm::coordinator::{run_serve, serve_report_json};
use aimm::runtime::json::write as jw;
use aimm::workloads::ArrivalProcess;

fn main() {
    let mut cfg = SystemConfig::default();
    cfg.mapping = MappingScheme::Aimm;
    let threads = default_threads();
    println!(
        "serve churn: {} tenant(s) x {} arrival process(es), {} round(s), on {threads} thread(s)",
        cfg.serve.tenants,
        ArrivalProcess::ALL.len(),
        cfg.serve.rounds
    );

    let mut t = Table::new(
        "Serve churn tail (slowdown = residency / isolated run)",
        &["arrivals", "tenants", "rounds", "p50", "p99", "p999", "fairness", "wall"],
    );
    let mut by_arrivals: Vec<(&str, String)> = Vec::new();
    let t0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
    for p in ArrivalProcess::ALL {
        cfg.serve.arrivals = p;
        let start = Instant::now(); // detlint: allow(wall-clock) — report timing only
        let (outcome, _agent) = run_serve(&cfg, threads, None).expect("serve run");
        t.row(vec![
            p.name().to_string(),
            cfg.serve.tenants.to_string(),
            outcome.rounds.len().to_string(),
            format!("{:.3}", outcome.p50),
            format!("{:.3}", outcome.p99),
            format!("{:.3}", outcome.p999),
            format!("{:.3}", outcome.fairness),
            format!("{:?}", start.elapsed()),
        ]);
        by_arrivals.push((p.name(), serve_report_json(&cfg, &outcome)));
    }
    let wall = t0.elapsed();
    println!("{}", t.render());

    let grid = format!(
        "{} tenants x {{poisson,bursty,diurnal}} x {} rounds, {} slots, {}-page budget, \
         mean gap {}, scale {}, AIMM mapping",
        cfg.serve.tenants,
        cfg.serve.rounds,
        cfg.serve.slots,
        cfg.serve.page_budget,
        cfg.serve.mean_gap,
        cfg.serve.scale
    );
    let json = jw::obj(&[
        ("schema", jw::string("aimm-serve-bench-v1")),
        ("grid", jw::string(&grid)),
        ("measured", "true".to_string()),
        ("by_arrivals", jw::obj(&by_arrivals)),
        ("regenerate", jw::string("cargo bench --bench serve_churn")),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    std::fs::write(path, &json).expect("write BENCH_serve.json");
    println!("wrote {path} in {wall:?}");
}
