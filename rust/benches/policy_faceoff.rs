//! Bench: mapping-policy face-off (EXPERIMENTS.md §Policy face-off).
//! The head-to-head comparison the paper's Fig 11-style plots imply but
//! never show: all five policies — {B, TOM, AIMM, CODA, ORACLE} —
//! across three benchmarks and all three cube-network topologies on
//! the 4×4 grid, holding the trace constant within each
//! (benchmark, topology) slice so the mapping policy is the only
//! variable. Writes `BENCH_policy.json` at the repository root (fixed
//! key order, so re-runs diff clean).
//!
//! Run with `cargo bench --bench policy_faceoff` (release; ignore
//! debug numbers). CI's serial job executes this on every push.

use std::time::Instant;

use aimm::bench::sweep::{cell_json, default_threads, run_grid, CellResult, SweepGrid};
use aimm::bench::Table;
use aimm::config::{MappingScheme, TopologyKind};
use aimm::runtime::json::write as jw;
use aimm::workloads::Benchmark;

/// Big enough for migration/remap decisions to matter, small enough
/// that 45 cells × 2 runs stay in CI range.
const SCALE: f64 = 0.04;
/// Two runs per cell: AIMM's second run reflects a warmed network; the
/// face-off reads the steady-state (last) run everywhere.
const RUNS: usize = 2;

const BENCHES: [Benchmark; 3] = [Benchmark::Spmv, Benchmark::Km, Benchmark::Mac];

fn slice<'a>(
    results: &'a [CellResult],
    bench: Benchmark,
    topology: TopologyKind,
) -> Vec<&'a CellResult> {
    results
        .iter()
        .filter(|r| r.cell.benches == [bench] && r.cell.topology == topology)
        .collect()
}

fn main() {
    let mut grid = SweepGrid::new(SCALE, RUNS);
    grid.benches = BENCHES.iter().map(|&b| vec![b]).collect();
    grid.mappings = MappingScheme::ALL.to_vec();
    grid.topologies = TopologyKind::ALL.to_vec();
    let cells = grid.cells();
    assert_eq!(cells.len(), 45, "3 benches x 5 policies x 3 topologies");
    let threads = default_threads();
    println!(
        "policy face-off: {} cells ({RUNS} runs each, scale {SCALE}) on {threads} thread(s)",
        cells.len()
    );
    let t0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
    let results = run_grid(&cells, threads).expect("policy face-off grid");
    let wall = t0.elapsed();

    let mut t = Table::new(
        "Policy face-off (steady-state run per cell)",
        &["cell", "cycles", "opc", "avg hops", "util", "migrated"],
    );
    for r in &results {
        let last = r.summary.last();
        t.row(vec![
            r.cell.name(),
            last.cycles.to_string(),
            format!("{:.4}", last.opc()),
            format!("{:.2}", last.avg_hops),
            format!("{:.3}", last.compute_utilization),
            format!("{:.2}", last.fraction_pages_migrated),
        ]);
    }
    println!("{}", t.render());

    // Structural invariant: within a (benchmark, topology) slice every
    // policy ran the SAME trace (the workload seed ignores the mapping
    // axis), so all five cells must complete the same op count — the
    // property that makes the OPC columns comparable at all.
    let mut opc_rows: Vec<(String, String)> = Vec::new();
    for &bench in &BENCHES {
        for topology in TopologyKind::ALL {
            let cells = slice(&results, bench, topology);
            assert_eq!(cells.len(), 5, "{}/{topology}", bench.name());
            let ops0 = cells[0].summary.last().ops_completed;
            for c in &cells {
                assert_eq!(
                    c.summary.last().ops_completed,
                    ops0,
                    "trace drift inside the {}/{topology} slice ({})",
                    bench.name(),
                    c.cell.name()
                );
            }
            let fields: Vec<(&str, String)> = cells
                .iter()
                .map(|c| (c.cell.mapping.name(), jw::num(c.summary.last().opc())))
                .collect();
            opc_rows.push((
                format!("{}/{}", bench.name(), topology.name()),
                jw::obj(&fields),
            ));
        }
    }

    let cells_json: Vec<String> = results.iter().map(cell_json).collect();
    let opc_fields: Vec<(&str, String)> =
        opc_rows.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let json = jw::obj(&[
        ("schema", jw::string("aimm-policy-v1")),
        (
            "grid",
            jw::string(&format!(
                "{{SPMV,KM,MAC}}/BNMP x {{B,TOM,AIMM,CODA,ORACLE}} x 4x4 x \
                 {{mesh,torus,ring}} (scale {SCALE}, {RUNS} runs)"
            )),
        ),
        ("measured", "true".to_string()),
        ("opc_by_slice", jw::obj(&opc_fields)),
        ("cells", format!("[{}]", cells_json.join(","))),
        ("regenerate", jw::string("cargo bench --bench policy_faceoff")),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_policy.json");
    std::fs::write(path, &json).expect("write BENCH_policy.json");
    println!("wrote {path} ({} cells) in {wall:?}", results.len());
}
