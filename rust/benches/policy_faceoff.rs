//! Bench: mapping-policy face-off (EXPERIMENTS.md §Policy face-off).
//! The head-to-head comparison the paper's Fig 11-style plots imply but
//! never show: all six policies — {B, TOM, AIMM, AIMM-MC, CODA,
//! ORACLE} — across four benchmarks (the paper's SPMV/KM/MAC plus the
//! GCM pointer-chasing family) and all three cube-network topologies
//! on the 4×4 grid, holding the trace constant within each
//! (benchmark, topology) slice so the mapping policy is the only
//! variable. A final column runs oracle-warm-started AIMM on the mesh
//! slices — same traces, pre-trained start. Writes `BENCH_policy.json`
//! at the repository root (fixed key order, so re-runs diff clean).
//!
//! Run with `cargo bench --bench policy_faceoff` (release; ignore
//! debug numbers). CI's serial job executes this on every push.

use std::time::Instant;

use aimm::agent::WarmStart;
use aimm::bench::sweep::{cell_json, default_threads, run_grid, CellResult, SweepGrid};
use aimm::bench::Table;
use aimm::config::{MappingScheme, TopologyKind};
use aimm::coordinator::{episode_ops, run_stream_policy, warm_started_policy};
use aimm::runtime::json::write as jw;
use aimm::workloads::Benchmark;

/// Big enough for migration/remap decisions to matter, small enough
/// that 72 cells × 2 runs stay in CI range.
const SCALE: f64 = 0.04;
/// Two runs per cell: AIMM's second run reflects a warmed network; the
/// face-off reads the steady-state (last) run everywhere.
const RUNS: usize = 2;

const BENCHES: [Benchmark; 4] =
    [Benchmark::Spmv, Benchmark::Km, Benchmark::Mac, Benchmark::Gcm];

fn slice<'a>(
    results: &'a [CellResult],
    bench: Benchmark,
    topology: TopologyKind,
) -> Vec<&'a CellResult> {
    results
        .iter()
        .filter(|r| r.cell.benches == [bench] && r.cell.topology == topology)
        .collect()
}

fn main() {
    let mut grid = SweepGrid::new(SCALE, RUNS);
    grid.benches = BENCHES.iter().map(|&b| vec![b]).collect();
    grid.mappings = MappingScheme::ALL.to_vec();
    grid.topologies = TopologyKind::ALL.to_vec();
    let cells = grid.cells();
    assert_eq!(cells.len(), 72, "4 benches x 6 policies x 3 topologies");
    let threads = default_threads();
    println!(
        "policy face-off: {} cells ({RUNS} runs each, scale {SCALE}) on {threads} thread(s)",
        cells.len()
    );
    let t0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
    let results = run_grid(&cells, threads).expect("policy face-off grid");
    let wall = t0.elapsed();

    let mut t = Table::new(
        "Policy face-off (steady-state run per cell)",
        &["cell", "cycles", "opc", "avg hops", "util", "migrated"],
    );
    for r in &results {
        let last = r.summary.last();
        t.row(vec![
            r.cell.name(),
            last.cycles.to_string(),
            format!("{:.4}", last.opc()),
            format!("{:.2}", last.avg_hops),
            format!("{:.3}", last.compute_utilization),
            format!("{:.2}", last.fraction_pages_migrated),
        ]);
    }
    println!("{}", t.render());

    // Structural invariant: within a (benchmark, topology) slice every
    // policy ran the SAME trace (the workload seed ignores the mapping
    // axis), so all six cells must complete the same op count — the
    // property that makes the OPC columns comparable at all.
    let mut opc_rows: Vec<(String, String)> = Vec::new();
    for &bench in &BENCHES {
        for topology in TopologyKind::ALL {
            let cells = slice(&results, bench, topology);
            assert_eq!(cells.len(), 6, "{}/{topology}", bench.name());
            let ops0 = cells[0].summary.last().ops_completed;
            for c in &cells {
                assert_eq!(
                    c.summary.last().ops_completed,
                    ops0,
                    "trace drift inside the {}/{topology} slice ({})",
                    bench.name(),
                    c.cell.name()
                );
            }
            let fields: Vec<(&str, String)> = cells
                .iter()
                .map(|c| (c.cell.mapping.name(), jw::num(c.summary.last().opc())))
                .collect();
            opc_rows.push((
                format!("{}/{}", bench.name(), topology.name()),
                jw::obj(&fields),
            ));
        }
    }

    // Warm-started AIMM column: the same mesh traces, but the agent
    // starts from the oracle-distilled weights instead of cold. Reuses
    // each mesh AIMM cell's exact config so the op stream is the one
    // the grid already ran — asserted below.
    let mut wt = Table::new(
        "Oracle-warm-started AIMM (mesh slices, steady-state run)",
        &["bench", "distilled examples", "opc", "cold-AIMM opc"],
    );
    let mut warm_rows: Vec<(&str, String)> = Vec::new();
    for &bench in &BENCHES {
        let mesh = slice(&results, bench, TopologyKind::Mesh);
        let cold = mesh
            .iter()
            .find(|c| c.cell.mapping == MappingScheme::Aimm)
            .expect("mesh AIMM cell");
        let cfg = cold.cell.config().expect("cell config");
        let (ops, name) = episode_ops(&cfg, &[bench], SCALE).expect("episode ops");
        let (policy, distill) =
            warm_started_policy(&cfg, &ops, WarmStart::Oracle).expect("warm start");
        let (summary, _) =
            run_stream_policy(&cfg, &ops, RUNS, &name, policy).expect("warm episode");
        assert_eq!(
            summary.last().ops_completed,
            cold.summary.last().ops_completed,
            "warm-started {} ran a drifted trace",
            bench.name()
        );
        let examples: usize = distill.iter().map(|d| d.examples).sum();
        wt.row(vec![
            bench.name().into(),
            examples.to_string(),
            format!("{:.4}", summary.last().opc()),
            format!("{:.4}", cold.summary.last().opc()),
        ]);
        warm_rows.push((bench.name(), jw::num(summary.last().opc())));
    }
    println!("{}", wt.render());

    let cells_json: Vec<String> = results.iter().map(cell_json).collect();
    let opc_fields: Vec<(&str, String)> =
        opc_rows.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let json = jw::obj(&[
        ("schema", jw::string("aimm-policy-v1")),
        (
            "grid",
            jw::string(&format!(
                "{{SPMV,KM,MAC,GCM}}/BNMP x {{B,TOM,AIMM,AIMM-MC,CODA,ORACLE}} x 4x4 x \
                 {{mesh,torus,ring}} (scale {SCALE}, {RUNS} runs) + oracle-warm AIMM on mesh"
            )),
        ),
        ("measured", "true".to_string()),
        ("opc_by_slice", jw::obj(&opc_fields)),
        ("warm_aimm_opc_by_bench", jw::obj(&warm_rows)),
        ("cells", format!("[{}]", cells_json.join(","))),
        ("regenerate", jw::string("cargo bench --bench policy_faceoff")),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_policy.json");
    std::fs::write(path, &json).expect("write BENCH_policy.json");
    println!("wrote {path} ({} cells) in {wall:?}", results.len());
}
