//! Bench: regenerate Fig 14 (dynamic energy) and the §7.7 area table.
use aimm::bench::{area_table, fig14};

fn main() {
    let t0 = std::time::Instant::now(); // detlint: allow(wall-clock) — report timing only
    println!("{}", fig14(0.12, 2).expect("fig14").render());
    println!("{}", area_table().render());
    println!("fig14 regenerated in {:?}", t0.elapsed());
}
