//! Bench: polled vs next-event engine on the **default** `aimm sweep`
//! grid (27 cells, scale 0.12, 2 runs) — the acceptance measurement for
//! the event engine (EXPERIMENTS.md §Perf). Verifies the two engines'
//! reports are byte-identical while timing them, then records the
//! wall-clock ratio in `BENCH_engine.json` at the repository root.
//!
//! Run with `cargo bench --bench engine_speedup` (release; ignore debug
//! numbers).

use std::time::Instant;

use aimm::bench::sweep::{default_threads, report_json, run_grid, SweepGrid};
use aimm::config::Engine;

fn time_default_grid(engine: Engine, threads: usize) -> (f64, String) {
    let mut grid = SweepGrid::new(0.12, 2);
    grid.engine = engine;
    let cells = grid.cells();
    let t0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
    let results = run_grid(&cells, threads).expect("default sweep grid");
    (t0.elapsed().as_secs_f64(), report_json(&results))
}

fn main() {
    let threads = default_threads();
    println!("default sweep grid (27 cells, scale 0.12, 2 runs) on {threads} thread(s)");
    let (polled_s, polled_report) = time_default_grid(Engine::Polled, threads);
    println!("  polled: {polled_s:.2}s");
    let (event_s, event_report) = time_default_grid(Engine::Event, threads);
    println!("  event:  {event_s:.2}s");
    assert_eq!(
        polled_report, event_report,
        "engines must produce byte-identical sweep reports"
    );
    let speedup = polled_s / event_s.max(1e-12);
    println!("  speedup: {speedup:.2}x (reports byte-identical)");

    let json = format!(
        "{{\"schema\":\"aimm-engine-bench-v1\",\
         \"grid\":\"default 27-cell sweep (scale 0.12, 2 runs)\",\
         \"measured\":true,\
         \"profile\":\"{}\",\
         \"threads\":{threads},\
         \"polled_wall_s\":{polled_s:.3},\
         \"event_wall_s\":{event_s:.3},\
         \"speedup\":{speedup:.3},\
         \"reports_identical\":true,\
         \"regenerate\":\"cargo bench --bench engine_speedup\"}}",
        if cfg!(debug_assertions) { "debug" } else { "release" },
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json");
    std::fs::write(path, &json).expect("write BENCH_engine.json");
    println!("wrote {path}");
}
