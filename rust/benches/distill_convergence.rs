//! Bench: oracle-distillation convergence study (EXPERIMENTS.md
//! §Distill). Runs the §6.1 five-run episode protocol from four
//! starting points — cold vs oracle-warm-started, single-agent AIMM vs
//! the per-MC AIMM-MC pool — on two trace families, and reports how
//! many episodes each variant needs to reach 95% of its own
//! steady-state OPC. The paper's claim for distillation is exactly this
//! curve: imitating the oracle's first-touch placement before cycle 0
//! buys back early-episode OPC that a cold agent spends exploring.
//! Writes `BENCH_distill.json` at the repository root (fixed key order,
//! so re-runs diff clean — wall times are printed, never serialized).
//!
//! Run with `cargo bench --bench distill_convergence` (release; ignore
//! debug numbers). CI's serial job executes this on every push.

use std::time::Instant;

use aimm::agent::WarmStart;
use aimm::bench::sweep::atomic_write_text;
use aimm::bench::Table;
use aimm::config::{MappingScheme, SystemConfig};
use aimm::coordinator::{episode_ops, run_stream_policy, warm_started_policy};
use aimm::runtime::json::write as jw;
use aimm::workloads::Benchmark;

/// Small enough for CI's serial job, big enough that the agent sees
/// multiple invocation windows per run and the OPC curve has shape.
const SCALE: f64 = 0.04;
/// The paper's single-program protocol: 5 repeated runs, simulation
/// state cleared and the learner retained between runs.
const RUNS: usize = 5;

const BENCHES: [Benchmark; 2] = [Benchmark::Spmv, Benchmark::Gcm];

const VARIANTS: [(&str, MappingScheme, WarmStart); 4] = [
    ("AIMM cold", MappingScheme::Aimm, WarmStart::None),
    ("AIMM warm", MappingScheme::Aimm, WarmStart::Oracle),
    ("AIMM-MC cold", MappingScheme::AimmMc, WarmStart::None),
    ("AIMM-MC warm", MappingScheme::AimmMc, WarmStart::Oracle),
];

/// 1-based episode index where the variant first reaches 95% of its own
/// final-run OPC — the study's headline number. Self-referential on
/// purpose: it measures the shape of each curve, not who wins (the
/// face-off bench ranks policies).
fn episodes_to_95pct(opcs: &[f64]) -> usize {
    let target = opcs.last().copied().unwrap_or(0.0) * 0.95;
    opcs.iter().position(|&o| o >= target).map(|i| i + 1).unwrap_or(opcs.len())
}

fn main() {
    let t0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
    let mut bench_fields: Vec<(String, String)> = Vec::new();

    for &b in &BENCHES {
        let mut t = Table::new(
            &format!("Distillation convergence on {} ({RUNS}-run protocol)", b.name()),
            &["variant", "distilled examples", "episodes to 95%", "run-1 opc", "final opc"],
        );
        let mut variant_fields: Vec<(&str, String)> = Vec::new();
        let mut ops_done: Vec<u64> = Vec::new();
        for &(label, mapping, warm) in &VARIANTS {
            let mut cfg = SystemConfig::default();
            cfg.mapping = mapping;
            let (ops, name) = episode_ops(&cfg, &[b], SCALE).expect("episode ops");
            let (policy, distill) =
                warm_started_policy(&cfg, &ops, warm).expect("starting policy");
            let examples: usize = distill.iter().map(|d| d.examples).sum();
            let (summary, _) =
                run_stream_policy(&cfg, &ops, RUNS, &name, policy).expect("episode");
            let opcs: Vec<f64> = summary.runs.iter().map(|r| r.opc()).collect();
            let episodes = episodes_to_95pct(&opcs);
            ops_done.push(summary.last().ops_completed);
            t.row(vec![
                label.into(),
                examples.to_string(),
                episodes.to_string(),
                format!("{:.4}", opcs[0]),
                format!("{:.4}", opcs[RUNS - 1]),
            ]);
            variant_fields.push((
                label,
                jw::obj(&[
                    ("distill_examples", examples.to_string()),
                    ("episodes_to_95pct", episodes.to_string()),
                    ("run1_opc", jw::num(opcs[0])),
                    ("final_opc", jw::num(opcs[RUNS - 1])),
                ]),
            ));
        }
        // Warm-starting pre-trains weights; it must not perturb the op
        // stream itself.
        assert!(
            ops_done.windows(2).all(|w| w[0] == w[1]),
            "op stream drifted across {} variants",
            b.name()
        );
        println!("{}", t.render());
        bench_fields.push((b.name().to_string(), jw::obj(&variant_fields)));
    }

    let wall = t0.elapsed();
    let fields: Vec<(&str, String)> =
        bench_fields.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let json = jw::obj(&[
        ("schema", jw::string("aimm-distill-bench-v1")),
        (
            "grid",
            jw::string(&format!(
                "{{SPMV,GCM}} x {{AIMM,AIMM-MC}} x {{cold,oracle-warm}} x {RUNS} runs \
                 (scale {SCALE})"
            )),
        ),
        ("measured", "true".to_string()),
        ("benches", jw::obj(&fields)),
        ("regenerate", jw::string("cargo bench --bench distill_convergence")),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_distill.json");
    atomic_write_text(std::path::Path::new(path), &json).expect("write BENCH_distill.json");
    println!("wrote {path} in {wall:?}");
}
