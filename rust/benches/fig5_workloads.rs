//! Bench: regenerate Fig 5a/5b/5c (workload analysis) and time it.
use aimm::bench::{bench_fn, fig5a, fig5b, fig5c};

fn main() {
    let scale = 0.25;
    println!("{}", fig5a(scale, 7).render());
    println!("{}", fig5b(scale, 7).render());
    println!("{}", fig5c(scale, 7).render());
    let r = bench_fn("fig5 full analysis", 1, 5, || {
        let _ = (fig5a(scale, 7), fig5b(scale, 7), fig5c(scale, 7));
    });
    println!("{}", r.report());
}
