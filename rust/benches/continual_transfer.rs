//! Bench: cross-program continual-learning transfer (EXPERIMENTS.md
//! §Curriculum). Runs the two paper-benchmark curriculum sequences
//! SC→KM→RD and LUD→RBM carrying one agent end-to-end, re-runs every
//! stage cold as the baseline, and records the warm-start cells in
//! `BENCH_continual.json` at the repository root (fixed key order, so
//! re-runs on the same toolchain diff clean).
//!
//! Run with `cargo bench --bench continual_transfer` (release; ignore
//! debug numbers).

use std::time::Instant;

use aimm::bench::sweep::{continual_report_json, ContinualSequence};
use aimm::config::{MappingScheme, SystemConfig};
use aimm::coordinator::{run_curriculum, CurriculumStage};
use aimm::workloads::Benchmark;

/// Matches the engine-speedup bench grid: small enough for CI, big
/// enough that the agent actually learns within a stage.
const SCALE: f64 = 0.12;

fn sequence(name: &str, stages: &[&[Benchmark]]) -> ContinualSequence {
    let mut cfg = SystemConfig::default();
    cfg.mapping = MappingScheme::Aimm;
    let stages: Vec<CurriculumStage> =
        stages.iter().map(|&b| CurriculumStage::new(b.to_vec())).collect();
    let t0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
    let (report, agent) =
        run_curriculum(&cfg, &stages, SCALE, None).expect("curriculum sequence");
    let agent = agent.expect("AIMM curriculum carries an agent");
    println!(
        "{name}: {} stages in {:?} (agent: {} invocations, {} train steps)",
        report.stages.len(),
        t0.elapsed(),
        agent.stats.invocations,
        agent.stats.train_steps,
    );
    for s in &report.stages {
        println!(
            "  {:>12}: cold first {:.4} → warm first {:.4} ({:+.1}%), warm last {:.4}",
            s.name,
            s.cold_first_opc(),
            s.warm_first_opc(),
            s.transfer_gain() * 100.0,
            s.warm.last().opc(),
        );
    }
    ContinualSequence {
        name: name.to_string(),
        technique: cfg.technique,
        mapping: cfg.mapping,
        scale: SCALE,
        seed: cfg.seed,
        report,
    }
}

fn main() {
    let seqs = vec![
        sequence("SC>KM>RD", &[&[Benchmark::Sc], &[Benchmark::Km], &[Benchmark::Rd]]),
        sequence("LUD>RBM", &[&[Benchmark::Lud], &[Benchmark::Rbm]]),
    ];
    let json = continual_report_json(&seqs);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_continual.json");
    std::fs::write(path, &json).expect("write BENCH_continual.json");
    println!("wrote {path} ({} sequences)", seqs.len());
}
