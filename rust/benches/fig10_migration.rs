//! Bench: regenerate Fig 10 (migration statistics).
use aimm::bench::fig10;

fn main() {
    let t0 = std::time::Instant::now(); // detlint: allow(wall-clock) — report timing only
    println!("{}", fig10(0.12, 2).expect("fig10").render());
    println!("fig10 regenerated in {:?}", t0.elapsed());
}
