//! Bench: regenerate Fig 7 (avg hop count + computation utilization).
use aimm::bench::fig7;

fn main() {
    let t0 = std::time::Instant::now(); // detlint: allow(wall-clock) — report timing only
    println!("{}", fig7(0.12, 2).expect("fig7").render());
    println!("fig7 regenerated in {:?}", t0.elapsed());
}
