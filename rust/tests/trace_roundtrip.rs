//! The aimm-trace-v1 capture/replay battery (EXPERIMENTS.md §Trace,
//! DESIGN.md §14). Locks down the trace frontend's headline guarantee —
//! a captured episode replays **bit-identically** to the generated run
//! under both engines — plus the format's canonical-form property
//! (write→parse→write is the identity on bytes), the parser's loud
//! failure modes, the streaming reader's bounded lookahead, and a
//! committed golden trace whose replay stats are byte-pinned across PRs.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use aimm::bench::sweep::{atomic_write_text, stats_json};
use aimm::config::{Engine, MappingScheme, SystemConfig, Technique};
use aimm::coordinator::{episode_ops, fresh_agent, run_episode_with, run_traced_with, System};
use aimm::mapping::AnyPolicy;
use aimm::metrics::RunStats;
use aimm::nmp::{NmpOp, OpKind};
use aimm::workloads::{generate, render_trace, Benchmark, FileProvider, FileTrace, TraceProvider};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("aimm_trace_rt_{}_{name}", std::process::id()))
}

fn write_tmp(name: &str, text: &str) -> PathBuf {
    let p = tmp(name);
    atomic_write_text(&p, text).expect("write temp trace");
    p
}

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

/// Bit-level identity, same digest the engine-equivalence suite pins:
/// the fixed-key JSON covers every scalar aggregate, the timeline and
/// float fields are compared through their raw bits.
fn assert_identical(g: &RunStats, r: &RunStats, ctx: &str) {
    assert_eq!(stats_json(g), stats_json(r), "stats diverged: {ctx}");
    assert_eq!(g.opc_timeline.len(), r.opc_timeline.len(), "timeline length: {ctx}");
    for (i, (a, b)) in g.opc_timeline.iter().zip(&r.opc_timeline).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "timeline[{i}]: {ctx}");
    }
}

fn capture_of(cfg: &SystemConfig, benches: &[Benchmark], scale: f64, tag: &str) -> FileTrace {
    let (ops, name) = episode_ops(cfg, benches, scale).expect("episode ops");
    let text = render_trace(&name, scale, &ops).expect("render capture");
    let path = write_tmp(&format!("cap_{tag}.tr"), &text);
    FileTrace::open(&path).expect("open capture")
}

// ---------------------------------------------------------------------
// Tentpole: capture → replay is bit-identical
// ---------------------------------------------------------------------

/// Three benchmarks (incl. the GCM trace family) × two offload
/// techniques × both engines, two runs each: the replayed episode's
/// stats match the generated episode's to the bit on every run.
#[test]
fn capture_replay_is_bit_identical_across_benchmarks_techniques_engines() {
    for bench in [Benchmark::Mac, Benchmark::Spmv, Benchmark::Gcm] {
        for technique in [Technique::Bnmp, Technique::Pei] {
            for engine in Engine::ALL {
                let mut cfg = SystemConfig::default();
                cfg.technique = technique;
                cfg.engine = engine;
                let ctx = format!("{}/{}/{}", bench.name(), technique.name(), engine.name());
                let file = capture_of(&cfg, &[bench], 0.03, &ctx.replace('/', "_"));
                let (gen_s, _) =
                    run_episode_with(&cfg, &[bench], 0.03, 2, None).expect("generated");
                let (rep_s, _) = run_traced_with(&cfg, &file, 2, None).expect("replayed");
                assert_eq!(gen_s.runs.len(), rep_s.runs.len(), "{ctx}");
                for (i, (g, r)) in gen_s.runs.iter().zip(&rep_s.runs).enumerate() {
                    assert_identical(g, r, &format!("{ctx} run {i}"));
                }
            }
        }
    }
}

/// The learning policy replays too: a multi-program capture (interleaved
/// pids) under AIMM, with identically-seeded cold agents on both sides,
/// stays bit-identical across both runs — the agent sees the same op
/// stream through either frontend.
#[test]
fn multi_program_capture_replays_bit_identically_under_aimm() {
    let mut cfg = SystemConfig::default();
    cfg.mapping = MappingScheme::Aimm;
    let benches = [Benchmark::Rd, Benchmark::Km];
    let file = capture_of(&cfg, &benches, 0.03, "multi_aimm");
    assert_eq!(file.pid_count(), 2, "multi-program capture carries both pids");
    let (gen_s, _) =
        run_episode_with(&cfg, &benches, 0.03, 2, Some(fresh_agent(&cfg).unwrap()))
            .expect("generated");
    let (rep_s, _) = run_traced_with(&cfg, &file, 2, Some(fresh_agent(&cfg).unwrap()))
        .expect("replayed");
    for (i, (g, r)) in gen_s.runs.iter().zip(&rep_s.runs).enumerate() {
        assert_identical(g, r, &format!("RD-KM/AIMM run {i}"));
    }
}

/// The oracle's replay path profiles the trace by *streaming* it
/// (OracleProfiler) where the generated path profiles the op vector —
/// the two assignments, and therefore the runs, must agree to the bit.
#[test]
fn oracle_replay_matches_generated_oracle_bit_for_bit() {
    let mut cfg = SystemConfig::default();
    cfg.mapping = MappingScheme::Oracle;
    let file = capture_of(&cfg, &[Benchmark::Spmv], 0.03, "oracle");
    let (gen_s, _) = run_episode_with(&cfg, &[Benchmark::Spmv], 0.03, 2, None).expect("generated");
    let (rep_s, _) = run_traced_with(&cfg, &file, 2, None).expect("replayed");
    for (i, (g, r)) in gen_s.runs.iter().zip(&rep_s.runs).enumerate() {
        assert_identical(g, r, &format!("SPMV/ORACLE run {i}"));
    }
}

// ---------------------------------------------------------------------
// Canonical form: write → parse → write is the identity
// ---------------------------------------------------------------------

#[test]
fn write_parse_write_is_byte_identical_for_every_benchmark() {
    for b in Benchmark::ALL {
        let trace = generate(b, 1, 0.02, 11);
        let text = render_trace(b.name(), 0.02, &trace.ops).expect("render");
        let path = write_tmp(&format!("wpw_{}.tr", b.name()), &text);
        let file = FileTrace::open(&path).expect("parse");
        assert_eq!(file.render().expect("re-render"), text, "{} drifted", b.name());
        let _ = std::fs::remove_file(path);
    }
}

// ---------------------------------------------------------------------
// Golden fixture: committed trace, byte-pinned replay stats
// ---------------------------------------------------------------------

/// The committed hand-written trace parses, is already in canonical
/// form, and replays to stats pinned byte-for-byte across PRs.
/// Bootstrapping mirrors sweep_golden.rs: on a checkout without the
/// stats pin, both engines must agree before the pin is written.
#[test]
fn golden_trace_fixture_replays_to_pinned_stats() {
    let tr = fixture("trace_golden.tr");
    let file = FileTrace::open(&tr).expect("golden trace parses");
    assert_eq!(file.name(), "GOLDEN");
    assert_eq!((file.pid_count(), file.op_count()), (2, 10));
    let committed = std::fs::read_to_string(&tr).expect("read golden trace");
    assert_eq!(
        file.render().expect("render"),
        committed,
        "committed golden trace is not in canonical writer form"
    );

    let cfg = SystemConfig::default();
    let (s, _) = run_traced_with(&cfg, &file, 2, None).expect("replay golden");
    let digest = |runs: &[RunStats]| {
        format!("[{}]", runs.iter().map(stats_json).collect::<Vec<_>>().join(","))
    };
    let got = digest(&s.runs);
    let pin = fixture("trace_golden_stats.json");
    if !pin.exists() {
        let mut polled = SystemConfig::default();
        polled.engine = Engine::Polled;
        let (p, _) = run_traced_with(&polled, &file, 2, None).expect("replay golden (polled)");
        assert_eq!(
            got,
            digest(&p.runs),
            "engines disagree on the golden trace — refusing to bootstrap the stats pin"
        );
        std::fs::write(&pin, &got).expect("bootstrap golden trace stats");
        eprintln!("bootstrapped {} — commit it to pin cross-PR replay behaviour", pin.display());
        return;
    }
    let golden = std::fs::read_to_string(&pin).expect("read golden stats");
    assert_eq!(
        got, golden,
        "golden trace replay diverged from {} — if the behavioural change is \
         intentional, delete the pin, rerun, and commit the regenerated file",
        pin.display()
    );
}

// ---------------------------------------------------------------------
// Parser failure modes: loud, with path:line
// ---------------------------------------------------------------------

/// A tiny canonical trace (header + 3 ops) the negative tests mutate.
fn tiny_ops() -> Vec<NmpOp> {
    vec![
        NmpOp { pid: 1, kind: OpKind::Add, dest: 0x1000, src1: 0x2000, src2: None },
        NmpOp { pid: 2, kind: OpKind::Mac, dest: 0x3000, src1: 0x4000, src2: Some(0x5000) },
        NmpOp { pid: 1, kind: OpKind::Max, dest: 0x1000, src1: 0x3000, src2: None },
    ]
}

fn tiny_text() -> String {
    render_trace("TINY", 0.25, &tiny_ops()).expect("tiny trace")
}

fn open_err(name: &str, text: &str) -> String {
    let path = write_tmp(name, text);
    let err = FileTrace::open(&path).expect_err("open must fail");
    let chain = format!("{err:#}");
    let _ = std::fs::remove_file(&path);
    chain
}

#[test]
fn open_rejects_truncated_file_with_line_number() {
    let text = tiny_text();
    let truncated: String = text.lines().take(3).map(|l| format!("{l}\n")).collect();
    let err = open_err("trunc.tr", &truncated);
    assert!(err.contains("truncated trace"), "{err}");
    assert!(err.contains("header declares 3 ops, file ends after 2"), "{err}");
    assert!(err.contains(":4"), "missing line number: {err}");
}

#[test]
fn open_rejects_garbage_op_line_with_line_number() {
    let text = tiny_text().replace(
        "{\"pid\":\"0x2\",\"kind\":\"MAC\"",
        "this is not json {\"pid\":\"0x2\",\"kind\":\"MAC\"",
    );
    let err = open_err("garbage.tr", &text);
    assert!(err.contains("op line is not valid JSON"), "{err}");
    assert!(err.contains(":3"), "missing line number: {err}");
}

#[test]
fn open_rejects_extra_ops_as_header_count_mismatch() {
    let mut text = tiny_text();
    let last = text.lines().last().unwrap().to_string();
    text.push_str(&last);
    text.push('\n');
    let err = open_err("extra.tr", &text);
    assert!(err.contains("content after the declared 3 ops"), "{err}");
    assert!(err.contains("header op count mismatch"), "{err}");
    assert!(err.contains(":5"), "missing line number: {err}");
}

#[test]
fn open_rejects_duplicate_header_with_line_number() {
    // Concatenating two captures: the second header lands mid-file.
    let tiny = tiny_text();
    let header = tiny.lines().next().unwrap();
    let mut lines: Vec<&str> = tiny.lines().collect();
    lines.insert(2, header);
    let text: String = lines.iter().map(|l| format!("{l}\n")).collect();
    let err = open_err("dup.tr", &text);
    assert!(err.contains("duplicate header line"), "{err}");
    assert!(err.contains(":3"), "missing line number: {err}");
}

#[test]
fn open_rejects_pid_outside_declared_range() {
    use aimm::workloads::trace_file::{header_line, op_line};
    let op = NmpOp { pid: 2, kind: OpKind::Add, dest: 0x1000, src1: 0x2000, src2: None };
    let text = format!("{}\n{}\n", header_line("T", 1, 0.5, 1), op_line(&op));
    let err = open_err("pid_range.tr", &text);
    assert!(err.contains("outside the declared range 1..=1"), "{err}");
    assert!(err.contains(":2"), "missing line number: {err}");
}

#[test]
fn open_rejects_missing_pid_coverage() {
    use aimm::workloads::trace_file::{header_line, op_line};
    let op = NmpOp { pid: 1, kind: OpKind::Add, dest: 0x1000, src1: 0x2000, src2: None };
    let text = format!("{}\n{}\n", header_line("T", 2, 0.5, 1), op_line(&op));
    let err = open_err("pid_cover.tr", &text);
    assert!(err.contains("header declares 2 pid(s) but pid 2 never appears"), "{err}");
}

#[test]
fn open_rejects_wrong_schema_and_empty_file() {
    // The wrong tag is built at runtime — a literal would trip the
    // detlint schema-freeze rule.
    let wrong = "aimm-trace-v1".replace("v1", "v9");
    let text = tiny_text().replace("aimm-trace-v1", &wrong);
    let err = open_err("schema.tr", &text);
    assert!(err.contains("expected schema"), "{err}");
    assert!(err.contains(":1"), "missing line number: {err}");
    let err = open_err("empty.tr", "");
    assert!(err.contains("empty file (no header line)"), "{err}");
}

#[test]
fn blank_lines_are_ignored_everywhere() {
    let tiny = tiny_text();
    let spaced: String = tiny.lines().map(|l| format!("\n{l}\n\n")).collect();
    let path = write_tmp("spaced.tr", &spaced);
    let file = FileTrace::open(&path).expect("blank lines are legal");
    assert_eq!(file.op_count(), 3);
    // Canonical render strips the blanks again.
    assert_eq!(file.render().unwrap(), tiny);
    let _ = std::fs::remove_file(path);
}

// ---------------------------------------------------------------------
// Streaming contract: bounded lookahead, never slurps
// ---------------------------------------------------------------------

/// A counting wrapper asserting the lookahead occupancy never exceeds
/// the configured cap while a full simulation drains the provider.
struct CappedCheck {
    inner: FileProvider,
    cap: usize,
    max_buffered: Arc<AtomicUsize>,
}

impl TraceProvider for CappedCheck {
    fn peek(&self) -> Option<NmpOp> {
        self.inner.peek()
    }
    fn consume(&mut self) -> anyhow::Result<()> {
        self.inner.consume()?;
        let b = self.inner.buffered();
        assert!(b <= self.cap, "lookahead {b} exceeded cap {}", self.cap);
        self.max_buffered.fetch_max(b, Ordering::Relaxed);
        Ok(())
    }
    fn consumed(&self) -> u64 {
        self.inner.consumed()
    }
    fn drained(&self) -> bool {
        self.inner.drained()
    }
    fn total_ops(&self) -> u64 {
        self.inner.total_ops()
    }
    fn pids(&self) -> &[aimm::config::Pid] {
        self.inner.pids()
    }
    fn distinct_pages(&self) -> u64 {
        self.inner.distinct_pages()
    }
}

/// Replays a >100k-op capture through an 8-op lookahead: completion
/// proves the reader streams (a slurping reader would need the whole op
/// vector; the probe proves at most 8 ops were ever buffered).
#[test]
fn large_trace_replays_through_a_tiny_bounded_buffer() {
    let trace = generate(Benchmark::Mac, 1, 2.0, 11);
    assert!(trace.ops.len() > 100_000, "need >100k ops, got {}", trace.ops.len());
    let text = render_trace("MAC-big", 2.0, &trace.ops).expect("render big");
    let path = write_tmp("big.tr", &text);
    let file = FileTrace::open(&path).expect("open big");
    let max = Arc::new(AtomicUsize::new(0));
    let provider = CappedCheck {
        inner: file.provider_with_cap(8).expect("capped provider"),
        cap: 8,
        max_buffered: max.clone(),
    };
    let cfg = SystemConfig::default();
    let policy = AnyPolicy::new(&cfg, &[], None);
    let mut sys = System::with_provider(cfg.clone(), Box::new(provider), policy);
    let stats = sys.run().expect("bounded replay");
    assert_eq!(stats.ops_completed, trace.ops.len() as u64);
    let m = max.load(Ordering::Relaxed);
    assert!(m > 0 && m <= 8, "lookahead probe out of range: {m}");
    let _ = std::fs::remove_file(path);
}

/// The provider trait stays object-safe: System consumes it boxed.
#[test]
fn provider_trait_is_object_safe_and_reports_totals() {
    let text = tiny_text();
    let path = write_tmp("dyn.tr", &text);
    let file = FileTrace::open(&path).expect("open");
    let p: Box<dyn TraceProvider> = Box::new(file.provider().expect("provider"));
    assert_eq!(p.total_ops(), 3);
    assert_eq!(p.pids(), &[1, 2]);
    assert!(!p.drained());
    let _ = std::fs::remove_file(path);
}

/// The provider seam keeps `distinct_pages` exact: at end of run the
/// streaming count equals the eager whole-trace count.
#[test]
fn streaming_distinct_pages_matches_the_eager_count() {
    let trace = generate(Benchmark::Spmv, 1, 0.03, 11);
    let text = render_trace("SPMV", 0.03, &trace.ops).expect("render");
    let path = write_tmp("distinct.tr", &text);
    let file = FileTrace::open(&path).expect("open");
    let mut p = file.provider().expect("provider");
    while p.peek().is_some() {
        p.consume().expect("consume");
    }
    assert_eq!(p.distinct_pages(), trace.distinct_pages() as u64);
    let _ = std::fs::remove_file(path);
}
