//! Integration tests: whole-system episodes across techniques, mappings,
//! mesh sizes and program mixes, plus cross-module invariants that only
//! show up when everything is wired together.

use aimm::agent::AimmAgent;
use aimm::config::{MappingScheme, SystemConfig, Technique};
use aimm::coordinator::{run_single, run_stream, System};
use aimm::nmp::{NmpOp, OpKind};
use aimm::runtime::LinearQ;
use aimm::workloads::{generate, interleave, Benchmark};

fn cfg() -> SystemConfig {
    SystemConfig::default()
}

fn small_trace(bench: Benchmark) -> Vec<NmpOp> {
    generate(bench, 1, 0.03, 11).ops
}

#[test]
fn every_technique_times_every_mapping_completes() {
    // All five registered policies — B, TOM, AIMM, CODA, ORACLE.
    for technique in Technique::ALL {
        for mapping in MappingScheme::ALL {
            let mut c = cfg();
            c.technique = technique;
            c.mapping = mapping;
            let ops = small_trace(Benchmark::Spmv);
            let n = ops.len() as u64;
            // AIMM path uses the linear mock for test determinism/speed.
            let agent = mapping.uses_agent().then(|| {
                AimmAgent::new(Box::new(LinearQ::new(1e-2, 0.95, 3)), c.agent.clone(), 5)
            });
            let mut sys = System::new(c, ops, agent);
            let stats = sys.run().unwrap();
            assert_eq!(stats.ops_completed, n, "{technique}/{mapping}");
        }
    }
}

#[test]
fn all_benchmarks_complete_on_bnmp() {
    for b in Benchmark::ALL {
        let ops = small_trace(b);
        let n = ops.len() as u64;
        let mut sys = System::new(cfg(), ops, None);
        let stats = sys.run().unwrap();
        assert_eq!(stats.ops_completed, n, "{b:?}");
        assert!(stats.cycles > 0);
    }
}

#[test]
fn mesh_8x8_completes() {
    let mut c = cfg();
    c.mesh_cols = 8;
    c.mesh_rows = 8;
    let ops = small_trace(Benchmark::Km);
    let n = ops.len() as u64;
    let mut sys = System::new(c, ops, None);
    assert_eq!(sys.run().unwrap().ops_completed, n);
}

#[test]
fn deterministic_baseline_runs() {
    let ops = small_trace(Benchmark::Pr);
    let a = System::new(cfg(), ops.clone(), None).run().unwrap();
    let b = System::new(cfg(), ops, None).run().unwrap();
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.ops_completed, b.ops_completed);
    assert_eq!(a.energy, b.energy);
}

#[test]
fn multi_program_with_hoard_isolates_processes() {
    let (ops, traces) = interleave(
        vec![generate(Benchmark::Mac, 0, 0.02, 1), generate(Benchmark::Rd, 0, 0.02, 2)],
        7,
    );
    let mut c = cfg();
    c.hoard = true;
    let n = ops.len() as u64;
    let mut sys = System::new(c, ops, None);
    let stats = sys.run().unwrap();
    assert_eq!(stats.ops_completed, n);
    // HOARD co-location: each process's pages should occupy few cubes.
    for t in &traces {
        let mut cubes: Vec<usize> =
            sys.mmu.mappings(t.pid).iter().map(|(_, loc)| loc.cube).collect();
        cubes.sort_unstable();
        cubes.dedup();
        assert!(
            cubes.len() <= 8,
            "pid {} spread over {} cubes under HOARD",
            t.pid,
            cubes.len()
        );
    }
}

#[test]
fn aimm_agent_state_machine_over_runs() {
    let mut c = cfg();
    c.mapping = MappingScheme::Aimm;
    let ops = small_trace(Benchmark::Rbm);
    let mut agent = Some(AimmAgent::new(
        Box::new(LinearQ::new(1e-2, 0.95, 3)),
        c.agent.clone(),
        5,
    ));
    let mut total_inv = 0;
    for _ in 0..3 {
        let mut sys = System::new(c.clone(), ops.clone(), agent.take());
        sys.run().unwrap();
        agent = sys.take_agent();
        let a = agent.as_ref().unwrap();
        assert!(a.stats.invocations >= total_inv, "invocations monotone");
        total_inv = a.stats.invocations;
    }
    // Replay memory accumulated experience across runs.
    assert!(agent.unwrap().replay.len() > 0);
}

#[test]
fn migration_preserves_translation_correctness() {
    // After an AIMM run with migrations, every trace page must still
    // translate, and no two pages may share a (cube, frame).
    let mut c = cfg();
    c.mapping = MappingScheme::Aimm;
    let ops = small_trace(Benchmark::Km);
    let agent =
        AimmAgent::new(Box::new(LinearQ::new(1e-2, 0.95, 3)), c.agent.clone(), 5);
    let mut sys = System::new(c, ops.clone(), Some(agent));
    sys.run().unwrap();
    let mappings = sys.mmu.mappings(1);
    let mut frames: Vec<(usize, u64)> =
        mappings.iter().map(|(_, loc)| (loc.cube, loc.frame)).collect();
    let before = frames.len();
    frames.sort_unstable();
    frames.dedup();
    assert_eq!(frames.len(), before, "two vpages share a physical frame");
    for op in &ops {
        for p in op.vpages() {
            assert!(
                sys.mmu.translate(op.pid, p).is_some(),
                "page {p:#x} lost its mapping"
            );
        }
    }
}

#[test]
fn runner_protocol_matches_paper() {
    // §6.1: per-run stats independent for baseline; agent carried for AIMM.
    let c = cfg();
    let s = run_single(&c, Benchmark::Mac, 0.02, 3).unwrap();
    assert_eq!(s.runs.len(), 3);
    assert!(s.runs.windows(2).all(|w| w[0].cycles == w[1].cycles));

    let mut ca = cfg();
    ca.mapping = MappingScheme::Aimm;
    let s = run_single(&ca, Benchmark::Mac, 0.02, 2).unwrap();
    assert!(s.runs.iter().all(|r| r.agent_invocations > 0));
}

#[test]
fn run_stream_handles_empty_guard() {
    // A tiny stream still produces sane stats.
    let c = cfg();
    let ops = vec![NmpOp { pid: 1, kind: OpKind::Add, dest: 0x1000, src1: 0x2000, src2: None }];
    let s = run_stream(&c, &ops, 1, "tiny").unwrap();
    assert_eq!(s.last().ops_completed, 1);
    assert!(s.last().opc() > 0.0);
}

#[test]
fn energy_accumulates_and_aimm_adds_hardware_energy() {
    let base = {
        let mut sys = System::new(cfg(), small_trace(Benchmark::Km), None);
        sys.run().unwrap()
    };
    let aimm = {
        let mut c = cfg();
        c.mapping = MappingScheme::Aimm;
        let agent =
            AimmAgent::new(Box::new(LinearQ::new(1e-2, 0.95, 3)), c.agent.clone(), 5);
        let mut sys = System::new(c, small_trace(Benchmark::Km), Some(agent));
        sys.run().unwrap()
    };
    assert!(base.energy.memory_nj > 0.0);
    assert!(base.energy.network_nj > 0.0);
    // The agent's weight/replay/state-buffer energy only shows up on AIMM.
    assert!(aimm.energy.aimm_hardware_nj > base.energy.aimm_hardware_nj);
}

#[test]
fn opc_timeline_covers_run() {
    let mut sys = System::new(cfg(), small_trace(Benchmark::Sc), None);
    let stats = sys.run().unwrap();
    let expected = stats.cycles / SystemConfig::default().opc_sample_period;
    assert!(stats.opc_timeline.len() as u64 >= expected.saturating_sub(1));
}
