//! Serve-mode churn determinism battery: the tenant schedule and the
//! whole serve outcome are pure functions of the config seed —
//! byte-identical at any worker count, for every arrival process — and
//! the polled and event engines agree bit-for-bit on a churn scenario,
//! per-tenant accounting included. This extends the engine-equivalence
//! contract (DESIGN.md §8) to the open-loop service: admission,
//! page leasing, departure and eviction must all be clock-exact.
//!
//! Agents are built on the `LinearQ` mock (not `best_qfunction`) so the
//! battery is deterministic in every build flavor.

use aimm::agent::AimmAgent;
use aimm::bench::sweep::stats_json;
use aimm::config::{Engine, MappingScheme, SystemConfig};
use aimm::coordinator::{build_tenants, isolated_baselines, run_serve, serve_stream_with};
use aimm::metrics::RunStats;
use aimm::runtime::LinearQ;
use aimm::workloads::ArrivalProcess;

/// Small but non-trivial: five tenants contending for two slots, so the
/// admission queue, page leases and departures all actually engage.
fn serve_cfg(arrivals: ArrivalProcess, seed: u64) -> SystemConfig {
    let mut c = SystemConfig::default();
    c.mapping = MappingScheme::Aimm;
    c.seed = seed;
    c.serve.arrivals = arrivals;
    c.serve.tenants = 5;
    c.serve.mean_gap = 150;
    c.serve.slots = 2;
    c.serve.page_budget = 2048;
    c.serve.rounds = 1;
    c.serve.scale = 0.02;
    c
}

fn mk_agent(cfg: &SystemConfig) -> AimmAgent {
    AimmAgent::new(
        Box::new(LinearQ::new(cfg.agent.lr, cfg.agent.gamma, 7)),
        cfg.agent.clone(),
        cfg.seed ^ 0xA6E7,
    )
}

/// Bit-level identity, tenants included: the JSON digest covers every
/// scalar aggregate, the tenant rows cover the serve lifecycle, and the
/// float fields are compared through raw bits.
fn assert_identical(a: &RunStats, b: &RunStats, ctx: &str) {
    assert_eq!(stats_json(a), stats_json(b), "stats diverged: {ctx}");
    assert_eq!(a.tenants, b.tenants, "tenant accounting diverged: {ctx}");
    assert_eq!(a.opc_timeline.len(), b.opc_timeline.len(), "timeline length: {ctx}");
    for (i, (x, y)) in a.opc_timeline.iter().zip(&b.opc_timeline).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "timeline[{i}]: {ctx}");
    }
    for (name, x, y) in [
        ("avg_hops", a.avg_hops, b.avg_hops),
        ("avg_packet_latency", a.avg_packet_latency, b.avg_packet_latency),
        ("compute_utilization", a.compute_utilization, b.compute_utilization),
        ("compute_balance", a.compute_balance, b.compute_balance),
        ("row_hit_rate", a.row_hit_rate, b.row_hit_rate),
        ("agent_avg_loss", a.agent_avg_loss, b.agent_avg_loss),
        ("agent_cumulative_reward", a.agent_cumulative_reward, b.agent_cumulative_reward),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{name}: {ctx}");
    }
}

/// The tenant schedule (names, pids, arrival cycles, op streams, page
/// footprints) is a pure function of the seed for every arrival
/// process — and actually moves when the seed does.
#[test]
fn tenant_schedule_is_a_pure_function_of_the_seed() {
    for p in ArrivalProcess::ALL {
        let cfg = serve_cfg(p, 42);
        let a = build_tenants(&cfg);
        let b = build_tenants(&cfg);
        assert_eq!(a, b, "{p}: same seed must give an identical tenant schedule");
        let c = build_tenants(&serve_cfg(p, 43));
        assert_ne!(a, c, "{p}: a different seed must move the schedule");
    }
}

/// The whole serve outcome — isolated baselines, per-round stats,
/// slowdown distribution, tail percentiles, fairness — is identical at
/// 1 and 4 workers for every arrival process. Worker threads only run
/// the embarrassingly-parallel isolated baselines; the churn itself is
/// simulated on one clock.
#[test]
fn serve_outcome_is_worker_count_invariant() {
    for p in ArrivalProcess::ALL {
        let cfg = serve_cfg(p, 0xC0FFEE);
        let (one, _) = run_serve(&cfg, 1, Some(mk_agent(&cfg))).expect("1 worker");
        let (four, _) = run_serve(&cfg, 4, Some(mk_agent(&cfg))).expect("4 workers");
        assert_eq!(one.baselines, four.baselines, "{p}: isolated baselines");
        let sa: Vec<u64> = one.slowdowns.iter().map(|x| x.to_bits()).collect();
        let sb: Vec<u64> = four.slowdowns.iter().map(|x| x.to_bits()).collect();
        assert_eq!(sa, sb, "{p}: slowdown distribution");
        for (name, x, y) in [
            ("p50", one.p50, four.p50),
            ("p99", one.p99, four.p99),
            ("p999", one.p999, four.p999),
            ("fairness", one.fairness, four.fairness),
        ] {
            assert_eq!(x.to_bits(), y.to_bits(), "{p}: {name}");
        }
        assert_eq!(one.rounds.len(), four.rounds.len(), "{p}: round count");
        for (i, (ra, rb)) in one.rounds.iter().zip(&four.rounds).enumerate() {
            assert_identical(ra, rb, &format!("{p} round {i}"));
        }
        assert!(one.last_round().ops_completed > 0, "{p}: the service must actually run");
    }
}

/// Polled vs event bit-identity for a bursty churn scenario with the
/// learning agent in the loop, across two service rounds — and the
/// isolated per-tenant baselines agree across engines too (each is a
/// single-tenant run, i.e. exactly the DESIGN.md §8 contract).
#[test]
fn polled_and_event_serve_runs_are_bit_identical() {
    let mut polled = serve_cfg(ArrivalProcess::Bursty, 23);
    polled.serve.rounds = 2;
    let mut event = polled.clone();
    polled.engine = Engine::Polled;
    event.engine = Engine::Event;
    let tenants = build_tenants(&polled);
    assert_eq!(tenants, build_tenants(&event), "the schedule ignores the engine");
    let pagent = Some(mk_agent(&polled));
    let eagent = Some(mk_agent(&event));
    let (p, pa) = serve_stream_with(&polled, &tenants, 2, pagent).expect("polled");
    let (e, ea) = serve_stream_with(&event, &tenants, 2, eagent).expect("event");
    assert_eq!(p.len(), e.len(), "round count");
    for (i, (rp, re)) in p.iter().zip(&e).enumerate() {
        assert_identical(rp, re, &format!("round {i}"));
    }
    assert!(pa.expect("polled agent survives").stats.invocations > 0);
    assert!(ea.expect("event agent survives").stats.invocations > 0);
    let bp = isolated_baselines(&polled, &tenants, 2).expect("polled baselines");
    let be = isolated_baselines(&event, &tenants, 2).expect("event baselines");
    assert_eq!(bp, be, "isolated baselines are engine-invariant");
}
