//! Serve-mode checkpointing: interrupting the service at a round
//! boundary — save the agent, rebuild it the way `--resume` does,
//! finish the remaining rounds — must be bit-identical to the
//! uninterrupted service. This extends the continual-learning
//! checkpoint contract (tests/continual.rs) to the open-loop churn:
//! the agent is the ONLY cross-round state, so a checkpoint captures
//! everything the rest of the service needs.
//!
//! Agents are built on the `LinearQ` mock (not `best_qfunction`) so the
//! battery is deterministic in every build flavor.

use aimm::agent::{AgentCheckpoint, AimmAgent};
use aimm::bench::sweep::stats_json;
use aimm::config::{MappingScheme, SystemConfig};
use aimm::coordinator::{build_tenants, ensure_serve_checkpointable, serve_stream_with};
use aimm::metrics::RunStats;
use aimm::runtime::{LinearQ, QFunction};
use aimm::workloads::ArrivalProcess;

fn serve_cfg(seed: u64) -> SystemConfig {
    let mut c = SystemConfig::default();
    c.mapping = MappingScheme::Aimm;
    c.seed = seed;
    c.serve.arrivals = ArrivalProcess::Poisson;
    c.serve.tenants = 4;
    c.serve.mean_gap = 150;
    c.serve.slots = 2;
    c.serve.page_budget = 2048;
    c.serve.scale = 0.02;
    c
}

fn mk_agent(cfg: &SystemConfig) -> AimmAgent {
    AimmAgent::new(
        Box::new(LinearQ::new(cfg.agent.lr, cfg.agent.gamma, 7)),
        cfg.agent.clone(),
        cfg.seed ^ 0xA6E7,
    )
}

/// Resume-from-checkpoint: rebuild the agent the way `--resume` does,
/// but pinned to the LinearQ backend.
fn rebuild(ck_text: &str, cfg: &SystemConfig) -> AimmAgent {
    let ck = AgentCheckpoint::parse(ck_text).expect("checkpoint parses");
    let mut qf = Box::new(LinearQ::new(0.5, 0.5, 999)); // overwritten by restore
    qf.restore(&ck.q).expect("snapshot restores into linear-mock");
    AimmAgent::from_checkpoint(qf, cfg.agent.clone(), &ck).expect("agent rebuilds")
}

/// Three uninterrupted service rounds vs two rounds + checkpoint +
/// resume + one round: every per-round `RunStats`, tenant accounting
/// included, must match byte for byte.
#[test]
fn mid_churn_checkpoint_resume_is_bit_identical() {
    let cfg = serve_cfg(77);
    let tenants = build_tenants(&cfg);
    let (straight, _) =
        serve_stream_with(&cfg, &tenants, 3, Some(mk_agent(&cfg))).expect("straight");
    let (head, agent) = serve_stream_with(&cfg, &tenants, 2, Some(mk_agent(&cfg))).expect("head");
    let mut agent = agent.expect("agent survives the head rounds");
    assert!(agent.stats.invocations > 0, "the churn must exercise the agent");
    let ck = agent.checkpoint().expect("mid-churn checkpoint").to_json();
    let resumed = rebuild(&ck, &cfg);
    let (tail, _) = serve_stream_with(&cfg, &tenants, 1, Some(resumed)).expect("tail");
    let spliced: Vec<RunStats> = head.into_iter().chain(tail).collect();
    assert_eq!(straight.len(), spliced.len(), "round count");
    for (i, (a, b)) in straight.iter().zip(&spliced).enumerate() {
        assert_eq!(stats_json(a), stats_json(b), "round {i} stats diverged after resume");
        assert_eq!(a.tenants, b.tenants, "round {i} tenant accounting diverged");
        for (j, (x, y)) in a.opc_timeline.iter().zip(&b.opc_timeline).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "round {i} timeline[{j}]");
        }
    }
}

/// Every non-AIMM policy refuses serve-mode checkpointing loudly,
/// naming itself — learned state is the only thing worth saving, and a
/// silent no-op checkpoint would look like a successful one.
#[test]
fn non_aimm_policies_refuse_serve_checkpointing_by_name() {
    for scheme in MappingScheme::ALL {
        let mut cfg = serve_cfg(1);
        cfg.mapping = scheme;
        match ensure_serve_checkpointable(&cfg) {
            Ok(()) => assert!(scheme.checkpointable(), "{scheme}: the guard must fire"),
            Err(err) => {
                let msg = err.to_string();
                assert!(!scheme.checkpointable(), "{scheme}: spurious refusal: {msg}");
                assert!(msg.contains(scheme.name()), "{scheme}: {msg}");
                assert!(msg.contains("not checkpointable"), "{scheme}: {msg}");
            }
        }
    }
}
