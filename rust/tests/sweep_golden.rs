//! Golden-fixture snapshot: the serialized sweep report for a small,
//! fixed grid must stay byte-identical **across PRs**, extending
//! `sweep_determinism.rs` (worker-count invariance within one build) to
//! cross-build invariance. Any change to workload generation, the
//! simulator, the RNG, the agent or the JSON writer shows up here as a
//! byte diff.
//!
//! Bootstrapping: on a checkout without the fixture the test writes
//! `tests/fixtures/sweep_golden.json` and passes — commit that file to
//! arm the snapshot. To *intentionally* change simulator behaviour,
//! delete the fixture, rerun the suite, and commit the regenerated file
//! together with the behavioural change so the diff is reviewable.

use std::path::PathBuf;

use aimm::bench::sweep::{report_json, run_grid, SweepGrid};
use aimm::config::MappingScheme;
use aimm::workloads::Benchmark;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/sweep_golden.json")
}

/// Small but representative: single- and multi-program cells, baseline
/// and learning agent — 6 cells, one run each, tiny traces.
fn golden_grid() -> SweepGrid {
    let mut g = SweepGrid::new(0.03, 1);
    g.benches = vec![
        vec![Benchmark::Mac],
        vec![Benchmark::Spmv],
        vec![Benchmark::Rd, Benchmark::Km],
    ];
    g.mappings = vec![MappingScheme::Baseline, MappingScheme::Aimm];
    g
}

#[test]
fn sweep_report_matches_committed_golden_fixture() {
    let results = run_grid(&golden_grid().cells(), 2).expect("golden sweep");
    let report = report_json(&results);
    let path = fixture_path();
    if !path.exists() {
        // Never pin a one-engine artifact: before writing the fixture,
        // require the polled reference engine to reproduce the report
        // byte-for-byte, so even the bootstrap run asserts something.
        let mut polled = golden_grid();
        polled.engine = aimm::config::Engine::Polled;
        let polled_results = run_grid(&polled.cells(), 2).expect("golden sweep (polled)");
        assert_eq!(
            report,
            report_json(&polled_results),
            "engines disagree on the golden grid — refusing to bootstrap the fixture"
        );
        std::fs::create_dir_all(path.parent().unwrap()).expect("fixtures dir");
        std::fs::write(&path, &report).expect("bootstrap golden fixture");
        eprintln!(
            "bootstrapped {} — commit it to pin cross-PR behaviour",
            path.display()
        );
        return;
    }
    let golden = std::fs::read_to_string(&path).expect("read golden fixture");
    assert_eq!(
        report,
        golden,
        "sweep report diverged from the committed golden fixture {} — if the \
         behavioural change is intentional, delete the fixture, rerun, and \
         commit the regenerated file",
        path.display()
    );
}
