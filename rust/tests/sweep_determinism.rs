//! Sweep determinism: a grid cell must produce byte-identical `RunStats`
//! whatever the worker count (EXPERIMENTS.md §Sweep). This is the
//! contract that makes `aimm sweep` results comparable across machines
//! and the figure harnesses reproducible — the simulator must not leak
//! thread identity (e.g. per-thread hash seeds) into any decision.

use aimm::bench::sweep::{
    cell_json, cell_key, merge_entries, merge_files, report_json, report_json_outcomes,
    run_grid, run_journaled, JournalEntry, ShardSpec, SweepGrid,
};
use aimm::config::{MappingScheme, TopologyKind};
use aimm::workloads::Benchmark;

/// A small but representative grid: baseline + learning agent, single-
/// and multi-program cells, two meshes. 8 cells, tiny traces.
fn grid() -> SweepGrid {
    let mut g = SweepGrid::new(0.04, 2);
    g.benches = vec![vec![Benchmark::Mac], vec![Benchmark::Rd, Benchmark::Spmv]];
    g.mappings = vec![MappingScheme::Baseline, MappingScheme::Aimm];
    g.meshes = vec![(4, 4), (8, 8)];
    g
}

#[test]
fn cells_identical_at_any_worker_count() {
    let cells = grid().cells();
    assert_eq!(cells.len(), 8);
    let serial = run_grid(&cells, 1).expect("serial sweep");
    let parallel = run_grid(&cells, 4).expect("parallel sweep");
    assert_eq!(serial.len(), parallel.len());
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(
            cell_json(s),
            cell_json(p),
            "cell {} diverged between 1 and 4 workers",
            s.cell.name()
        );
    }
    // The whole report (fixed key order, no wall-clock) matches too.
    assert_eq!(report_json(&serial), report_json(&parallel));
}

/// The topology axis obeys the same contract: torus and ring cells are
/// byte-identical at any worker count (wraparound routing, bubble flow
/// control and the ring MC arcs are all deterministic — EXPERIMENTS.md
/// §Topology).
#[test]
fn topology_cells_identical_at_any_worker_count() {
    let mut g = SweepGrid::new(0.03, 1);
    g.benches = vec![vec![Benchmark::Mac], vec![Benchmark::Rd, Benchmark::Spmv]];
    g.mappings = vec![MappingScheme::Baseline, MappingScheme::Aimm];
    g.topologies = vec![TopologyKind::Torus, TopologyKind::Ring];
    let cells = g.cells();
    assert_eq!(cells.len(), 8);
    let serial = run_grid(&cells, 1).expect("serial topology sweep");
    let parallel = run_grid(&cells, 4).expect("parallel topology sweep");
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(
            cell_json(s),
            cell_json(p),
            "cell {} diverged between 1 and 4 workers",
            s.cell.name()
        );
    }
    assert_eq!(report_json(&serial), report_json(&parallel));
    // The off-default cells advertise their topology in name and JSON.
    for r in &serial {
        let topo = r.cell.topology.name();
        assert!(r.cell.name().contains(&format!("/{topo}/")), "{}", r.cell.name());
        assert!(cell_json(r).contains(&format!("\"topology\":\"{topo}\"")));
    }
}

/// The new policies obey the same contract: CODA's windowed counters
/// sort deterministically (never by map-iteration order) and the
/// oracle's dry-run assignment is a pure function of the trace, so
/// their cells are byte-identical at any worker count too.
#[test]
fn coda_and_oracle_cells_identical_at_any_worker_count() {
    let mut g = SweepGrid::new(0.03, 1);
    g.benches = vec![vec![Benchmark::Mac], vec![Benchmark::Rd, Benchmark::Spmv]];
    g.mappings = vec![MappingScheme::Coda, MappingScheme::Oracle];
    let cells = g.cells();
    assert_eq!(cells.len(), 4);
    let serial = run_grid(&cells, 1).expect("serial policy sweep");
    let parallel = run_grid(&cells, 4).expect("parallel policy sweep");
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(
            cell_json(s),
            cell_json(p),
            "cell {} diverged between 1 and 4 workers",
            s.cell.name()
        );
    }
    assert_eq!(report_json(&serial), report_json(&parallel));
    // The new policies are first-class cells: named and serialized like
    // the paper's trio.
    assert!(serial.iter().any(|r| r.cell.name().contains("/CODA/")));
    assert!(serial.iter().any(|r| r.cell.name().contains("/ORACLE/")));
    for r in &serial {
        assert!(r.summary.last().ops_completed > 0, "{}", r.cell.name());
        assert!(cell_json(r).contains(&format!("\"mapping\":\"{}\"", r.cell.mapping.name())));
    }
}

/// The GCM trace family (registry addition, not part of the paper's
/// Table 2 set) obeys the worker-count contract like any other cell:
/// its seeded graph build and mark-phase walk are pure functions of
/// `(pid, scale, seed)`, so GCM cells — alone and interleaved with a
/// paper benchmark — are byte-identical at any worker count.
#[test]
fn gcm_cells_identical_at_any_worker_count() {
    let mut g = SweepGrid::new(0.03, 1);
    g.benches = vec![vec![Benchmark::Gcm], vec![Benchmark::Gcm, Benchmark::Mac]];
    g.mappings = vec![MappingScheme::Baseline, MappingScheme::Aimm];
    let cells = g.cells();
    assert_eq!(cells.len(), 4);
    let serial = run_grid(&cells, 1).expect("serial gcm sweep");
    let parallel = run_grid(&cells, 4).expect("parallel gcm sweep");
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(
            cell_json(s),
            cell_json(p),
            "cell {} diverged between 1 and 4 workers",
            s.cell.name()
        );
    }
    assert_eq!(report_json(&serial), report_json(&parallel));
    for r in &serial {
        assert!(r.summary.last().ops_completed > 0, "{}", r.cell.name());
        assert!(r.cell.name().contains("GCM"), "{}", r.cell.name());
    }
}

/// The v2 learning shapes obey the same contract. AIMM-MC's per-agent
/// seeds (`mc_seed`) and its round-robin gossip ring are pure functions
/// of the cell config — no map-iteration order, no thread identity — so
/// per-MC-pool cells, alone and on the GCM trace family, are
/// byte-identical at any worker count.
#[test]
fn aimm_mc_cells_identical_at_any_worker_count() {
    let mut g = SweepGrid::new(0.03, 1);
    g.benches =
        vec![vec![Benchmark::Mac], vec![Benchmark::Gcm], vec![Benchmark::Rd, Benchmark::Spmv]];
    g.mappings = vec![MappingScheme::AimmMc];
    let cells = g.cells();
    assert_eq!(cells.len(), 3);
    let serial = run_grid(&cells, 1).expect("serial aimm-mc sweep");
    let parallel = run_grid(&cells, 4).expect("parallel aimm-mc sweep");
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(
            cell_json(s),
            cell_json(p),
            "cell {} diverged between 1 and 4 workers",
            s.cell.name()
        );
    }
    assert_eq!(report_json(&serial), report_json(&parallel));
    for r in &serial {
        assert!(r.cell.name().contains("/AIMM-MC/"), "{}", r.cell.name());
        assert!(r.summary.last().agent_invocations > 0, "{}", r.cell.name());
        assert!(cell_json(r).contains("\"mapping\":\"AIMM-MC\""), "{}", r.cell.name());
    }
}

/// Warm-started runs keep the contract too: the oracle dry pass, the
/// dataset derivation, and the distillation batch shuffle are seeded
/// entirely from the cell config, so a warm-started AIMM episode is
/// byte-identical whichever thread builds and runs it.
#[test]
fn warm_started_runs_identical_across_threads() {
    use aimm::agent::WarmStart;
    use aimm::bench::sweep::stats_json;
    use aimm::config::SystemConfig;
    use aimm::coordinator::{episode_ops, run_stream_policy, warm_started_policy};

    fn run_once() -> Vec<String> {
        let mut cfg = SystemConfig::default();
        cfg.mapping = MappingScheme::Aimm;
        cfg.seed = 41;
        let (ops, name) = episode_ops(&cfg, &[Benchmark::Mac], 0.03).expect("episode ops");
        let (policy, stats) =
            warm_started_policy(&cfg, &ops, WarmStart::Oracle).expect("warm start");
        assert!(stats[0].examples > 0, "distillation must see the dry pass");
        let (summary, _) = run_stream_policy(&cfg, &ops, 2, &name, policy).expect("episode");
        summary.runs.iter().map(stats_json).collect()
    }

    let here = run_once();
    let threads: Vec<_> = (0..2).map(|_| std::thread::spawn(run_once)).collect();
    for t in threads {
        let theirs = t.join().expect("worker thread");
        assert_eq!(theirs, here, "warm-started run leaked thread identity");
    }
}

/// Shard-count invariance: slicing the default test grid 2-of-2 or
/// 4-of-4, running every slice at a *different* worker count, and
/// merging the journal entries reproduces the unsharded report
/// byte-for-byte. This is the contract that lets CI fan a sweep across
/// jobs and still compare the merged artifact with `cmp`.
#[test]
fn sharded_merge_is_byte_identical_to_unsharded() {
    let cells = grid().cells();
    let unsharded = report_json(&run_grid(&cells, 2).expect("unsharded sweep"));
    for n in [2usize, 4] {
        let mut entries = Vec::new();
        for s in 0..n {
            let spec = ShardSpec { index: s, count: n };
            let owned: Vec<usize> = (0..cells.len()).filter(|&i| spec.selects(i)).collect();
            let slice: Vec<_> = owned.iter().map(|&i| cells[i].clone()).collect();
            // Worker count varies per shard; the cells must not care.
            let results = run_grid(&slice, s + 1).expect("shard sweep");
            for (&i, r) in owned.iter().zip(&results) {
                entries.push(JournalEntry {
                    idx: i,
                    key: cell_key(&r.cell),
                    cell: cell_json(r),
                });
            }
        }
        let merged = merge_entries(entries).expect("merge");
        assert_eq!(merged, unsharded, "{n}-way shard merge diverged");
    }
}

/// End-to-end through the batch runner: each shard journals to its own
/// file, `merge_files` folds them, and the result matches an unsharded
/// journaled run — which then resumes 100% from cache, still
/// byte-identical.
#[test]
fn shard_journals_merge_to_the_unsharded_report() {
    let dir = std::env::temp_dir().join(format!("aimm_shard_merge_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let mut g = SweepGrid::new(0.03, 1);
    g.benches = vec![vec![Benchmark::Mac], vec![Benchmark::Rd]];
    g.mappings = vec![MappingScheme::Baseline, MappingScheme::Aimm];
    let cells = g.cells();
    assert_eq!(cells.len(), 4);

    let full_journal = dir.join("full.jsonl");
    let full = run_journaled(&cells, None, 2, &full_journal).expect("unsharded run");
    assert_eq!((full.computed, full.cached), (4, 0));
    let unsharded = report_json_outcomes(&full.outcomes);
    // The journaled runner and the plain runner agree to the byte.
    assert_eq!(unsharded, report_json(&run_grid(&cells, 1).expect("plain run")));

    let n = 2usize;
    let mut paths = Vec::new();
    for s in 0..n {
        let path = dir.join(format!("shard{s}.jsonl"));
        let spec = ShardSpec { index: s, count: n };
        let rep = run_journaled(&cells, Some(spec), s + 1, &path).expect("shard run");
        assert_eq!(rep.computed, 2, "shard {s} owns half the grid");
        paths.push(path);
    }
    let merged = merge_files(&paths).expect("merge");
    assert_eq!(merged, unsharded);

    // Resume: re-running the unsharded grid replays the journal without
    // simulating a single cell and still emits identical bytes.
    let resumed = run_journaled(&cells, None, 4, &full_journal).expect("resume");
    assert_eq!((resumed.computed, resumed.cached), (0, 4));
    assert_eq!(report_json_outcomes(&resumed.outcomes), unsharded);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn report_is_valid_json_with_expected_shape() {
    let mut g = grid();
    g.benches = vec![vec![Benchmark::Mac]];
    g.meshes = vec![(4, 4)];
    let results = run_grid(&g.cells(), 2).expect("sweep");
    let report = report_json(&results);
    let parsed = aimm::runtime::json::parse(&report).expect("report parses");
    assert_eq!(parsed.get("schema").unwrap().as_str(), Some("aimm-sweep-v1"));
    let cells = parsed.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 2); // MAC × {B, AIMM}
    for cell in cells {
        let runs = cell.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 2);
        for run in runs {
            assert!(run.get("cycles").unwrap().as_f64().unwrap() > 0.0);
            assert!(run.get("opc").unwrap().as_f64().unwrap() > 0.0);
        }
        // The learning cells actually invoked the agent.
        if cell.get("mapping").unwrap().as_str() == Some("AIMM") {
            assert!(
                runs[0].get("agent_invocations").unwrap().as_f64().unwrap() > 0.0
            );
        }
    }
}
