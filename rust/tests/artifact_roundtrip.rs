//! AOT artifact round-trip tests: rust ⇄ PJRT ⇄ compiled JAX/Pallas HLO.
//! These run against real artifacts (`make artifacts`) and skip —
//! loudly — when they are absent, so `cargo test` works pre-build.

use aimm::agent::AimmAgent;
use aimm::config::{MappingScheme, SystemConfig};
use aimm::coordinator::System;
use aimm::runtime::{artifacts_dir, PjrtQNet, QFunction, TrainBatch, BATCH, NUM_ACTIONS, STATE_DIM};
use aimm::workloads::{generate, Benchmark};

fn load() -> Option<PjrtQNet> {
    let dir = artifacts_dir()?;
    match PjrtQNet::load(&dir, 1e-3, 0.95) {
        Ok(q) => Some(q),
        Err(e) => panic!("artifacts present but failed to load: {e}"),
    }
}

#[test]
fn manifest_matches_crate_constants() {
    let Some(q) = load() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    // 64→128→128→{1,8} dueling net.
    let expect = 64 * 128 + 128 + 128 * 128 + 128 + 128 + 1 + 128 * 8 + 8;
    assert_eq!(q.param_size(), expect);
}

#[test]
fn greedy_action_stable_under_repeat() {
    let Some(mut q) = load() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let s: Vec<f32> = (0..STATE_DIM).map(|i| (i as f32) / STATE_DIM as f32).collect();
    let a = q.q_values(&s).unwrap();
    for _ in 0..5 {
        assert_eq!(q.q_values(&s).unwrap(), a);
    }
}

#[test]
fn dueling_structure_sane() {
    // Q values differ across actions for a generic state (the advantage
    // head is alive), and change when the state changes.
    let Some(mut q) = load() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let s1 = vec![0.25f32; STATE_DIM];
    let mut s2 = s1.clone();
    s2[0] = 0.9;
    let q1 = q.q_values(&s1).unwrap();
    let q2 = q.q_values(&s2).unwrap();
    let spread = q1.iter().cloned().fold(f32::MIN, f32::max)
        - q1.iter().cloned().fold(f32::MAX, f32::min);
    assert!(spread > 0.0, "all Q equal: dead advantage head?");
    assert_ne!(q1, q2, "state change must change Q");
    assert_eq!(q1.len(), NUM_ACTIONS);
}

#[test]
fn online_learning_shifts_greedy_action() {
    // Reward action 6 massively for a distinctive state: after training,
    // greedy(s) should become 6.
    let Some(mut q) = load() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let mut s = vec![0.0f32; STATE_DIM];
    s[3] = 1.0;
    let mut batch = TrainBatch {
        s: s.iter().cycle().take(BATCH * STATE_DIM).copied().collect(),
        a: vec![6; BATCH],
        r: vec![5.0; BATCH],
        s2: vec![0.0; BATCH * STATE_DIM],
        done: vec![1.0; BATCH],
    };
    // Also push down a rival action.
    for i in 0..BATCH / 2 {
        batch.a[i] = 1;
        batch.r[i] = -5.0;
    }
    for _ in 0..120 {
        q.train_batch(&batch).unwrap();
    }
    let qv = q.q_values(&s).unwrap();
    let best = (0..NUM_ACTIONS).max_by(|&a, &b| qv[a].total_cmp(&qv[b])).unwrap();
    assert_eq!(best, 6, "q-values after training: {qv:?}");
}

#[test]
fn full_system_episode_with_pjrt_agent() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let qnet = PjrtQNet::load(&dir, 1e-3, 0.95).unwrap();
    let mut cfg = SystemConfig::default();
    cfg.mapping = MappingScheme::Aimm;
    let agent = AimmAgent::new(Box::new(qnet), cfg.agent.clone(), 42);
    let trace = generate(Benchmark::Spmv, 1, 0.05, cfg.seed);
    let n = trace.ops.len() as u64;
    let mut sys = System::new(cfg, trace.ops, Some(agent));
    let stats = sys.run().unwrap();
    assert_eq!(stats.ops_completed, n);
    assert!(stats.agent_invocations > 0);
    assert!(stats.energy.aimm_hardware_nj > 0.0, "agent energy accounted");
}
