//! Continual-learning integration tests: the premise (one agent carried
//! across an episode's repeated runs, §6.1), the checkpoint round trip
//! (save → load → identical Q-values), and the bit-identity guarantee
//! (save at an episode boundary, resume, finish → `RunStats` identical
//! to the uninterrupted protocol, under both engines).
//!
//! Agents are built on the `LinearQ` mock explicitly (not
//! `best_qfunction`) so the tests are deterministic in every build
//! flavor, including one with real PJRT artifacts on disk.

use aimm::agent::{
    mc_seed, warm_start_agent, AgentCheckpoint, AimmAgent, CheckpointBundle, WarmStart,
};
use aimm::bench::sweep::stats_json;
use aimm::config::{Engine, MappingScheme, SystemConfig};
use aimm::coordinator::{run_stream_policy, run_stream_with, System};
use aimm::mapping::{AimmMultiPolicy, AnyPolicy};
use aimm::metrics::RunStats;
use aimm::nmp::NmpOp;
use aimm::runtime::{LinearQ, QFunction, STATE_DIM};
use aimm::workloads::{generate, Benchmark};

fn aimm_cfg(engine: Engine) -> SystemConfig {
    let mut c = SystemConfig::default();
    c.mapping = MappingScheme::Aimm;
    c.engine = engine;
    // Slow, floor-less ε decay so "keeps decaying" is strict across runs,
    // and a ring big enough that replay growth stays strict too.
    c.agent.eps_decay = 0.999;
    c.agent.eps_end = 0.0;
    c.agent.replay_capacity = 65_536;
    c
}

fn mk_agent(cfg: &SystemConfig) -> AimmAgent {
    AimmAgent::new(
        Box::new(LinearQ::new(cfg.agent.lr, cfg.agent.gamma, 7)),
        cfg.agent.clone(),
        cfg.seed ^ 0xA6E7,
    )
}

fn trace(cfg: &SystemConfig) -> Vec<NmpOp> {
    generate(Benchmark::Spmv, 1, 0.05, cfg.seed).ops
}

/// Resume-from-checkpoint: rebuild the agent the way `--resume` does,
/// but pinned to the LinearQ backend.
fn rebuild(ck_text: &str, cfg: &SystemConfig) -> AimmAgent {
    let ck = AgentCheckpoint::parse(ck_text).expect("checkpoint parses");
    let mut qf = Box::new(LinearQ::new(0.5, 0.5, 999)); // overwritten by restore
    qf.restore(&ck.q).expect("snapshot restores into linear-mock");
    AimmAgent::from_checkpoint(qf, cfg.agent.clone(), &ck).expect("agent rebuilds")
}

/// The continual premise: `run_stream` really carries ONE agent across
/// the episode's repeated runs — replay memory strictly grows, ε keeps
/// decaying, train steps and invocations are monotone.
#[test]
fn run_stream_carries_the_agent_across_runs() {
    let cfg = aimm_cfg(Engine::Event);
    let ops = trace(&cfg);
    let mut agent = Some(mk_agent(&cfg));
    let mut prev_replay = 0usize;
    let mut prev_eps = f32::INFINITY;
    let mut prev_trains = 0u64;
    let mut prev_inv = 0u64;
    for run in 0..3 {
        let mut sys = System::new(cfg.clone(), ops.clone(), agent.take());
        sys.run().unwrap();
        agent = sys.take_agent();
        let a = agent.as_ref().expect("agent survives the run");
        assert!(
            a.replay.len() > prev_replay,
            "run {run}: replay stuck at {} (was {prev_replay})",
            a.replay.len()
        );
        assert!(
            a.epsilon() < prev_eps,
            "run {run}: ε stopped decaying ({} !< {prev_eps})",
            a.epsilon()
        );
        assert!(a.stats.train_steps >= prev_trains, "run {run}: train steps went backwards");
        assert!(a.stats.invocations > prev_inv, "run {run}: no invocations this run");
        prev_replay = a.replay.len();
        prev_eps = a.epsilon();
        prev_trains = a.stats.train_steps;
        prev_inv = a.stats.invocations;
    }
    let a = agent.unwrap();
    assert!(a.stats.train_steps > 0, "three runs must produce training");
}

/// Save → file → load → identical Q-values on a probe batch of states.
#[test]
fn checkpoint_file_roundtrip_preserves_q_values() {
    let cfg = aimm_cfg(Engine::Event);
    let ops = trace(&cfg);
    let (_, agent) =
        run_stream_with(&cfg, &ops, 2, "SPMV", Some(mk_agent(&cfg))).unwrap();
    let mut agent = agent.expect("agent survives");
    assert!(agent.stats.train_steps > 0, "test needs a trained network");

    let ck = agent.checkpoint().unwrap();
    let path = std::env::temp_dir().join("aimm_continual_roundtrip.json");
    ck.save(&path).unwrap();
    let loaded = AgentCheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.to_json(), ck.to_json(), "file round trip is byte-exact");

    let mut restored = rebuild(&ck.to_json(), &cfg);
    // Probe batch: a spread of synthetic states.
    for k in 0..32 {
        let mut s = [0.0f32; STATE_DIM];
        for (i, slot) in s.iter_mut().enumerate() {
            *slot = ((i * 7 + k * 13) % 29) as f32 / 29.0;
        }
        let a = agent.probe_q(&s).unwrap();
        let b = restored.probe_q(&s).unwrap();
        let a_bits: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
        let b_bits: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a_bits, b_bits, "probe state {k}: Q-values diverged");
    }
}

fn assert_runs_identical(a: &RunStats, b: &RunStats, ctx: &str) {
    assert_eq!(stats_json(a), stats_json(b), "stats diverged: {ctx}");
    let ta: Vec<u32> = a.opc_timeline.iter().map(|v| v.to_bits()).collect();
    let tb: Vec<u32> = b.opc_timeline.iter().map(|v| v.to_bits()).collect();
    assert_eq!(ta, tb, "OPC timeline diverged: {ctx}");
}

/// The acceptance invariant: saving at an episode boundary, reloading,
/// and finishing the protocol yields the same `RunStats` as the
/// uninterrupted run — under both engines.
#[test]
fn resume_is_bit_identical_under_both_engines() {
    for engine in Engine::ALL {
        let cfg = aimm_cfg(engine);
        let ops = trace(&cfg);

        // Uninterrupted: 3 runs straight through.
        let (full, _) =
            run_stream_with(&cfg, &ops, 3, "SPMV", Some(mk_agent(&cfg))).unwrap();

        // Interrupted: 2 runs, checkpoint at the boundary, rebuild from
        // the serialized form, finish the third run.
        let (head, agent) =
            run_stream_with(&cfg, &ops, 2, "SPMV", Some(mk_agent(&cfg))).unwrap();
        let text = agent.unwrap().checkpoint().unwrap().to_json();
        let resumed = rebuild(&text, &cfg);
        let (tail, _) =
            run_stream_with(&cfg, &ops, 1, "SPMV", Some(resumed)).unwrap();

        // The first two runs were unaffected by the save.
        for i in 0..2 {
            assert_runs_identical(&full.runs[i], &head.runs[i], &format!("{engine} run {i}"));
        }
        // And the resumed third run equals the uninterrupted third.
        assert_runs_identical(&full.runs[2], &tail.runs[0], &format!("{engine} resumed run"));
    }
}

/// The policy trait's checkpoint hooks: every non-checkpointable
/// policy (baseline/TOM/CODA/oracle) refuses `snapshot` and `restore`
/// loudly, naming itself — the same contract the CLI's
/// `--checkpoint`/`--resume` guard surfaces as
/// "the {policy} policy is not checkpointable".
#[test]
fn non_checkpointable_policies_refuse_snapshot_by_name() {
    use aimm::mapping::{AnyPolicy, MappingPolicy};
    let donor_ck = {
        let cfg = aimm_cfg(Engine::Event);
        mk_agent(&cfg).checkpoint().expect("fresh agent is at a boundary")
    };
    for scheme in MappingScheme::ALL {
        if scheme.checkpointable() {
            continue;
        }
        let mut cfg = SystemConfig::default();
        cfg.mapping = scheme;
        let mut policy = AnyPolicy::new(&cfg, &[], None);
        let err = policy.snapshot().unwrap_err().to_string();
        assert!(err.contains(scheme.name()), "{}: {err}", scheme.name());
        assert!(err.contains("not checkpointable"), "{}: {err}", scheme.name());
        let err = policy.restore(&donor_ck).unwrap_err().to_string();
        assert!(err.contains(scheme.name()), "{}: {err}", scheme.name());
    }
    // And the checkpointable one round-trips through the same hooks.
    let cfg = aimm_cfg(Engine::Event);
    let mut policy = AnyPolicy::new(&cfg, &[], Some(mk_agent(&cfg)));
    let ck = policy.snapshot().expect("AIMM snapshots at the boundary");
    policy.restore(&ck).expect("AIMM restores its own checkpoint");
    assert_eq!(policy.snapshot().unwrap().to_json(), ck.to_json());
}

fn mc_cfg(engine: Engine) -> SystemConfig {
    let mut c = aimm_cfg(engine);
    c.mapping = MappingScheme::AimmMc;
    c
}

/// A LinearQ-pinned per-MC pool, seeded exactly like `fresh_mc_agents`
/// (same `mc_seed` / `^ 0xA6E7` folds) but deterministic in every build
/// flavor.
fn mk_pool(cfg: &SystemConfig) -> AnyPolicy {
    let agents: Vec<AimmAgent> = (0..cfg.num_mcs())
        .map(|mc| {
            let s = mc_seed(cfg.seed, mc);
            AimmAgent::new(
                Box::new(LinearQ::new(cfg.agent.lr, cfg.agent.gamma, s)),
                cfg.agent.clone(),
                s ^ 0xA6E7,
            )
        })
        .collect();
    AnyPolicy::AimmMc(Box::new(AimmMultiPolicy::with_agents(cfg, agents)))
}

/// Resume-from-bundle the way `--resume` does for `--mapping aimm-mc`,
/// but pinned to the LinearQ backend.
fn rebuild_pool(text: &str, cfg: &SystemConfig) -> AnyPolicy {
    let bundle = CheckpointBundle::parse(text).expect("bundle parses");
    bundle
        .ensure_resumable(cfg.num_mcs(), WarmStart::None)
        .expect("bundle shape matches the run");
    let agents: Vec<AimmAgent> = bundle
        .agents
        .iter()
        .map(|ck| {
            let mut qf = Box::new(LinearQ::new(0.5, 0.5, 999)); // overwritten by restore
            qf.restore(&ck.q).expect("snapshot restores into linear-mock");
            AimmAgent::from_checkpoint(qf, cfg.agent.clone(), ck).expect("agent rebuilds")
        })
        .collect();
    AnyPolicy::AimmMc(Box::new(AimmMultiPolicy::with_agents(cfg, agents)))
}

/// The v2 acceptance invariant: saving the whole per-MC pool as an
/// aimm-checkpoint-v2 bundle at an episode boundary, reloading every
/// agent from the serialized form, and finishing the protocol yields the
/// same `RunStats` as the uninterrupted run — under both engines.
#[test]
fn multi_agent_resume_is_bit_identical_under_both_engines() {
    for engine in Engine::ALL {
        let cfg = mc_cfg(engine);
        let ops = trace(&cfg);

        let (full, _) = run_stream_policy(&cfg, &ops, 3, "SPMV", mk_pool(&cfg)).unwrap();

        let (head, policy) = run_stream_policy(&cfg, &ops, 2, "SPMV", mk_pool(&cfg)).unwrap();
        let bundle = policy.checkpoint_bundle(WarmStart::None).unwrap();
        assert_eq!(bundle.agents.len(), cfg.num_mcs(), "one bundle entry per MC");
        let text = bundle.to_json();
        assert!(text.starts_with("{\"schema\":\"aimm-checkpoint-v2\""), "v2 envelope");
        let (tail, _) =
            run_stream_policy(&cfg, &ops, 1, "SPMV", rebuild_pool(&text, &cfg)).unwrap();

        for i in 0..2 {
            assert_runs_identical(
                &full.runs[i],
                &head.runs[i],
                &format!("aimm-mc {engine} run {i}"),
            );
        }
        assert_runs_identical(
            &full.runs[2],
            &tail.runs[0],
            &format!("aimm-mc {engine} resumed run"),
        );
    }
}

/// Warm-started AIMM: distillation happens exactly once, before episode
/// 1 — a bundle saved mid-protocol records the provenance, refuses a
/// drifted mode by field name, and the resumed tail (which never
/// re-distills) matches the uninterrupted run bit for bit.
#[test]
fn warm_started_checkpoint_records_and_enforces_provenance() {
    let cfg = aimm_cfg(Engine::Event);
    let ops = trace(&cfg);
    // `with_batch` carries the same weights as `new` under the same seed
    // but declares the fixed batch distillation needs.
    let mk_warm = || {
        let mut a = AimmAgent::new(
            Box::new(LinearQ::with_batch(cfg.agent.lr, cfg.agent.gamma, 7, cfg.agent.batch_size)),
            cfg.agent.clone(),
            cfg.seed ^ 0xA6E7,
        );
        warm_start_agent(&mut a, &cfg, &ops).expect("distillation runs on the mock");
        a
    };

    let (full, _) = run_stream_with(&cfg, &ops, 3, "SPMV", Some(mk_warm())).unwrap();
    let (_, agent) = run_stream_with(&cfg, &ops, 2, "SPMV", Some(mk_warm())).unwrap();
    let bundle =
        CheckpointBundle::single(WarmStart::Oracle, agent.unwrap().checkpoint().unwrap());
    let parsed = CheckpointBundle::parse(&bundle.to_json()).unwrap();
    assert_eq!(parsed.warm_start, WarmStart::Oracle, "provenance survives the round trip");

    // Drifted warm-start mode: refused, naming the field.
    let err = parsed.ensure_resumable(1, WarmStart::None).unwrap_err().to_string();
    assert!(err.contains("warm_start"), "{err}");
    parsed.ensure_resumable(1, WarmStart::Oracle).unwrap();

    // Resume finishes the protocol bit-identically — no re-distillation.
    let resumed = rebuild(&parsed.agents[0].to_json(), &cfg);
    let (tail, _) = run_stream_with(&cfg, &ops, 1, "SPMV", Some(resumed)).unwrap();
    assert_runs_identical(&full.runs[2], &tail.runs[0], "warm-started resume");
}

/// Cross-engine: a checkpoint written under one engine resumes
/// bit-identically under the other (the engine is a clock strategy, not
/// simulation state — DESIGN.md §8).
#[test]
fn checkpoint_crosses_engines() {
    let polled = aimm_cfg(Engine::Polled);
    let event = aimm_cfg(Engine::Event);
    let ops = trace(&polled);

    let (_, agent) =
        run_stream_with(&polled, &ops, 2, "SPMV", Some(mk_agent(&polled))).unwrap();
    let text = agent.unwrap().checkpoint().unwrap().to_json();

    let (on_polled, _) =
        run_stream_with(&polled, &ops, 1, "SPMV", Some(rebuild(&text, &polled))).unwrap();
    let (on_event, _) =
        run_stream_with(&event, &ops, 1, "SPMV", Some(rebuild(&text, &event))).unwrap();
    assert_runs_identical(on_polled.last(), on_event.last(), "cross-engine resume");
}
