//! Resume-after-interrupt contract for the journaled sweep runner
//! (EXPERIMENTS.md §Sweep, DESIGN.md §12): a killed sweep resumes from
//! its JSONL journal and still produces a `BENCH_sweep.json`
//! byte-identical to an uninterrupted run. Torn appends are dropped
//! loudly and recomputed; entries whose `cell_key` no longer matches the
//! grid are recomputed, never silently reused; and report writes are
//! atomic, so an interrupt can leave a stale `.tmp` but never a torn
//! report.

use std::path::PathBuf;

use aimm::bench::sweep::{
    journal_path_for, report_json, report_json_outcomes, run_grid, run_journaled,
    write_report, SweepGrid,
};
use aimm::config::MappingScheme;
use aimm::workloads::Benchmark;

/// Fresh per-test scratch directory (tests in this file run fine in
/// parallel: each uses its own tag).
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("aimm_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Four tiny cells: baseline + learning agent over two benchmarks.
fn small_grid() -> SweepGrid {
    let mut g = SweepGrid::new(0.03, 1);
    g.benches = vec![vec![Benchmark::Mac], vec![Benchmark::Rd]];
    g.mappings = vec![MappingScheme::Baseline, MappingScheme::Aimm];
    g
}

#[test]
fn resume_after_truncation_is_byte_identical() {
    let dir = tmp_dir("resume_truncate");
    let journal = dir.join("sweep.jsonl");
    let cells = small_grid().cells();
    let full = run_journaled(&cells, None, 2, &journal).expect("full run");
    let want = report_json_outcomes(&full.outcomes);
    // Baseline sanity: the journaled runner matches the plain runner.
    assert_eq!(want, report_json(&run_grid(&cells, 1).expect("plain run")));

    // Simulated kill mid-grid: keep two complete journal lines plus a
    // torn third append (no trailing newline, cut mid-object).
    let text = std::fs::read_to_string(&journal).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 4, "one journal line per cell");
    let torn = &lines[2][..lines[2].len() / 2];
    std::fs::write(&journal, format!("{}\n{}\n{torn}", lines[0], lines[1])).unwrap();

    let resumed = run_journaled(&cells, None, 3, &journal).expect("resume");
    assert_eq!(resumed.corrupt, 1, "torn tail dropped loudly, not mis-parsed");
    assert_eq!((resumed.computed, resumed.cached), (2, 2));
    assert_eq!(report_json_outcomes(&resumed.outcomes), want, "resumed report diverged");

    // And the journal healed: one more resume is a pure cache replay.
    let replay = run_journaled(&cells, None, 1, &journal).expect("replay");
    assert_eq!((replay.computed, replay.cached, replay.corrupt), (0, 4, 0));
    assert_eq!(report_json_outcomes(&replay.outcomes), want);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_garbage_lines_are_skipped_loudly_and_recomputed() {
    let dir = tmp_dir("resume_corrupt");
    let journal = dir.join("sweep.jsonl");
    let cells = small_grid().cells();
    let full = run_journaled(&cells, None, 1, &journal).expect("full run");
    let want = report_json_outcomes(&full.outcomes);

    // One recorded line overwritten by junk, plus a foreign-schema line
    // (valid JSON, wrong tool) appended.
    let text = std::fs::read_to_string(&journal).unwrap();
    let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
    lines[1] = "not json at all {{{".to_string();
    lines.push("{\"schema\":\"other-tool-v9\",\"idx\":0}".to_string());
    std::fs::write(&journal, lines.join("\n")).unwrap();

    let resumed = run_journaled(&cells, None, 2, &journal).expect("resume");
    assert_eq!(resumed.corrupt, 2, "garbage and foreign lines both flagged");
    assert_eq!((resumed.computed, resumed.cached), (1, 3));
    assert_eq!(report_json_outcomes(&resumed.outcomes), want);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A journal line whose `cell_key` matches no cell of the current grid —
/// here hand-tampered, the hostile version of "the code changed under
/// the journal" — is recomputed, never reused.
#[test]
fn tampered_cell_key_is_recomputed_not_reused() {
    let dir = tmp_dir("resume_tamper");
    let journal = dir.join("sweep.jsonl");
    let cells = small_grid().cells();
    let full = run_journaled(&cells, None, 1, &journal).expect("full run");
    let want = report_json_outcomes(&full.outcomes);

    let text = std::fs::read_to_string(&journal).unwrap();
    let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
    let mut e = aimm::bench::sweep::journal::parse_line(&lines[0]).expect("line parses");
    e.key ^= 1;
    lines[0] = e.line();
    std::fs::write(&journal, lines.join("\n")).unwrap();

    let resumed = run_journaled(&cells, None, 2, &journal).expect("resume");
    assert_eq!(resumed.stale, 1, "mismatched cell_key dropped as stale");
    assert_eq!((resumed.computed, resumed.cached), (1, 3));
    assert_eq!(report_json_outcomes(&resumed.outcomes), want);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The organic stale case: the grid changed (here, scale — any axis or
/// the engine behaves the same, they all feed `cell_key`), so every old
/// journal entry is dropped and the whole grid recomputes. The old
/// numbers never leak into the new report.
#[test]
fn changed_grid_drops_every_stale_entry() {
    let dir = tmp_dir("resume_stale_grid");
    let journal = dir.join("sweep.jsonl");
    run_journaled(&small_grid().cells(), None, 1, &journal).expect("old-grid run");

    let mut g2 = small_grid();
    g2.scale = 0.04;
    let cells2 = g2.cells();
    let fresh = run_journaled(&cells2, None, 2, &dir.join("fresh.jsonl")).expect("fresh run");
    let want = report_json_outcomes(&fresh.outcomes);

    let resumed = run_journaled(&cells2, None, 2, &journal).expect("resume on old journal");
    assert_eq!(resumed.stale, 4, "every old entry dropped");
    assert_eq!((resumed.computed, resumed.cached), (4, 0));
    assert_eq!(report_json_outcomes(&resumed.outcomes), want);
    let _ = std::fs::remove_dir_all(&dir);
}

/// `write_report` is atomic: a pre-existing stale `.tmp` from an
/// interrupted earlier write neither blocks nor pollutes the next write,
/// and the rename leaves no `.tmp` behind.
#[test]
fn write_report_replaces_stale_tmp_atomically() {
    let dir = tmp_dir("report_tmp");
    let out = dir.join("BENCH_sweep.json");
    let tmp = dir.join("BENCH_sweep.json.tmp");
    std::fs::write(&tmp, "torn garbage from an interrupted write").unwrap();

    let mut g = SweepGrid::new(0.03, 1);
    g.benches = vec![vec![Benchmark::Mac]];
    g.mappings = vec![MappingScheme::Baseline];
    let results = run_grid(&g.cells(), 1).expect("tiny run");
    write_report(&out, &results).expect("atomic write");
    assert_eq!(std::fs::read_to_string(&out).unwrap(), report_json(&results));
    assert!(!tmp.exists(), "stale tmp renamed away, not left behind");
    // The journal naming convention the CLI pairs with this report.
    assert_eq!(journal_path_for(&out), dir.join("BENCH_sweep.jsonl"));
    let _ = std::fs::remove_dir_all(&dir);
}
