//! Multi-program composition invariants for `workloads::multi::interleave`
//! across every combination the paper studies (§7.5.2): per-program op
//! order is preserved, no op is lost or invented, pids are reassigned to
//! 1..=N, and the merged stream is a pure function of (traces, seed).

use aimm::nmp::NmpOp;
use aimm::workloads::multi::paper_combinations;
use aimm::workloads::{generate, interleave, Benchmark, Trace};

fn combo_traces(combo: &[&str], seed: u64) -> Vec<Trace> {
    combo
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let bench = Benchmark::from_name(name)
                .unwrap_or_else(|| panic!("unknown paper benchmark {name}"));
            // Arbitrary distinct input pids: interleave must relabel.
            generate(bench, 40 + i as u32, 0.02, seed + i as u64)
        })
        .collect()
}

fn op_key(op: &NmpOp) -> (u32, u64, u64, Option<u64>) {
    (op.pid, op.dest, op.src1, op.src2)
}

#[test]
fn interleave_invariants_hold_for_all_paper_combinations() {
    for (ci, combo) in paper_combinations().iter().enumerate() {
        let seed = 0x5EED + ci as u64;
        let (merged, relabeled) = interleave(combo_traces(combo, seed), seed ^ 0x3117);

        // Total op count conserved.
        let expected_total: usize = relabeled.iter().map(|t| t.len()).sum();
        assert_eq!(merged.len(), expected_total, "{combo:?}");

        // Pids reassigned to exactly 1..=N.
        let mut pids: Vec<u32> = merged.iter().map(|o| o.pid).collect();
        pids.sort_unstable();
        pids.dedup();
        let want: Vec<u32> = (1..=combo.len() as u32).collect();
        assert_eq!(pids, want, "{combo:?}");

        // Per-pid subsequences equal the relabeled source traces, op for
        // op and in order.
        for trace in &relabeled {
            let sub: Vec<&NmpOp> = merged.iter().filter(|o| o.pid == trace.pid).collect();
            assert_eq!(sub.len(), trace.len(), "{combo:?} pid {}", trace.pid);
            for (got, want) in sub.iter().zip(&trace.ops) {
                assert_eq!(op_key(got), op_key(want), "{combo:?} pid {}", trace.pid);
            }
        }
    }
}

#[test]
fn interleave_is_deterministic_for_identical_seeds() {
    for (ci, combo) in paper_combinations().iter().enumerate() {
        let seed = 0xD0 + ci as u64;
        let (a, _) = interleave(combo_traces(combo, seed), seed ^ 0x3117);
        let (b, _) = interleave(combo_traces(combo, seed), seed ^ 0x3117);
        assert_eq!(a.len(), b.len(), "{combo:?}");
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(op_key(x), op_key(y), "{combo:?} op {i}");
        }
        // A different interleave seed permutes the schedule (same
        // multiset of ops, different order) for genuinely multi-program
        // combos — guards against the seed being silently ignored.
        let (c, _) = interleave(combo_traces(combo, seed), seed ^ 0x7777);
        assert_eq!(c.len(), a.len(), "{combo:?}");
        let same_order = a.iter().zip(&c).all(|(x, y)| op_key(x) == op_key(y));
        assert!(!same_order, "{combo:?}: interleave ignored its seed");
    }
}
