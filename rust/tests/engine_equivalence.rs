//! Differential equivalence of the two simulation engines (DESIGN.md
//! §8): for a grid of (benchmark-combo × technique × seed) cells —
//! mapping schemes cycled across cells so Baseline, TOM and AIMM are all
//! exercised — the event engine's full `RunStats` must be **bit-
//! identical** to the polled engine's on every run of every cell. This
//! is the contract that lets every figure, sweep and RL experiment run
//! on the fast engine while the polled loop remains the semantic
//! reference.

use aimm::agent::WarmStart;
use aimm::bench::sweep::stats_json;
use aimm::config::{Engine, MappingScheme, SystemConfig, Technique, TopologyKind};
use aimm::coordinator::{episode_ops, run_cell, run_stream_policy, warm_started_policy};
use aimm::metrics::RunStats;
use aimm::workloads::Benchmark;

/// Bit-level identity: the JSON digest covers every scalar aggregate
/// (cycles, OPC, hops, utilization, migration and agent counters,
/// energy); the OPC timeline and float fields are additionally compared
/// through their raw bits, since formatting could in principle collapse
/// distinct values.
fn assert_identical(p: &RunStats, e: &RunStats, ctx: &str) {
    assert_eq!(stats_json(p), stats_json(e), "stats diverged: {ctx}");
    assert_eq!(p.opc_timeline.len(), e.opc_timeline.len(), "timeline length: {ctx}");
    for (i, (a, b)) in p.opc_timeline.iter().zip(&e.opc_timeline).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "timeline[{i}]: {ctx}");
    }
    for (name, a, b) in [
        ("avg_hops", p.avg_hops, e.avg_hops),
        ("avg_packet_latency", p.avg_packet_latency, e.avg_packet_latency),
        ("compute_utilization", p.compute_utilization, e.compute_utilization),
        ("compute_balance", p.compute_balance, e.compute_balance),
        ("row_hit_rate", p.row_hit_rate, e.row_hit_rate),
        ("agent_avg_loss", p.agent_avg_loss, e.agent_avg_loss),
        ("agent_cumulative_reward", p.agent_cumulative_reward, e.agent_cumulative_reward),
        ("energy_aimm_nj", p.energy.aimm_hardware_nj, e.energy.aimm_hardware_nj),
        ("energy_network_nj", p.energy.network_nj, e.energy.network_nj),
        ("energy_memory_nj", p.energy.memory_nj, e.energy.memory_nj),
    ] {
        assert_eq!(a.to_bits(), b.to_bits(), "{name}: {ctx}");
    }
}

fn cell_cfg(technique: Technique, mapping: MappingScheme, seed: u64) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.technique = technique;
    cfg.mapping = mapping;
    cfg.seed = seed;
    cfg
}

#[test]
fn engines_are_bit_identical_across_the_grid() {
    // Single-program cells plus one multi-program combo; every offload
    // technique; two seeds. Mapping schemes cycle with the cell index so
    // all six policies (B, TOM, AIMM, AIMM-MC, CODA, ORACLE) are covered
    // without sextupling the grid.
    let combos: [&[Benchmark]; 3] = [
        &[Benchmark::Mac],
        &[Benchmark::Spmv],
        &[Benchmark::Rd, Benchmark::Km],
    ];
    let seeds = [3u64, 0xA133];
    let runs = 2; // exercises agent carry-over between runs
    let mut idx = 0usize;
    for benches in combos {
        for technique in Technique::ALL {
            for seed in seeds {
                let mapping = MappingScheme::ALL[idx % MappingScheme::ALL.len()];
                idx += 1;
                let mut polled_cfg = cell_cfg(technique, mapping, seed);
                polled_cfg.engine = Engine::Polled;
                let mut event_cfg = cell_cfg(technique, mapping, seed);
                event_cfg.engine = Engine::Event;
                let ctx = format!(
                    "{:?}/{}/{}/seed {seed:#x}",
                    benches.iter().map(|b| b.name()).collect::<Vec<_>>(),
                    technique,
                    mapping
                );
                let p = run_cell(&polled_cfg, benches, 0.03, runs)
                    .unwrap_or_else(|e| panic!("polled {ctx}: {e}"));
                let e = run_cell(&event_cfg, benches, 0.03, runs)
                    .unwrap_or_else(|e| panic!("event {ctx}: {e}"));
                assert_eq!(p.runs.len(), e.runs.len(), "{ctx}");
                for (i, (rp, re)) in p.runs.iter().zip(&e.runs).enumerate() {
                    assert_identical(rp, re, &format!("{ctx} run {i}"));
                }
            }
        }
    }
}

/// The two new policies keep the polled/event contract on dedicated
/// cells (the cycling grid above covers them too, but these pin the
/// interesting mechanisms by name): CODA's window evaluations fire at
/// identical cycles under both engines, and the oracle's profiled
/// first-touch placement is clock-independent by construction.
#[test]
fn engines_are_bit_identical_for_coda_and_oracle() {
    for (mapping, bench) in [
        (MappingScheme::Coda, Benchmark::Spmv),
        (MappingScheme::Coda, Benchmark::Rd),
        (MappingScheme::Oracle, Benchmark::Km),
        (MappingScheme::Oracle, Benchmark::Mac),
    ] {
        let mut polled_cfg = cell_cfg(Technique::Bnmp, mapping, 23);
        polled_cfg.engine = Engine::Polled;
        let mut event_cfg = cell_cfg(Technique::Bnmp, mapping, 23);
        event_cfg.engine = Engine::Event;
        let ctx = format!("{}/{}", mapping, bench.name());
        let p = run_cell(&polled_cfg, &[bench], 0.03, 2)
            .unwrap_or_else(|e| panic!("polled {ctx}: {e}"));
        let e = run_cell(&event_cfg, &[bench], 0.03, 2)
            .unwrap_or_else(|e| panic!("event {ctx}: {e}"));
        assert_eq!(p.runs.len(), e.runs.len(), "{ctx}");
        for (i, (rp, re)) in p.runs.iter().zip(&e.runs).enumerate() {
            assert_identical(rp, re, &format!("{ctx} run {i}"));
        }
        assert!(p.last().ops_completed > 0, "{ctx}: cell must actually run");
    }
}

/// The non-mesh topologies keep the same polled/event contract: the
/// fabric's event hook is occupancy-based and never looks at which links
/// (including torus/ring wraparounds) packets ride, so the time skip is
/// legal — proven here bit-for-bit on one torus and one ring cell, with
/// the learning agent in the loop.
#[test]
fn engines_are_bit_identical_on_torus_and_ring() {
    for (topology, bench) in
        [(TopologyKind::Torus, Benchmark::Spmv), (TopologyKind::Ring, Benchmark::Mac)]
    {
        let mut polled_cfg = cell_cfg(Technique::Bnmp, MappingScheme::Aimm, 23);
        polled_cfg.topology = topology;
        let mut event_cfg = polled_cfg.clone();
        polled_cfg.engine = Engine::Polled;
        event_cfg.engine = Engine::Event;
        let ctx = format!("{}/{}", topology, bench.name());
        let p = run_cell(&polled_cfg, &[bench], 0.03, 2)
            .unwrap_or_else(|e| panic!("polled {ctx}: {e}"));
        let e = run_cell(&event_cfg, &[bench], 0.03, 2)
            .unwrap_or_else(|e| panic!("event {ctx}: {e}"));
        assert_eq!(p.runs.len(), e.runs.len(), "{ctx}");
        for (i, (rp, re)) in p.runs.iter().zip(&e.runs).enumerate() {
            assert_identical(rp, re, &format!("{ctx} run {i}"));
        }
        assert!(p.last().avg_hops > 0.0, "{ctx}: packets must actually travel");
    }
}

/// The GCM pointer-chasing trace family (workloads/graph.rs) keeps the
/// polled/event contract under every paper mapping. GCM's op stream is
/// the adversarial case for the event engine's time skip — long
/// dependence-free load chains touching scattered pages — so it gets
/// dedicated cells rather than riding the cycling grid.
#[test]
fn engines_are_bit_identical_on_gcm() {
    for mapping in MappingScheme::PAPER {
        let mut polled_cfg = cell_cfg(Technique::Bnmp, mapping, 29);
        polled_cfg.engine = Engine::Polled;
        let mut event_cfg = cell_cfg(Technique::Bnmp, mapping, 29);
        event_cfg.engine = Engine::Event;
        let ctx = format!("GCM/{mapping}");
        let p = run_cell(&polled_cfg, &[Benchmark::Gcm], 0.03, 2)
            .unwrap_or_else(|e| panic!("polled {ctx}: {e}"));
        let e = run_cell(&event_cfg, &[Benchmark::Gcm], 0.03, 2)
            .unwrap_or_else(|e| panic!("event {ctx}: {e}"));
        assert_eq!(p.runs.len(), e.runs.len(), "{ctx}");
        for (i, (rp, re)) in p.runs.iter().zip(&e.runs).enumerate() {
            assert_identical(rp, re, &format!("{ctx} run {i}"));
        }
        assert!(p.last().ops_completed > 0, "{ctx}: cell must actually run");
    }
}

/// The v2 learning shapes keep the polled/event contract on dedicated
/// cells. AIMM-MC's gossip schedule counts policy invocations, not
/// cycles, so the per-MC pool (and its ring exchanges) must land on
/// identical decisions under both engines; the GCM cell stresses that
/// with scattered pointer-chasing pages. The warm-started cells prove
/// distillation happens strictly before cycle 0 — the pre-trained
/// weights are engine-independent inputs, so the runs stay bit-equal.
#[test]
fn engines_are_bit_identical_for_aimm_mc_and_warm_started_aimm() {
    for bench in [Benchmark::Spmv, Benchmark::Gcm] {
        let mut polled_cfg = cell_cfg(Technique::Bnmp, MappingScheme::AimmMc, 31);
        polled_cfg.engine = Engine::Polled;
        let mut event_cfg = cell_cfg(Technique::Bnmp, MappingScheme::AimmMc, 31);
        event_cfg.engine = Engine::Event;
        let ctx = format!("AIMM-MC/{}", bench.name());
        let p = run_cell(&polled_cfg, &[bench], 0.03, 2)
            .unwrap_or_else(|e| panic!("polled {ctx}: {e}"));
        let e = run_cell(&event_cfg, &[bench], 0.03, 2)
            .unwrap_or_else(|e| panic!("event {ctx}: {e}"));
        assert_eq!(p.runs.len(), e.runs.len(), "{ctx}");
        for (i, (rp, re)) in p.runs.iter().zip(&e.runs).enumerate() {
            assert_identical(rp, re, &format!("{ctx} run {i}"));
        }
        assert!(p.last().agent_invocations > 0, "{ctx}: the pool must actually decide");
    }
    for mapping in [MappingScheme::Aimm, MappingScheme::AimmMc] {
        let mut polled_cfg = cell_cfg(Technique::Bnmp, mapping, 37);
        polled_cfg.engine = Engine::Polled;
        let mut event_cfg = cell_cfg(Technique::Bnmp, mapping, 37);
        event_cfg.engine = Engine::Event;
        let (ops, name) = episode_ops(&polled_cfg, &[Benchmark::Mac], 0.03).unwrap();
        let ctx = format!("warm-started {mapping}/{name}");
        let (policy, stats) = warm_started_policy(&polled_cfg, &ops, WarmStart::Oracle)
            .unwrap_or_else(|e| panic!("{ctx}: {e}"));
        assert!(!stats.is_empty() && stats.iter().all(|s| s.examples > 0), "{ctx}");
        let (p, _) = run_stream_policy(&polled_cfg, &ops, 2, &name, policy)
            .unwrap_or_else(|e| panic!("polled {ctx}: {e}"));
        let (policy, _) = warm_started_policy(&event_cfg, &ops, WarmStart::Oracle)
            .unwrap_or_else(|e| panic!("{ctx}: {e}"));
        let (e, _) = run_stream_policy(&event_cfg, &ops, 2, &name, policy)
            .unwrap_or_else(|e| panic!("event {ctx}: {e}"));
        assert_eq!(p.runs.len(), e.runs.len(), "{ctx}");
        for (i, (rp, re)) in p.runs.iter().zip(&e.runs).enumerate() {
            assert_identical(rp, re, &format!("{ctx} run {i}"));
        }
    }
}

#[test]
fn engines_are_bit_identical_on_the_8x8_mesh_with_hoard() {
    // The mesh-scaling + multi-program corner: 64 cubes, HOARD frame
    // allocation, interleaved pids.
    let mut polled_cfg = cell_cfg(Technique::Bnmp, MappingScheme::Aimm, 17);
    polled_cfg.mesh_cols = 8;
    polled_cfg.mesh_rows = 8;
    polled_cfg.hoard = true;
    let mut event_cfg = polled_cfg.clone();
    polled_cfg.engine = Engine::Polled;
    event_cfg.engine = Engine::Event;
    let benches = [Benchmark::Sc, Benchmark::Mac];
    let p = run_cell(&polled_cfg, &benches, 0.03, 1).expect("polled 8x8");
    let e = run_cell(&event_cfg, &benches, 0.03, 1).expect("event 8x8");
    assert_identical(p.last(), e.last(), "8x8 HOARD multi-program");
}
