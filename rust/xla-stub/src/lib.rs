//! API-compatible stub of the `xla` crate (PJRT bindings), used by the
//! `pjrt` cargo feature of the `aimm` crate in offline builds.
//!
//! The real dependency wraps `xla_extension`'s PJRT C API, a native
//! library that cannot be vendored into this repository. This stub
//! mirrors exactly the API surface `aimm::runtime::pjrt` uses, so
//! `cargo build --features pjrt` type-checks the whole PJRT path with
//! zero native dependencies. Failure is deferred to *runtime* (client
//! construction returns an error); `aimm::runtime::best_qfunction`
//! catches it and falls back to the linear mock, so a stub-linked build
//! remains fully functional minus real artifact execution.
//!
//! To execute AOT artifacts, swap the `xla` path dependency in
//! `rust/Cargo.toml` for a real PJRT-backed build of the crate.

use std::fmt;

/// Error raised by every runtime entry point of the stub.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} needs the real xla crate (PJRT runtime); this build links the offline API stub"
    )))
}

/// An HLO module parsed from text form (path retained for diagnostics).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    /// "Parse" an HLO text file. The stub verifies the file exists so
    /// artifact-path mistakes still fail with a useful message; actual
    /// parsing is deferred to the (failing) client compile.
    pub fn from_text_file(path: &str) -> Result<Self> {
        if !std::path::Path::new(path).is_file() {
            return Err(Error(format!("no such HLO file: {path}")));
        }
        Ok(Self { path: path.to_string() })
    }
}

/// A computation handle built from an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self { _path: proto.path.clone() }
    }
}

/// PJRT client handle. The stub cannot construct one: `cpu()` is the
/// single point of failure for the whole execution path.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu()")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile()")
    }
}

/// A compiled executable (unreachable through the stub client).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute()")
    }
}

/// A device buffer (unreachable through the stub client).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync()")
    }
}

/// A host-side literal. The stub keeps only the element count so shape
/// mistakes surface even without a runtime.
#[derive(Debug, Clone)]
pub struct Literal {
    len: usize,
}

impl Literal {
    pub fn vec1<T>(data: &[T]) -> Self {
        Self { len: data.len() }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Self> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.len {
            return Err(Error(format!("cannot reshape {} elements to {dims:?}", self.len)));
        }
        Ok(self.clone())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec()")
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1()")
    }

    pub fn to_tuple4(&self) -> Result<(Literal, Literal, Literal, Literal)> {
        unavailable("Literal::to_tuple4()")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_loudly() {
        let err = PjRtClient::cpu().err().expect("stub must not build a client");
        assert!(err.to_string().contains("xla stub"));
    }

    #[test]
    fn literal_shapes_checked() {
        let l = Literal::vec1(&[0.0f32; 64]);
        assert!(l.reshape(&[1, 64]).is_ok());
        assert!(l.reshape(&[2, 64]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn missing_hlo_file_reported() {
        assert!(HloModuleProto::from_text_file("/no/such/file.hlo.txt").is_err());
    }
}
