//! Q-network training demo + diagnostic probe: runs one trace through the
//! full stack and dumps per-component counters (MC, mesh, cubes) plus the
//! agent's per-action reward attribution — the view used to debug the
//! learning loop during development.
//!
//!     cargo run --release --example train_qnet [--aimm] [--hoard]

use aimm::agent::AimmAgent;
use aimm::config::{MappingScheme, SystemConfig};
use aimm::coordinator::System;
use aimm::runtime::best_qfunction;
use aimm::workloads::{generate, Benchmark};

fn main() {
    let mut cfg = SystemConfig::default();
    let aimm_mode = std::env::args().any(|a| a == "--aimm");
    cfg.mapping = if aimm_mode { MappingScheme::Aimm } else { MappingScheme::Baseline };
    cfg.hoard = std::env::args().any(|a| a == "--hoard");
    let bench = Benchmark::Spmv;
    let trace = generate(bench, 1, 0.25, cfg.seed);
    let mut agent = aimm_mode.then(|| {
        let qf = best_qfunction(cfg.agent.lr, cfg.agent.gamma, cfg.seed);
        AimmAgent::new(qf, cfg.agent.clone(), 42)
    });
    if let Some(a) = agent.as_ref() {
        println!("agent backend: {}", a.backend());
    }
    let mut last_sys = None;
    for run in 0..(if aimm_mode { 3 } else { 1 }) {
        let mut sys = System::new(cfg.clone(), trace.ops.clone(), agent.take());
        let st = sys.run().unwrap();
        agent = sys.take_agent();
        println!("run {run}: cycles={} opc={:.3}", st.cycles, st.opc());
        if let Some(a) = agent.as_ref() {
            println!("  per-action (count, avg reward):");
            for i in 0..8 {
                let n = a.stats.action_counts[i];
                if n > 0 {
                    println!(
                        "    a{i}: n={n} avg_r={:+.3}",
                        a.stats.action_reward_sum[i] / n as f64
                    );
                }
            }
        }
        last_sys = Some(sys);
    }
    let sys = last_sys.unwrap();
    println!(
        "mesh: injected={} delivered={} avg_lat={:.1} qwait/fwd={:.1}",
        sys.mesh.stats.injected,
        sys.mesh.stats.delivered,
        sys.mesh.stats.avg_latency(),
        sys.mesh.stats.total_queue_wait as f64 / sys.mesh.stats.forwards.max(1) as f64
    );
    for mc in &sys.mcs {
        println!(
            "mc{}: dispatched={} completed={} tlb_hit={:.2} avg_op_lat={:.1}",
            mc.id,
            mc.stats.ops_dispatched,
            mc.stats.ops_completed,
            mc.tlb.hit_rate(),
            if mc.stats.ops_completed > 0 {
                mc.stats.total_op_latency as f64 / mc.stats.ops_completed as f64
            } else {
                0.0
            }
        );
    }
}
