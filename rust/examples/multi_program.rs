//! Multi-program scenario (paper §7.5.2): run a diverse application mix
//! concurrently under BNMP, BNMP+HOARD, BNMP+AIMM and BNMP+HOARD+AIMM,
//! reproducing the Fig 12 comparison on one combination.
//!
//!     cargo run --release --example multi_program [A,B,C]

use aimm::config::{MappingScheme, SystemConfig, Technique};
use aimm::coordinator::run_multi;
use aimm::workloads::Benchmark;

fn main() -> anyhow::Result<()> {
    let combo: Vec<Benchmark> = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "SC,SPMV,KM".to_string())
        .split(',')
        .map(|n| Benchmark::from_name(n.trim()).expect("unknown benchmark"))
        .collect();
    let scale = 0.12;
    let runs = 3;
    let names: Vec<&str> = combo.iter().map(|b| b.name()).collect();
    println!("multi-program combo: {}\n", names.join("-"));

    let mut results = Vec::new();
    for (label, hoard, mapping) in [
        ("BNMP", false, MappingScheme::Baseline),
        ("BNMP+HOARD", true, MappingScheme::Baseline),
        ("BNMP+AIMM", false, MappingScheme::Aimm),
        ("BNMP+HOARD+AIMM", true, MappingScheme::Aimm),
    ] {
        let mut cfg = SystemConfig::default();
        cfg.technique = Technique::Bnmp;
        cfg.hoard = hoard;
        cfg.mapping = mapping;
        let s = run_multi(&cfg, &combo, scale, runs)?;
        println!(
            "{label:>16}: cycles={:>8} opc={:.4} hops={:.2}",
            s.last().cycles,
            s.last().opc(),
            s.last().avg_hops
        );
        results.push((label, s.last().cycles));
    }
    let base = results[0].1 as f64;
    println!();
    for (label, cycles) in results {
        println!("{label:>16}: normalized {:.2}", cycles as f64 / base);
    }
    Ok(())
}
