//! Oracle probe: hand-coded policies over the real state vector measure
//! the headroom available to the learned agent.
use aimm::agent::AimmAgent;
use aimm::config::{AgentConfig, MappingScheme, SystemConfig};
use aimm::coordinator::System;
use aimm::runtime::{QFunction, TrainBatch, NUM_ACTIONS};
use aimm::workloads::{generate, Benchmark};

struct FixedQ(usize);
impl QFunction for FixedQ {
    fn q_values(&mut self, _s: &[f32]) -> anyhow::Result<[f32; NUM_ACTIONS]> {
        let mut q = [0.0; NUM_ACTIONS];
        q[self.0] = 1.0;
        Ok(q)
    }
    fn train_batch(&mut self, _b: &TrainBatch) -> anyhow::Result<f32> { Ok(0.0) }
    fn sync_target(&mut self) {}
    fn backend(&self) -> &'static str { "fixed" }
}

/// Migrate-once-when-far: near-data remap iff the page has never been
/// migrated (s[34] == 0) and its recent hop history is high; else default.
struct OracleQ;
impl QFunction for OracleQ {
    fn q_values(&mut self, s: &[f32]) -> anyhow::Result<[f32; NUM_ACTIONS]> {
        let mut q = [0.0; NUM_ACTIONS];
        let migs = s[34];
        let h = &s[35..39]; // hop history, /16-normalized
        let mean = (h[0] + h[1] + h[2] + h[3]) / 4.0;
        let spread = h.iter().cloned().fold(0.0f32, f32::max)
            - h.iter().cloned().fold(1.0f32, f32::min);
        // Far from compute, stably so, and not already migrated.
        if migs == 0.0 && mean > 1.4 / 16.0 && spread < 1.1 / 16.0 {
            q[1] = 1.0; // near-data
        } else {
            q[0] = 1.0; // default
        }
        Ok(q)
    }
    fn train_batch(&mut self, _b: &TrainBatch) -> anyhow::Result<f32> { Ok(0.0) }
    fn sync_target(&mut self) {}
    fn backend(&self) -> &'static str { "oracle" }
}

fn run_policy(bench: Benchmark, qf: Box<dyn QFunction>, runs: usize) -> (u64, f64) {
    let mut cfg = SystemConfig::default();
    cfg.mapping = MappingScheme::Aimm;
    let mut acfg = AgentConfig::default();
    acfg.eps_start = 0.0;
    acfg.eps_end = 0.0;
    cfg.agent = acfg.clone();
    let trace = generate(bench, 1, 1.0, cfg.seed);
    let mut agent = Some(AimmAgent::new(qf, acfg, 42));
    let (mut cycles, mut migrated) = (0, 0.0);
    for _ in 0..runs {
        let mut sys = System::new(cfg.clone(), trace.ops.clone(), agent.take());
        let st = sys.run().unwrap();
        agent = sys.take_agent();
        cycles = st.cycles;
        migrated = st.fraction_pages_migrated;
    }
    (cycles, migrated)
}

fn main() {
    let bench_name = std::env::args().nth(1).unwrap_or("SPMV".into());
    let bench = Benchmark::from_name(&bench_name).unwrap();
    let (base, _) = run_policy(bench, Box::new(FixedQ(0)), 1);
    let (oracle, frac) = run_policy(bench, Box::new(OracleQ), 1);
    println!(
        "{bench_name}: default={base} oracle(migrate-once-when-far)={oracle} ({:+.1}%) \
         migrated={frac:.2}",
        (oracle as f64 / base as f64 - 1.0) * 100.0
    );
}
