//! Mesh scaling (paper §7.5.1): the same workload on a 4×4 and an 8×8
//! memory-cube network — AIMM adapts with no prior training on the new
//! hardware because the per-MC state aggregation is mesh-size-invariant
//! (DESIGN.md §5).
//!
//!     cargo run --release --example mesh_scaling [BENCH]

use aimm::config::{MappingScheme, SystemConfig};
use aimm::coordinator::run_single;
use aimm::workloads::Benchmark;

fn main() -> anyhow::Result<()> {
    let bench = std::env::args()
        .nth(1)
        .and_then(|n| Benchmark::from_name(&n))
        .unwrap_or(Benchmark::Rbm);
    let scale = 0.25;
    for (cols, rows) in [(4usize, 4usize), (8, 8)] {
        let mut cfg = SystemConfig::default();
        cfg.mesh_cols = cols;
        cfg.mesh_rows = rows;

        cfg.mapping = MappingScheme::Baseline;
        let base = run_single(&cfg, bench, scale, 1)?;
        cfg.mapping = MappingScheme::Aimm;
        let aimm = run_single(&cfg, bench, scale, 3)?;
        println!(
            "{}x{} mesh, {}: B={} cycles, AIMM={} cycles (norm {:.2}), hops B={:.2} AIMM={:.2}",
            cols,
            rows,
            bench.name(),
            base.last().cycles,
            aimm.last().cycles,
            aimm.last().cycles as f64 / base.last().cycles as f64,
            base.last().avg_hops,
            aimm.last().avg_hops,
        );
    }
    Ok(())
}
