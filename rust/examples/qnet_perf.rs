use aimm::runtime::{artifacts_dir, PjrtQNet, QFunction, TrainBatch, BATCH, STATE_DIM};
use std::time::Instant;
fn main() {
    let dir = artifacts_dir().expect("artifacts");
    let mut q = PjrtQNet::load(&dir, 1e-3, 0.95).unwrap();
    let s = vec![0.3f32; STATE_DIM];
    for _ in 0..20 { q.q_values(&s).unwrap(); }
    let t0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
    let n = 500;
    for _ in 0..n { q.q_values(&s).unwrap(); }
    println!("infer: {:?}/call", t0.elapsed() / n);
    let batch = TrainBatch {
        s: vec![0.1; BATCH * STATE_DIM], a: vec![1; BATCH], r: vec![0.5; BATCH],
        s2: vec![0.2; BATCH * STATE_DIM], done: vec![0.0; BATCH],
    };
    for _ in 0..5 { q.train_batch(&batch).unwrap(); }
    let t0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
    let n = 100;
    for _ in 0..n { q.train_batch(&batch).unwrap(); }
    println!("train: {:?}/step", t0.elapsed() / n);
}
