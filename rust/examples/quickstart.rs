//! Quickstart: the end-to-end driver (DESIGN.md §7).
//!
//! Runs the SPMV kernel on the 4×4-mesh NMP system three ways — BNMP
//! baseline, BNMP+TOM, BNMP+AIMM (5 repeated runs, DQN persisting across
//! runs per §6.1) — and reports execution time, OPC, hop count and the
//! OPC timeline. With `make artifacts` built, the AIMM agent's dueling
//! Q-network runs through PJRT from the AOT-compiled JAX/Pallas HLO;
//! without artifacts it falls back to the pure-rust linear Q (and says so).
//!
//!     cargo run --release --example quickstart [BENCH] [scale]

use aimm::bench::resample;
use aimm::config::{MappingScheme, SystemConfig};
use aimm::coordinator::{run_single, EpisodeSummary};
#[cfg(feature = "pjrt")]
use aimm::runtime::artifacts_dir;
use aimm::workloads::Benchmark;

fn report(label: &str, s: &EpisodeSummary) {
    let last = s.last();
    println!(
        "{label:>10}: cycles={:>8} opc={:.4} hops={:.2} util={:.3} migrated={:.2}",
        last.cycles,
        last.opc(),
        last.avg_hops,
        last.compute_utilization,
        last.fraction_pages_migrated
    );
}

fn main() -> anyhow::Result<()> {
    let bench = std::env::args()
        .nth(1)
        .and_then(|n| Benchmark::from_name(&n))
        .unwrap_or(Benchmark::Spmv);
    let scale: f64 =
        std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(0.5);

    #[cfg(feature = "pjrt")]
    match artifacts_dir() {
        Some(d) => println!("artifacts: {} (PJRT dueling DQN)", d.display()),
        None => println!("artifacts: NOT FOUND — falling back to linear-Q mock"),
    }
    #[cfg(not(feature = "pjrt"))]
    println!("built without the `pjrt` feature — linear-Q mock agent");
    println!("benchmark {} at scale {scale}\n", bench.name());

    let mut cfg = SystemConfig::default();

    cfg.mapping = MappingScheme::Baseline;
    let base = run_single(&cfg, bench, scale, 1)?;
    report("BNMP (B)", &base);

    cfg.mapping = MappingScheme::Tom;
    let tom = run_single(&cfg, bench, scale, 1)?;
    report("BNMP+TOM", &tom);

    cfg.mapping = MappingScheme::Aimm;
    let aimm = run_single(&cfg, bench, scale, 5)?;
    report("BNMP+AIMM", &aimm);

    let b = base.last().cycles as f64;
    println!(
        "\nnormalized exec time: B=1.00  TOM={:.2}  AIMM={:.2}",
        tom.last().cycles as f64 / b,
        aimm.last().cycles as f64 / b
    );

    // Learning curve across runs (Fig 9's signal).
    println!("\nAIMM learning across runs (cycles per run):");
    for (i, r) in aimm.runs.iter().enumerate() {
        println!("  run {i}: {:>8} cycles, {:>5} invocations, loss {:.3}",
            r.cycles, r.agent_invocations, r.agent_avg_loss);
    }
    let series: Vec<f32> =
        aimm.runs.iter().flat_map(|r| r.opc_timeline.iter().copied()).collect();
    println!("\nOPC timeline (resampled to 24 points):");
    let pts = resample(&series, 24);
    let maxv = pts.iter().cloned().fold(0.001f32, f32::max);
    for (i, v) in pts.iter().enumerate() {
        let bar = "#".repeat(((v / maxv) * 40.0) as usize);
        println!("  t{i:02} {v:.3} {bar}");
    }
    Ok(())
}
