use aimm::config::SystemConfig;
use aimm::coordinator::System;
use aimm::workloads::{generate, Benchmark};
fn main() {
    let cfg = SystemConfig::default();
    let trace = generate(Benchmark::Spmv, 1, 0.12, cfg.seed);
    for _ in 0..20 {
        let mut sys = System::new(cfg.clone(), trace.ops.clone(), None);
        sys.run().unwrap();
    }
}
