//! `aimm` — the leader binary: run episodes, regenerate the paper's
//! tables and figures, sweep the design space, inspect workloads and
//! configurations.
//!
//! ```text
//! aimm run      --bench SPMV [--technique BNMP] [--mapping AIMM|AIMM-MC]
//!               [--scale 0.5] [--runs 5] [--mesh 4x4] [--topology torus]
//!               [--hoard] [--config file.toml] [--seed N]
//!               [--warm-start none|oracle]
//!               [--checkpoint out.json] [--resume in.json]
//! aimm sweep    [--benches all] [--mappings all] [--meshes 4x4,8x8]
//!               [--topologies mesh,torus,ring] [--threads N]
//!               [--out BENCH_sweep.json] [--journal FILE.jsonl]
//!               [--shard I/N] [--fresh] | --merge a.jsonl,b.jsonl
//! aimm analyze  --fig 5a|5b|5c [--scale 1.0]
//! aimm table    --fig 6|7|8|9|10|11|12|13|14|area [--scale 0.25] [--runs 3]
//! aimm table1 | aimm table2
//! aimm multi    --benches SC,KM,RD,MAC [--hoard] [--mapping AIMM] ...
//! aimm curriculum --stages SC,KM,RD [--out BENCH_continual.json] ...
//! aimm serve    [--arrivals poisson|bursty|diurnal] [--tenants 12]
//!               [--mean-gap 400] [--slots 4] [--page-budget 4096]
//!               [--rounds 2] [--out BENCH_serve.json] ...
//! ```

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::path::Path;
use std::process::ExitCode;

use aimm::agent::{CheckpointBundle, DistillStats, WarmStart};
use aimm::bench::figures;
use aimm::bench::sweep::{self, ContinualSequence, SweepGrid};
use aimm::bench::Table;
use aimm::config::{Engine, MappingScheme, SystemConfig, Technique, TopologyKind};
use aimm::coordinator::{
    ensure_serve_checkpointable, episode_ops, fresh_agent, run_curriculum_policy,
    run_serve_policy, run_stream_policy, run_traced_policy, serve_report_json,
    warm_started_policy, CurriculumStage,
};
use aimm::mapping::AnyPolicy;
use aimm::nmp::NmpOp;
use aimm::workloads::{render_trace, ArrivalProcess, Benchmark, FileTrace};

/// Q-backend note for `--help`, matching what this binary was built with.
#[cfg(feature = "pjrt")]
const BACKEND_NOTE: &str =
    "Artifacts: set AIMM_ARTIFACTS or run from the repo root (artifacts/).\n\
     Without artifacts the agent falls back to a pure-rust linear Q (noted in output).";
#[cfg(not(feature = "pjrt"))]
const BACKEND_NOTE: &str =
    "This binary was built without the `pjrt` feature: the agent always uses the\n\
     pure-rust linear Q. Rebuild with `--features pjrt` to execute AOT artifacts.";

fn usage() -> String {
    format!(
        "aimm — AIMM NMP mapping reproduction\n\
         \n\
         subcommands:\n\
           run      --bench <NAME> [--technique BNMP|LDB|PEI]\n\
                    [--mapping B|TOM|AIMM|AIMM-MC|CODA|ORACLE]\n\
                    (AIMM-MC drives one agent per memory controller, with\n\
                    deterministic round-robin experience gossip)\n\
                    [--scale F] [--runs N] [--mesh CxR] [--topology mesh|torus|ring]\n\
                    [--hoard] [--seed N] [--config FILE] [--engine polled|event]\n\
                    [--warm-start none|oracle] pre-train the learning agents on\n\
                    the oracle's dry pass before episode 1 (AIMM/AIMM-MC only;\n\
                    not with --trace — distillation needs the generated stream)\n\
                    [--checkpoint OUT.json] save every learned agent at the\n\
                    episode boundary (aimm-checkpoint-v2 bundle)\n\
                    [--resume IN.json] resume from a saved bundle (or a legacy\n\
                    v1 single-agent file); refused if the per-MC agent count or\n\
                    warm-start mode drifted\n\
                    (checkpoints demand --mapping AIMM or AIMM-MC: the policies\n\
                    with learned state)\n\
                    [--capture OUT.tr] write the episode's op stream as a\n\
                    versioned trace file (replayable, bit-identical stats)\n\
                    [--trace FILE.tr] replay a captured trace instead of\n\
                    generating (--bench and --scale don't apply)\n\
           multi    --benches A,B,C (same options as run, including --capture;\n\
                    replay a multi-program capture with run --trace)\n\
           curriculum --stages A,B+C,D (ordered; + joins a multi-program stage)\n\
                    [--runs N (0 = paper default per stage)] [--scale F]\n\
                    [--warm-start none|oracle] distill stage 1's oracle pass\n\
                    into the agents before the curriculum starts\n\
                    [--resume IN.json] [--checkpoint OUT.json]\n\
                    [--out BENCH_continual.json]\n\
                    runs the stages carrying ONE learned policy end-to-end (one\n\
                    agent, or AIMM-MC's per-MC pool) and prints the cold-vs-warm\n\
                    first-run transfer table (defaults to --mapping AIMM)\n\
           sweep    [--benches all|A,B,A+B (use + for a multi-program combo)]\n\
                    [--techniques BNMP,LDB,PEI|all]\n\
                    [--mappings B,TOM,AIMM,CODA,ORACLE|all (default: the paper's\n\
                    B,TOM,AIMM trio)]\n\
                    [--meshes 4x4,8x8] [--topologies mesh,torus,ring|all]\n\
                    [--topology X (single-topology shorthand)]\n\
                    [--seeds N,M] [--scale F] [--runs N]\n\
                    [--threads N] [--hoard] [--engine polled|event]\n\
                    [--out BENCH_sweep.json]\n\
                    [--journal FILE.jsonl (default: --out with .jsonl)]\n\
                    [--shard I/N (run only the I-th of N deterministic grid\n\
                    slices; journal only, no aggregated report — merge after)]\n\
                    [--fresh (delete the journal first, disabling resume)]\n\
                    [--merge a.jsonl,b.jsonl (fold shard journals into --out\n\
                    without running anything)]\n\
                    every finished cell is journaled; rerunning the same grid\n\
                    resumes from the journal for free (Ctrl-C safe)\n\
           serve    open-loop multi-tenant service: tenants arrive on a\n\
                    stochastic schedule, lease pages + a compute slot, run\n\
                    their op stream, and depart; ONE agent learns across the\n\
                    whole service lifetime (defaults to --mapping AIMM)\n\
                    [--arrivals poisson|bursty|diurnal] [--tenants N]\n\
                    [--mean-gap CYCLES] [--slots N] [--page-budget PAGES]\n\
                    [--rounds N] [--scale F] [--threads N] [--seed N]\n\
                    [--mapping ...] [--engine polled|event] [--config FILE]\n\
                    [--warm-start none|oracle] (pre-train on the tenants'\n\
                    pooled op streams before round 1)\n\
                    [--out BENCH_serve.json] [--checkpoint OUT.json]\n\
                    [--resume IN.json]\n\
                    prints per-tenant slowdown vs an isolated run plus the\n\
                    p50/p99/p999 tail and Jain fairness index\n\
           analyze  --fig 5a|5b|5c [--scale F] [--seed N]\n\
           table    --fig 6|7|8|9|10|11|12|13|14|area [--scale F] [--runs N]\n\
           table1   print the active hardware configuration (paper Table 1)\n\
           table2   print the benchmark list (paper Table 2)\n\
           config   print the default config as TOML\n\
         \n\
         {BACKEND_NOTE}"
    )
}

// The parse errors list every valid name, derived from the same `ALL`
// registries `from_name` reads — a policy/technique/topology added to
// its registry shows up in the error text automatically.

fn parse_technique(t: &str) -> Result<Technique, String> {
    Technique::from_name(t)
        .ok_or_else(|| format!("unknown technique {t} (expected {})", Technique::name_list()))
}

fn parse_mapping(m: &str) -> Result<MappingScheme, String> {
    MappingScheme::from_name(m).ok_or_else(|| {
        format!("unknown mapping {m} (expected {}, or BASELINE)", MappingScheme::name_list())
    })
}

fn parse_engine(e: &str) -> Result<Engine, String> {
    Engine::from_name(e)
        .ok_or_else(|| format!("unknown engine {e} (expected {})", Engine::name_list()))
}

fn parse_topology(t: &str) -> Result<TopologyKind, String> {
    TopologyKind::from_name(t)
        .ok_or_else(|| format!("unknown topology {t} (expected {})", TopologyKind::name_list()))
}

fn parse_arrivals(a: &str) -> Result<ArrivalProcess, String> {
    ArrivalProcess::from_name(a)
        .ok_or_else(|| format!("unknown arrivals {a} (expected {})", ArrivalProcess::name_list()))
}

/// Parse a non-negative count flag (`--mean-gap`, `--page-budget`).
fn parse_count(flag: &str, v: &str) -> Result<u64, String> {
    match v.parse() {
        Ok(n) => Ok(n),
        Err(_) => Err(format!("bad --{flag} {v:?} (expected a non-negative integer)")),
    }
}

/// Seeds parse as decimal or `0x`-hex — the hex form is what
/// `BENCH_sweep.json` records. A report cell reproduces via
/// `aimm run --seed 0x…` (applied as-is); `sweep --seeds` instead takes
/// base seeds that are re-folded with each cell's benchmark combo.
fn parse_seed(s: &str) -> Result<u64, String> {
    let s = s.trim();
    let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => s.parse::<u64>(),
    };
    parsed.map_err(|_| format!("bad seed {s:?} (expected decimal or 0x-hex)"))
}

/// `--shard I/N`: 0-based slice of the canonically ordered grid (shard
/// `I` owns the cells whose grid index `i` has `i % N == I`).
fn parse_shard(s: &str) -> Result<sweep::ShardSpec, String> {
    let (i, n) = s
        .trim()
        .split_once('/')
        .ok_or_else(|| format!("shard expects I/N (e.g. 0/4), got {s:?}"))?;
    let index = i.trim().parse().map_err(|_| format!("bad shard index {i:?}"))?;
    let count = n.trim().parse().map_err(|_| format!("bad shard count {n:?}"))?;
    if count == 0 || index >= count {
        return Err(format!("shard {index}/{count} out of range (0-based index < count)"));
    }
    Ok(sweep::ShardSpec { index, count })
}

fn parse_mesh(s: &str) -> Result<(usize, usize), String> {
    let (c, r) = s
        .trim()
        .split_once('x')
        .ok_or_else(|| format!("mesh expects CxR, got {s:?}"))?;
    let c = c.parse().map_err(|_| format!("bad mesh cols {c:?}"))?;
    let r = r.parse().map_err(|_| format!("bad mesh rows {r:?}"))?;
    Ok((c, r))
}

/// Comma-separated benchmark combos; `+` joins a multi-program combo
/// (`SC,KM+RD` = `[SC]` then `[KM, RD]`). Shared by `sweep --benches`
/// and `curriculum --stages`.
fn parse_combos(list: &str) -> Result<Vec<Vec<Benchmark>>, String> {
    list.split(',')
        .map(|combo| {
            combo
                .split('+')
                .map(|n| {
                    Benchmark::from_name(n.trim())
                        .ok_or_else(|| format!("unknown benchmark {n:?}"))
                })
                .collect::<Result<Vec<_>, _>>()
        })
        .collect()
}

/// `--warm-start <mode>`: how the learning policy is initialized before
/// its first episode — `none` (cold, the default) or `oracle`
/// (distillation pre-training on the oracle's dry pass, DESIGN.md §15).
fn warm_start_flag(args: &Args) -> Result<WarmStart, String> {
    match args.get("warm-start") {
        Some(w) => WarmStart::from_name(w).ok_or_else(|| {
            format!("unknown warm-start {w} (expected {})", WarmStart::name_list())
        }),
        None => Ok(WarmStart::None),
    }
}

/// The CLI guard the checkpoint plumbing hangs off: `--checkpoint` and
/// `--resume` demand a policy with learned state to persist — AIMM's
/// single agent or AIMM-MC's per-MC pool — and every other scheme is
/// rejected loudly, naming itself. Silently ignoring the flag under
/// B/TOM/CODA/ORACLE would be the exact bug class this plumbing exists
/// to remove.
fn ensure_cli_checkpointable(args: &Args, cfg: &SystemConfig) -> Result<(), String> {
    let wants_ckpt = args.get("checkpoint").is_some() || args.get("resume").is_some();
    if wants_ckpt && !cfg.mapping.checkpointable() {
        return Err(format!(
            "--checkpoint/--resume require --mapping AIMM or AIMM-MC: \
             the {} policy is not checkpointable",
            cfg.mapping
        ));
    }
    Ok(())
}

/// Learned agents the configured mapping carries — the expected bundle
/// shape for drift rejection: 1 for AIMM, one per MC for AIMM-MC.
fn expected_agents(cfg: &SystemConfig) -> usize {
    if cfg.mapping == MappingScheme::AimmMc {
        cfg.num_mcs()
    } else {
        1
    }
}

/// `--resume PATH`: load the v2 bundle (or a legacy v1 single-agent
/// document), refuse shape/provenance drift by field name, and rebuild
/// the run's starting policy from it. A resumed policy is never
/// re-distilled — the bundle records the warm-start mode it was trained
/// under and `ensure_resumable` holds the requested mode to it.
fn resume_policy(cfg: &SystemConfig, path: &str, warm: WarmStart) -> Result<AnyPolicy, String> {
    let bundle = CheckpointBundle::load(Path::new(path)).map_err(|e| e.to_string())?;
    bundle
        .ensure_resumable(expected_agents(cfg), warm)
        .map_err(|e| format!("resume {path}: {e}"))?;
    let seed_agent = if cfg.mapping.uses_agent() {
        Some(fresh_agent(cfg).map_err(|e| e.to_string())?)
    } else {
        None
    };
    let mut policy = AnyPolicy::new(cfg, &[], seed_agent);
    policy
        .restore_from_bundle(&bundle)
        .map_err(|e| format!("resume {path}: {e}"))?;
    println!(
        "resumed {} agent(s) from {path} ({} backend, warm-start {})",
        bundle.agents.len(),
        bundle.agents[0].q.backend,
        bundle.warm_start.name()
    );
    Ok(policy)
}

/// The policy an episode-running subcommand starts with: resumed from a
/// bundle when `--resume` was given, otherwise built cold or distilled
/// per `--warm-start` over the episode's op stream.
fn initial_policy(
    args: &Args,
    cfg: &SystemConfig,
    ops: &[NmpOp],
    warm: WarmStart,
) -> Result<AnyPolicy, String> {
    match args.get("resume") {
        Some(path) => resume_policy(cfg, path, warm),
        None => {
            let (policy, stats) =
                warm_started_policy(cfg, ops, warm).map_err(|e| e.to_string())?;
            print_distill(warm, &stats);
            Ok(policy)
        }
    }
}

/// Surface what a warm-start did — "pre-trained on N pages" belongs on
/// the console, not silently inside the policy.
fn print_distill(warm: WarmStart, stats: &[DistillStats]) {
    let Some(first) = stats.first() else { return };
    let batches: usize = stats.iter().map(|s| s.batches).sum();
    println!(
        "warm-start {}: {} agent(s) distilled from {} oracle pages \
         ({} examples x {} epochs, {} batches of {})",
        warm.name(),
        stats.len(),
        first.pages,
        first.examples,
        first.epochs,
        batches,
        first.batch
    );
}

/// Honor `--checkpoint PATH`: bundle every learned agent the policy
/// carries at the episode boundary the run just reached, stamped with
/// the run's warm-start provenance (aimm-checkpoint-v2).
fn save_bundle(args: &Args, policy: &AnyPolicy, warm: WarmStart) -> Result<(), String> {
    let Some(path) = args.get("checkpoint") else { return Ok(()) };
    let bundle = policy.checkpoint_bundle(warm).map_err(|e| e.to_string())?;
    bundle.save(Path::new(path)).map_err(|e| e.to_string())?;
    println!(
        "wrote checkpoint {path} ({} agent(s), {} backend, warm-start {})",
        bundle.agents.len(),
        bundle.agents[0].q.backend,
        bundle.warm_start.name()
    );
    Ok(())
}

/// Tiny flag parser: `--key value` pairs plus bare flags.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args, String> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let boolean = ["hoard", "help", "fresh"].contains(&key);
                if boolean {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                } else {
                    let val = argv
                        .get(i + 1)
                        .ok_or_else(|| format!("--{key} needs a value"))?;
                    flags.insert(key.to_string(), val.clone());
                    i += 2;
                }
            } else {
                return Err(format!("unexpected argument {a:?}"));
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad number {v:?}")),
            None => Ok(default),
        }
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer {v:?}")),
            None => Ok(default),
        }
    }
}

fn build_cfg(args: &Args) -> Result<SystemConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => SystemConfig::load(std::path::Path::new(path))
            .map_err(|e| format!("config {path}: {e}"))?,
        None => SystemConfig::default(),
    };
    if let Some(t) = args.get("technique") {
        cfg.technique = parse_technique(t)?;
    }
    if let Some(m) = args.get("mapping") {
        cfg.mapping = parse_mapping(m)?;
    }
    if let Some(mesh) = args.get("mesh") {
        let (c, r) = parse_mesh(mesh)?;
        cfg.mesh_cols = c;
        cfg.mesh_rows = r;
    }
    if let Some(t) = args.get("topology") {
        cfg.topology = parse_topology(t)?;
    }
    if args.get("hoard").is_some() {
        cfg.hoard = true;
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = parse_seed(s)?;
    }
    if let Some(e) = args.get("engine") {
        cfg.engine = parse_engine(e)?;
    }
    cfg.validate().map_err(|e| e.to_string())?;
    Ok(cfg)
}

fn print_summary(s: &aimm::coordinator::EpisodeSummary, cfg: &SystemConfig) {
    println!(
        "episode {} [{} + {}{}{}{}] — {} runs",
        s.name,
        cfg.technique,
        cfg.mapping,
        if cfg.hoard { " + HOARD" } else { "" },
        // Off-default topology is worth flagging: it changes the numbers.
        match cfg.topology {
            TopologyKind::Mesh => String::new(),
            other => format!(" | {other}"),
        },
        // The engine never changes the numbers (DESIGN.md §8); flag the
        // slow reference loop so timing comparisons stay honest.
        if cfg.engine == Engine::Polled { " | polled" } else { "" },
        s.runs.len()
    );
    for (i, r) in s.runs.iter().enumerate() {
        println!(
            "  run {i}: cycles={:>9} ops={:>8} opc={:.4} hops={:.2} util={:.3} \
             migrated={:.2} inv={} loss={:.4}",
            r.cycles,
            r.ops_completed,
            r.opc(),
            r.avg_hops,
            r.compute_utilization,
            r.fraction_pages_migrated,
            r.agent_invocations,
            r.agent_avg_loss,
        );
    }
    let first = s.first();
    let last = s.last();
    if first.cycles > 0 {
        println!(
            "  exec-time change across runs: {:+.1}%  \
             (energy: aimm {:.0} nJ, net {:.0} nJ, mem {:.0} nJ)",
            (last.cycles as f64 / first.cycles as f64 - 1.0) * 100.0,
            last.energy.aimm_hardware_nj,
            last.energy.network_nj,
            last.energy.memory_nj,
        );
    }
}

fn real_main() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    if args.get("help").is_some() {
        println!("{}", usage());
        return Ok(());
    }
    let scale = args.f64_or("scale", 0.25)?;
    let seed = match args.get("seed") {
        Some(s) => parse_seed(s)?,
        None => 7,
    };

    match cmd.as_str() {
        "run" => {
            let cfg = build_cfg(&args)?;
            let runs = args.usize_or("runs", figures::SINGLE_RUNS)?;
            let warm = warm_start_flag(&args)?;
            ensure_cli_checkpointable(&args, &cfg)?;
            let (s, policy) = if let Some(path) = args.get("trace") {
                // Replay: the file is the whole workload definition.
                if args.get("bench").is_some() {
                    return Err("--trace replays a captured stream; drop --bench".into());
                }
                if warm != WarmStart::None {
                    return Err(
                        "--warm-start distills from a generated op stream and cannot \
                         profile a --trace replay; generate with --bench to warm-start \
                         (a resumed bundle already carries its warm-start)"
                            .into(),
                    );
                }
                let file = FileTrace::open(Path::new(path)).map_err(|e| e.to_string())?;
                println!(
                    "replaying {path}: {} ({} ops, {} pid(s), captured at scale {})",
                    file.name(),
                    file.op_count(),
                    file.pid_count(),
                    file.scale()
                );
                if let Some(out) = args.get("capture") {
                    // Re-emit the stream being replayed (canonical form).
                    let text = file.render().map_err(|e| e.to_string())?;
                    sweep::atomic_write_text(Path::new(out), &text)
                        .map_err(|e| e.to_string())?;
                    println!("captured {out} ({} ops)", file.op_count());
                }
                let initial = match args.get("resume") {
                    Some(ck) => Some(resume_policy(&cfg, ck, warm)?),
                    None => None,
                };
                run_traced_policy(&cfg, &file, runs, initial).map_err(|e| e.to_string())?
            } else {
                let name = args.get("bench").ok_or("run needs --bench (or --trace FILE)")?;
                let bench = Benchmark::from_name(name)
                    .ok_or_else(|| format!("unknown benchmark {name:?}"))?;
                let (ops, ep_name) =
                    episode_ops(&cfg, &[bench], scale).map_err(|e| e.to_string())?;
                if let Some(out) = args.get("capture") {
                    let text = render_trace(&ep_name, scale, &ops).map_err(|e| e.to_string())?;
                    sweep::atomic_write_text(Path::new(out), &text)
                        .map_err(|e| e.to_string())?;
                    println!("captured {out} ({} ops)", ops.len());
                }
                let policy = initial_policy(&args, &cfg, &ops, warm)?;
                run_stream_policy(&cfg, &ops, runs, &ep_name, policy)
                    .map_err(|e| e.to_string())?
            };
            print_summary(&s, &cfg);
            save_bundle(&args, &policy, warm)?;
        }
        "multi" => {
            let cfg = build_cfg(&args)?;
            if args.get("trace").is_some() {
                return Err(
                    "multi generates its stream; replay a capture with run --trace".into()
                );
            }
            let list = args.get("benches").ok_or("multi needs --benches A,B,C")?;
            let benches: Vec<Benchmark> = list
                .split(',')
                .map(|n| {
                    Benchmark::from_name(n.trim())
                        .ok_or_else(|| format!("unknown benchmark {n:?}"))
                })
                .collect::<Result<_, _>>()?;
            if benches.len() < 2 {
                return Err("multi needs at least two benchmarks (use run for one)".into());
            }
            let runs = args.usize_or("runs", figures::MULTI_RUNS)?;
            let warm = warm_start_flag(&args)?;
            ensure_cli_checkpointable(&args, &cfg)?;
            let (ops, ep_name) = episode_ops(&cfg, &benches, scale).map_err(|e| e.to_string())?;
            if let Some(out) = args.get("capture") {
                let text = render_trace(&ep_name, scale, &ops).map_err(|e| e.to_string())?;
                sweep::atomic_write_text(Path::new(out), &text).map_err(|e| e.to_string())?;
                println!("captured {out} ({} ops)", ops.len());
            }
            let policy = initial_policy(&args, &cfg, &ops, warm)?;
            let (s, policy) = run_stream_policy(&cfg, &ops, runs, &ep_name, policy)
                .map_err(|e| e.to_string())?;
            print_summary(&s, &cfg);
            save_bundle(&args, &policy, warm)?;
        }
        "curriculum" => {
            let mut cfg = build_cfg(&args)?;
            // Transfer only exists for the learned mapping; default to
            // AIMM unless the user chose a scheme explicitly — via the
            // flag or a `mapping` key in their config file. A config
            // that only tunes hardware knobs must not silently drop the
            // curriculum to Baseline (all-zero transfer, doubled work).
            let explicit_mapping = args.get("mapping").is_some()
                || args.get("config").is_some_and(|path| {
                    std::fs::read_to_string(path)
                        .ok()
                        .and_then(|text| aimm::config::parse_kv(&text).ok())
                        .is_some_and(|kvs| kvs.iter().any(|(k, _)| k == "mapping"))
                });
            if !explicit_mapping {
                cfg.mapping = MappingScheme::Aimm;
            }
            let list = args
                .get("stages")
                .ok_or("curriculum needs --stages A,B+C,… (e.g. SC,KM,RD)")?;
            let combos = parse_combos(list)?;
            // 0 = per-stage §6.1 default (5 single-program, 10 multi).
            let runs = args.usize_or("runs", 0)?;
            let stages: Vec<CurriculumStage> = combos
                .into_iter()
                .map(|benches| CurriculumStage { benches, runs })
                .collect();
            let warm = warm_start_flag(&args)?;
            ensure_cli_checkpointable(&args, &cfg)?;
            let initial = match args.get("resume") {
                Some(path) => Some(resume_policy(&cfg, path, warm)?),
                None => None,
            };
            if initial.is_none() && warm != WarmStart::None {
                println!(
                    "warm-start {}: distilling stage 1's oracle pass into the {} policy \
                     before the curriculum starts",
                    warm.name(),
                    cfg.mapping
                );
            }
            let t0 = std::time::Instant::now();
            let (report, policy) = run_curriculum_policy(&cfg, &stages, scale, initial, warm)
                .map_err(|e| e.to_string())?;
            println!(
                "curriculum: {} stage(s) × cold+warm in {:?}",
                report.stages.len(),
                t0.elapsed()
            );
            let mut t = Table::new(
                "Curriculum transfer (first-run OPC: cold start vs inherited model)",
                &[
                    "stage",
                    "runs",
                    "cold first",
                    "warm first",
                    "transfer",
                    "cold last",
                    "warm last",
                ],
            );
            for s in &report.stages {
                t.row(vec![
                    s.name.clone(),
                    s.warm.runs.len().to_string(),
                    format!("{:.4}", s.cold_first_opc()),
                    format!("{:.4}", s.warm_first_opc()),
                    format!("{:+.1}%", s.transfer_gain() * 100.0),
                    format!("{:.4}", s.cold.last().opc()),
                    format!("{:.4}", s.warm.last().opc()),
                ]);
            }
            println!("{}", t.render());
            if let Some(out) = args.get("out") {
                let name: String = report
                    .stages
                    .iter()
                    .map(|s| s.name.as_str())
                    .collect::<Vec<_>>()
                    .join(">");
                let seq = ContinualSequence {
                    name,
                    technique: cfg.technique,
                    mapping: cfg.mapping,
                    scale,
                    seed: cfg.seed,
                    report: report.clone(),
                };
                sweep::write_continual_report(Path::new(out), &[seq])
                    .map_err(|e| e.to_string())?;
                println!("wrote {out}");
            }
            save_bundle(&args, &policy, warm)?;
        }
        "serve" => {
            let mut cfg = build_cfg(&args)?;
            // Serve is the continual-learning service story: one agent
            // carried across the whole tenant churn. Same defaulting
            // rule as curriculum — AIMM unless the user picked a scheme
            // via the flag or a `mapping` key in their config file.
            let explicit_mapping = args.get("mapping").is_some()
                || args.get("config").is_some_and(|path| {
                    std::fs::read_to_string(path)
                        .ok()
                        .and_then(|text| aimm::config::parse_kv(&text).ok())
                        .is_some_and(|kvs| kvs.iter().any(|(k, _)| k == "mapping"))
                });
            if !explicit_mapping {
                cfg.mapping = MappingScheme::Aimm;
            }
            if let Some(a) = args.get("arrivals") {
                cfg.serve.arrivals = parse_arrivals(a)?;
            }
            cfg.serve.tenants = args.usize_or("tenants", cfg.serve.tenants)?;
            if let Some(v) = args.get("mean-gap") {
                cfg.serve.mean_gap = parse_count("mean-gap", v)?;
            }
            cfg.serve.slots = args.usize_or("slots", cfg.serve.slots)?;
            if let Some(v) = args.get("page-budget") {
                cfg.serve.page_budget = parse_count("page-budget", v)?;
            }
            cfg.serve.rounds = args.usize_or("rounds", cfg.serve.rounds)?;
            cfg.serve.scale = args.f64_or("scale", cfg.serve.scale)?;
            cfg.validate().map_err(|e| e.to_string())?;
            let warm = warm_start_flag(&args)?;
            if args.get("checkpoint").is_some() || args.get("resume").is_some() {
                ensure_serve_checkpointable(&cfg).map_err(|e| e.to_string())?;
            }
            let initial = match args.get("resume") {
                Some(path) => Some(resume_policy(&cfg, path, warm)?),
                None => None,
            };
            if initial.is_none() && warm != WarmStart::None {
                println!(
                    "warm-start {}: distilling the tenants' pooled op streams into the \
                     {} policy before round 1",
                    warm.name(),
                    cfg.mapping
                );
            }
            let threads = args.usize_or("threads", sweep::default_threads())?.max(1);
            println!(
                "serve: {} tenant(s), {} arrivals (mean gap {}), {} slot(s), \
                 {}-page budget, {} round(s), mapping {}",
                cfg.serve.tenants,
                cfg.serve.arrivals,
                cfg.serve.mean_gap,
                cfg.serve.slots,
                cfg.serve.page_budget,
                cfg.serve.rounds,
                cfg.mapping
            );
            let t0 = std::time::Instant::now();
            let (outcome, policy) =
                run_serve_policy(&cfg, threads, initial, warm).map_err(|e| e.to_string())?;
            let last = outcome.last_round();
            let mut t = Table::new(
                "Serve churn (last round; slowdown = residency / isolated run)",
                &["tenant", "pid", "arrival", "admitted", "finished", "ops", "pages", "slowdown"],
            );
            let base = outcome.slowdowns.len() - last.tenants.len();
            for (i, ts) in last.tenants.iter().enumerate() {
                t.row(vec![
                    ts.name.clone(),
                    ts.pid.to_string(),
                    ts.arrival.to_string(),
                    ts.admitted.to_string(),
                    ts.finished.to_string(),
                    ts.ops.to_string(),
                    ts.pages.to_string(),
                    format!("{:.3}", outcome.slowdowns[base + i]),
                ]);
            }
            println!("{}", t.render());
            println!(
                "tail (all {} round(s) pooled): p50 {:.3}x  p99 {:.3}x  p999 {:.3}x  \
                 Jain fairness {:.3}  ({:?})",
                outcome.rounds.len(),
                outcome.p50,
                outcome.p99,
                outcome.p999,
                outcome.fairness,
                t0.elapsed()
            );
            if let Some(out) = args.get("out") {
                let text = serve_report_json(&cfg, &outcome);
                sweep::atomic_write_text(Path::new(out), &text).map_err(|e| e.to_string())?;
                println!("wrote {out}");
            }
            save_bundle(&args, &policy, warm)?;
        }
        "sweep" => {
            // Merge mode: fold shard journals into one aggregated report
            // and exit — nothing runs, the grid axes don't apply.
            if let Some(list) = args.get("merge") {
                for flag in ["shard", "fresh", "journal"] {
                    if args.get(flag).is_some() {
                        return Err(format!("--merge runs nothing; drop --{flag}"));
                    }
                }
                let paths: Vec<std::path::PathBuf> =
                    list.split(',').map(|p| std::path::PathBuf::from(p.trim())).collect();
                let report = sweep::merge_files(&paths).map_err(|e| e.to_string())?;
                let out = args.get("out").unwrap_or("BENCH_sweep.json");
                sweep::atomic_write_text(Path::new(out), &report).map_err(|e| e.to_string())?;
                println!("merged {} journal(s) -> {out}", paths.len());
                return Ok(());
            }
            // The grid takes plural axis flags; catch the singular forms
            // `run` accepts instead of silently ignoring them.
            for (singular, plural) in [
                ("bench", "benches"),
                ("technique", "techniques"),
                ("mapping", "mappings"),
                ("mesh", "meshes"),
                ("seed", "seeds"),
            ] {
                if args.get(singular).is_some() {
                    return Err(format!("sweep takes --{plural}, not --{singular}"));
                }
            }
            // Sweep defaults are calibrated like the bench targets
            // (scale 0.12, 2 runs) so the default 27-cell grid finishes
            // in minutes, not hours.
            let scale = args.f64_or("scale", 0.12)?;
            let runs = args.usize_or("runs", 2)?;
            let mut grid = SweepGrid::new(scale, runs);
            if let Some(list) = args.get("benches") {
                if !list.eq_ignore_ascii_case("all") {
                    grid.benches = parse_combos(list)?;
                }
            }
            if let Some(list) = args.get("techniques") {
                grid.techniques = if list.eq_ignore_ascii_case("all") {
                    Technique::ALL.to_vec()
                } else {
                    list.split(',')
                        .map(|t| parse_technique(t.trim()))
                        .collect::<Result<_, _>>()?
                };
            }
            if let Some(list) = args.get("mappings") {
                // `all` = every registered policy (B, TOM, AIMM, CODA,
                // ORACLE); the default without the flag stays the
                // paper's trio so existing reports don't grow cells.
                grid.mappings = if list.eq_ignore_ascii_case("all") {
                    MappingScheme::ALL.to_vec()
                } else {
                    list.split(',')
                        .map(|m| parse_mapping(m.trim()))
                        .collect::<Result<_, _>>()?
                };
            }
            if let Some(list) = args.get("meshes") {
                grid.meshes = list.split(',').map(parse_mesh).collect::<Result<_, _>>()?;
            }
            // Topology accepts both spellings: `--topologies a,b|all` for
            // a multi-value axis, `--topology x` (the same flag run/multi
            // take) for a single-topology sweep.
            if let Some(list) = args.get("topologies") {
                if args.get("topology").is_some() {
                    return Err("pass either --topology or --topologies, not both".into());
                }
                grid.topologies = if list.eq_ignore_ascii_case("all") {
                    TopologyKind::ALL.to_vec()
                } else {
                    list.split(',')
                        .map(|t| parse_topology(t.trim()))
                        .collect::<Result<_, _>>()?
                };
            } else if let Some(t) = args.get("topology") {
                grid.topologies = vec![parse_topology(t)?];
            }
            if let Some(list) = args.get("seeds") {
                grid.seeds = list.split(',').map(parse_seed).collect::<Result<_, _>>()?;
            }
            if args.get("hoard").is_some() {
                grid.hoard = vec![true];
            }
            if let Some(e) = args.get("engine") {
                // A run-wide switch, not a grid axis: both engines give
                // identical stats, so reports diff clean either way.
                grid.engine = parse_engine(e)?;
            }
            let threads = args.usize_or("threads", sweep::default_threads())?.max(1);
            let cells = grid.cells();
            if cells.is_empty() {
                return Err("sweep grid is empty".into());
            }
            let shard = args.get("shard").map(parse_shard).transpose()?;
            let out = args.get("out").unwrap_or("BENCH_sweep.json");
            let journal = match args.get("journal") {
                Some(p) => std::path::PathBuf::from(p),
                None => sweep::journal_path_for(Path::new(out)),
            };
            if args.get("fresh").is_some() {
                match std::fs::remove_file(&journal) {
                    Ok(()) => println!("removed journal {} (--fresh)", journal.display()),
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                    Err(e) => return Err(format!("removing {}: {e}", journal.display())),
                }
            }
            let owned = match shard {
                Some(s) => (0..cells.len()).filter(|&i| s.selects(i)).count(),
                None => cells.len(),
            };
            let shard_note = match shard {
                Some(s) => format!(" (shard {}/{} of {} total)", s.index, s.count, cells.len()),
                None => String::new(),
            };
            println!(
                "sweep: {owned} cells{shard_note} ({runs} runs each, scale {scale}) on \
                 {threads} thread(s), journal {}",
                journal.display()
            );
            let t0 = std::time::Instant::now();
            let report = sweep::run_journaled(&cells, shard, threads, &journal)
                .map_err(|e| e.to_string())?;
            let mut t = Table::new(
                "Sweep results (steady-state run per cell)",
                &["cell", "cycles", "opc", "hops", "util", "migrated", "src"],
            );
            for o in &report.outcomes {
                let row = o.row().map_err(|e| e.to_string())?;
                t.row(vec![
                    row.name,
                    row.cycles.to_string(),
                    format!("{:.4}", row.opc),
                    format!("{:.2}", row.avg_hops),
                    format!("{:.3}", row.compute_utilization),
                    format!("{:.2}", row.fraction_pages_migrated),
                    (if row.cached { "cache" } else { "run" }).to_string(),
                ]);
            }
            println!("{}", t.render());
            println!(
                "journal: {} computed, {} resumed from {}{}{}",
                report.computed,
                report.cached,
                journal.display(),
                if report.stale > 0 {
                    format!(", {} stale dropped", report.stale)
                } else {
                    String::new()
                },
                if report.corrupt > 0 {
                    format!(", {} corrupt line(s) dropped", report.corrupt)
                } else {
                    String::new()
                },
            );
            match shard {
                Some(s) => println!(
                    "shard {}/{} done in {:?} — no aggregated report; once every shard \
                     ran, fold the journals with `aimm sweep --merge …`",
                    s.index,
                    s.count,
                    t0.elapsed()
                ),
                None => {
                    let text = sweep::report_json_outcomes(&report.outcomes);
                    sweep::atomic_write_text(Path::new(out), &text).map_err(|e| e.to_string())?;
                    println!(
                        "wrote {out} ({} cells) in {:?}",
                        report.outcomes.len(),
                        t0.elapsed()
                    );
                }
            }
        }
        "analyze" => {
            let fig = args.get("fig").ok_or("analyze needs --fig 5a|5b|5c")?;
            let t = match fig {
                "5a" => figures::fig5a(scale.max(0.5), seed),
                "5b" => figures::fig5b(scale.max(0.5), seed),
                "5c" => figures::fig5c(scale.max(0.5), seed),
                other => return Err(format!("unknown analysis figure {other}")),
            };
            println!("{}", t.render());
        }
        "table" => {
            let fig = args.get("fig").ok_or("table needs --fig N")?;
            let runs = args.usize_or("runs", 3)?;
            let t = match fig {
                "6" => figures::fig6(scale, runs),
                "7" => figures::fig7(scale, runs),
                "8" => figures::fig8(scale, runs),
                "9" => figures::fig9(scale, runs, 24),
                "10" => figures::fig10(scale, runs),
                "11" => figures::fig11(scale, runs),
                "12" => figures::fig12(scale, runs),
                "13" => figures::fig13(scale, runs),
                "14" => figures::fig14(scale, runs),
                "area" => Ok(figures::area_table()),
                other => return Err(format!("unknown figure {other}")),
            }
            .map_err(|e| e.to_string())?;
            println!("{}", t.render());
        }
        "table1" => {
            let cfg = build_cfg(&args)?;
            println!("{}", figures::table1(&cfg).render());
        }
        "table2" => println!("{}", figures::table2().render()),
        "config" => println!("{}", SystemConfig::default().to_toml()),
        other => {
            return Err(format!("unknown subcommand {other:?}\n\n{}", usage()));
        }
    }
    Ok(())
}

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(argv: &[&str]) -> Args {
        let owned: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
        Args::parse(&owned).expect("test flags parse")
    }

    /// The CLI guard the checkpoint plumbing hangs off: every
    /// non-checkpointable policy is rejected loudly, naming itself,
    /// for `--checkpoint` and `--resume` alike — and both learning
    /// shapes (AIMM, AIMM-MC) pass through.
    #[test]
    fn checkpoint_flags_reject_non_checkpointable_policies_by_name() {
        for scheme in MappingScheme::ALL {
            let mut cfg = SystemConfig::default();
            cfg.mapping = scheme;
            // No checkpoint flags: the guard never fires.
            assert!(ensure_cli_checkpointable(&args(&[]), &cfg).is_ok(), "{scheme}");
            for flag in ["--checkpoint", "--resume"] {
                let a = args(&[flag, "ck.json"]);
                match ensure_cli_checkpointable(&a, &cfg) {
                    Ok(()) => {
                        assert!(scheme.checkpointable(), "{scheme}: guard must fire");
                    }
                    Err(err) => {
                        assert!(!scheme.checkpointable(), "{scheme}: guard must not fire");
                        assert!(err.contains(scheme.name()), "{scheme}: {err}");
                        assert!(err.contains("not checkpointable"), "{scheme}: {err}");
                    }
                }
            }
        }
    }

    /// `--resume` goes through the v2 bundle loader and its drift
    /// rejection: the expected bundle shape follows the mapping (one
    /// agent for AIMM, one per MC for AIMM-MC), so a bundle saved under
    /// the other shape is refused naming the drifted field.
    #[test]
    fn resume_checks_bundle_shape_against_the_mapping() {
        let mut aimm = SystemConfig::default();
        aimm.mapping = MappingScheme::Aimm;
        assert_eq!(expected_agents(&aimm), 1);
        let mut mc = SystemConfig::default();
        mc.mapping = MappingScheme::AimmMc;
        assert_eq!(expected_agents(&mc), mc.num_mcs());
        assert!(mc.num_mcs() > 1, "drift between the shapes must be observable");
        // A missing file fails on IO, naming the path — not on a panic.
        let err = resume_policy(&aimm, "/nonexistent/bundle.json", WarmStart::None)
            .unwrap_err();
        assert!(err.contains("/nonexistent/bundle.json"), "{err}");
    }

    /// `--warm-start` parses through the registry and lists the valid
    /// modes on a typo; the absent flag is a cold start.
    #[test]
    fn warm_start_flag_parses_and_lists_names() {
        assert_eq!(warm_start_flag(&args(&[])), Ok(WarmStart::None));
        assert_eq!(warm_start_flag(&args(&["--warm-start", "none"])), Ok(WarmStart::None));
        assert_eq!(
            warm_start_flag(&args(&["--warm-start", "ORACLE"])),
            Ok(WarmStart::Oracle)
        );
        let err = warm_start_flag(&args(&["--warm-start", "sgd"])).unwrap_err();
        assert!(err.contains("none|oracle"), "{err}");
    }

    /// `--shard I/N` parses 0-based and rejects everything out of range
    /// loudly — a shard silently clamped to a different slice would run
    /// the wrong cells and still merge cleanly.
    #[test]
    fn shard_flag_parses_strictly() {
        assert_eq!(parse_shard("0/4"), Ok(sweep::ShardSpec { index: 0, count: 4 }));
        assert_eq!(parse_shard(" 3/4 "), Ok(sweep::ShardSpec { index: 3, count: 4 }));
        assert_eq!(parse_shard("0/1"), Ok(sweep::ShardSpec { index: 0, count: 1 }));
        for bad in ["4/4", "1/0", "4", "a/4", "0/b", "-1/4", "1/4/2"] {
            assert!(parse_shard(bad).is_err(), "{bad:?} parsed");
        }
    }

    /// CLI parse errors list the valid names, derived from the same
    /// registries `from_name` reads — coda/oracle show up automatically.
    #[test]
    fn flag_parse_errors_list_valid_names() {
        let err = parse_mapping("bogus").unwrap_err();
        assert!(err.contains("B|TOM|AIMM|AIMM-MC|CODA|ORACLE"), "{err}");
        let err = parse_technique("bogus").unwrap_err();
        assert!(err.contains("BNMP|LDB|PEI"), "{err}");
        let err = parse_engine("bogus").unwrap_err();
        assert!(err.contains("polled|event"), "{err}");
        let err = parse_topology("bogus").unwrap_err();
        assert!(err.contains("mesh|torus|ring"), "{err}");
        // And the new policies parse as first-class CLI values.
        assert_eq!(parse_mapping("coda"), Ok(MappingScheme::Coda));
        assert_eq!(parse_mapping("oracle"), Ok(MappingScheme::Oracle));
        assert_eq!(parse_mapping("aimm-mc"), Ok(MappingScheme::AimmMc));
    }

    /// `serve --arrivals` parses every registered process and lists
    /// them all on a typo, same registry-backed contract as the other
    /// name flags.
    #[test]
    fn arrivals_flag_parses_every_process_and_lists_names() {
        for p in ArrivalProcess::ALL {
            assert_eq!(parse_arrivals(p.name()), Ok(p), "{p} roundtrips");
            assert_eq!(parse_arrivals(&p.name().to_uppercase()), Ok(p));
        }
        let err = parse_arrivals("bogus").unwrap_err();
        assert!(err.contains("poisson|bursty|diurnal"), "{err}");
    }

    /// The count flags reject garbage by flag name instead of panicking
    /// or silently defaulting.
    #[test]
    fn count_flags_parse_strictly() {
        assert_eq!(parse_count("mean-gap", "400"), Ok(400));
        assert_eq!(parse_count("page-budget", "0"), Ok(0));
        for bad in ["", "-3", "4.5", "many"] {
            let err = parse_count("mean-gap", bad).unwrap_err();
            assert!(err.contains("--mean-gap"), "{bad:?}: {err}");
        }
    }
}
