//! The coordinator: wires the substrates into a running NMP system and
//! orchestrates the paper's episode protocol (§6.1 — 5 repeated runs for
//! single-program workloads, 10 for multi-program, clearing simulation
//! state but retaining the DNN between runs).

pub mod runner;
pub mod system;

pub use runner::{run_cell, run_multi, run_single, run_stream, EpisodeSummary};
pub use system::System;
