//! The coordinator: wires the substrates into a running NMP system and
//! orchestrates the paper's episode protocol (§6.1 — 5 repeated runs for
//! single-program workloads, 10 for multi-program, clearing simulation
//! state but retaining the DNN between runs), plus the cross-program
//! [`curriculum`] driver that carries one agent through an ordered
//! sequence of episodes and measures cold-vs-warm transfer.

pub mod curriculum;
pub mod runner;
pub mod system;

pub use curriculum::{run_curriculum, CurriculumReport, CurriculumStage, StageOutcome};
pub use runner::{
    episode_ops, fresh_agent, run_cell, run_episode_with, run_multi, run_single, run_stream,
    run_stream_with, EpisodeSummary,
};
pub use system::System;
