//! The coordinator: wires the substrates into a running NMP system and
//! orchestrates the paper's episode protocol (§6.1 — 5 repeated runs for
//! single-program workloads, 10 for multi-program, clearing simulation
//! state but retaining the DNN between runs), plus the cross-program
//! [`curriculum`] driver that carries one agent through an ordered
//! sequence of episodes and measures cold-vs-warm transfer. The
//! [`serve`] module layers an open-loop multi-tenant service on top:
//! tenants arrive on a stochastic schedule, lease pages and compute
//! slots, run, and depart, while one agent learns across the whole
//! service lifetime and tail slowdown/fairness are reported.

pub mod curriculum;
pub mod runner;
pub mod serve;
pub mod system;

pub use curriculum::{
    run_curriculum, run_curriculum_policy, CurriculumReport, CurriculumStage, StageOutcome,
};
pub use runner::{
    episode_ops, fresh_agent, run_cell, run_episode_with, run_multi, run_single, run_stream,
    run_stream_policy, run_stream_with, run_traced_policy, run_traced_with, warm_started_policy,
    EpisodeSummary,
};
pub use serve::{
    build_tenants, ensure_serve_checkpointable, isolated_baselines, run_serve, run_serve_policy,
    serve_report_json, serve_stream_policy, serve_stream_with, summarize, ServeOutcome,
    TenantFeed, TenantRun, TenantSpec,
};
pub use system::System;
