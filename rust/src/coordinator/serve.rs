//! Multi-tenant service mode (`aimm serve`): open-loop tenant churn.
//!
//! The paper's multi-program evaluation (§7.5.2) interleaves a fixed
//! program set that starts and ends together. The ROADMAP north-star —
//! heavy traffic from millions of users — is a different regime: tenants
//! *arrive* (drawn from the benchmark mix on a Poisson / bursty /
//! diurnal interarrival process, [`crate::workloads::arrivals`]),
//! *lease* pages and a compute slot at admission, run a bounded op
//! stream, and *depart*, releasing every page — while ONE
//! continually-learning agent (PR 3's checkpoint machinery, threaded
//! through the PR 5 [`MappingPolicy`](crate::mapping::MappingPolicy)
//! seam) survives the whole service lifetime.
//!
//! The headline metric is not mean OPC but the **per-tenant slowdown
//! distribution**: each tenant's service time (arrival → last op
//! completed, queueing included) over its isolated-run baseline, reported
//! as nearest-rank p50/p99/p999 plus a Jain fairness index
//! ([`crate::metrics::percentiles`]). Co-location quality degrades
//! precisely when page ownership churns, so the tail — not the mean — is
//! where a mapping policy earns its keep.
//!
//! Everything is a pure function of `SystemConfig` (tenant mix, arrival
//! schedule and per-tenant traces all derive from `cfg.seed`), baselines
//! fan out through the order-preserving
//! [`parallel_map`](crate::bench::sweep::parallel_map), and the serve run
//! itself is single-threaded simulation — so results are byte-identical
//! at any worker count and across both engines.

use std::collections::VecDeque;

use crate::agent::{AimmAgent, WarmStart};
use crate::bench::sweep::parallel_map;
use crate::config::{Pid, SystemConfig};
use crate::mapping::{AnyPolicy, MappingPolicy};
use crate::metrics::{jain_fairness, percentile, RunStats, TenantStats};
use crate::nmp::NmpOp;
use crate::runtime::json::write as jw;
use crate::sim::{Cycle, Rng};
use crate::workloads::{arrival_schedule, generate, Benchmark};

use super::runner::{fresh_agent, warm_started_policy};
use super::system::System;

/// Seed fold for the bench-mix stream (which benchmark each tenant runs
/// and its trace seed). Distinct from every other fold in the crate.
const MIX_SEED_FOLD: u64 = 0x5E27;
/// Seed fold for the arrival schedule.
const ARRIVAL_SEED_FOLD: u64 = 0xA221;

/// One tenant: identity, arrival time, op stream and page footprint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSpec {
    /// Benchmark name the tenant was drawn as (e.g. `SPMV`).
    pub name: String,
    pub pid: Pid,
    /// Cycle at which the tenant joins the admission queue.
    pub arrival: Cycle,
    pub ops: Vec<NmpOp>,
    /// Distinct pages the tenant leases while resident.
    pub pages: u64,
}

/// A tenant's live bookkeeping inside a serve run.
#[derive(Debug, Clone)]
pub struct TenantRun {
    pub spec: TenantSpec,
    /// Next op index to issue.
    pub next_op: usize,
    /// Ops completed so far.
    pub done: u64,
    pub admitted_at: Option<Cycle>,
    pub finished_at: Option<Cycle>,
}

/// The open-loop admission machine [`System`] drives in serve mode:
/// arrivals → FIFO wait queue → admission (compute slot + page lease) →
/// round-robin issue → departure. All state is plain vectors and
/// indices; nothing here depends on map iteration order or threads.
#[derive(Debug, Clone)]
pub struct TenantFeed {
    /// All tenants, in arrival order (index = pid - 1 for built mixes).
    pub tenants: Vec<TenantRun>,
    /// Index of the next tenant yet to arrive.
    next_arrival: usize,
    /// Arrived, awaiting admission (strict FIFO).
    wait: VecDeque<usize>,
    /// Resident tenants (indices into `tenants`).
    pub active: Vec<usize>,
    /// Round-robin issue cursor over `active`.
    pub cursor: usize,
    leased_pages: u64,
    slots: usize,
    page_budget: u64,
    total_ops: u64,
    distinct_pages_total: u64,
    last_arrival: Cycle,
}

impl TenantFeed {
    /// Wrap `tenants` (must be sorted by arrival, with unique pids; each
    /// footprint must fit the page budget alone or its admission would
    /// stall the FIFO forever).
    pub fn new(tenants: Vec<TenantSpec>, slots: usize, page_budget: u64) -> anyhow::Result<Self> {
        anyhow::ensure!(slots >= 1, "serve needs at least one compute slot");
        let mut pids: Vec<Pid> = tenants.iter().map(|t| t.pid).collect();
        pids.sort_unstable();
        pids.dedup();
        anyhow::ensure!(pids.len() == tenants.len(), "tenant pids must be unique");
        for w in tenants.windows(2) {
            anyhow::ensure!(
                w[0].arrival <= w[1].arrival,
                "tenants must be sorted by arrival cycle"
            );
        }
        for t in &tenants {
            anyhow::ensure!(
                t.pages <= page_budget,
                "tenant {} (pid {}) leases {} pages, over the {page_budget}-page budget — \
                 it could never be admitted",
                t.name,
                t.pid,
                t.pages
            );
        }
        let total_ops = tenants.iter().map(|t| t.ops.len() as u64).sum();
        let distinct_pages_total = tenants.iter().map(|t| t.pages).sum();
        let last_arrival = tenants.last().map(|t| t.arrival).unwrap_or(0);
        Ok(Self {
            tenants: tenants
                .into_iter()
                .map(|spec| TenantRun {
                    spec,
                    next_op: 0,
                    done: 0,
                    admitted_at: None,
                    finished_at: None,
                })
                .collect(),
            next_arrival: 0,
            wait: VecDeque::new(),
            active: Vec::new(),
            cursor: 0,
            leased_pages: 0,
            slots,
            page_budget,
            total_ops,
            distinct_pages_total,
            last_arrival,
        })
    }

    /// Move every tenant whose arrival cycle has passed into the wait
    /// queue (in arrival order).
    pub fn enqueue_arrivals(&mut self, now: Cycle) {
        while self.next_arrival < self.tenants.len()
            && self.tenants[self.next_arrival].spec.arrival <= now
        {
            self.wait.push_back(self.next_arrival);
            self.next_arrival += 1;
        }
    }

    /// The FIFO head, if a compute slot and the page budget can take it.
    fn head_fits(&self) -> Option<usize> {
        let &ti = self.wait.front()?;
        let fits = self.active.len() < self.slots
            && self.leased_pages + self.tenants[ti].spec.pages <= self.page_budget;
        fits.then_some(ti)
    }

    /// Would [`admit_ready`](Self::admit_ready) admit someone right now?
    /// (The event engine's admission wake-up condition.)
    pub fn can_admit(&self) -> bool {
        self.head_fits().is_some()
    }

    /// Admit from the FIFO head while slots and budget allow — strict
    /// FIFO, no skipping, so admission order never depends on tenant
    /// size. Returns the admitted pids (the system creates their
    /// address spaces).
    pub fn admit_ready(&mut self, now: Cycle) -> Vec<Pid> {
        let mut admitted = Vec::new();
        while let Some(ti) = self.head_fits() {
            self.wait.pop_front();
            let t = &mut self.tenants[ti];
            t.admitted_at = Some(now);
            if t.spec.ops.is_empty() {
                // A degenerate zero-op tenant is served instantly;
                // without this it would never complete an op, never set
                // `finished_at`, and wedge its slot forever.
                t.finished_at = Some(now);
            }
            self.leased_pages += t.spec.pages;
            self.active.push(ti);
            admitted.push(t.spec.pid);
        }
        admitted
    }

    /// An op of `pid` completed. Linear scan: tenant counts are dozens,
    /// and a pid→index map would only duplicate this Vec.
    pub fn on_complete(&mut self, pid: Pid, now: Cycle) {
        for t in &mut self.tenants {
            if t.spec.pid == pid {
                t.done += 1;
                if t.done == t.spec.ops.len() as u64 {
                    t.finished_at = Some(now);
                }
                return;
            }
        }
    }

    /// Remove `active[k]` and return its page lease to the budget.
    pub fn depart(&mut self, k: usize) {
        let ti = self.active.remove(k);
        self.leased_pages -= self.tenants[ti].spec.pages;
    }

    /// Does any resident tenant still have ops to issue?
    pub fn has_issuable(&self) -> bool {
        self.active.iter().any(|&ti| {
            let t = &self.tenants[ti];
            t.next_op < t.spec.ops.len()
        })
    }

    /// The next not-yet-queued arrival cycle, if any.
    pub fn next_arrival_at(&self) -> Option<Cycle> {
        self.tenants.get(self.next_arrival).map(|t| t.spec.arrival)
    }

    /// Every tenant arrived, was admitted, and departed.
    pub fn all_done(&self) -> bool {
        self.next_arrival >= self.tenants.len() && self.wait.is_empty() && self.active.is_empty()
    }

    pub fn last_arrival(&self) -> Cycle {
        self.last_arrival
    }

    pub fn total_ops(&self) -> u64 {
        self.total_ops
    }

    /// Sum of per-tenant distinct-page footprints. Pids are unique and
    /// never reused, so the sum is exactly the distinct (pid, page)
    /// count of the whole service trace.
    pub fn distinct_pages_total(&self) -> u64 {
        self.distinct_pages_total
    }

    /// Per-tenant accounting rows for [`RunStats::tenants`], in tenant
    /// (arrival) order.
    pub fn tenant_stats(&self) -> Vec<TenantStats> {
        self.tenants
            .iter()
            .map(|t| TenantStats {
                name: t.spec.name.clone(),
                pid: t.spec.pid,
                arrival: t.spec.arrival,
                admitted: t.admitted_at.unwrap_or(0),
                finished: t.finished_at.unwrap_or(0),
                ops: t.spec.ops.len() as u64,
                pages: t.spec.pages,
            })
            .collect()
    }
}

/// Build the tenant mix for `cfg`: arrival times from the configured
/// interarrival process, one benchmark draw + trace seed per tenant from
/// an independent Rng stream. Pure function of the config — the whole
/// service workload is pinned by `cfg.seed`.
pub fn build_tenants(cfg: &SystemConfig) -> Vec<TenantSpec> {
    let serve = &cfg.serve;
    let arrivals = arrival_schedule(
        serve.arrivals,
        serve.tenants,
        serve.mean_gap,
        cfg.seed ^ ARRIVAL_SEED_FOLD,
    );
    let mut rng = Rng::new(cfg.seed ^ MIX_SEED_FOLD);
    let mut out = Vec::with_capacity(arrivals.len());
    for (i, &arrival) in arrivals.iter().enumerate() {
        let bench = *rng.choice(&Benchmark::ALL);
        let trace_seed = rng.next_u64();
        let pid = i as Pid + 1;
        let trace = generate(bench, pid, serve.scale, trace_seed);
        let pages = trace.distinct_pages() as u64;
        out.push(TenantSpec {
            name: bench.name().to_string(),
            pid,
            arrival,
            ops: trace.ops,
            pages,
        });
    }
    out
}

/// Run the service `rounds` times, threading the mapping policy through
/// every round exactly like
/// [`run_stream_with`](crate::coordinator::run_stream_with) threads it
/// through episode runs: per-round control state resets, carried
/// learning state — the continual-learning premise — survives the whole
/// service lifetime.
/// The policy is constructed over the concatenated tenant streams so
/// profile-based policies (ORACLE) see the full op population.
pub fn serve_stream_with(
    cfg: &SystemConfig,
    tenants: &[TenantSpec],
    rounds: usize,
    agent: Option<AimmAgent>,
) -> anyhow::Result<(Vec<RunStats>, Option<AimmAgent>)> {
    let all_ops: Vec<NmpOp> = tenants.iter().flat_map(|t| t.ops.iter().copied()).collect();
    let policy = AnyPolicy::new(cfg, &all_ops, agent);
    let (stats, mut policy) = serve_stream_policy(cfg, tenants, rounds, policy)?;
    Ok((stats, policy.take_agent()))
}

/// The policy-carrying core of [`serve_stream_with`]: thread an existing
/// policy through `rounds` service rounds and hand the whole policy
/// back. AIMM-MC and warm-started lineages come through here — their
/// learned state lives in the policy object, not the single-agent seam.
pub fn serve_stream_policy(
    cfg: &SystemConfig,
    tenants: &[TenantSpec],
    rounds: usize,
    mut policy: AnyPolicy,
) -> anyhow::Result<(Vec<RunStats>, AnyPolicy)> {
    anyhow::ensure!(rounds >= 1, "serve needs at least one round");
    let mut stats = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let feed = TenantFeed::new(tenants.to_vec(), cfg.serve.slots, cfg.serve.page_budget)?;
        let mut sys = System::with_tenants(cfg.clone(), feed, policy);
        stats.push(sys.run()?);
        policy = sys.take_policy();
    }
    Ok((stats, policy))
}

/// Each tenant's isolated-run baseline: the cycles its stream takes on
/// an otherwise-empty system under the same config (cold agent for
/// agent-bearing policies — the §6.1 episode start). Fanned out through
/// the order-preserving [`parallel_map`], so the returned vector is in
/// tenant order at any worker count.
pub fn isolated_baselines(
    cfg: &SystemConfig,
    tenants: &[TenantSpec],
    threads: usize,
) -> anyhow::Result<Vec<u64>> {
    let results = parallel_map(tenants, threads.max(1), |t| -> anyhow::Result<u64> {
        let agent = if cfg.mapping.uses_agent() { Some(fresh_agent(cfg)?) } else { None };
        let mut sys = System::new(cfg.clone(), t.ops.clone(), agent);
        Ok(sys.run()?.cycles)
    });
    results.into_iter().collect()
}

/// A finished serve study: per-round stats, per-tenant baselines, and
/// the pooled tail/fairness numbers.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    /// Per-round stats; each round's `tenants` rows are in tenant order.
    pub rounds: Vec<RunStats>,
    /// Per-tenant isolated baselines (cycles), tenant order.
    pub baselines: Vec<u64>,
    /// Per-tenant slowdowns pooled across all rounds (round-major,
    /// tenant order inside each round): service time (arrival → last op
    /// complete, queueing delay included) over the isolated baseline.
    pub slowdowns: Vec<f64>,
    pub p50: f64,
    pub p99: f64,
    pub p999: f64,
    pub fairness: f64,
}

impl ServeOutcome {
    /// The steady-state round (last — after learning converges).
    pub fn last_round(&self) -> &RunStats {
        self.rounds.last().expect("at least one round")
    }
}

/// Compute pooled slowdowns + tail metrics from per-round stats and
/// per-tenant baselines.
pub fn summarize(rounds: Vec<RunStats>, baselines: Vec<u64>) -> anyhow::Result<ServeOutcome> {
    let mut slowdowns = Vec::with_capacity(rounds.len() * baselines.len());
    for r in &rounds {
        anyhow::ensure!(
            r.tenants.len() == baselines.len(),
            "round reports {} tenants, {} baselines",
            r.tenants.len(),
            baselines.len()
        );
        for (t, &base) in r.tenants.iter().zip(&baselines) {
            anyhow::ensure!(
                t.finished >= t.arrival && base > 0,
                "tenant {} (pid {}) has no finished service interval",
                t.name,
                t.pid
            );
            slowdowns.push((t.finished - t.arrival) as f64 / base as f64);
        }
    }
    let p50 = percentile(&slowdowns, 50.0);
    let p99 = percentile(&slowdowns, 99.0);
    let p999 = percentile(&slowdowns, 99.9);
    let fairness = jain_fairness(&slowdowns);
    Ok(ServeOutcome { rounds, baselines, slowdowns, p50, p99, p999, fairness })
}

/// The whole serve study for `cfg`: build the mix, run the isolated
/// baselines (`threads` workers), run `cfg.serve.rounds` service rounds
/// carrying `agent` (or a fresh one for agent-bearing policies), and
/// reduce to tail metrics. Returns the outcome plus the carried agent
/// for checkpointing.
pub fn run_serve(
    cfg: &SystemConfig,
    threads: usize,
    agent: Option<AimmAgent>,
) -> anyhow::Result<(ServeOutcome, Option<AimmAgent>)> {
    let initial = agent.map(|a| AnyPolicy::new(cfg, &[], Some(a)));
    let (outcome, mut policy) = run_serve_policy(cfg, threads, initial, WarmStart::None)?;
    Ok((outcome, policy.take_agent()))
}

/// The policy-level serve study behind [`run_serve`] — the entry the
/// `--warm-start` and AIMM-MC paths use. `warm_start` distills the
/// concatenated tenant streams (the same op population the oracle's dry
/// run profiles) into the serving policy before round 1; resuming from
/// `initial` skips distillation — the learning it would seed is already
/// there. Isolated baselines always run cold: they are the yardstick.
pub fn run_serve_policy(
    cfg: &SystemConfig,
    threads: usize,
    initial: Option<AnyPolicy>,
    warm_start: WarmStart,
) -> anyhow::Result<(ServeOutcome, AnyPolicy)> {
    let tenants = build_tenants(cfg);
    anyhow::ensure!(!tenants.is_empty(), "serve needs at least one tenant");
    let baselines = isolated_baselines(cfg, &tenants, threads)?;
    let policy = match initial {
        Some(p) => {
            anyhow::ensure!(
                p.scheme() == cfg.mapping,
                "the initial policy is {} but the config maps with {} — refusing to mix \
                 lineages",
                p.scheme().name(),
                cfg.mapping
            );
            p
        }
        None => {
            let all_ops: Vec<NmpOp> =
                tenants.iter().flat_map(|t| t.ops.iter().copied()).collect();
            warm_started_policy(cfg, &all_ops, warm_start)?.0
        }
    };
    let (rounds, policy) = serve_stream_policy(cfg, &tenants, cfg.serve.rounds, policy)?;
    Ok((summarize(rounds, baselines)?, policy))
}

/// Serve-mode checkpointing carries learned state across service rounds;
/// only the AIMM shapes have any. Refuse loudly, by name, before any
/// work happens.
pub fn ensure_serve_checkpointable(cfg: &SystemConfig) -> anyhow::Result<()> {
    anyhow::ensure!(
        cfg.mapping.checkpointable(),
        "serve-mode --checkpoint/--resume require --mapping AIMM or AIMM-MC: the {} policy \
         is not checkpointable (only AIMM carries learned state)",
        cfg.mapping.name()
    );
    Ok(())
}

// ---------------------------------------------------------------------
// Report (`BENCH_serve.json`): fixed key order, like every report in
// bench/sweep — byte-reproducible for a given config and parseable by
// runtime/json.rs. Engine is deliberately omitted (polled and event
// serve reports must diff clean, like sweep reports).
// ---------------------------------------------------------------------

fn tenant_row_json(t: &TenantStats, slowdown: f64) -> String {
    jw::obj(&[
        ("name", jw::string(&t.name)),
        ("pid", t.pid.to_string()),
        ("arrival", t.arrival.to_string()),
        ("admitted", t.admitted.to_string()),
        ("finished", t.finished.to_string()),
        ("ops", t.ops.to_string()),
        ("pages", t.pages.to_string()),
        ("slowdown", jw::num(slowdown)),
    ])
}

/// Serialize a serve study. Per-tenant rows come from the **last**
/// (steady-state) round; the tail numbers pool every round.
pub fn serve_report_json(cfg: &SystemConfig, outcome: &ServeOutcome) -> String {
    let last = outcome.last_round();
    let last_slowdowns = &outcome.slowdowns[outcome.slowdowns.len() - last.tenants.len()..];
    let rows: Vec<String> = last
        .tenants
        .iter()
        .zip(last_slowdowns)
        .map(|(t, &s)| tenant_row_json(t, s))
        .collect();
    jw::obj(&[
        ("schema", jw::string("aimm-serve-v1")),
        ("arrivals", jw::string(cfg.serve.arrivals.name())),
        ("tenants", cfg.serve.tenants.to_string()),
        ("mean_gap", cfg.serve.mean_gap.to_string()),
        ("slots", cfg.serve.slots.to_string()),
        ("page_budget", cfg.serve.page_budget.to_string()),
        ("rounds", cfg.serve.rounds.to_string()),
        ("scale", jw::num(cfg.serve.scale)),
        ("seed", jw::hex_u64(cfg.seed)),
        ("mapping", jw::string(cfg.mapping.name())),
        ("p50_slowdown", jw::num(outcome.p50)),
        ("p99_slowdown", jw::num(outcome.p99)),
        ("p999_slowdown", jw::num(outcome.p999)),
        ("fairness", jw::num(outcome.fairness)),
        ("tenant_rows", format!("[{}]", rows.join(","))),
        ("regenerate", jw::string("cargo bench --bench serve_churn")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MappingScheme;

    fn serve_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.frames_per_cube = 4096;
        cfg.serve.tenants = 4;
        cfg.serve.mean_gap = 200;
        cfg.serve.slots = 2;
        cfg.serve.page_budget = 2048;
        cfg.serve.rounds = 1;
        cfg.serve.scale = 0.02;
        cfg
    }

    #[test]
    fn build_tenants_is_deterministic_and_pid_unique() {
        let cfg = serve_cfg();
        let a = build_tenants(&cfg);
        let b = build_tenants(&cfg);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.pid, y.pid);
            assert_eq!(x.name, y.name);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.ops.len(), y.ops.len());
        }
        let mut pids: Vec<Pid> = a.iter().map(|t| t.pid).collect();
        pids.dedup();
        assert_eq!(pids, vec![1, 2, 3, 4]);
        let mut other = cfg.clone();
        other.seed ^= 1;
        let c = build_tenants(&other);
        let a_arrivals: Vec<Cycle> = a.iter().map(|t| t.arrival).collect();
        let c_arrivals: Vec<Cycle> = c.iter().map(|t| t.arrival).collect();
        assert_ne!(a_arrivals, c_arrivals, "different seeds must change the mix");
    }

    #[test]
    fn feed_admission_respects_slots_and_budget_fifo() {
        let mk = |pid: Pid, arrival: Cycle, pages: u64| TenantSpec {
            name: format!("T{pid}"),
            pid,
            arrival,
            ops: Vec::new(),
            pages,
        };
        let specs = vec![mk(1, 0, 60), mk(2, 0, 50), mk(3, 0, 10)];
        let mut feed = TenantFeed::new(specs, 2, 100).unwrap();
        feed.enqueue_arrivals(0);
        // Slot for 1; 2 does not fit the budget, and FIFO means 3 may
        // NOT jump the queue even though it would fit.
        assert_eq!(feed.admit_ready(0), vec![1]);
        assert!(!feed.can_admit());
        // 1 departs → budget frees → 2 then 3 admit in order.
        feed.tenants[0].finished_at = Some(5);
        feed.depart(0);
        assert_eq!(feed.admit_ready(6), vec![2, 3]);
        assert!(!feed.all_done());
        feed.depart(0);
        feed.depart(0);
        assert!(feed.all_done());
    }

    #[test]
    fn feed_rejects_oversized_and_unsorted_tenants() {
        let mk = |pid: Pid, arrival: Cycle, pages: u64| TenantSpec {
            name: format!("T{pid}"),
            pid,
            arrival,
            ops: Vec::new(),
            pages,
        };
        let err = TenantFeed::new(vec![mk(1, 0, 200)], 1, 100).unwrap_err().to_string();
        assert!(err.contains("over the 100-page budget"), "{err}");
        let unsorted = vec![mk(1, 9, 1), mk(2, 3, 1)];
        let err = TenantFeed::new(unsorted, 1, 100).unwrap_err().to_string();
        assert!(err.contains("sorted by arrival"), "{err}");
        let dup_pids = vec![mk(7, 0, 1), mk(7, 1, 1)];
        let err = TenantFeed::new(dup_pids, 1, 100).unwrap_err().to_string();
        assert!(err.contains("unique"), "{err}");
    }

    #[test]
    fn serve_run_completes_every_tenant_and_releases_pages() {
        let cfg = serve_cfg();
        let (outcome, agent) = run_serve(&cfg, 2, None).unwrap();
        assert!(agent.is_none(), "baseline carries no agent");
        assert_eq!(outcome.rounds.len(), 1);
        let r = &outcome.rounds[0];
        let total: u64 = r.tenants.iter().map(|t| t.ops).sum();
        assert_eq!(r.ops_completed, total);
        for t in &r.tenants {
            assert!(t.admitted >= t.arrival, "{}", t.name);
            assert!(t.finished > t.admitted, "{}", t.name);
        }
        assert!(outcome.p50 > 0.0);
        assert!(outcome.p999 >= outcome.p99 && outcome.p99 >= outcome.p50);
        assert!(outcome.fairness > 0.0 && outcome.fairness <= 1.0);
    }

    #[test]
    fn serve_carries_the_agent_across_rounds() {
        let mut cfg = serve_cfg();
        cfg.mapping = MappingScheme::Aimm;
        cfg.serve.rounds = 2;
        let (outcome, agent) = run_serve(&cfg, 2, None).unwrap();
        assert_eq!(outcome.rounds.len(), 2);
        let agent = agent.expect("AIMM agent survives the service");
        assert!(agent.stats.invocations > 0);
        assert!(outcome.rounds.iter().all(|r| r.agent_invocations > 0));
    }

    #[test]
    fn serve_report_has_fixed_keys_and_parses_back() {
        let cfg = serve_cfg();
        let (outcome, _) = run_serve(&cfg, 2, None).unwrap();
        let text = serve_report_json(&cfg, &outcome);
        assert_eq!(text, serve_report_json(&cfg, &outcome), "fixed key order");
        let parsed = crate::runtime::json::parse(&text).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("aimm-serve-v1"));
        assert_eq!(parsed.get("arrivals").unwrap().as_str(), Some("poisson"));
        assert!(parsed.get("p999_slowdown").is_some());
        let rows = parsed.get("tenant_rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 4);
        assert!(rows[0].get("slowdown").is_some());
    }

    #[test]
    fn non_aimm_policies_refuse_serve_checkpointing_by_name() {
        for mapping in MappingScheme::ALL {
            let mut cfg = serve_cfg();
            cfg.mapping = mapping;
            let res = ensure_serve_checkpointable(&cfg);
            if mapping.checkpointable() {
                assert!(res.is_ok(), "{mapping}");
            } else {
                let err = res.unwrap_err().to_string();
                assert!(err.contains(mapping.name()), "{err}");
                assert!(err.contains("not checkpointable"), "{err}");
            }
        }
    }
}
