//! The simulated NMP system: CPU-side op feed → MCs → cube network →
//! cubes, with the migration system and the configured
//! [`MappingPolicy`] plugged in. One `tick` = one memory-network
//! cycle. The system is **policy-agnostic**: it owns the actuators
//! (MMU, compute-remap table, migration engine), forwards events to
//! the policy (dispatched ops via the MCs, clock ticks), and applies
//! whatever [`MappingAction`]s come back — it never asks *which*
//! scheme is configured. Likewise the interconnect geometry (mesh /
//! torus / ring) is entirely the fabric's business
//! ([`crate::noc::topology`]); this module only ever asks it
//! topology-neutral questions (routing happens inside `mesh.tick`, MC
//! homing via `cfg.cube_home_mc`).

use std::collections::HashSet;

use crate::agent::AimmAgent;
use crate::alloc::{HoardAllocator, Placement, StripePlacement};
use crate::config::{Engine, Pid, SystemConfig, VPage};
use crate::cube::Cube;
use crate::mapping::{AnyPolicy, ComputeRemapTable, MappingAction, MappingPolicy, PolicyCtx};
use crate::mc::{IssueDeps, Mc};
use crate::metrics::{EnergyCounts, EnergyModel, RunStats};
use crate::migration::{MigRequest, MigrationSystem};
use crate::mmu::Mmu;
use crate::nmp::{CpuCache, NmpOp};
use crate::noc::packet::{Packet, Payload};
use crate::noc::Mesh;
use crate::sim::{Cycle, EventWheel};
use crate::workloads::{GeneratedProvider, TraceProvider};
use super::serve::TenantFeed;

/// How often cubes report occupancy / row-hit to their MC (§5.1
/// "communicated to a cube's nearest memory controller periodically").
const CUBE_REPORT_PERIOD: u64 = 64;

/// Hard guard against livelocked configurations.
const MAX_CYCLES_PER_OP: u64 = 600;
const MAX_CYCLES_FLOOR: u64 = 2_000_000;

/// The assembled system.
pub struct System {
    pub cfg: SystemConfig,
    pub mesh: Mesh,
    pub cubes: Vec<Cube>,
    pub mcs: Vec<Mc>,
    pub mmu: Mmu,
    placement: Box<dyn Placement>,
    /// The configured mapping policy — the whole decision layer.
    policy: AnyPolicy,
    pub remap_table: ComputeRemapTable,
    cpu_cache: CpuCache,
    pub migration: MigrationSystem,

    /// Trace feed: the op stream, behind the provider seam — generated
    /// traces wrap their vector ([`GeneratedProvider`]), captured files
    /// stream with bounded lookahead
    /// ([`FileProvider`](crate::workloads::FileProvider)).
    provider: Box<dyn TraceProvider>,
    issued: u64,
    completed: u64,

    /// Serve mode (`aimm serve`): tenants arriving, leasing pages and
    /// compute slots, and departing while the run is live. `None` on
    /// every trace path — the episode/sweep runners never construct
    /// it, so their behaviour (and the golden fixture) is untouched.
    tenant_feed: Option<TenantFeed>,

    now: Cycle,

    // Migration bookkeeping (Fig 10).
    migrated_pages: HashSet<(Pid, VPage)>,
    accesses_on_migrated: u64,
    page_accesses_total: u64,
    migrations_total: u64,
    /// Pages ever written (destination operands) — these migrate in
    /// blocking mode; read-only pages go non-blocking (§5.3).
    rw_pages: HashSet<(Pid, VPage)>,

    /// Reused delivery scratch buffer (allocation-free hot loop).
    scratch: Vec<Packet>,
    // Timeline.
    opc_timeline: Vec<f32>,
    ops_at_last_sample: u64,
    next_sample_at: Cycle,
}

impl System {
    /// Build a system for `ops` (single- or multi-program stream) with
    /// the policy `cfg.mapping` describes — `agent` drives AIMM;
    /// passing one with any other mapping panics (see
    /// [`AnyPolicy::new`]). Pids appearing in the stream get address
    /// spaces. Convenience wrapper over
    /// [`with_policy`](Self::with_policy).
    pub fn new(cfg: SystemConfig, ops: Vec<NmpOp>, agent: Option<AimmAgent>) -> Self {
        let policy = AnyPolicy::new(&cfg, &ops, agent);
        Self::with_policy(cfg, ops, policy)
    }

    /// Build a system around an explicit mapping policy (the carryover
    /// path: [`take_policy`](Self::take_policy) from the previous run
    /// feeds the next run's construction). Calls the policy's
    /// episode-start hook — per-run control state resets, carried
    /// learning state survives (§6.1).
    pub fn with_policy(cfg: SystemConfig, ops: Vec<NmpOp>, policy: AnyPolicy) -> Self {
        Self::with_provider(cfg, Box::new(GeneratedProvider::new(ops)), policy)
    }

    /// Build a system around any op-stream provider — the replay path
    /// (`aimm run --trace`) hands in a
    /// [`FileProvider`](crate::workloads::FileProvider) here, and the
    /// generated path arrives via [`with_policy`](Self::with_policy)
    /// wrapping its vector. Pids are taken from the provider (every
    /// implementation knows them up front).
    pub fn with_provider(
        cfg: SystemConfig,
        provider: Box<dyn TraceProvider>,
        mut policy: AnyPolicy,
    ) -> Self {
        let mut mmu = Mmu::new(&cfg);
        for pid in provider.pids() {
            mmu.create_process(*pid);
        }
        let placement: Box<dyn Placement> = if cfg.hoard {
            Box::new(HoardAllocator::new())
        } else {
            Box::new(StripePlacement::default())
        };
        let mesh = Mesh::new(&cfg);
        let cubes = (0..cfg.num_cubes()).map(|i| Cube::new(i, &cfg)).collect();
        let mcs = (0..cfg.num_mcs()).map(|i| Mc::new(i, &cfg)).collect();
        policy.start_episode();
        Self {
            migration: MigrationSystem::new(&cfg),
            remap_table: ComputeRemapTable::new(4096),
            cpu_cache: CpuCache::new(cfg.cpu_cache_lines),
            mesh,
            cubes,
            mcs,
            mmu,
            placement,
            policy,
            provider,
            issued: 0,
            completed: 0,
            tenant_feed: None,
            now: 0,
            migrated_pages: HashSet::new(),
            accesses_on_migrated: 0,
            page_accesses_total: 0,
            migrations_total: 0,
            rw_pages: HashSet::new(),
            scratch: Vec::new(),
            opc_timeline: Vec::new(),
            ops_at_last_sample: 0,
            next_sample_at: cfg.opc_sample_period,
            cfg,
        }
    }

    /// Build a serve-mode system: no upfront trace — ops arrive through
    /// `feed`'s tenants, each getting its address space at *admission*
    /// (not construction) and losing it at departure. The policy is
    /// threaded exactly like [`with_policy`](Self::with_policy), so one
    /// agent survives the whole service lifetime across rounds.
    pub fn with_tenants(cfg: SystemConfig, feed: TenantFeed, policy: AnyPolicy) -> Self {
        let mut sys = Self::with_policy(cfg, Vec::new(), policy);
        sys.tenant_feed = Some(feed);
        sys
    }

    pub fn now(&self) -> Cycle {
        self.now
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// The active mapping policy.
    pub fn policy(&self) -> &AnyPolicy {
        &self.policy
    }

    /// Reclaim the policy for the next run (episode-boundary carryover;
    /// leaves the no-op baseline behind).
    pub fn take_policy(&mut self) -> AnyPolicy {
        std::mem::replace(&mut self.policy, AnyPolicy::baseline())
    }

    /// Reclaim the agent (to carry the DNN into the next run, §6.1).
    /// Agent-less policies yield `None`.
    pub fn take_agent(&mut self) -> Option<AimmAgent> {
        self.policy.take_agent()
    }

    fn outstanding(&self) -> u64 {
        self.issued - self.completed
    }

    /// Total ops this run carries: the trace length, or in serve mode
    /// the sum of every tenant's stream — the policy's progress
    /// denominator must not read zero just because ops arrive late.
    fn total_ops(&self) -> u64 {
        match &self.tenant_feed {
            Some(f) => f.total_ops(),
            None => self.provider.total_ops(),
        }
    }

    /// Feed ops from the trace into MC queues (CPU issue). Errors are
    /// the provider's — a streamed trace file failing mid-read — and
    /// abort the run loudly; the generated path is infallible.
    fn feed(&mut self) -> anyhow::Result<()> {
        if self.tenant_feed.is_some() {
            self.feed_serve();
            return Ok(());
        }
        let mut budget = self.cfg.issue_width;
        while budget > 0 && self.outstanding() < self.cfg.max_outstanding as u64 {
            let Some(op) = self.provider.peek() else { break };
            // Cores issue through their nearest MC; with ops spread over
            // the 16 cores this is round-robin across the 4 MCs (and keeps
            // MC load independent of where data lives). `consumed()` is
            // the op's stream index — the same round-robin key as the
            // pre-seam `next_op` counter.
            let mc_id = (self.provider.consumed() % self.cfg.num_mcs() as u64) as usize;
            match self.mcs[mc_id].enqueue(op) {
                Ok(()) => {
                    self.provider.consume()?;
                    self.issued += 1;
                    budget -= 1;
                    // Track writability + migrated-page access stats.
                    self.rw_pages.insert((op.pid, op.dest_vpage()));
                    let (pages, n) = op.vpages_arr();
                    for &p in &pages[..n] {
                        self.page_accesses_total += 1;
                        if self.migrated_pages.contains(&(op.pid, p)) {
                            self.accesses_on_migrated += 1;
                        }
                    }
                }
                Err(_) => break, // backpressure: stop feeding this cycle
            }
        }
        Ok(())
    }

    /// Serve-mode CPU feed: arrivals due this cycle join the admission
    /// queue, the FIFO head is admitted while a compute slot and page
    /// budget are free (strict FIFO — no skipping, so admission order
    /// never depends on tenant size), and the issue budget round-robins
    /// across resident tenants. Everything here is driven by `self.now`
    /// and feed state alone, so both engines and any worker count
    /// replay it identically.
    fn feed_serve(&mut self) {
        let mut feed = self.tenant_feed.take().expect("serve mode");
        let now = self.now;
        feed.enqueue_arrivals(now);
        for pid in feed.admit_ready(now) {
            self.mmu.create_process(pid);
        }
        let mut budget = self.cfg.issue_width;
        let mut skipped = 0usize;
        while budget > 0
            && self.outstanding() < self.cfg.max_outstanding as u64
            && !feed.active.is_empty()
            && skipped < feed.active.len()
        {
            let slot = feed.cursor % feed.active.len();
            let ti = feed.active[slot];
            let t = &mut feed.tenants[ti];
            if t.next_op >= t.spec.ops.len() {
                // Drained (awaiting acks or departure): rotate past it.
                feed.cursor += 1;
                skipped += 1;
                continue;
            }
            let op = t.spec.ops[t.next_op];
            // Same nearest-MC round-robin as the trace feed (`issued`
            // equals `next_op` there, so the two paths agree).
            let mc_id = self.issued as usize % self.cfg.num_mcs();
            match self.mcs[mc_id].enqueue(op) {
                Ok(()) => {
                    t.next_op += 1;
                    feed.cursor += 1;
                    skipped = 0;
                    self.issued += 1;
                    budget -= 1;
                    self.rw_pages.insert((op.pid, op.dest_vpage()));
                    let (pages, n) = op.vpages_arr();
                    for &p in &pages[..n] {
                        self.page_accesses_total += 1;
                        if self.migrated_pages.contains(&(op.pid, p)) {
                            self.accesses_on_migrated += 1;
                        }
                    }
                }
                Err(_) => break, // backpressure: stop feeding this cycle
            }
        }
        self.tenant_feed = Some(feed);
    }

    fn inject_or_retain(mesh: &mut Mesh, out: &mut std::collections::VecDeque<Packet>) {
        while let Some(pk) = out.pop_front() {
            if let Err(pk) = mesh.inject(pk) {
                out.push_front(pk);
                break;
            }
        }
    }

    /// One cycle.
    pub fn tick(&mut self) -> anyhow::Result<()> {
        let now = self.now;

        // 1. CPU feed.
        self.feed()?;

        // 2. MC issue + drain their outgoing packets.
        for i in 0..self.mcs.len() {
            let mut deps = IssueDeps {
                mmu: &mut self.mmu,
                placement: self.placement.as_mut(),
                policy: &mut self.policy,
                cpu_cache: &mut self.cpu_cache,
                remap: &mut self.remap_table,
                migration: &self.migration,
                mesh: &self.mesh,
                technique: self.cfg.technique,
            };
            self.mcs[i].tick_issue(now, &mut deps)?;
            Self::inject_or_retain(&mut self.mesh, &mut self.mcs[i].out);
        }

        // 3. Migration system.
        self.migration.tick(now, &mut self.mmu);
        Self::inject_or_retain(&mut self.mesh, &mut self.migration.out);

        // 4. Fabric.
        self.mesh.tick(now);

        // 5. Deliveries → cubes and MCs (scratch swap: no allocation).
        for c in 0..self.cubes.len() {
            if self.mesh.delivered_cube[c].is_empty() {
                continue;
            }
            std::mem::swap(&mut self.scratch, &mut self.mesh.delivered_cube[c]);
            for pk in self.scratch.drain(..) {
                self.cubes[c].receive(pk, now);
            }
        }
        for m in 0..self.mcs.len() {
            let delivered = std::mem::take(&mut self.mesh.delivered_mc[m]);
            for pk in delivered {
                match pk.payload {
                    Payload::MigChunkAck { token, .. } => {
                        self.migration.receive_ack(token, now, &mut self.mmu);
                    }
                    _ => {
                        if let Some((pid, _latency)) = self.mcs[m].receive(pk, now) {
                            self.completed += 1;
                            if let Some(feed) = &mut self.tenant_feed {
                                feed.on_complete(pid, now);
                            }
                        }
                    }
                }
            }
        }

        // 6. Cubes compute/memory + drain outgoing.
        for c in 0..self.cubes.len() {
            self.cubes[c].tick(now);
            Self::inject_or_retain(&mut self.mesh, &mut self.cubes[c].out);
        }

        // 7. Completed migrations: OS bookkeeping + stats.
        let completed_migs = std::mem::take(&mut self.migration.completed);
        for cm in completed_migs {
            self.migrations_total += 1;
            self.migrated_pages.insert((cm.pid, cm.vpage));
            for mc in &mut self.mcs {
                mc.tlb.invalidate(cm.pid, cm.vpage);
                if mc.page_cache.get(&(cm.pid, cm.vpage)).is_some() {
                    mc.page_cache.on_migration((cm.pid, cm.vpage), cm.latency);
                }
            }
        }

        // 7b. Serve mode: departures. Runs after the migration drain
        // (step 7) so a commit landing this very cycle already cleared
        // `in_flight` — both engines see the departure condition flip
        // inside the same tick, never between ticks, which keeps the
        // event engine's skips legal.
        if self.tenant_feed.is_some() {
            self.tenant_maintenance();
        }

        // 8. Periodic cube → MC reports.
        if now % CUBE_REPORT_PERIOD == 0 {
            for cube in &self.cubes {
                let occ = cube.table.occupancy() as f64;
                let rhr = cube.row_hit_rate();
                let mc = self.cfg.cube_home_mc(cube.id);
                self.mcs[mc].counters.report(cube.id, occ, rhr);
            }
        }

        // 9. Mapping-policy decision step: TOM's phase machine, the
        // AIMM agent's invocation, CODA's window evaluation — whatever
        // the configured policy does, its decisions come back as
        // `MappingAction`s, applied in emission order right here.
        let actions = {
            let mut ctx = PolicyCtx {
                mcs: &mut self.mcs,
                cubes: &self.cubes,
                mmu: &mut self.mmu,
                remap_table: &mut self.remap_table,
                mesh: &self.mesh,
                completed: self.completed,
                total_ops: self.total_ops(),
            };
            self.policy.tick(now, &mut ctx)?
        };
        self.apply_actions(actions);

        // 10. OPC timeline sampling.
        if now >= self.next_sample_at {
            let delta = self.completed - self.ops_at_last_sample;
            self.opc_timeline.push(delta as f32 / self.cfg.opc_sample_period as f32);
            self.ops_at_last_sample = self.completed;
            self.next_sample_at = now + self.cfg.opc_sample_period;
        }

        self.now += 1;
        Ok(())
    }

    /// Apply the policy's decisions, in emission order. This is the
    /// single place mapping decisions become simulator state:
    ///
    /// * data migrations go through the MDMA engine, blocking iff the
    ///   page was ever written (§5.3 — derived from `rw_pages`, so the
    ///   policy never tracks writability itself);
    /// * compute remaps land in the [`ComputeRemapTable`] the MCs
    ///   consult at dispatch;
    /// * force-remaps (TOM's traffic-free epoch re-layout) update the
    ///   MMU and shoot down every MC TLB, page by page, exactly as the
    ///   pre-trait relayout loop interleaved them.
    fn apply_actions(&mut self, actions: Vec<MappingAction>) {
        let serve = self.tenant_feed.is_some();
        for action in actions {
            // Serve mode only: drop stale advice about pages that are
            // not mapped — a departed tenant's, or a profiled page its
            // tenant has not touched yet. The trace path applies every
            // action exactly as before (an unmapped target there is
            // still routed into the same rejection accounting the
            // golden fixture pins).
            if serve {
                let (pid, vpage) = match &action {
                    MappingAction::MigratePage { pid, vpage, .. } => (*pid, *vpage),
                    MappingAction::RemapCompute { pid, vpage, .. } => (*pid, *vpage),
                    MappingAction::ForceRemap { pid, vpage, .. } => (*pid, *vpage),
                };
                if !self.mmu.is_mapped(pid, vpage) {
                    continue;
                }
            }
            match action {
                MappingAction::MigratePage { pid, vpage, to_cube } => {
                    let blocking = self.rw_pages.contains(&(pid, vpage));
                    self.migration.request(MigRequest { pid, vpage, to_cube, blocking });
                }
                MappingAction::RemapCompute { pid, vpage, cube } => {
                    self.remap_table.insert(pid, vpage, cube);
                }
                MappingAction::ForceRemap { pid, vpage, to_cube } => {
                    self.mmu.force_remap(pid, vpage, to_cube);
                    for mc in &mut self.mcs {
                        mc.tlb.invalidate(pid, vpage);
                    }
                }
            }
        }
    }

    /// Serve-mode departures (tick step 7b): a tenant whose last op has
    /// completed leaves once no page of its address space has a
    /// migration queued or in flight. On departure every mapping is
    /// scrubbed from the MC TLBs, the compute-remap table and the
    /// placement before the MMU returns its frames — so a successor
    /// tenant reusing those frames can never hit a stale translation or
    /// remap entry. Gating on [`MigrationSystem::has_pid_in_flight`]
    /// makes the frame release safe: `in_flight` covers a migration's
    /// whole lifetime (request → commit/abort), so no MDMA job can
    /// touch a freed frame afterwards.
    fn tenant_maintenance(&mut self) {
        let mut feed = self.tenant_feed.take().expect("serve mode");
        let mut k = 0;
        while k < feed.active.len() {
            let ti = feed.active[k];
            let t = &feed.tenants[ti];
            let pid = t.spec.pid;
            if t.finished_at.is_some() && !self.migration.has_pid_in_flight(pid) {
                // `Mmu::mappings` walks the page table in index order —
                // deterministic scrub order at any worker count.
                for (vpage, loc) in self.mmu.mappings(pid) {
                    for mc in &mut self.mcs {
                        mc.tlb.invalidate(pid, vpage);
                    }
                    self.remap_table.remove(pid, vpage);
                    self.placement.note_free(pid, loc.cube);
                }
                self.mmu.release_process(pid);
                feed.depart(k);
            } else {
                k += 1;
            }
        }
        self.tenant_feed = Some(feed);
    }

    /// Everything drained?
    pub fn is_done(&self) -> bool {
        let source_drained = match &self.tenant_feed {
            // Serve: every tenant arrived, was admitted, and departed.
            Some(feed) => feed.all_done(),
            None => self.provider.drained(),
        };
        source_drained
            && self.outstanding() == 0
            && self.mesh.is_idle()
            && self.migration.is_idle()
            && self.cubes.iter().all(|c| c.is_idle())
            && self.mcs.iter().all(|m| m.is_idle())
    }

    /// Run to completion; returns the collected statistics.
    ///
    /// The configured [`Engine`] only chooses *how* the clock advances:
    /// both engines produce bit-identical `RunStats` (DESIGN.md §8,
    /// enforced by `rust/tests/engine_equivalence.rs`).
    pub fn run(&mut self) -> anyhow::Result<RunStats> {
        // Serve runs idle until the last arrival however sparse the
        // schedule, so the livelock guard starts counting from there.
        let horizon = self.tenant_feed.as_ref().map(|f| f.last_arrival()).unwrap_or(0);
        let max_cycles = horizon + MAX_CYCLES_FLOOR.max(self.total_ops() * MAX_CYCLES_PER_OP);
        match self.cfg.engine {
            Engine::Polled => self.drive_polled(max_cycles)?,
            Engine::Event => self.drive_event(max_cycles)?,
        }
        // Episode end: the policy closes out (AIMM files its terminal
        // transition; everything else is a no-op).
        let mut ctx = PolicyCtx {
            mcs: &mut self.mcs,
            cubes: &self.cubes,
            mmu: &mut self.mmu,
            remap_table: &mut self.remap_table,
            mesh: &self.mesh,
            completed: self.completed,
            total_ops: self.total_ops(),
        };
        self.policy.finish(&mut ctx);
        Ok(self.stats())
    }

    /// The original reference loop: tick every cycle unconditionally.
    fn drive_polled(&mut self, max_cycles: u64) -> anyhow::Result<()> {
        while !self.is_done() {
            self.tick()?;
            anyhow::ensure!(
                self.now < max_cycles,
                "simulation exceeded {max_cycles} cycles ({} / {} ops done)",
                self.completed,
                self.total_ops()
            );
        }
        Ok(())
    }

    /// Next-event loop: every component files its next interesting cycle
    /// into the [`EventWheel`]; the clock jumps straight to the earliest
    /// one, bulk-applying the skipped span's accounting (DESIGN.md §8).
    /// `tick` itself is untouched — event cycles replay the exact polled
    /// semantics, which is what keeps the two engines bit-identical.
    fn drive_event(&mut self, max_cycles: u64) -> anyhow::Result<()> {
        let mut wheel = EventWheel::new(self.now);
        while !self.is_done() {
            wheel.reset(self.now);
            self.schedule_events(&mut wheel);
            match wheel.earliest() {
                Some(at) if at < max_cycles => {
                    if at > self.now {
                        self.skip_to(at);
                    }
                }
                _ => {
                    // No component will ever act again (livelock), or the
                    // next action lies beyond the cycle guard: the polled
                    // loop would spin pure-accounting cycles up to the
                    // guard and fail — fail identically without spinning.
                    anyhow::bail!(
                        "simulation exceeded {max_cycles} cycles ({} / {} ops done)",
                        self.completed,
                        self.total_ops()
                    );
                }
            }
            self.tick()?;
            anyhow::ensure!(
                self.now < max_cycles,
                "simulation exceeded {max_cycles} cycles ({} / {} ops done)",
                self.completed,
                self.total_ops()
            );
        }
        Ok(())
    }

    /// Collect every component's next-interesting cycle. A component
    /// reports the earliest cycle at which its tick can change any state
    /// (queues, stats, RNG draws, packets); cycles in between are pure
    /// per-cycle accounting, which [`skip_to`](Self::skip_to) bulk-applies.
    /// The hooks are topology-independent: the fabric's event is keyed on
    /// buffer occupancy and the earliest in-flight wire arrival, whatever
    /// links (including torus/ring wraparounds) the packets ride — so the
    /// skip stays legal on every `SystemConfig::topology`.
    fn schedule_events(&self, wheel: &mut EventWheel) {
        let now = self.now;
        // CPU feed keeps trying while trace ops remain and the
        // outstanding window has room. (A full MC queue also blocks the
        // feed, but that same queue then issues every cycle — covered by
        // the MC's own event below.)
        match &self.tenant_feed {
            Some(feed) => {
                // Serve mode: the next arrival wakes the admission
                // queue; a fitting FIFO head admits now; resident
                // tenants with remaining ops keep the feed hot while
                // the outstanding window has room. Departures need no
                // event of their own — the condition only flips inside
                // ticks already driven by delivery/migration events,
                // and step 7b runs in that same tick.
                if let Some(at) = feed.next_arrival_at() {
                    wheel.schedule(at.max(now));
                }
                if feed.can_admit() {
                    wheel.schedule(now);
                }
                if feed.has_issuable() && self.outstanding() < self.cfg.max_outstanding as u64 {
                    wheel.schedule(now);
                }
            }
            None => {
                if !self.provider.drained()
                    && self.outstanding() < self.cfg.max_outstanding as u64
                {
                    wheel.schedule(now);
                }
            }
        }
        for mc in &self.mcs {
            if let Some(at) = mc.next_event(now, &self.migration) {
                wheel.schedule(at);
            }
        }
        if let Some(at) = self.migration.next_event(now) {
            wheel.schedule(at);
        }
        if let Some(at) = self.mesh.next_event(now) {
            wheel.schedule(at);
        }
        for cube in &self.cubes {
            if let Some(at) = cube.next_event(now) {
                wheel.schedule(at);
            }
        }
        if let Some(at) = self.policy.next_event(now, self.completed, self.total_ops()) {
            wheel.schedule(at);
        }
    }

    /// Jump the clock from `self.now` to `target`, applying the per-cycle
    /// accounting the polled loop would have performed for every cycle in
    /// `[self.now, target)`. Legal only when no component can change
    /// state in that span (which [`schedule_events`](Self::schedule_events)
    /// guarantees by construction); every counter a polled tick touches
    /// unconditionally is updated bit-identically:
    ///
    /// * queue / NMP-table occupancy integrals — integer bulk adds;
    /// * cube → MC reports at skipped multiples of [`CUBE_REPORT_PERIOD`]
    ///   — component state is frozen, but the running averages are still
    ///   fed once per report cycle (an EWMA update is not closed-form
    ///   reducible without changing the float rounding);
    /// * OPC timeline samples at skipped sample points — `completed` is
    ///   frozen, so the first skipped sample takes the pending delta and
    ///   the rest record zero, exactly as the polled loop would.
    fn skip_to(&mut self, target: Cycle) {
        debug_assert!(target > self.now);
        let span = target - self.now;
        for mc in &mut self.mcs {
            mc.observe_span(span);
        }
        self.migration.observe_span(span);
        for cube in &mut self.cubes {
            cube.observe_span(span);
        }
        let mut report_at = self.now.next_multiple_of(CUBE_REPORT_PERIOD);
        while report_at < target {
            for cube in &self.cubes {
                let occ = cube.table.occupancy() as f64;
                let rhr = cube.row_hit_rate();
                let mc = self.cfg.cube_home_mc(cube.id);
                self.mcs[mc].counters.report(cube.id, occ, rhr);
            }
            report_at += CUBE_REPORT_PERIOD;
        }
        while self.next_sample_at < target {
            let delta = self.completed - self.ops_at_last_sample;
            self.opc_timeline.push(delta as f32 / self.cfg.opc_sample_period as f32);
            self.ops_at_last_sample = self.completed;
            self.next_sample_at += self.cfg.opc_sample_period;
        }
        self.now = target;
    }

    /// Collect statistics for the run so far.
    pub fn stats(&self) -> RunStats {
        let cycles = self.now;
        let n_cubes = self.cubes.len() as f64;
        let busy: Vec<f64> = self.cubes.iter().map(|c| c.stats.compute_busy as f64).collect();
        let busy_sum: f64 = busy.iter().sum();
        let busy_sq: f64 = busy.iter().map(|b| b * b).sum();
        // Jain's fairness index as the compute-distribution measure.
        let compute_balance =
            if busy_sq > 0.0 { busy_sum * busy_sum / (n_cubes * busy_sq) } else { 0.0 };
        let compute_utilization = if cycles > 0 {
            busy_sum / (cycles as f64 * n_cubes)
        } else {
            0.0
        };
        let (acc, hits) = self.cubes.iter().fold((0u64, 0u64), |(a, h), c| {
            let ca: u64 = c.vaults.iter().map(|v| v.accesses()).sum();
            let ch: u64 = c.vaults.iter().map(|v| v.row_hits()).sum();
            (a + ca, h + ch)
        });
        // Fig 10's denominator: distinct (pid, page) pairs the run
        // touches. Serve mode has no upfront trace, so the feed
        // precomputes the sum of per-tenant footprints (pids are unique
        // and never reused, so the sum *is* the distinct count).
        let distinct_page_count = match &self.tenant_feed {
            Some(feed) => feed.distinct_pages_total(),
            None => self.provider.distinct_pages(),
        };

        let mut energy_counts = EnergyCounts::default();
        for mc in &self.mcs {
            energy_counts.page_info_accesses += mc.page_cache.touches;
        }
        for cube in &self.cubes {
            energy_counts.nmp_buffer_accesses += cube.stats.nmp_table_touches;
            energy_counts.memory_bits += cube.stats.mem_accesses * 512;
        }
        energy_counts.mig_queue_accesses = self.migration.stats.queue_touches;
        energy_counts.mdma_accesses = self.migration.stats.mdma_touches;
        energy_counts.bit_hops = self.mesh.stats.bit_hops;
        // Sum over every agent the policy carries — one for AIMM, one
        // per MC for AIMM-MC, none for the rest — so single- and
        // multi-agent runs report through the same code path (and the
        // single-agent numbers are bit-identical to the pre-pool code).
        let (mut inv, mut trains, mut cum_r) = (0u64, 0u64, 0.0f64);
        let mut loss_sum = 0.0f64;
        for a in self.policy.agents() {
            energy_counts.weight_accesses += a.stats.weight_accesses;
            energy_counts.replay_accesses += a.stats.replay_accesses;
            energy_counts.state_buf_accesses += a.stats.state_buf_accesses;
            inv += a.stats.invocations;
            trains += a.stats.train_steps;
            loss_sum += a.stats.loss_sum;
            cum_r += a.stats.cumulative_reward;
        }
        let loss = if trains == 0 { 0.0 } else { loss_sum / trains as f64 };

        RunStats {
            cycles,
            ops_completed: self.completed,
            opc_timeline: self.opc_timeline.clone(),
            avg_hops: self.mesh.stats.avg_hops(),
            avg_packet_latency: self.mesh.stats.avg_latency(),
            compute_utilization,
            compute_balance,
            fraction_pages_migrated: if distinct_page_count == 0 {
                0.0
            } else {
                self.migrated_pages.len() as f64 / distinct_page_count as f64
            },
            fraction_accesses_on_migrated: if self.page_accesses_total == 0 {
                0.0
            } else {
                self.accesses_on_migrated as f64 / self.page_accesses_total as f64
            },
            pages_migrated: self.migrated_pages.len() as u64,
            migrations: self.migrations_total,
            row_hit_rate: if acc == 0 { 0.0 } else { hits as f64 / acc as f64 },
            agent_invocations: inv,
            agent_train_steps: trains,
            agent_avg_loss: loss,
            agent_cumulative_reward: cum_r,
            energy: EnergyModel::default().breakdown(&energy_counts),
            tenants: self
                .tenant_feed
                .as_ref()
                .map(|f| f.tenant_stats())
                .unwrap_or_default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MappingScheme, Technique};
    use crate::nmp::OpKind;
    use crate::runtime::LinearQ;
    use crate::workloads::{generate, Benchmark};

    fn small_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.frames_per_cube = 4096;
        cfg
    }

    fn simple_ops(n: u64) -> Vec<NmpOp> {
        (0..n)
            .map(|i| NmpOp {
                pid: 1,
                kind: OpKind::Add,
                dest: (i % 8) << 12 | (i * 64) & 0xfff,
                src1: ((i % 8) + 16) << 12,
                src2: Some(((i % 4) + 32) << 12),
            })
            .collect()
    }

    #[test]
    fn baseline_run_completes_all_ops() {
        let mut sys = System::new(small_cfg(), simple_ops(200), None);
        let stats = sys.run().unwrap();
        assert_eq!(stats.ops_completed, 200);
        assert!(stats.cycles > 0);
        assert!(stats.opc() > 0.0);
        assert!(stats.avg_hops > 0.0);
    }

    #[test]
    fn all_techniques_complete() {
        for technique in Technique::ALL {
            let mut cfg = small_cfg();
            cfg.technique = technique;
            let mut sys = System::new(cfg, simple_ops(150), None);
            let stats = sys.run().unwrap();
            assert_eq!(stats.ops_completed, 150, "{technique}");
        }
    }

    #[test]
    fn tom_run_completes() {
        let mut cfg = small_cfg();
        cfg.mapping = MappingScheme::Tom;
        let mut sys = System::new(cfg, simple_ops(300), None);
        let stats = sys.run().unwrap();
        assert_eq!(stats.ops_completed, 300);
    }

    #[test]
    fn coda_and_oracle_runs_complete() {
        for mapping in [MappingScheme::Coda, MappingScheme::Oracle] {
            let mut cfg = small_cfg();
            cfg.mapping = mapping;
            let trace = generate(Benchmark::Spmv, 1, 0.08, 3);
            let n = trace.ops.len() as u64;
            let mut sys = System::new(cfg, trace.ops, None);
            let stats = sys.run().unwrap();
            assert_eq!(stats.ops_completed, n, "{mapping}");
            assert!(sys.take_agent().is_none(), "{mapping} carries no agent");
        }
    }

    /// CodaGreedy is live hardware, not dead code: a hot source page
    /// whose consumers all compute on one cube gets migrated there.
    #[test]
    fn coda_migrates_a_hot_source_page() {
        let mut cfg = small_cfg();
        cfg.mapping = MappingScheme::Coda;
        // Every op writes page 8 (one compute cube under BNMP) and
        // reads page 100 — page 100's counters concentrate on page 8's
        // cube, far past the hysteresis margin.
        let ops: Vec<NmpOp> = (0..6000)
            .map(|i| NmpOp {
                pid: 1,
                kind: OpKind::Add,
                dest: 8 << 12 | (i * 64) & 0xfff,
                src1: 100 << 12 | (i * 64) & 0xfff,
                src2: None,
            })
            .collect();
        let n = ops.len() as u64;
        let mut sys = System::new(cfg, ops, None);
        let stats = sys.run().unwrap();
        assert_eq!(stats.ops_completed, n);
        assert!(stats.migrations >= 1, "expected at least one CODA migration");
    }

    /// The oracle's replay is deterministic and its dry run is
    /// side-effect-free: two fresh systems over the same trace produce
    /// byte-identical stats, and profiling again changes nothing.
    #[test]
    fn oracle_replay_is_deterministic() {
        let mut cfg = small_cfg();
        cfg.mapping = MappingScheme::Oracle;
        let trace = generate(Benchmark::Km, 1, 0.08, 5);
        let a = System::new(cfg.clone(), trace.ops.clone(), None).run().unwrap();
        // A second dry run over the same stream is pure.
        let assignment = crate::mapping::policy::profile_assignment(&trace.ops, 16);
        assert_eq!(
            assignment,
            crate::mapping::policy::profile_assignment(&trace.ops, 16)
        );
        let b = System::new(cfg, trace.ops.clone(), None).run().unwrap();
        assert_identical(&a, &b, "oracle replay");
    }

    #[test]
    fn aimm_run_with_mock_agent() {
        let mut cfg = small_cfg();
        cfg.mapping = MappingScheme::Aimm;
        let agent = AimmAgent::new(
            Box::new(LinearQ::new(1e-2, 0.95, 5)),
            cfg.agent.clone(),
            11,
        );
        let trace = generate(Benchmark::Spmv, 1, 0.1, 3);
        let mut sys = System::new(cfg, trace.ops, Some(agent));
        let stats = sys.run().unwrap();
        assert!(stats.ops_completed > 0);
        assert!(stats.agent_invocations > 0, "agent must be invoked");
        // The agent survives for the next run.
        assert!(sys.take_agent().is_some());
    }

    #[test]
    fn workload_trace_completes_on_bnmp() {
        let trace = generate(Benchmark::Mac, 1, 0.1, 3);
        let n = trace.len() as u64;
        let mut sys = System::new(small_cfg(), trace.ops, None);
        let stats = sys.run().unwrap();
        assert_eq!(stats.ops_completed, n);
        assert!(stats.row_hit_rate > 0.0 && stats.row_hit_rate < 1.0);
        assert!(stats.compute_utilization > 0.0);
        assert!(stats.energy.total_nj() > 0.0);
    }

    #[test]
    fn multi_program_stream_completes() {
        use crate::workloads::interleave;
        let (ops, _) = interleave(
            vec![
                generate(Benchmark::Mac, 0, 0.05, 1),
                generate(Benchmark::Rd, 0, 0.05, 2),
            ],
            9,
        );
        let n = ops.len() as u64;
        let mut cfg = small_cfg();
        cfg.hoard = true;
        let mut sys = System::new(cfg, ops, None);
        let stats = sys.run().unwrap();
        assert_eq!(stats.ops_completed, n);
    }

    #[test]
    fn opc_timeline_sampled() {
        let mut sys = System::new(small_cfg(), simple_ops(400), None);
        let stats = sys.run().unwrap();
        assert!(!stats.opc_timeline.is_empty());
    }

    /// Bit-identity helper for the engine-equivalence tests below: the
    /// JSON digest covers every aggregate, the timeline is compared at
    /// the bit level (the broader grid lives in
    /// `rust/tests/engine_equivalence.rs`).
    fn assert_identical(p: &RunStats, e: &RunStats, ctx: &str) {
        assert_eq!(
            crate::bench::sweep::stats_json(p),
            crate::bench::sweep::stats_json(e),
            "stats diverged: {ctx}"
        );
        let pt: Vec<u32> = p.opc_timeline.iter().map(|v| v.to_bits()).collect();
        let et: Vec<u32> = e.opc_timeline.iter().map(|v| v.to_bits()).collect();
        assert_eq!(pt, et, "OPC timeline diverged: {ctx}");
    }

    fn run_both(cfg: &SystemConfig, ops: &[NmpOp]) -> (RunStats, RunStats) {
        let mut polled_cfg = cfg.clone();
        polled_cfg.engine = Engine::Polled;
        let mut event_cfg = cfg.clone();
        event_cfg.engine = Engine::Event;
        let polled = System::new(polled_cfg, ops.to_vec(), None).run().unwrap();
        let event = System::new(event_cfg, ops.to_vec(), None).run().unwrap();
        (polled, event)
    }

    #[test]
    fn event_engine_matches_polled_on_all_techniques() {
        for technique in Technique::ALL {
            let mut cfg = small_cfg();
            cfg.technique = technique;
            let (p, e) = run_both(&cfg, &simple_ops(300));
            assert_identical(&p, &e, technique.name());
        }
    }

    #[test]
    fn event_engine_matches_polled_under_tom_epochs() {
        let mut cfg = small_cfg();
        cfg.mapping = MappingScheme::Tom;
        let trace = generate(Benchmark::Spmv, 1, 0.08, 9);
        let (p, e) = run_both(&cfg, &trace.ops);
        assert_identical(&p, &e, "TOM");
    }

    #[test]
    fn event_engine_matches_polled_for_coda_and_oracle() {
        for mapping in [MappingScheme::Coda, MappingScheme::Oracle] {
            let mut cfg = small_cfg();
            cfg.mapping = mapping;
            let trace = generate(Benchmark::Spmv, 1, 0.08, 9);
            let (p, e) = run_both(&cfg, &trace.ops);
            assert_identical(&p, &e, mapping.name());
        }
    }

    #[test]
    fn event_engine_matches_polled_with_learning_agent() {
        let mut cfg = small_cfg();
        cfg.mapping = MappingScheme::Aimm;
        let trace = generate(Benchmark::Km, 1, 0.08, 4);
        let mk_agent = |cfg: &SystemConfig| {
            AimmAgent::new(Box::new(LinearQ::new(1e-2, 0.95, 5)), cfg.agent.clone(), 11)
        };
        let mut polled_cfg = cfg.clone();
        polled_cfg.engine = Engine::Polled;
        let agent = mk_agent(&polled_cfg);
        let p = System::new(polled_cfg, trace.ops.clone(), Some(agent)).run().unwrap();
        let mut event_cfg = cfg;
        event_cfg.engine = Engine::Event;
        let agent = mk_agent(&event_cfg);
        let e = System::new(event_cfg, trace.ops.clone(), Some(agent)).run().unwrap();
        assert_identical(&p, &e, "AIMM");
        assert!(p.agent_invocations > 0, "agent must actually run");
    }
}
