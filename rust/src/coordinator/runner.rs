//! Episode orchestration per the paper's protocol (§6.1): each
//! single-program episode runs 5 times, multi-program 10 times; every run
//! rebuilds the simulator from scratch but the agent's DNN (and replay
//! memory) persists — the continual-learning premise.

use crate::agent::{fresh_mc_agents, warm_start_agent, AimmAgent, DistillStats, WarmStart};
use crate::config::{MappingScheme, SystemConfig};
use crate::mapping::{AimmMultiPolicy, AnyPolicy, OracleProfile, OracleProfiler};
use crate::metrics::RunStats;
use crate::nmp::NmpOp;
use crate::runtime::best_qfunction;
use crate::workloads::{generate, interleave, Benchmark, FileTrace, TraceProvider};

use super::system::System;

/// Repeated-run counts from §6.1.
pub const SINGLE_RUNS: usize = 5;
pub const MULTI_RUNS: usize = 10;

/// Summary across an episode's repeated runs.
#[derive(Debug, Clone)]
pub struct EpisodeSummary {
    pub name: String,
    pub runs: Vec<RunStats>,
}

impl EpisodeSummary {
    /// The steady-state run (last one — after learning converges).
    pub fn last(&self) -> &RunStats {
        self.runs.last().expect("at least one run")
    }

    /// First run (cold agent).
    pub fn first(&self) -> &RunStats {
        self.runs.first().expect("at least one run")
    }

    /// Mean cycles across the runs; 0.0 for an empty summary. Serve-mode
    /// tenants can complete zero episodes under aggressive admission
    /// limits, and `0/0` here used to poison downstream aggregates with
    /// NaN (which the JSON writer then silently turned into `null`).
    pub fn mean_cycles(&self) -> f64 {
        if self.runs.is_empty() {
            return 0.0;
        }
        self.runs.iter().map(|r| r.cycles as f64).sum::<f64>() / self.runs.len() as f64
    }

    /// Mean OPC across the runs; 0.0 for an empty summary (see
    /// [`EpisodeSummary::mean_cycles`]).
    pub fn mean_opc(&self) -> f64 {
        if self.runs.is_empty() {
            return 0.0;
        }
        self.runs.iter().map(|r| r.opc()).sum::<f64>() / self.runs.len() as f64
    }
}

/// A cold agent for `cfg` — the §6.1 episode start. Public so the
/// curriculum driver and the CLI's checkpoint plumbing build agents
/// through the exact same path the plain episode runner uses.
pub fn fresh_agent(cfg: &SystemConfig) -> anyhow::Result<AimmAgent> {
    AimmAgent::try_new(
        best_qfunction(cfg.agent.lr, cfg.agent.gamma, cfg.seed, cfg.agent.batch_size),
        cfg.agent.clone(),
        cfg.seed ^ 0xA6E7,
    )
}

/// The agent an episode starts with under `cfg`: a cold one for AIMM,
/// none for the agent-less policies.
fn default_agent(cfg: &SystemConfig) -> anyhow::Result<Option<AimmAgent>> {
    if cfg.mapping.uses_agent() {
        Ok(Some(fresh_agent(cfg)?))
    } else {
        Ok(None)
    }
}

/// Run one op stream `runs` times, threading the mapping policy through
/// every run via the episode-boundary carryover seam
/// (`System::with_policy` / `System::take_policy`): per-run control
/// state resets at each construction, carried learning state — AIMM's
/// network and replay, the continual-learning premise — survives. The
/// agent (if the policy holds one) is handed back afterwards so callers
/// can carry it into the *next* episode (curriculum stages, checkpoint
/// files). Pass `None` to run agent-less schemes.
pub fn run_stream_with(
    cfg: &SystemConfig,
    ops: &[NmpOp],
    runs: usize,
    name: &str,
    agent: Option<AimmAgent>,
) -> anyhow::Result<(EpisodeSummary, Option<AimmAgent>)> {
    let policy = AnyPolicy::new(cfg, ops, agent);
    let (summary, mut policy) = run_stream_policy(cfg, ops, runs, name, policy)?;
    Ok((summary, policy.take_agent()))
}

/// The policy-carrying core of [`run_stream_with`]: thread an existing
/// policy through `runs` constructions of the system and hand the whole
/// policy back. The single-agent paths wrap this and extract the agent;
/// AIMM-MC callers (curriculum stages, the checkpoint CLI) must use this
/// directly — the per-MC pool lives *inside* the policy object and
/// `take_agent` deliberately leaves it intact.
pub fn run_stream_policy(
    cfg: &SystemConfig,
    ops: &[NmpOp],
    runs: usize,
    name: &str,
    mut policy: AnyPolicy,
) -> anyhow::Result<(EpisodeSummary, AnyPolicy)> {
    let mut stats = Vec::with_capacity(runs);
    for _ in 0..runs {
        let mut sys = System::with_policy(cfg.clone(), ops.to_vec(), policy);
        stats.push(sys.run()?);
        policy = sys.take_policy();
    }
    Ok((EpisodeSummary { name: name.to_string(), runs: stats }, policy))
}

/// Build the policy an episode starts from under `warm_start` — the one
/// constructor behind `--warm-start` on every mode (run, curriculum,
/// serve). `WarmStart::None` is exactly [`AnyPolicy::new`] over
/// [`fresh_agent`]; `WarmStart::Oracle` first distills the oracle's dry
/// pass over `ops` into each learning agent
/// ([`crate::agent::warm_start_agent`]) — one agent for AIMM, the whole
/// per-MC pool for AIMM-MC (same labeled dataset, per-agent Q-inits keep
/// the pool diverse). Requesting a warm start for a policy that carries
/// no learnable state is refused loudly, as is a Q-backend that declares
/// no fixed training batch.
pub fn warm_started_policy(
    cfg: &SystemConfig,
    ops: &[NmpOp],
    warm_start: WarmStart,
) -> anyhow::Result<(AnyPolicy, Vec<DistillStats>)> {
    if warm_start == WarmStart::None {
        return Ok((AnyPolicy::new(cfg, ops, default_agent(cfg)?), Vec::new()));
    }
    match cfg.mapping {
        MappingScheme::Aimm => {
            let mut agent = fresh_agent(cfg)?;
            let stats = warm_start_agent(&mut agent, cfg, ops)?;
            Ok((AnyPolicy::new(cfg, ops, Some(agent)), vec![stats]))
        }
        MappingScheme::AimmMc => {
            let mut agents = fresh_mc_agents(cfg)?;
            let mut stats = Vec::with_capacity(agents.len());
            for agent in &mut agents {
                stats.push(warm_start_agent(agent, cfg, ops)?);
            }
            let policy = AnyPolicy::AimmMc(Box::new(AimmMultiPolicy::with_agents(cfg, agents)));
            Ok((policy, stats))
        }
        other => anyhow::bail!(
            "--warm-start {} needs a learning policy to pre-train, but the mapping is {} \
             (use AIMM or AIMM-MC)",
            warm_start.name(),
            other
        ),
    }
}

/// Replay a captured trace file `runs` times — the `--trace` episode
/// path. The streaming counterpart of [`run_stream_with`]: every run
/// re-opens the file through a fresh bounded-lookahead
/// [`FileProvider`](crate::workloads::FileProvider), so the op vector
/// is never materialized. The oracle's dry run streams the file once
/// through [`OracleProfiler`] up front (where [`AnyPolicy::new`] would
/// have read the vector); every other policy ignores the op stream at
/// construction.
pub fn run_traced_with(
    cfg: &SystemConfig,
    file: &FileTrace,
    runs: usize,
    agent: Option<AimmAgent>,
) -> anyhow::Result<(EpisodeSummary, Option<AimmAgent>)> {
    anyhow::ensure!(
        agent.is_none() || cfg.mapping.uses_agent(),
        "an agent only drives the AIMM policy (mapping is {})",
        cfg.mapping
    );
    let initial =
        (cfg.mapping != MappingScheme::Oracle).then(|| AnyPolicy::new(cfg, &[], agent));
    let (summary, mut policy) = run_traced_policy(cfg, file, runs, initial)?;
    Ok((summary, policy.take_agent()))
}

/// The policy-carrying core of [`run_traced_with`]: replay the trace
/// `runs` times through an existing policy, or — when `initial` is
/// `None` — through the default policy for `cfg` (for the oracle, that
/// is the up-front streaming profile pass; for AIMM/AIMM-MC, cold
/// agents). The checkpoint CLI resumes AIMM-MC replays through this
/// seam: the restored per-MC pool lives inside the policy object and
/// comes back intact for the next save.
pub fn run_traced_policy(
    cfg: &SystemConfig,
    file: &FileTrace,
    runs: usize,
    initial: Option<AnyPolicy>,
) -> anyhow::Result<(EpisodeSummary, AnyPolicy)> {
    let mut policy = match initial {
        Some(p) => p,
        None if cfg.mapping == MappingScheme::Oracle => {
            let mut profiler = OracleProfiler::new(cfg.num_cubes());
            let mut provider = file.provider()?;
            while let Some(op) = provider.peek() {
                profiler.observe(&op);
                provider.consume()?;
            }
            AnyPolicy::Oracle(OracleProfile::from_assignment(profiler.finish()))
        }
        None => AnyPolicy::new(cfg, &[], default_agent(cfg)?),
    };
    let mut stats = Vec::with_capacity(runs);
    for _ in 0..runs {
        let provider = Box::new(file.provider()?);
        let mut sys = System::with_provider(cfg.clone(), provider, policy);
        stats.push(sys.run()?);
        policy = sys.take_policy();
    }
    Ok((EpisodeSummary { name: file.name().to_string(), runs: stats }, policy))
}

/// Run one op stream `runs` times with the configured mapping scheme,
/// carrying the agent across runs when AIMM is active.
pub fn run_stream(
    cfg: &SystemConfig,
    ops: &[NmpOp],
    runs: usize,
    name: &str,
) -> anyhow::Result<EpisodeSummary> {
    let agent = default_agent(cfg)?;
    Ok(run_stream_with(cfg, ops, runs, name, agent)?.0)
}

/// Build the op stream for a benchmark combination: one entry is the
/// §6.1 single-program trace, several are interleaved multi-program
/// (§7.5.2). The (combo, `cfg.seed`) pair fully determines the stream —
/// `run_single`, `run_multi`, `run_cell` and the curriculum driver all
/// come through here, so a stage's trace is identical wherever it runs
/// (which is what makes cold-vs-warm comparisons meaningful).
pub fn episode_ops(
    cfg: &SystemConfig,
    benches: &[Benchmark],
    scale: f64,
) -> anyhow::Result<(Vec<NmpOp>, String)> {
    anyhow::ensure!(!benches.is_empty(), "episode needs at least one benchmark");
    if benches.len() == 1 {
        let trace = generate(benches[0], 1, scale, cfg.seed);
        Ok((trace.ops, benches[0].name().to_string()))
    } else {
        let traces = benches
            .iter()
            .enumerate()
            .map(|(i, &b)| generate(b, i as u32 + 1, scale, cfg.seed + i as u64))
            .collect();
        let (ops, _) = interleave(traces, cfg.seed ^ 0x3117);
        let name = benches.iter().map(|b| b.name()).collect::<Vec<_>>().join("-");
        Ok((ops, name))
    }
}

/// [`run_stream_with`] over a benchmark combination's episode stream:
/// the seam the checkpoint-carrying CLI paths and the curriculum driver
/// share with the plain runners.
pub fn run_episode_with(
    cfg: &SystemConfig,
    benches: &[Benchmark],
    scale: f64,
    runs: usize,
    agent: Option<AimmAgent>,
) -> anyhow::Result<(EpisodeSummary, Option<AimmAgent>)> {
    let (ops, name) = episode_ops(cfg, benches, scale)?;
    run_stream_with(cfg, &ops, runs, &name, agent)
}

/// Single-program episode (§6.1: 5 runs, scale = paper's "medium").
pub fn run_single(
    cfg: &SystemConfig,
    bench: Benchmark,
    scale: f64,
    runs: usize,
) -> anyhow::Result<EpisodeSummary> {
    Ok(run_episode_with(cfg, &[bench], scale, runs, default_agent(cfg)?)?.0)
}

/// One sweep-grid cell: a single benchmark runs the §6.1 single-program
/// protocol, a combination the multi-program one. The outcome is fully
/// determined by (`cfg`, `benches`, `scale`, `runs`) — the parallel
/// sweep harness ([`crate::bench::sweep`]) relies on this to produce
/// identical stats for a cell regardless of which worker thread runs it,
/// and the resumable batch layer ([`crate::bench::sweep::journal`])
/// extends the same contract across processes: a cell cached in a sweep
/// journal under its [`crate::bench::sweep::cell_key`] stands in,
/// byte-for-byte, for re-running this function.
pub fn run_cell(
    cfg: &SystemConfig,
    benches: &[Benchmark],
    scale: f64,
    runs: usize,
) -> anyhow::Result<EpisodeSummary> {
    anyhow::ensure!(!benches.is_empty(), "sweep cell needs at least one benchmark");
    Ok(run_episode_with(cfg, benches, scale, runs, default_agent(cfg)?)?.0)
}

/// Multi-program episode (§7.5.2).
pub fn run_multi(
    cfg: &SystemConfig,
    benches: &[Benchmark],
    scale: f64,
    runs: usize,
) -> anyhow::Result<EpisodeSummary> {
    anyhow::ensure!(benches.len() >= 2, "multi-program episode needs at least two benchmarks");
    Ok(run_episode_with(cfg, benches, scale, runs, default_agent(cfg)?)?.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MappingScheme, Technique};
    use crate::mapping::MappingPolicy;

    fn cfg(mapping: MappingScheme) -> SystemConfig {
        let mut c = SystemConfig::default();
        c.mapping = mapping;
        c.technique = Technique::Bnmp;
        c
    }

    #[test]
    fn empty_summary_means_are_zero_not_nan() {
        let s = EpisodeSummary { name: "empty".to_string(), runs: Vec::new() };
        assert_eq!(s.mean_cycles(), 0.0);
        assert_eq!(s.mean_opc(), 0.0);
        assert!(!s.mean_cycles().is_nan());
        assert!(!s.mean_opc().is_nan());
    }

    #[test]
    fn single_episode_runs_repeatedly() {
        let s = run_single(&cfg(MappingScheme::Baseline), Benchmark::Mac, 0.05, 2).unwrap();
        assert_eq!(s.runs.len(), 2);
        assert_eq!(s.name, "MAC");
        // Deterministic baseline: identical runs.
        assert_eq!(s.runs[0].cycles, s.runs[1].cycles);
    }

    #[test]
    fn aimm_agent_persists_across_runs() {
        let s = run_single(&cfg(MappingScheme::Aimm), Benchmark::Spmv, 0.05, 2).unwrap();
        assert_eq!(s.runs.len(), 2);
        // Agent invocations happen in both runs.
        assert!(s.runs[0].agent_invocations > 0);
        assert!(s.runs[1].agent_invocations > 0);
    }

    #[test]
    fn run_cell_dispatches_single_and_multi() {
        let c = cfg(MappingScheme::Baseline);
        let s = run_cell(&c, &[Benchmark::Mac], 0.03, 1).unwrap();
        assert_eq!(s.name, "MAC");
        let m = run_cell(&c, &[Benchmark::Mac, Benchmark::Rd], 0.03, 1).unwrap();
        assert_eq!(m.name, "MAC-RD");
        assert!(run_cell(&c, &[], 0.03, 1).is_err());
    }

    #[test]
    fn engines_agree_across_the_episode_protocol() {
        use crate::config::Engine;
        // Run-to-run agent carry-over must not perturb equivalence: the
        // same DNN/replay state feeds run N+1 under either engine.
        for mapping in MappingScheme::ALL {
            let mut polled_cfg = cfg(mapping);
            polled_cfg.engine = Engine::Polled;
            let mut event_cfg = cfg(mapping);
            event_cfg.engine = Engine::Event;
            let p = run_single(&polled_cfg, Benchmark::Spmv, 0.04, 2).unwrap();
            let e = run_single(&event_cfg, Benchmark::Spmv, 0.04, 2).unwrap();
            assert_eq!(p.runs.len(), e.runs.len());
            for (i, (rp, re)) in p.runs.iter().zip(&e.runs).enumerate() {
                assert_eq!(rp.cycles, re.cycles, "{mapping} run {i}");
                assert_eq!(rp.ops_completed, re.ops_completed, "{mapping} run {i}");
                assert_eq!(rp.migrations, re.migrations, "{mapping} run {i}");
                assert_eq!(rp.agent_invocations, re.agent_invocations, "{mapping} run {i}");
                assert_eq!(
                    rp.avg_hops.to_bits(),
                    re.avg_hops.to_bits(),
                    "{mapping} run {i}"
                );
            }
        }
    }

    #[test]
    fn run_episode_with_returns_the_carried_agent() {
        let c = cfg(MappingScheme::Aimm);
        let agent = Some(fresh_agent(&c).unwrap());
        let (s, carried) = run_episode_with(&c, &[Benchmark::Mac], 0.04, 2, agent).unwrap();
        assert_eq!(s.runs.len(), 2);
        let carried = carried.expect("agent survives the episode");
        assert!(carried.stats.invocations > 0);
        // Baseline episodes thread no agent.
        let c = cfg(MappingScheme::Baseline);
        let (_, none) = run_episode_with(&c, &[Benchmark::Mac], 0.04, 1, None).unwrap();
        assert!(none.is_none());
    }

    #[test]
    fn episode_ops_is_stable_and_matches_the_runners() {
        let c = cfg(MappingScheme::Baseline);
        let (a, name_a) = episode_ops(&c, &[Benchmark::Mac, Benchmark::Rd], 0.03).unwrap();
        let (b, name_b) = episode_ops(&c, &[Benchmark::Mac, Benchmark::Rd], 0.03).unwrap();
        assert_eq!(name_a, "MAC-RD");
        assert_eq!(name_a, name_b);
        assert_eq!(a.len(), b.len());
        assert!(episode_ops(&c, &[], 0.03).is_err());
        // run_multi now rejects a single-benchmark "multi" episode
        // (previously it silently built a different stream than
        // run_single for the same benchmark).
        assert!(run_multi(&c, &[Benchmark::Mac], 0.03, 1).is_err());
    }

    #[test]
    fn aimm_mc_pool_persists_across_runs_via_the_policy_seam() {
        let c = cfg(MappingScheme::AimmMc);
        let (ops, name) = episode_ops(&c, &[Benchmark::Spmv], 0.05).unwrap();
        let policy = AnyPolicy::new(&c, &ops, None);
        let (s, policy) = run_stream_policy(&c, &ops, 2, &name, policy).unwrap();
        assert_eq!(s.runs.len(), 2);
        assert!(s.runs[0].agent_invocations > 0);
        assert!(s.runs[1].agent_invocations > 0);
        // The pool came back intact, with cumulative experience: the
        // stats keep counting across runs (continual learning), so the
        // pool total equals what the last run reported.
        let pool = policy.agents();
        assert_eq!(pool.len(), c.num_mcs());
        assert!(s.runs[1].agent_invocations >= s.runs[0].agent_invocations);
        let total: u64 = pool.iter().map(|a| a.stats.invocations).sum();
        assert_eq!(total, s.runs[1].agent_invocations);
    }

    #[test]
    fn warm_started_policy_covers_every_learning_shape() {
        let c = cfg(MappingScheme::Aimm);
        let (ops, _) = episode_ops(&c, &[Benchmark::Mac], 0.04).unwrap();
        // None = the plain constructor, no distillation.
        let (_, stats) = warm_started_policy(&c, &ops, WarmStart::None).unwrap();
        assert!(stats.is_empty());
        // AIMM distills one agent.
        let (p, stats) = warm_started_policy(&c, &ops, WarmStart::Oracle).unwrap();
        assert_eq!(p.scheme(), MappingScheme::Aimm);
        assert_eq!(stats.len(), 1);
        assert!(stats[0].examples > 0);
        // AIMM-MC distills the whole pool.
        let mc = cfg(MappingScheme::AimmMc);
        let (p, stats) = warm_started_policy(&mc, &ops, WarmStart::Oracle).unwrap();
        assert_eq!(p.scheme(), MappingScheme::AimmMc);
        assert_eq!(stats.len(), mc.num_mcs());
        // Stateless policies refuse by name.
        let b = cfg(MappingScheme::Baseline);
        let err = warm_started_policy(&b, &ops, WarmStart::Oracle).unwrap_err().to_string();
        assert!(err.contains("B"), "{err}");
        assert!(err.contains("oracle"), "{err}");
    }

    #[test]
    fn multi_episode_composes() {
        let s = run_multi(
            &cfg(MappingScheme::Baseline),
            &[Benchmark::Mac, Benchmark::Rd],
            0.05,
            1,
        )
        .unwrap();
        assert_eq!(s.name, "MAC-RD");
        assert!(s.last().ops_completed > 0);
    }
}
