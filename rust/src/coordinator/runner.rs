//! Episode orchestration per the paper's protocol (§6.1): each
//! single-program episode runs 5 times, multi-program 10 times; every run
//! rebuilds the simulator from scratch but the agent's DNN (and replay
//! memory) persists — the continual-learning premise.

use crate::agent::AimmAgent;
use crate::config::{MappingScheme, SystemConfig};
use crate::mapping::{AnyPolicy, OracleProfile, OracleProfiler};
use crate::metrics::RunStats;
use crate::nmp::NmpOp;
use crate::runtime::best_qfunction;
use crate::workloads::{generate, interleave, Benchmark, FileTrace, TraceProvider};

use super::system::System;

/// Repeated-run counts from §6.1.
pub const SINGLE_RUNS: usize = 5;
pub const MULTI_RUNS: usize = 10;

/// Summary across an episode's repeated runs.
#[derive(Debug, Clone)]
pub struct EpisodeSummary {
    pub name: String,
    pub runs: Vec<RunStats>,
}

impl EpisodeSummary {
    /// The steady-state run (last one — after learning converges).
    pub fn last(&self) -> &RunStats {
        self.runs.last().expect("at least one run")
    }

    /// First run (cold agent).
    pub fn first(&self) -> &RunStats {
        self.runs.first().expect("at least one run")
    }

    /// Mean cycles across the runs; 0.0 for an empty summary. Serve-mode
    /// tenants can complete zero episodes under aggressive admission
    /// limits, and `0/0` here used to poison downstream aggregates with
    /// NaN (which the JSON writer then silently turned into `null`).
    pub fn mean_cycles(&self) -> f64 {
        if self.runs.is_empty() {
            return 0.0;
        }
        self.runs.iter().map(|r| r.cycles as f64).sum::<f64>() / self.runs.len() as f64
    }

    /// Mean OPC across the runs; 0.0 for an empty summary (see
    /// [`EpisodeSummary::mean_cycles`]).
    pub fn mean_opc(&self) -> f64 {
        if self.runs.is_empty() {
            return 0.0;
        }
        self.runs.iter().map(|r| r.opc()).sum::<f64>() / self.runs.len() as f64
    }
}

/// A cold agent for `cfg` — the §6.1 episode start. Public so the
/// curriculum driver and the CLI's checkpoint plumbing build agents
/// through the exact same path the plain episode runner uses.
pub fn fresh_agent(cfg: &SystemConfig) -> anyhow::Result<AimmAgent> {
    AimmAgent::try_new(
        best_qfunction(cfg.agent.lr, cfg.agent.gamma, cfg.seed),
        cfg.agent.clone(),
        cfg.seed ^ 0xA6E7,
    )
}

/// The agent an episode starts with under `cfg`: a cold one for AIMM,
/// none for the agent-less policies.
fn default_agent(cfg: &SystemConfig) -> anyhow::Result<Option<AimmAgent>> {
    if cfg.mapping.uses_agent() {
        Ok(Some(fresh_agent(cfg)?))
    } else {
        Ok(None)
    }
}

/// Run one op stream `runs` times, threading the mapping policy through
/// every run via the episode-boundary carryover seam
/// (`System::with_policy` / `System::take_policy`): per-run control
/// state resets at each construction, carried learning state — AIMM's
/// network and replay, the continual-learning premise — survives. The
/// agent (if the policy holds one) is handed back afterwards so callers
/// can carry it into the *next* episode (curriculum stages, checkpoint
/// files). Pass `None` to run agent-less schemes.
pub fn run_stream_with(
    cfg: &SystemConfig,
    ops: &[NmpOp],
    runs: usize,
    name: &str,
    agent: Option<AimmAgent>,
) -> anyhow::Result<(EpisodeSummary, Option<AimmAgent>)> {
    let mut policy = AnyPolicy::new(cfg, ops, agent);
    let mut stats = Vec::with_capacity(runs);
    for _ in 0..runs {
        let mut sys = System::with_policy(cfg.clone(), ops.to_vec(), policy);
        stats.push(sys.run()?);
        policy = sys.take_policy();
    }
    Ok((EpisodeSummary { name: name.to_string(), runs: stats }, policy.take_agent()))
}

/// Replay a captured trace file `runs` times — the `--trace` episode
/// path. The streaming counterpart of [`run_stream_with`]: every run
/// re-opens the file through a fresh bounded-lookahead
/// [`FileProvider`](crate::workloads::FileProvider), so the op vector
/// is never materialized. The oracle's dry run streams the file once
/// through [`OracleProfiler`] up front (where [`AnyPolicy::new`] would
/// have read the vector); every other policy ignores the op stream at
/// construction.
pub fn run_traced_with(
    cfg: &SystemConfig,
    file: &FileTrace,
    runs: usize,
    agent: Option<AimmAgent>,
) -> anyhow::Result<(EpisodeSummary, Option<AimmAgent>)> {
    anyhow::ensure!(
        agent.is_none() || cfg.mapping.uses_agent(),
        "an agent only drives the AIMM policy (mapping is {})",
        cfg.mapping
    );
    let mut policy = if cfg.mapping == MappingScheme::Oracle {
        let mut profiler = OracleProfiler::new(cfg.num_cubes());
        let mut provider = file.provider()?;
        while let Some(op) = provider.peek() {
            profiler.observe(&op);
            provider.consume()?;
        }
        AnyPolicy::Oracle(OracleProfile::from_assignment(profiler.finish()))
    } else {
        AnyPolicy::new(cfg, &[], agent)
    };
    let mut stats = Vec::with_capacity(runs);
    for _ in 0..runs {
        let provider = Box::new(file.provider()?);
        let mut sys = System::with_provider(cfg.clone(), provider, policy);
        stats.push(sys.run()?);
        policy = sys.take_policy();
    }
    Ok((
        EpisodeSummary { name: file.name().to_string(), runs: stats },
        policy.take_agent(),
    ))
}

/// Run one op stream `runs` times with the configured mapping scheme,
/// carrying the agent across runs when AIMM is active.
pub fn run_stream(
    cfg: &SystemConfig,
    ops: &[NmpOp],
    runs: usize,
    name: &str,
) -> anyhow::Result<EpisodeSummary> {
    let agent = default_agent(cfg)?;
    Ok(run_stream_with(cfg, ops, runs, name, agent)?.0)
}

/// Build the op stream for a benchmark combination: one entry is the
/// §6.1 single-program trace, several are interleaved multi-program
/// (§7.5.2). The (combo, `cfg.seed`) pair fully determines the stream —
/// `run_single`, `run_multi`, `run_cell` and the curriculum driver all
/// come through here, so a stage's trace is identical wherever it runs
/// (which is what makes cold-vs-warm comparisons meaningful).
pub fn episode_ops(
    cfg: &SystemConfig,
    benches: &[Benchmark],
    scale: f64,
) -> anyhow::Result<(Vec<NmpOp>, String)> {
    anyhow::ensure!(!benches.is_empty(), "episode needs at least one benchmark");
    if benches.len() == 1 {
        let trace = generate(benches[0], 1, scale, cfg.seed);
        Ok((trace.ops, benches[0].name().to_string()))
    } else {
        let traces = benches
            .iter()
            .enumerate()
            .map(|(i, &b)| generate(b, i as u32 + 1, scale, cfg.seed + i as u64))
            .collect();
        let (ops, _) = interleave(traces, cfg.seed ^ 0x3117);
        let name = benches.iter().map(|b| b.name()).collect::<Vec<_>>().join("-");
        Ok((ops, name))
    }
}

/// [`run_stream_with`] over a benchmark combination's episode stream:
/// the seam the checkpoint-carrying CLI paths and the curriculum driver
/// share with the plain runners.
pub fn run_episode_with(
    cfg: &SystemConfig,
    benches: &[Benchmark],
    scale: f64,
    runs: usize,
    agent: Option<AimmAgent>,
) -> anyhow::Result<(EpisodeSummary, Option<AimmAgent>)> {
    let (ops, name) = episode_ops(cfg, benches, scale)?;
    run_stream_with(cfg, &ops, runs, &name, agent)
}

/// Single-program episode (§6.1: 5 runs, scale = paper's "medium").
pub fn run_single(
    cfg: &SystemConfig,
    bench: Benchmark,
    scale: f64,
    runs: usize,
) -> anyhow::Result<EpisodeSummary> {
    Ok(run_episode_with(cfg, &[bench], scale, runs, default_agent(cfg)?)?.0)
}

/// One sweep-grid cell: a single benchmark runs the §6.1 single-program
/// protocol, a combination the multi-program one. The outcome is fully
/// determined by (`cfg`, `benches`, `scale`, `runs`) — the parallel
/// sweep harness ([`crate::bench::sweep`]) relies on this to produce
/// identical stats for a cell regardless of which worker thread runs it,
/// and the resumable batch layer ([`crate::bench::sweep::journal`])
/// extends the same contract across processes: a cell cached in a sweep
/// journal under its [`crate::bench::sweep::cell_key`] stands in,
/// byte-for-byte, for re-running this function.
pub fn run_cell(
    cfg: &SystemConfig,
    benches: &[Benchmark],
    scale: f64,
    runs: usize,
) -> anyhow::Result<EpisodeSummary> {
    anyhow::ensure!(!benches.is_empty(), "sweep cell needs at least one benchmark");
    Ok(run_episode_with(cfg, benches, scale, runs, default_agent(cfg)?)?.0)
}

/// Multi-program episode (§7.5.2).
pub fn run_multi(
    cfg: &SystemConfig,
    benches: &[Benchmark],
    scale: f64,
    runs: usize,
) -> anyhow::Result<EpisodeSummary> {
    anyhow::ensure!(benches.len() >= 2, "multi-program episode needs at least two benchmarks");
    Ok(run_episode_with(cfg, benches, scale, runs, default_agent(cfg)?)?.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MappingScheme, Technique};

    fn cfg(mapping: MappingScheme) -> SystemConfig {
        let mut c = SystemConfig::default();
        c.mapping = mapping;
        c.technique = Technique::Bnmp;
        c
    }

    #[test]
    fn empty_summary_means_are_zero_not_nan() {
        let s = EpisodeSummary { name: "empty".to_string(), runs: Vec::new() };
        assert_eq!(s.mean_cycles(), 0.0);
        assert_eq!(s.mean_opc(), 0.0);
        assert!(!s.mean_cycles().is_nan());
        assert!(!s.mean_opc().is_nan());
    }

    #[test]
    fn single_episode_runs_repeatedly() {
        let s = run_single(&cfg(MappingScheme::Baseline), Benchmark::Mac, 0.05, 2).unwrap();
        assert_eq!(s.runs.len(), 2);
        assert_eq!(s.name, "MAC");
        // Deterministic baseline: identical runs.
        assert_eq!(s.runs[0].cycles, s.runs[1].cycles);
    }

    #[test]
    fn aimm_agent_persists_across_runs() {
        let s = run_single(&cfg(MappingScheme::Aimm), Benchmark::Spmv, 0.05, 2).unwrap();
        assert_eq!(s.runs.len(), 2);
        // Agent invocations happen in both runs.
        assert!(s.runs[0].agent_invocations > 0);
        assert!(s.runs[1].agent_invocations > 0);
    }

    #[test]
    fn run_cell_dispatches_single_and_multi() {
        let c = cfg(MappingScheme::Baseline);
        let s = run_cell(&c, &[Benchmark::Mac], 0.03, 1).unwrap();
        assert_eq!(s.name, "MAC");
        let m = run_cell(&c, &[Benchmark::Mac, Benchmark::Rd], 0.03, 1).unwrap();
        assert_eq!(m.name, "MAC-RD");
        assert!(run_cell(&c, &[], 0.03, 1).is_err());
    }

    #[test]
    fn engines_agree_across_the_episode_protocol() {
        use crate::config::Engine;
        // Run-to-run agent carry-over must not perturb equivalence: the
        // same DNN/replay state feeds run N+1 under either engine.
        for mapping in MappingScheme::ALL {
            let mut polled_cfg = cfg(mapping);
            polled_cfg.engine = Engine::Polled;
            let mut event_cfg = cfg(mapping);
            event_cfg.engine = Engine::Event;
            let p = run_single(&polled_cfg, Benchmark::Spmv, 0.04, 2).unwrap();
            let e = run_single(&event_cfg, Benchmark::Spmv, 0.04, 2).unwrap();
            assert_eq!(p.runs.len(), e.runs.len());
            for (i, (rp, re)) in p.runs.iter().zip(&e.runs).enumerate() {
                assert_eq!(rp.cycles, re.cycles, "{mapping} run {i}");
                assert_eq!(rp.ops_completed, re.ops_completed, "{mapping} run {i}");
                assert_eq!(rp.migrations, re.migrations, "{mapping} run {i}");
                assert_eq!(rp.agent_invocations, re.agent_invocations, "{mapping} run {i}");
                assert_eq!(
                    rp.avg_hops.to_bits(),
                    re.avg_hops.to_bits(),
                    "{mapping} run {i}"
                );
            }
        }
    }

    #[test]
    fn run_episode_with_returns_the_carried_agent() {
        let c = cfg(MappingScheme::Aimm);
        let agent = Some(fresh_agent(&c).unwrap());
        let (s, carried) = run_episode_with(&c, &[Benchmark::Mac], 0.04, 2, agent).unwrap();
        assert_eq!(s.runs.len(), 2);
        let carried = carried.expect("agent survives the episode");
        assert!(carried.stats.invocations > 0);
        // Baseline episodes thread no agent.
        let c = cfg(MappingScheme::Baseline);
        let (_, none) = run_episode_with(&c, &[Benchmark::Mac], 0.04, 1, None).unwrap();
        assert!(none.is_none());
    }

    #[test]
    fn episode_ops_is_stable_and_matches_the_runners() {
        let c = cfg(MappingScheme::Baseline);
        let (a, name_a) = episode_ops(&c, &[Benchmark::Mac, Benchmark::Rd], 0.03).unwrap();
        let (b, name_b) = episode_ops(&c, &[Benchmark::Mac, Benchmark::Rd], 0.03).unwrap();
        assert_eq!(name_a, "MAC-RD");
        assert_eq!(name_a, name_b);
        assert_eq!(a.len(), b.len());
        assert!(episode_ops(&c, &[], 0.03).is_err());
        // run_multi now rejects a single-benchmark "multi" episode
        // (previously it silently built a different stream than
        // run_single for the same benchmark).
        assert!(run_multi(&c, &[Benchmark::Mac], 0.03, 1).is_err());
    }

    #[test]
    fn multi_episode_composes() {
        let s = run_multi(
            &cfg(MappingScheme::Baseline),
            &[Benchmark::Mac, Benchmark::Rd],
            0.05,
            1,
        )
        .unwrap();
        assert_eq!(s.name, "MAC-RD");
        assert!(s.last().ops_completed > 0);
    }
}
