//! Cross-program curriculum driver — the continual-learning experiment
//! the paper headlines (§6.1, §7.4 / Fig 10's pretrained-AIMM results):
//! run an ordered sequence of episodes (single- or multi-program) while
//! **one agent persists end-to-end**, and measure what the inherited
//! model is worth by re-running every stage cold (fresh agent) as the
//! baseline.
//!
//! The interesting number per stage is the *first-run* OPC: later runs
//! converge with or without transfer, but the first run of a stage is
//! where a warm-started network either pays off or interferes. The
//! driver reports cold vs warm first-run OPC (and the steady-state last
//! run for context) as a transfer table, rendered by `aimm curriculum`
//! and serialized into `BENCH_continual.json`
//! (`crate::bench::sweep::write_continual_report`).
//!
//! Determinism: a stage's trace depends only on (combo, `cfg.seed`) via
//! [`episode_ops`], and cold agents are built through the same
//! [`fresh_agent`] path as plain episodes — so the cold column of a
//! curriculum equals the standalone episode numbers, and the whole table
//! is reproducible under either simulation engine.

use crate::agent::{AimmAgent, WarmStart};
use crate::config::SystemConfig;
use crate::mapping::{AnyPolicy, MappingPolicy};
use crate::workloads::Benchmark;

use super::runner::{
    episode_ops, run_stream_policy, warm_started_policy, EpisodeSummary, MULTI_RUNS, SINGLE_RUNS,
};

/// One curriculum stage: a benchmark combination and its repeat count.
#[derive(Debug, Clone)]
pub struct CurriculumStage {
    /// One entry = single-program episode, several = multi-program.
    pub benches: Vec<Benchmark>,
    /// Repeated runs within the stage (0 = the §6.1 default for the
    /// combination arity: 5 single-program, 10 multi-program).
    pub runs: usize,
}

impl CurriculumStage {
    pub fn new(benches: Vec<Benchmark>) -> Self {
        Self { benches, runs: 0 }
    }

    /// The effective repeat count (§6.1 defaults when unset).
    pub fn effective_runs(&self) -> usize {
        if self.runs > 0 {
            self.runs
        } else if self.benches.len() > 1 {
            MULTI_RUNS
        } else {
            SINGLE_RUNS
        }
    }
}

/// One executed stage: the warm episode (agent inherited from the
/// previous stages) and the cold baseline (fresh agent).
#[derive(Debug, Clone)]
pub struct StageOutcome {
    pub name: String,
    pub warm: EpisodeSummary,
    pub cold: EpisodeSummary,
}

impl StageOutcome {
    /// First-run OPC with the inherited model.
    pub fn warm_first_opc(&self) -> f64 {
        self.warm.first().opc()
    }

    /// First-run OPC of the cold baseline.
    pub fn cold_first_opc(&self) -> f64 {
        self.cold.first().opc()
    }

    /// Relative first-run gain of warm over cold (+0.05 = 5% better).
    /// 0 when the cold baseline produced no throughput (degenerate cell).
    pub fn transfer_gain(&self) -> f64 {
        let cold = self.cold_first_opc();
        if cold > 0.0 {
            self.warm_first_opc() / cold - 1.0
        } else {
            0.0
        }
    }
}

/// The executed curriculum.
#[derive(Debug, Clone)]
pub struct CurriculumReport {
    pub stages: Vec<StageOutcome>,
}

/// Run `stages` in order, threading one agent end-to-end (warm), and a
/// fresh agent per stage as the cold baseline. `initial` seeds the warm
/// lineage — pass a checkpoint-restored agent to continue a previous
/// curriculum, or `None` to start cold (stage 0's warm column then
/// equals its cold column, a useful self-check). Returns the report and
/// the final agent for checkpointing.
///
/// For non-AIMM mappings there is no agent to carry; the driver still
/// runs (warm == cold) so schemes stay comparable, but the transfer
/// column is definitionally zero.
pub fn run_curriculum(
    cfg: &SystemConfig,
    stages: &[CurriculumStage],
    scale: f64,
    initial: Option<AimmAgent>,
) -> anyhow::Result<(CurriculumReport, Option<AimmAgent>)> {
    anyhow::ensure!(
        initial.is_none() || cfg.mapping.uses_agent(),
        "an initial agent only makes sense with --mapping AIMM (got {})",
        cfg.mapping
    );
    let initial_policy = initial.map(|a| AnyPolicy::new(cfg, &[], Some(a)));
    let (report, mut policy) =
        run_curriculum_policy(cfg, stages, scale, initial_policy, WarmStart::None)?;
    Ok((report, policy.take_agent()))
}

/// The policy-level curriculum core behind [`run_curriculum`] — the
/// entry the `--warm-start` and AIMM-MC paths use, since both carry
/// learned state that does not fit the single-agent seam. Per stage:
///
/// * the **cold** baseline is always a fresh, never-warm-started policy
///   (it is the yardstick any distillation or transfer gain is measured
///   against);
/// * the **warm** lineage carries learned state stage-to-stage for the
///   AIMM shapes (one agent, or the whole per-MC pool), while stateless
///   policies are rebuilt per stage exactly as before — the oracle
///   re-profiles each stage's ops, TOM re-learns its epochs.
///
/// `warm_start` applies once, to the warm lineage's starting policy,
/// distilled from stage 0's op stream (resuming from `initial` skips
/// distillation — the learning it would seed is already there).
pub fn run_curriculum_policy(
    cfg: &SystemConfig,
    stages: &[CurriculumStage],
    scale: f64,
    initial: Option<AnyPolicy>,
    warm_start: WarmStart,
) -> anyhow::Result<(CurriculumReport, AnyPolicy)> {
    anyhow::ensure!(!stages.is_empty(), "curriculum needs at least one stage");
    if let Some(p) = &initial {
        anyhow::ensure!(
            p.scheme() == cfg.mapping,
            "the initial policy is {} but the config maps with {} — refusing to mix lineages",
            p.scheme().name(),
            cfg.mapping
        );
    }
    let mut warm_policy = match initial {
        Some(p) => p,
        None => {
            let (ops0, _) = episode_ops(cfg, &stages[0].benches, scale)?;
            warm_started_policy(cfg, &ops0, warm_start)?.0
        }
    };
    let mut outcomes = Vec::with_capacity(stages.len());
    for stage in stages {
        let runs = stage.effective_runs();
        let (ops, name) = episode_ops(cfg, &stage.benches, scale)?;
        let (cold_policy, _) = warm_started_policy(cfg, &ops, WarmStart::None)?;
        let (cold, _) = run_stream_policy(cfg, &ops, runs, &name, cold_policy)?;
        let stage_policy = if matches!(warm_policy, AnyPolicy::Aimm(_) | AnyPolicy::AimmMc(_)) {
            warm_policy
        } else {
            // Stateless schemes restart from this stage's op stream (the
            // oracle's dry run profiles *these* ops) — identical to the
            // pre-policy-carry behavior.
            AnyPolicy::new(cfg, &ops, None)
        };
        let (warm, carried) = run_stream_policy(cfg, &ops, runs, &name, stage_policy)?;
        warm_policy = carried;
        outcomes.push(StageOutcome { name, warm, cold });
    }
    Ok((CurriculumReport { stages: outcomes }, warm_policy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MappingScheme, Technique};
    use crate::coordinator::fresh_agent;

    fn cfg(mapping: MappingScheme) -> SystemConfig {
        let mut c = SystemConfig::default();
        c.mapping = mapping;
        c.technique = Technique::Bnmp;
        c
    }

    fn stages(combos: &[&[Benchmark]], runs: usize) -> Vec<CurriculumStage> {
        combos
            .iter()
            .map(|&b| CurriculumStage { benches: b.to_vec(), runs })
            .collect()
    }

    #[test]
    fn effective_runs_follow_the_protocol() {
        assert_eq!(CurriculumStage::new(vec![Benchmark::Sc]).effective_runs(), SINGLE_RUNS);
        assert_eq!(
            CurriculumStage::new(vec![Benchmark::Sc, Benchmark::Km]).effective_runs(),
            MULTI_RUNS
        );
        let mut s = CurriculumStage::new(vec![Benchmark::Sc]);
        s.runs = 2;
        assert_eq!(s.effective_runs(), 2);
    }

    #[test]
    fn curriculum_carries_one_agent_across_stages() {
        let c = cfg(MappingScheme::Aimm);
        let st = stages(&[&[Benchmark::Sc], &[Benchmark::Km]], 2);
        let (report, agent) = run_curriculum(&c, &st, 0.04, None).unwrap();
        assert_eq!(report.stages.len(), 2);
        let agent = agent.expect("agent survives the curriculum");
        // The carried agent saw every warm run of every stage; a single
        // stage's cold agent saw only its own. Lifetime invocation
        // totals are cumulative in RunStats, so the warm lineage's
        // stage-1 totals must exceed stage-1's cold totals.
        let s1 = &report.stages[1];
        assert!(
            s1.warm.last().agent_invocations > s1.cold.last().agent_invocations,
            "warm {} <= cold {}",
            s1.warm.last().agent_invocations,
            s1.cold.last().agent_invocations
        );
        assert!(agent.stats.invocations >= s1.warm.last().agent_invocations);
        // Stage 0 started cold, so its warm lineage == its cold baseline.
        let s0 = &report.stages[0];
        assert_eq!(s0.warm.first().cycles, s0.cold.first().cycles);
        assert_eq!(s0.warm.last().cycles, s0.cold.last().cycles);
    }

    #[test]
    fn baseline_curriculum_has_no_transfer() {
        let c = cfg(MappingScheme::Baseline);
        let st = stages(&[&[Benchmark::Mac], &[Benchmark::Rd]], 1);
        let (report, agent) = run_curriculum(&c, &st, 0.03, None).unwrap();
        assert!(agent.is_none());
        for s in &report.stages {
            assert_eq!(s.warm.first().cycles, s.cold.first().cycles);
            assert_eq!(s.transfer_gain(), 0.0);
        }
    }

    #[test]
    fn curriculum_rejects_bad_inputs() {
        let c = cfg(MappingScheme::Aimm);
        assert!(run_curriculum(&c, &[], 0.03, None).is_err());
        let b = cfg(MappingScheme::Baseline);
        let agent = fresh_agent(&cfg(MappingScheme::Aimm)).unwrap();
        let st = stages(&[&[Benchmark::Mac]], 1);
        assert!(run_curriculum(&b, &st, 0.03, Some(agent)).is_err());
    }

    #[test]
    fn curriculum_policy_carries_the_mc_pool() {
        let c = cfg(MappingScheme::AimmMc);
        let st = stages(&[&[Benchmark::Sc], &[Benchmark::Km]], 2);
        let (report, policy) =
            run_curriculum_policy(&c, &st, 0.04, None, WarmStart::None).unwrap();
        assert_eq!(report.stages.len(), 2);
        assert_eq!(policy.scheme(), MappingScheme::AimmMc);
        // The carried pool saw every warm run; stage 1's cold pool saw
        // only its own stage (invocation totals are cumulative).
        let s1 = &report.stages[1];
        assert!(
            s1.warm.last().agent_invocations > s1.cold.last().agent_invocations,
            "warm {} <= cold {}",
            s1.warm.last().agent_invocations,
            s1.cold.last().agent_invocations
        );
        // The single-agent wrapper hands no agent back for the pool —
        // the learned state lives in the policy object.
        let (_, none) = run_curriculum(&c, &st, 0.04, None).unwrap();
        assert!(none.is_none());
    }

    #[test]
    fn curriculum_accepts_warm_start_and_rejects_mixed_lineages() {
        let c = cfg(MappingScheme::Aimm);
        let st = stages(&[&[Benchmark::Mac]], 1);
        let (report, policy) =
            run_curriculum_policy(&c, &st, 0.03, None, WarmStart::Oracle).unwrap();
        assert_eq!(report.stages.len(), 1);
        assert_eq!(policy.scheme(), MappingScheme::Aimm);
        // A lineage from one scheme cannot seed a curriculum of another.
        let mc = cfg(MappingScheme::AimmMc);
        let donor = AnyPolicy::new(&mc, &[], None);
        let err = run_curriculum_policy(&c, &st, 0.03, Some(donor), WarmStart::None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("AIMM-MC"), "{err}");
        // Warm-starting a stateless scheme fails loudly at construction.
        let b = cfg(MappingScheme::Baseline);
        assert!(run_curriculum_policy(&b, &st, 0.03, None, WarmStart::Oracle).is_err());
    }

    #[test]
    fn engines_agree_on_the_whole_curriculum() {
        use crate::config::Engine;
        let st = stages(&[&[Benchmark::Sc], &[Benchmark::Sc, Benchmark::Km]], 1);
        let mut polled = cfg(MappingScheme::Aimm);
        polled.engine = Engine::Polled;
        let mut event = cfg(MappingScheme::Aimm);
        event.engine = Engine::Event;
        let (p, _) = run_curriculum(&polled, &st, 0.03, None).unwrap();
        let (e, _) = run_curriculum(&event, &st, 0.03, None).unwrap();
        for (sp, se) in p.stages.iter().zip(&e.stages) {
            for (rp, re) in sp
                .warm
                .runs
                .iter()
                .chain(&sp.cold.runs)
                .zip(se.warm.runs.iter().chain(&se.cold.runs))
            {
                assert_eq!(
                    crate::bench::sweep::stats_json(rp),
                    crate::bench::sweep::stats_json(re),
                    "stage {}",
                    sp.name
                );
            }
        }
    }
}
