//! The compute-remap table (paper §5.3): maps a destination page to the
//! cube where its NMP operations should execute, decoupling computation
//! location from data location. Consulted by the NMP-op scheduler in the
//! MC on every dispatch; written by the AIMM agent's compute-remapping
//! actions.

use std::collections::HashMap;

use crate::config::{CubeId, Pid, VPage};

/// Bounded page → compute-cube remap table.
#[derive(Debug)]
pub struct ComputeRemapTable {
    map: HashMap<(Pid, VPage), CubeId>,
    /// Insertion order for capacity eviction (oldest first).
    order: Vec<(Pid, VPage)>,
    capacity: usize,
    pub lookups: u64,
    pub hits: u64,
}

impl ComputeRemapTable {
    pub fn new(capacity: usize) -> Self {
        Self { map: HashMap::new(), order: Vec::new(), capacity, lookups: 0, hits: 0 }
    }

    /// Record an agent suggestion for a page.
    pub fn insert(&mut self, pid: Pid, vpage: VPage, cube: CubeId) {
        let key = (pid, vpage);
        if self.map.insert(key, cube).is_none() {
            self.order.push(key);
            if self.order.len() > self.capacity {
                let victim = self.order.remove(0);
                self.map.remove(&victim);
            }
        }
    }

    /// Scheduler consultation: where should ops on this page compute?
    pub fn lookup(&mut self, pid: Pid, vpage: VPage) -> Option<CubeId> {
        self.lookups += 1;
        let hit = self.map.get(&(pid, vpage)).copied();
        if hit.is_some() {
            self.hits += 1;
        }
        hit
    }

    /// Drop a suggestion (agent chose "default mapping" for this page).
    pub fn remove(&mut self, pid: Pid, vpage: VPage) {
        if self.map.remove(&(pid, vpage)).is_some() {
            self.order.retain(|k| *k != (pid, vpage));
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_after_insert() {
        let mut t = ComputeRemapTable::new(4);
        t.insert(1, 100, 7);
        assert_eq!(t.lookup(1, 100), Some(7));
        assert_eq!(t.lookup(1, 101), None);
        assert_eq!(t.hits, 1);
        assert_eq!(t.lookups, 2);
    }

    #[test]
    fn overwrite_updates() {
        let mut t = ComputeRemapTable::new(4);
        t.insert(1, 100, 7);
        t.insert(1, 100, 3);
        assert_eq!(t.lookup(1, 100), Some(3));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn capacity_evicts_oldest() {
        let mut t = ComputeRemapTable::new(2);
        t.insert(1, 1, 0);
        t.insert(1, 2, 0);
        t.insert(1, 3, 0);
        assert_eq!(t.lookup(1, 1), None);
        assert!(t.lookup(1, 2).is_some());
        assert!(t.lookup(1, 3).is_some());
    }

    #[test]
    fn remove_clears() {
        let mut t = ComputeRemapTable::new(2);
        t.insert(1, 1, 5);
        t.remove(1, 1);
        assert_eq!(t.lookup(1, 1), None);
        assert!(t.is_empty());
    }
}
