//! The mapping-policy seam: every "who decides where pages live and
//! where their computation runs" scheme behind one trait.
//!
//! The paper frames AIMM as "a plugin module for various NMP systems"
//! (§5) — the decision layer is the pluggable part, the fabric and the
//! memory system are not. [`MappingPolicy`] makes that literal: the
//! [`crate::coordinator::System`] owns the *actuators* (the MMU, the
//! [`ComputeRemapTable`], the migration engine) and forwards *events*
//! (dispatched ops, clock ticks); the policy owns the whole decision
//! lifecycle and answers with [`MappingAction`]s the system applies.
//!
//! Six policies implement the trait:
//!
//! * [`BaselinePolicy`] — the figures' "B" column: no decisions at all.
//! * [`TomPolicy`] — wraps [`TomMapper`]: epoch-profiled page→cube
//!   hashing, bulk re-layouts at phase boundaries.
//! * [`AimmPolicy`] — wraps [`AimmAgent`]: the RL control loop (state
//!   assembly from the MCs, ε-greedy actions, migration + compute-remap
//!   actuation, invocation-interval scheduling).
//! * [`AimmMultiPolicy`] — the per-MC multi-agent variant
//!   (`--mapping aimm-mc`, DESIGN.md §15): one lightweight agent per
//!   memory controller, each observing only its own MC and attached
//!   cubes, coordinated by the deterministic replay gossip of
//!   [`crate::agent::multi`].
//! * [`CodaGreedy`] — CODA-style compute/data co-location (Kim et al.)
//!   without learning: windowed per-page compute counters, migrate a
//!   page to the cube issuing the majority of its NMP ops once the lead
//!   crosses a hysteresis margin.
//! * [`OracleProfile`] — perfect-knowledge upper bound: a side-effect-
//!   free dry run over the op stream derives the best static page→cube
//!   assignment, which then drives first-touch placement on the replay.
//!
//! Dispatch goes through the [`AnyPolicy`] enum — a direct `match` per
//! call, mirroring `noc::topology::AnyTopology`, so the per-dispatch
//! hot path ([`MappingPolicy::observe_dispatch`],
//! [`MappingPolicy::first_touch_cube`]) pays no `&dyn` vtable.
//!
//! ## Byte-identity contract
//!
//! B, TOM and AIMM behave **bit-identically** to the pre-trait
//! simulator (`tests/fixtures/sweep_golden.json` and the
//! engine-equivalence grid pin this):
//!
//! * the policy hooks run at the exact tick positions the hardwired
//!   code ran (dispatch observation inside MC issue, decisions between
//!   the periodic cube reports and the OPC sample);
//! * [`AimmPolicy`] carries the former `System` fields (`next_agent_at`,
//!   `ops_at_last_invoke`, `page_mc_rr`, and the action-target RNG with
//!   its original `seed ^ 0x5157` stream) and re-derives them per
//!   episode exactly as `System::new` did;
//! * actions are applied in emission order immediately after the
//!   decision step, and every action the old code performed inline
//!   (migration request, remap-table insert, TOM's force-remap + TLB
//!   shootdown sequence) maps to one [`MappingAction`] applied the same
//!   way (see `System::apply_actions`).

use std::collections::HashMap;

use crate::agent::{
    build_state, fresh_mc_agents, gossip_exchange, hist4, hop_scale, Action, AgentCheckpoint,
    AimmAgent, CheckpointBundle, PageSignals, PerMcSignals, StateVec, SysSignals, WarmStart,
    GOSSIP_BURST, GOSSIP_EVERY,
};
use crate::config::{CubeId, MappingScheme, Pid, SystemConfig, VPage};
use crate::cube::Cube;
use crate::mc::Mc;
use crate::mmu::Mmu;
use crate::nmp::NmpOp;
use crate::noc::Mesh;
use crate::sim::{Cycle, Rng};

use super::remap_table::ComputeRemapTable;
use super::tom::{TomEvent, TomMapper};

/// CodaGreedy evaluation window in cycles. Sits between the agent's
/// invocation intervals (100–250) and TOM's epochs (30k): long enough
/// for per-page counters to mean something, short enough to react
/// within an episode.
pub const CODA_WINDOW: u64 = 1024;
/// Minimum ops observed on a page within a window before CodaGreedy
/// considers migrating it.
pub const CODA_MIN_OPS: u32 = 16;
/// Hysteresis margin: the leading cube must issue at least this many
/// times the runner-up's ops (and an absolute majority) to trigger a
/// migration — a 50/50 page never ping-pongs.
pub const CODA_MARGIN: u32 = 2;
/// Migrations CodaGreedy requests per evaluation window (keeps the
/// 128-entry migration queue from being flooded by one hot window).
pub const CODA_MAX_MIGRATIONS: usize = 8;

/// What a policy wants done. The `System` applies actions in emission
/// order, immediately after the decision step of the same tick:
///
/// 1. [`MappingAction::MigratePage`] → a [`crate::migration::MigRequest`]
///    (blocking iff the page was ever written — the §5.3 rule — which
///    the system derives from its `rw_pages` set);
/// 2. [`MappingAction::RemapCompute`] → [`ComputeRemapTable::insert`];
/// 3. [`MappingAction::ForceRemap`] → `Mmu::force_remap` plus a TLB
///    shootdown on every MC (TOM's traffic-free bulk re-layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MappingAction {
    /// Migrate a page's data to `to_cube` through the MDMA engine.
    MigratePage { pid: Pid, vpage: VPage, to_cube: CubeId },
    /// Steer future NMP ops on this page to compute at `cube`.
    RemapCompute { pid: Pid, vpage: VPage, cube: CubeId },
    /// Instantly relocate a page (kernel-boundary re-layout, no network
    /// traffic) — TOM's epoch adoption.
    ForceRemap { pid: Pid, vpage: VPage, to_cube: CubeId },
}

/// The system state a policy may observe (and, where the AIMM control
/// loop demands it, mutate: candidate selection rotates the page-info
/// caches, state assembly touches the MMU walk and remap-table lookup
/// counters) while deciding. Borrowed field-by-field from `System` for
/// the duration of one decision step.
pub struct PolicyCtx<'a> {
    pub mcs: &'a mut [Mc],
    pub cubes: &'a [Cube],
    pub mmu: &'a mut Mmu,
    pub remap_table: &'a mut ComputeRemapTable,
    pub mesh: &'a Mesh,
    /// Ops completed so far (the policy's progress/throughput signal).
    pub completed: u64,
    /// Total ops in the trace; policies go quiet once
    /// `completed == total_ops` (nothing left to steer).
    pub total_ops: u64,
}

/// The full decision lifecycle of a mapping scheme. Every hook has a
/// no-op default so stateless policies stay empty.
pub trait MappingPolicy {
    /// Which [`MappingScheme`] this policy implements (names in errors,
    /// reports and tables).
    fn scheme(&self) -> MappingScheme;

    /// Episode start (§6.1: "simulation states are cleared except the
    /// DNN model"). Called once per `System` construction; resets every
    /// per-run control field while keeping whatever the policy carries
    /// across runs (AIMM's network + replay; nothing for the rest).
    fn start_episode(&mut self) {}

    /// First-touch placement override: the cube a not-yet-mapped page
    /// should be allocated in, or `None` to defer to the configured
    /// frame allocator. Consulted by the MC's translation path.
    fn first_touch_cube(&self, _pid: Pid, _vpage: VPage) -> Option<CubeId> {
        None
    }

    /// Observe one dispatched NMP op (TOM's co-location profiling,
    /// CODA's per-page compute counters). `sources` holds the source
    /// operand pages; `compute_cube` is the final scheduling decision
    /// (technique rule plus any compute-remap override).
    fn observe_dispatch(
        &mut self,
        _dest: (Pid, VPage),
        _sources: &[(Pid, VPage)],
        _compute_cube: CubeId,
    ) {
    }

    /// The per-tick decision step: observe the clock, decide, return
    /// the actions to apply. Called every cycle by the polled engine;
    /// the event engine calls it at the cycles
    /// [`next_event`](Self::next_event) announces — a policy must
    /// therefore be a pure no-op on cycles it did not announce.
    fn tick(
        &mut self,
        _now: Cycle,
        _ctx: &mut PolicyCtx<'_>,
    ) -> anyhow::Result<Vec<MappingAction>> {
        Ok(Vec::new())
    }

    /// Earliest cycle ≥ `now` at which [`tick`](Self::tick) can act
    /// (event engine, DESIGN.md §8). `None` = never again this run.
    fn next_event(&self, _now: Cycle, _completed: u64, _total_ops: u64) -> Option<Cycle> {
        None
    }

    /// Episode end: the run drained. AIMM files its terminal transition
    /// here; everything else has nothing to close out.
    fn finish(&mut self, _ctx: &mut PolicyCtx<'_>) {}

    /// Borrow the learning agent, if this policy carries one (stats
    /// collection, diagnostics). Multi-agent policies return their
    /// first agent here — use [`agents`](Self::agents) for the pool.
    fn agent(&self) -> Option<&AimmAgent> {
        None
    }

    /// Every learning agent this policy carries, in a stable order
    /// (MC 0..n for the per-MC pool). The `System`'s stats collection
    /// sums over this, so single- and multi-agent runs report through
    /// one code path.
    fn agents(&self) -> Vec<&AimmAgent> {
        self.agent().into_iter().collect()
    }

    /// Capture a continual-learning checkpoint. Errs loudly — naming
    /// the policy — for every scheme without learned state.
    fn snapshot(&self) -> anyhow::Result<AgentCheckpoint> {
        anyhow::bail!(
            "the {} policy is not checkpointable (only AIMM carries learned state)",
            self.scheme().name()
        )
    }

    /// Restore from a continual-learning checkpoint. Errs loudly —
    /// naming the policy — for every scheme without learned state.
    fn restore(&mut self, _ck: &AgentCheckpoint) -> anyhow::Result<()> {
        anyhow::bail!(
            "the {} policy is not checkpointable (only AIMM carries learned state)",
            self.scheme().name()
        )
    }
}

// ---------------------------------------------------------------------
// B — the absence of a policy.
// ---------------------------------------------------------------------

/// The figures' "B" column: pages stay where the allocator put them,
/// computation follows the offloading technique's static rule.
#[derive(Debug, Default, Clone, Copy)]
pub struct BaselinePolicy;

impl MappingPolicy for BaselinePolicy {
    fn scheme(&self) -> MappingScheme {
        MappingScheme::Baseline
    }
}

// ---------------------------------------------------------------------
// TOM — epoch-profiled physical-address remapping.
// ---------------------------------------------------------------------

/// [`TomMapper`] behind the policy trait: first-touch placement through
/// the adopted hash, virtual profiling of every dispatched op, and a
/// bulk [`MappingAction::ForceRemap`] sweep when an epoch boundary
/// adopts a new candidate.
pub struct TomPolicy {
    mapper: TomMapper,
    n_cubes: usize,
}

impl TomPolicy {
    pub fn new(cfg: &SystemConfig) -> Self {
        Self { mapper: TomMapper::new(cfg.num_cubes()), n_cubes: cfg.num_cubes() }
    }

    /// The wrapped mapper (diagnostics: adoption counts, current
    /// candidate).
    pub fn mapper(&self) -> &TomMapper {
        &self.mapper
    }
}

impl MappingPolicy for TomPolicy {
    fn scheme(&self) -> MappingScheme {
        MappingScheme::Tom
    }

    /// Every run re-profiles from scratch — exactly the fresh
    /// `TomMapper` the pre-trait `System::new` built per run.
    fn start_episode(&mut self) {
        self.mapper = TomMapper::new(self.n_cubes);
    }

    fn first_touch_cube(&self, pid: Pid, vpage: VPage) -> Option<CubeId> {
        Some(self.mapper.target_cube(pid, vpage))
    }

    fn observe_dispatch(
        &mut self,
        dest: (Pid, VPage),
        sources: &[(Pid, VPage)],
        _compute_cube: CubeId,
    ) {
        self.mapper.record_op(dest, sources);
    }

    fn tick(&mut self, now: Cycle, ctx: &mut PolicyCtx<'_>) -> anyhow::Result<Vec<MappingAction>> {
        let mut actions = Vec::new();
        if let Some(TomEvent::Apply(_)) = self.mapper.tick(now) {
            // Emission order mirrors the pre-trait relayout loop: pids
            // ascending, each pid's mapping snapshot in table order, so
            // the frame-pool alloc/free sequence is unchanged.
            for pid in ctx.mmu.pids() {
                for (vpage, loc) in ctx.mmu.mappings(pid) {
                    let target = self.mapper.target_cube(pid, vpage);
                    if target != loc.cube {
                        actions.push(MappingAction::ForceRemap { pid, vpage, to_cube: target });
                    }
                }
            }
        }
        Ok(actions)
    }

    fn next_event(&self, now: Cycle, _completed: u64, _total_ops: u64) -> Option<Cycle> {
        Some(self.mapper.next_boundary().max(now))
    }
}

// ---------------------------------------------------------------------
// AIMM — the RL control loop.
// ---------------------------------------------------------------------

/// [`AimmAgent`] behind the policy trait. Owns the control state the
/// pre-trait `System` kept inline: the invocation schedule
/// (`next_agent_at`), the OPC window (`ops_at_last_invoke`), the
/// round-robin over MC page-info caches (`page_mc_rr`) and the
/// action-target RNG (`cfg.seed ^ 0x5157`, reseeded per episode exactly
/// as `System::new` re-built it per run).
pub struct AimmPolicy {
    agent: AimmAgent,
    rng: Rng,
    seed: u64,
    next_agent_at: Cycle,
    ops_at_last_invoke: u64,
    page_mc_rr: usize,
}

impl AimmPolicy {
    pub fn new(cfg: &SystemConfig, agent: AimmAgent) -> Self {
        let next_agent_at = agent.current_interval();
        Self {
            agent,
            rng: Rng::new(cfg.seed ^ 0x5157),
            seed: cfg.seed,
            next_agent_at,
            ops_at_last_invoke: 0,
            page_mc_rr: 0,
        }
    }

    /// Move the learning agent out (episode-boundary carryover).
    pub fn into_agent(self) -> AimmAgent {
        self.agent
    }

    /// Assemble the 64-slot state vector (paper §4.2) from the MCs,
    /// cubes and the candidate page's info-cache entry.
    fn assemble_state(
        &self,
        ctx: &mut PolicyCtx<'_>,
        page: Option<(usize, (Pid, VPage))>,
        opc: f32,
    ) -> StateVec {
        let per_mc: Vec<PerMcSignals> = ctx
            .mcs
            .iter()
            .map(|mc| PerMcSignals {
                occ_mean: mc.counters.occ_mean(),
                occ_max: mc.counters.occ_max(),
                row_hit_mean: mc.counters.row_hit_mean(),
                row_hit_min: mc.counters.row_hit_min(),
                queue_occ: mc.queue.occupancy(),
            })
            .collect();
        let n = ctx.cubes.len() as f32;
        let cube_occ_mean = ctx.cubes.iter().map(|c| c.table.occupancy()).sum::<f32>() / n;
        let cube_occ_max =
            ctx.cubes.iter().map(|c| c.table.occupancy()).fold(0.0f32, f32::max);
        let cube_rh_mean =
            (ctx.cubes.iter().map(|c| c.row_hit_rate()).sum::<f64>() / n as f64) as f32;
        let sys = SysSignals {
            per_mc,
            action_histogram: self.agent.action_histogram(),
            interval_norm: self.agent.interval_norm(),
            recent_opc: opc,
            cube_occ_mean,
            cube_occ_max,
            cube_row_hit_mean: cube_rh_mean,
        };
        let page_sig = match page {
            Some((mc_idx, key)) => {
                let page_cube = ctx.mmu.translate(key.0, key.1).map(|l| l.cube).unwrap_or(0);
                let remapped = ctx.remap_table.lookup(key.0, key.1);
                let mc = &ctx.mcs[mc_idx];
                let info = mc.page_cache.get(&key);
                let compute_cube = remapped.unwrap_or_else(|| {
                    info.map(|e| e.last_compute_cube).unwrap_or(page_cube)
                });
                match info {
                    Some(e) => PageSignals {
                        access_rate: mc.page_cache.access_rate(&key),
                        migrations_per_access: e.migrations_per_access(),
                        hop_hist: hist4(&e.hop_hist.padded()),
                        lat_hist: hist4(&e.lat_hist.padded()),
                        mig_lat_hist: hist4(&e.mig_lat_hist.padded()),
                        action_hist: hist4(&e.action_hist.padded()),
                        page_cube_norm: page_cube as f32 / n,
                        compute_cube_norm: compute_cube as f32 / n,
                    },
                    None => PageSignals::default(),
                }
            }
            None => PageSignals::default(),
        };
        build_state(&sys, &page_sig, hop_scale(ctx.mesh.diameter()))
    }

    /// One agent invocation (§5.3): pick the candidate page, assemble
    /// the state, invoke the agent, translate its action into
    /// [`MappingAction`]s.
    fn invoke(
        &mut self,
        now: Cycle,
        ctx: &mut PolicyCtx<'_>,
    ) -> anyhow::Result<Vec<MappingAction>> {
        // Pick the page: MCs take turns providing their hottest entry.
        let num_mcs = ctx.mcs.len();
        let mut chosen: Option<(usize, (Pid, VPage))> = None;
        for i in 0..num_mcs {
            let mc = (self.page_mc_rr + i) % num_mcs;
            if let Some(key) = ctx.mcs[mc].page_cache.select_candidate() {
                chosen = Some((mc, key));
                break;
            }
        }
        self.page_mc_rr = (self.page_mc_rr + 1) % num_mcs;

        let interval = self.agent.current_interval();
        let elapsed_ops = ctx.completed - self.ops_at_last_invoke;
        let opc = elapsed_ops as f64 / interval.max(1) as f64;
        self.ops_at_last_invoke = ctx.completed;

        let state = self.assemble_state(ctx, chosen, opc as f32);
        let decision = self.agent.invoke(state, opc, now)?;
        self.next_agent_at = now + decision.next_interval;

        let Some((mc_idx, key)) = chosen else { return Ok(Vec::new()) };
        let (pid, vpage) = key;
        // Current compute location of the page's ops: the remap table's
        // suggestion, else where its most recent op actually computed.
        let page_cube = ctx.mmu.translate(pid, vpage).map(|l| l.cube).unwrap_or(0);
        let info_cubes = ctx.mcs[mc_idx]
            .page_cache
            .get(&key)
            .map(|e| (e.last_src1_cube, e.last_compute_cube));
        let (src1_cube, last_cc) = info_cubes.unwrap_or((page_cube, page_cube));
        let compute_cube = ctx.remap_table.lookup(pid, vpage).unwrap_or(last_cc);

        let mut actions = Vec::new();
        match decision.action {
            Action::Default | Action::IncreaseInterval | Action::DecreaseInterval => {}
            Action::NearData | Action::FarData => {
                if let Some(target) = decision.action.target_cube(
                    ctx.mesh,
                    compute_cube,
                    src1_cube,
                    &mut self.rng,
                ) {
                    if target != page_cube {
                        actions.push(MappingAction::MigratePage { pid, vpage, to_cube: target });
                    }
                }
                ctx.mcs[mc_idx].page_cache.on_action(key, decision.action.index() as u8);
            }
            Action::NearCompute | Action::FarCompute | Action::SourceCompute => {
                if let Some(target) = decision.action.target_cube(
                    ctx.mesh,
                    compute_cube,
                    src1_cube,
                    &mut self.rng,
                ) {
                    actions.push(MappingAction::RemapCompute { pid, vpage, cube: target });
                }
                ctx.mcs[mc_idx].page_cache.on_action(key, decision.action.index() as u8);
            }
        }
        Ok(actions)
    }
}

impl MappingPolicy for AimmPolicy {
    fn scheme(&self) -> MappingScheme {
        MappingScheme::Aimm
    }

    /// Reset the per-run control state (the fields `System::new` used to
    /// re-initialize each run) while the agent keeps its network, replay
    /// memory and ε schedule — the continual-learning premise.
    fn start_episode(&mut self) {
        self.agent.start_episode();
        self.rng = Rng::new(self.seed ^ 0x5157);
        self.next_agent_at = self.agent.current_interval();
        self.ops_at_last_invoke = 0;
        self.page_mc_rr = 0;
    }

    fn tick(&mut self, now: Cycle, ctx: &mut PolicyCtx<'_>) -> anyhow::Result<Vec<MappingAction>> {
        // Invoke while work remains — the agent has nothing to steer
        // once the trace has drained.
        if now < self.next_agent_at || ctx.completed >= ctx.total_ops {
            return Ok(Vec::new());
        }
        self.invoke(now, ctx)
    }

    fn next_event(&self, now: Cycle, completed: u64, total_ops: u64) -> Option<Cycle> {
        (completed < total_ops).then(|| self.next_agent_at.max(now))
    }

    /// Terminal agent transition at the end of the run.
    fn finish(&mut self, ctx: &mut PolicyCtx<'_>) {
        let interval = self.agent.current_interval();
        let elapsed_ops = ctx.completed - self.ops_at_last_invoke;
        let opc = elapsed_ops as f64 / interval.max(1) as f64;
        let state = self.assemble_state(ctx, None, opc as f32);
        self.agent.finish_episode(state, opc);
    }

    fn agent(&self) -> Option<&AimmAgent> {
        Some(&self.agent)
    }

    fn snapshot(&self) -> anyhow::Result<AgentCheckpoint> {
        self.agent.checkpoint()
    }

    fn restore(&mut self, ck: &AgentCheckpoint) -> anyhow::Result<()> {
        let cfg = self.agent.config().clone();
        self.agent = ck.build_agent(&cfg)?;
        // Pair the restored agent with fresh per-run control state,
        // exactly as the real resume path does (AnyPolicy::new →
        // System::with_policy → start_episode) — a restore must never
        // keep the pre-restore schedule or RNG stream.
        self.start_episode();
        Ok(())
    }
}

// ---------------------------------------------------------------------
// AIMM-MC — the per-MC multi-agent RL control loop.
// ---------------------------------------------------------------------

/// The per-MC agent pool behind `--mapping aimm-mc` (DESIGN.md §15).
/// One lightweight [`AimmAgent`] per memory controller, each with its
/// own invocation schedule, OPC window and masked observation:
///
/// * the per-MC state slots carry only the agent's *own* MC (the other
///   slots stay zero — the layout of [`build_state`] is shared with the
///   single-agent policy, so the Q-architecture is identical);
/// * cube aggregates run over the MC's attached cubes only
///   (`SystemConfig::mc_nearest_cubes`);
/// * the candidate page comes from the agent's own MC page-info cache —
///   no cross-MC candidate stealing.
///
/// Coordination is deterministic round-robin gossip
/// ([`gossip_exchange`]): after every [`GOSSIP_EVERY`] invocations
/// system-wide, one agent (the ring cursor) hands its
/// [`GOSSIP_BURST`] freshest transitions to its successor. Every
/// control field resets per episode and the RNG streams derive from
/// `cfg.seed`, so runs are bit-reproducible at any worker count and
/// checkpoints at episode boundaries resume bit-identically.
pub struct AimmMultiPolicy {
    agents: Vec<AimmAgent>,
    /// Shared action-target RNG (`cfg.seed ^ 0x5157`, reseeded per
    /// episode — the same stream discipline as [`AimmPolicy`]).
    rng: Rng,
    seed: u64,
    /// Per-MC observed cube sets (`SystemConfig::mc_nearest_cubes`).
    nearest: Vec<Vec<CubeId>>,
    /// Per-agent next invocation cycle.
    next_at: Vec<Cycle>,
    /// Per-agent completed-op count at its last invocation (OPC window).
    ops_at_last_invoke: Vec<u64>,
    /// System-wide invocation counter driving the gossip cadence.
    invocations: u64,
    /// Ring cursor: which agent gossips next.
    gossip_from: usize,
}

impl AimmMultiPolicy {
    /// Build the pool from the config alone. Panics — with the agent
    /// layer's validation message — only on an agent configuration that
    /// [`SystemConfig::validate`] would already have rejected (empty
    /// interval table, zero batch, replay below batch) or on a PJRT
    /// fixed-batch mismatch, mirroring [`AimmAgent::new`].
    pub fn new(cfg: &SystemConfig) -> Self {
        Self::with_agents(cfg, fresh_mc_agents(cfg).expect("invalid agent configuration"))
    }

    /// Wrap an existing pool (the warm-start path pre-trains the agents
    /// before handing them in). Panics when the pool size does not match
    /// the MC count — the masked states and gossip ring assume one
    /// agent per MC.
    pub fn with_agents(cfg: &SystemConfig, agents: Vec<AimmAgent>) -> Self {
        assert_eq!(
            agents.len(),
            cfg.num_mcs(),
            "AIMM-MC drives one agent per MC"
        );
        let next_at = agents.iter().map(|a| a.current_interval()).collect();
        let n = agents.len();
        Self {
            rng: Rng::new(cfg.seed ^ 0x5157),
            seed: cfg.seed,
            nearest: (0..n).map(|mc| cfg.mc_nearest_cubes(mc)).collect(),
            next_at,
            ops_at_last_invoke: vec![0; n],
            invocations: 0,
            gossip_from: 0,
            agents,
        }
    }

    /// The pool, MC order.
    pub fn agent_pool(&self) -> &[AimmAgent] {
        &self.agents
    }

    /// Mutable pool access (the warm-start path pre-trains in place).
    pub fn agent_pool_mut(&mut self) -> &mut [AimmAgent] {
        &mut self.agents
    }

    /// Episode-boundary checkpoint of every agent, MC order — the
    /// `agents` array of a v2 [`CheckpointBundle`].
    pub fn snapshot_bundle(&self) -> anyhow::Result<Vec<AgentCheckpoint>> {
        self.agents.iter().map(|a| a.checkpoint()).collect()
    }

    /// Restore every agent from a bundle's `agents` array. The count
    /// must match the pool ([`CheckpointBundle::ensure_resumable`] gives
    /// the caller the pointed per-MC-drift message first; this is the
    /// backstop). Control state resets exactly like a fresh episode.
    pub fn restore_bundle(&mut self, cks: &[AgentCheckpoint]) -> anyhow::Result<()> {
        anyhow::ensure!(
            cks.len() == self.agents.len(),
            "checkpoint drift: per-MC agent count is {} but this policy drives {} — \
             resume refused",
            cks.len(),
            self.agents.len()
        );
        let mut restored = Vec::with_capacity(cks.len());
        for (agent, ck) in self.agents.iter().zip(cks) {
            restored.push(ck.build_agent(agent.config())?);
        }
        self.agents = restored;
        self.start_episode();
        Ok(())
    }

    /// Masked state for agent `mc_idx`: own MC slot populated, sibling
    /// slots zero; cube aggregates over the attached cubes only. The
    /// page block matches [`AimmPolicy::assemble_state`] (cube ids keep
    /// the global normalization so actions target the shared mesh
    /// coordinate system).
    fn assemble_state_for(
        &self,
        mc_idx: usize,
        ctx: &mut PolicyCtx<'_>,
        page: Option<(Pid, VPage)>,
        opc: f32,
    ) -> StateVec {
        let mut per_mc = vec![PerMcSignals::default(); ctx.mcs.len()];
        let mc = &ctx.mcs[mc_idx];
        per_mc[mc_idx] = PerMcSignals {
            occ_mean: mc.counters.occ_mean(),
            occ_max: mc.counters.occ_max(),
            row_hit_mean: mc.counters.row_hit_mean(),
            row_hit_min: mc.counters.row_hit_min(),
            queue_occ: mc.queue.occupancy(),
        };
        let n = ctx.cubes.len() as f32;
        let own = &self.nearest[mc_idx];
        let k = own.len().max(1) as f32;
        let cube_occ_mean =
            own.iter().map(|&c| ctx.cubes[c].table.occupancy()).sum::<f32>() / k;
        let cube_occ_max =
            own.iter().map(|&c| ctx.cubes[c].table.occupancy()).fold(0.0f32, f32::max);
        let cube_rh_mean =
            (own.iter().map(|&c| ctx.cubes[c].row_hit_rate()).sum::<f64>() / k as f64) as f32;
        let sys = SysSignals {
            per_mc,
            action_histogram: self.agents[mc_idx].action_histogram(),
            interval_norm: self.agents[mc_idx].interval_norm(),
            recent_opc: opc,
            cube_occ_mean,
            cube_occ_max,
            cube_row_hit_mean: cube_rh_mean,
        };
        let page_sig = match page {
            Some(key) => {
                let page_cube = ctx.mmu.translate(key.0, key.1).map(|l| l.cube).unwrap_or(0);
                let remapped = ctx.remap_table.lookup(key.0, key.1);
                let mc = &ctx.mcs[mc_idx];
                let info = mc.page_cache.get(&key);
                let compute_cube = remapped.unwrap_or_else(|| {
                    info.map(|e| e.last_compute_cube).unwrap_or(page_cube)
                });
                match info {
                    Some(e) => PageSignals {
                        access_rate: mc.page_cache.access_rate(&key),
                        migrations_per_access: e.migrations_per_access(),
                        hop_hist: hist4(&e.hop_hist.padded()),
                        lat_hist: hist4(&e.lat_hist.padded()),
                        mig_lat_hist: hist4(&e.mig_lat_hist.padded()),
                        action_hist: hist4(&e.action_hist.padded()),
                        page_cube_norm: page_cube as f32 / n,
                        compute_cube_norm: compute_cube as f32 / n,
                    },
                    None => PageSignals::default(),
                }
            }
            None => PageSignals::default(),
        };
        build_state(&sys, &page_sig, hop_scale(ctx.mesh.diameter()))
    }

    /// One invocation of agent `mc_idx`, mirroring
    /// [`AimmPolicy::invoke`] with the candidate drawn from — and the
    /// action applied through — the agent's own MC only. Also advances
    /// the gossip ring on its system-wide cadence.
    fn invoke_one(
        &mut self,
        mc_idx: usize,
        now: Cycle,
        ctx: &mut PolicyCtx<'_>,
    ) -> anyhow::Result<Vec<MappingAction>> {
        let chosen = ctx.mcs[mc_idx].page_cache.select_candidate();

        let interval = self.agents[mc_idx].current_interval();
        let elapsed_ops = ctx.completed - self.ops_at_last_invoke[mc_idx];
        let opc = elapsed_ops as f64 / interval.max(1) as f64;
        self.ops_at_last_invoke[mc_idx] = ctx.completed;

        let state = self.assemble_state_for(mc_idx, ctx, chosen, opc as f32);
        let decision = self.agents[mc_idx].invoke(state, opc, now)?;
        self.next_at[mc_idx] = now + decision.next_interval;

        self.invocations += 1;
        if self.invocations % GOSSIP_EVERY == 0 {
            gossip_exchange(&mut self.agents, self.gossip_from, GOSSIP_BURST);
            self.gossip_from = (self.gossip_from + 1) % self.agents.len();
        }

        let Some(key) = chosen else { return Ok(Vec::new()) };
        let (pid, vpage) = key;
        let page_cube = ctx.mmu.translate(pid, vpage).map(|l| l.cube).unwrap_or(0);
        let info_cubes = ctx.mcs[mc_idx]
            .page_cache
            .get(&key)
            .map(|e| (e.last_src1_cube, e.last_compute_cube));
        let (src1_cube, last_cc) = info_cubes.unwrap_or((page_cube, page_cube));
        let compute_cube = ctx.remap_table.lookup(pid, vpage).unwrap_or(last_cc);

        let mut actions = Vec::new();
        match decision.action {
            Action::Default | Action::IncreaseInterval | Action::DecreaseInterval => {}
            Action::NearData | Action::FarData => {
                if let Some(target) = decision.action.target_cube(
                    ctx.mesh,
                    compute_cube,
                    src1_cube,
                    &mut self.rng,
                ) {
                    if target != page_cube {
                        actions.push(MappingAction::MigratePage { pid, vpage, to_cube: target });
                    }
                }
                ctx.mcs[mc_idx].page_cache.on_action(key, decision.action.index() as u8);
            }
            Action::NearCompute | Action::FarCompute | Action::SourceCompute => {
                if let Some(target) = decision.action.target_cube(
                    ctx.mesh,
                    compute_cube,
                    src1_cube,
                    &mut self.rng,
                ) {
                    actions.push(MappingAction::RemapCompute { pid, vpage, cube: target });
                }
                ctx.mcs[mc_idx].page_cache.on_action(key, decision.action.index() as u8);
            }
        }
        Ok(actions)
    }
}

impl MappingPolicy for AimmMultiPolicy {
    fn scheme(&self) -> MappingScheme {
        MappingScheme::AimmMc
    }

    /// Per-run control reset for the whole pool: every agent keeps its
    /// network/replay/ε, every schedule and counter — including the
    /// gossip cadence and ring cursor — restarts, so an episode-boundary
    /// resume replays the next episode bit-identically.
    fn start_episode(&mut self) {
        for a in &mut self.agents {
            a.start_episode();
        }
        self.rng = Rng::new(self.seed ^ 0x5157);
        for (at, a) in self.next_at.iter_mut().zip(&self.agents) {
            *at = a.current_interval();
        }
        self.ops_at_last_invoke.iter_mut().for_each(|o| *o = 0);
        self.invocations = 0;
        self.gossip_from = 0;
    }

    fn tick(&mut self, now: Cycle, ctx: &mut PolicyCtx<'_>) -> anyhow::Result<Vec<MappingAction>> {
        if ctx.completed >= ctx.total_ops {
            return Ok(Vec::new());
        }
        // Ascending MC order: deterministic emission order when several
        // agents are due on the same cycle; agents not yet due are pure
        // no-ops, which keeps the event engine's skips legal.
        let mut actions = Vec::new();
        for mc in 0..self.agents.len() {
            if now >= self.next_at[mc] {
                actions.extend(self.invoke_one(mc, now, ctx)?);
            }
        }
        Ok(actions)
    }

    fn next_event(&self, now: Cycle, completed: u64, total_ops: u64) -> Option<Cycle> {
        (completed < total_ops)
            .then(|| self.next_at.iter().copied().min().unwrap_or(now).max(now))
    }

    /// Terminal transition for every agent, MC order.
    fn finish(&mut self, ctx: &mut PolicyCtx<'_>) {
        for mc in 0..self.agents.len() {
            let interval = self.agents[mc].current_interval();
            let elapsed_ops = ctx.completed - self.ops_at_last_invoke[mc];
            let opc = elapsed_ops as f64 / interval.max(1) as f64;
            let state = self.assemble_state_for(mc, ctx, None, opc as f32);
            self.agents[mc].finish_episode(state, opc);
        }
    }

    fn agent(&self) -> Option<&AimmAgent> {
        self.agents.first()
    }

    fn agents(&self) -> Vec<&AimmAgent> {
        self.agents.iter().collect()
    }

    /// The pool does not fit a single-agent checkpoint — point the
    /// caller at the v2 bundle path instead of snapshotting agent 0 and
    /// silently dropping the rest.
    fn snapshot(&self) -> anyhow::Result<AgentCheckpoint> {
        anyhow::bail!(
            "the AIMM-MC policy carries {} agents — checkpoint it as an \
             aimm-checkpoint-v2 bundle (AnyPolicy::checkpoint_bundle), not a \
             single-agent document",
            self.agents.len()
        )
    }

    fn restore(&mut self, _ck: &AgentCheckpoint) -> anyhow::Result<()> {
        anyhow::bail!(
            "the AIMM-MC policy carries {} agents — restore it from an \
             aimm-checkpoint-v2 bundle (AnyPolicy::restore_from_bundle), not a \
             single-agent document",
            self.agents.len()
        )
    }
}

// ---------------------------------------------------------------------
// CODA-greedy — co-location without learning.
// ---------------------------------------------------------------------

/// Windowed greedy co-location in the spirit of CODA (Kim et al.):
/// count, per page, which cube each of its NMP ops computed on; at
/// every [`CODA_WINDOW`]-cycle boundary migrate the hottest pages to
/// their dominant compute cube — but only when that cube issued an
/// absolute majority of the page's ops *and* leads the runner-up by
/// [`CODA_MARGIN`]× (hysteresis: contended pages never ping-pong).
pub struct CodaGreedy {
    n_cubes: usize,
    next_eval_at: Cycle,
    /// Per-page, per-cube op counts for the current window.
    counts: HashMap<(Pid, VPage), Vec<u32>>,
    /// Lifetime migrations requested (diagnostics).
    pub migrations_requested: u64,
}

impl CodaGreedy {
    pub fn new(cfg: &SystemConfig) -> Self {
        Self {
            n_cubes: cfg.num_cubes(),
            next_eval_at: CODA_WINDOW,
            counts: HashMap::new(),
            migrations_requested: 0,
        }
    }

    fn bump(&mut self, key: (Pid, VPage), cube: CubeId) {
        let n = self.n_cubes;
        self.counts.entry(key).or_insert_with(|| vec![0u32; n])[cube] += 1;
    }

    /// Close the window: decide migrations, clear the counters.
    fn evaluate(&mut self, mmu: &mut Mmu) -> Vec<MappingAction> {
        // Only pages past the op floor can migrate — filter before the
        // sort so a hot window's long cold tail costs one sum each, not
        // a seat in the O(P log P) sort. Deterministic order: hottest
        // first, ties by lowest key — never by map-iteration order
        // (sweep cells must be identical on any worker thread).
        let mut pages: Vec<((Pid, VPage), u32)> = self
            .counts
            .iter()
            .map(|(k, c)| (*k, c.iter().sum()))
            .filter(|&(_, total)| total >= CODA_MIN_OPS)
            .collect();
        pages.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let mut actions = Vec::new();
        for (key, total) in pages {
            if actions.len() >= CODA_MAX_MIGRATIONS {
                break;
            }
            let c = &self.counts[&key];
            let mut best = 0usize;
            let mut runner_up = 0u32;
            for (i, &v) in c.iter().enumerate().skip(1) {
                if v > c[best] {
                    best = i; // strict >: ties break to the lowest cube
                }
            }
            for (i, &v) in c.iter().enumerate() {
                if i != best && v > runner_up {
                    runner_up = v;
                }
            }
            // Hysteresis: absolute majority AND a margin× lead.
            if u64::from(c[best]) * 2 <= u64::from(total) {
                continue;
            }
            if u64::from(c[best]) < u64::from(CODA_MARGIN) * u64::from(runner_up.max(1)) {
                continue;
            }
            let current = mmu.translate(key.0, key.1).map(|l| l.cube);
            if current.is_none() || current == Some(best) {
                continue; // unmapped, or already co-located
            }
            self.migrations_requested += 1;
            actions.push(MappingAction::MigratePage { pid: key.0, vpage: key.1, to_cube: best });
        }
        self.counts.clear();
        actions
    }
}

impl MappingPolicy for CodaGreedy {
    fn scheme(&self) -> MappingScheme {
        MappingScheme::Coda
    }

    fn start_episode(&mut self) {
        self.counts.clear();
        self.next_eval_at = CODA_WINDOW;
    }

    fn observe_dispatch(
        &mut self,
        dest: (Pid, VPage),
        sources: &[(Pid, VPage)],
        compute_cube: CubeId,
    ) {
        self.bump(dest, compute_cube);
        for &s in sources {
            self.bump(s, compute_cube);
        }
    }

    fn tick(&mut self, now: Cycle, ctx: &mut PolicyCtx<'_>) -> anyhow::Result<Vec<MappingAction>> {
        if now < self.next_eval_at || ctx.completed >= ctx.total_ops {
            return Ok(Vec::new());
        }
        self.next_eval_at = now + CODA_WINDOW;
        Ok(self.evaluate(ctx.mmu))
    }

    fn next_event(&self, now: Cycle, completed: u64, total_ops: u64) -> Option<Cycle> {
        (completed < total_ops).then(|| self.next_eval_at.max(now))
    }
}

// ---------------------------------------------------------------------
// Oracle — perfect-knowledge static placement.
// ---------------------------------------------------------------------

/// The upper-bound reference column: a two-pass policy that dry-runs
/// the op stream before the simulation starts and replays with the
/// best static page→cube assignment it found, applied through
/// first-touch placement (like TOM's hash, but per-page and with
/// perfect knowledge). The dry run is a pure function of the trace —
/// it touches no simulator state, so it is side-effect-free on
/// `RunStats` by construction.
pub struct OracleProfile {
    assignment: HashMap<(Pid, VPage), CubeId>,
}

impl OracleProfile {
    pub fn new(cfg: &SystemConfig, ops: &[NmpOp]) -> Self {
        Self { assignment: profile_assignment(ops, cfg.num_cubes()) }
    }

    /// Build the policy from a dry run performed elsewhere — the replay
    /// path (`aimm run --trace`) streams the trace file through an
    /// [`OracleProfiler`] and hands the finished assignment in here,
    /// never holding the op vector.
    pub fn from_assignment(assignment: HashMap<(Pid, VPage), CubeId>) -> Self {
        Self { assignment }
    }

    /// Pages the dry run assigned (diagnostics/tests).
    pub fn assignment(&self) -> &HashMap<(Pid, VPage), CubeId> {
        &self.assignment
    }
}

impl MappingPolicy for OracleProfile {
    fn scheme(&self) -> MappingScheme {
        MappingScheme::Oracle
    }

    fn first_touch_cube(&self, pid: Pid, vpage: VPage) -> Option<CubeId> {
        self.assignment.get(&(pid, vpage)).copied()
    }
}

/// The oracle's dry run: derive a static page→cube assignment from the
/// full op stream. Two deterministic passes:
///
/// 1. **Destination pages** (where BNMP-style scheduling computes) are
///    assigned greedily, hottest first (ties: lowest `(pid, page)`), to
///    the least-loaded cube (ties: lowest cube id) — balancing compute
///    across the network.
/// 2. **Pure source pages** join the cube that computes the most of
///    their consuming ops (ties: lowest cube id) — perfect co-location,
///    so operand fetches become zero-hop.
///
/// Pages serving both roles keep their destination assignment (compute
/// happens there). Pure function of `(ops, n_cubes)`: no RNG, no
/// simulator state, same input → same map. Thin wrapper over the
/// streaming [`OracleProfiler`], which the replay path feeds one op at
/// a time.
pub fn profile_assignment(ops: &[NmpOp], n_cubes: usize) -> HashMap<(Pid, VPage), CubeId> {
    let mut profiler = OracleProfiler::new(n_cubes);
    for op in ops {
        profiler.observe(op);
    }
    profiler.finish()
}

/// The oracle dry run as a streaming accumulator: [`observe`] each op
/// as it goes by (memory is bounded by distinct page *pairs*, never the
/// op count — a trace file streams through without being slurped), then
/// [`finish`] derives the same assignment [`profile_assignment`]
/// computes from the whole vector.
///
/// Equivalence argument: pass 1 consumes only per-destination-page op
/// counts (u64 sums — order-invariant). Pass 2's vote for a source key
/// is `count(src, dest) summed into votes[assignment[dest]]`; grouping
/// the counts per `(src, dest)` pair first and folding at finish time
/// sums the same u64s, so the vote vectors — and the strict-`>` argmax
/// over them — are identical.
///
/// [`observe`]: OracleProfiler::observe
/// [`finish`]: OracleProfiler::finish
pub struct OracleProfiler {
    n_cubes: usize,
    /// Per-destination-page op counts (pass 1 input).
    dest_ops: HashMap<(Pid, VPage), u64>,
    /// Per touched page: counts keyed by the destination page of the
    /// consuming op (pass 2 input, folded through pass 1's assignment
    /// at finish time).
    src_pairs: HashMap<(Pid, VPage), HashMap<(Pid, VPage), u64>>,
}

impl OracleProfiler {
    pub fn new(n_cubes: usize) -> Self {
        Self { n_cubes, dest_ops: HashMap::new(), src_pairs: HashMap::new() }
    }

    /// Accumulate one op.
    pub fn observe(&mut self, op: &NmpOp) {
        let dest_key = (op.pid, op.dest_vpage());
        *self.dest_ops.entry(dest_key).or_insert(0) += 1;
        let (pages, n) = op.vpages_arr();
        for &v in &pages[..n] {
            *self
                .src_pairs
                .entry((op.pid, v))
                .or_default()
                .entry(dest_key)
                .or_insert(0) += 1;
        }
    }

    /// Close out: the two deterministic passes of the dry run.
    pub fn finish(self) -> HashMap<(Pid, VPage), CubeId> {
        // Pass 1: destination pages, hottest first (ties: lowest key),
        // to the least-loaded cube (ties: lowest cube id).
        let mut order: Vec<((Pid, VPage), u64)> = self.dest_ops.into_iter().collect();
        order.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let mut load = vec![0u64; self.n_cubes];
        let mut assignment: HashMap<(Pid, VPage), CubeId> = HashMap::with_capacity(order.len());
        for (key, n) in order {
            let mut best = 0usize;
            for (c, &l) in load.iter().enumerate().skip(1) {
                if l < load[best] {
                    best = c;
                }
            }
            load[best] += n;
            assignment.insert(key, best);
        }
        // Pass 2: pure source pages follow their consumers. Each
        // per-key argmax writes an independent slot and the vote sums
        // are commutative u64 adds, so the resulting map's content is
        // invariant to visit order of either map.
        // detlint: allow(hash-iter) — order-invariant per-key inserts
        for (key, per_dest) in self.src_pairs {
            if assignment.contains_key(&key) {
                continue; // destination pages stay where pass 1 put them
            }
            let mut votes = vec![0u64; self.n_cubes];
            // detlint: allow(hash-iter) — commutative u64 vote sums
            for (dest_key, count) in per_dest {
                votes[assignment[&dest_key]] += count;
            }
            let mut best = 0usize;
            for (c, &v) in votes.iter().enumerate().skip(1) {
                if v > votes[best] {
                    best = c; // strict >: ties break to the lowest cube
                }
            }
            assignment.insert(key, best);
        }
        assignment
    }
}

// ---------------------------------------------------------------------
// AnyPolicy — the enum carrier.
// ---------------------------------------------------------------------

/// The policy a [`SystemConfig`] describes, carried as an enum so every
/// trait call dispatches by direct `match` (no `&dyn` vtable on the
/// per-dispatch hot path, mirroring `AnyTopology`). The AIMM variant is
/// boxed: the agent embeds its replay/config/stats inline (~0.7 KB),
/// which would otherwise bloat every carrier of the enum.
pub enum AnyPolicy {
    Baseline(BaselinePolicy),
    Tom(TomPolicy),
    Aimm(Box<AimmPolicy>),
    AimmMc(Box<AimmMultiPolicy>),
    Coda(CodaGreedy),
    Oracle(OracleProfile),
}

/// One `match` over the six carriers — the whole dispatch mechanism.
macro_rules! dispatch {
    ($self:expr, $p:ident => $body:expr) => {
        match $self {
            AnyPolicy::Baseline($p) => $body,
            AnyPolicy::Tom($p) => $body,
            AnyPolicy::Aimm($p) => $body,
            AnyPolicy::AimmMc($p) => $body,
            AnyPolicy::Coda($p) => $body,
            AnyPolicy::Oracle($p) => $body,
        }
    };
}

impl AnyPolicy {
    /// The policy `cfg.mapping` selects. `ops` feeds the oracle's dry
    /// run (ignored by every other policy); `agent` drives AIMM — an
    /// AIMM config without an agent runs the no-op baseline policy,
    /// exactly as the pre-trait `System` ran agent-less when handed
    /// `None`.
    ///
    /// # Panics
    ///
    /// Handing an agent to a non-AIMM mapping panics: silently dropping
    /// a trained network would be the worse failure, and no policy
    /// other than AIMM can drive one.
    pub fn new(cfg: &SystemConfig, ops: &[NmpOp], agent: Option<AimmAgent>) -> AnyPolicy {
        assert!(
            agent.is_none() || cfg.mapping.uses_agent(),
            "an agent only drives the AIMM policy (mapping is {})",
            cfg.mapping
        );
        match cfg.mapping {
            MappingScheme::Baseline => AnyPolicy::baseline(),
            MappingScheme::Tom => AnyPolicy::Tom(TomPolicy::new(cfg)),
            MappingScheme::Aimm => match agent {
                Some(agent) => AnyPolicy::Aimm(Box::new(AimmPolicy::new(cfg, agent))),
                None => AnyPolicy::baseline(),
            },
            // The per-MC pool is self-seeding from the config — it never
            // rides the single-agent carryover slot (`uses_agent()` is
            // false for AIMM-MC; cross-episode carry moves the whole
            // policy, not one agent).
            MappingScheme::AimmMc => AnyPolicy::AimmMc(Box::new(AimmMultiPolicy::new(cfg))),
            MappingScheme::Coda => AnyPolicy::Coda(CodaGreedy::new(cfg)),
            MappingScheme::Oracle => AnyPolicy::Oracle(OracleProfile::new(cfg, ops)),
        }
    }

    /// The no-op policy (placeholder after [`AnyPolicy::take_agent`],
    /// test scaffolding).
    pub fn baseline() -> AnyPolicy {
        AnyPolicy::Baseline(BaselinePolicy)
    }

    /// Episode-boundary carryover: move the learning agent out (the
    /// policy degenerates to baseline), or `None` for agent-less
    /// policies. Replaces the pre-trait AIMM-only `System::take_agent`
    /// plumbing.
    pub fn take_agent(&mut self) -> Option<AimmAgent> {
        match std::mem::replace(self, AnyPolicy::baseline()) {
            AnyPolicy::Aimm(p) => Some(p.into_agent()),
            other => {
                *self = other;
                None
            }
        }
    }

    /// Capture a v2 [`CheckpointBundle`] — the checkpoint format that
    /// fits both learning shapes. AIMM wraps its single agent, AIMM-MC
    /// bundles the whole MC-ordered pool; everything else refuses by
    /// name (the trait `snapshot` contract, lifted to bundles).
    pub fn checkpoint_bundle(&self, warm_start: WarmStart) -> anyhow::Result<CheckpointBundle> {
        match self {
            AnyPolicy::Aimm(p) => Ok(CheckpointBundle::single(warm_start, p.snapshot()?)),
            AnyPolicy::AimmMc(p) => {
                Ok(CheckpointBundle { warm_start, agents: p.snapshot_bundle()? })
            }
            other => anyhow::bail!(
                "the {} policy is not checkpointable (only AIMM carries learned state)",
                other.scheme().name()
            ),
        }
    }

    /// Restore learned state from a v2 bundle. The caller has already
    /// run [`CheckpointBundle::ensure_resumable`] against its requested
    /// shape; this performs the actual agent rebuilds (and re-checks the
    /// count against the live pool as a backstop).
    pub fn restore_from_bundle(&mut self, bundle: &CheckpointBundle) -> anyhow::Result<()> {
        match self {
            AnyPolicy::Aimm(p) => {
                anyhow::ensure!(
                    bundle.agents.len() == 1,
                    "checkpoint drift: per-MC agent count is {} but this run drives 1 \
                     agent(s) — resume refused",
                    bundle.agents.len()
                );
                p.restore(&bundle.agents[0])
            }
            AnyPolicy::AimmMc(p) => p.restore_bundle(&bundle.agents),
            other => anyhow::bail!(
                "the {} policy is not checkpointable (only AIMM carries learned state)",
                other.scheme().name()
            ),
        }
    }
}

impl MappingPolicy for AnyPolicy {
    fn scheme(&self) -> MappingScheme {
        dispatch!(self, p => p.scheme())
    }

    fn start_episode(&mut self) {
        dispatch!(self, p => p.start_episode())
    }

    fn first_touch_cube(&self, pid: Pid, vpage: VPage) -> Option<CubeId> {
        dispatch!(self, p => p.first_touch_cube(pid, vpage))
    }

    fn observe_dispatch(
        &mut self,
        dest: (Pid, VPage),
        sources: &[(Pid, VPage)],
        compute_cube: CubeId,
    ) {
        dispatch!(self, p => p.observe_dispatch(dest, sources, compute_cube))
    }

    fn tick(&mut self, now: Cycle, ctx: &mut PolicyCtx<'_>) -> anyhow::Result<Vec<MappingAction>> {
        dispatch!(self, p => p.tick(now, ctx))
    }

    fn next_event(&self, now: Cycle, completed: u64, total_ops: u64) -> Option<Cycle> {
        dispatch!(self, p => p.next_event(now, completed, total_ops))
    }

    fn finish(&mut self, ctx: &mut PolicyCtx<'_>) {
        dispatch!(self, p => p.finish(ctx))
    }

    fn agent(&self) -> Option<&AimmAgent> {
        dispatch!(self, p => p.agent())
    }

    fn snapshot(&self) -> anyhow::Result<AgentCheckpoint> {
        dispatch!(self, p => p.snapshot())
    }

    fn restore(&mut self, ck: &AgentCheckpoint) -> anyhow::Result<()> {
        dispatch!(self, p => p.restore(ck))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nmp::OpKind;

    fn ctx_parts() -> (Mmu, ComputeRemapTable, Mesh) {
        let cfg = SystemConfig::default();
        let mut mmu = Mmu::new(&cfg);
        mmu.create_process(1);
        (mmu, ComputeRemapTable::new(64), Mesh::new(&cfg))
    }

    fn op(pid: Pid, dest_page: u64, src_page: u64) -> NmpOp {
        NmpOp { pid, kind: OpKind::Add, dest: dest_page << 12, src1: src_page << 12, src2: None }
    }

    #[test]
    fn baseline_policy_is_inert() {
        let (mut mmu, mut remap, mesh) = ctx_parts();
        let mut p = BaselinePolicy;
        assert_eq!(p.scheme(), MappingScheme::Baseline);
        assert_eq!(p.first_touch_cube(1, 7), None);
        assert_eq!(p.next_event(5, 0, 10), None);
        let mut ctx = PolicyCtx {
            mcs: &mut [],
            cubes: &[],
            mmu: &mut mmu,
            remap_table: &mut remap,
            mesh: &mesh,
            completed: 0,
            total_ops: 10,
        };
        assert!(p.tick(100, &mut ctx).unwrap().is_empty());
    }

    #[test]
    fn tom_policy_mirrors_the_mapper() {
        let cfg = SystemConfig::default();
        let p = TomPolicy::new(&cfg);
        for v in 0..64u64 {
            assert_eq!(p.first_touch_cube(1, v), Some(p.mapper().target_cube(1, v)));
        }
        // The event hook is the mapper's phase boundary, clamped to now.
        assert_eq!(p.next_event(0, 0, 10), Some(p.mapper().next_boundary()));
        let far = p.mapper().next_boundary() + 5;
        assert_eq!(p.next_event(far, 0, 10), Some(far));
    }

    #[test]
    fn tom_policy_resets_per_episode() {
        let cfg = SystemConfig::default();
        let mut p = TomPolicy::new(&cfg);
        p.observe_dispatch((1, 3), &[(1, 99)], 0);
        let boundary = p.mapper().next_boundary();
        let (mut mmu, mut remap, mesh) = ctx_parts();
        let mut ctx = PolicyCtx {
            mcs: &mut [],
            cubes: &[],
            mmu: &mut mmu,
            remap_table: &mut remap,
            mesh: &mesh,
            completed: 0,
            total_ops: 10,
        };
        p.tick(boundary, &mut ctx).unwrap();
        assert_eq!(p.mapper().adoptions, 1);
        // start_episode re-profiles from scratch — the fresh mapper the
        // pre-trait System built per run.
        p.start_episode();
        assert_eq!(p.mapper().adoptions, 0);
        assert_eq!(p.mapper().next_boundary(), boundary);
    }

    /// The hysteresis contract: a 50/50-contended page never migrates
    /// (no ping-pong), a dominated page migrates exactly once, and a
    /// page below the op floor is ignored.
    #[test]
    fn coda_hysteresis_blocks_contended_pages() {
        let cfg = SystemConfig::default();
        let (mut mmu, mut remap, mesh) = ctx_parts();
        mmu.map_page(1, 10, 0).unwrap();
        mmu.map_page(1, 11, 0).unwrap();
        mmu.map_page(1, 12, 0).unwrap();
        let mut coda = CodaGreedy::new(&cfg);
        // Page 10: perfect 50/50 split between cubes 3 and 5.
        for _ in 0..40 {
            coda.observe_dispatch((1, 10), &[], 3);
            coda.observe_dispatch((1, 10), &[], 5);
        }
        // Page 11: every op computes on cube 7.
        for _ in 0..40 {
            coda.observe_dispatch((1, 11), &[], 7);
        }
        // Page 12: dominated, but below CODA_MIN_OPS.
        for _ in 0..3 {
            coda.observe_dispatch((1, 12), &[], 7);
        }
        let mut ctx = PolicyCtx {
            mcs: &mut [],
            cubes: &[],
            mmu: &mut mmu,
            remap_table: &mut remap,
            mesh: &mesh,
            completed: 0,
            total_ops: 1000,
        };
        let actions = coda.tick(CODA_WINDOW, &mut ctx).unwrap();
        assert_eq!(
            actions,
            vec![MappingAction::MigratePage { pid: 1, vpage: 11, to_cube: 7 }],
            "only the dominated, hot-enough page migrates"
        );
    }

    #[test]
    fn coda_does_not_ping_pong_a_migrated_page() {
        let cfg = SystemConfig::default();
        let (mut mmu, mut remap, mesh) = ctx_parts();
        mmu.map_page(1, 11, 0).unwrap();
        let mut coda = CodaGreedy::new(&cfg);
        for _ in 0..40 {
            coda.observe_dispatch((1, 11), &[], 7);
        }
        let mut ctx = PolicyCtx {
            mcs: &mut [],
            cubes: &[],
            mmu: &mut mmu,
            remap_table: &mut remap,
            mesh: &mesh,
            completed: 0,
            total_ops: 1000,
        };
        let first = coda.tick(CODA_WINDOW, &mut ctx).unwrap();
        assert_eq!(first.len(), 1);
        assert_eq!(coda.migrations_requested, 1);
        // The migration lands; the same access pattern in the next
        // window keeps favoring cube 7 — where the page now lives.
        assert!(ctx.mmu.force_remap(1, 11, 7));
        for _ in 0..40 {
            coda.observe_dispatch((1, 11), &[], 7);
        }
        let second = coda.tick(2 * CODA_WINDOW, &mut ctx).unwrap();
        assert!(second.is_empty(), "co-located page must not migrate again: {second:?}");
        assert_eq!(coda.migrations_requested, 1, "the lifetime counter must not grow");
    }

    #[test]
    fn coda_window_schedule_matches_polled_gating() {
        let cfg = SystemConfig::default();
        let (mut mmu, mut remap, mesh) = ctx_parts();
        let mut coda = CodaGreedy::new(&cfg);
        // Event hook announces exactly the window boundary while work
        // remains, and goes quiet when the trace has drained.
        assert_eq!(coda.next_event(0, 0, 10), Some(CODA_WINDOW));
        assert_eq!(coda.next_event(0, 10, 10), None);
        let mut ctx = PolicyCtx {
            mcs: &mut [],
            cubes: &[],
            mmu: &mut mmu,
            remap_table: &mut remap,
            mesh: &mesh,
            completed: 0,
            total_ops: 10,
        };
        // Ticks short of the boundary are pure no-ops (skip legality).
        assert!(coda.tick(CODA_WINDOW - 1, &mut ctx).unwrap().is_empty());
        assert_eq!(coda.next_event(CODA_WINDOW - 1, 0, 10), Some(CODA_WINDOW));
        coda.tick(CODA_WINDOW, &mut ctx).unwrap();
        assert_eq!(coda.next_event(CODA_WINDOW, 0, 10), Some(2 * CODA_WINDOW));
    }

    /// The oracle dry run is a pure function: same trace, same map —
    /// and every assigned cube is in range.
    #[test]
    fn oracle_profile_is_deterministic() {
        let ops: Vec<NmpOp> = (0..200).map(|i| op(1, i % 8, 100 + i % 16)).collect();
        let a = profile_assignment(&ops, 16);
        let b = profile_assignment(&ops, 16);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        // detlint: allow(hash-iter) — test-only range check; asserts are per-entry
        for (&(_, _), &cube) in &a {
            assert!(cube < 16);
        }
        // Every trace page got an assignment (first touch always hits).
        for o in &ops {
            for v in o.vpages() {
                assert!(a.contains_key(&(o.pid, v)), "page {v} unassigned");
            }
        }
    }

    #[test]
    fn oracle_colocates_sources_with_their_consumers() {
        // Every op writes page 5 and reads pages 50/51: perfect
        // knowledge puts the sources on page 5's cube — zero-hop
        // operand fetches under BNMP.
        let mut ops = Vec::new();
        for i in 0..60 {
            ops.push(op(1, 5, 50 + i % 2));
        }
        let a = profile_assignment(&ops, 16);
        let dest_cube = a[&(1, 5)];
        assert_eq!(a[&(1, 50)], dest_cube);
        assert_eq!(a[&(1, 51)], dest_cube);
        // And the policy serves exactly its profiled assignment via
        // first touch.
        let cfg = SystemConfig::default();
        let p = OracleProfile::new(&cfg, &ops);
        assert_eq!(*p.assignment(), a);
        assert_eq!(p.first_touch_cube(1, 50), Some(dest_cube));
        assert_eq!(p.first_touch_cube(1, 999), None, "unseen pages defer to the allocator");
    }

    #[test]
    fn oracle_balances_destination_load() {
        // 16 equally hot destination pages over 16 cubes: the greedy
        // balancer gives every cube exactly one.
        let mut ops = Vec::new();
        for round in 0..10 {
            for d in 0..16 {
                ops.push(op(1, d, 200 + round));
            }
        }
        let a = profile_assignment(&ops, 16);
        let mut used: Vec<CubeId> = (0..16u64).map(|d| a[&(1, d)]).collect();
        used.sort_unstable();
        used.dedup();
        assert_eq!(used.len(), 16, "every cube hosts exactly one hot dest page");
    }

    // The non-checkpointable snapshot/restore error contract (every
    // non-AIMM policy refuses by name) is pinned at the integration
    // level in rust/tests/continual.rs — the layer the CLI's
    // --checkpoint/--resume plumbing actually exercises.

    #[test]
    fn aimm_policy_snapshot_and_carryover() {
        let mut cfg = SystemConfig::default();
        cfg.mapping = MappingScheme::Aimm;
        let agent = crate::coordinator::fresh_agent(&cfg).unwrap();
        let mut policy = AnyPolicy::new(&cfg, &[], Some(agent));
        assert_eq!(policy.scheme(), MappingScheme::Aimm);
        assert!(policy.agent().is_some());
        // Boundary snapshot works, and restore round-trips through the
        // trait hook.
        let ck = policy.snapshot().unwrap();
        policy.restore(&ck).unwrap();
        assert_eq!(policy.snapshot().unwrap().to_json(), ck.to_json());
        // Carryover: the agent moves out, the husk is baseline.
        let taken = policy.take_agent();
        assert!(taken.is_some());
        assert_eq!(policy.scheme(), MappingScheme::Baseline);
        assert!(policy.take_agent().is_none());
    }

    #[test]
    fn policy_construction_follows_the_scheme() {
        let ops = vec![op(1, 1, 2)];
        for scheme in MappingScheme::ALL {
            let mut cfg = SystemConfig::default();
            cfg.mapping = scheme;
            let agent = scheme
                .uses_agent()
                .then(|| crate::coordinator::fresh_agent(&cfg).unwrap());
            let policy = AnyPolicy::new(&cfg, &ops, agent);
            assert_eq!(policy.scheme(), scheme, "{scheme}");
        }
        // AIMM without an agent degenerates to the no-op baseline,
        // matching the pre-trait System handed `None`.
        let mut cfg = SystemConfig::default();
        cfg.mapping = MappingScheme::Aimm;
        assert_eq!(AnyPolicy::new(&cfg, &ops, None).scheme(), MappingScheme::Baseline);
    }

    #[test]
    fn aimm_mc_policy_carries_one_agent_per_mc() {
        let mut cfg = SystemConfig::default();
        cfg.mapping = MappingScheme::AimmMc;
        let policy = AnyPolicy::new(&cfg, &[], None);
        assert_eq!(policy.scheme(), MappingScheme::AimmMc);
        assert_eq!(policy.agents().len(), cfg.num_mcs());
        // `agent()` exposes the first of the pool for stats plumbing
        // that predates multi-agent.
        assert!(policy.agent().is_some());
        // The pool never rides the single-agent carryover slot.
        let mut policy = policy;
        assert!(policy.take_agent().is_none());
        assert_eq!(policy.scheme(), MappingScheme::AimmMc);
    }

    #[test]
    fn aimm_mc_bundle_roundtrip_is_bit_exact() {
        let mut cfg = SystemConfig::default();
        cfg.mapping = MappingScheme::AimmMc;
        let mut policy = AnyPolicy::new(&cfg, &[], None);
        let bundle = policy.checkpoint_bundle(WarmStart::Oracle).unwrap();
        assert_eq!(bundle.agents.len(), cfg.num_mcs());
        bundle.ensure_resumable(cfg.num_mcs(), WarmStart::Oracle).unwrap();
        policy.restore_from_bundle(&bundle).unwrap();
        assert_eq!(
            policy.checkpoint_bundle(WarmStart::Oracle).unwrap().to_json(),
            bundle.to_json()
        );
        // Drifted pool size refuses at the policy backstop too.
        let mut short = CheckpointBundle {
            warm_start: bundle.warm_start,
            agents: bundle.agents[..1].to_vec(),
        };
        let err = policy.restore_from_bundle(&short).unwrap_err().to_string();
        assert!(err.contains("per-MC agent count"), "{err}");
        short.agents = bundle.agents.clone();
        policy.restore_from_bundle(&short).unwrap();
    }

    #[test]
    fn aimm_mc_refuses_single_document_checkpoints_by_format() {
        let mut cfg = SystemConfig::default();
        cfg.mapping = MappingScheme::AimmMc;
        let mut policy = AnyPolicy::new(&cfg, &[], None);
        let err = policy.snapshot().unwrap_err().to_string();
        assert!(err.contains("aimm-checkpoint-v2"), "{err}");
        // And the single-agent restore hook refuses symmetrically.
        let mut aimm_cfg = SystemConfig::default();
        aimm_cfg.mapping = MappingScheme::Aimm;
        let agent = crate::coordinator::fresh_agent(&aimm_cfg).unwrap();
        let single = AnyPolicy::new(&aimm_cfg, &[], Some(agent)).snapshot().unwrap();
        let err = policy.restore(&single).unwrap_err().to_string();
        assert!(err.contains("aimm-checkpoint-v2"), "{err}");
    }

    #[test]
    fn bundle_checkpointing_refuses_stateless_policies_by_name() {
        let cfg = SystemConfig::default();
        let mut policy = AnyPolicy::baseline();
        let err = policy.checkpoint_bundle(WarmStart::None).unwrap_err().to_string();
        assert!(err.contains("B"), "{err}");
        let mut mc_cfg = cfg.clone();
        mc_cfg.mapping = MappingScheme::AimmMc;
        let donor = AnyPolicy::new(&mc_cfg, &[], None);
        let bundle = donor.checkpoint_bundle(WarmStart::None).unwrap();
        let err = policy.restore_from_bundle(&bundle).unwrap_err().to_string();
        assert!(err.contains("not checkpointable"), "{err}");
    }
}
