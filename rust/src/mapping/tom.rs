//! Transparent Offloading and Mapping (TOM) — the physical-address
//! remapping comparison point (paper §6.3).
//!
//! TOM derives, per epoch, the page→cube hash with the best data
//! co-location: it "profiles a small fraction of the data and derives a
//! mapping with best data co-location, which is used as the mapping
//! scheme for that kernel". Our adaptation profiles the first
//! [`PROFILE_CYCLES`] of each epoch, scoring **all** candidate mappings
//! simultaneously on the observed NMP-op stream (virtual evaluation —
//! nothing moves during profiling), then adopts the scheme with the best
//! co-location that incurs the least data movement for the remainder of
//! the epoch.
//!
//! Because TOM is a *physical-to-DRAM* scheme, adoption is a
//! kernel-boundary re-layout, not runtime migration: the system applies
//! the bulk remap without network traffic (unlike AIMM page migration,
//! which pays for every byte moved — exactly the trade-off §3.1
//! discusses).
//!
//! TOM is topology-agnostic: candidates hash a page number to a cube id
//! mod `n_cubes` and are scored purely on *co-location* (operands on the
//! compute cube, i.e. zero-hop fetches), which is worth the same on
//! mesh, torus and ring. Hop-distance-aware placement is exactly what
//! AIMM adds on top (its far targets route through
//! [`crate::noc::topology::Topology::distant_cube`]).

use std::collections::HashSet;

use crate::config::{CubeId, Pid, VPage};
use crate::sim::Cycle;

/// Number of candidate hash schemes.
pub const TOM_CANDIDATES: usize = 8;
/// Profiling window per epoch ("a small fraction").
pub const PROFILE_CYCLES: u64 = 1500;
/// Steady phase after adoption.
pub const EPOCH_CYCLES: u64 = 30_000;

/// One candidate page→cube hash.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    /// Right-shift (block size in pages = 2^shift).
    pub shift: u32,
    /// XOR-fold shift (0 = none).
    pub fold: u32,
}

impl Candidate {
    pub fn cube(&self, pid: Pid, vpage: VPage, n_cubes: usize) -> CubeId {
        // Distinct per-process rotation so multi-program runs do not
        // trivially collide on cube 0.
        let v = vpage >> self.shift;
        let v = if self.fold > 0 { v ^ (v >> self.fold) } else { v };
        ((v + pid as u64) % n_cubes as u64) as CubeId
    }
}

/// Built-in candidate set: interleavings at several block granularities
/// plus xor-folded variants (covers streaming and strided access).
pub fn candidates() -> [Candidate; TOM_CANDIDATES] {
    [
        Candidate { shift: 0, fold: 0 },
        Candidate { shift: 1, fold: 0 },
        Candidate { shift: 2, fold: 0 },
        Candidate { shift: 3, fold: 0 },
        Candidate { shift: 4, fold: 0 },
        Candidate { shift: 6, fold: 0 },
        Candidate { shift: 0, fold: 4 },
        Candidate { shift: 2, fold: 6 },
    ]
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Observing traffic until `until`; all candidates scored virtually.
    Profiling { until: Cycle },
    /// Best candidate adopted until `until`.
    Steady { until: Cycle },
}

/// What the system must do after a `tick`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TomEvent {
    /// Apply candidate `idx`'s mapping (bulk re-layout).
    Apply(usize),
}

/// The TOM mapper.
pub struct TomMapper {
    cands: [Candidate; TOM_CANDIDATES],
    n_cubes: usize,
    phase: Phase,
    current: usize,
    /// Per-candidate (co-location score, ops observed) for this epoch.
    scores: [(f64, u64); TOM_CANDIDATES],
    /// Pages seen while profiling (for the data-movement tiebreak).
    seen_pages: HashSet<(Pid, VPage)>,
    pub adoptions: u64,
}

impl TomMapper {
    pub fn new(n_cubes: usize) -> Self {
        Self {
            cands: candidates(),
            n_cubes,
            phase: Phase::Profiling { until: PROFILE_CYCLES },
            current: 0,
            scores: [(0.0, 0); TOM_CANDIDATES],
            seen_pages: HashSet::new(),
            adoptions: 0,
        }
    }

    /// The cube the *currently adopted* candidate assigns to a page.
    pub fn target_cube(&self, pid: Pid, vpage: VPage) -> CubeId {
        self.cands[self.current].cube(pid, vpage, self.n_cubes)
    }

    pub fn current_candidate(&self) -> usize {
        self.current
    }

    /// The next phase boundary — the only cycle at which
    /// [`tick`](Self::tick) can change state, and therefore the wakeup
    /// the event engine files for TOM (DESIGN.md §8). Always in the
    /// future at a tick boundary: crossing it immediately re-arms the
    /// phase machine with a later deadline.
    pub fn next_boundary(&self) -> Cycle {
        match self.phase {
            Phase::Profiling { until } | Phase::Steady { until } => until,
        }
    }

    /// Record a dispatched op: score the co-location every candidate
    /// WOULD achieve (virtual profiling — data does not move).
    pub fn record_op(&mut self, dest: (Pid, VPage), sources: &[(Pid, VPage)]) {
        if let Phase::Profiling { .. } = self.phase {
            for (i, cand) in self.cands.iter().enumerate() {
                let dc = cand.cube(dest.0, dest.1, self.n_cubes);
                let co = if sources.is_empty() {
                    1.0
                } else {
                    sources
                        .iter()
                        .filter(|(p, v)| cand.cube(*p, *v, self.n_cubes) == dc)
                        .count() as f64
                        / sources.len() as f64
                };
                self.scores[i].0 += co;
                self.scores[i].1 += 1;
            }
            self.seen_pages.insert(dest);
            for s in sources {
                self.seen_pages.insert(*s);
            }
        }
    }

    /// Advance the phase machine. Returns a mapping change to apply.
    pub fn tick(&mut self, now: Cycle) -> Option<TomEvent> {
        match self.phase {
            Phase::Profiling { until } if now >= until => {
                let best = self.pick_best();
                self.phase = Phase::Steady { until: now + EPOCH_CYCLES };
                self.adoptions += 1;
                let changed = best != self.current;
                self.current = best;
                self.scores = [(0.0, 0); TOM_CANDIDATES];
                self.seen_pages.clear();
                changed.then_some(TomEvent::Apply(best))
            }
            Phase::Steady { until } if now >= until => {
                self.phase = Phase::Profiling { until: now + PROFILE_CYCLES };
                None
            }
            _ => None,
        }
    }

    /// Best co-location; ties broken by least data movement relative to
    /// the currently adopted candidate.
    fn pick_best(&self) -> usize {
        let mut best = self.current;
        let mut best_score = -1.0f64;
        let mut best_movement = u64::MAX;
        for i in 0..TOM_CANDIDATES {
            let (sum, n) = self.scores[i];
            let score = if n == 0 { 0.0 } else { sum / n as f64 };
            let movement = self.movement(i);
            if score > best_score + 1e-12
                || ((score - best_score).abs() <= 1e-12 && movement < best_movement)
            {
                best = i;
                best_score = score;
                best_movement = movement;
            }
        }
        best
    }

    /// Pages that would change cube if candidate `idx` replaced the
    /// currently adopted one.
    fn movement(&self, idx: usize) -> u64 {
        self.seen_pages
            .iter() // detlint: allow(hash-iter) — count() of a filter is order-insensitive
            .filter(|(p, v)| {
                self.cands[idx].cube(*p, *v, self.n_cubes)
                    != self.cands[self.current].cube(*p, *v, self.n_cubes)
            })
            .count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_cubes_in_range() {
        for cand in candidates() {
            for v in 0..1000u64 {
                assert!(cand.cube(1, v, 16) < 16);
            }
        }
    }

    #[test]
    fn adoption_after_profiling_window() {
        let mut tom = TomMapper::new(16);
        let mut now = 0;
        while tom.adoptions == 0 {
            tom.tick(now);
            now += 1;
            assert!(now < 10_000);
        }
        assert!(now >= PROFILE_CYCLES);
    }

    #[test]
    fn aligned_pairs_select_colocating_candidate() {
        // Ops pair page X with page X+64-aligned counterpart in another
        // region whose base is congruent mod 16: candidate shift 0
        // co-locates them; block candidates do not.
        let mut tom = TomMapper::new(16);
        for k in 0..200u64 {
            // dest region base 0, src region base 1024 (64-page aligned,
            // 1024 % 16 == 0): same index → same cube under shift 0.
            tom.record_op((1, k % 48), &[(1, 1024 + k % 48)]);
        }
        let mut now = 0;
        while tom.adoptions == 0 {
            tom.tick(now);
            now += 1;
        }
        let chosen = candidates()[tom.current_candidate()];
        assert_eq!(chosen, candidates()[0], "shift-0 co-locates aligned pairs: {chosen:?}");
    }

    #[test]
    fn next_boundary_is_exactly_where_tick_transitions() {
        let mut tom = TomMapper::new(16);
        assert_eq!(tom.next_boundary(), PROFILE_CYCLES);
        // Ticking anywhere short of the boundary is a no-op…
        assert!(tom.tick(tom.next_boundary() - 1).is_none());
        assert_eq!(tom.adoptions, 0);
        // …and the boundary cycle itself adopts and re-arms.
        tom.tick(PROFILE_CYCLES);
        assert_eq!(tom.adoptions, 1);
        assert_eq!(tom.next_boundary(), PROFILE_CYCLES + EPOCH_CYCLES);
        tom.tick(tom.next_boundary());
        assert_eq!(
            tom.next_boundary(),
            PROFILE_CYCLES + EPOCH_CYCLES + PROFILE_CYCLES,
            "steady phase returns to profiling"
        );
    }

    #[test]
    fn virtual_profiling_does_not_remap_midwindow() {
        let mut tom = TomMapper::new(16);
        // No Apply events before the window closes.
        for now in 0..PROFILE_CYCLES - 1 {
            assert!(tom.tick(now).is_none());
        }
    }

    #[test]
    fn steady_phase_returns_to_profiling() {
        let mut tom = TomMapper::new(16);
        let mut now = 0;
        while tom.adoptions < 2 {
            tom.tick(now);
            now += 1;
            assert!(now < 3 * (EPOCH_CYCLES + PROFILE_CYCLES));
        }
    }
}
