//! Mapping policies — who decides where a page's data lives and where
//! its computation runs. The decision layer is pluggable (the paper
//! frames AIMM as "a plugin module for various NMP systems", §5):
//! every scheme implements the [`policy::MappingPolicy`] trait and the
//! simulator applies whatever [`policy::MappingAction`]s it emits,
//! never asking *which* scheme is configured.
//!
//! The six policies, selectable via `--mapping` / the `mapping` TOML
//! key ([`crate::config::MappingScheme`]):
//!
//! * **B** ([`policy::BaselinePolicy`]) is the *absence* of a scheme:
//!   pages stay where the frame allocator put them, computation follows
//!   the offloading technique's static rule.
//! * **TOM** ([`policy::TomPolicy`] over [`tom::TomMapper`]) profiles
//!   each epoch's NMP-op stream, scores a fixed candidate set of
//!   page→cube hashes on the co-location they *would* have achieved,
//!   and bulk-adopts the winner at the epoch boundary. Pure function of
//!   page numbers — topology-agnostic by construction.
//! * **AIMM** ([`policy::AimmPolicy`]) writes the
//!   [`remap_table::ComputeRemapTable`]: the RL agent's per-page
//!   *computation* placement overrides, resolved at MC dispatch time.
//!   Its data-side counterpart is page migration
//!   ([`crate::migration`]), and its far targets are topology-aware
//!   through [`crate::noc::topology::Topology::distant_cube`].
//! * **AIMM-MC** ([`policy::AimmMultiPolicy`]) is the multi-agent
//!   variant: one lightweight per-MC agent observing only its attached
//!   cubes, coordinated through deterministic round-robin gossip over
//!   the shared replay schema (`crate::agent::multi`).
//! * **CODA** ([`policy::CodaGreedy`]) is the learning-free co-location
//!   competitor (Kim et al.): windowed per-page compute counters and
//!   hysteresis-gated migration toward the dominant compute cube.
//! * **ORACLE** ([`policy::OracleProfile`]) is the perfect-knowledge
//!   upper bound: a side-effect-free dry run over the op stream derives
//!   the best static page→cube assignment, replayed via first-touch
//!   placement.
//!
//! What is deliberately *not* here: V→P translation ([`crate::mmu`])
//! and frame allocation ([`crate::alloc`]). A mapping policy only
//! redirects — the MMU stays the single source of truth for where a
//! page physically is, and the `System` owns every actuator the
//! policy's actions drive.

pub mod policy;
pub mod remap_table;
pub mod tom;

pub use policy::{
    profile_assignment, AimmMultiPolicy, AimmPolicy, AnyPolicy, BaselinePolicy, CodaGreedy,
    MappingAction, MappingPolicy, OracleProfile, OracleProfiler, PolicyCtx, TomPolicy,
};
pub use remap_table::ComputeRemapTable;
pub use tom::{TomEvent, TomMapper, TOM_CANDIDATES};
