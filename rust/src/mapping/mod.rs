//! Mapping schemes — who decides where a page's data lives and where its
//! computation runs. Together with the placement policies in
//! [`crate::alloc`], these implement the "B / TOM / AIMM" columns of the
//! paper's evaluation (§6.3):
//!
//! * **B** (baseline) is the *absence* of a scheme: pages stay where the
//!   frame allocator put them, computation follows the offloading
//!   technique's static rule.
//! * **TOM** ([`tom::TomMapper`]) profiles each epoch's NMP-op stream,
//!   scores a fixed candidate set of page→cube hashes on the co-location
//!   they *would* have achieved, and bulk-adopts the winner at the epoch
//!   boundary. It is a pure function of page numbers — cube ids come out
//!   of a hash mod `num_cubes` — so it is topology-agnostic by
//!   construction: it optimizes co-location (zero-hop operand fetches),
//!   not hop distance, on mesh, torus and ring alike.
//! * **AIMM** writes the [`remap_table::ComputeRemapTable`]: the RL
//!   agent's per-page *computation* placement overrides, resolved at MC
//!   dispatch time. Its data-side counterpart is page migration
//!   ([`crate::migration`]), and its far targets are topology-aware
//!   through [`crate::noc::topology::Topology::distant_cube`].
//!
//! What is deliberately *not* here: V→P translation ([`crate::mmu`]) and
//! frame allocation ([`crate::alloc`]). A mapping scheme only redirects —
//! the MMU stays the single source of truth for where a page physically
//! is.

pub mod remap_table;
pub mod tom;

pub use remap_table::ComputeRemapTable;
pub use tom::{TomEvent, TomMapper, TOM_CANDIDATES};
