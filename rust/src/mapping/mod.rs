//! Mapping schemes: the TOM physical-address remapper and the AIMM
//! compute-remap table (§5.3, §6.3). Together with the placement policies
//! in [`crate::alloc`], these implement the "B / TOM / AIMM" columns of
//! the paper's evaluation.

pub mod remap_table;
pub mod tom;

pub use remap_table::ComputeRemapTable;
pub use tom::{TomEvent, TomMapper, TOM_CANDIDATES};
