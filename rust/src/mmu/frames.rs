//! Per-cube physical frame pools. A pool hands out frame indices in
//! ascending order first (fresh memory), then recycles freed frames LIFO
//! (hot reuse), and never double-allocates — property-tested below.

/// Free-frame pool for one cube.
#[derive(Debug)]
pub struct FramePool {
    capacity: usize,
    next_fresh: u64,
    freelist: Vec<u64>,
    allocated: usize,
}

impl FramePool {
    pub fn new(capacity: usize) -> Self {
        Self { capacity, next_fresh: 0, freelist: Vec::new(), allocated: 0 }
    }

    pub fn alloc(&mut self) -> Option<u64> {
        let frame = if let Some(f) = self.freelist.pop() {
            f
        } else if (self.next_fresh as usize) < self.capacity {
            let f = self.next_fresh;
            self.next_fresh += 1;
            f
        } else {
            return None;
        };
        self.allocated += 1;
        Some(frame)
    }

    pub fn free(&mut self, frame: u64) {
        debug_assert!(frame < self.next_fresh, "free of never-allocated frame");
        debug_assert!(!self.freelist.contains(&frame), "double free of frame {frame}");
        self.allocated -= 1;
        self.freelist.push(frame);
    }

    pub fn free_count(&self) -> usize {
        self.capacity - self.allocated
    }

    pub fn allocated(&self) -> usize {
        self.allocated
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;
    use std::collections::HashSet;

    #[test]
    fn alloc_unique_until_exhausted() {
        let mut p = FramePool::new(16);
        let mut seen = HashSet::new();
        for _ in 0..16 {
            assert!(seen.insert(p.alloc().unwrap()));
        }
        assert_eq!(p.alloc(), None);
    }

    #[test]
    fn recycles_freed() {
        let mut p = FramePool::new(2);
        let a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        assert_eq!(p.alloc(), None);
        p.free(a);
        assert_eq!(p.alloc(), Some(a));
    }

    /// Property: under random alloc/free interleavings, live frames are
    /// always unique and counts are consistent.
    #[test]
    fn prop_no_double_allocation() {
        let mut rng = Rng::new(2024);
        for trial in 0..50 {
            let cap = 1 + rng.index(64);
            let mut p = FramePool::new(cap);
            let mut live: Vec<u64> = Vec::new();
            for _ in 0..500 {
                if rng.chance(0.6) {
                    if let Some(f) = p.alloc() {
                        assert!(!live.contains(&f), "trial {trial}: frame {f} double-allocated");
                        live.push(f);
                    } else {
                        assert_eq!(live.len(), cap);
                    }
                } else if !live.is_empty() {
                    let idx = rng.index(live.len());
                    p.free(live.swap_remove(idx));
                }
                assert_eq!(p.allocated(), live.len());
                assert_eq!(p.free_count(), cap - live.len());
            }
        }
    }
}
