//! A real 4-level radix page table (9 bits per level, 4 KiB pages), as in
//! x86-64 / Table 1's "4-level page table". The walk cost model charges
//! [`WALK_LEVELS`] sequential accesses on a TLB miss.

use crate::config::{CubeId, Pid, VPage};

/// Levels in the radix tree.
pub const WALK_LEVELS: usize = 4;
/// Radix bits per level.
const BITS: u32 = 9;
const FANOUT: usize = 1 << BITS;

/// A physical page location: cube + frame index within the cube.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PhysLoc {
    pub cube: CubeId,
    pub frame: u64,
}

/// Leaf level: frame entries.
struct L1 {
    entries: Vec<Option<PhysLoc>>,
}

impl L1 {
    fn new() -> Self {
        Self { entries: vec![None; FANOUT] }
    }
}

/// Interior level: children.
struct Interior<T> {
    children: Vec<Option<Box<T>>>,
}

impl<T> Interior<T> {
    fn new() -> Self {
        Self { children: (0..FANOUT).map(|_| None).collect() }
    }
}

type L2 = Interior<L1>;
type L3 = Interior<L2>;
type L4 = Interior<L3>;

/// One process's address space: the 4-level tree.
pub struct AddressSpace {
    pub pid: Pid,
    root: L4,
    mapped: u64,
}

fn idx(vpage: VPage, level: u32) -> usize {
    ((vpage >> (BITS * level)) & (FANOUT as u64 - 1)) as usize
}

impl AddressSpace {
    pub fn new(pid: Pid) -> Self {
        Self { pid, root: L4::new(), mapped: 0 }
    }

    pub fn mapped_pages(&self) -> u64 {
        self.mapped
    }

    /// Walk the tree; `None` on any non-present level.
    pub fn translate(&self, vpage: VPage) -> Option<PhysLoc> {
        let l3 = self.root.children[idx(vpage, 3)].as_ref()?;
        let l2 = l3.children[idx(vpage, 2)].as_ref()?;
        let l1 = l2.children[idx(vpage, 1)].as_ref()?;
        l1.entries[idx(vpage, 0)]
    }

    /// Install a mapping, allocating interior nodes on demand.
    pub fn map(&mut self, vpage: VPage, loc: PhysLoc) {
        let l3 = self.root.children[idx(vpage, 3)].get_or_insert_with(|| Box::new(L3::new()));
        let l2 = l3.children[idx(vpage, 2)].get_or_insert_with(|| Box::new(L2::new()));
        let l1 = l2.children[idx(vpage, 1)].get_or_insert_with(|| Box::new(L1::new()));
        let slot = &mut l1.entries[idx(vpage, 0)];
        if slot.is_none() {
            self.mapped += 1;
        }
        *slot = Some(loc);
    }

    /// Replace an existing mapping (page remap / migration commit).
    pub fn remap(&mut self, vpage: VPage, loc: PhysLoc) {
        debug_assert!(self.translate(vpage).is_some(), "remap of unmapped page");
        self.map(vpage, loc);
    }

    /// Remove a mapping; returns the old location.
    pub fn unmap(&mut self, vpage: VPage) -> Option<PhysLoc> {
        let l3 = self.root.children[idx(vpage, 3)].as_mut()?;
        let l2 = l3.children[idx(vpage, 2)].as_mut()?;
        let l1 = l2.children[idx(vpage, 1)].as_mut()?;
        let old = l1.entries[idx(vpage, 0)].take();
        if old.is_some() {
            self.mapped -= 1;
        }
        old
    }

    /// Enumerate all mappings (walks the whole tree; analysis only).
    pub fn mappings(&self) -> Vec<(VPage, PhysLoc)> {
        let mut out = Vec::with_capacity(self.mapped as usize);
        for (i3, l3) in self.root.children.iter().enumerate() {
            let Some(l3) = l3 else { continue };
            for (i2, l2) in l3.children.iter().enumerate() {
                let Some(l2) = l2 else { continue };
                for (i1, l1) in l2.children.iter().enumerate() {
                    let Some(l1) = l1 else { continue };
                    for (i0, e) in l1.entries.iter().enumerate() {
                        if let Some(loc) = e {
                            let vpage = ((i3 as u64) << (BITS * 3))
                                | ((i2 as u64) << (BITS * 2))
                                | ((i1 as u64) << BITS)
                                | i0 as u64;
                            out.push((vpage, *loc));
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_tree_translate() {
        let mut a = AddressSpace::new(1);
        assert_eq!(a.translate(0), None);
        a.map(0, PhysLoc { cube: 1, frame: 10 });
        // A vpage sharing no interior nodes (differs in the top level).
        a.map(1 << 27, PhysLoc { cube: 2, frame: 20 });
        assert_eq!(a.translate(0).unwrap().frame, 10);
        assert_eq!(a.translate(1 << 27).unwrap().cube, 2);
        assert_eq!(a.translate(12345), None);
        assert_eq!(a.mapped_pages(), 2);
    }

    #[test]
    fn remap_replaces() {
        let mut a = AddressSpace::new(1);
        a.map(99, PhysLoc { cube: 0, frame: 1 });
        a.remap(99, PhysLoc { cube: 5, frame: 7 });
        assert_eq!(a.translate(99), Some(PhysLoc { cube: 5, frame: 7 }));
        assert_eq!(a.mapped_pages(), 1);
    }

    #[test]
    fn unmap_removes() {
        let mut a = AddressSpace::new(1);
        a.map(4, PhysLoc { cube: 0, frame: 0 });
        assert_eq!(a.unmap(4), Some(PhysLoc { cube: 0, frame: 0 }));
        assert_eq!(a.translate(4), None);
        assert_eq!(a.unmap(4), None);
        assert_eq!(a.mapped_pages(), 0);
    }

    #[test]
    fn mappings_enumerate_all() {
        let mut a = AddressSpace::new(1);
        let pages: Vec<VPage> = vec![0, 1, 511, 512, 1 << 18, (1 << 27) + 3];
        for (i, &p) in pages.iter().enumerate() {
            a.map(p, PhysLoc { cube: i % 4, frame: i as u64 });
        }
        let mut got: Vec<VPage> = a.mappings().into_iter().map(|(v, _)| v).collect();
        got.sort_unstable();
        let mut want = pages.clone();
        want.sort_unstable();
        assert_eq!(got, want);
    }
}
