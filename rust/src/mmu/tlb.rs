//! Small fully-associative TLB with LRU replacement, one per MC. A miss
//! charges the 4-level walk latency to the issuing memory controller.

use crate::config::{Pid, VPage};

use super::page_table::PhysLoc;

#[derive(Debug)]
struct TlbEntry {
    pid: Pid,
    vpage: VPage,
    loc: PhysLoc,
    /// LRU stamp.
    used: u64,
}

/// Fully-associative, LRU-replaced TLB.
#[derive(Debug)]
pub struct Tlb {
    entries: Vec<TlbEntry>,
    capacity: usize,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Tlb {
    pub fn new(capacity: usize) -> Self {
        Self { entries: Vec::with_capacity(capacity), capacity, clock: 0, hits: 0, misses: 0 }
    }

    pub fn lookup(&mut self, pid: Pid, vpage: VPage) -> Option<PhysLoc> {
        self.clock += 1;
        if let Some(e) = self.entries.iter_mut().find(|e| e.pid == pid && e.vpage == vpage) {
            e.used = self.clock;
            self.hits += 1;
            Some(e.loc)
        } else {
            self.misses += 1;
            None
        }
    }

    pub fn insert(&mut self, pid: Pid, vpage: VPage, loc: PhysLoc) {
        self.clock += 1;
        if let Some(e) = self.entries.iter_mut().find(|e| e.pid == pid && e.vpage == vpage) {
            e.loc = loc;
            e.used = self.clock;
            return;
        }
        if self.entries.len() >= self.capacity {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.used)
                .map(|(i, _)| i)
                .unwrap();
            self.entries.swap_remove(lru);
        }
        self.entries.push(TlbEntry { pid, vpage, loc, used: self.clock });
    }

    /// Invalidate a translation (page remapped by migration).
    pub fn invalidate(&mut self, pid: Pid, vpage: VPage) {
        self.entries.retain(|e| !(e.pid == pid && e.vpage == vpage));
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loc(cube: usize) -> PhysLoc {
        PhysLoc { cube, frame: 0 }
    }

    #[test]
    fn hit_after_insert() {
        let mut t = Tlb::new(4);
        assert_eq!(t.lookup(1, 10), None);
        t.insert(1, 10, loc(3));
        assert_eq!(t.lookup(1, 10), Some(loc(3)));
        assert_eq!(t.hits, 1);
        assert_eq!(t.misses, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut t = Tlb::new(2);
        t.insert(1, 1, loc(0));
        t.insert(1, 2, loc(1));
        t.lookup(1, 1); // touch 1 → 2 becomes LRU
        t.insert(1, 3, loc(2));
        assert_eq!(t.lookup(1, 2), None);
        assert!(t.lookup(1, 1).is_some());
        assert!(t.lookup(1, 3).is_some());
    }

    #[test]
    fn invalidate_removes() {
        let mut t = Tlb::new(4);
        t.insert(1, 10, loc(3));
        t.invalidate(1, 10);
        assert_eq!(t.lookup(1, 10), None);
    }

    #[test]
    fn pid_isolation() {
        let mut t = Tlb::new(4);
        t.insert(1, 10, loc(3));
        assert_eq!(t.lookup(2, 10), None);
    }
}
