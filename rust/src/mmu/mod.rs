//! Paging system: 4-level page tables per process, a per-MC TLB, and
//! per-cube physical frame pools (Table 1: MMU with 4-level page table).
//!
//! The virtual→physical mapping is the lever AIMM actuates: page
//! remapping allocates a frame in a new cube, migrates the data, and
//! updates the page table (§5.3). The frame pools bound cube capacity.

pub mod frames;
pub mod page_table;
pub mod tlb;

pub use frames::FramePool;
pub use page_table::{AddressSpace, PhysLoc, WALK_LEVELS};
pub use tlb::Tlb;

use std::collections::HashMap;

use crate::config::{CubeId, Pid, SystemConfig, VPage, PAGE_SIZE};
use crate::cube::PhysAddr;

/// An in-progress remap (allocated new frame, old mapping still live).
#[derive(Debug, Clone, Copy)]
pub struct PendingRemap {
    pub old: PhysLoc,
    pub new: PhysLoc,
}

/// The memory-management unit: address spaces + frame pools.
pub struct Mmu {
    spaces: HashMap<Pid, AddressSpace>,
    pools: Vec<FramePool>,
    pending: HashMap<(Pid, VPage), PendingRemap>,
    /// Cumulative page-table walk levels touched (walk-latency model).
    pub walks: u64,
}

impl Mmu {
    pub fn new(cfg: &SystemConfig) -> Self {
        Self {
            spaces: HashMap::new(),
            pools: (0..cfg.num_cubes()).map(|_| FramePool::new(cfg.frames_per_cube)).collect(),
            pending: HashMap::new(),
            walks: 0,
        }
    }

    pub fn create_process(&mut self, pid: Pid) {
        self.spaces.entry(pid).or_insert_with(|| AddressSpace::new(pid));
    }

    pub fn has_process(&self, pid: Pid) -> bool {
        self.spaces.contains_key(&pid)
    }

    /// Tear down a process: drop its address space and return every
    /// frame it held to the per-cube pools (tenant departure in serve
    /// mode). The caller must first quiesce the process — no pending
    /// remaps and no in-flight migrations for `pid` — or the freed
    /// frames could be handed out while a migration still writes them;
    /// the serve driver gates departure on exactly that condition.
    /// No-op for an unknown pid.
    pub fn release_process(&mut self, pid: Pid) {
        debug_assert!(
            // detlint: allow(hash-iter) — existential any() in a debug assert, order-free
            !self.pending.keys().any(|(p, _)| *p == pid),
            "release_process({pid}) with pending remaps"
        );
        if let Some(space) = self.spaces.remove(&pid) {
            for (_vpage, loc) in space.mappings() {
                self.pools[loc.cube].free(loc.frame);
            }
        }
    }

    /// Is `vpage` currently mapped for `pid`? Unlike
    /// [`Mmu::translate`] this is a pure query: it counts no page walk
    /// and triggers no first-touch. Policy actions check this before
    /// touching a page so stale advice about a departed tenant is
    /// dropped instead of resurrecting its mappings.
    pub fn is_mapped(&self, pid: Pid, vpage: VPage) -> bool {
        match self.spaces.get(&pid) {
            Some(space) => space.translate(vpage).is_some(),
            None => false,
        }
    }

    /// Map `vpage` into a frame of `cube`. Errors if the cube is out of
    /// frames or the page is already mapped.
    pub fn map_page(&mut self, pid: Pid, vpage: VPage, cube: CubeId) -> anyhow::Result<PhysLoc> {
        let space = self
            .spaces
            .get_mut(&pid)
            .ok_or_else(|| anyhow::anyhow!("unknown pid {pid}"))?;
        anyhow::ensure!(space.translate(vpage).is_none(), "vpage {vpage:#x} already mapped");
        let frame = self.pools[cube]
            .alloc()
            .ok_or_else(|| anyhow::anyhow!("cube {cube} out of frames"))?;
        let loc = PhysLoc { cube, frame };
        space.map(vpage, loc);
        Ok(loc)
    }

    /// Translate, counting the 4-level walk (the MC charges TLB-miss
    /// latency based on [`WALK_LEVELS`]).
    pub fn translate(&mut self, pid: Pid, vpage: VPage) -> Option<PhysLoc> {
        let space = self.spaces.get(&pid)?;
        let loc = space.translate(vpage)?;
        self.walks += WALK_LEVELS as u64;
        Some(loc)
    }

    /// Physical address of a virtual byte address (None if unmapped).
    pub fn phys_addr(&mut self, pid: Pid, vaddr: u64) -> Option<PhysAddr> {
        let loc = self.translate(pid, vaddr >> crate::config::PAGE_SHIFT)?;
        Some(PhysAddr::new(loc.cube, loc.frame * PAGE_SIZE + (vaddr & (PAGE_SIZE - 1))))
    }

    /// Begin a page remap: allocate the destination frame, keep the old
    /// mapping live (reads continue during non-blocking migration).
    pub fn begin_remap(
        &mut self,
        pid: Pid,
        vpage: VPage,
        new_cube: CubeId,
    ) -> anyhow::Result<PendingRemap> {
        anyhow::ensure!(
            !self.pending.contains_key(&(pid, vpage)),
            "vpage {vpage:#x} already migrating"
        );
        let space = self
            .spaces
            .get(&pid)
            .ok_or_else(|| anyhow::anyhow!("unknown pid {pid}"))?;
        let old = space
            .translate(vpage)
            .ok_or_else(|| anyhow::anyhow!("vpage {vpage:#x} not mapped"))?;
        anyhow::ensure!(old.cube != new_cube, "remap to the same cube");
        let frame = self.pools[new_cube]
            .alloc()
            .ok_or_else(|| anyhow::anyhow!("cube {new_cube} out of frames"))?;
        let pr = PendingRemap { old, new: PhysLoc { cube: new_cube, frame } };
        self.pending.insert((pid, vpage), pr);
        Ok(pr)
    }

    /// Commit a remap: install the new mapping, release the old frame
    /// (the OS page-table-update interrupt of §5.3).
    pub fn commit_remap(&mut self, pid: Pid, vpage: VPage) -> anyhow::Result<PendingRemap> {
        let pr = self
            .pending
            .remove(&(pid, vpage))
            .ok_or_else(|| anyhow::anyhow!("no pending remap for {vpage:#x}"))?;
        let space = self.spaces.get_mut(&pid).expect("space existed at begin_remap");
        space.remap(vpage, pr.new);
        self.pools[pr.old.cube].free(pr.old.frame);
        Ok(pr)
    }

    /// Abort a remap (e.g. migration queue overflow downstream).
    pub fn abort_remap(&mut self, pid: Pid, vpage: VPage) {
        if let Some(pr) = self.pending.remove(&(pid, vpage)) {
            self.pools[pr.new.cube].free(pr.new.frame);
        }
    }

    /// Instantly move a page to `new_cube` with no migration traffic —
    /// TOM's kernel-boundary bulk re-layout (see mapping::tom). No-op if
    /// the page already lives there or is mid-migration.
    pub fn force_remap(&mut self, pid: Pid, vpage: VPage, new_cube: CubeId) -> bool {
        if self.pending.contains_key(&(pid, vpage)) {
            return false;
        }
        let Some(space) = self.spaces.get(&pid) else { return false };
        let Some(old) = space.translate(vpage) else { return false };
        if old.cube == new_cube {
            return false;
        }
        let Some(frame) = self.pools[new_cube].alloc() else { return false };
        let space = self.spaces.get_mut(&pid).unwrap();
        space.remap(vpage, PhysLoc { cube: new_cube, frame });
        self.pools[old.cube].free(old.frame);
        true
    }

    pub fn free_frames(&self, cube: CubeId) -> usize {
        self.pools[cube].free_count()
    }

    /// All (vpage, loc) mappings of a process (analysis/debug).
    pub fn mappings(&self, pid: Pid) -> Vec<(VPage, PhysLoc)> {
        self.spaces.get(&pid).map(|s| s.mappings()).unwrap_or_default()
    }

    /// All live process ids.
    pub fn pids(&self) -> Vec<Pid> {
        let mut v: Vec<Pid> = self.spaces.keys().copied().collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mmu() -> Mmu {
        let mut cfg = SystemConfig::default();
        cfg.frames_per_cube = 8;
        let mut m = Mmu::new(&cfg);
        m.create_process(1);
        m
    }

    #[test]
    fn map_translate_roundtrip() {
        let mut m = mmu();
        let loc = m.map_page(1, 0x42, 3).unwrap();
        assert_eq!(loc.cube, 3);
        assert_eq!(m.translate(1, 0x42), Some(loc));
        assert_eq!(m.translate(1, 0x43), None);
    }

    #[test]
    fn double_map_rejected() {
        let mut m = mmu();
        m.map_page(1, 7, 0).unwrap();
        assert!(m.map_page(1, 7, 1).is_err());
    }

    #[test]
    fn frames_exhaust() {
        let mut m = mmu();
        for v in 0..8 {
            m.map_page(1, v, 2).unwrap();
        }
        assert!(m.map_page(1, 99, 2).is_err());
        assert_eq!(m.free_frames(2), 0);
    }

    #[test]
    fn remap_lifecycle() {
        let mut m = mmu();
        let old = m.map_page(1, 5, 0).unwrap();
        let pr = m.begin_remap(1, 5, 4).unwrap();
        assert_eq!(pr.old, old);
        // Old mapping still live during migration.
        assert_eq!(m.translate(1, 5), Some(old));
        let committed = m.commit_remap(1, 5).unwrap();
        assert_eq!(m.translate(1, 5), Some(committed.new));
        // Old frame returned to its pool.
        assert_eq!(m.free_frames(0), 8);
    }

    #[test]
    fn abort_returns_new_frame() {
        let mut m = mmu();
        m.map_page(1, 5, 0).unwrap();
        m.begin_remap(1, 5, 4).unwrap();
        assert_eq!(m.free_frames(4), 7);
        m.abort_remap(1, 5);
        assert_eq!(m.free_frames(4), 8);
    }

    #[test]
    fn phys_addr_offsets() {
        let mut m = mmu();
        let loc = m.map_page(1, 2, 6).unwrap();
        let pa = m.phys_addr(1, 2 * PAGE_SIZE + 100).unwrap();
        assert_eq!(pa.cube, 6);
        assert_eq!(pa.offset, loc.frame * PAGE_SIZE + 100);
    }

    #[test]
    fn double_remap_rejected() {
        let mut m = mmu();
        m.map_page(1, 5, 0).unwrap();
        m.begin_remap(1, 5, 4).unwrap();
        assert!(m.begin_remap(1, 5, 2).is_err());
    }

    #[test]
    fn release_process_returns_every_frame() {
        let mut m = mmu();
        m.map_page(1, 1, 0).unwrap();
        m.map_page(1, 2, 0).unwrap();
        m.map_page(1, 3, 4).unwrap();
        assert_eq!(m.free_frames(0), 6);
        assert_eq!(m.free_frames(4), 7);
        m.release_process(1);
        assert!(!m.has_process(1));
        assert_eq!(m.free_frames(0), 8);
        assert_eq!(m.free_frames(4), 8);
        // Idempotent: releasing an unknown pid is a no-op.
        m.release_process(1);
        m.release_process(99);
        // The frames are genuinely reusable by a successor tenant.
        m.create_process(2);
        for v in 0..8 {
            m.map_page(2, v, 0).unwrap();
        }
    }

    #[test]
    fn is_mapped_is_a_pure_query() {
        let mut m = mmu();
        m.map_page(1, 0x42, 3).unwrap();
        let walks_before = m.walks;
        assert!(m.is_mapped(1, 0x42));
        assert!(!m.is_mapped(1, 0x43));
        assert!(!m.is_mapped(9, 0x42));
        assert_eq!(m.walks, walks_before, "no page walks counted");
        m.release_process(1);
        assert!(!m.is_mapped(1, 0x42));
    }
}
