//! Page-frame placement policies, including the NMP-aware HOARD allocator
//! (paper §6.3).
//!
//! A [`Placement`] policy answers one question for the paging system: *in
//! which cube should this process's new page live?* The MMU then takes a
//! frame from that cube's pool.
//!
//! * [`StripePlacement`] — the default OS behaviour in the baseline
//!   multi-program setup: frames interleave round-robin across all cubes,
//!   so processes' data intermingle ("shared and contended", §7.5.2).
//! * [`HoardAllocator`] — the adapted HOARD: per-process hoards of
//!   superblocks keep each program's pages co-located in its home cubes,
//!   "contributing to the physical proximity of data that is expected to
//!   be accessed together".

pub mod hoard;

pub use hoard::HoardAllocator;

use crate::config::{CubeId, Pid, VPage};

/// Chooses a host cube for a freshly-touched page.
pub trait Placement {
    /// Pick the cube for (pid, vpage). `free_frames[cube]` lets policies
    /// avoid exhausted cubes.
    fn place(&mut self, pid: Pid, vpage: VPage, free_frames: &[usize]) -> CubeId;

    /// Note a page leaving a cube (migration away or process exit).
    fn note_free(&mut self, _pid: Pid, _cube: CubeId) {}

    fn name(&self) -> &'static str;
}

/// Round-robin interleaving across cubes (baseline OS default mapping —
/// footnote 1 of the paper: "default data mapping ... decided by the OS").
#[derive(Debug, Default)]
pub struct StripePlacement {
    next: usize,
}

impl Placement for StripePlacement {
    fn place(&mut self, _pid: Pid, _vpage: VPage, free_frames: &[usize]) -> CubeId {
        let n = free_frames.len();
        for i in 0..n {
            let cube = (self.next + i) % n;
            if free_frames[cube] > 0 {
                self.next = (cube + 1) % n;
                return cube;
            }
        }
        // All full: caller's map_page will surface the error.
        self.next % n
    }

    fn name(&self) -> &'static str {
        "stripe"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripe_round_robins() {
        let mut p = StripePlacement::default();
        let free = vec![10; 4];
        let cubes: Vec<CubeId> = (0..8).map(|v| p.place(1, v, &free)).collect();
        assert_eq!(cubes, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn stripe_skips_full_cubes() {
        let mut p = StripePlacement::default();
        let free = vec![0, 5, 0, 5];
        let cubes: Vec<CubeId> = (0..4).map(|v| p.place(1, v, &free)).collect();
        assert_eq!(cubes, vec![1, 3, 1, 3]);
    }
}
