//! NMP-aware HOARD page-frame allocator (paper §6.3).
//!
//! The original HOARD gives each thread a private heap refilled in bulk
//! ("superblocks") from a global pool, so one thread's objects end up
//! physically adjacent. The paper adapts the heuristic per *program*:
//! each process hoards superblocks of frames from a small set of home
//! cubes, co-locating its pages and preventing cross-process interleaving.
//!
//! Model: a superblock is a budget of `SUPERBLOCK` frames charged against
//! one cube. A process allocates from its current superblock; when that
//! runs dry it grabs a new superblock, preferring its home cubes (chosen
//! at first touch, spread across processes), then neighbouring spill
//! cubes. Freed frames return to the process hoard and are reused before
//! any new superblock is requested; hoards exceeding the release
//! threshold return whole superblocks' worth of budget to the global pool.

use std::collections::HashMap;

use crate::config::{CubeId, Pid, VPage};

use super::Placement;

/// Frames per superblock (4 KiB × 64 = 256 KiB chunks).
pub const SUPERBLOCK: usize = 64;
/// Hoard release threshold, in superblocks of freed frames.
pub const RELEASE_THRESHOLD: usize = 2;

#[derive(Debug)]
struct ProcessHeap {
    /// Home cubes, in preference order.
    homes: Vec<CubeId>,
    /// Remaining frames in the active superblock, and its cube.
    active: Option<(CubeId, usize)>,
    /// Freed-frame credit per cube (reused before new superblocks).
    hoarded: HashMap<CubeId, usize>,
}

/// The allocator: global state is just the per-process heaps plus a
/// round-robin cursor for assigning home cubes to new processes.
#[derive(Debug, Default)]
pub struct HoardAllocator {
    heaps: HashMap<Pid, ProcessHeap>,
    next_home: usize,
    /// Frames handed back to the global pool (statistic).
    pub released: u64,
}

impl HoardAllocator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Home cubes for a new process: a contiguous quadrant-ish run of
    /// cubes starting at the round-robin cursor.
    fn assign_homes(&mut self, n_cubes: usize) -> Vec<CubeId> {
        let homes_per_proc = (n_cubes / 4).max(1);
        let start = self.next_home;
        self.next_home = (self.next_home + homes_per_proc) % n_cubes;
        (0..homes_per_proc).map(|i| (start + i) % n_cubes).collect()
    }

    fn heap(&mut self, pid: Pid, n_cubes: usize) -> &mut ProcessHeap {
        if !self.heaps.contains_key(&pid) {
            let homes = self.assign_homes(n_cubes);
            self.heaps.insert(
                pid,
                ProcessHeap { homes, active: None, hoarded: HashMap::new() },
            );
        }
        self.heaps.get_mut(&pid).unwrap()
    }

    /// Cube preference order for a heap: homes first, then everything
    /// else by index (spill).
    fn preference(heap: &ProcessHeap, n_cubes: usize) -> Vec<CubeId> {
        let mut order = heap.homes.clone();
        for c in 0..n_cubes {
            if !order.contains(&c) {
                order.push(c);
            }
        }
        order
    }
}

impl Placement for HoardAllocator {
    fn place(&mut self, pid: Pid, _vpage: VPage, free_frames: &[usize]) -> CubeId {
        let n_cubes = free_frames.len();
        let heap = self.heap(pid, n_cubes);

        // 1. Reuse hoarded (freed) frames: strongest locality. Ties break
        // by lowest cube id, never by map-iteration order: hash order
        // differs between threads, and sweep cells must produce identical
        // stats on any worker.
        if let Some((&cube, _)) = heap
            .hoarded
            .iter() // detlint: allow(hash-iter) — max_by_key over a total order (count, then key)
            .filter(|(_, &n)| n > 0)
            .max_by_key(|(k, n)| (**n, std::cmp::Reverse(**k)))
        {
            *heap.hoarded.get_mut(&cube).unwrap() -= 1;
            return cube;
        }

        // 2. Active superblock.
        if let Some((cube, left)) = heap.active {
            if left > 0 && free_frames[cube] > 0 {
                heap.active = Some((cube, left - 1));
                return cube;
            }
        }

        // 3. New superblock from the most-preferred cube with space.
        let order = Self::preference(heap, n_cubes);
        for cube in order {
            if free_frames[cube] > 0 {
                heap.active = Some((cube, SUPERBLOCK - 1));
                return cube;
            }
        }
        0 // exhausted everywhere; MMU will report the failure
    }

    fn note_free(&mut self, pid: Pid, cube: CubeId) {
        if let Some(heap) = self.heaps.get_mut(&pid) {
            let entry = heap.hoarded.entry(cube).or_insert(0);
            *entry += 1;
            // Release whole superblocks back to the global pool once the
            // hoard exceeds the threshold.
            if *entry > RELEASE_THRESHOLD * SUPERBLOCK {
                *entry -= SUPERBLOCK;
                self.released += SUPERBLOCK as u64;
            }
        }
    }

    fn name(&self) -> &'static str {
        "hoard"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn free16() -> Vec<usize> {
        vec![1000; 16]
    }

    #[test]
    fn process_pages_colocate() {
        let mut h = HoardAllocator::new();
        let free = free16();
        let cubes: Vec<CubeId> = (0..SUPERBLOCK as u64).map(|v| h.place(1, v, &free)).collect();
        // One superblock's worth of pages all land in one cube.
        assert!(cubes.iter().all(|&c| c == cubes[0]), "{cubes:?}");
    }

    #[test]
    fn processes_get_disjoint_homes() {
        let mut h = HoardAllocator::new();
        let free = free16();
        let c1 = h.place(1, 0, &free);
        let c2 = h.place(2, 0, &free);
        let c3 = h.place(3, 0, &free);
        let c4 = h.place(4, 0, &free);
        let mut all = vec![c1, c2, c3, c4];
        all.dedup();
        assert_eq!(all.len(), 4, "four processes share no first home: {all:?}");
    }

    #[test]
    fn spills_when_homes_full() {
        let mut h = HoardAllocator::new();
        let mut free = free16();
        let home = h.place(1, 0, &free);
        // Exhaust the home quadrant.
        for c in 0..16 {
            if c == home || (c / 4 == home / 4) {
                free[c] = 0;
            }
        }
        free[home] = 0;
        let spill = h.place(1, 1, &free);
        assert_ne!(spill, home);
        assert!(free[spill] > 0);
    }

    #[test]
    fn freed_frames_reused_first() {
        let mut h = HoardAllocator::new();
        let free = free16();
        let first = h.place(1, 0, &free);
        h.note_free(1, 9);
        // Hoarded frame in cube 9 is reused before the active superblock.
        assert_eq!(h.place(1, 1, &free), 9);
        // Then allocation returns to the superblock.
        assert_eq!(h.place(1, 2, &free), first);
    }

    #[test]
    fn hoard_releases_excess() {
        let mut h = HoardAllocator::new();
        let free = free16();
        h.place(1, 0, &free);
        for _ in 0..(RELEASE_THRESHOLD * SUPERBLOCK + 1) {
            h.note_free(1, 3);
        }
        assert_eq!(h.released, SUPERBLOCK as u64);
    }
}
