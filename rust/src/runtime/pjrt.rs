//! The real Q-network: AOT-compiled HLO executed through the PJRT C API
//! (xla crate). HLO *text* is the interchange format — see aot.py and
//! /opt/xla-example/README.md for why serialized protos are rejected.

use std::path::Path;

use super::params::{Manifest, ParamStore};
use super::{QFunction, QSnapshot, TrainBatch, NUM_ACTIONS, STATE_DIM};

/// Energy-relevant event counters (folded into Fig 14 by the metrics
/// module: weight-matrix / state-buffer accesses per §7.7).
#[derive(Debug, Clone, Default)]
pub struct QNetCounters {
    pub inferences: u64,
    pub train_steps: u64,
}

/// PJRT-backed dueling DQN.
pub struct PjrtQNet {
    exe_infer: xla::PjRtLoadedExecutable,
    exe_train: xla::PjRtLoadedExecutable,
    store: ParamStore,
    manifest: Manifest,
    lr: f32,
    gamma: f32,
    /// Cached θ literal: rebuilt only when training updates parameters.
    theta_lit: xla::Literal,
    pub counters: QNetCounters,
}

impl PjrtQNet {
    /// Load artifacts from `dir`, compile both executables on the PJRT
    /// CPU client, and initialise parameters from `theta_init.bin`.
    pub fn load(dir: &Path, lr: f32, gamma: f32) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let store = ParamStore::load(dir, &manifest)?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |file: &str| -> anyhow::Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                dir.join(file)
                    .to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
            )?;
            Ok(client.compile(&xla::XlaComputation::from_proto(&proto))?)
        };
        let exe_infer = compile(&manifest.infer_file)?;
        let exe_train = compile(&manifest.train_file)?;
        let theta_lit = xla::Literal::vec1(&store.theta);
        Ok(Self {
            exe_infer,
            exe_train,
            store,
            manifest,
            lr,
            gamma,
            theta_lit,
            counters: QNetCounters::default(),
        })
    }

    pub fn param_size(&self) -> usize {
        self.manifest.param_size
    }

    pub fn store(&self) -> &ParamStore {
        &self.store
    }

    /// Reset parameters (fresh episode family); keeps compiled executables.
    pub fn reset_params(&mut self, theta: Vec<f32>) {
        self.store = ParamStore::from_theta(theta);
        self.theta_lit = xla::Literal::vec1(&self.store.theta);
    }
}

impl QFunction for PjrtQNet {
    fn q_values(&mut self, s: &[f32]) -> anyhow::Result<[f32; NUM_ACTIONS]> {
        anyhow::ensure!(s.len() == STATE_DIM, "state len {} != {STATE_DIM}", s.len());
        self.counters.inferences += 1;
        let s_lit = xla::Literal::vec1(s).reshape(&[1, STATE_DIM as i64])?;
        let result = self.exe_infer.execute::<xla::Literal>(&[self.theta_lit.clone(), s_lit])?;
        let out = result[0][0].to_literal_sync()?.to_tuple1()?;
        let q = out.to_vec::<f32>()?;
        anyhow::ensure!(q.len() == NUM_ACTIONS, "bad q length {}", q.len());
        let mut arr = [0.0f32; NUM_ACTIONS];
        arr.copy_from_slice(&q);
        Ok(arr)
    }

    fn train_batch(&mut self, batch: &TrainBatch) -> anyhow::Result<f32> {
        batch.validate()?;
        // The AOT train executable is shape-specialized: a batch of any
        // other size would mis-execute, so reject it loudly.
        anyhow::ensure!(
            batch.batch_len() == self.manifest.batch,
            "pjrt artifacts are compiled for batch {} but got a batch of {} \
             (AgentConfig.batch_size must equal the artifact batch)",
            self.manifest.batch,
            batch.batch_len()
        );
        self.counters.train_steps += 1;
        let b = self.manifest.batch as i64;
        let sdim = STATE_DIM as i64;
        let hyper =
            xla::Literal::vec1(&[(self.store.t + 1) as f32, self.lr, self.gamma]);
        let args = [
            self.theta_lit.clone(),
            xla::Literal::vec1(&self.store.target_theta),
            xla::Literal::vec1(&self.store.m),
            xla::Literal::vec1(&self.store.v),
            hyper,
            xla::Literal::vec1(&batch.s).reshape(&[b, sdim])?,
            xla::Literal::vec1(&batch.a),
            xla::Literal::vec1(&batch.r),
            xla::Literal::vec1(&batch.s2).reshape(&[b, sdim])?,
            xla::Literal::vec1(&batch.done),
        ];
        let result = self.exe_train.execute::<xla::Literal>(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let (theta, m, v, loss) = tuple.to_tuple4()?;
        self.store.theta = theta.to_vec::<f32>()?;
        self.store.m = m.to_vec::<f32>()?;
        self.store.v = v.to_vec::<f32>()?;
        self.store.t += 1;
        self.theta_lit = xla::Literal::vec1(&self.store.theta);
        Ok(loss.to_vec::<f32>()?[0])
    }

    fn sync_target(&mut self) {
        self.store.sync_target();
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }

    fn snapshot(&self) -> anyhow::Result<QSnapshot> {
        Ok(QSnapshot {
            backend: self.backend().to_string(),
            lr: self.lr,
            gamma: self.gamma,
            theta: self.store.theta.clone(),
            target_theta: self.store.target_theta.clone(),
            m: self.store.m.clone(),
            v: self.store.v.clone(),
            t: self.store.t,
            train_steps: self.counters.train_steps,
        })
    }

    fn restore(&mut self, snap: &QSnapshot) -> anyhow::Result<()> {
        // Backend check first: a same-sized flat vector from another
        // network layout would execute silently and compute garbage.
        anyhow::ensure!(
            snap.backend == self.backend(),
            "checkpoint was produced by backend {:?}, this agent runs {:?} — \
             cross-backend restores are not meaningful",
            snap.backend,
            self.backend()
        );
        let n = self.manifest.param_size;
        for (name, len) in [
            ("theta", snap.theta.len()),
            ("target_theta", snap.target_theta.len()),
            ("m", snap.m.len()),
            ("v", snap.v.len()),
        ] {
            anyhow::ensure!(
                len == n,
                "restoring a {:?} snapshot into pjrt: {name} has {len} entries, \
                 artifact expects {n}",
                snap.backend
            );
        }
        self.store.theta = snap.theta.clone();
        self.store.target_theta = snap.target_theta.clone();
        self.store.m = snap.m.clone();
        self.store.v = snap.v.clone();
        self.store.t = snap.t;
        self.lr = snap.lr;
        self.gamma = snap.gamma;
        self.counters.train_steps = snap.train_steps;
        self.theta_lit = xla::Literal::vec1(&self.store.theta);
        Ok(())
    }

    /// The train executable only accepts the artifact's compiled batch.
    fn fixed_batch(&self) -> Option<usize> {
        Some(self.manifest.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    fn load() -> Option<PjrtQNet> {
        let dir = artifacts_dir()?;
        PjrtQNet::load(&dir, 1e-3, 0.95).ok()
    }

    /// These tests exercise the full AOT round trip; they skip (pass
    /// vacuously) when `make artifacts` has not been run.
    #[test]
    fn infer_shapes_and_determinism() {
        let Some(mut q) = load() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let s = vec![0.1f32; STATE_DIM];
        let a = q.q_values(&s).unwrap();
        let b = q.q_values(&s).unwrap();
        assert_eq!(a, b, "inference must be deterministic");
        assert!(a.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn train_reduces_loss_on_fixed_batch() {
        let Some(mut q) = load() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        // A fixed supervised-ish batch: reward 1 for action 2 everywhere.
        let mut batch = TrainBatch {
            s: vec![0.0; super::super::BATCH * STATE_DIM],
            a: vec![2; super::super::BATCH],
            r: vec![1.0; super::super::BATCH],
            s2: vec![0.0; super::super::BATCH * STATE_DIM],
            done: vec![1.0; super::super::BATCH],
        };
        for i in 0..super::super::BATCH {
            for j in 0..STATE_DIM {
                batch.s[i * STATE_DIM + j] = ((i + j) % 7) as f32 / 7.0;
                batch.s2[i * STATE_DIM + j] = ((i * j) % 5) as f32 / 5.0;
            }
        }
        let first = q.train_batch(&batch).unwrap();
        let mut last = first;
        for _ in 0..30 {
            last = q.train_batch(&batch).unwrap();
        }
        assert!(last.is_finite() && first.is_finite());
        assert!(last < first, "loss should fall: first={first} last={last}");
    }

    #[test]
    fn snapshot_restore_roundtrips_param_store() {
        let Some(mut q) = load() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let batch = TrainBatch {
            s: vec![0.2; super::super::BATCH * STATE_DIM],
            a: vec![1; super::super::BATCH],
            r: vec![0.5; super::super::BATCH],
            s2: vec![0.2; super::super::BATCH * STATE_DIM],
            done: vec![0.0; super::super::BATCH],
        };
        q.train_batch(&batch).unwrap();
        let snap = q.snapshot().unwrap();
        assert_eq!(snap.backend, "pjrt");
        assert_eq!(snap.theta.len(), q.param_size());
        assert_eq!(snap.t, 1);

        let Some(mut r) = load() else { return };
        r.restore(&snap).unwrap();
        let s = vec![0.1f32; STATE_DIM];
        assert_eq!(q.q_values(&s).unwrap(), r.q_values(&s).unwrap());
        // A wrong-layout snapshot is rejected loudly.
        let mut bad = snap.clone();
        bad.m.pop();
        assert!(r.restore(&bad).is_err());
        assert_eq!(r.fixed_batch(), Some(super::super::BATCH));
    }

    #[test]
    fn params_change_after_training() {
        let Some(mut q) = load() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let before = q.store().theta.clone();
        let batch = TrainBatch {
            s: vec![0.3; super::super::BATCH * STATE_DIM],
            a: vec![0; super::super::BATCH],
            r: vec![1.0; super::super::BATCH],
            s2: vec![0.3; super::super::BATCH * STATE_DIM],
            done: vec![0.0; super::super::BATCH],
        };
        q.train_batch(&batch).unwrap();
        let after = &q.store().theta;
        assert_ne!(&before, after);
        assert_eq!(before.len(), after.len());
    }
}
