//! Dependency-free Q-function: a linear per-action approximator trained
//! with the same DQN target rule. Used by unit/integration tests and as a
//! graceful fallback when `artifacts/` is absent. NOT the paper's agent —
//! the evaluation always runs the PJRT dueling network.

use crate::sim::Rng;

use super::{QFunction, TrainBatch, NUM_ACTIONS, STATE_DIM};

/// Q(s, a) = w_a · s + b_a.
pub struct LinearQ {
    w: Vec<f32>, // NUM_ACTIONS × STATE_DIM
    b: [f32; NUM_ACTIONS],
    tw: Vec<f32>,
    tb: [f32; NUM_ACTIONS],
    lr: f32,
    gamma: f32,
    pub train_steps: u64,
}

impl LinearQ {
    pub fn new(lr: f32, gamma: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> =
            (0..NUM_ACTIONS * STATE_DIM).map(|_| (rng.f32() - 0.5) * 0.02).collect();
        Self {
            tw: w.clone(),
            w,
            b: [0.0; NUM_ACTIONS],
            tb: [0.0; NUM_ACTIONS],
            lr,
            gamma,
            train_steps: 0,
        }
    }

    fn q_with(w: &[f32], b: &[f32; NUM_ACTIONS], s: &[f32]) -> [f32; NUM_ACTIONS] {
        let mut out = *b;
        for (a, out_a) in out.iter_mut().enumerate() {
            let row = &w[a * STATE_DIM..(a + 1) * STATE_DIM];
            *out_a += row.iter().zip(s).map(|(wi, si)| wi * si).sum::<f32>();
        }
        out
    }
}

impl QFunction for LinearQ {
    fn q_values(&mut self, s: &[f32]) -> anyhow::Result<[f32; NUM_ACTIONS]> {
        anyhow::ensure!(s.len() == STATE_DIM);
        Ok(Self::q_with(&self.w, &self.b, s))
    }

    fn train_batch(&mut self, batch: &TrainBatch) -> anyhow::Result<f32> {
        batch.validate()?;
        self.train_steps += 1;
        let n = batch.a.len();
        let mut loss = 0.0f32;
        for i in 0..n {
            let s = &batch.s[i * STATE_DIM..(i + 1) * STATE_DIM];
            let s2 = &batch.s2[i * STATE_DIM..(i + 1) * STATE_DIM];
            let a = batch.a[i] as usize;
            let q = Self::q_with(&self.w, &self.b, s)[a];
            let q2max = Self::q_with(&self.tw, &self.tb, s2)
                .iter()
                .copied()
                .fold(f32::NEG_INFINITY, f32::max);
            let y = batch.r[i] + self.gamma * (1.0 - batch.done[i]) * q2max;
            let td = y - q;
            loss += td * td;
            let row = &mut self.w[a * STATE_DIM..(a + 1) * STATE_DIM];
            for (wi, si) in row.iter_mut().zip(s) {
                *wi += self.lr * td * si;
            }
            self.b[a] += self.lr * td;
        }
        Ok(loss / n as f32)
    }

    fn sync_target(&mut self) {
        self.tw.copy_from_slice(&self.w);
        self.tb = self.b;
    }

    fn backend(&self) -> &'static str {
        "linear-mock"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::BATCH;

    fn batch_for_action(a: i32, r: f32) -> TrainBatch {
        let mut s = vec![0.0; BATCH * STATE_DIM];
        for i in 0..BATCH {
            s[i * STATE_DIM] = 1.0;
        }
        TrainBatch {
            s: s.clone(),
            a: vec![a; BATCH],
            r: vec![r; BATCH],
            s2: s,
            done: vec![1.0; BATCH],
        }
    }

    #[test]
    fn learns_action_values() {
        let mut q = LinearQ::new(0.05, 0.9, 1);
        for _ in 0..50 {
            q.train_batch(&batch_for_action(3, 1.0)).unwrap();
            q.train_batch(&batch_for_action(5, -1.0)).unwrap();
        }
        let mut s = vec![0.0; STATE_DIM];
        s[0] = 1.0;
        let qv = q.q_values(&s).unwrap();
        assert!(qv[3] > 0.5, "q[3]={}", qv[3]);
        assert!(qv[5] < -0.5, "q[5]={}", qv[5]);
    }

    #[test]
    fn loss_decreases() {
        let mut q = LinearQ::new(0.05, 0.9, 2);
        let b = batch_for_action(0, 1.0);
        let first = q.train_batch(&b).unwrap();
        for _ in 0..30 {
            q.train_batch(&b).unwrap();
        }
        let last = q.train_batch(&b).unwrap();
        assert!(last < first);
    }

    #[test]
    fn target_network_lags_until_sync() {
        let mut q = LinearQ::new(0.05, 0.9, 3);
        let b = batch_for_action(0, 1.0);
        for _ in 0..10 {
            q.train_batch(&b).unwrap();
        }
        // Online weights moved; target still initial.
        assert_ne!(q.w, q.tw);
        q.sync_target();
        assert_eq!(q.w, q.tw);
    }
}
