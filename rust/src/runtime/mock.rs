//! Dependency-free Q-function: a linear per-action approximator trained
//! with the same DQN target rule. Used by unit/integration tests and as a
//! graceful fallback when `artifacts/` is absent. NOT the paper's agent —
//! the evaluation always runs the PJRT dueling network.

use crate::sim::Rng;

use super::{QFunction, QSnapshot, TrainBatch, NUM_ACTIONS, STATE_DIM};

/// Flat parameter count of [`LinearQ`]: per-action weight rows plus the
/// bias vector. This is the `theta` layout its [`QFunction::snapshot`]
/// exports: `w` (row-major, `NUM_ACTIONS × STATE_DIM`) then `b`.
pub const LINEAR_PARAMS: usize = NUM_ACTIONS * STATE_DIM + NUM_ACTIONS;

/// Q(s, a) = w_a · s + b_a.
pub struct LinearQ {
    w: Vec<f32>, // NUM_ACTIONS × STATE_DIM
    b: [f32; NUM_ACTIONS],
    tw: Vec<f32>,
    tb: [f32; NUM_ACTIONS],
    lr: f32,
    gamma: f32,
    pub train_steps: u64,
    /// Declared [`QFunction::fixed_batch`]. The linear mock can in fact
    /// train any row count, but declaring the caller's batch size lets
    /// batch-shape consumers (oracle distillation warm-start) work
    /// against the same contract the AOT-compiled backend enforces.
    fixed: Option<usize>,
}

impl LinearQ {
    pub fn new(lr: f32, gamma: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> =
            (0..NUM_ACTIONS * STATE_DIM).map(|_| (rng.f32() - 0.5) * 0.02).collect();
        Self {
            tw: w.clone(),
            w,
            b: [0.0; NUM_ACTIONS],
            tb: [0.0; NUM_ACTIONS],
            lr,
            gamma,
            train_steps: 0,
            fixed: None,
        }
    }

    /// Like [`LinearQ::new`] but declaring `batch` as the fixed training
    /// batch. Weights are identical to `new` with the same seed — only
    /// the advertised [`QFunction::fixed_batch`] differs.
    pub fn with_batch(lr: f32, gamma: f32, seed: u64, batch: usize) -> Self {
        Self { fixed: Some(batch), ..Self::new(lr, gamma, seed) }
    }

    fn q_with(w: &[f32], b: &[f32; NUM_ACTIONS], s: &[f32]) -> [f32; NUM_ACTIONS] {
        let mut out = *b;
        for (a, out_a) in out.iter_mut().enumerate() {
            let row = &w[a * STATE_DIM..(a + 1) * STATE_DIM];
            *out_a += row.iter().zip(s).map(|(wi, si)| wi * si).sum::<f32>();
        }
        out
    }

    fn flatten(w: &[f32], b: &[f32; NUM_ACTIONS]) -> Vec<f32> {
        let mut out = Vec::with_capacity(LINEAR_PARAMS);
        out.extend_from_slice(w);
        out.extend_from_slice(b);
        out
    }

    fn unflatten(flat: &[f32]) -> anyhow::Result<(Vec<f32>, [f32; NUM_ACTIONS])> {
        anyhow::ensure!(
            flat.len() == LINEAR_PARAMS,
            "linear-mock parameter vector has {} entries, expected {LINEAR_PARAMS}",
            flat.len()
        );
        let w = flat[..NUM_ACTIONS * STATE_DIM].to_vec();
        let mut b = [0.0f32; NUM_ACTIONS];
        b.copy_from_slice(&flat[NUM_ACTIONS * STATE_DIM..]);
        Ok((w, b))
    }
}

impl QFunction for LinearQ {
    fn q_values(&mut self, s: &[f32]) -> anyhow::Result<[f32; NUM_ACTIONS]> {
        anyhow::ensure!(s.len() == STATE_DIM);
        Ok(Self::q_with(&self.w, &self.b, s))
    }

    fn train_batch(&mut self, batch: &TrainBatch) -> anyhow::Result<f32> {
        batch.validate()?;
        self.train_steps += 1;
        let n = batch.a.len();
        let mut loss = 0.0f32;
        for i in 0..n {
            let s = &batch.s[i * STATE_DIM..(i + 1) * STATE_DIM];
            let s2 = &batch.s2[i * STATE_DIM..(i + 1) * STATE_DIM];
            let a = batch.a[i] as usize;
            let q = Self::q_with(&self.w, &self.b, s)[a];
            let q2max = Self::q_with(&self.tw, &self.tb, s2)
                .iter()
                .copied()
                .fold(f32::NEG_INFINITY, f32::max);
            let y = batch.r[i] + self.gamma * (1.0 - batch.done[i]) * q2max;
            let td = y - q;
            loss += td * td;
            let row = &mut self.w[a * STATE_DIM..(a + 1) * STATE_DIM];
            for (wi, si) in row.iter_mut().zip(s) {
                *wi += self.lr * td * si;
            }
            self.b[a] += self.lr * td;
        }
        Ok(loss / n as f32)
    }

    fn sync_target(&mut self) {
        self.tw.copy_from_slice(&self.w);
        self.tb = self.b;
    }

    fn backend(&self) -> &'static str {
        "linear-mock"
    }

    fn fixed_batch(&self) -> Option<usize> {
        self.fixed
    }

    fn snapshot(&self) -> anyhow::Result<QSnapshot> {
        Ok(QSnapshot {
            backend: self.backend().to_string(),
            lr: self.lr,
            gamma: self.gamma,
            theta: Self::flatten(&self.w, &self.b),
            target_theta: Self::flatten(&self.tw, &self.tb),
            // SGD backend: no Adam moments.
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
            train_steps: self.train_steps,
        })
    }

    fn restore(&mut self, snap: &QSnapshot) -> anyhow::Result<()> {
        // Backend check first: a same-sized parameter vector from a
        // different network would "restore" into garbage Q-values.
        anyhow::ensure!(
            snap.backend == self.backend(),
            "checkpoint was produced by backend {:?}, this agent runs {:?} — \
             cross-backend restores are not meaningful",
            snap.backend,
            self.backend()
        );
        let (w, b) = Self::unflatten(&snap.theta).map_err(|e| {
            anyhow::anyhow!("restoring a {:?} snapshot into linear-mock: {e}", snap.backend)
        })?;
        let (tw, tb) = Self::unflatten(&snap.target_theta).map_err(|e| {
            anyhow::anyhow!("restoring a {:?} snapshot into linear-mock: {e}", snap.backend)
        })?;
        self.w = w;
        self.b = b;
        self.tw = tw;
        self.tb = tb;
        self.lr = snap.lr;
        self.gamma = snap.gamma;
        self.train_steps = snap.train_steps;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::BATCH;

    fn batch_for_action(a: i32, r: f32) -> TrainBatch {
        let mut s = vec![0.0; BATCH * STATE_DIM];
        for i in 0..BATCH {
            s[i * STATE_DIM] = 1.0;
        }
        TrainBatch {
            s: s.clone(),
            a: vec![a; BATCH],
            r: vec![r; BATCH],
            s2: s,
            done: vec![1.0; BATCH],
        }
    }

    #[test]
    fn learns_action_values() {
        let mut q = LinearQ::new(0.05, 0.9, 1);
        for _ in 0..50 {
            q.train_batch(&batch_for_action(3, 1.0)).unwrap();
            q.train_batch(&batch_for_action(5, -1.0)).unwrap();
        }
        let mut s = vec![0.0; STATE_DIM];
        s[0] = 1.0;
        let qv = q.q_values(&s).unwrap();
        assert!(qv[3] > 0.5, "q[3]={}", qv[3]);
        assert!(qv[5] < -0.5, "q[5]={}", qv[5]);
    }

    #[test]
    fn loss_decreases() {
        let mut q = LinearQ::new(0.05, 0.9, 2);
        let b = batch_for_action(0, 1.0);
        let first = q.train_batch(&b).unwrap();
        for _ in 0..30 {
            q.train_batch(&b).unwrap();
        }
        let last = q.train_batch(&b).unwrap();
        assert!(last < first);
    }

    /// The continual-learning seam: a restored network answers exactly
    /// like the one that was snapshotted — including the lagging target
    /// (training after restore uses the same targets, hence identical
    /// weight updates).
    #[test]
    fn snapshot_restore_roundtrip_is_exact() {
        let mut q = LinearQ::new(0.05, 0.9, 21);
        for _ in 0..7 {
            q.train_batch(&batch_for_action(2, 1.0)).unwrap();
        }
        let snap = q.snapshot().unwrap();
        assert_eq!(snap.backend, "linear-mock");
        assert_eq!(snap.theta.len(), LINEAR_PARAMS);
        assert_eq!(snap.train_steps, 7);

        // Restore into a differently-seeded, differently-tuned instance.
        let mut r = LinearQ::new(0.9, 0.1, 99);
        r.restore(&snap).unwrap();
        let mut s = vec![0.0; STATE_DIM];
        s[0] = 1.0;
        s[5] = -0.25;
        assert_eq!(q.q_values(&s).unwrap(), r.q_values(&s).unwrap());
        // Training continues identically (same lr/gamma/targets).
        let b = batch_for_action(2, 1.0);
        assert_eq!(q.train_batch(&b).unwrap().to_bits(), r.train_batch(&b).unwrap().to_bits());
        assert_eq!(q.q_values(&s).unwrap(), r.q_values(&s).unwrap());
        assert_eq!(r.train_steps, 8);
    }

    #[test]
    fn restore_rejects_wrong_layout() {
        let mut q = LinearQ::new(0.05, 0.9, 1);
        let mut snap = q.snapshot().unwrap();
        snap.theta.pop();
        let err = q.restore(&snap).unwrap_err().to_string();
        assert!(err.contains("linear-mock"), "{err}");
    }

    /// `with_batch` only changes the advertised contract: the weights (and
    /// therefore every byte of downstream behavior) match `new` exactly.
    #[test]
    fn with_batch_declares_fixed_batch_without_changing_weights() {
        let mut plain = LinearQ::new(0.05, 0.9, 11);
        let mut sized = LinearQ::with_batch(0.05, 0.9, 11, 32);
        assert_eq!(plain.fixed_batch(), None);
        assert_eq!(sized.fixed_batch(), Some(32));
        let mut s = vec![0.0; STATE_DIM];
        s[1] = 1.0;
        assert_eq!(plain.q_values(&s).unwrap(), sized.q_values(&s).unwrap());
    }

    #[test]
    fn target_network_lags_until_sync() {
        let mut q = LinearQ::new(0.05, 0.9, 3);
        let b = batch_for_action(0, 1.0);
        for _ in 0..10 {
            q.train_batch(&b).unwrap();
        }
        // Online weights moved; target still initial.
        assert_ne!(q.w, q.tw);
        q.sync_target();
        assert_eq!(q.w, q.tw);
    }
}
