//! Minimal JSON parser for the artifact manifest (the offline crate
//! universe has no serde_json). Supports the full JSON grammar except
//! exotic number forms; good enough for machine-generated manifests.
//!
//! The [`write`] half is the matching fixed-key-order writer: callers
//! pass fields in the order they want them emitted, so byte-pinned
//! artifacts (`BENCH_sweep.json`, `BENCH_continual.json`, the agent
//! checkpoints) are reproducible byte-for-byte. Everything this module
//! writes parses back through [`parse`].

use std::collections::HashMap;

/// Fixed-key-order JSON writer helpers, shared by the sweep report and
/// journal (bench/sweep/) and the continual-learning checkpoint format
/// (agent/checkpoint.rs). No reflection, no trait magic: callers build
/// value strings bottom-up and list object fields in emission order.
pub mod write {
    /// Finite numbers print via Rust's shortest-roundtrip formatting;
    /// NaN/∞ (e.g. 0/0 on a degenerate cell) become `null` so they stay
    /// distinguishable from a genuine zero — the in-crate parser handles
    /// null.
    pub fn num(x: f64) -> String {
        if x.is_finite() {
            format!("{x}")
        } else {
            "null".to_string()
        }
    }

    /// A JSON string literal with the escapes [`super::parse`] understands.
    pub fn string(s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 2);
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out.push('"');
        out
    }

    /// An array from already-serialized element strings.
    pub fn arr(items: &[String]) -> String {
        format!("[{}]", items.join(","))
    }

    /// An object whose keys appear exactly in the given order.
    pub fn obj(fields: &[(&str, String)]) -> String {
        let body: Vec<String> =
            fields.iter().map(|(k, v)| format!("{}:{}", string(k), v)).collect();
        format!("{{{}}}", body.join(","))
    }

    /// A `u64` as a `0x`-hex JSON *string*. Full 64-bit values exceed
    /// 2^53 and would lose bits through any double-based JSON number
    /// path (including [`super::parse`]); the hex-string form is exact
    /// and matches what `BENCH_sweep.json` records for seeds.
    pub fn hex_u64(v: u64) -> String {
        string(&format!("{v:#x}"))
    }
}

/// Parse the `0x`-hex string form emitted by [`write::hex_u64`].
pub fn parse_hex_u64(s: &str) -> anyhow::Result<u64> {
    let hex = s
        .strip_prefix("0x")
        .or_else(|| s.strip_prefix("0X"))
        .ok_or_else(|| anyhow::anyhow!("expected 0x-hex string, got {s:?}"))?;
    Ok(u64::from_str_radix(hex, 16)?)
}

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse a JSON-Lines text: one JSON value per line. Returns a
/// `(line_number, raw_line, parse_result)` triple per non-blank line —
/// line numbers are 1-based for error messages, the raw line is passed
/// through verbatim (no trailing newline) so callers can recover exact
/// bytes, and blank/whitespace-only lines are skipped. Per-line parse
/// failures are returned, not raised: the caller decides what a bad
/// line means (the sweep journal drops torn appends loudly on resume;
/// `aimm sweep --merge` refuses them).
pub fn parse_lines(text: &str) -> Vec<(usize, &str, anyhow::Result<Json>)> {
    text.lines()
        .enumerate()
        .filter(|(_, raw)| !raw.trim().is_empty())
        .map(|(i, raw)| (i + 1, raw, parse(raw)))
        .collect()
}

pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.pos == p.bytes.len(), "trailing characters at {}", p.pos);
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow::anyhow!("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        anyhow::ensure!(
            got == b,
            "expected {:?} at {}, got {:?}",
            b as char,
            self.pos,
            got as char
        );
        Ok(())
    }

    fn literal(&mut self, word: &str, val: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "bad literal at {}",
            self.pos
        );
        self.pos += word.len();
        Ok(val)
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at {}", other.map(|c| c as char), self.pos),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => anyhow::bail!("expected , or }} got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(out)),
                c => anyhow::bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => anyhow::bail!("bad escape \\{}", c as char),
                },
                c => s.push(c as char),
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let is_num_byte =
            |c: u8| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-');
        while matches!(self.peek(), Some(c) if is_num_byte(c)) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "state_dim": 64, "num_actions": 8,
            "adam": {"b1": 0.9, "eps": 1e-8},
            "params": [{"name": "w1", "shape": [64, 128], "start": 0}],
            "flag": true, "nothing": null
        }"#;
        let j = parse(text).unwrap();
        assert_eq!(j.get("state_dim").unwrap().as_usize(), Some(64));
        assert_eq!(j.get("adam").unwrap().get("b1").unwrap().as_f64(), Some(0.9));
        let params = j.get("params").unwrap().as_arr().unwrap();
        assert_eq!(params[0].get("name").unwrap().as_str(), Some("w1"));
        assert_eq!(
            params[0].get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
            Some(128)
        );
        assert_eq!(j.get("flag"), Some(&Json::Bool(true)));
        assert_eq!(j.get("nothing"), Some(&Json::Null));
    }

    #[test]
    fn parses_strings_with_escapes() {
        let j = parse(r#""a\"b\nA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\nA"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn writer_output_parses_back() {
        let text = write::obj(&[
            ("name", write::string("a\"b\nc")),
            ("n", write::num(0.25)),
            ("bad", write::num(f64::NAN)),
            ("seed", write::hex_u64(u64::MAX)),
            ("xs", write::arr(&[write::num(1.0), write::num(2.0)])),
        ]);
        let j = parse(&text).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("a\"b\nc"));
        assert_eq!(j.get("n").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("bad"), Some(&Json::Null));
        assert_eq!(
            parse_hex_u64(j.get("seed").unwrap().as_str().unwrap()).unwrap(),
            u64::MAX
        );
        assert_eq!(j.get("xs").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn hex_u64_roundtrip_and_rejects_decimal() {
        for v in [0u64, 1, 0xA133, u64::MAX] {
            let lit = write::hex_u64(v);
            // Strip the surrounding quotes to get the raw string payload.
            let inner = lit.trim_matches('"');
            assert_eq!(parse_hex_u64(inner).unwrap(), v);
        }
        assert!(parse_hex_u64("123").is_err());
        assert!(parse_hex_u64("0xzz").is_err());
    }

    #[test]
    fn parse_lines_numbers_skips_blanks_and_flags_torn_tails() {
        let text = "{\"a\":1}\n\n  \n{\"b\":2}\n{\"c\":"; // torn final line
        let lines = parse_lines(text);
        assert_eq!(lines.len(), 3, "blank lines skipped");
        let (n1, raw1, ref p1) = lines[0];
        assert_eq!((n1, raw1), (1, "{\"a\":1}"));
        assert_eq!(p1.as_ref().unwrap().get("a").unwrap().as_usize(), Some(1));
        let (n2, raw2, ref p2) = lines[1];
        assert_eq!((n2, raw2), (4, "{\"b\":2}"), "line numbers are 1-based and real");
        assert!(p2.is_ok());
        let (n3, raw3, ref p3) = lines[2];
        assert_eq!((n3, raw3), (5, "{\"c\":"));
        assert!(p3.is_err(), "torn tail reported, not raised");
        assert!(parse_lines("").is_empty());
        assert!(parse_lines("\n\n").is_empty());
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let j = parse("[-1.5, 2e3, 1e-8]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1.5));
        assert_eq!(a[1].as_f64(), Some(2000.0));
        assert!((a[2].as_f64().unwrap() - 1e-8).abs() < 1e-20);
    }
}
