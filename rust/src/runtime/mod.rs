//! Q-function backends. The PJRT runtime (behind the `pjrt` cargo
//! feature) loads the AOT artifacts produced by `make artifacts`
//! (python/compile/aot.py) and executes the AIMM Q-network from rust.
//!
//! That path is the only place the three layers meet at run time: the L2
//! JAX model (with its L1 Pallas kernels already lowered inside) arrives
//! as HLO text, is compiled once on the PJRT CPU client, and then serves
//! the agent's inference and training calls with **no python anywhere**.
//! The default build carries no native dependency and always uses the
//! pure-rust [`LinearQ`] mock instead.
//!
//! The artifact contract (shapes, flat-parameter layout) is defined by
//! python/compile/model.py and mirrored by the constants below; the
//! manifest.json emitted alongside the artifacts is checked at load time
//! so drift fails loudly instead of mis-executing.

pub mod json;
pub mod mock;
pub mod params;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use mock::LinearQ;
pub use params::{Manifest, ParamStore};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtQNet;

use std::path::PathBuf;

/// Agent state vector width — MUST equal model.STATE_DIM in python.
pub const STATE_DIM: usize = 64;
/// Action count — MUST equal model.NUM_ACTIONS.
pub const NUM_ACTIONS: usize = 8;
/// Training batch — MUST equal model.BATCH.
pub const BATCH: usize = 32;
/// Hidden width (for energy accounting of weight-matrix touches).
pub const HIDDEN: usize = 128;

/// One training batch in flat layout (`s`/`s2` are `batch_len × STATE_DIM`).
///
/// The row count is whatever the replay buffer sampled
/// (`AgentConfig.batch_size`); backends that can only execute a fixed
/// batch (the AOT-compiled PJRT artifacts, pinned to [`BATCH`]) advertise
/// it through [`QFunction::fixed_batch`] and reject other sizes.
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub s: Vec<f32>,
    pub a: Vec<i32>,
    pub r: Vec<f32>,
    pub s2: Vec<f32>,
    pub done: Vec<f32>,
}

impl TrainBatch {
    /// Number of rows in the batch.
    pub fn batch_len(&self) -> usize {
        self.a.len()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.a.len();
        anyhow::ensure!(n > 0, "empty training batch");
        anyhow::ensure!(
            self.s.len() == n * STATE_DIM,
            "bad s len {} for batch of {n}",
            self.s.len()
        );
        anyhow::ensure!(
            self.s2.len() == n * STATE_DIM,
            "bad s2 len {} for batch of {n}",
            self.s2.len()
        );
        anyhow::ensure!(self.r.len() == n, "bad r len {}", self.r.len());
        anyhow::ensure!(self.done.len() == n, "bad done len {}", self.done.len());
        anyhow::ensure!(self.a.iter().all(|&a| (a as usize) < NUM_ACTIONS), "action out of range");
        Ok(())
    }
}

/// A backend-agnostic export of a Q-function's learned parameters — the
/// payload the continual-learning checkpoints (agent/checkpoint.rs)
/// carry between processes. Everything is flat `f32`/`u64` so the format
/// needs no knowledge of layer structure; the backend that produced the
/// snapshot is recorded so a mismatched restore fails with a useful
/// message instead of a bare length error.
#[derive(Debug, Clone, PartialEq)]
pub struct QSnapshot {
    /// [`QFunction::backend`] of the producer.
    pub backend: String,
    pub lr: f32,
    pub gamma: f32,
    /// Online parameters, flat (backend-defined layout).
    pub theta: Vec<f32>,
    /// Target-network parameters, same layout as `theta`.
    pub target_theta: Vec<f32>,
    /// Adam first/second moments (empty for backends without Adam state,
    /// e.g. the SGD-trained [`LinearQ`]).
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Adam step count.
    pub t: u64,
    /// Training steps performed so far.
    pub train_steps: u64,
}

/// The Q-function the agent consults. Implemented by `PjrtQNet` (the
/// real AOT-compiled network, behind the `pjrt` cargo feature) and
/// [`LinearQ`] (a dependency-free mock for tests and artifact-less
/// environments).
pub trait QFunction {
    /// Q(s, ·) for a single state.
    fn q_values(&mut self, s: &[f32]) -> anyhow::Result<[f32; NUM_ACTIONS]>;
    /// One DQN training step; returns the batch loss.
    fn train_batch(&mut self, batch: &TrainBatch) -> anyhow::Result<f32>;
    /// Copy online parameters into the target network.
    fn sync_target(&mut self);
    /// Human-readable backend name (diagnostics).
    fn backend(&self) -> &'static str;

    /// Export the learned parameters for a continual-learning checkpoint.
    /// Backends that cannot round-trip their parameters (hand-coded
    /// oracle policies and the like) keep the erroring default.
    fn snapshot(&self) -> anyhow::Result<QSnapshot> {
        anyhow::bail!("backend {:?} does not support parameter snapshots", self.backend())
    }

    /// Import parameters previously exported by [`QFunction::snapshot`].
    /// Must fail loudly on any layout mismatch (wrong backend, wrong
    /// parameter count) — never truncate or zero-fill.
    fn restore(&mut self, snap: &QSnapshot) -> anyhow::Result<()> {
        let _ = snap;
        anyhow::bail!("backend {:?} does not support parameter restore", self.backend())
    }

    /// `Some(n)` when the backend can only train batches of exactly `n`
    /// rows (AOT-compiled artifacts are shape-specialized); `None` when
    /// any row count works. Agent construction rejects an
    /// `AgentConfig.batch_size` that contradicts this.
    fn fixed_batch(&self) -> Option<usize> {
        None
    }
}

/// Locate the artifacts directory: `$AIMM_ARTIFACTS`, then `artifacts/`
/// relative to the working directory and its parents.
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("AIMM_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.is_dir() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").is_file() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Build the best available Q-function: the PJRT backend when this build
/// carries it (`--features pjrt`) *and* artifacts load, otherwise the
/// pure-rust mock (tests, CI, offline builds without `make artifacts`).
///
/// `batch` is the training batch size the caller intends to drive
/// (`AgentConfig.batch_size`): the PJRT artifacts are shape-specialized
/// to [`BATCH`] regardless, and the mock declares `batch` through
/// [`QFunction::fixed_batch`] so batch-shape consumers — the oracle
/// distillation pre-trainer above all — can size their batches at
/// construction time instead of discovering a `None` mid-episode.
pub fn best_qfunction(lr: f32, gamma: f32, seed: u64, batch: usize) -> Box<dyn QFunction> {
    #[cfg(feature = "pjrt")]
    if let Some(q) = artifacts_dir().and_then(|d| PjrtQNet::load(&d, lr, gamma).ok()) {
        return Box::new(q);
    }
    Box::new(LinearQ::with_batch(lr, gamma, seed, batch))
}

/// The batch size `--warm-start` pre-training must use, or a loud
/// config-time error naming the backend when it declares no fixed batch
/// — instead of the pre-trainer failing mid-episode after minutes of
/// simulation. Callers probe this right after `best_qfunction`.
pub fn warm_start_batch(qf: &dyn QFunction) -> anyhow::Result<usize> {
    qf.fixed_batch().ok_or_else(|| {
        anyhow::anyhow!(
            "--warm-start needs a fixed training batch to shape its distillation \
             batches, but backend {:?} declares none (fixed_batch() = None)",
            qf.backend()
        )
    })
}

/// Batch pre-training entry point (oracle distillation, agent/distill.rs):
/// run every batch through [`QFunction::train_batch`] in order, sync the
/// target network once at the end, and return the mean loss. Plain
/// supervised-style pre-training is just DQN steps on synthetic terminal
/// transitions, so no new backend surface is needed.
pub fn pretrain(qf: &mut dyn QFunction, batches: &[TrainBatch]) -> anyhow::Result<f32> {
    anyhow::ensure!(!batches.is_empty(), "pre-training needs at least one batch");
    let mut loss_sum = 0.0f64;
    for b in batches {
        loss_sum += qf.train_batch(b)? as f64;
    }
    qf.sync_target();
    Ok((loss_sum / batches.len() as f64) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_batch_validation() {
        let good = TrainBatch {
            s: vec![0.0; BATCH * STATE_DIM],
            a: vec![0; BATCH],
            r: vec![0.0; BATCH],
            s2: vec![0.0; BATCH * STATE_DIM],
            done: vec![0.0; BATCH],
        };
        assert!(good.validate().is_ok());
        let mut bad = good.clone();
        bad.a[0] = NUM_ACTIONS as i32;
        assert!(bad.validate().is_err());
        let mut short = good;
        short.s.pop();
        assert!(short.validate().is_err());
    }

    /// `AgentConfig.batch_size` is honored: validation keys off the
    /// actual row count, not the compiled-in [`BATCH`].
    #[test]
    fn train_batch_validates_any_row_count() {
        let n = 7;
        let b = TrainBatch {
            s: vec![0.0; n * STATE_DIM],
            a: vec![0; n],
            r: vec![0.0; n],
            s2: vec![0.0; n * STATE_DIM],
            done: vec![0.0; n],
        };
        assert!(b.validate().is_ok());
        assert_eq!(b.batch_len(), n);
        let empty = TrainBatch { s: vec![], a: vec![], r: vec![], s2: vec![], done: vec![] };
        assert!(empty.validate().is_err());
    }
}
