//! Artifact manifest parsing and the flat-parameter store.
//!
//! `manifest.json` (written by python/compile/aot.py) pins the network
//! dimensions and the flat-θ layout; loading verifies them against this
//! crate's compiled-in constants so a stale artifact cannot silently
//! mis-execute. `theta_init.bin` carries the He-initialised parameters as
//! little-endian f32.

use std::path::Path;

use super::json::{self, Json};
use super::{BATCH, NUM_ACTIONS, STATE_DIM};

/// One named parameter slice of the flat vector.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub start: usize,
    pub end: usize,
}

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub state_dim: usize,
    pub num_actions: usize,
    pub hidden: usize,
    pub batch: usize,
    pub param_size: usize,
    pub params: Vec<ParamSpec>,
    pub infer_file: String,
    pub train_file: String,
    pub theta_init_file: String,
}

impl Manifest {
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let j = json::parse(text)?;
        let field = |k: &str| -> anyhow::Result<&Json> {
            j.get(k).ok_or_else(|| anyhow::anyhow!("manifest missing key {k:?}"))
        };
        let usize_field = |k: &str| -> anyhow::Result<usize> {
            field(k)?.as_usize().ok_or_else(|| anyhow::anyhow!("manifest key {k:?} not a number"))
        };
        let params = field("params")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("params not an array"))?
            .iter()
            .map(|p| -> anyhow::Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow::anyhow!("param missing name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow::anyhow!("param missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    start: p.get("start").and_then(Json::as_usize).unwrap_or(0),
                    end: p.get("end").and_then(Json::as_usize).unwrap_or(0),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let artifacts = field("artifacts")?;
        let art = |k: &str| -> anyhow::Result<String> {
            Ok(artifacts
                .get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("artifacts missing {k:?}"))?
                .to_string())
        };
        Ok(Self {
            state_dim: usize_field("state_dim")?,
            num_actions: usize_field("num_actions")?,
            hidden: usize_field("hidden")?,
            batch: usize_field("batch")?,
            param_size: usize_field("param_size")?,
            params,
            infer_file: art("infer")?,
            train_file: art("train")?,
            theta_init_file: art("theta_init")?,
        })
    }

    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let m = Self::parse(&text)?;
        m.check_contract()?;
        Ok(m)
    }

    /// Verify the artifact matches this build's compiled-in interface.
    pub fn check_contract(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.state_dim == STATE_DIM,
            "artifact state_dim {} != crate {}",
            self.state_dim,
            STATE_DIM
        );
        anyhow::ensure!(
            self.num_actions == NUM_ACTIONS,
            "artifact num_actions {} != crate {}",
            self.num_actions,
            NUM_ACTIONS
        );
        anyhow::ensure!(self.batch == BATCH, "artifact batch {} != crate {}", self.batch, BATCH);
        let spec_total: usize = self.params.iter().map(|p| p.end - p.start).sum();
        anyhow::ensure!(
            spec_total == self.param_size,
            "param spec total {spec_total} != param_size {}",
            self.param_size
        );
        Ok(())
    }
}

/// Online/target parameters plus Adam moments, all flat f32.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub theta: Vec<f32>,
    pub target_theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Adam step count (1-based at first update).
    pub t: u64,
}

impl ParamStore {
    /// Load `theta_init.bin` (little-endian f32) and zeroed moments.
    pub fn load(dir: &Path, manifest: &Manifest) -> anyhow::Result<Self> {
        let bytes = std::fs::read(dir.join(&manifest.theta_init_file))?;
        anyhow::ensure!(
            bytes.len() == manifest.param_size * 4,
            "theta_init.bin is {} bytes, expected {}",
            bytes.len(),
            manifest.param_size * 4
        );
        let theta: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Self::from_theta(theta))
    }

    pub fn from_theta(theta: Vec<f32>) -> Self {
        let n = theta.len();
        Self {
            target_theta: theta.clone(),
            theta,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    pub fn sync_target(&mut self) {
        self.target_theta.copy_from_slice(&self.theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_text(state_dim: usize) -> String {
        format!(
            r#"{{
              "state_dim": {state_dim}, "num_actions": 8, "hidden": 128,
              "batch": 32, "param_size": 20,
              "adam": {{"b1": 0.9, "b2": 0.999, "eps": 1e-8}},
              "params": [
                {{"name": "w1", "shape": [4, 4], "start": 0, "end": 16}},
                {{"name": "b1", "shape": [4], "start": 16, "end": 20}}
              ],
              "artifacts": {{"infer": "i.txt", "train": "t.txt", "theta_init": "th.bin"}}
            }}"#
        )
    }

    #[test]
    fn parse_and_contract() {
        let m = Manifest::parse(&manifest_text(64)).unwrap();
        assert_eq!(m.param_size, 20);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].shape, vec![4, 4]);
        assert!(m.check_contract().is_ok());
    }

    #[test]
    fn contract_rejects_dim_mismatch() {
        let m = Manifest::parse(&manifest_text(32)).unwrap();
        assert!(m.check_contract().is_err());
    }

    #[test]
    fn param_store_sync() {
        let mut p = ParamStore::from_theta(vec![1.0, 2.0]);
        p.theta[0] = 9.0;
        assert_eq!(p.target_theta[0], 1.0);
        p.sync_target();
        assert_eq!(p.target_theta[0], 9.0);
    }
}
