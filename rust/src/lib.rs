//! # AIMM — continual-learning data & computation mapping for NMP
//!
//! Reproduction of *"Continual Learning Approach for Improving the Data and
//! Computation Mapping in Near-Memory Processing System"* (cs.AR 2021).
//!
//! The crate hosts the full three-layer stack's Layer 3: a cycle-level
//! memory-cube-network NMP simulator (the paper's evaluation substrate), the
//! NMP offloading techniques (BNMP / LDB / PEI), the mapping policies
//! (default / TOM / AIMM / CODA-greedy / oracle-profile behind one
//! `MappingPolicy` trait), and the AIMM reinforcement-learning coordinator.
//! When built with the `pjrt` cargo feature, the agent's dueling Q-network
//! executes AOT-compiled JAX/Pallas HLO through the PJRT C API
//! ([`runtime`]); the default build has no native dependency and uses the
//! pure-rust linear-Q mock. Python never runs at simulation time.
//!
//! Module map (see DESIGN.md §4 for the full inventory):
//!
//! * [`sim`] — deterministic cycle-level simulation core (clock, RNG, stats)
//! * [`noc`] — memory-cube network: routers, links, VCs, deterministic
//!   minimal routing over a pluggable topology (mesh / torus / ring)
//! * [`cube`] — 3D memory cube: vaults, banks, row buffer, NMP-op table
//! * [`mc`] — memory controllers: queues, page-info cache, system counters
//! * [`mmu`] — 4-level page table, V→P translation, per-cube frame pools
//! * [`alloc`] — NMP-aware HOARD page-frame allocator
//! * [`migration`] — migration queue + MDMA engine (blocking/non-blocking)
//! * [`nmp`] — NMP-op format and the BNMP/LDB/PEI offloading techniques
//! * [`mapping`] — the `MappingPolicy` trait and its five policies (B /
//!   TOM / AIMM / CODA-greedy / oracle-profile), plus the remap table
//! * [`agent`] — AIMM RL agent: state, actions, reward, replay, ε-greedy,
//!   and the versioned continual-learning checkpoint format
//! * [`runtime`] — `QFunction` backends: linear mock + manifest plumbing
//!   by default, PJRT artifact execution behind the `pjrt` feature
//! * [`workloads`] — the 9 benchmark trace generators + workload analysis
//! * [`coordinator`] — episode runner wiring everything together, plus
//!   the cross-program curriculum driver (cold-vs-warm transfer)
//! * [`metrics`] — performance counters, energy/area model (paper §7.7)
//! * [`config`] — hardware/agent configuration (paper Table 1 defaults)
//! * [`bench`] — measurement harness, figure tables and the parallel
//!   design-space sweep behind `cargo bench` / `aimm sweep`

#![forbid(unsafe_code)]

pub mod agent;
pub mod alloc;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod cube;
pub mod mapping;
pub mod mc;
pub mod metrics;
pub mod migration;
pub mod mmu;
pub mod nmp;
pub mod noc;
pub mod runtime;
pub mod sim;
pub mod workloads;
