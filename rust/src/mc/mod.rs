//! Memory controllers (Table 1: 4, one per CMP corner): request queues,
//! the fully-associative page-information cache feeding the AIMM agent,
//! per-quadrant system-information counters, V→P translation via TLB +
//! MMU, and NMP-op scheduling/dispatch into the memory network.

pub mod mc;
pub mod page_cache;
pub mod sys_counters;

pub use mc::{IssueDeps, Mc, McStats};
pub use page_cache::{PageInfo, PageInfoCache};
pub use sys_counters::SystemCounters;
