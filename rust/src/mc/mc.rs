//! The memory controller proper: queues ops from the CPU side, translates
//! them (TLB + 4-level walk + first-touch placement), schedules the
//! compute cube (technique + compute-remap table), dispatches into the
//! network and absorbs ACKs.

use std::collections::{HashMap, VecDeque};

use crate::alloc::Placement;
use crate::config::{McId, SystemConfig, Technique, VPage};
use crate::cube::PhysAddr;
use crate::mapping::{AnyPolicy, ComputeRemapTable, MappingPolicy};
use crate::migration::MigrationSystem;
use crate::mmu::{Mmu, Tlb, WALK_LEVELS};
use crate::nmp::{schedule, CpuCache, NmpOp};
use crate::noc::packet::{NodeId, OpToken, Packet, Payload};
use crate::noc::Mesh;
use crate::sim::{BoundedQueue, Cycle};

use super::page_cache::PageInfoCache;
use super::sys_counters::SystemCounters;

/// TLB entries per MC.
const TLB_ENTRIES: usize = 64;
/// NMP-op dispatches per MC per cycle.
const DISPATCH_WIDTH: usize = 2;

/// Shared structures the MC borrows while issuing (owned by the System).
pub struct IssueDeps<'a> {
    pub mmu: &'a mut Mmu,
    pub placement: &'a mut dyn Placement,
    /// The configured mapping policy: consulted for first-touch
    /// placement overrides and notified of every dispatched op.
    pub policy: &'a mut AnyPolicy,
    pub cpu_cache: &'a mut CpuCache,
    pub remap: &'a mut ComputeRemapTable,
    pub migration: &'a MigrationSystem,
    pub mesh: &'a Mesh,
    pub technique: Technique,
}

/// An op dispatched and not yet ACKed.
#[derive(Debug, Clone, Copy)]
struct Outstanding {
    pid: u32,
    dest_vpage: VPage,
    dispatched_at: Cycle,
}

/// MC statistics.
#[derive(Debug, Clone, Default)]
pub struct McStats {
    pub ops_enqueued: u64,
    pub ops_dispatched: u64,
    pub ops_completed: u64,
    pub total_op_latency: u64,
    pub tlb_miss_stalls: u64,
    pub blocked_on_migration: u64,
}

/// One memory controller.
pub struct Mc {
    pub id: McId,
    pub queue: BoundedQueue<NmpOp>,
    /// Ops parked by a blocking migration of a page they touch; only
    /// accesses to the migrating page block (§5.3), everything else
    /// keeps flowing.
    parked: Vec<NmpOp>,
    pub tlb: Tlb,
    pub page_cache: PageInfoCache,
    pub counters: SystemCounters,
    pub out: VecDeque<Packet>,
    outstanding: HashMap<OpToken, Outstanding>,
    next_token: OpToken,
    token_stride: u64,
    stall_until: Cycle,
    pub stats: McStats,
    pt_walk_latency: u64,
}

impl Mc {
    pub fn new(id: McId, cfg: &SystemConfig) -> Self {
        Self {
            id,
            queue: BoundedQueue::new(cfg.mc_queue_cap),
            parked: Vec::new(),
            tlb: Tlb::new(TLB_ENTRIES),
            page_cache: PageInfoCache::new(cfg.page_info_entries),
            counters: SystemCounters::new(cfg.mc_nearest_cubes(id)),
            out: VecDeque::new(),
            outstanding: HashMap::new(),
            next_token: id as u64 + 1,
            token_stride: cfg.num_mcs() as u64,
            stall_until: 0,
            stats: McStats::default(),
            pt_walk_latency: cfg.timing.pt_walk,
        }
    }

    /// Offer an op from the CPU side. Errors when the queue is full.
    pub fn enqueue(&mut self, op: NmpOp) -> Result<(), NmpOp> {
        self.queue.push(op).map(|()| {
            self.stats.ops_enqueued += 1;
        })
    }

    pub fn outstanding_count(&self) -> usize {
        self.outstanding.len()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty()
            && self.parked.is_empty()
            && self.outstanding.is_empty()
            && self.out.is_empty()
    }

    /// Earliest cycle ≥ `now` at which [`tick_issue`](Self::tick_issue)
    /// or the injection retry can change state (event engine, DESIGN.md
    /// §8). A non-empty request queue issues (or parks blocked heads)
    /// every non-stalled cycle; parked ops only matter once a migration
    /// unlock makes one eligible — and the unlocking ACK is itself a
    /// delivery event, after which this is re-evaluated. `None` means
    /// the MC performs pure accounting until an external delivery.
    pub fn next_event(&self, now: Cycle, migration: &MigrationSystem) -> Option<Cycle> {
        let mut next = Cycle::MAX;
        if !self.out.is_empty() {
            next = now; // retry injection into the mesh
        }
        let issue_at = now.max(self.stall_until);
        let parked_ready = || {
            self.parked.iter().any(|op| {
                let (pages, n) = op.vpages_arr();
                !pages[..n].iter().any(|&v| migration.is_blocked(op.pid, v))
            })
        };
        if !self.queue.is_empty() || parked_ready() {
            next = next.min(issue_at);
        }
        (next != Cycle::MAX).then_some(next)
    }

    /// Bulk-apply `span` skipped cycles of per-cycle accounting (the
    /// `queue.observe()` each polled `tick_issue` performs, stalled or
    /// not) — bit-identical to `span` consecutive quiescent ticks.
    pub fn observe_span(&mut self, span: u64) {
        self.queue.observe_n(span);
    }

    /// Translate one page, charging walk latency on a TLB miss and
    /// performing first-touch placement for unmapped pages.
    fn translate_page(
        &mut self,
        deps: &mut IssueDeps<'_>,
        pid: u32,
        vpage: VPage,
    ) -> anyhow::Result<crate::mmu::PhysLoc> {
        if let Some(loc) = self.tlb.lookup(pid, vpage) {
            return Ok(loc);
        }
        self.stats.tlb_miss_stalls += 1;
        self.stall_until = self.stall_until.max(self.pt_walk_latency * WALK_LEVELS as u64 / 4);
        let loc = match deps.mmu.translate(pid, vpage) {
            Some(loc) => loc,
            None => {
                // First touch: the policy's placement override (TOM's
                // hash, the oracle's profiled assignment), else the OS
                // default allocator.
                let cube = match deps.policy.first_touch_cube(pid, vpage) {
                    Some(cube) => cube,
                    None => {
                        let n = deps.mesh.num_cubes();
                        let free: Vec<usize> =
                            (0..n).map(|c| deps.mmu.free_frames(c)).collect();
                        deps.placement.place(pid, vpage, &free)
                    }
                };
                deps.mmu.map_page(pid, vpage, cube)?
            }
        };
        self.tlb.insert(pid, vpage, loc);
        Ok(loc)
    }

    /// Issue up to `DISPATCH_WIDTH` ops per cycle (dual-channel command
    /// issue). Ops touching a blocking-migrating page are parked (only
    /// that page's accesses wait); others flow.
    pub fn tick_issue(&mut self, now: Cycle, deps: &mut IssueDeps<'_>) -> anyhow::Result<()> {
        self.queue.observe();
        if now < self.stall_until {
            return Ok(());
        }
        for _ in 0..DISPATCH_WIDTH {
            self.issue_one(now, deps)?;
        }
        Ok(())
    }

    fn issue_one(&mut self, now: Cycle, deps: &mut IssueDeps<'_>) -> anyhow::Result<()> {
        // First, try to un-park an op whose migration has finished.
        let op = if let Some(pos) = self.parked.iter().position(|op| {
            let (pages, n) = op.vpages_arr();
            !pages[..n].iter().any(|&v| deps.migration.is_blocked(op.pid, v))
        }) {
            self.parked.remove(pos)
        } else {
            // Pull from the queue, parking blocked heads (bounded scan).
            let mut picked = None;
            for _ in 0..4 {
                match self.queue.pop() {
                    Some(op)
                        if {
                            let (pages, n) = op.vpages_arr();
                            pages[..n].iter().any(|&v| deps.migration.is_blocked(op.pid, v))
                        } =>
                    {
                        self.stats.blocked_on_migration += 1;
                        self.parked.push(op);
                    }
                    Some(op) => {
                        picked = Some(op);
                        break;
                    }
                    None => break,
                }
            }
            match picked {
                Some(op) => op,
                None => return Ok(()),
            }
        };

        // V→P for all operands (may first-touch allocate).
        let dest_loc = self.translate_page(deps, op.pid, op.dest_vpage())?;
        let src1_loc = self.translate_page(deps, op.pid, op.src1_vpage())?;
        let src2_loc = match op.src2_vpage() {
            Some(v) => Some(self.translate_page(deps, op.pid, v)?),
            None => None,
        };
        let page_off = |addr: u64| addr & (crate::config::PAGE_SIZE - 1);
        let dest = PhysAddr::new(
            dest_loc.cube,
            dest_loc.frame * crate::config::PAGE_SIZE + page_off(op.dest),
        );
        let src1 = PhysAddr::new(
            src1_loc.cube,
            src1_loc.frame * crate::config::PAGE_SIZE + page_off(op.src1),
        );
        let src2 = src2_loc.map(|loc| {
            PhysAddr::new(
                loc.cube,
                loc.frame * crate::config::PAGE_SIZE + page_off(op.src2.unwrap()),
            )
        });

        // Technique scheduling, then the agent's compute-remap table
        // overrides (keyed by destination page, §5.3).
        let mut decision = schedule(deps.technique, &op, dest, src1, src2, deps.cpu_cache);
        if let Some(cube) = deps.remap.lookup(op.pid, op.dest_vpage()) {
            decision.compute_cube = cube;
        }

        // The policy observes every dispatched op (TOM's co-location
        // profiling, CODA's per-page compute counters; a no-op for the
        // rest). `compute_cube` is the final decision, remap included.
        let mut sources = [(op.pid, op.src1_vpage()); 2];
        let n_sources = match op.src2_vpage() {
            Some(v) => {
                sources[1] = (op.pid, v);
                2
            }
            None => 1,
        };
        deps.policy.observe_dispatch(
            (op.pid, op.dest_vpage()),
            &sources[..n_sources],
            decision.compute_cube,
        );

        let token = self.next_token;
        self.next_token += self.token_stride;

        let pk = Packet::new(
            token,
            NodeId::Mc(self.id),
            NodeId::Cube(decision.compute_cube),
            Payload::NmpDispatch {
                token,
                dest,
                src1,
                src2,
                carried_operands: decision.carried_operands,
                dest_vpage: op.dest_vpage(),
            },
            now,
        );
        self.out.push_back(pk);

        self.outstanding.insert(
            token,
            Outstanding { pid: op.pid, dest_vpage: op.dest_vpage(), dispatched_at: now },
        );
        self.stats.ops_dispatched += 1;
        // Page-info accounting for every page the op touches; the dest
        // page additionally records the source cube for source-compute
        // remapping.
        // Per-page hop history: distance between the page's data and the
        // computation consuming it (§4.2 "communication hop count ... of
        // the data in the page") — the signal that tells the agent which
        // pages are far from their compute.
        let cc = decision.compute_cube;
        let dist = |cube: crate::config::CubeId| {
            deps.mesh.hop_distance(NodeId::Cube(cube), NodeId::Cube(cc))
        };
        self.page_cache
            .on_dispatch((op.pid, op.dest_vpage()), dist(dest.cube), src1.cube, cc);
        self.page_cache
            .on_dispatch((op.pid, op.src1_vpage()), dist(src1.cube), src1.cube, cc);
        if let (Some(v), Some(s2)) = (op.src2_vpage(), src2) {
            self.page_cache.on_dispatch((op.pid, v), dist(s2.cube), src1.cube, cc);
        }
        Ok(())
    }

    /// Handle a packet delivered to this MC. A completed op returns its
    /// `(pid, latency)` so the coordinator can attribute the completion
    /// to a tenant (serve mode) as well as count it.
    pub fn receive(&mut self, pk: Packet, now: Cycle) -> Option<(u32, u64)> {
        match pk.payload {
            Payload::NmpAck { token, .. } => {
                if let Some(o) = self.outstanding.remove(&token) {
                    let latency = now - o.dispatched_at;
                    self.stats.ops_completed += 1;
                    self.stats.total_op_latency += latency;
                    self.page_cache.on_ack((o.pid, o.dest_vpage), latency);
                    return Some((o.pid, latency));
                }
                None
            }
            _ => None,
        }
    }

    /// A migration of a page this MC tracks completed.
    pub fn on_migration_done(&mut self, pid: u32, vpage: VPage, latency: u64) {
        self.tlb.invalidate(pid, vpage);
        self.page_cache.on_migration((pid, vpage), latency);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::StripePlacement;
    use crate::nmp::OpKind;

    fn op(dest: u64, src1: u64, src2: Option<u64>) -> NmpOp {
        NmpOp { pid: 1, kind: OpKind::Add, dest, src1, src2 }
    }

    struct Ctx {
        mmu: Mmu,
        placement: StripePlacement,
        policy: AnyPolicy,
        cpu_cache: CpuCache,
        remap: ComputeRemapTable,
        migration: MigrationSystem,
        mesh: Mesh,
    }

    fn ctx() -> (Mc, Ctx) {
        let cfg = SystemConfig::default();
        let mut mmu = Mmu::new(&cfg);
        mmu.create_process(1);
        (
            Mc::new(0, &cfg),
            Ctx {
                mmu,
                placement: StripePlacement::default(),
                policy: AnyPolicy::baseline(),
                cpu_cache: CpuCache::new(cfg.cpu_cache_lines),
                remap: ComputeRemapTable::new(1024),
                migration: MigrationSystem::new(&cfg),
                mesh: Mesh::new(&cfg),
            },
        )
    }

    fn deps(c: &mut Ctx) -> IssueDeps<'_> {
        IssueDeps {
            mmu: &mut c.mmu,
            placement: &mut c.placement,
            policy: &mut c.policy,
            cpu_cache: &mut c.cpu_cache,
            remap: &mut c.remap,
            migration: &c.migration,
            mesh: &c.mesh,
            technique: Technique::Bnmp,
        }
    }

    #[test]
    fn dispatch_creates_packet_and_outstanding() {
        let (mut mc, mut c) = ctx();
        mc.enqueue(op(0x1000, 0x2000, Some(0x3000))).unwrap();
        let mut now = 0;
        while mc.out.is_empty() {
            mc.tick_issue(now, &mut deps(&mut c)).unwrap();
            now += 1;
            assert!(now < 10_000);
        }
        assert_eq!(mc.outstanding_count(), 1);
        assert_eq!(mc.stats.ops_dispatched, 1);
        let pk = &mc.out[0];
        assert!(matches!(pk.payload, Payload::NmpDispatch { .. }));
        // BNMP: compute cube = dest page's cube (stripe put page 1 in cube 0).
        assert_eq!(pk.dst, NodeId::Cube(0));
    }

    #[test]
    fn ack_completes_and_records_latency() {
        let (mut mc, mut c) = ctx();
        mc.enqueue(op(0x1000, 0x2000, None)).unwrap();
        let mut now = 0;
        while mc.outstanding_count() == 0 {
            mc.tick_issue(now, &mut deps(&mut c)).unwrap();
            now += 1;
        }
        // detlint: allow(hash-iter) — test map holds exactly one entry at this point
        let token = *mc.outstanding.keys().next().unwrap();
        let ack = Packet::new(
            token,
            NodeId::Cube(0),
            NodeId::Mc(0),
            Payload::NmpAck { token, compute_cube: 0 },
            now + 90,
        );
        let lat = mc.receive(ack, now + 100);
        assert_eq!(lat.map(|(pid, _)| pid), Some(1), "completion attributes its pid");
        assert_eq!(mc.stats.ops_completed, 1);
        assert!(mc.is_idle() || !mc.out.is_empty());
    }

    #[test]
    fn remap_table_overrides_compute_cube() {
        let (mut mc, mut c) = ctx();
        c.remap.insert(1, 1, 9); // dest vpage 1 → cube 9
        mc.enqueue(op(0x1000, 0x2000, None)).unwrap();
        let mut now = 0;
        while mc.out.is_empty() {
            mc.tick_issue(now, &mut deps(&mut c)).unwrap();
            now += 1;
        }
        assert_eq!(mc.out[0].dst, NodeId::Cube(9));
    }

    #[test]
    fn blocking_migration_holds_op() {
        let (mut mc, mut c) = ctx();
        // Map the page first so migration can target it.
        c.mmu.map_page(1, 1, 0).unwrap();
        c.migration
            .request(crate::migration::MigRequest { pid: 1, vpage: 1, to_cube: 3, blocking: true });
        mc.enqueue(op(0x1000, 0x2000, None)).unwrap();
        for now in 0..50 {
            mc.tick_issue(now, &mut deps(&mut c)).unwrap();
        }
        assert_eq!(mc.stats.ops_dispatched, 0);
        assert!(mc.stats.blocked_on_migration > 0);
    }

    #[test]
    fn next_event_reflects_queue_and_parked_state() {
        let (mut mc, mut c) = ctx();
        assert_eq!(mc.next_event(0, &c.migration), None, "idle MC is quiescent");
        mc.enqueue(op(0x1000, 0x2000, None)).unwrap();
        assert_eq!(mc.next_event(0, &c.migration), Some(0), "queued op issues now");
        // Park the op behind a blocking migration: the MC stays busy
        // while the op is in the queue (it pops-and-parks), then goes
        // quiescent once parked-and-blocked.
        c.mmu.map_page(1, 1, 0).unwrap();
        c.migration
            .request(crate::migration::MigRequest { pid: 1, vpage: 1, to_cube: 3, blocking: true });
        for now in 0..4 {
            mc.tick_issue(now, &mut deps(&mut c)).unwrap();
        }
        assert!(mc.stats.blocked_on_migration > 0);
        assert_eq!(
            mc.next_event(9, &c.migration),
            None,
            "parked-blocked op waits for the migration ACK, not the clock"
        );
    }

    #[test]
    fn tlb_caches_translations() {
        let (mut mc, mut c) = ctx();
        mc.enqueue(op(0x1000, 0x1008, None)).unwrap(); // same page twice
        mc.enqueue(op(0x1010, 0x1018, None)).unwrap();
        let mut now = 0;
        while mc.stats.ops_dispatched < 2 {
            mc.tick_issue(now, &mut deps(&mut c)).unwrap();
            now += 1;
            assert!(now < 10_000);
        }
        // First op misses once (dest+src same page), second op hits.
        assert!(mc.tlb.hits >= 2, "hits={}", mc.tlb.hits);
    }
}
