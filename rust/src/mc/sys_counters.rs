//! Per-MC system-information counters (paper §5.1): two vectors tracking
//! the running average of NMP-table occupancy and row-buffer hit rate for
//! the MC's nearest cubes, refreshed by periodic cube reports.

use crate::config::CubeId;
use crate::sim::RunningAvg;

/// Smoothing weight for the running averages.
const ALPHA: f64 = 0.25;

#[derive(Debug)]
pub struct SystemCounters {
    cubes: Vec<CubeId>,
    occ: Vec<RunningAvg>,
    row_hit: Vec<RunningAvg>,
}

impl SystemCounters {
    pub fn new(nearest: Vec<CubeId>) -> Self {
        let n = nearest.len();
        Self {
            cubes: nearest,
            occ: (0..n).map(|_| RunningAvg::new(ALPHA)).collect(),
            row_hit: (0..n).map(|_| RunningAvg::new(ALPHA)).collect(),
        }
    }

    /// Periodic report from a cube (ignored if not one of ours).
    pub fn report(&mut self, cube: CubeId, occupancy: f64, row_hit_rate: f64) {
        if let Some(i) = self.cubes.iter().position(|&c| c == cube) {
            self.occ[i].update(occupancy);
            self.row_hit[i].update(row_hit_rate);
        }
    }

    pub fn nearest(&self) -> &[CubeId] {
        &self.cubes
    }

    /// Aggregates for the agent state (mesh-size-invariant encoding,
    /// DESIGN.md §5): occupancy mean/max, row-hit mean/min.
    pub fn occ_mean(&self) -> f32 {
        mean(self.occ.iter().map(|a| a.get()))
    }

    pub fn occ_max(&self) -> f32 {
        self.occ.iter().map(|a| a.get()).fold(0.0, f64::max) as f32
    }

    pub fn row_hit_mean(&self) -> f32 {
        mean(self.row_hit.iter().map(|a| a.get()))
    }

    pub fn row_hit_min(&self) -> f32 {
        self.row_hit.iter().map(|a| a.get()).fold(1.0, f64::min) as f32
    }
}

fn mean(it: impl Iterator<Item = f64>) -> f32 {
    let (sum, n) = it.fold((0.0, 0usize), |(s, n), v| (s + v, n + 1));
    if n == 0 {
        0.0
    } else {
        (sum / n as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_tracked_per_cube() {
        let mut sc = SystemCounters::new(vec![0, 1, 4, 5]);
        sc.report(0, 0.8, 0.5);
        sc.report(1, 0.4, 0.9);
        assert!((sc.occ_mean() - 0.3).abs() < 1e-6); // (0.8+0.4+0+0)/4
        assert!((sc.occ_max() - 0.8).abs() < 1e-6);
        assert!((sc.row_hit_min() - 0.0).abs() < 1e-6); // unreported cubes 0
    }

    #[test]
    fn foreign_cube_ignored() {
        let mut sc = SystemCounters::new(vec![0, 1]);
        sc.report(9, 1.0, 1.0);
        assert_eq!(sc.occ_max(), 0.0);
    }

    #[test]
    fn running_average_smooths() {
        let mut sc = SystemCounters::new(vec![0]);
        sc.report(0, 1.0, 1.0);
        sc.report(0, 0.0, 0.0);
        let v = sc.occ_mean();
        assert!(v > 0.0 && v < 1.0, "smoothed value, got {v}");
    }
}
