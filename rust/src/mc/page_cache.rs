//! Fully-associative page-information cache (paper §5.1): one per MC,
//! 128 entries by default, least-frequently-used replacement where the
//! victim's content is *abandoned* (unlike a cache, nothing writes back).
//!
//! Each entry tracks the per-page signals of the agent's state: access
//! and migration counts plus four fixed-length histories — communication
//! hop count, packet latency, migration latency, and actions taken.

use std::collections::{HashMap, VecDeque};

use crate::config::{CubeId, Pid, VPage};
use crate::sim::History;

/// History length for each per-page series (DESIGN.md §5: 4 samples).
pub const HIST_LEN: usize = 4;

/// Per-page information record.
#[derive(Debug, Clone)]
pub struct PageInfo {
    pub accesses: u64,
    pub migrations: u64,
    pub hop_hist: History,
    pub lat_hist: History,
    pub mig_lat_hist: History,
    pub action_hist: History,
    /// Host cube of the first source of the page's most recent op —
    /// target of the "source compute remapping" action.
    pub last_src1_cube: CubeId,
    /// Compute cube of the page's most recent op — the reference point
    /// of the near/far remapping actions (§4.2).
    pub last_compute_cube: CubeId,
}

impl PageInfo {
    fn new() -> Self {
        Self {
            accesses: 0,
            migrations: 0,
            hop_hist: History::new(HIST_LEN),
            lat_hist: History::new(HIST_LEN),
            mig_lat_hist: History::new(HIST_LEN),
            action_hist: History::new(HIST_LEN),
            last_src1_cube: 0,
            last_compute_cube: 0,
        }
    }

    /// Migrations per access (agent state field).
    pub fn migrations_per_access(&self) -> f32 {
        if self.accesses == 0 {
            0.0
        } else {
            self.migrations as f32 / self.accesses as f32
        }
    }
}

/// The cache itself.
#[derive(Debug)]
pub struct PageInfoCache {
    entries: HashMap<(Pid, VPage), PageInfo>,
    capacity: usize,
    /// Recently supplied remap candidates (rotation ring): the agent
    /// works through the actively-accessed set instead of hammering one
    /// page (§5.3 "actively accessed pages are chosen as candidates").
    recent_selected: VecDeque<(Pid, VPage)>,
    /// Total accesses recorded across all (even evicted) entries — the
    /// denominator of the "page access rate" state field.
    pub total_accesses: u64,
    /// Cache touches for the 0.05 nJ/access energy constant (§7.7).
    pub touches: u64,
    pub evictions: u64,
}

impl PageInfoCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            entries: HashMap::with_capacity(capacity),
            capacity,
            recent_selected: VecDeque::new(),
            total_accesses: 0,
            touches: 0,
            evictions: 0,
        }
    }

    fn entry_mut(&mut self, key: (Pid, VPage)) -> &mut PageInfo {
        self.touches += 1;
        if !self.entries.contains_key(&key) {
            if self.entries.len() >= self.capacity {
                // LFU victim, content abandoned (§5.1). Ties break by
                // lowest key, never by map-iteration order: hash order
                // differs between threads, and sweep cells must produce
                // identical stats on any worker.
                let victim = self
                    .entries
                    .iter() // detlint: allow(hash-iter) — min_by_key over a total order
                    .min_by_key(|(k, e)| (e.accesses, **k))
                    .map(|(k, _)| *k)
                    .unwrap();
                self.entries.remove(&victim);
                self.evictions += 1;
            }
            self.entries.insert(key, PageInfo::new());
        }
        self.entries.get_mut(&key).unwrap()
    }

    /// An NMP-op touching this page was dispatched.
    pub fn on_dispatch(
        &mut self,
        key: (Pid, VPage),
        hop_estimate: u32,
        src1_cube: CubeId,
        compute_cube: CubeId,
    ) {
        self.total_accesses += 1;
        let e = self.entry_mut(key);
        e.accesses += 1;
        e.hop_hist.push(hop_estimate as f32);
        e.last_src1_cube = src1_cube;
        e.last_compute_cube = compute_cube;
    }

    /// ACK observed: record round-trip packet latency.
    pub fn on_ack(&mut self, key: (Pid, VPage), latency: u64) {
        if self.entries.contains_key(&key) {
            self.touches += 1;
            self.entries.get_mut(&key).unwrap().lat_hist.push(latency as f32);
        }
    }

    /// Migration of this page finished.
    pub fn on_migration(&mut self, key: (Pid, VPage), latency: u64) {
        let e = self.entry_mut(key);
        e.migrations += 1;
        e.mig_lat_hist.push(latency as f32);
    }

    /// The agent took `action` with this page as the remap target.
    pub fn on_action(&mut self, key: (Pid, VPage), action: u8) {
        let e = self.entry_mut(key);
        e.action_hist.push(action as f32);
    }

    pub fn get(&self, key: &(Pid, VPage)) -> Option<&PageInfo> {
        self.entries.get(key)
    }

    /// The most frequently accessed page currently cached — the paper's
    /// "highly accessed page" selected as the remapping candidate.
    pub fn hottest(&self) -> Option<((Pid, VPage), &PageInfo)> {
        self.entries
            .iter() // detlint: allow(hash-iter) — max_by_key over a total order
            .max_by_key(|(k, e)| (e.accesses, std::cmp::Reverse(*k)))
            .map(|(k, e)| (*k, e))
    }

    /// Remap-candidate selection: the most-accessed page NOT supplied
    /// recently, rotating the agent through the active set. Falls back to
    /// the overall hottest when everything is recent.
    pub fn select_candidate(&mut self) -> Option<(Pid, VPage)> {
        let ring = self.capacity / 2;
        let pick = self
            .entries
            .iter() // detlint: allow(hash-iter) — max_by_key over a total order
            .filter(|(k, _)| !self.recent_selected.contains(k))
            .max_by_key(|(k, e)| (e.accesses, std::cmp::Reverse(**k)))
            .map(|(k, _)| *k)
            .or_else(|| self.hottest().map(|(k, _)| k))?;
        self.recent_selected.push_back(pick);
        while self.recent_selected.len() > ring {
            self.recent_selected.pop_front();
        }
        Some(pick)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fraction of all recorded accesses that hit `key`'s page (the
    /// "page access rate" state field).
    pub fn access_rate(&self, key: &(Pid, VPage)) -> f32 {
        match (self.entries.get(key), self.total_accesses) {
            (Some(e), t) if t > 0 => e.accesses as f32 / t as f32,
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_creates_and_counts() {
        let mut c = PageInfoCache::new(4);
        c.on_dispatch((1, 10), 3, 7, 2);
        c.on_dispatch((1, 10), 5, 8, 4);
        let e = c.get(&(1, 10)).unwrap();
        assert_eq!(e.accesses, 2);
        assert_eq!(e.last_src1_cube, 8);
        assert_eq!(e.hop_hist.last(), Some(5.0));
        assert!((c.access_rate(&(1, 10)) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn lfu_evicts_coldest() {
        let mut c = PageInfoCache::new(2);
        c.on_dispatch((1, 1), 0, 0, 0);
        c.on_dispatch((1, 1), 0, 0, 0);
        c.on_dispatch((1, 2), 0, 0, 0);
        c.on_dispatch((1, 3), 0, 0, 0); // evicts (1,2): fewest accesses
        assert!(c.get(&(1, 1)).is_some());
        assert!(c.get(&(1, 2)).is_none());
        assert!(c.get(&(1, 3)).is_some());
        assert_eq!(c.evictions, 1);
    }

    #[test]
    fn lfu_ties_break_by_lowest_key() {
        let mut c = PageInfoCache::new(2);
        c.on_dispatch((1, 5), 0, 0, 0);
        c.on_dispatch((1, 2), 0, 0, 0);
        // Both cached pages have one access; the insert below must evict
        // the lowest key, (1, 2) — deterministically, on every thread.
        c.on_dispatch((1, 9), 0, 0, 0);
        assert!(c.get(&(1, 2)).is_none());
        assert!(c.get(&(1, 5)).is_some());
        assert!(c.get(&(1, 9)).is_some());
    }

    #[test]
    fn hottest_by_access_count() {
        let mut c = PageInfoCache::new(4);
        for _ in 0..5 {
            c.on_dispatch((1, 9), 0, 0, 0);
        }
        c.on_dispatch((1, 2), 0, 0, 0);
        assert_eq!(c.hottest().unwrap().0, (1, 9));
    }

    #[test]
    fn ack_without_entry_is_noop() {
        let mut c = PageInfoCache::new(2);
        c.on_ack((1, 99), 100);
        assert!(c.is_empty());
    }

    #[test]
    fn migration_stats_tracked() {
        let mut c = PageInfoCache::new(2);
        c.on_dispatch((1, 1), 0, 0, 0);
        c.on_migration((1, 1), 400);
        let e = c.get(&(1, 1)).unwrap();
        assert_eq!(e.migrations, 1);
        assert_eq!(e.mig_lat_hist.last(), Some(400.0));
        assert!((e.migrations_per_access() - 1.0).abs() < 1e-6);
    }
}
