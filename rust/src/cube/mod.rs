//! 3D-stacked memory cube: 32 vaults × 8 banks with open-page row-buffer
//! timing, a crossbar from the base-die logic to the vaults, the NMP-op
//! table and the near-memory compute unit (Table 1, §6.2).

pub mod bank;
pub mod cube;
pub mod nmp_table;

pub use bank::{Bank, MemAccess, MemAccessKind, Vault};
pub use cube::{AccessTag, Cube, CubeStats};
pub use nmp_table::{EntryState, NmpEntry, NmpTable};

use crate::config::CubeId;

/// A physical address: host cube plus byte offset inside that cube.
///
/// The paper's two-step mapping (Fig 1) ends here: the paging system picks
/// the cube (frame), and the in-cube DRAM mapping decodes the offset into
/// vault / bank / row.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PhysAddr {
    pub cube: CubeId,
    pub offset: u64,
}

impl PhysAddr {
    pub fn new(cube: CubeId, offset: u64) -> Self {
        Self { cube, offset }
    }
}

/// In-cube DRAM address mapping: byte offset → (vault, bank, row).
///
/// Low-order interleaving below the row: 64 B blocks stripe across vaults
/// then banks, which spreads sequential pages over all vaults for
/// memory-level parallelism (the classic physical-to-DRAM mapping the
/// paper's §2 references).
#[derive(Debug, Clone)]
pub struct DramMap {
    pub vaults: usize,
    pub banks: usize,
    /// Row size in bytes (per bank).
    pub row_bytes: u64,
}

impl DramMap {
    pub fn new(vaults: usize, banks: usize) -> Self {
        Self { vaults, banks, row_bytes: 2048 }
    }

    /// Decode an in-cube offset.
    pub fn decode(&self, offset: u64) -> (usize, usize, u64) {
        let block = offset >> 6; // 64 B blocks
        let vault = (block as usize) & (self.vaults - 1);
        let bank = ((block as usize) >> self.vaults.trailing_zeros()) & (self.banks - 1);
        let within = block >> (self.vaults.trailing_zeros() + self.banks.trailing_zeros());
        let row = within / (self.row_bytes / 64);
        (vault, bank, row)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_strides_vaults_first() {
        let m = DramMap::new(32, 8);
        let (v0, b0, _) = m.decode(0);
        let (v1, b1, _) = m.decode(64);
        assert_eq!((v0, b0), (0, 0));
        assert_eq!((v1, b1), (1, 0));
        let (v32, b32, _) = m.decode(64 * 32);
        assert_eq!((v32, b32), (0, 1));
    }

    #[test]
    fn decode_in_range() {
        let m = DramMap::new(32, 8);
        for i in 0..10_000u64 {
            let (v, b, _) = m.decode(i * 64 + (i % 64));
            assert!(v < 32);
            assert!(b < 8);
        }
    }

    #[test]
    fn same_row_for_adjacent_blocks_same_bank() {
        let m = DramMap::new(32, 8);
        // Two offsets mapping to same (vault,bank) and adjacent 64B blocks
        // within a row must share the row.
        let a = m.decode(0);
        let b = m.decode(64 * 32 * 8); // next block on (vault 0, bank 0)
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2, "2 KiB row holds 32 blocks per bank");
    }
}
