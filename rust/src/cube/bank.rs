//! DRAM bank and vault-controller timing model.
//!
//! Open-page policy: a bank keeps its last row latched in the row buffer;
//! hits cost `row_hit` cycles, conflicts/misses cost `row_miss`. The
//! per-cube *average row buffer hit rate* these banks report is one of the
//! system-state inputs to the AIMM agent (§5.1).

use crate::sim::{BoundedQueue, Cycle};

/// What a memory access does. Reads and writes share timing in this model
/// (write-through row buffer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemAccessKind {
    Read,
    Write,
}

/// One 64-byte-granularity access queued at a vault controller.
#[derive(Debug, Clone)]
pub struct MemAccess<T> {
    pub offset: u64,
    pub kind: MemAccessKind,
    /// Caller-defined completion tag (protocol continuation).
    pub tag: T,
}

/// One DRAM bank: open row + busy window + hit statistics.
#[derive(Debug, Clone)]
pub struct Bank {
    open_row: Option<u64>,
    busy_until: Cycle,
    pub accesses: u64,
    pub row_hits: u64,
}

impl Default for Bank {
    fn default() -> Self {
        Self { open_row: None, busy_until: 0, accesses: 0, row_hits: 0 }
    }
}

impl Bank {
    pub fn is_free(&self, now: Cycle) -> bool {
        self.busy_until <= now
    }

    /// Cycle at which the bank next accepts an access — the wakeup the
    /// event engine files for a vault whose head access waits on this
    /// bank (DESIGN.md §8).
    pub fn free_at(&self) -> Cycle {
        self.busy_until
    }

    /// Start an access to `row`; returns its latency.
    pub fn access(&mut self, row: u64, now: Cycle, row_hit: u64, row_miss: u64) -> u64 {
        debug_assert!(self.is_free(now));
        self.accesses += 1;
        let lat = if self.open_row == Some(row) {
            self.row_hits += 1;
            row_hit
        } else {
            self.open_row = Some(row);
            row_miss
        };
        self.busy_until = now + lat;
        lat
    }

    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.row_hits as f64 / self.accesses as f64
        }
    }
}

/// A vault: its controller queue plus its banks. One access may be issued
/// per vault per cycle (TSV bandwidth), targeting a free bank.
#[derive(Debug)]
pub struct Vault<T> {
    pub queue: BoundedQueue<MemAccess<T>>,
    pub banks: Vec<Bank>,
}

impl<T> Vault<T> {
    pub fn new(banks: usize, queue_cap: usize) -> Self {
        Self {
            queue: BoundedQueue::new(queue_cap),
            banks: (0..banks).map(|_| Bank::default()).collect(),
        }
    }

    pub fn accesses(&self) -> u64 {
        self.banks.iter().map(|b| b.accesses).sum()
    }

    pub fn row_hits(&self) -> u64 {
        self.banks.iter().map(|b| b.row_hits).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_access_misses_then_hits() {
        let mut b = Bank::default();
        assert_eq!(b.access(7, 0, 14, 42), 42);
        assert!(!b.is_free(10));
        assert!(b.is_free(42));
        assert_eq!(b.access(7, 42, 14, 42), 14);
        assert_eq!(b.access(9, 60, 14, 42), 42);
        assert!((b.hit_rate() - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn vault_aggregates() {
        let mut v: Vault<()> = Vault::new(4, 8);
        v.banks[0].access(1, 0, 14, 42);
        v.banks[1].access(1, 0, 14, 42);
        v.banks[1].access(1, 100, 14, 42);
        assert_eq!(v.accesses(), 3);
        assert_eq!(v.row_hits(), 1);
    }
}
