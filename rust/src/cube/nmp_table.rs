//! The NMP-op table: per-cube bookkeeping of outstanding near-memory
//! operations (Table 1: 512 entries). Occupancy is reported to the nearest
//! MC and is part of the agent's system state (§5.1); a full table denies
//! new dispatches, which throttles the memory-network flow (§7.6).

use crate::config::{McId, VPage};
use crate::cube::PhysAddr;
use crate::noc::packet::OpToken;
use crate::sim::Cycle;

/// Lifecycle of an NMP-op table entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryState {
    /// Waiting for operand fetches (local reads and/or remote SourceResps).
    WaitingSources,
    /// In the compute queue / ALU.
    Computing,
    /// Destination write issued locally, waiting for bank completion.
    WritingDest,
    /// Remote destination write issued, waiting for WriteAck.
    WaitingWriteAck,
}

/// One outstanding NMP operation at its computation cube.
#[derive(Debug, Clone)]
pub struct NmpEntry {
    pub token: OpToken,
    pub dest: PhysAddr,
    pub dest_vpage: VPage,
    pub issuing_mc: McId,
    pub pending_sources: u8,
    pub state: EntryState,
    pub created: Cycle,
}

/// Fixed-capacity table of outstanding ops.
#[derive(Debug)]
pub struct NmpTable {
    entries: Vec<NmpEntry>,
    capacity: usize,
    /// Cumulative occupancy integral for average-occupancy reporting.
    occ_acc: u64,
    observations: u64,
    pub denied: u64,
    pub allocated_total: u64,
}

impl NmpTable {
    pub fn new(capacity: usize) -> Self {
        Self {
            entries: Vec::with_capacity(capacity.min(1024)),
            capacity,
            occ_acc: 0,
            observations: 0,
            denied: 0,
            allocated_total: 0,
        }
    }

    pub fn has_space(&self) -> bool {
        self.entries.len() < self.capacity
    }

    pub fn allocate(&mut self, entry: NmpEntry) -> Result<(), NmpEntry> {
        if !self.has_space() {
            self.denied += 1;
            return Err(entry);
        }
        self.allocated_total += 1;
        self.entries.push(entry);
        Ok(())
    }

    pub fn get_mut(&mut self, token: OpToken) -> Option<&mut NmpEntry> {
        self.entries.iter_mut().find(|e| e.token == token)
    }

    pub fn remove(&mut self, token: OpToken) -> Option<NmpEntry> {
        let pos = self.entries.iter().position(|e| e.token == token)?;
        Some(self.entries.swap_remove(pos))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fractional occupancy in [0, 1].
    pub fn occupancy(&self) -> f32 {
        self.entries.len() as f32 / self.capacity as f32
    }

    /// Record one per-cycle occupancy observation.
    pub fn observe(&mut self) {
        self.observe_n(1);
    }

    /// Record `n` identical observations at once (event-engine skip
    /// spans). Integer arithmetic keeps the occupancy integral
    /// bit-identical to `n` consecutive [`observe`](Self::observe)s.
    pub fn observe_n(&mut self, n: u64) {
        self.occ_acc += self.entries.len() as u64 * n;
        self.observations += n;
    }

    pub fn avg_occupancy(&self) -> f64 {
        if self.observations == 0 {
            0.0
        } else {
            self.occ_acc as f64 / (self.observations as f64 * self.capacity as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(token: OpToken) -> NmpEntry {
        NmpEntry {
            token,
            dest: PhysAddr::new(0, 0),
            dest_vpage: 0,
            issuing_mc: 0,
            pending_sources: 2,
            state: EntryState::WaitingSources,
            created: 0,
        }
    }

    #[test]
    fn allocate_until_full_then_deny() {
        let mut t = NmpTable::new(2);
        t.allocate(entry(1)).unwrap();
        t.allocate(entry(2)).unwrap();
        assert!(t.allocate(entry(3)).is_err());
        assert_eq!(t.denied, 1);
        assert_eq!(t.len(), 2);
        assert!((t.occupancy() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn remove_frees_space() {
        let mut t = NmpTable::new(1);
        t.allocate(entry(7)).unwrap();
        assert!(t.remove(7).is_some());
        assert!(t.remove(7).is_none());
        assert!(t.has_space());
    }

    #[test]
    fn occupancy_average() {
        let mut t = NmpTable::new(4);
        t.allocate(entry(1)).unwrap();
        t.observe(); // 1/4
        t.allocate(entry(2)).unwrap();
        t.observe(); // 2/4
        assert!((t.avg_occupancy() - 0.375).abs() < 1e-9);
    }
}
