//! The memory-cube component: base-die NMP logic, vault/bank timing and
//! the protocol state machine tying dispatches, operand fetches, compute
//! and write-back together (§6.2, BNMP op flow in §6.3).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::config::{CubeId, McId, SystemConfig};
use crate::noc::packet::{MigToken, NodeId, OpToken, Packet, Payload};
use crate::sim::Cycle;

use super::bank::{MemAccess, MemAccessKind, Vault};
use super::nmp_table::{EntryState, NmpEntry, NmpTable};
use super::{DramMap, PhysAddr};

/// Completion continuation for a vault access.
#[derive(Debug, Clone)]
pub enum AccessTag {
    /// Local operand read for an op computing in this cube.
    LocalSource { token: OpToken },
    /// Operand read on behalf of a remote compute cube.
    RemoteSource { token: OpToken, reply_to: CubeId },
    /// Local destination write; completes the op.
    DestWrite { token: OpToken },
    /// Destination write on behalf of a remote compute cube (LDB /
    /// compute-remapped paths).
    RemoteDestWrite { token: OpToken, reply_to: CubeId },
    /// Migration chunk read at the old host.
    MigChunkRead { token: MigToken, chunk: u32, new: CubeId },
    /// Migration chunk write at the new host.
    MigChunkWrite { token: MigToken, chunk: u32 },
}

/// Per-cube statistics (feed Fig 7/8/13 and the energy model).
#[derive(Debug, Clone, Default)]
pub struct CubeStats {
    pub ops_completed: u64,
    pub compute_busy: u64,
    pub mem_accesses: u64,
    /// NMP-op-table touches (allocate/update/remove) for the 0.122 nJ
    /// per-access energy constant (§7.7).
    pub nmp_table_touches: u64,
    /// Phase-latency integrals for profiling: entry-creation → sources
    /// ready, → compute done, → op finished (ACK sent).
    pub wait_sources_sum: u64,
    pub wait_finish_sum: u64,
    /// Cycles dispatches spent parked in the inbox (table full).
    pub inbox_wait_sum: u64,
}

/// Deterministically ordered completion event.
#[derive(Debug)]
struct Completion {
    at: Cycle,
    seq: u64,
    tag: AccessTag,
}

impl PartialEq for Completion {
    fn eq(&self, other: &Self) -> bool {
        (self.at, self.seq) == (other.at, other.seq)
    }
}
impl Eq for Completion {}
impl PartialOrd for Completion {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Completion {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// One memory cube.
pub struct Cube {
    pub id: CubeId,
    pub map: DramMap,
    pub vaults: Vec<Vault<AccessTag>>,
    pub table: NmpTable,
    /// Dispatches denied by a full table wait here (backpressure).
    inbox: VecDeque<Packet>,
    /// Accesses that found their vault queue full.
    retry: VecDeque<MemAccess<AccessTag>>,
    completions: BinaryHeap<Reverse<Completion>>,
    seq: u64,
    /// Tokens ready for the base-die ALU.
    compute_q: VecDeque<OpToken>,
    alu_free_at: Cycle,
    /// Compute completions (token, ready-at).
    compute_done: BinaryHeap<Reverse<(Cycle, u64, OpToken)>>,
    /// Pending vault accesses (fast-skip for the vault scan).
    pending_accesses: u32,
    /// Outgoing packets awaiting injection (drained by the system).
    pub out: VecDeque<Packet>,
    pub stats: CubeStats,
    /// Where migration chunk ACKs go (the MDMA's home MC).
    mdma_home: McId,
    row_hit: u64,
    row_miss: u64,
    nmp_compute: u64,
}

impl Cube {
    pub fn new(id: CubeId, cfg: &SystemConfig) -> Self {
        let vaults = (0..cfg.vaults_per_cube)
            .map(|_| Vault::new(cfg.banks_per_vault, 16))
            .collect();
        Self {
            id,
            map: DramMap::new(cfg.vaults_per_cube, cfg.banks_per_vault),
            vaults,
            table: NmpTable::new(cfg.nmp_table_entries),
            inbox: VecDeque::new(),
            retry: VecDeque::new(),
            completions: BinaryHeap::new(),
            seq: 0,
            compute_q: VecDeque::new(),
            alu_free_at: 0,
            compute_done: BinaryHeap::new(),
            pending_accesses: 0,
            out: VecDeque::new(),
            stats: CubeStats::default(),
            mdma_home: 0,
            row_hit: cfg.timing.row_hit,
            row_miss: cfg.timing.row_miss,
            nmp_compute: cfg.timing.nmp_compute,
        }
    }

    /// Average row-buffer hit rate across all banks (agent state input).
    pub fn row_hit_rate(&self) -> f64 {
        let (acc, hits) = self.vaults.iter().fold((0u64, 0u64), |(a, h), v| {
            (a + v.accesses(), h + v.row_hits())
        });
        if acc == 0 {
            0.0
        } else {
            hits as f64 / acc as f64
        }
    }

    /// Work still pending anywhere inside the cube.
    pub fn is_idle(&self) -> bool {
        self.table.is_empty()
            && self.inbox.is_empty()
            && self.retry.is_empty()
            && self.completions.is_empty()
            && self.compute_q.is_empty()
            && self.compute_done.is_empty()
            && self.out.is_empty()
            && self.vaults.iter().all(|v| v.queue.is_empty())
    }

    /// Earliest cycle ≥ `now` at which this cube's [`tick`](Self::tick)
    /// can do more than per-cycle accounting (event engine, DESIGN.md
    /// §8): retry/injection backlogs arbitrate every cycle; a vault with
    /// a queued head access wakes when that access's bank frees; bank
    /// and ALU completions mature at their scheduled cycles. `None`
    /// means the cube is quiescent until an external delivery.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        // Nothing files earlier than `now`: short-circuit the vault scan
        // as soon as an immediate event is certain (hot in busy phases).
        if !self.out.is_empty() || !self.retry.is_empty() {
            return Some(now);
        }
        let mut next = Cycle::MAX;
        if !self.compute_q.is_empty() {
            if self.alu_free_at <= now {
                return Some(now);
            }
            next = self.alu_free_at;
        }
        if self.pending_accesses > 0 {
            for vault in &self.vaults {
                if let Some(head) = vault.queue.peek() {
                    let (_, bank, _) = self.map.decode(head.offset);
                    let at = vault.banks[bank].free_at();
                    if at <= now {
                        return Some(now);
                    }
                    next = next.min(at);
                }
            }
        }
        if let Some(Reverse(c)) = self.completions.peek() {
            next = next.min(now.max(c.at));
        }
        if let Some(&Reverse((at, _, _))) = self.compute_done.peek() {
            next = next.min(now.max(at));
        }
        (next != Cycle::MAX).then_some(next.max(now))
    }

    /// Bulk-apply `span` skipped cycles of per-cycle accounting (the
    /// `table.observe()` each polled tick performs) — bit-identical to
    /// `span` consecutive quiescent ticks.
    pub fn observe_span(&mut self, span: u64) {
        self.table.observe_n(span);
    }

    /// Handle a packet delivered to this cube.
    pub fn receive(&mut self, pk: Packet, now: Cycle) {
        match pk.payload.clone() {
            Payload::NmpDispatch { .. } => {
                self.inbox.push_back(pk);
                self.drain_inbox(now);
            }
            Payload::SourceReq { token, addr, reply_to } => {
                debug_assert_eq!(addr.cube, self.id);
                self.queue_access(
                    addr.offset,
                    MemAccessKind::Read,
                    AccessTag::RemoteSource { token, reply_to },
                );
            }
            Payload::SourceResp { token, .. } => {
                self.operand_arrived(token, now);
            }
            Payload::WriteReq { token, addr, reply_to } => {
                debug_assert_eq!(addr.cube, self.id);
                self.queue_access(
                    addr.offset,
                    MemAccessKind::Write,
                    AccessTag::RemoteDestWrite { token, reply_to },
                );
            }
            Payload::WriteAck { token } => {
                self.finish_op(token, now);
            }
            Payload::MigRead { token, chunk, new, .. } => {
                self.queue_access(
                    (chunk as u64) << 8,
                    MemAccessKind::Read,
                    AccessTag::MigChunkRead { token, chunk, new },
                );
            }
            Payload::MigChunk { token, chunk, .. } => {
                self.queue_access(
                    (chunk as u64) << 8,
                    MemAccessKind::Write,
                    AccessTag::MigChunkWrite { token, chunk },
                );
            }
            Payload::NmpAck { .. } | Payload::MigChunkAck { .. } => {
                unreachable!("MC-bound payload delivered to a cube");
            }
        }
    }

    fn queue_access(&mut self, offset: u64, kind: MemAccessKind, tag: AccessTag) {
        let (vault, _, _) = self.map.decode(offset);
        let acc = MemAccess { offset, kind, tag };
        self.pending_accesses += 1;
        if let Err(acc) = self.vaults[vault].queue.push(acc) {
            self.retry.push_back(acc);
        }
    }

    /// Admit queued dispatches while the table has space.
    fn drain_inbox(&mut self, now: Cycle) {
        while self.table.has_space() {
            let Some(pk) = self.inbox.pop_front() else { break };
            let Payload::NmpDispatch { token, dest, src1, src2, carried_operands, dest_vpage } =
                pk.payload
            else {
                unreachable!()
            };
            let issuing_mc = match pk.src {
                NodeId::Mc(m) => m,
                NodeId::Cube(_) => unreachable!("dispatch must come from an MC"),
            };
            let mut sources: Vec<PhysAddr> = Vec::with_capacity(2);
            sources.push(src1);
            if let Some(s2) = src2 {
                sources.push(s2);
            }
            // PEI may carry operand data inline; those need no fetch.
            let needed = sources.len().saturating_sub(carried_operands as usize);
            let entry = NmpEntry {
                token,
                dest,
                dest_vpage,
                issuing_mc,
                pending_sources: needed as u8,
                state: if needed == 0 { EntryState::Computing } else { EntryState::WaitingSources },
                created: now,
            };
            self.stats.nmp_table_touches += 1;
            self.table
                .allocate(entry)
                .unwrap_or_else(|_| unreachable!("space checked above"));
            if needed == 0 {
                self.compute_q.push_back(token);
            } else {
                for src in sources.into_iter().skip(carried_operands as usize) {
                    if src.cube == self.id {
                        self.queue_access(
                            src.offset,
                            MemAccessKind::Read,
                            AccessTag::LocalSource { token },
                        );
                    } else {
                        let id = token;
                        self.out.push_back(Packet::new(
                            id,
                            NodeId::Cube(self.id),
                            NodeId::Cube(src.cube),
                            Payload::SourceReq { token, addr: src, reply_to: self.id },
                            now,
                        ));
                    }
                }
            }
        }
    }

    /// One operand (local read or remote response) became available.
    fn operand_arrived(&mut self, token: OpToken, now: Cycle) {
        self.stats.nmp_table_touches += 1;
        let mut ready = false;
        if let Some(e) = self.table.get_mut(token) {
            debug_assert!(e.pending_sources > 0);
            e.pending_sources -= 1;
            if e.pending_sources == 0 {
                e.state = EntryState::Computing;
                self.compute_q.push_back(token);
                ready = true;
            }
        }
        if ready {
            self.note_sources_ready(token, now);
        }
    }

    /// Record the sources-ready phase boundary for profiling.
    fn note_sources_ready(&mut self, token: OpToken, now: Cycle) {
        if let Some(e) = self.table.get_mut(token) {
            self.stats.wait_sources_sum += now.saturating_sub(e.created);
        }
    }

    /// Destination write finished (locally or via remote ACK): op done.
    fn finish_op(&mut self, token: OpToken, now: Cycle) {
        self.stats.nmp_table_touches += 1;
        if let Some(e) = self.table.remove(token) {
            self.stats.ops_completed += 1;
            self.stats.wait_finish_sum += now.saturating_sub(e.created);
            self.out.push_back(Packet::new(
                token,
                NodeId::Cube(self.id),
                NodeId::Mc(e.issuing_mc),
                Payload::NmpAck { token, compute_cube: self.id },
                now,
            ));
            // Newly freed entry may admit a parked dispatch.
            self.drain_inbox(now);
        }
    }

    /// Advance the cube one cycle.
    pub fn tick(&mut self, now: Cycle) {
        // Retry accesses that found a full vault queue.
        for _ in 0..self.retry.len() {
            let Some(acc) = self.retry.pop_front() else { break };
            let (vault, _, _) = self.map.decode(acc.offset);
            if let Err(acc) = self.vaults[vault].queue.push(acc) {
                self.retry.push_back(acc);
                break; // keep FIFO order, try again next cycle
            }
        }

        // Vault controllers: issue at most one access per vault per cycle
        // (skipped entirely when no access is pending anywhere).
        if self.pending_accesses > 0 {
            for vault in &mut self.vaults {
                let Some(head) = vault.queue.peek() else { continue };
                let (_, bank, row) = self.map.decode(head.offset);
                if vault.banks[bank].is_free(now) {
                    let acc = vault.queue.pop().unwrap();
                    self.pending_accesses -= 1;
                    let lat = vault.banks[bank].access(row, now, self.row_hit, self.row_miss);
                    self.stats.mem_accesses += 1;
                    self.seq += 1;
                    self.completions
                        .push(Reverse(Completion { at: now + lat, seq: self.seq, tag: acc.tag }));
                }
            }
        }

        // Matured bank completions → protocol continuations.
        while let Some(Reverse(head)) = self.completions.peek() {
            if head.at > now {
                break;
            }
            let Reverse(c) = self.completions.pop().unwrap();
            match c.tag {
                AccessTag::LocalSource { token } => self.operand_arrived(token, now),
                AccessTag::RemoteSource { token, reply_to } => {
                    self.out.push_back(Packet::new(
                        token,
                        NodeId::Cube(self.id),
                        NodeId::Cube(reply_to),
                        Payload::SourceResp { token, addr: PhysAddr::new(self.id, 0) },
                        now,
                    ));
                }
                AccessTag::DestWrite { token } => self.finish_op(token, now),
                AccessTag::RemoteDestWrite { token, reply_to } => {
                    self.out.push_back(Packet::new(
                        token,
                        NodeId::Cube(self.id),
                        NodeId::Cube(reply_to),
                        Payload::WriteAck { token },
                        now,
                    ));
                }
                AccessTag::MigChunkRead { token, chunk, new } => {
                    self.out.push_back(Packet::new(
                        token,
                        NodeId::Cube(self.id),
                        NodeId::Cube(new),
                        Payload::MigChunk { token, chunk, new },
                        now,
                    ));
                }
                AccessTag::MigChunkWrite { token, chunk } => {
                    self.out.push_back(Packet::new(
                        token,
                        NodeId::Cube(self.id),
                        NodeId::Mc(self.mdma_home),
                        Payload::MigChunkAck { token, chunk },
                        now,
                    ));
                }
            }
        }

        // Base-die FU: pipelined — one op issues per cycle, each takes
        // `nmp_compute` cycles to produce its result.
        if self.alu_free_at <= now {
            if let Some(token) = self.compute_q.pop_front() {
                self.alu_free_at = now + 1;
                self.stats.compute_busy += 1;
                self.seq += 1;
                self.compute_done.push(Reverse((now + self.nmp_compute, self.seq, token)));
            }
        }

        // Computation finished → write destination.
        while let Some(&Reverse((at, _, _))) = self.compute_done.peek() {
            if at > now {
                break;
            }
            let Reverse((_, _, token)) = self.compute_done.pop().unwrap();
            let Some(e) = self.table.get_mut(token) else { continue };
            let dest = e.dest;
            if dest.cube == self.id {
                e.state = EntryState::WritingDest;
                self.queue_access(
                    dest.offset,
                    MemAccessKind::Write,
                    AccessTag::DestWrite { token },
                );
            } else {
                e.state = EntryState::WaitingWriteAck;
                self.out.push_back(Packet::new(
                    token,
                    NodeId::Cube(self.id),
                    NodeId::Cube(dest.cube),
                    Payload::WriteReq { token, addr: dest, reply_to: self.id },
                    now,
                ));
            }
        }

        self.table.observe();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn dispatch(token: OpToken, cube: CubeId, dest: PhysAddr, src1: PhysAddr) -> Packet {
        Packet::new(
            token,
            NodeId::Mc(0),
            NodeId::Cube(cube),
            Payload::NmpDispatch {
                token,
                dest,
                src1,
                src2: None,
                carried_operands: 0,
                dest_vpage: 0,
            },
            0,
        )
    }

    fn run(cube: &mut Cube, cycles: u64) {
        for now in 0..cycles {
            cube.tick(now);
        }
    }

    #[test]
    fn local_op_completes_and_acks() {
        let cfg = SystemConfig::default();
        let mut cube = Cube::new(3, &cfg);
        cube.receive(dispatch(1, 3, PhysAddr::new(3, 0), PhysAddr::new(3, 4096)), 0);
        run(&mut cube, 500);
        let acks: Vec<_> = cube
            .out
            .iter()
            .filter(|p| matches!(p.payload, Payload::NmpAck { .. }))
            .collect();
        assert_eq!(acks.len(), 1);
        assert_eq!(acks[0].dst, NodeId::Mc(0));
        assert_eq!(cube.stats.ops_completed, 1);
        assert!(cube.table.is_empty());
    }

    #[test]
    fn remote_source_emits_request() {
        let cfg = SystemConfig::default();
        let mut cube = Cube::new(0, &cfg);
        cube.receive(dispatch(9, 0, PhysAddr::new(0, 0), PhysAddr::new(5, 64)), 0);
        run(&mut cube, 5);
        let reqs: Vec<_> = cube
            .out
            .iter()
            .filter(|p| matches!(p.payload, Payload::SourceReq { .. }))
            .collect();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].dst, NodeId::Cube(5));
        // Op not complete until the response arrives.
        assert_eq!(cube.stats.ops_completed, 0);

        // Simulate the response arriving.
        cube.receive(
            Packet::new(
                9,
                NodeId::Cube(5),
                NodeId::Cube(0),
                Payload::SourceResp { token: 9, addr: PhysAddr::new(5, 64) },
                10,
            ),
            10,
        );
        for now in 10..600 {
            cube.tick(now);
        }
        assert_eq!(cube.stats.ops_completed, 1);
    }

    #[test]
    fn table_full_parks_dispatches() {
        let mut cfg = SystemConfig::default();
        cfg.nmp_table_entries = 1;
        let mut cube = Cube::new(0, &cfg);
        // Two ops with remote sources so the first stays outstanding.
        cube.receive(dispatch(1, 0, PhysAddr::new(0, 0), PhysAddr::new(5, 0)), 0);
        cube.receive(dispatch(2, 0, PhysAddr::new(0, 64), PhysAddr::new(6, 0)), 0);
        run(&mut cube, 3);
        assert_eq!(cube.table.len(), 1);
        // Only the admitted op fetched its source.
        let reqs = cube
            .out
            .iter()
            .filter(|p| matches!(p.payload, Payload::SourceReq { .. }))
            .count();
        assert_eq!(reqs, 1);
    }

    #[test]
    fn remote_dest_write_path() {
        let cfg = SystemConfig::default();
        let mut cube = Cube::new(2, &cfg);
        // Dest lives in cube 7: after compute we must see a WriteReq, and
        // the op completes only on WriteAck.
        cube.receive(dispatch(4, 2, PhysAddr::new(7, 0), PhysAddr::new(2, 64)), 0);
        run(&mut cube, 500);
        assert!(cube
            .out
            .iter()
            .any(|p| matches!(p.payload, Payload::WriteReq { .. }) && p.dst == NodeId::Cube(7)));
        assert_eq!(cube.stats.ops_completed, 0);
        cube.receive(
            Packet::new(4, NodeId::Cube(7), NodeId::Cube(2), Payload::WriteAck { token: 4 }, 500),
            500,
        );
        for now in 500..520 {
            cube.tick(now);
        }
        assert_eq!(cube.stats.ops_completed, 1);
    }

    #[test]
    fn migration_chunks_forwarded() {
        let cfg = SystemConfig::default();
        let mut cube = Cube::new(1, &cfg);
        cube.receive(
            Packet::new(
                100,
                NodeId::Mc(0),
                NodeId::Cube(1),
                Payload::MigRead { token: 77, chunk: 0, old: 1, new: 9 },
                0,
            ),
            0,
        );
        run(&mut cube, 200);
        assert!(cube
            .out
            .iter()
            .any(|p| matches!(p.payload, Payload::MigChunk { token: 77, .. })
                && p.dst == NodeId::Cube(9)));
    }

    #[test]
    fn next_event_tracks_pending_work() {
        let cfg = SystemConfig::default();
        let mut cube = Cube::new(0, &cfg);
        assert_eq!(cube.next_event(0), None, "fresh cube is quiescent");
        cube.receive(dispatch(1, 0, PhysAddr::new(0, 0), PhysAddr::new(0, 4096)), 0);
        // Local source read queued: the vault can issue it immediately.
        assert_eq!(cube.next_event(0), Some(0));
        // Drive to completion: while busy the cube must always report a
        // wakeup no earlier than `now`, and must go silent once idle.
        let mut now = 0;
        while !cube.is_idle() {
            let at = cube.next_event(now).expect("busy cube must report an event");
            assert!(at >= now, "wakeup {at} before now {now}");
            cube.tick(now);
            cube.out.clear(); // the system would drain these
            now += 1;
            assert!(now < 1000);
        }
        assert_eq!(cube.next_event(now), None);
    }

    #[test]
    fn observe_span_matches_repeated_ticks() {
        let cfg = SystemConfig::default();
        let mut a = Cube::new(0, &cfg);
        let mut b = Cube::new(0, &cfg);
        for cube in [&mut a, &mut b] {
            cube.table
                .allocate(NmpEntry {
                    token: 1,
                    dest: PhysAddr::new(0, 0),
                    dest_vpage: 0,
                    issuing_mc: 0,
                    pending_sources: 2,
                    state: EntryState::WaitingSources,
                    created: 0,
                })
                .unwrap();
        }
        for _ in 0..25 {
            a.table.observe(); // what 25 quiescent polled ticks apply
        }
        b.observe_span(25);
        assert_eq!(a.table.avg_occupancy().to_bits(), b.table.avg_occupancy().to_bits());
        assert!(a.table.avg_occupancy() > 0.0);
    }

    #[test]
    fn row_hit_rate_reported() {
        let cfg = SystemConfig::default();
        let mut cube = Cube::new(0, &cfg);
        // Same page, sequential 64B blocks: vault-strided so most are
        // misses; just assert the rate is within [0,1] and accesses count.
        for i in 0..8 {
            let pk = dispatch(i, 0, PhysAddr::new(0, i * 64), PhysAddr::new(0, 4096 + i * 64));
            cube.receive(pk, 0);
        }
        run(&mut cube, 2000);
        assert!(cube.stats.mem_accesses >= 16);
        let r = cube.row_hit_rate();
        assert!((0.0..=1.0).contains(&r));
    }
}
