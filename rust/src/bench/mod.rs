//! Self-contained measurement harness (the offline crate universe has no
//! criterion), the paper-figure table generators shared by the CLI
//! (`aimm table --fig N`) and the `cargo bench` targets, and the parallel
//! design-space sweep harness behind `aimm sweep` ([`sweep`]).

pub mod figures;
pub mod harness;
pub mod sweep;

pub use figures::*;
pub use harness::{bench_fn, BenchResult, Table};
pub use sweep::{
    run_grid, CellResult, ContinualSequence, SweepCell, SweepGrid,
};
