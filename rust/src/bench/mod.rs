//! Self-contained measurement harness (the offline crate universe has no
//! criterion) plus the paper-figure table generators shared by the CLI
//! (`aimm table --fig N`) and the `cargo bench` targets.

pub mod figures;
pub mod harness;

pub use figures::*;
pub use harness::{bench_fn, BenchResult, Table};
