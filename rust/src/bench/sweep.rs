//! Parallel design-space sweep harness (DESIGN.md §6.3).
//!
//! A sweep is a grid of [`SweepCell`]s — benchmark (or multi-program
//! combination) × offloading technique × mapping scheme × mesh dims ×
//! cube-network topology × HOARD × seed — fanned across OS worker
//! threads. Each cell builds its
//! own [`SystemConfig`] from its own seed and runs the §6.1 episode
//! protocol through [`crate::coordinator::run_cell`], so per-cell results
//! are **byte-identical for any worker count**: the simulator holds no
//! global state, and every map reduction on the simulation path breaks
//! ties deterministically (never by hash-iteration order, which differs
//! between threads).
//!
//! Results are collected through an mpsc channel tagged with the cell's
//! grid index and re-ordered into grid order, then rendered either as a
//! table (`aimm sweep`) or as a machine-readable `BENCH_sweep.json`
//! report with a fixed key order ([`report_json`]). The figure harnesses
//! for Figs 6, 11 and 12 are grids over this module; Fig 5's per-bench
//! trace analysis fans out through [`parallel_map`].

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::config::{Engine, MappingScheme, SystemConfig, Technique, TopologyKind};
use crate::coordinator::{run_cell, EpisodeSummary};
use crate::metrics::RunStats;
use crate::sim::Rng;
use crate::workloads::Benchmark;

/// One grid cell: everything needed to reproduce one episode family.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// One entry = single-program episode; several = multi-program.
    pub benches: Vec<Benchmark>,
    pub technique: Technique,
    pub mapping: MappingScheme,
    /// Grid dimensions (cols, rows).
    pub mesh: (usize, usize),
    /// Cube-network topology. `Mesh` is the default and keeps the cell's
    /// name and JSON byte-identical to pre-topology reports (the golden
    /// fixture); torus/ring cells carry an extra name segment and a
    /// `topology` JSON field.
    pub topology: TopologyKind,
    pub hoard: bool,
    /// Master seed for this cell's config (trace + all RNG streams).
    pub seed: u64,
    pub scale: f64,
    pub runs: usize,
    /// Simulation engine. Deliberately excluded from [`SweepCell::name`]
    /// and the JSON report: both engines produce bit-identical stats
    /// (DESIGN.md §8), so polled and event sweeps of the same grid must
    /// diff clean cell-by-cell.
    pub engine: Engine,
}

impl SweepCell {
    /// Human-readable cell label for tables and logs. Includes the seed
    /// so replicate rows (`--seeds N,M`) stay distinguishable.
    pub fn name(&self) -> String {
        let combo =
            self.benches.iter().map(|b| b.name()).collect::<Vec<_>>().join("-");
        // The topology segment appears only off-default, so mesh cell
        // names (and the golden fixture pinning them) never change.
        let topology = match self.topology {
            TopologyKind::Mesh => String::new(),
            other => format!("/{}", other.name()),
        };
        format!(
            "{}/{}/{}/{}x{}{}{}/s{:x}",
            combo,
            self.technique,
            self.mapping,
            self.mesh.0,
            self.mesh.1,
            topology,
            if self.hoard { "/HOARD" } else { "" },
            self.seed,
        )
    }

    /// The cell's full system configuration.
    pub fn config(&self) -> anyhow::Result<SystemConfig> {
        let mut cfg = SystemConfig::default();
        cfg.technique = self.technique;
        cfg.mapping = self.mapping;
        cfg.mesh_cols = self.mesh.0;
        cfg.mesh_rows = self.mesh.1;
        cfg.topology = self.topology;
        cfg.hoard = self.hoard;
        cfg.seed = self.seed;
        cfg.engine = self.engine;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Execute the cell (the worker-thread body).
    pub fn run(&self) -> anyhow::Result<EpisodeSummary> {
        let cfg = self.config()?;
        run_cell(&cfg, &self.benches, self.scale, self.runs)
    }
}

/// Decorrelate a seed by `index` with no dependence on execution order.
/// The mixing core is [`sim::Rng`](crate::sim::Rng)'s splitmix64 — the
/// crate's single PRNG — fed a golden-ratio-spread combination of the
/// inputs.
pub fn derive_seed(base: u64, index: u64) -> u64 {
    Rng::new(base ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64()
}

/// The workload seed for a benchmark combination: a fold of the combo's
/// identity into `base`. Depends only on *what* runs — never on grid
/// position or scheduling — so a (bench, technique, mapping) cell reports
/// identical numbers whether it came from a parallel grid (Figs 6/11/12),
/// a serial figure loop (Figs 7–10/13/14), or `aimm sweep`.
pub fn workload_seed(base: u64, benches: &[Benchmark]) -> u64 {
    benches.iter().fold(base, |acc, &b| derive_seed(acc, b as u64 + 1))
}

/// Axes of a sweep grid. `cells()` takes the cartesian product.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    /// Workloads; an inner vec with several entries is one multi-program
    /// combination.
    pub benches: Vec<Vec<Benchmark>>,
    pub techniques: Vec<Technique>,
    pub mappings: Vec<MappingScheme>,
    pub meshes: Vec<(usize, usize)>,
    /// Cube-network topologies (EXPERIMENTS.md §Topology). Defaults to
    /// the paper's mesh only.
    pub topologies: Vec<TopologyKind>,
    pub hoard: Vec<bool>,
    /// Base seeds; each is a replicate of the whole grid.
    pub seeds: Vec<u64>,
    pub scale: f64,
    pub runs: usize,
    /// Simulation engine for every cell — a run-wide switch, not an
    /// axis, because both engines yield identical stats (the per-cell
    /// numbers would just duplicate).
    pub engine: Engine,
}

impl SweepGrid {
    /// Default grid: all nine benchmarks under BNMP across the paper's
    /// three mapping schemes on the 4×4 mesh — 27 cells, the paper's
    /// Fig 6 BNMP slice. Deliberately [`MappingScheme::PAPER`], not
    /// `ALL`: new policies (CODA, ORACLE) join a sweep only when asked
    /// for (`--mappings`), so default reports — and the golden fixture
    /// pinned to them — never grow cells.
    pub fn new(scale: f64, runs: usize) -> Self {
        Self {
            benches: Benchmark::ALL.iter().map(|&b| vec![b]).collect(),
            techniques: vec![Technique::Bnmp],
            mappings: MappingScheme::PAPER.to_vec(),
            meshes: vec![(4, 4)],
            topologies: vec![TopologyKind::Mesh],
            hoard: vec![false],
            seeds: vec![SystemConfig::default().seed],
            scale,
            runs,
            engine: SystemConfig::default().engine,
        }
    }

    /// Cartesian product in fixed nested order: bench → technique →
    /// mapping → mesh → topology → hoard → seed (innermost fastest).
    ///
    /// Cells that differ only in technique / mapping / mesh / topology /
    /// hoard share a workload seed so scheme comparisons hold the trace
    /// constant; cells that differ in workload or base seed get
    /// decorrelated streams via [`workload_seed`], which depends only on
    /// the combo's identity — never on grid position or execution order.
    pub fn cells(&self) -> Vec<SweepCell> {
        let mut out = Vec::new();
        for benches in &self.benches {
            for &technique in &self.techniques {
                for &mapping in &self.mappings {
                    for &mesh in &self.meshes {
                        for &topology in &self.topologies {
                            for &hoard in &self.hoard {
                                for &seed in &self.seeds {
                                    out.push(SweepCell {
                                        benches: benches.clone(),
                                        technique,
                                        mapping,
                                        mesh,
                                        topology,
                                        hoard,
                                        seed: workload_seed(seed, benches),
                                        scale: self.scale,
                                        runs: self.runs,
                                        engine: self.engine,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// Worker count to use when the caller has no preference.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One executed cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub cell: SweepCell,
    pub summary: EpisodeSummary,
}

/// Fan `cells` across up to `threads` scoped workers via [`parallel_map`]
/// and pair each summary with its cell, in grid order. Every cell's
/// config is validated up front, so a bad axis value (say a 1×1 mesh)
/// fails in milliseconds instead of after hours of valid cells whose
/// finished work an error return would discard. On a runtime failure the
/// first failing cell by grid index wins.
pub fn run_grid(cells: &[SweepCell], threads: usize) -> anyhow::Result<Vec<CellResult>> {
    if cells.is_empty() {
        return Ok(Vec::new());
    }
    for (i, cell) in cells.iter().enumerate() {
        cell.config()
            .map_err(|e| anyhow::anyhow!("sweep cell {i} ({}): {e}", cell.name()))?;
    }
    let summaries = parallel_map(cells, threads, SweepCell::run);
    let mut out = Vec::with_capacity(cells.len());
    for (i, res) in summaries.into_iter().enumerate() {
        let summary = res
            .map_err(|e| anyhow::anyhow!("sweep cell {i} ({}) failed: {e}", cells[i].name()))?;
        out.push(CellResult { cell: cells[i].clone(), summary });
    }
    Ok(out)
}

/// Order-preserving parallel map over a slice — the one fan-out primitive
/// in the crate. Workers claim indices through an atomic cursor and send
/// `(index, result)` through an mpsc channel; item `i`'s result lands at
/// index `i` whatever thread computed it. [`run_grid`] and the Fig 5
/// analysis harnesses both sit on top of this.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let threads = threads.clamp(1, items.len());
    if threads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let slots: Vec<Option<R>> = std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                if tx.send((i, f(&items[i]))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots
    });
    slots
        .into_iter()
        .map(|o| o.expect("worker sent every claimed index"))
        .collect()
}

// ---------------------------------------------------------------------
// JSON report (fixed key order — runtime/json.rs can parse it back, and
// the determinism test compares these strings byte-for-byte). The
// writer primitives live in runtime/json.rs (`json::write`) and are
// shared with the agent-checkpoint format; these thin aliases keep the
// report code readable and the emitted bytes unchanged.
// ---------------------------------------------------------------------

use crate::runtime::json::write as jw;

fn jnum(x: f64) -> String {
    jw::num(x)
}

fn jstr(s: &str) -> String {
    jw::string(s)
}

fn jobj(fields: &[(&str, String)]) -> String {
    jw::obj(fields)
}

/// Serialize one run's statistics.
pub fn stats_json(r: &RunStats) -> String {
    jobj(&[
        ("cycles", r.cycles.to_string()),
        ("ops_completed", r.ops_completed.to_string()),
        ("opc", jnum(r.opc())),
        ("avg_hops", jnum(r.avg_hops)),
        ("avg_packet_latency", jnum(r.avg_packet_latency)),
        ("compute_utilization", jnum(r.compute_utilization)),
        ("compute_balance", jnum(r.compute_balance)),
        ("fraction_pages_migrated", jnum(r.fraction_pages_migrated)),
        ("fraction_accesses_on_migrated", jnum(r.fraction_accesses_on_migrated)),
        ("pages_migrated", r.pages_migrated.to_string()),
        ("migrations", r.migrations.to_string()),
        ("row_hit_rate", jnum(r.row_hit_rate)),
        ("agent_invocations", r.agent_invocations.to_string()),
        ("agent_train_steps", r.agent_train_steps.to_string()),
        ("agent_avg_loss", jnum(r.agent_avg_loss)),
        ("agent_cumulative_reward", jnum(r.agent_cumulative_reward)),
        ("energy_aimm_nj", jnum(r.energy.aimm_hardware_nj)),
        ("energy_network_nj", jnum(r.energy.network_nj)),
        ("energy_memory_nj", jnum(r.energy.memory_nj)),
        ("timeline_samples", r.opc_timeline.len().to_string()),
    ])
}

/// Serialize one executed cell: descriptor + per-run stats.
pub fn cell_json(res: &CellResult) -> String {
    let c = &res.cell;
    let benches: Vec<String> = c.benches.iter().map(|b| jstr(b.name())).collect();
    let runs: Vec<String> = res.summary.runs.iter().map(stats_json).collect();
    let mut fields: Vec<(&str, String)> = vec![
        ("name", jstr(&res.summary.name)),
        ("benches", format!("[{}]", benches.join(","))),
        ("technique", jstr(c.technique.name())),
        ("mapping", jstr(c.mapping.name())),
        ("mesh", jstr(&format!("{}x{}", c.mesh.0, c.mesh.1))),
    ];
    // Like the cell name's topology segment: emitted only off-default,
    // so pre-topology reports — and the committed golden fixture — stay
    // byte-identical for mesh grids.
    if c.topology != TopologyKind::Mesh {
        fields.push(("topology", jstr(c.topology.name())));
    }
    fields.push(("hoard", c.hoard.to_string()));
    // 0x-hex string, not a bare number: full 64-bit seeds exceed 2^53
    // and would lose bits through any double-based JSON parser
    // (including runtime/json.rs). `aimm run --seed` accepts this 0x
    // form as-is — that is the reproduce-from-report path. Feeding it
    // to `aimm sweep --seeds` would NOT reproduce the cell: grid
    // seeds are base seeds that get re-folded per combo.
    fields.push(("seed", jstr(&format!("{:#x}", c.seed))));
    fields.push(("scale", jnum(c.scale)));
    fields.push(("runs", format!("[{}]", runs.join(","))));
    jobj(&fields)
}

/// The whole report. Deliberately excludes worker count and wall-clock so
/// the file is reproducible byte-for-byte for a given grid.
pub fn report_json(results: &[CellResult]) -> String {
    let cells: Vec<String> = results.iter().map(cell_json).collect();
    jobj(&[
        ("schema", jstr("aimm-sweep-v1")),
        ("cell_count", results.len().to_string()),
        ("cells", format!("[{}]", cells.join(","))),
    ])
}

/// Write the report to `path` (the `BENCH_sweep.json` artifact).
pub fn write_report(path: &Path, results: &[CellResult]) -> anyhow::Result<()> {
    std::fs::write(path, report_json(results))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

// ---------------------------------------------------------------------
// Continual-learning report (`BENCH_continual.json`): warm-start cells.
// Same fixed-key-order discipline as the sweep report — the file is
// byte-reproducible for a given grid and parses back through
// runtime/json.rs.
// ---------------------------------------------------------------------

/// One executed curriculum sequence plus the context needed to
/// reproduce it (`aimm curriculum --stages … --seed 0x…`).
#[derive(Debug, Clone)]
pub struct ContinualSequence {
    /// Stage names joined with `>` (e.g. `SC>KM>RD`).
    pub name: String,
    pub technique: Technique,
    pub mapping: MappingScheme,
    pub scale: f64,
    /// The config's master seed (0x-hex in the report, like sweep cells).
    pub seed: u64,
    pub report: crate::coordinator::CurriculumReport,
}

fn stage_json(s: &crate::coordinator::StageOutcome) -> String {
    let warm: Vec<String> = s.warm.runs.iter().map(stats_json).collect();
    let cold: Vec<String> = s.cold.runs.iter().map(stats_json).collect();
    jobj(&[
        ("name", jstr(&s.name)),
        ("runs", s.warm.runs.len().to_string()),
        // The headline transfer numbers, then the full per-run stats.
        ("cold_first_opc", jnum(s.cold_first_opc())),
        ("warm_first_opc", jnum(s.warm_first_opc())),
        ("transfer_gain", jnum(s.transfer_gain())),
        ("cold_last_opc", jnum(s.cold.last().opc())),
        ("warm_last_opc", jnum(s.warm.last().opc())),
        ("cold", format!("[{}]", cold.join(","))),
        ("warm", format!("[{}]", warm.join(","))),
    ])
}

/// Serialize one curriculum sequence.
pub fn sequence_json(seq: &ContinualSequence) -> String {
    let stages: Vec<String> = seq.report.stages.iter().map(stage_json).collect();
    jobj(&[
        ("name", jstr(&seq.name)),
        ("technique", jstr(seq.technique.name())),
        ("mapping", jstr(seq.mapping.name())),
        ("scale", jnum(seq.scale)),
        ("seed", jstr(&format!("{:#x}", seq.seed))),
        ("stages", format!("[{}]", stages.join(","))),
    ])
}

/// The whole continual-learning report.
pub fn continual_report_json(seqs: &[ContinualSequence]) -> String {
    let body: Vec<String> = seqs.iter().map(sequence_json).collect();
    jobj(&[
        ("schema", jstr("aimm-continual-v1")),
        ("sequence_count", seqs.len().to_string()),
        ("sequences", format!("[{}]", body.join(","))),
    ])
}

/// Write the report to `path` (the `BENCH_continual.json` artifact).
pub fn write_continual_report(path: &Path, seqs: &[ContinualSequence]) -> anyhow::Result<()> {
    std::fs::write(path, continual_report_json(seqs))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_is_fig6_bnmp_slice() {
        let grid = SweepGrid::new(0.1, 2);
        assert_eq!(grid.mappings, MappingScheme::PAPER.to_vec());
        let cells = grid.cells();
        assert_eq!(cells.len(), 27); // 9 benches × 1 technique × 3 mappings
        // Mapping is the innermost populated axis.
        assert_eq!(cells[0].mapping, MappingScheme::Baseline);
        assert_eq!(cells[1].mapping, MappingScheme::Tom);
        assert_eq!(cells[2].mapping, MappingScheme::Aimm);
        // Same bench ⇒ same workload seed across mappings.
        assert_eq!(cells[0].seed, cells[2].seed);
        // Different bench ⇒ decorrelated seed.
        assert_ne!(cells[0].seed, cells[3].seed);
    }

    #[test]
    fn engine_is_a_switch_not_an_axis() {
        let mut grid = SweepGrid::new(0.1, 1);
        grid.engine = Engine::Polled;
        let cells = grid.cells();
        assert!(cells.iter().all(|c| c.engine == Engine::Polled));
        assert_eq!(cells[0].config().unwrap().engine, Engine::Polled);
        // The engine never leaks into cell names (nor the JSON report),
        // so polled and event reports of the same grid diff clean.
        assert!(!cells[0].name().to_lowercase().contains("polled"));
    }

    #[test]
    fn topology_is_an_axis_with_mesh_default_unchanged() {
        // Default grids carry only the mesh, and a mesh cell's name and
        // JSON never mention topology — pre-topology reports (and the
        // golden fixture) must stay byte-identical.
        let grid = SweepGrid::new(0.1, 1);
        assert_eq!(grid.topologies, vec![TopologyKind::Mesh]);
        let cells = grid.cells();
        assert!(cells.iter().all(|c| c.topology == TopologyKind::Mesh));
        assert!(!cells[0].name().contains("mesh"), "{}", cells[0].name());

        let mut grid = SweepGrid::new(0.1, 1);
        grid.benches = vec![vec![Benchmark::Mac]];
        grid.topologies = vec![TopologyKind::Torus, TopologyKind::Ring];
        let cells = grid.cells();
        assert_eq!(cells.len(), 6); // 1 bench × 3 mappings × 2 topologies
        assert!(cells[0].name().ends_with(&format!("/torus/s{:x}", cells[0].seed)));
        assert!(cells[1].name().contains("/ring/"));
        assert_eq!(cells[0].config().unwrap().topology, TopologyKind::Torus);
        // Same combo ⇒ same workload seed across topologies, so the
        // comparison holds the trace constant.
        assert_eq!(cells[0].seed, cells[1].seed);
    }

    #[test]
    fn cell_json_carries_topology_only_off_default() {
        let mut grid = SweepGrid::new(0.03, 1);
        grid.benches = vec![vec![Benchmark::Mac]];
        grid.mappings = vec![MappingScheme::Baseline];
        grid.topologies = vec![TopologyKind::Mesh, TopologyKind::Ring];
        let results = run_grid(&grid.cells(), 2).unwrap();
        let mesh_json = cell_json(&results[0]);
        let ring_json = cell_json(&results[1]);
        assert!(!mesh_json.contains("\"topology\""), "{mesh_json}");
        assert!(ring_json.contains("\"topology\":\"ring\""), "{ring_json}");
        // And the report still parses through the in-crate JSON parser.
        let parsed = crate::runtime::json::parse(&report_json(&results)).unwrap();
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert!(cells[0].get("topology").is_none());
        assert_eq!(cells[1].get("topology").unwrap().as_str(), Some("ring"));
    }

    #[test]
    fn derive_seed_is_stable_and_spreads() {
        assert_eq!(derive_seed(7, 3), derive_seed(7, 3));
        assert_ne!(derive_seed(7, 3), derive_seed(7, 4));
        assert_ne!(derive_seed(7, 0), derive_seed(8, 0));
    }

    #[test]
    fn workload_seed_depends_on_combo_not_position() {
        let base = SystemConfig::default().seed;
        // Same combo ⇒ same seed, wherever it sits in a grid.
        assert_eq!(
            workload_seed(base, &[Benchmark::Spmv]),
            workload_seed(base, &[Benchmark::Spmv])
        );
        // Different combo (or order) ⇒ different seed.
        assert_ne!(
            workload_seed(base, &[Benchmark::Spmv]),
            workload_seed(base, &[Benchmark::Mac])
        );
        assert_ne!(
            workload_seed(base, &[Benchmark::Mac, Benchmark::Rd]),
            workload_seed(base, &[Benchmark::Rd, Benchmark::Mac])
        );
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..40).collect();
        let doubled = parallel_map(&items, 4, |&i| i * 2);
        assert_eq!(doubled, (0..40).map(|i| i * 2).collect::<Vec<_>>());
        assert!(parallel_map(&[] as &[usize], 4, |&i| i).is_empty());
    }

    #[test]
    fn json_escaping_and_shape() {
        assert_eq!(jstr("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(jnum(0.25), "0.25");
        assert_eq!(jnum(f64::NAN), "null");
        assert_eq!(jnum(f64::INFINITY), "null");
        let o = jobj(&[("k", "1".to_string())]);
        assert_eq!(o, "{\"k\":1}");
    }

    #[test]
    fn continual_report_is_deterministic_and_parses_back() {
        use crate::coordinator::{run_curriculum, CurriculumStage};
        let mut cfg = SystemConfig::default();
        cfg.mapping = MappingScheme::Aimm;
        let stages = vec![
            CurriculumStage { benches: vec![Benchmark::Mac], runs: 1 },
            CurriculumStage { benches: vec![Benchmark::Rd], runs: 1 },
        ];
        let (report, _) = run_curriculum(&cfg, &stages, 0.03, None).unwrap();
        let seq = ContinualSequence {
            name: "MAC>RD".to_string(),
            technique: cfg.technique,
            mapping: cfg.mapping,
            scale: 0.03,
            seed: cfg.seed,
            report,
        };
        let text = continual_report_json(std::slice::from_ref(&seq));
        assert_eq!(text, continual_report_json(&[seq]), "fixed key order");
        let parsed = crate::runtime::json::parse(&text).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("aimm-continual-v1"));
        assert_eq!(parsed.get("sequence_count").unwrap().as_usize(), Some(1));
        let seqs = parsed.get("sequences").unwrap().as_arr().unwrap();
        let stages = seqs[0].get("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        for s in stages {
            assert!(s.get("cold_first_opc").is_some());
            assert!(s.get("warm_first_opc").is_some());
            assert!(s.get("transfer_gain").is_some());
            assert_eq!(s.get("cold").unwrap().as_arr().unwrap().len(), 1);
            assert_eq!(s.get("warm").unwrap().as_arr().unwrap().len(), 1);
        }
    }

    #[test]
    fn invalid_cell_fails_fast() {
        let mut grid = SweepGrid::new(0.03, 1);
        grid.benches = vec![vec![Benchmark::Mac]];
        grid.meshes = vec![(1, 1)]; // below the 2×2 minimum
        let err = run_grid(&grid.cells(), 2).unwrap_err().to_string();
        assert!(err.contains("sweep cell 0"), "{err}");
    }

    #[test]
    fn tiny_grid_runs_in_parallel() {
        let mut grid = SweepGrid::new(0.03, 1);
        grid.benches = vec![vec![Benchmark::Mac], vec![Benchmark::Rd]];
        let cells = grid.cells();
        assert_eq!(cells.len(), 6);
        let results = run_grid(&cells, 3).unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert!(r.summary.last().ops_completed > 0, "{}", r.cell.name());
        }
        // Report parses back through the in-crate JSON parser.
        let parsed = crate::runtime::json::parse(&report_json(&results)).unwrap();
        assert_eq!(parsed.get("cell_count").unwrap().as_usize(), Some(6));
        assert_eq!(
            parsed.get("cells").unwrap().as_arr().unwrap().len(),
            6
        );
    }
}
