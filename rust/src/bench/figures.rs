//! Paper-figure regeneration harnesses (DESIGN.md §6 experiment index).
//!
//! Every table/figure of the paper's evaluation maps to one function here
//! returning a [`Table`] with the same rows/series the paper plots. The
//! CLI (`aimm table --fig N`) and the `cargo bench` targets are thin
//! wrappers over these. `scale` shrinks the workload (1.0 = the paper's
//! "medium"), `runs` is the repeated-run count of §6.1.
//!
//! The grid-shaped figures (5, 6, 11, 12) fan their independent cells
//! across worker threads through [`super::sweep`]; cell order — and
//! therefore every table row — is fixed by the grid, not the scheduler.

use crate::config::{MappingScheme, SystemConfig, Technique};
use crate::coordinator::{run_single, EpisodeSummary};
use crate::metrics::area_report;
use crate::workloads::{
    affinity_quadrants, classify_pages, generate, mean_active_pages, Benchmark,
};

use super::harness::Table;
use super::sweep::{default_threads, parallel_map, run_grid, workload_seed, SweepGrid};

pub use crate::coordinator::runner::{MULTI_RUNS, SINGLE_RUNS};

fn cfg_with(technique: Technique, mapping: MappingScheme) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.technique = technique;
    cfg.mapping = mapping;
    cfg
}

fn f2(x: f64) -> String {
    format!("{x:.2}")
}

fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Table 1: active hardware configuration.
pub fn table1(cfg: &SystemConfig) -> Table {
    let mut t = Table::new("Table 1: Hardware Configurations", &["component", "configuration"]);
    t.row(vec!["CMP".into(), "16 core, 32KB cache/core, 16-entry MSHR".into()]);
    t.row(vec![
        "Memory Controller".into(),
        format!(
            "{}, one per CMP corner, page info cache ({} entries)",
            cfg.num_mcs(),
            cfg.page_info_entries
        ),
    ]);
    t.row(vec!["MMU".into(), "4-level page table".into()]);
    t.row(vec![
        "Migration Management".into(),
        format!("migration queue ({} entries)", cfg.migration_queue_cap),
    ]);
    t.row(vec![
        "Memory Cube".into(),
        format!("{} vaults, {} banks/vault, crossbar", cfg.vaults_per_cube, cfg.banks_per_vault),
    ]);
    t.row(vec![
        "Memory Cube Network".into(),
        format!(
            "{}x{} mesh, 3-stage router, {}-bit links, {} VCs",
            cfg.mesh_cols, cfg.mesh_rows, cfg.timing.link_bits, cfg.vcs
        ),
    ]);
    t.row(vec!["NMP-Op table".into(), format!("{} entries", cfg.nmp_table_entries)]);
    t
}

/// Table 2: benchmark list.
pub fn table2() -> Table {
    let mut t = Table::new("Table 2: Benchmarks", &["kernel", "description"]);
    for b in Benchmark::ALL {
        t.row(vec![b.name().into(), b.description().into()]);
    }
    t
}

/// Fig 5a: page-access-volume classification per benchmark. Each
/// benchmark's trace generation + analysis is independent, so the nine
/// rows compute in parallel while keeping `Benchmark::PAPER` order.
pub fn fig5a(scale: f64, seed: u64) -> Table {
    let mut t = Table::new(
        "Fig 5a: page access classification (fraction of pages)",
        &["bench", "light(<=15)", "moderate(<=255)", "heavy(>255)", "pages"],
    );
    let rows = parallel_map(&Benchmark::PAPER, default_threads(), |&b| {
        let trace = generate(b, 1, scale, seed);
        let c = classify_pages(&trace);
        vec![
            b.name().into(),
            f3(c.light_frac()),
            f3(c.moderate_frac()),
            f3(c.heavy_frac()),
            c.total().to_string(),
        ]
    });
    for row in rows {
        t.row(row);
    }
    t
}

/// Fig 5b: mean active pages per epoch (parallel over benchmarks).
pub fn fig5b(scale: f64, seed: u64) -> Table {
    let epoch = 512;
    let mut t = Table::new(
        "Fig 5b: active page distribution (mean distinct pages / 512-op epoch)",
        &["bench", "active pages", "total pages"],
    );
    let rows = parallel_map(&Benchmark::PAPER, default_threads(), |&b| {
        let trace = generate(b, 1, scale, seed);
        vec![
            b.name().into(),
            f2(mean_active_pages(&trace, epoch)),
            trace.distinct_pages().to_string(),
        ]
    });
    for row in rows {
        t.row(row);
    }
    t
}

/// Fig 5c: affinity quadrants (parallel over benchmarks).
pub fn fig5c(scale: f64, seed: u64) -> Table {
    let mut t = Table::new(
        "Fig 5c: page affinity quadrants (fraction of pages)",
        &["bench", "loR-loW", "loR-hiW", "hiR-loW", "hiR-hiW"],
    );
    let rows = parallel_map(&Benchmark::PAPER, default_threads(), |&b| {
        let trace = generate(b, 1, scale, seed);
        let q = affinity_quadrants(&trace);
        let tot = q.total().max(1) as f64;
        vec![
            b.name().into(),
            f3(q.low_radix_low_weight as f64 / tot),
            f3(q.low_radix_high_weight as f64 / tot),
            f3(q.high_radix_low_weight as f64 / tot),
            f3(q.high_radix_high_weight as f64 / tot),
        ]
    });
    for row in rows {
        t.row(row);
    }
    t
}

/// Run one (bench, technique, mapping) cell serially, with the same
/// workload seed the sweep grids assign — so a cell reports identical
/// numbers whether a figure runs it here (Figs 7–10/14) or through a
/// parallel grid (Figs 6/11/12).
fn cell(
    bench: Benchmark,
    technique: Technique,
    mapping: MappingScheme,
    scale: f64,
    runs: usize,
) -> anyhow::Result<EpisodeSummary> {
    let mut cfg = cfg_with(technique, mapping);
    cfg.seed = workload_seed(cfg.seed, &[bench]);
    run_single(&cfg, bench, scale, runs)
}

/// Fig 6: execution time normalized to each technique's baseline. The
/// full 9 × 3 × 3 grid runs as one parallel sweep; the reader below
/// consumes results in the grid's fixed nested order (bench → technique
/// → mapping, with the default `MappingScheme::PAPER` = [B, TOM, AIMM]).
pub fn fig6(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut grid = SweepGrid::new(scale, runs);
    grid.techniques = Technique::ALL.to_vec();
    let cells = grid.cells();
    let results = run_grid(&cells, default_threads())?;
    let mut t = Table::new(
        "Fig 6: normalized execution time (B = 1.00, lower is better)",
        &["bench", "technique", "B", "TOM", "AIMM"],
    );
    let mut it = results.iter();
    for b in Benchmark::PAPER {
        for technique in Technique::ALL {
            let base = it.next().expect("grid order");
            let tom = it.next().expect("grid order");
            let aimm = it.next().expect("grid order");
            // Release-mode asserts: rows are paired to results by position,
            // so a drift in SweepGrid's nesting must abort, not mislabel.
            assert_eq!(base.cell.benches, vec![b], "fig6 grid order drift");
            assert_eq!(base.cell.technique, technique, "fig6 grid order drift");
            assert_eq!(base.cell.mapping, MappingScheme::Baseline, "fig6 grid order drift");
            assert_eq!(tom.cell.mapping, MappingScheme::Tom, "fig6 grid order drift");
            assert_eq!(aimm.cell.mapping, MappingScheme::Aimm, "fig6 grid order drift");
            let b_cycles = base.summary.last().cycles as f64;
            t.row(vec![
                b.name().into(),
                technique.name().into(),
                "1.00".into(),
                f2(tom.summary.last().cycles as f64 / b_cycles),
                f2(aimm.summary.last().cycles as f64 / b_cycles),
            ]);
        }
    }
    Ok(t)
}

/// Fig 7: average hop count + computation utilization (BNMP family).
pub fn fig7(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 7: avg hop count and computation utilization (BNMP)",
        &["bench", "hops B", "hops TOM", "hops AIMM", "util B", "util TOM", "util AIMM"],
    );
    for b in Benchmark::PAPER {
        let base = cell(b, Technique::Bnmp, MappingScheme::Baseline, scale, runs)?;
        let tom = cell(b, Technique::Bnmp, MappingScheme::Tom, scale, runs)?;
        let aimm = cell(b, Technique::Bnmp, MappingScheme::Aimm, scale, runs)?;
        t.row(vec![
            b.name().into(),
            f2(base.last().avg_hops),
            f2(tom.last().avg_hops),
            f2(aimm.last().avg_hops),
            f3(base.last().compute_utilization),
            f3(tom.last().compute_utilization),
            f3(aimm.last().compute_utilization),
        ]);
    }
    Ok(t)
}

/// Fig 8: normalized OPC across techniques.
pub fn fig8(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 8: normalized memory operations per cycle (B = 1.00, higher is better)",
        &["bench", "technique", "B", "TOM", "AIMM"],
    );
    for b in Benchmark::PAPER {
        for technique in Technique::ALL {
            let base = cell(b, technique, MappingScheme::Baseline, scale, runs)?;
            let tom = cell(b, technique, MappingScheme::Tom, scale, runs)?;
            let aimm = cell(b, technique, MappingScheme::Aimm, scale, runs)?;
            let b_opc = base.last().opc().max(1e-12);
            t.row(vec![
                b.name().into(),
                technique.name().into(),
                "1.00".into(),
                f2(tom.last().opc() / b_opc),
                f2(aimm.last().opc() / b_opc),
            ]);
        }
    }
    Ok(t)
}

/// Resample a timeline to `n` points, preserving order (paper footnote 2).
pub fn resample(series: &[f32], n: usize) -> Vec<f32> {
    if series.is_empty() || n == 0 {
        return vec![];
    }
    (0..n)
        .map(|i| {
            let idx = i * series.len() / n;
            series[idx.min(series.len() - 1)]
        })
        .collect()
}

/// Fig 9: OPC timeline under AIMM (learning convergence).
pub fn fig9(scale: f64, runs: usize, points: usize) -> anyhow::Result<Table> {
    let mut header = vec!["bench".to_string()];
    header.extend((0..points).map(|i| format!("t{i}")));
    let mut t = Table::new(
        "Fig 9: OPC timeline under BNMP+AIMM (fixed-size resample across runs)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for b in Benchmark::PAPER {
        let aimm = cell(b, Technique::Bnmp, MappingScheme::Aimm, scale, runs)?;
        // Concatenate all runs' timelines: the learning signal spans runs.
        let series: Vec<f32> =
            aimm.runs.iter().flat_map(|r| r.opc_timeline.iter().copied()).collect();
        let mut row = vec![b.name().to_string()];
        row.extend(resample(&series, points).iter().map(|v| format!("{v:.3}")));
        t.row(row);
    }
    Ok(t)
}

/// Fig 10: migration statistics under BNMP+AIMM.
pub fn fig10(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 10: migration stats (BNMP+AIMM)",
        &["bench", "frac pages migrated", "frac accesses on migrated", "migrations"],
    );
    for b in Benchmark::PAPER {
        let aimm = cell(b, Technique::Bnmp, MappingScheme::Aimm, scale, runs)?;
        let last = aimm.last();
        t.row(vec![
            b.name().into(),
            f3(last.fraction_pages_migrated),
            f3(last.fraction_accesses_on_migrated),
            last.migrations.to_string(),
        ]);
    }
    Ok(t)
}

/// Fig 11: 8×8 mesh, normalized execution time (BNMP family). One
/// parallel sweep over 9 benchmarks × 3 mappings on the larger mesh.
pub fn fig11(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut grid = SweepGrid::new(scale, runs);
    grid.meshes = vec![(8, 8)];
    let cells = grid.cells();
    let results = run_grid(&cells, default_threads())?;
    let mut t = Table::new(
        "Fig 11: normalized execution time, 8x8 mesh (B = 1.00)",
        &["bench", "B", "TOM", "AIMM"],
    );
    let mut it = results.iter();
    for b in Benchmark::PAPER {
        let base = it.next().expect("grid order");
        let tom = it.next().expect("grid order");
        let aimm = it.next().expect("grid order");
        assert_eq!(base.cell.benches, vec![b], "fig11 grid order drift");
        assert_eq!(base.cell.mapping, MappingScheme::Baseline, "fig11 grid order drift");
        assert_eq!(tom.cell.mapping, MappingScheme::Tom, "fig11 grid order drift");
        assert_eq!(aimm.cell.mapping, MappingScheme::Aimm, "fig11 grid order drift");
        let bc = base.summary.last().cycles as f64;
        t.row(vec![
            b.name().into(),
            "1.00".into(),
            f2(tom.summary.last().cycles as f64 / bc),
            f2(aimm.summary.last().cycles as f64 / bc),
        ]);
    }
    Ok(t)
}

/// Fig 12: multi-program workloads (§7.5.2): BNMP, +HOARD, +AIMM,
/// +HOARD+AIMM, normalized to plain BNMP. The 4-combo × {mapping ×
/// HOARD} grid runs as one parallel sweep; within a combo the grid order
/// is (B, no-hoard), (B, hoard), (AIMM, no-hoard), (AIMM, hoard).
pub fn fig12(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let combos: Vec<Vec<Benchmark>> = crate::workloads::multi::paper_combinations()
        .into_iter()
        .map(|names| names.iter().map(|n| Benchmark::from_name(n).unwrap()).collect())
        .collect();
    let mut grid = SweepGrid::new(scale, runs);
    grid.benches = combos;
    grid.mappings = vec![MappingScheme::Baseline, MappingScheme::Aimm];
    grid.hoard = vec![false, true];
    let cells = grid.cells();
    let results = run_grid(&cells, default_threads())?;
    let mut t = Table::new(
        "Fig 12: multi-program normalized execution time (BNMP = 1.00)",
        &["combo", "BNMP", "+HOARD", "+AIMM", "+HOARD+AIMM"],
    );
    let mut it = results.iter();
    for _ in 0..grid.benches.len() {
        let base = it.next().expect("grid order");
        let hoard = it.next().expect("grid order");
        let aimm = it.next().expect("grid order");
        let both = it.next().expect("grid order");
        assert!(
            !base.cell.hoard && base.cell.mapping == MappingScheme::Baseline,
            "fig12 grid order drift"
        );
        assert!(
            hoard.cell.hoard && hoard.cell.mapping == MappingScheme::Baseline,
            "fig12 grid order drift"
        );
        assert!(
            !aimm.cell.hoard && aimm.cell.mapping == MappingScheme::Aimm,
            "fig12 grid order drift"
        );
        assert!(
            both.cell.hoard && both.cell.mapping == MappingScheme::Aimm,
            "fig12 grid order drift"
        );
        let bc = base.summary.last().cycles as f64;
        t.row(vec![
            base.summary.name.clone(),
            "1.00".into(),
            f2(hoard.summary.last().cycles as f64 / bc),
            f2(aimm.summary.last().cycles as f64 / bc),
            f2(both.summary.last().cycles as f64 / bc),
        ]);
    }
    Ok(t)
}

/// Fig 13: sensitivity to page-info-cache and NMP-table sizes (PR, SPMV).
pub fn fig13(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let cache_sizes = [32usize, 64, 128, 256];
    let table_sizes = [32usize, 64, 128, 256, 512];
    let mut t = Table::new(
        "Fig 13: sensitivity (execution cycles, BNMP+AIMM)",
        &["bench", "param", "size", "cycles"],
    );
    for b in [Benchmark::Pr, Benchmark::Spmv] {
        for &e in &cache_sizes {
            let mut cfg = cfg_with(Technique::Bnmp, MappingScheme::Aimm);
            cfg.page_info_entries = e;
            cfg.seed = workload_seed(cfg.seed, &[b]);
            let s = run_single(&cfg, b, scale, runs)?;
            let cycles = s.last().cycles.to_string();
            t.row(vec![b.name().into(), "page-cache".into(), format!("E-{e}"), cycles]);
        }
        for &e in &table_sizes {
            let mut cfg = cfg_with(Technique::Bnmp, MappingScheme::Aimm);
            cfg.nmp_table_entries = e;
            cfg.seed = workload_seed(cfg.seed, &[b]);
            let s = run_single(&cfg, b, scale, runs)?;
            let cycles = s.last().cycles.to_string();
            t.row(vec![b.name().into(), "nmp-table".into(), format!("E-{e}"), cycles]);
        }
    }
    Ok(t)
}

/// Fig 14: dynamic energy breakdown (BNMP+AIMM vs BNMP baseline).
pub fn fig14(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 14: dynamic energy (nJ): baseline vs AIMM",
        &["bench", "B net", "B mem", "AIMM hw", "AIMM net", "AIMM mem", "net overhead"],
    );
    for b in Benchmark::PAPER {
        let base = cell(b, Technique::Bnmp, MappingScheme::Baseline, scale, runs)?;
        let aimm = cell(b, Technique::Bnmp, MappingScheme::Aimm, scale, runs)?;
        let be = &base.last().energy;
        let ae = &aimm.last().energy;
        let overhead =
            if be.network_nj > 0.0 { ae.network_nj / be.network_nj - 1.0 } else { 0.0 };
        t.row(vec![
            b.name().into(),
            f2(be.network_nj),
            f2(be.memory_nj),
            f2(ae.aimm_hardware_nj),
            f2(ae.network_nj),
            f2(ae.memory_nj),
            format!("{:+.1}%", overhead * 100.0),
        ]);
    }
    Ok(t)
}

/// §7.7 area table.
pub fn area_table() -> Table {
    let mut t = Table::new(
        "Area & per-access energy (paper §7.7, Cacti 45nm)",
        &["module", "structure", "size", "area mm^2", "nJ/access"],
    );
    for item in area_report() {
        t.row(vec![
            item.module.into(),
            item.structure.into(),
            item.size.into(),
            format!("{:.3}", item.area_mm2),
            format!("{:.4}", item.energy_nj_per_access),
        ]);
    }
    t
}

/// Re-export for callers that need a raw stream run.
pub use crate::coordinator::runner::run_stream as run_raw_stream;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_tables_render() {
        let cfg = SystemConfig::default();
        assert!(table1(&cfg).render().contains("4-level page table"));
        assert!(table2().rows.len() == Benchmark::ALL.len());
        assert!(area_table().render().contains("replay buffer"));
    }

    #[test]
    fn fig5_tables_have_all_benchmarks() {
        for t in [fig5a(0.2, 1), fig5b(0.2, 1), fig5c(0.2, 1)] {
            assert_eq!(t.rows.len(), 9);
        }
    }

    #[test]
    fn fig5_parallel_is_deterministic_and_ordered() {
        // Same inputs ⇒ identical render regardless of worker scheduling,
        // and rows stay in Benchmark::PAPER order.
        assert_eq!(fig5a(0.2, 7).render(), fig5a(0.2, 7).render());
        let t = fig5b(0.2, 7);
        let names: Vec<&str> = t.rows.iter().map(|r| r[0].as_str()).collect();
        let want: Vec<&str> = Benchmark::PAPER.iter().map(|b| b.name()).collect();
        assert_eq!(names, want);
    }

    #[test]
    fn resample_preserves_order() {
        let s: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let r = resample(&s, 10);
        assert_eq!(r.len(), 10);
        assert!(r.windows(2).all(|w| w[0] <= w[1]));
        assert!(resample(&[], 5).is_empty());
    }

    /// Smoke one tiny fig6 cell end-to-end (mock agent acceptable).
    #[test]
    fn fig_cell_smoke() {
        let s = cell(Benchmark::Mac, Technique::Bnmp, MappingScheme::Baseline, 0.05, 1).unwrap();
        assert!(s.last().ops_completed > 0);
    }
}
