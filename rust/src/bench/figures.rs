//! Paper-figure regeneration harnesses (DESIGN.md §6 experiment index).
//!
//! Every table/figure of the paper's evaluation maps to one function here
//! returning a [`Table`] with the same rows/series the paper plots. The
//! CLI (`aimm table --fig N`) and the `cargo bench` targets are thin
//! wrappers over these. `scale` shrinks the workload (1.0 = the paper's
//! "medium"), `runs` is the repeated-run count of §6.1.

use crate::config::{MappingScheme, SystemConfig, Technique};
use crate::coordinator::{run_multi, run_single, EpisodeSummary};
use crate::metrics::area_report;
use crate::workloads::{
    affinity_quadrants, classify_pages, generate, mean_active_pages, Benchmark,
};

use super::harness::Table;

pub use crate::coordinator::runner::{MULTI_RUNS, SINGLE_RUNS};

fn cfg_with(technique: Technique, mapping: MappingScheme) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.technique = technique;
    cfg.mapping = mapping;
    cfg
}

fn f2(x: f64) -> String {
    format!("{x:.2}")
}

fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Table 1: active hardware configuration.
pub fn table1(cfg: &SystemConfig) -> Table {
    let mut t = Table::new("Table 1: Hardware Configurations", &["component", "configuration"]);
    t.row(vec!["CMP".into(), "16 core, 32KB cache/core, 16-entry MSHR".into()]);
    t.row(vec![
        "Memory Controller".into(),
        format!(
            "{}, one per CMP corner, page info cache ({} entries)",
            cfg.num_mcs(),
            cfg.page_info_entries
        ),
    ]);
    t.row(vec!["MMU".into(), "4-level page table".into()]);
    t.row(vec![
        "Migration Management".into(),
        format!("migration queue ({} entries)", cfg.migration_queue_cap),
    ]);
    t.row(vec![
        "Memory Cube".into(),
        format!("{} vaults, {} banks/vault, crossbar", cfg.vaults_per_cube, cfg.banks_per_vault),
    ]);
    t.row(vec![
        "Memory Cube Network".into(),
        format!(
            "{}x{} mesh, 3-stage router, {}-bit links, {} VCs",
            cfg.mesh_cols, cfg.mesh_rows, cfg.timing.link_bits, cfg.vcs
        ),
    ]);
    t.row(vec!["NMP-Op table".into(), format!("{} entries", cfg.nmp_table_entries)]);
    t
}

/// Table 2: benchmark list.
pub fn table2() -> Table {
    let mut t = Table::new("Table 2: Benchmarks", &["kernel", "description"]);
    for b in Benchmark::ALL {
        t.row(vec![b.name().into(), b.description().into()]);
    }
    t
}

/// Fig 5a: page-access-volume classification per benchmark.
pub fn fig5a(scale: f64, seed: u64) -> Table {
    let mut t = Table::new(
        "Fig 5a: page access classification (fraction of pages)",
        &["bench", "light(<=15)", "moderate(<=255)", "heavy(>255)", "pages"],
    );
    for b in Benchmark::ALL {
        let trace = generate(b, 1, scale, seed);
        let c = classify_pages(&trace);
        t.row(vec![
            b.name().into(),
            f3(c.light_frac()),
            f3(c.moderate_frac()),
            f3(c.heavy_frac()),
            c.total().to_string(),
        ]);
    }
    t
}

/// Fig 5b: mean active pages per epoch.
pub fn fig5b(scale: f64, seed: u64) -> Table {
    let epoch = 512;
    let mut t = Table::new(
        "Fig 5b: active page distribution (mean distinct pages / 512-op epoch)",
        &["bench", "active pages", "total pages"],
    );
    for b in Benchmark::ALL {
        let trace = generate(b, 1, scale, seed);
        t.row(vec![
            b.name().into(),
            f2(mean_active_pages(&trace, epoch)),
            trace.distinct_pages().to_string(),
        ]);
    }
    t
}

/// Fig 5c: affinity quadrants.
pub fn fig5c(scale: f64, seed: u64) -> Table {
    let mut t = Table::new(
        "Fig 5c: page affinity quadrants (fraction of pages)",
        &["bench", "loR-loW", "loR-hiW", "hiR-loW", "hiR-hiW"],
    );
    for b in Benchmark::ALL {
        let trace = generate(b, 1, scale, seed);
        let q = affinity_quadrants(&trace);
        let tot = q.total().max(1) as f64;
        t.row(vec![
            b.name().into(),
            f3(q.low_radix_low_weight as f64 / tot),
            f3(q.low_radix_high_weight as f64 / tot),
            f3(q.high_radix_low_weight as f64 / tot),
            f3(q.high_radix_high_weight as f64 / tot),
        ]);
    }
    t
}

/// Run one (bench, technique, mapping) cell.
fn cell(
    bench: Benchmark,
    technique: Technique,
    mapping: MappingScheme,
    scale: f64,
    runs: usize,
) -> anyhow::Result<EpisodeSummary> {
    let cfg = cfg_with(technique, mapping);
    run_single(&cfg, bench, scale, runs)
}

/// Fig 6: execution time normalized to each technique's baseline.
pub fn fig6(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 6: normalized execution time (B = 1.00, lower is better)",
        &["bench", "technique", "B", "TOM", "AIMM"],
    );
    for b in Benchmark::ALL {
        for technique in Technique::ALL {
            let base = cell(b, technique, MappingScheme::Baseline, scale, runs)?;
            let tom = cell(b, technique, MappingScheme::Tom, scale, runs)?;
            let aimm = cell(b, technique, MappingScheme::Aimm, scale, runs)?;
            let b_cycles = base.last().cycles as f64;
            t.row(vec![
                b.name().into(),
                technique.name().into(),
                "1.00".into(),
                f2(tom.last().cycles as f64 / b_cycles),
                f2(aimm.last().cycles as f64 / b_cycles),
            ]);
        }
    }
    Ok(t)
}

/// Fig 7: average hop count + computation utilization (BNMP family).
pub fn fig7(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 7: avg hop count and computation utilization (BNMP)",
        &["bench", "hops B", "hops TOM", "hops AIMM", "util B", "util TOM", "util AIMM"],
    );
    for b in Benchmark::ALL {
        let base = cell(b, Technique::Bnmp, MappingScheme::Baseline, scale, runs)?;
        let tom = cell(b, Technique::Bnmp, MappingScheme::Tom, scale, runs)?;
        let aimm = cell(b, Technique::Bnmp, MappingScheme::Aimm, scale, runs)?;
        t.row(vec![
            b.name().into(),
            f2(base.last().avg_hops),
            f2(tom.last().avg_hops),
            f2(aimm.last().avg_hops),
            f3(base.last().compute_utilization),
            f3(tom.last().compute_utilization),
            f3(aimm.last().compute_utilization),
        ]);
    }
    Ok(t)
}

/// Fig 8: normalized OPC across techniques.
pub fn fig8(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 8: normalized memory operations per cycle (B = 1.00, higher is better)",
        &["bench", "technique", "B", "TOM", "AIMM"],
    );
    for b in Benchmark::ALL {
        for technique in Technique::ALL {
            let base = cell(b, technique, MappingScheme::Baseline, scale, runs)?;
            let tom = cell(b, technique, MappingScheme::Tom, scale, runs)?;
            let aimm = cell(b, technique, MappingScheme::Aimm, scale, runs)?;
            let b_opc = base.last().opc().max(1e-12);
            t.row(vec![
                b.name().into(),
                technique.name().into(),
                "1.00".into(),
                f2(tom.last().opc() / b_opc),
                f2(aimm.last().opc() / b_opc),
            ]);
        }
    }
    Ok(t)
}

/// Resample a timeline to `n` points, preserving order (paper footnote 2).
pub fn resample(series: &[f32], n: usize) -> Vec<f32> {
    if series.is_empty() || n == 0 {
        return vec![];
    }
    (0..n)
        .map(|i| {
            let idx = i * series.len() / n;
            series[idx.min(series.len() - 1)]
        })
        .collect()
}

/// Fig 9: OPC timeline under AIMM (learning convergence).
pub fn fig9(scale: f64, runs: usize, points: usize) -> anyhow::Result<Table> {
    let mut header = vec!["bench".to_string()];
    header.extend((0..points).map(|i| format!("t{i}")));
    let mut t = Table::new(
        "Fig 9: OPC timeline under BNMP+AIMM (fixed-size resample across runs)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for b in Benchmark::ALL {
        let aimm = cell(b, Technique::Bnmp, MappingScheme::Aimm, scale, runs)?;
        // Concatenate all runs' timelines: the learning signal spans runs.
        let series: Vec<f32> =
            aimm.runs.iter().flat_map(|r| r.opc_timeline.iter().copied()).collect();
        let mut row = vec![b.name().to_string()];
        row.extend(resample(&series, points).iter().map(|v| format!("{v:.3}")));
        t.row(row);
    }
    Ok(t)
}

/// Fig 10: migration statistics under BNMP+AIMM.
pub fn fig10(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 10: migration stats (BNMP+AIMM)",
        &["bench", "frac pages migrated", "frac accesses on migrated", "migrations"],
    );
    for b in Benchmark::ALL {
        let aimm = cell(b, Technique::Bnmp, MappingScheme::Aimm, scale, runs)?;
        let last = aimm.last();
        t.row(vec![
            b.name().into(),
            f3(last.fraction_pages_migrated),
            f3(last.fraction_accesses_on_migrated),
            last.migrations.to_string(),
        ]);
    }
    Ok(t)
}

/// Fig 11: 8×8 mesh, normalized execution time (BNMP family).
pub fn fig11(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 11: normalized execution time, 8x8 mesh (B = 1.00)",
        &["bench", "B", "TOM", "AIMM"],
    );
    for b in Benchmark::ALL {
        let mk = |mapping| -> anyhow::Result<EpisodeSummary> {
            let mut cfg = cfg_with(Technique::Bnmp, mapping);
            cfg.mesh_cols = 8;
            cfg.mesh_rows = 8;
            run_single(&cfg, b, scale, runs)
        };
        let base = mk(MappingScheme::Baseline)?;
        let tom = mk(MappingScheme::Tom)?;
        let aimm = mk(MappingScheme::Aimm)?;
        let bc = base.last().cycles as f64;
        t.row(vec![
            b.name().into(),
            "1.00".into(),
            f2(tom.last().cycles as f64 / bc),
            f2(aimm.last().cycles as f64 / bc),
        ]);
    }
    Ok(t)
}

/// Fig 12: multi-program workloads (§7.5.2): BNMP, +HOARD, +AIMM,
/// +HOARD+AIMM, normalized to plain BNMP.
pub fn fig12(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let combos: Vec<Vec<Benchmark>> = crate::workloads::multi::paper_combinations()
        .into_iter()
        .map(|names| names.iter().map(|n| Benchmark::from_name(n).unwrap()).collect())
        .collect();
    let mut t = Table::new(
        "Fig 12: multi-program normalized execution time (BNMP = 1.00)",
        &["combo", "BNMP", "+HOARD", "+AIMM", "+HOARD+AIMM"],
    );
    for combo in combos {
        let mk = |hoard: bool, mapping| -> anyhow::Result<EpisodeSummary> {
            let mut cfg = cfg_with(Technique::Bnmp, mapping);
            cfg.hoard = hoard;
            run_multi(&cfg, &combo, scale, runs)
        };
        let base = mk(false, MappingScheme::Baseline)?;
        let hoard = mk(true, MappingScheme::Baseline)?;
        let aimm = mk(false, MappingScheme::Aimm)?;
        let both = mk(true, MappingScheme::Aimm)?;
        let bc = base.last().cycles as f64;
        t.row(vec![
            base.name.clone(),
            "1.00".into(),
            f2(hoard.last().cycles as f64 / bc),
            f2(aimm.last().cycles as f64 / bc),
            f2(both.last().cycles as f64 / bc),
        ]);
    }
    Ok(t)
}

/// Fig 13: sensitivity to page-info-cache and NMP-table sizes (PR, SPMV).
pub fn fig13(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let cache_sizes = [32usize, 64, 128, 256];
    let table_sizes = [32usize, 64, 128, 256, 512];
    let mut t = Table::new(
        "Fig 13: sensitivity (execution cycles, BNMP+AIMM)",
        &["bench", "param", "size", "cycles"],
    );
    for b in [Benchmark::Pr, Benchmark::Spmv] {
        for &e in &cache_sizes {
            let mut cfg = cfg_with(Technique::Bnmp, MappingScheme::Aimm);
            cfg.page_info_entries = e;
            let s = run_single(&cfg, b, scale, runs)?;
            t.row(vec![b.name().into(), "page-cache".into(), format!("E-{e}"), s.last().cycles.to_string()]);
        }
        for &e in &table_sizes {
            let mut cfg = cfg_with(Technique::Bnmp, MappingScheme::Aimm);
            cfg.nmp_table_entries = e;
            let s = run_single(&cfg, b, scale, runs)?;
            t.row(vec![b.name().into(), "nmp-table".into(), format!("E-{e}"), s.last().cycles.to_string()]);
        }
    }
    Ok(t)
}

/// Fig 14: dynamic energy breakdown (BNMP+AIMM vs BNMP baseline).
pub fn fig14(scale: f64, runs: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        "Fig 14: dynamic energy (nJ): baseline vs AIMM",
        &["bench", "B net", "B mem", "AIMM hw", "AIMM net", "AIMM mem", "net overhead"],
    );
    for b in Benchmark::ALL {
        let base = cell(b, Technique::Bnmp, MappingScheme::Baseline, scale, runs)?;
        let aimm = cell(b, Technique::Bnmp, MappingScheme::Aimm, scale, runs)?;
        let be = &base.last().energy;
        let ae = &aimm.last().energy;
        let overhead =
            if be.network_nj > 0.0 { ae.network_nj / be.network_nj - 1.0 } else { 0.0 };
        t.row(vec![
            b.name().into(),
            f2(be.network_nj),
            f2(be.memory_nj),
            f2(ae.aimm_hardware_nj),
            f2(ae.network_nj),
            f2(ae.memory_nj),
            format!("{:+.1}%", overhead * 100.0),
        ]);
    }
    Ok(t)
}

/// §7.7 area table.
pub fn area_table() -> Table {
    let mut t = Table::new(
        "Area & per-access energy (paper §7.7, Cacti 45nm)",
        &["module", "structure", "size", "area mm^2", "nJ/access"],
    );
    for item in area_report() {
        t.row(vec![
            item.module.into(),
            item.structure.into(),
            item.size.into(),
            format!("{:.3}", item.area_mm2),
            format!("{:.4}", item.energy_nj_per_access),
        ]);
    }
    t
}

/// Re-export for callers that need a raw stream run.
pub use crate::coordinator::runner::run_stream as run_raw_stream;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_tables_render() {
        let cfg = SystemConfig::default();
        assert!(table1(&cfg).render().contains("4-level page table"));
        assert!(table2().rows.len() == 9);
        assert!(area_table().render().contains("replay buffer"));
    }

    #[test]
    fn fig5_tables_have_all_benchmarks() {
        for t in [fig5a(0.2, 1), fig5b(0.2, 1), fig5c(0.2, 1)] {
            assert_eq!(t.rows.len(), 9);
        }
    }

    #[test]
    fn resample_preserves_order() {
        let s: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let r = resample(&s, 10);
        assert_eq!(r.len(), 10);
        assert!(r.windows(2).all(|w| w[0] <= w[1]));
        assert!(resample(&[], 5).is_empty());
    }

    /// Smoke one tiny fig6 cell end-to-end (mock agent acceptable).
    #[test]
    fn fig_cell_smoke() {
        let s = cell(Benchmark::Mac, Technique::Bnmp, MappingScheme::Baseline, 0.05, 1).unwrap();
        assert!(s.last().ops_completed > 0);
    }
}
