//! Micro-benchmark harness: warmup + repeated measurement with median /
//! mean / stddev reporting, and a plain-text table renderer for the
//! paper-shaped outputs.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>6} iters  median {:>12?}  mean {:>12?} ± {:?}  [{:?} .. {:?}]",
            self.name, self.iters, self.median, self.mean, self.stddev, self.min, self.max
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn bench_fn<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now(); // detlint: allow(wall-clock) — report timing only
        f();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let median = samples[samples.len() / 2];
    let total: Duration = samples.iter().sum();
    let mean = total / iters as u32;
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|s| {
            let d = s.as_secs_f64() - mean_s;
            d * d
        })
        .sum::<f64>()
        / iters as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        median,
        mean,
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: samples[0],
        max: *samples.last().unwrap(),
    }
}

/// A paper-shaped results table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let mut x = 0u64;
        let r = bench_fn("noop-ish", 2, 5, || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median && r.median <= r.max);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["bench", "value"]);
        t.row(vec!["MAC".into(), "1.00".into()]);
        t.row(vec!["SPMV".into(), "0.50".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("MAC"));
        assert!(s.lines().count() >= 5);
    }
}
