//! Crash-safe incremental journal, resume, sharding and merge — the
//! batch discipline that makes `aimm sweep` restartable and fan-out-able
//! (DESIGN.md §12).
//!
//! Every finished cell is appended to a JSON-Lines journal as one
//! self-describing line `{"schema":…,"idx":…,"cell_key":…,"cell":{…}}`
//! the moment it completes, under a mutex, with an explicit flush — a
//! killed sweep loses at most the cells that were in flight. The `cell`
//! payload is the exact [`super::cell_json`] byte string the aggregated
//! report embeds, so resuming from a journal or merging shard journals
//! reassembles `BENCH_sweep.json` *byte-identically* to an uninterrupted
//! single-process run: cached cells are spliced back in verbatim, never
//! re-serialized.
//!
//! `idx` is the cell's position in the canonically ordered full grid
//! ([`super::SweepGrid::cells`]), which is a pure function of the axis
//! lists — so a shard partition (`idx % shard_count == shard_index`) and
//! the merged cell order are worker- and machine-invariant.
//!
//! On resume every line is verified before reuse: unparseable lines (a
//! torn tail from a kill mid-append) are dropped loudly, and lines whose
//! `cell_key` matches no cell of the current grid are dropped as stale —
//! the cell is recomputed, never silently reused.

use std::collections::{HashMap, HashSet};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::runtime::json::{self, write as jw, Json};

use super::cache::{cell_key, CellOutcome};
use super::grid::{parallel_map, CellResult, SweepCell};
use super::report_json_from_cells;

/// Per-line schema tag; bump alongside any layout change so old
/// journals read as stale instead of misparsing.
pub const LINE_SCHEMA: &str = "aimm-sweep-cell-v1";

/// The journal sitting next to a report `out` path: `.json` (or any
/// extension) becomes `.jsonl`, an extension-less path gains one —
/// `BENCH_sweep.json` journals to `BENCH_sweep.jsonl`.
pub fn journal_path_for(out: &Path) -> PathBuf {
    out.with_extension("jsonl")
}

/// Serialize one journal line (no trailing newline). `cell` must be the
/// [`super::cell_json`] string of the finished cell; it is embedded
/// verbatim as the last field so [`parse_line`] can recover the exact
/// bytes.
pub fn line(idx: usize, key: u64, cell: &str) -> String {
    jw::obj(&[
        ("schema", jw::string(LINE_SCHEMA)),
        ("idx", idx.to_string()),
        ("cell_key", jw::hex_u64(key)),
        ("cell", cell.to_string()),
    ])
}

/// One parsed journal line.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalEntry {
    /// Position in the canonically ordered full grid.
    pub idx: usize,
    /// [`cell_key`] of the cell that produced this entry.
    pub key: u64,
    /// The serialized cell, byte-for-byte as the report embeds it.
    pub cell: String,
}

impl JournalEntry {
    /// Re-serialize; `parse_line(entry.line())` round-trips exactly.
    pub fn line(&self) -> String {
        line(self.idx, self.key, &self.cell)
    }
}

/// Parse one journal line, recovering the embedded cell *verbatim*.
///
/// The line must parse as JSON, carry the [`LINE_SCHEMA`] tag, and its
/// trailing `cell` field is sliced back out of the raw text (the writer
/// always emits it last) — then re-parsed standalone as a final guard
/// against hand-edited lines with reordered fields.
pub fn parse_line(raw: &str) -> anyhow::Result<JournalEntry> {
    let j = json::parse(raw.trim_end())?;
    entry_from(raw, &j)
}

/// The [`parse_line`] body after the JSON parse, split out so the bulk
/// readers ([`read`], [`merge_files`]) can reuse the parse that
/// [`json::parse_lines`] already did.
fn entry_from(raw: &str, j: &Json) -> anyhow::Result<JournalEntry> {
    let schema = j.get("schema").and_then(Json::as_str);
    anyhow::ensure!(
        schema == Some(LINE_SCHEMA),
        "journal line schema {schema:?}, expected {LINE_SCHEMA:?}"
    );
    let idx = j
        .get("idx")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("journal line missing idx"))?;
    anyhow::ensure!(
        idx >= 0.0 && idx.fract() == 0.0 && idx < 9e15,
        "journal line idx {idx} is not a cell index"
    );
    let key = json::parse_hex_u64(
        j.get("cell_key")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("journal line missing cell_key"))?,
    )?;
    let marker = "\"cell\":";
    let start = raw
        .find(marker)
        .ok_or_else(|| anyhow::anyhow!("journal line missing cell field"))?
        + marker.len();
    let trimmed = raw.trim_end();
    anyhow::ensure!(trimmed.ends_with('}'), "journal line does not end the object");
    let cell = &trimmed[start..trimmed.len() - 1];
    anyhow::ensure!(
        cell.starts_with('{') && json::parse(cell).is_ok(),
        "journal line cell field is not the trailing object"
    );
    Ok(JournalEntry { idx: idx as usize, key, cell: cell.to_string() })
}

/// Read a journal: parsed entries plus `(line_number, error)` for every
/// corrupt line (1-based). A missing file is an empty journal, not an
/// error — that is the cold-start case.
pub fn read(path: &Path) -> anyhow::Result<(Vec<JournalEntry>, Vec<(usize, String)>)> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok((Vec::new(), Vec::new()))
        }
        Err(e) => anyhow::bail!("reading journal {}: {e}", path.display()),
    };
    let mut entries = Vec::new();
    let mut corrupt = Vec::new();
    for (lineno, raw, parsed) in json::parse_lines(&text) {
        match parsed.and_then(|j| entry_from(raw, &j)) {
            Ok(entry) => entries.push(entry),
            Err(e) => corrupt.push((lineno, e.to_string())),
        }
    }
    Ok((entries, corrupt))
}

/// Write `text` to `path` atomically: write `<path>.tmp`, then rename
/// over the target. An interrupt can leave a stale `.tmp` behind but
/// never a torn report; the next write simply overwrites the leftover.
pub fn atomic_write_text(path: &Path, text: &str) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| anyhow::anyhow!("creating {}: {e}", parent.display()))?;
        }
    }
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = PathBuf::from(tmp_name);
    std::fs::write(&tmp, text).map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("renaming {} -> {}: {e}", tmp.display(), path.display()))
}

/// A deterministic stride partition of the grid: shard `index` owns the
/// cells whose canonical grid index `i` satisfies `i % count == index`.
/// Partition membership depends only on the grid definition — never on
/// worker count, machine, or which shards run first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// 0-based shard index, `< count`.
    pub index: usize,
    /// Total shard count, `>= 1`.
    pub count: usize,
}

impl ShardSpec {
    pub fn selects(&self, idx: usize) -> bool {
        idx % self.count == self.index
    }
}

/// What a journaled sweep did: the outcomes for the selected cells in
/// canonical grid order, plus resume accounting.
#[derive(Debug)]
pub struct SweepRunReport {
    pub outcomes: Vec<CellOutcome>,
    /// Selected cells replayed from the journal.
    pub cached: usize,
    /// Selected cells simulated this process.
    pub computed: usize,
    /// Journal lines whose `cell_key` matched no cell of the current
    /// grid — dropped and recomputed (if still selected), never reused.
    pub stale: usize,
    /// Unparseable journal lines (torn appends, garbage) — dropped.
    pub corrupt: usize,
}

/// Run the shard-selected subset of `cells` with journaling and resume.
///
/// Completed cells found in the journal (verified by [`cell_key`]) are
/// replayed verbatim; the rest run on up to `threads` workers, each
/// appended to the journal the moment it finishes. The journal is
/// compacted (atomically) first whenever corrupt, stale or re-indexed
/// lines would otherwise linger. Entries for grid cells *outside* the
/// shard are preserved, so sequential shard runs may share one journal.
pub fn run_journaled(
    cells: &[SweepCell],
    shard: Option<ShardSpec>,
    threads: usize,
    journal: &Path,
) -> anyhow::Result<SweepRunReport> {
    if let Some(s) = shard {
        anyhow::ensure!(s.count >= 1 && s.index < s.count, "bad shard {}/{}", s.index, s.count);
    }
    let keys: Vec<u64> = cells.iter().map(cell_key).collect();
    let selected: Vec<usize> = (0..cells.len())
        .filter(|&i| shard.map_or(true, |s| s.selects(i)))
        .collect();
    for &i in &selected {
        let cell = &cells[i];
        cell.config()
            .map_err(|e| anyhow::anyhow!("sweep cell {i} ({}): {e}", cell.name()))?;
    }

    // Load and verify the journal. `cache` maps cell_key -> (journal
    // idx, serialized cell); last write wins so a compaction that raced
    // an append converges on the newest entry.
    let (entries, corrupt) = read(journal)?;
    for (lineno, err) in &corrupt {
        eprintln!(
            "journal {}: line {lineno} unreadable ({err}) — dropping (torn append?)",
            journal.display()
        );
    }
    let grid_keys: HashMap<u64, usize> = keys.iter().enumerate().map(|(i, &k)| (k, i)).collect();
    let mut cache: HashMap<u64, (usize, String)> = HashMap::new();
    let mut stale = 0usize;
    let mut duplicates = 0usize;
    for e in entries {
        if grid_keys.contains_key(&e.key) {
            if cache.insert(e.key, (e.idx, e.cell)).is_some() {
                duplicates += 1;
            }
        } else {
            stale += 1;
            eprintln!(
                "journal {}: cell_key {:#x} matches no cell of the current grid — \
                 dropping stale entry (will recompute, not reuse)",
                journal.display(),
                e.key
            );
        }
    }

    // Compact when anything was dropped or an entry's recorded index
    // drifted from the current canonical order (grid axes reordered):
    // rewrite only verified entries, re-indexed, atomically.
    // detlint: allow(hash-iter) — existential any(): the boolean fold is order-independent
    let drifted = cache.iter().any(|(k, (idx, _))| grid_keys[k] != *idx);
    if stale > 0 || duplicates > 0 || !corrupt.is_empty() || drifted {
        let mut text = String::new();
        for (i, &k) in keys.iter().enumerate() {
            if let Some((_, cell)) = cache.get(&k) {
                text.push_str(&line(i, k, cell));
                text.push('\n');
            }
        }
        atomic_write_text(journal, &text)?;
    }

    // Run the misses, appending each result as it completes. A crash
    // here loses only in-flight cells; everything journaled survives.
    let miss: Vec<usize> =
        selected.iter().copied().filter(|&i| !cache.contains_key(&keys[i])).collect();
    let mut fresh: HashMap<usize, CellResult> = HashMap::new();
    if !miss.is_empty() {
        if let Some(parent) = journal.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| anyhow::anyhow!("creating {}: {e}", parent.display()))?;
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(journal)
            .map_err(|e| anyhow::anyhow!("opening journal {}: {e}", journal.display()))?;
        let sink = Mutex::new(file);
        let results = parallel_map(&miss, threads, |&i| -> anyhow::Result<CellResult> {
            let summary = cells[i].run()?;
            let res = CellResult { cell: cells[i].clone(), summary };
            let mut text = line(i, keys[i], &super::cell_json(&res));
            text.push('\n');
            let mut f = sink.lock().expect("journal sink poisoned");
            f.write_all(text.as_bytes())?;
            f.flush()?;
            Ok(res)
        });
        for (&i, res) in miss.iter().zip(results) {
            let r = res
                .map_err(|e| anyhow::anyhow!("sweep cell {i} ({}) failed: {e}", cells[i].name()))?;
            fresh.insert(i, r);
        }
    }

    // Assemble outcomes in canonical grid order: journal hits verbatim,
    // fresh results via the same cell_json the hits were written with.
    let computed = miss.len();
    let outcomes: Vec<CellOutcome> = selected
        .iter()
        .map(|&i| match fresh.remove(&i) {
            Some(res) => CellOutcome::Fresh(res),
            None => {
                let (_, json) = &cache[&keys[i]];
                CellOutcome::Cached { key: keys[i], json: json.clone() }
            }
        })
        .collect();
    Ok(SweepRunReport {
        cached: selected.len() - computed,
        computed,
        stale,
        corrupt: corrupt.len(),
        outcomes,
    })
}

/// Fold journal entries into one aggregated report, byte-identical to an
/// unsharded run of the same grid. Strict by design: a merge that
/// silently tolerated a gap or a conflict would masquerade as a complete
/// study. Duplicate indices are allowed only when byte-identical (two
/// shards, or a shard plus a resumed re-run, legitimately overlap).
pub fn merge_entries(mut entries: Vec<JournalEntry>) -> anyhow::Result<String> {
    anyhow::ensure!(!entries.is_empty(), "no journal entries to merge");
    entries.sort_by_key(|e| e.idx);
    let mut cells: Vec<String> = Vec::new();
    for e in entries {
        if e.idx == cells.len() {
            // Next expected index.
            cells.push(e.cell);
        } else if e.idx + 1 == cells.len() {
            // Duplicate of the previous index: must agree byte-for-byte.
            anyhow::ensure!(
                cells[e.idx] == e.cell,
                "conflicting journal entries for cell index {} — shards from \
                 different grids or engine versions?",
                e.idx
            );
        } else {
            anyhow::bail!(
                "journal gap: expected cell index {}, found {} — is a shard \
                 journal missing or incomplete?",
                cells.len(),
                e.idx
            );
        }
    }
    Ok(report_json_from_cells(&cells))
}

/// [`merge_entries`] over journal files (`aimm sweep --merge a,b,…`).
/// Unlike resume, merge refuses corrupt lines outright: a merged report
/// must account for every byte of its inputs.
pub fn merge_files(paths: &[PathBuf]) -> anyhow::Result<String> {
    let mut entries = Vec::new();
    let mut seen = HashSet::new();
    for p in paths {
        anyhow::ensure!(seen.insert(p.clone()), "duplicate merge input {}", p.display());
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", p.display()))?;
        for (lineno, raw, parsed) in json::parse_lines(&text) {
            let entry = parsed
                .and_then(|j| entry_from(raw, &j))
                .map_err(|e| anyhow::anyhow!("{}:{lineno}: {e}", p.display()))?;
            entries.push(entry);
        }
    }
    merge_entries(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(idx: usize, key: u64, cell: &str) -> JournalEntry {
        JournalEntry { idx, key, cell: cell.to_string() }
    }

    #[test]
    fn line_round_trips_key_idx_and_cell_bytes() {
        let cell = r#"{"name":"MAC/BNMP/B/4x4/s7","runs":[{"opc":0.25}]}"#;
        let l = line(3, 0xDEAD_BEEF_1234_5678, cell);
        let e = parse_line(&l).unwrap();
        assert_eq!(e.idx, 3);
        assert_eq!(e.key, 0xDEAD_BEEF_1234_5678);
        assert_eq!(e.cell, cell);
        // Round-tripping the entry reproduces the identical line — the
        // serialization never perturbs the key or the cell bytes.
        assert_eq!(e.line(), l);
        assert_eq!(parse_line(&e.line()).unwrap(), e);
    }

    #[test]
    fn parse_line_rejects_torn_and_foreign_lines() {
        let good = line(0, 7, "{\"name\":\"x\"}");
        assert!(parse_line(&good).is_ok());
        // Torn tail: every strict prefix fails (JSON must close).
        for cut in 1..good.len() {
            assert!(parse_line(&good[..cut]).is_err(), "prefix {cut} parsed");
        }
        assert!(parse_line("").is_err());
        assert!(parse_line("garbage").is_err());
        // Valid JSON, wrong schema.
        assert!(parse_line("{\"schema\":\"other\",\"idx\":0}").is_err());
        // Missing fields.
        assert!(parse_line(&format!("{{\"schema\":\"{LINE_SCHEMA}\",\"idx\":1}}")).is_err());
    }

    #[test]
    fn journal_path_for_swaps_extension() {
        assert_eq!(
            journal_path_for(Path::new("BENCH_sweep.json")),
            PathBuf::from("BENCH_sweep.jsonl")
        );
        assert_eq!(
            journal_path_for(Path::new("out/report.json")),
            PathBuf::from("out/report.jsonl")
        );
        assert_eq!(journal_path_for(Path::new("report")), PathBuf::from("report.jsonl"));
    }

    #[test]
    fn shard_spec_partitions_exactly() {
        for n in 1..=5usize {
            for idx in 0..23usize {
                let owners: Vec<usize> = (0..n)
                    .filter(|&s| ShardSpec { index: s, count: n }.selects(idx))
                    .collect();
                assert_eq!(owners.len(), 1, "idx {idx} owned by {owners:?} of {n}");
                assert_eq!(owners[0], idx % n);
            }
        }
    }

    #[test]
    fn merge_orders_dedups_and_rejects_gaps_and_conflicts() {
        let a = entry(0, 10, "{\"name\":\"a\"}");
        let b = entry(1, 11, "{\"name\":\"b\"}");
        let c = entry(2, 12, "{\"name\":\"c\"}");
        // Out-of-order input merges in index order.
        let merged = merge_entries(vec![c.clone(), a.clone(), b.clone()]).unwrap();
        assert_eq!(
            merged,
            "{\"schema\":\"aimm-sweep-v1\",\"cell_count\":3,\
             \"cells\":[{\"name\":\"a\"},{\"name\":\"b\"},{\"name\":\"c\"}]}"
        );
        // Byte-identical duplicates collapse.
        let dup = merge_entries(vec![a.clone(), b.clone(), b.clone(), c.clone()]).unwrap();
        assert_eq!(dup, merged);
        // A gap is an incomplete shard set.
        let err = merge_entries(vec![a.clone(), c.clone()]).unwrap_err().to_string();
        assert!(err.contains("journal gap"), "{err}");
        assert!(err.contains("expected cell index 1"), "{err}");
        // A conflicting duplicate is a grid mismatch.
        let b2 = entry(1, 11, "{\"name\":\"B2\"}");
        let err = merge_entries(vec![a, b, b2]).unwrap_err().to_string();
        assert!(err.contains("conflicting journal entries"), "{err}");
        // Nothing to merge is an error, not an empty report.
        assert!(merge_entries(Vec::new()).is_err());
    }

    #[test]
    fn atomic_write_replaces_stale_tmp() {
        let dir = std::env::temp_dir().join(format!("aimm_atomic_write_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("report.json");
        let tmp = dir.join("report.json.tmp");
        // A stale tmp from an interrupted earlier write must not leak
        // into (or block) the next write.
        std::fs::write(&tmp, "torn garbage").unwrap();
        atomic_write_text(&out, "{\"ok\":true}").unwrap();
        assert_eq!(std::fs::read_to_string(&out).unwrap(), "{\"ok\":true}");
        assert!(!tmp.exists(), "tmp must be renamed away");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
