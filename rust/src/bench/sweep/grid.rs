//! Grid construction and parallel execution: [`SweepCell`] /
//! [`SweepGrid`] descriptors, the canonical cell ordering, per-cell
//! seeding, and the order-preserving [`parallel_map`] fan-out that
//! [`run_grid`] (and the Fig 5 analysis harnesses) sit on.
//!
//! The canonical order matters beyond aesthetics: the resumable batch
//! layer ([`super::journal`]) identifies a cell's place in a sweep by
//! its index in [`SweepGrid::cells`], so shard partitions and merged
//! reports are worker- and machine-invariant exactly because this
//! ordering is a pure function of the axis lists.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::config::{Engine, MappingScheme, SystemConfig, Technique, TopologyKind};
use crate::coordinator::{run_cell, EpisodeSummary};
use crate::sim::Rng;
use crate::workloads::Benchmark;

/// One grid cell: everything needed to reproduce one episode family.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// One entry = single-program episode; several = multi-program.
    pub benches: Vec<Benchmark>,
    pub technique: Technique,
    pub mapping: MappingScheme,
    /// Grid dimensions (cols, rows).
    pub mesh: (usize, usize),
    /// Cube-network topology. `Mesh` is the default and keeps the cell's
    /// name and JSON byte-identical to pre-topology reports (the golden
    /// fixture); torus/ring cells carry an extra name segment and a
    /// `topology` JSON field.
    pub topology: TopologyKind,
    pub hoard: bool,
    /// Master seed for this cell's config (trace + all RNG streams).
    pub seed: u64,
    pub scale: f64,
    pub runs: usize,
    /// Simulation engine. Deliberately excluded from [`SweepCell::name`]
    /// and the JSON report: both engines produce bit-identical stats
    /// (DESIGN.md §8), so polled and event sweeps of the same grid must
    /// diff clean. It *is* folded into [`super::cell_key`] — a cached
    /// cell is only reused for the exact engine that produced it.
    pub engine: Engine,
}

impl SweepCell {
    /// Human-readable cell label for tables and logs. Includes the seed
    /// so replicate rows (`--seeds N,M`) stay distinguishable.
    pub fn name(&self) -> String {
        let combo =
            self.benches.iter().map(|b| b.name()).collect::<Vec<_>>().join("-");
        // The topology segment appears only off-default, so mesh cell
        // names (and the golden fixture pinning them) never change.
        let topology = match self.topology {
            TopologyKind::Mesh => String::new(),
            other => format!("/{}", other.name()),
        };
        format!(
            "{}/{}/{}/{}x{}{}{}/s{:x}",
            combo,
            self.technique,
            self.mapping,
            self.mesh.0,
            self.mesh.1,
            topology,
            if self.hoard { "/HOARD" } else { "" },
            self.seed,
        )
    }

    /// The cell's full system configuration.
    pub fn config(&self) -> anyhow::Result<SystemConfig> {
        let mut cfg = SystemConfig::default();
        cfg.technique = self.technique;
        cfg.mapping = self.mapping;
        cfg.mesh_cols = self.mesh.0;
        cfg.mesh_rows = self.mesh.1;
        cfg.topology = self.topology;
        cfg.hoard = self.hoard;
        cfg.seed = self.seed;
        cfg.engine = self.engine;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Execute the cell (the worker-thread body).
    pub fn run(&self) -> anyhow::Result<EpisodeSummary> {
        let cfg = self.config()?;
        run_cell(&cfg, &self.benches, self.scale, self.runs)
    }
}

/// Decorrelate a seed by `index` with no dependence on execution order.
/// The mixing core is [`sim::Rng`](crate::sim::Rng)'s splitmix64 — the
/// crate's single PRNG — fed a golden-ratio-spread combination of the
/// inputs.
pub fn derive_seed(base: u64, index: u64) -> u64 {
    Rng::new(base ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64()
}

/// The workload seed for a benchmark combination: a fold of the combo's
/// identity into `base`. Depends only on *what* runs — never on grid
/// position or scheduling — so a (bench, technique, mapping) cell reports
/// identical numbers whether it came from a parallel grid (Figs 6/11/12),
/// a serial figure loop (Figs 7–10/13/14), or `aimm sweep`.
pub fn workload_seed(base: u64, benches: &[Benchmark]) -> u64 {
    benches.iter().fold(base, |acc, &b| derive_seed(acc, b as u64 + 1))
}

/// Axes of a sweep grid. `cells()` takes the cartesian product.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    /// Workloads; an inner vec with several entries is one multi-program
    /// combination.
    pub benches: Vec<Vec<Benchmark>>,
    pub techniques: Vec<Technique>,
    pub mappings: Vec<MappingScheme>,
    pub meshes: Vec<(usize, usize)>,
    /// Cube-network topologies (EXPERIMENTS.md §Topology). Defaults to
    /// the paper's mesh only.
    pub topologies: Vec<TopologyKind>,
    pub hoard: Vec<bool>,
    /// Base seeds; each is a replicate of the whole grid.
    pub seeds: Vec<u64>,
    pub scale: f64,
    pub runs: usize,
    /// Simulation engine for every cell — a run-wide switch, not an
    /// axis, because both engines yield identical stats (the per-cell
    /// numbers would just duplicate).
    pub engine: Engine,
}

impl SweepGrid {
    /// Default grid: the paper's nine benchmarks under BNMP across the
    /// paper's three mapping schemes on the 4×4 mesh — 27 cells, the
    /// paper's Fig 6 BNMP slice. Deliberately [`Benchmark::PAPER`] and
    /// [`MappingScheme::PAPER`], not `ALL`: registry additions (GCM,
    /// CODA, ORACLE) join a sweep only when asked for (`--benches` /
    /// `--mappings`), so default reports — and the golden fixture
    /// pinned to them — never grow cells.
    pub fn new(scale: f64, runs: usize) -> Self {
        Self {
            benches: Benchmark::PAPER.iter().map(|&b| vec![b]).collect(),
            techniques: vec![Technique::Bnmp],
            mappings: MappingScheme::PAPER.to_vec(),
            meshes: vec![(4, 4)],
            topologies: vec![TopologyKind::Mesh],
            hoard: vec![false],
            seeds: vec![SystemConfig::default().seed],
            scale,
            runs,
            engine: SystemConfig::default().engine,
        }
    }

    /// Cartesian product in fixed nested order: bench → technique →
    /// mapping → mesh → topology → hoard → seed (innermost fastest).
    ///
    /// Cells that differ only in technique / mapping / mesh / topology /
    /// hoard share a workload seed so scheme comparisons hold the trace
    /// constant; cells that differ in workload or base seed get
    /// decorrelated streams via [`workload_seed`], which depends only on
    /// the combo's identity — never on grid position or execution order.
    pub fn cells(&self) -> Vec<SweepCell> {
        let mut out = Vec::new();
        for benches in &self.benches {
            for &technique in &self.techniques {
                for &mapping in &self.mappings {
                    for &mesh in &self.meshes {
                        for &topology in &self.topologies {
                            for &hoard in &self.hoard {
                                for &seed in &self.seeds {
                                    out.push(SweepCell {
                                        benches: benches.clone(),
                                        technique,
                                        mapping,
                                        mesh,
                                        topology,
                                        hoard,
                                        seed: workload_seed(seed, benches),
                                        scale: self.scale,
                                        runs: self.runs,
                                        engine: self.engine,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// Worker count to use when the caller has no preference.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One executed cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub cell: SweepCell,
    pub summary: EpisodeSummary,
}

/// Fan `cells` across up to `threads` scoped workers via [`parallel_map`]
/// and pair each summary with its cell, in grid order. Every cell's
/// config is validated up front, so a bad axis value (say a 1×1 mesh)
/// fails in milliseconds instead of after hours of valid cells whose
/// finished work an error return would discard. On a runtime failure the
/// first failing cell by grid index wins.
pub fn run_grid(cells: &[SweepCell], threads: usize) -> anyhow::Result<Vec<CellResult>> {
    if cells.is_empty() {
        return Ok(Vec::new());
    }
    for (i, cell) in cells.iter().enumerate() {
        cell.config()
            .map_err(|e| anyhow::anyhow!("sweep cell {i} ({}): {e}", cell.name()))?;
    }
    let summaries = parallel_map(cells, threads, SweepCell::run);
    let mut out = Vec::with_capacity(cells.len());
    for (i, res) in summaries.into_iter().enumerate() {
        let summary = res
            .map_err(|e| anyhow::anyhow!("sweep cell {i} ({}) failed: {e}", cells[i].name()))?;
        out.push(CellResult { cell: cells[i].clone(), summary });
    }
    Ok(out)
}

/// Order-preserving parallel map over a slice — the one fan-out primitive
/// in the crate. Workers claim indices through an atomic cursor and send
/// `(index, result)` through an mpsc channel; item `i`'s result lands at
/// index `i` whatever thread computed it. [`run_grid`], the journaled
/// batch runner and the Fig 5 analysis harnesses all sit on top of this.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let threads = threads.clamp(1, items.len());
    if threads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let slots: Vec<Option<R>> = std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                if tx.send((i, f(&items[i]))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots
    });
    slots
        .into_iter()
        .map(|o| o.expect("worker sent every claimed index"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_is_fig6_bnmp_slice() {
        let grid = SweepGrid::new(0.1, 2);
        assert_eq!(grid.mappings, MappingScheme::PAPER.to_vec());
        // Registry additions (GCM) stay out of the default grid.
        assert!(!grid.benches.contains(&vec![Benchmark::Gcm]));
        let cells = grid.cells();
        assert_eq!(cells.len(), 27); // 9 benches × 1 technique × 3 mappings
        // Mapping is the innermost populated axis.
        assert_eq!(cells[0].mapping, MappingScheme::Baseline);
        assert_eq!(cells[1].mapping, MappingScheme::Tom);
        assert_eq!(cells[2].mapping, MappingScheme::Aimm);
        // Same bench ⇒ same workload seed across mappings.
        assert_eq!(cells[0].seed, cells[2].seed);
        // Different bench ⇒ decorrelated seed.
        assert_ne!(cells[0].seed, cells[3].seed);
    }

    #[test]
    fn engine_is_a_switch_not_an_axis() {
        let mut grid = SweepGrid::new(0.1, 1);
        grid.engine = Engine::Polled;
        let cells = grid.cells();
        assert!(cells.iter().all(|c| c.engine == Engine::Polled));
        assert_eq!(cells[0].config().unwrap().engine, Engine::Polled);
        // The engine never leaks into cell names (nor the JSON report),
        // so polled and event reports of the same grid diff clean.
        assert!(!cells[0].name().to_lowercase().contains("polled"));
    }

    #[test]
    fn topology_is_an_axis_with_mesh_default_unchanged() {
        // Default grids carry only the mesh, and a mesh cell's name and
        // JSON never mention topology — pre-topology reports (and the
        // golden fixture) must stay byte-identical.
        let grid = SweepGrid::new(0.1, 1);
        assert_eq!(grid.topologies, vec![TopologyKind::Mesh]);
        let cells = grid.cells();
        assert!(cells.iter().all(|c| c.topology == TopologyKind::Mesh));
        assert!(!cells[0].name().contains("mesh"), "{}", cells[0].name());

        let mut grid = SweepGrid::new(0.1, 1);
        grid.benches = vec![vec![Benchmark::Mac]];
        grid.topologies = vec![TopologyKind::Torus, TopologyKind::Ring];
        let cells = grid.cells();
        assert_eq!(cells.len(), 6); // 1 bench × 3 mappings × 2 topologies
        assert!(cells[0].name().ends_with(&format!("/torus/s{:x}", cells[0].seed)));
        assert!(cells[1].name().contains("/ring/"));
        assert_eq!(cells[0].config().unwrap().topology, TopologyKind::Torus);
        // Same combo ⇒ same workload seed across topologies, so the
        // comparison holds the trace constant.
        assert_eq!(cells[0].seed, cells[1].seed);
    }

    #[test]
    fn derive_seed_is_stable_and_spreads() {
        assert_eq!(derive_seed(7, 3), derive_seed(7, 3));
        assert_ne!(derive_seed(7, 3), derive_seed(7, 4));
        assert_ne!(derive_seed(7, 0), derive_seed(8, 0));
    }

    #[test]
    fn workload_seed_depends_on_combo_not_position() {
        let base = SystemConfig::default().seed;
        // Same combo ⇒ same seed, wherever it sits in a grid.
        assert_eq!(
            workload_seed(base, &[Benchmark::Spmv]),
            workload_seed(base, &[Benchmark::Spmv])
        );
        // Different combo (or order) ⇒ different seed.
        assert_ne!(
            workload_seed(base, &[Benchmark::Spmv]),
            workload_seed(base, &[Benchmark::Mac])
        );
        assert_ne!(
            workload_seed(base, &[Benchmark::Mac, Benchmark::Rd]),
            workload_seed(base, &[Benchmark::Rd, Benchmark::Mac])
        );
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..40).collect();
        let doubled = parallel_map(&items, 4, |&i| i * 2);
        assert_eq!(doubled, (0..40).map(|i| i * 2).collect::<Vec<_>>());
        assert!(parallel_map(&[] as &[usize], 4, |&i| i).is_empty());
    }

    #[test]
    fn invalid_cell_fails_fast() {
        let mut grid = SweepGrid::new(0.03, 1);
        grid.benches = vec![vec![Benchmark::Mac]];
        grid.meshes = vec![(1, 1)]; // below the 2×2 minimum
        let err = run_grid(&grid.cells(), 2).unwrap_err().to_string();
        assert!(err.contains("sweep cell 0"), "{err}");
    }
}
