//! Resumable, shardable design-space sweep batch system (DESIGN.md §6.3
//! and §12).
//!
//! A sweep is a grid of [`SweepCell`]s — benchmark (or multi-program
//! combination) × offloading technique × mapping scheme × mesh dims ×
//! cube-network topology × HOARD × seed — fanned across OS worker
//! threads. Each cell builds its own [`SystemConfig`] from its own seed
//! and runs the §6.1 episode protocol through
//! [`crate::coordinator::run_cell`], so per-cell results are
//! **byte-identical for any worker count**: the simulator holds no
//! global state, and every map reduction on the simulation path breaks
//! ties deterministically (never by hash-iteration order, which differs
//! between threads).
//!
//! The module splits along the batch-system seams:
//!
//! * [`grid`] — cell/grid descriptors, the canonical cell ordering, and
//!   the order-preserving [`parallel_map`] fan-out ([`run_grid`]).
//! * [`cache`] — [`cell_key`], the content hash a journaled sweep caches
//!   completed cells under.
//! * [`journal`] — the crash-safe JSONL journal, resume verification,
//!   `--shard i/n` partitioning and `--merge` ([`run_journaled`]).
//!
//! Results render either as a table (`aimm sweep`) or as a
//! machine-readable `BENCH_sweep.json` report with a fixed key order
//! ([`report_json`]), written atomically (temp file + rename) so an
//! interrupt can never leave a torn report. The figure harnesses for
//! Figs 6, 11 and 12 are grids over this module; Fig 5's per-bench
//! trace analysis fans out through [`parallel_map`].
//!
//! [`SystemConfig`]: crate::config::SystemConfig

pub mod cache;
pub mod grid;
pub mod journal;

pub use cache::{cell_key, CellOutcome, CellRow};
pub use grid::{
    default_threads, derive_seed, parallel_map, run_grid, workload_seed, CellResult, SweepCell,
    SweepGrid,
};
pub use journal::{
    atomic_write_text, journal_path_for, merge_entries, merge_files, run_journaled, JournalEntry,
    ShardSpec, SweepRunReport,
};

use std::path::Path;

use crate::config::{MappingScheme, Technique, TopologyKind};
use crate::metrics::RunStats;

// ---------------------------------------------------------------------
// JSON report (fixed key order — runtime/json.rs can parse it back, and
// the determinism test compares these strings byte-for-byte). The
// writer primitives live in runtime/json.rs (`json::write`) and are
// shared with the agent-checkpoint format; these thin aliases keep the
// report code readable and the emitted bytes unchanged.
// ---------------------------------------------------------------------

use crate::runtime::json::write as jw;

fn jnum(x: f64) -> String {
    jw::num(x)
}

fn jstr(s: &str) -> String {
    jw::string(s)
}

fn jobj(fields: &[(&str, String)]) -> String {
    jw::obj(fields)
}

/// Serialize one run's statistics.
pub fn stats_json(r: &RunStats) -> String {
    jobj(&[
        ("cycles", r.cycles.to_string()),
        ("ops_completed", r.ops_completed.to_string()),
        ("opc", jnum(r.opc())),
        ("avg_hops", jnum(r.avg_hops)),
        ("avg_packet_latency", jnum(r.avg_packet_latency)),
        ("compute_utilization", jnum(r.compute_utilization)),
        ("compute_balance", jnum(r.compute_balance)),
        ("fraction_pages_migrated", jnum(r.fraction_pages_migrated)),
        ("fraction_accesses_on_migrated", jnum(r.fraction_accesses_on_migrated)),
        ("pages_migrated", r.pages_migrated.to_string()),
        ("migrations", r.migrations.to_string()),
        ("row_hit_rate", jnum(r.row_hit_rate)),
        ("agent_invocations", r.agent_invocations.to_string()),
        ("agent_train_steps", r.agent_train_steps.to_string()),
        ("agent_avg_loss", jnum(r.agent_avg_loss)),
        ("agent_cumulative_reward", jnum(r.agent_cumulative_reward)),
        ("energy_aimm_nj", jnum(r.energy.aimm_hardware_nj)),
        ("energy_network_nj", jnum(r.energy.network_nj)),
        ("energy_memory_nj", jnum(r.energy.memory_nj)),
        ("timeline_samples", r.opc_timeline.len().to_string()),
    ])
}

/// Serialize one executed cell: descriptor + per-run stats. These exact
/// bytes are also what the journal records per cell, so cached and
/// fresh cells are indistinguishable in the aggregated report.
pub fn cell_json(res: &CellResult) -> String {
    let c = &res.cell;
    let benches: Vec<String> = c.benches.iter().map(|b| jstr(b.name())).collect();
    let runs: Vec<String> = res.summary.runs.iter().map(stats_json).collect();
    let mut fields: Vec<(&str, String)> = vec![
        ("name", jstr(&res.summary.name)),
        ("benches", format!("[{}]", benches.join(","))),
        ("technique", jstr(c.technique.name())),
        ("mapping", jstr(c.mapping.name())),
        ("mesh", jstr(&format!("{}x{}", c.mesh.0, c.mesh.1))),
    ];
    // Like the cell name's topology segment: emitted only off-default,
    // so pre-topology reports — and the committed golden fixture — stay
    // byte-identical for mesh grids.
    if c.topology != TopologyKind::Mesh {
        fields.push(("topology", jstr(c.topology.name())));
    }
    fields.push(("hoard", c.hoard.to_string()));
    // 0x-hex string, not a bare number: full 64-bit seeds exceed 2^53
    // and would lose bits through any double-based JSON parser
    // (including runtime/json.rs). `aimm run --seed` accepts this 0x
    // form as-is — that is the reproduce-from-report path. Feeding it
    // to `aimm sweep --seeds` would NOT reproduce the cell: grid
    // seeds are base seeds that get re-folded per combo.
    fields.push(("seed", jstr(&format!("{:#x}", c.seed))));
    fields.push(("scale", jnum(c.scale)));
    fields.push(("runs", format!("[{}]", runs.join(","))));
    jobj(&fields)
}

/// The aggregated report around already-serialized cell strings — the
/// one assembly point shared by fresh runs ([`report_json`]), resumed
/// runs ([`report_json_outcomes`]) and shard merges
/// ([`journal::merge_entries`]), so all three emit identical bytes for
/// identical cells.
pub fn report_json_from_cells(cells: &[String]) -> String {
    jobj(&[
        ("schema", jstr("aimm-sweep-v1")),
        ("cell_count", cells.len().to_string()),
        ("cells", format!("[{}]", cells.join(","))),
    ])
}

/// The whole report. Deliberately excludes worker count and wall-clock so
/// the file is reproducible byte-for-byte for a given grid.
pub fn report_json(results: &[CellResult]) -> String {
    report_json_from_cells(&results.iter().map(cell_json).collect::<Vec<_>>())
}

/// [`report_json`] over journaled outcomes: fresh cells serialize, cached
/// cells splice their journal bytes back in verbatim.
pub fn report_json_outcomes(outcomes: &[CellOutcome]) -> String {
    report_json_from_cells(&outcomes.iter().map(CellOutcome::json).collect::<Vec<_>>())
}

/// Write the report to `path` (the `BENCH_sweep.json` artifact)
/// atomically: an interrupt can never leave a torn report, only a stale
/// `<path>.tmp` that the next write overwrites.
pub fn write_report(path: &Path, results: &[CellResult]) -> anyhow::Result<()> {
    atomic_write_text(path, &report_json(results))
}

// ---------------------------------------------------------------------
// Continual-learning report (`BENCH_continual.json`): warm-start cells.
// Same fixed-key-order discipline as the sweep report — the file is
// byte-reproducible for a given grid and parses back through
// runtime/json.rs.
// ---------------------------------------------------------------------

/// One executed curriculum sequence plus the context needed to
/// reproduce it (`aimm curriculum --stages … --seed 0x…`).
#[derive(Debug, Clone)]
pub struct ContinualSequence {
    /// Stage names joined with `>` (e.g. `SC>KM>RD`).
    pub name: String,
    pub technique: Technique,
    pub mapping: MappingScheme,
    pub scale: f64,
    /// The config's master seed (0x-hex in the report, like sweep cells).
    pub seed: u64,
    pub report: crate::coordinator::CurriculumReport,
}

fn stage_json(s: &crate::coordinator::StageOutcome) -> String {
    let warm: Vec<String> = s.warm.runs.iter().map(stats_json).collect();
    let cold: Vec<String> = s.cold.runs.iter().map(stats_json).collect();
    jobj(&[
        ("name", jstr(&s.name)),
        ("runs", s.warm.runs.len().to_string()),
        // The headline transfer numbers, then the full per-run stats.
        ("cold_first_opc", jnum(s.cold_first_opc())),
        ("warm_first_opc", jnum(s.warm_first_opc())),
        ("transfer_gain", jnum(s.transfer_gain())),
        ("cold_last_opc", jnum(s.cold.last().opc())),
        ("warm_last_opc", jnum(s.warm.last().opc())),
        ("cold", format!("[{}]", cold.join(","))),
        ("warm", format!("[{}]", warm.join(","))),
    ])
}

/// Serialize one curriculum sequence.
pub fn sequence_json(seq: &ContinualSequence) -> String {
    let stages: Vec<String> = seq.report.stages.iter().map(stage_json).collect();
    jobj(&[
        ("name", jstr(&seq.name)),
        ("technique", jstr(seq.technique.name())),
        ("mapping", jstr(seq.mapping.name())),
        ("scale", jnum(seq.scale)),
        ("seed", jstr(&format!("{:#x}", seq.seed))),
        ("stages", format!("[{}]", stages.join(","))),
    ])
}

/// The whole continual-learning report.
pub fn continual_report_json(seqs: &[ContinualSequence]) -> String {
    let body: Vec<String> = seqs.iter().map(sequence_json).collect();
    jobj(&[
        ("schema", jstr("aimm-continual-v1")),
        ("sequence_count", seqs.len().to_string()),
        ("sequences", format!("[{}]", body.join(","))),
    ])
}

/// Write the report to `path` (the `BENCH_continual.json` artifact),
/// atomically like [`write_report`].
pub fn write_continual_report(path: &Path, seqs: &[ContinualSequence]) -> anyhow::Result<()> {
    atomic_write_text(path, &continual_report_json(seqs))
}

#[cfg(test)]
mod tests {
    use crate::config::{Engine, SystemConfig};
    use crate::workloads::Benchmark;

    use super::*;

    #[test]
    fn cell_json_carries_topology_only_off_default() {
        let mut grid = SweepGrid::new(0.03, 1);
        grid.benches = vec![vec![Benchmark::Mac]];
        grid.mappings = vec![MappingScheme::Baseline];
        grid.topologies = vec![TopologyKind::Mesh, TopologyKind::Ring];
        let results = run_grid(&grid.cells(), 2).unwrap();
        let mesh_json = cell_json(&results[0]);
        let ring_json = cell_json(&results[1]);
        assert!(!mesh_json.contains("\"topology\""), "{mesh_json}");
        assert!(ring_json.contains("\"topology\":\"ring\""), "{ring_json}");
        // And the report still parses through the in-crate JSON parser.
        let parsed = crate::runtime::json::parse(&report_json(&results)).unwrap();
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert!(cells[0].get("topology").is_none());
        assert_eq!(cells[1].get("topology").unwrap().as_str(), Some("ring"));
    }

    #[test]
    fn json_escaping_and_shape() {
        assert_eq!(jstr("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(jnum(0.25), "0.25");
        assert_eq!(jnum(f64::NAN), "null");
        assert_eq!(jnum(f64::INFINITY), "null");
        let o = jobj(&[("k", "1".to_string())]);
        assert_eq!(o, "{\"k\":1}");
    }

    #[test]
    fn report_assembly_points_agree() {
        // report_json, report_json_outcomes(Fresh) and merge_entries all
        // route through report_json_from_cells — identical bytes.
        let mut grid = SweepGrid::new(0.03, 1);
        grid.benches = vec![vec![Benchmark::Mac]];
        grid.mappings = vec![MappingScheme::Baseline, MappingScheme::Tom];
        let results = run_grid(&grid.cells(), 2).unwrap();
        let direct = report_json(&results);
        let outcomes: Vec<CellOutcome> = results.iter().cloned().map(CellOutcome::Fresh).collect();
        assert_eq!(report_json_outcomes(&outcomes), direct);
        let entries: Vec<JournalEntry> = results
            .iter()
            .enumerate()
            .map(|(i, r)| JournalEntry { idx: i, key: cell_key(&r.cell), cell: cell_json(r) })
            .collect();
        assert_eq!(merge_entries(entries).unwrap(), direct);
    }

    #[test]
    fn continual_report_is_deterministic_and_parses_back() {
        use crate::coordinator::{run_curriculum, CurriculumStage};
        let mut cfg = SystemConfig::default();
        cfg.mapping = MappingScheme::Aimm;
        let stages = vec![
            CurriculumStage { benches: vec![Benchmark::Mac], runs: 1 },
            CurriculumStage { benches: vec![Benchmark::Rd], runs: 1 },
        ];
        let (report, _) = run_curriculum(&cfg, &stages, 0.03, None).unwrap();
        let seq = ContinualSequence {
            name: "MAC>RD".to_string(),
            technique: cfg.technique,
            mapping: cfg.mapping,
            scale: 0.03,
            seed: cfg.seed,
            report,
        };
        let text = continual_report_json(std::slice::from_ref(&seq));
        assert_eq!(text, continual_report_json(&[seq]), "fixed key order");
        let parsed = crate::runtime::json::parse(&text).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("aimm-continual-v1"));
        assert_eq!(parsed.get("sequence_count").unwrap().as_usize(), Some(1));
        let seqs = parsed.get("sequences").unwrap().as_arr().unwrap();
        let stages = seqs[0].get("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        for s in stages {
            assert!(s.get("cold_first_opc").is_some());
            assert!(s.get("warm_first_opc").is_some());
            assert!(s.get("transfer_gain").is_some());
            assert_eq!(s.get("cold").unwrap().as_arr().unwrap().len(), 1);
            assert_eq!(s.get("warm").unwrap().as_arr().unwrap().len(), 1);
        }
    }

    #[test]
    fn tiny_grid_runs_in_parallel() {
        let mut grid = SweepGrid::new(0.03, 1);
        grid.benches = vec![vec![Benchmark::Mac], vec![Benchmark::Rd]];
        let cells = grid.cells();
        assert_eq!(cells.len(), 6);
        let results = run_grid(&cells, 3).unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert!(r.summary.last().ops_completed > 0, "{}", r.cell.name());
        }
        // Report parses back through the in-crate JSON parser.
        let parsed = crate::runtime::json::parse(&report_json(&results)).unwrap();
        assert_eq!(parsed.get("cell_count").unwrap().as_usize(), Some(6));
        assert_eq!(parsed.get("cells").unwrap().as_arr().unwrap().len(), 6);
    }

    #[test]
    fn engine_is_keyed_but_never_serialized() {
        // The report deliberately omits the engine (polled and event
        // sweeps must diff clean), but the cache key includes it — a
        // cached polled cell must never satisfy an event sweep.
        let mut grid = SweepGrid::new(0.1, 1);
        grid.benches = vec![vec![Benchmark::Mac]];
        let event = grid.cells();
        grid.engine = Engine::Polled;
        let polled = grid.cells();
        for (e, p) in event.iter().zip(&polled) {
            assert_eq!(e.name(), p.name());
            assert_ne!(cell_key(e), cell_key(p));
        }
    }
}
