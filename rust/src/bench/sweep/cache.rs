//! Content-hashed cell identity (DESIGN.md §12).
//!
//! [`cell_key`] is the identity a journaled sweep caches against: a
//! splitmix64 fold over a canonical serialization of *every*
//! [`SweepCell`] field — each benchmark in order, technique, mapping,
//! mesh dims, topology, HOARD, seed, scale bits, run count, and the
//! engine (which is deliberately absent from the display name and the
//! JSON report). Two cells share a key only if they would run the exact
//! same experiment, so a journal entry whose key matches the current
//! grid can be reused without re-simulating — and one whose key doesn't
//! is recomputed, never silently trusted.
//!
//! The key is a pure function of the cell: worker count, shard
//! assignment and grid position never feed it (property-tested below).

use crate::runtime::json::{self, Json};
use crate::sim::Rng;

use super::cell_json;
use super::grid::{CellResult, SweepCell};

/// Version tag folded into every key: bump when the canonical
/// serialization changes so stale journals from an older layout can
/// never alias a current cell.
const KEY_DOMAIN: &[u8] = b"aimm-cell-key-v1";

/// One splitmix64 fold step — the same golden-ratio-spread mix as
/// [`super::derive_seed`], chained so field order matters.
fn fold(acc: u64, v: u64) -> u64 {
    Rng::new(acc ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64()
}

/// Fold a byte string: its length first (so `"ab","c"` and `"a","bc"`
/// cannot collide), then its little-endian 8-byte chunks, zero-padded.
fn fold_bytes(acc: u64, bytes: &[u8]) -> u64 {
    let mut acc = fold(acc, bytes.len() as u64);
    for chunk in bytes.chunks(8) {
        let mut le = [0u8; 8];
        le[..chunk.len()].copy_from_slice(chunk);
        acc = fold(acc, u64::from_le_bytes(le));
    }
    acc
}

/// The cell's content hash: stable across processes, machines, worker
/// counts and shard assignments; different for any single-field change.
pub fn cell_key(cell: &SweepCell) -> u64 {
    let mut acc = fold_bytes(0, KEY_DOMAIN);
    acc = fold(acc, cell.benches.len() as u64);
    for b in &cell.benches {
        acc = fold_bytes(acc, b.name().as_bytes());
    }
    acc = fold_bytes(acc, cell.technique.name().as_bytes());
    acc = fold_bytes(acc, cell.mapping.name().as_bytes());
    acc = fold(acc, cell.mesh.0 as u64);
    acc = fold(acc, cell.mesh.1 as u64);
    acc = fold_bytes(acc, cell.topology.name().as_bytes());
    acc = fold(acc, cell.hoard as u64);
    acc = fold(acc, cell.seed);
    acc = fold(acc, cell.scale.to_bits());
    acc = fold(acc, cell.runs as u64);
    fold_bytes(acc, cell.engine.name().as_bytes())
}

/// One cell of a (possibly resumed) sweep: computed fresh this process,
/// or replayed verbatim from a journal. The cached variant carries the
/// journal's serialized cell *bytes*, so a resumed or merged report is
/// byte-identical to an uninterrupted run by construction — no float
/// ever takes a parse/re-format round trip.
#[derive(Debug, Clone)]
pub enum CellOutcome {
    Fresh(CellResult),
    Cached { key: u64, json: String },
}

impl CellOutcome {
    /// The serialized cell, exactly as the aggregated report embeds it.
    pub fn json(&self) -> String {
        match self {
            CellOutcome::Fresh(res) => cell_json(res),
            CellOutcome::Cached { json, .. } => json.clone(),
        }
    }

    /// The summary-table row (parsed back out of the serialized cell
    /// for cached entries).
    pub fn row(&self) -> anyhow::Result<CellRow> {
        match self {
            CellOutcome::Fresh(res) => {
                let last = res.summary.last();
                Ok(CellRow {
                    name: res.cell.name(),
                    cycles: last.cycles,
                    opc: last.opc(),
                    avg_hops: last.avg_hops,
                    compute_utilization: last.compute_utilization,
                    fraction_pages_migrated: last.fraction_pages_migrated,
                    cached: false,
                })
            }
            CellOutcome::Cached { json, .. } => CellRow::from_cell_json(json),
        }
    }
}

/// The fields the `aimm sweep` summary table prints for one cell.
#[derive(Debug, Clone)]
pub struct CellRow {
    pub name: String,
    pub cycles: u64,
    pub opc: f64,
    pub avg_hops: f64,
    pub compute_utilization: f64,
    pub fraction_pages_migrated: f64,
    /// Whether this row was replayed from a journal instead of run.
    pub cached: bool,
}

fn str_field<'a>(j: &'a Json, key: &str) -> anyhow::Result<&'a str> {
    j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("cell JSON missing string field {key:?}"))
}

/// Numeric field, tolerant of the writer's NaN/∞ → `null` convention.
fn num_field(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(f64::NAN)
}

impl CellRow {
    /// Rebuild the display row from one serialized cell ([`cell_json`]
    /// output): the cell name is re-derived from the recorded axes —
    /// [`super::stats_json`] keys the per-run numbers the table shows.
    pub fn from_cell_json(text: &str) -> anyhow::Result<CellRow> {
        let j = json::parse(text)?;
        let benches = j
            .get("benches")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("cell JSON missing benches"))?;
        let combo = benches
            .iter()
            .map(|b| b.as_str().unwrap_or("?"))
            .collect::<Vec<_>>()
            .join("-");
        // The topology segment exists only off-default, mirroring
        // SweepCell::name / cell_json.
        let topology = match j.get("topology").and_then(Json::as_str) {
            Some(t) => format!("/{t}"),
            None => String::new(),
        };
        let hoard = matches!(j.get("hoard"), Some(Json::Bool(true)));
        let seed = json::parse_hex_u64(str_field(&j, "seed")?)?;
        let name = format!(
            "{}/{}/{}/{}{}{}/s{:x}",
            combo,
            str_field(&j, "technique")?,
            str_field(&j, "mapping")?,
            str_field(&j, "mesh")?,
            topology,
            if hoard { "/HOARD" } else { "" },
            seed,
        );
        let runs = j
            .get("runs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("cell JSON missing runs"))?;
        let last = runs.last().ok_or_else(|| anyhow::anyhow!("cell JSON has zero runs"))?;
        Ok(CellRow {
            name,
            cycles: num_field(last, "cycles") as u64,
            opc: num_field(last, "opc"),
            avg_hops: num_field(last, "avg_hops"),
            compute_utilization: num_field(last, "compute_utilization"),
            fraction_pages_migrated: num_field(last, "fraction_pages_migrated"),
            cached: true,
        })
    }
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    use crate::config::{Engine, MappingScheme, Technique, TopologyKind};
    use crate::workloads::Benchmark;

    use super::super::grid::SweepGrid;
    use super::*;

    fn base() -> SweepCell {
        SweepCell {
            benches: vec![Benchmark::Mac],
            technique: Technique::Bnmp,
            mapping: MappingScheme::Aimm,
            mesh: (4, 4),
            topology: TopologyKind::Mesh,
            hoard: false,
            seed: 7,
            scale: 0.1,
            runs: 2,
            engine: Engine::Event,
        }
    }

    /// The single-field-sensitivity property: changing any one field —
    /// every axis, the seed, the engine — changes the key, and no two
    /// mutants collide with each other either.
    #[test]
    fn every_field_feeds_the_key() {
        let k0 = cell_key(&base());
        assert_eq!(k0, cell_key(&base()), "key is a pure function");
        let mut seen = HashSet::new();
        seen.insert(k0);
        let mut check = |cell: SweepCell, what: &str| {
            let k = cell_key(&cell);
            assert_ne!(k, k0, "{what} did not change the key");
            assert!(seen.insert(k), "{what} collided with another mutant");
        };
        for b in Benchmark::ALL {
            if b != Benchmark::Mac {
                let mut c = base();
                c.benches = vec![b];
                check(c, b.name());
            }
        }
        let mut c = base();
        c.benches = vec![Benchmark::Mac, Benchmark::Rd];
        check(c, "combo grows");
        let mut c = base();
        c.benches = vec![Benchmark::Rd, Benchmark::Mac];
        check(c, "combo order");
        for t in Technique::ALL {
            if t != Technique::Bnmp {
                let mut c = base();
                c.technique = t;
                check(c, t.name());
            }
        }
        for m in MappingScheme::ALL {
            if m != MappingScheme::Aimm {
                let mut c = base();
                c.mapping = m;
                check(c, m.name());
            }
        }
        let mut c = base();
        c.mesh = (8, 4);
        check(c, "mesh cols");
        let mut c = base();
        c.mesh = (4, 8);
        check(c, "mesh rows (transpose must differ from cols)");
        for t in TopologyKind::ALL {
            if t != TopologyKind::Mesh {
                let mut c = base();
                c.topology = t;
                check(c, t.name());
            }
        }
        let mut c = base();
        c.hoard = true;
        check(c, "hoard");
        let mut c = base();
        c.seed = 8;
        check(c, "seed");
        let mut c = base();
        c.scale = 0.2;
        check(c, "scale");
        let mut c = base();
        c.runs = 3;
        check(c, "runs");
        let mut c = base();
        c.engine = Engine::Polled;
        check(c, "engine");
    }

    /// Keys depend only on cell content: identical for clones, and the
    /// same whether a cell is looked at from the full grid or from any
    /// shard partition of it.
    #[test]
    fn key_is_position_and_shard_independent() {
        let mut g = SweepGrid::new(0.05, 1);
        g.benches = vec![vec![Benchmark::Mac], vec![Benchmark::Rd], vec![Benchmark::Spmv]];
        let cells = g.cells();
        let direct: Vec<u64> = cells.iter().map(cell_key).collect();
        assert_eq!(direct.len(), HashSet::<u64>::from_iter(direct.clone()).len());
        for n in [2usize, 4] {
            for s in 0..n {
                let shard: Vec<(usize, SweepCell)> = cells
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % n == s)
                    .map(|(i, c)| (i, c.clone()))
                    .collect();
                for (i, c) in shard {
                    assert_eq!(cell_key(&c), direct[i], "shard {s}/{n} cell {i}");
                }
            }
        }
    }

    #[test]
    fn row_from_cell_json_rebuilds_the_cell_name() {
        // Serialize a real (tiny) result both ways and compare rows.
        let mut g = SweepGrid::new(0.03, 1);
        g.benches = vec![vec![Benchmark::Mac]];
        g.mappings = vec![MappingScheme::Baseline];
        g.topologies = vec![TopologyKind::Ring];
        g.hoard = vec![true];
        let results = super::super::run_grid(&g.cells(), 1).unwrap();
        let fresh = CellOutcome::Fresh(results[0].clone()).row().unwrap();
        let cached = CellRow::from_cell_json(&cell_json(&results[0])).unwrap();
        assert_eq!(fresh.name, cached.name);
        assert_eq!(fresh.name, results[0].cell.name());
        assert_eq!(fresh.cycles, cached.cycles);
        assert_eq!(fresh.opc, cached.opc);
        assert_eq!(fresh.avg_hops, cached.avg_hops);
        assert!(!fresh.cached);
        assert!(cached.cached);
    }
}
