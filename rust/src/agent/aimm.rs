//! The AIMM control loop (paper Fig 4): periodically pull state from the
//! MCs, compute the reward for the previous action from the OPC delta,
//! store the transition, ε-greedily pick the next action, and train the
//! dueling Q-network from replay.

use crate::config::AgentConfig;
use crate::runtime::QFunction;
use crate::sim::{Cycle, History, Rng};

use super::actions::Action;
use super::checkpoint::{AgentCheckpoint, ReplaySnapshot};
use super::replay::{ReplayBuffer, Transition};
use super::state::StateVec;

/// Capacity of the recent-global-actions history feeding the state
/// histogram (a fixed hardware buffer in the paper's AIMM unit).
const ACTION_HISTORY_CAP: usize = 16;

/// What the system should do after an invocation.
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    pub action: Action,
    /// Interval (cycles) until the next invocation.
    pub next_interval: u64,
}

/// Agent bookkeeping surfaced in RunStats.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AgentStats {
    pub invocations: u64,
    pub train_steps: u64,
    pub loss_sum: f64,
    pub cumulative_reward: f64,
    pub action_counts: [u64; 8],
    /// Summed reward attributed to each action (diagnostics).
    pub action_reward_sum: [f64; 8],
    /// Energy events (§7.7): weight-matrix / replay / state-buffer.
    pub weight_accesses: u64,
    pub replay_accesses: u64,
    pub state_buf_accesses: u64,
}

/// The agent.
pub struct AimmAgent {
    qf: Box<dyn QFunction>,
    pub replay: ReplayBuffer,
    cfg: AgentConfig,
    rng: Rng,
    eps: f32,
    interval_idx: usize,
    pending: Option<(StateVec, Action)>,
    prev_opc: Option<f64>,
    invocations_since_train: u32,
    trains_since_sync: u32,
    /// Recent global actions (for the state histogram).
    pub action_history: History,
    pub stats: AgentStats,
}

impl AimmAgent {
    /// Construct an agent, validating the configuration against the
    /// backend. In particular a backend with a shape-specialized train
    /// executable ([`QFunction::fixed_batch`], i.e. the PJRT artifacts)
    /// rejects a contradicting `AgentConfig.batch_size` here — loudly,
    /// instead of mis-batching or silently ignoring the knob.
    pub fn try_new(qf: Box<dyn QFunction>, cfg: AgentConfig, seed: u64) -> anyhow::Result<Self> {
        anyhow::ensure!(!cfg.intervals.is_empty(), "agent needs at least one interval");
        anyhow::ensure!(cfg.batch_size > 0, "agent batch_size must be positive");
        anyhow::ensure!(
            cfg.replay_capacity >= cfg.batch_size,
            "replay_capacity {} smaller than batch_size {}",
            cfg.replay_capacity,
            cfg.batch_size
        );
        if let Some(fixed) = qf.fixed_batch() {
            anyhow::ensure!(
                cfg.batch_size == fixed,
                "backend {:?} trains a fixed batch of {fixed} (AOT artifact shape) but \
                 AgentConfig.batch_size = {} — regenerate the artifacts or drop the override",
                qf.backend(),
                cfg.batch_size
            );
        }
        let eps = cfg.eps_start;
        let interval_idx = cfg.initial_interval.min(cfg.intervals.len() - 1);
        Ok(Self {
            qf,
            replay: ReplayBuffer::new(cfg.replay_capacity, cfg.batch_size),
            cfg,
            rng: Rng::new(seed),
            eps,
            interval_idx,
            pending: None,
            prev_opc: None,
            invocations_since_train: 0,
            trains_since_sync: 0,
            action_history: History::new(ACTION_HISTORY_CAP),
            stats: AgentStats::default(),
        })
    }

    /// [`AimmAgent::try_new`] for callers with a known-good config;
    /// panics (loudly, with the validation message) on a bad one.
    pub fn new(qf: Box<dyn QFunction>, cfg: AgentConfig, seed: u64) -> Self {
        Self::try_new(qf, cfg, seed).expect("invalid agent configuration")
    }

    pub fn backend(&self) -> &'static str {
        self.qf.backend()
    }

    /// The hyperparameter configuration this agent runs under (the
    /// checkpoint plumbing validates resumes against it).
    pub fn config(&self) -> &AgentConfig {
        &self.cfg
    }

    /// Direct Q-network probe for diagnostics and tests: evaluates
    /// Q(s, ·) without counting an invocation, drawing randomness or
    /// touching the control state.
    pub fn probe_q(&mut self, s: &StateVec) -> anyhow::Result<[f32; 8]> {
        self.qf.q_values(s)
    }

    pub fn current_interval(&self) -> u64 {
        self.cfg.intervals[self.interval_idx]
    }

    /// Interval index normalised to [0, 1] for the state vector.
    pub fn interval_norm(&self) -> f32 {
        if self.cfg.intervals.len() <= 1 {
            0.0
        } else {
            self.interval_idx as f32 / (self.cfg.intervals.len() - 1) as f32
        }
    }

    /// Action histogram over the recent global history (state input).
    pub fn action_histogram(&self) -> [f32; 8] {
        let mut h = [0.0f32; 8];
        let n = self.action_history.len().max(1) as f32;
        for a in self.action_history.iter() {
            h[(a as usize).min(7)] += 1.0 / n;
        }
        h
    }

    pub fn epsilon(&self) -> f32 {
        self.eps
    }

    /// Reward from the OPC delta (paper §4.2: ±1 on improvement /
    /// degradation, 0 otherwise, with a small deadband).
    fn reward(&self, opc_now: f64) -> f32 {
        let Some(prev) = self.prev_opc else { return 0.0 };
        let band = self.cfg.reward_deadband * prev.max(1e-9);
        if opc_now > prev + band {
            1.0
        } else if opc_now < prev - band {
            -1.0
        } else {
            0.0
        }
    }

    /// One agent invocation. `state` is the freshly assembled state,
    /// `opc_now` the OPC observed over the elapsed interval.
    pub fn invoke(
        &mut self,
        state: StateVec,
        opc_now: f64,
        _now: Cycle,
    ) -> anyhow::Result<Decision> {
        self.stats.invocations += 1;
        self.stats.state_buf_accesses += 1;

        // Close out the previous (s, a) with its observed reward.
        let r = self.reward(opc_now);
        if let Some((s_prev, a_prev)) = self.pending.take() {
            self.stats.cumulative_reward += r as f64;
            self.stats.action_reward_sum[a_prev.index()] += r as f64;
            self.replay.push(Transition {
                s: s_prev,
                a: a_prev.index() as u8,
                r,
                s2: state,
                done: false,
            });
            self.stats.replay_accesses += 1;
        }

        // Train on schedule.
        self.invocations_since_train += 1;
        if self.invocations_since_train >= self.cfg.train_every && self.replay.has_batch() {
            self.invocations_since_train = 0;
            if let Some(batch) = self.replay.sample(&mut self.rng) {
                let rows = batch.batch_len() as u64;
                let loss = self.qf.train_batch(&batch)?;
                self.stats.train_steps += 1;
                self.stats.loss_sum += loss as f64;
                self.stats.weight_accesses += rows;
                self.stats.replay_accesses += rows;
                self.trains_since_sync += 1;
                if self.trains_since_sync >= self.cfg.target_sync {
                    self.trains_since_sync = 0;
                    self.qf.sync_target();
                }
            }
        }

        // ε-greedy action selection.
        let action = if self.rng.f32() < self.eps {
            Action::from_index(self.rng.index(8))
        } else {
            self.stats.weight_accesses += 1;
            let q = self.qf.q_values(&state)?;
            let mut best = 0;
            for i in 1..q.len() {
                if q[i] > q[best] {
                    best = i;
                }
            }
            Action::from_index(best)
        };
        self.eps = (self.eps * self.cfg.eps_decay).max(self.cfg.eps_end);
        self.stats.action_counts[action.index()] += 1;
        self.action_history.push(action.index() as f32);

        // Interval adjustment actions apply immediately (§4.2).
        match action {
            Action::IncreaseInterval => {
                self.interval_idx = (self.interval_idx + 1).min(self.cfg.intervals.len() - 1);
            }
            Action::DecreaseInterval => {
                self.interval_idx = self.interval_idx.saturating_sub(1);
            }
            _ => {}
        }

        self.pending = Some((state, action));
        self.prev_opc = Some(opc_now);
        Ok(Decision { action, next_interval: self.current_interval() })
    }

    /// Close the episode: final transition is terminal. The DNN model is
    /// deliberately retained (the paper re-runs episodes "where each time
    /// simulation states are cleared except the DNN model", §6.1).
    pub fn finish_episode(&mut self, final_state: StateVec, opc_now: f64) {
        let r = self.reward(opc_now);
        if let Some((s_prev, a_prev)) = self.pending.take() {
            self.stats.cumulative_reward += r as f64;
            self.replay.push(Transition {
                s: s_prev,
                a: a_prev.index() as u8,
                r,
                s2: final_state,
                done: true,
            });
            self.stats.replay_accesses += 1;
        }
        self.prev_opc = None;
    }

    /// Reset per-episode control state (keeps the learned network,
    /// replay memory and ε schedule — continual learning).
    pub fn start_episode(&mut self) {
        self.pending = None;
        self.prev_opc = None;
        self.interval_idx = self.cfg.initial_interval.min(self.cfg.intervals.len() - 1);
    }

    pub fn avg_loss(&self) -> f64 {
        if self.stats.train_steps == 0 {
            0.0
        } else {
            self.stats.loss_sum / self.stats.train_steps as f64
        }
    }

    /// The batch size oracle-distillation pre-training must shape its
    /// batches to, or a loud error naming the backend when it declares
    /// no fixed batch (see [`crate::runtime::warm_start_batch`]). Probed
    /// at configuration time so `--warm-start` on an unsupported backend
    /// fails before any simulation runs.
    pub fn warm_start_batch(&self) -> anyhow::Result<usize> {
        crate::runtime::warm_start_batch(self.qf.as_ref())
    }

    /// Imitation pre-training (oracle distillation, `agent/distill.rs`):
    /// run the labeled batches through the backend and sync the target
    /// network once. Deliberately does NOT move [`AgentStats`] — those
    /// counters describe the RL phase, and warm-start provenance is
    /// recorded in the checkpoint bundle instead, so a warm-started
    /// agent's reported train/energy stats stay comparable to a cold one.
    pub fn pretrain(&mut self, batches: &[crate::runtime::TrainBatch]) -> anyhow::Result<f32> {
        crate::runtime::pretrain(self.qf.as_mut(), batches)
    }

    /// Capture a continual-learning checkpoint (DESIGN.md §9). Only legal
    /// at an episode boundary — after [`AimmAgent::finish_episode`] /
    /// before the next run's first invocation — because an in-flight
    /// `(s, a)` pair cannot be resumed bit-identically (its reward
    /// depends on simulator state the checkpoint does not carry).
    pub fn checkpoint(&self) -> anyhow::Result<AgentCheckpoint> {
        anyhow::ensure!(
            self.pending.is_none() && self.prev_opc.is_none(),
            "checkpoint must be captured at an episode boundary \
             (a transition is still in flight)"
        );
        let (transitions, head) = self.replay.export();
        Ok(AgentCheckpoint {
            cfg: self.cfg.clone(),
            q: self.qf.snapshot()?,
            eps: self.eps,
            interval_idx: self.interval_idx,
            invocations_since_train: self.invocations_since_train,
            trains_since_sync: self.trains_since_sync,
            rng_state: self.rng.state(),
            action_history: self.action_history.iter().collect(),
            replay: ReplaySnapshot {
                capacity: self.replay.capacity(),
                batch: self.replay.batch(),
                head,
                pushes: self.replay.pushes,
                samples: self.replay.samples,
                transitions,
            },
            stats: self.stats.clone(),
        })
    }

    /// Rebuild an agent from a checkpoint. `qf` must already hold the
    /// restored parameters (see `AgentCheckpoint::build_agent`, which
    /// wires both steps); this validates the control state against `cfg`
    /// and rehydrates it exactly — including the ε-greedy RNG stream —
    /// so resuming reproduces the uninterrupted run bit-for-bit.
    pub fn from_checkpoint(
        qf: Box<dyn QFunction>,
        cfg: AgentConfig,
        ck: &AgentCheckpoint,
    ) -> anyhow::Result<Self> {
        let mut agent = Self::try_new(qf, cfg, 0)?;
        // The whole config must match what the checkpoint was trained
        // under: a drifted train_every / ε schedule / interval table
        // would silently break bit-identical resume. Changing
        // hyperparameters means starting a new agent, not resuming one.
        anyhow::ensure!(
            ck.cfg == agent.cfg,
            "checkpoint was trained under a different agent configuration — resume \
             requires the identical AgentConfig (saved: {:?}, given: {:?})",
            ck.cfg,
            agent.cfg
        );
        anyhow::ensure!(
            ck.interval_idx < agent.cfg.intervals.len(),
            "checkpoint interval_idx {} out of range for {} configured intervals",
            ck.interval_idx,
            agent.cfg.intervals.len()
        );
        anyhow::ensure!(
            ck.replay.capacity == agent.cfg.replay_capacity,
            "checkpoint replay capacity {} != configured replay_capacity {} — \
             a resized ring cannot resume bit-identically",
            ck.replay.capacity,
            agent.cfg.replay_capacity
        );
        anyhow::ensure!(
            ck.replay.batch == agent.cfg.batch_size,
            "checkpoint batch size {} != configured batch_size {}",
            ck.replay.batch,
            agent.cfg.batch_size
        );
        anyhow::ensure!(
            ck.action_history.len() <= ACTION_HISTORY_CAP,
            "checkpoint action history has {} entries, capacity is {ACTION_HISTORY_CAP}",
            ck.action_history.len()
        );
        agent.replay = ReplayBuffer::restore(
            ck.replay.capacity,
            ck.replay.batch,
            ck.replay.transitions.clone(),
            ck.replay.head,
            ck.replay.pushes,
            ck.replay.samples,
        )?;
        agent.rng = Rng::from_state(ck.rng_state);
        agent.eps = ck.eps;
        agent.interval_idx = ck.interval_idx;
        agent.invocations_since_train = ck.invocations_since_train;
        agent.trains_since_sync = ck.trains_since_sync;
        for &a in &ck.action_history {
            agent.action_history.push(a);
        }
        agent.stats = ck.stats.clone();
        Ok(agent)
    }
}

/// PJRT seam: the same control loop driven by the AOT-compiled dueling
/// network. Compiled only with `--features pjrt`; skips loudly when the
/// artifacts are absent or the build links the offline `xla` API stub
/// (whose client constructor errors instead of executing).
#[cfg(all(test, feature = "pjrt"))]
mod pjrt_tests {
    use super::*;
    use crate::config::AgentConfig;
    use crate::runtime::{artifacts_dir, PjrtQNet, STATE_DIM};

    #[test]
    fn agent_control_loop_drives_pjrt_backend() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let Ok(q) = PjrtQNet::load(&dir, 1e-3, 0.95) else {
            eprintln!("SKIP: artifacts present but PJRT unavailable (API-stub build)");
            return;
        };
        let mut a = AimmAgent::new(Box::new(q), AgentConfig::default(), 42);
        assert_eq!(a.backend(), "pjrt");
        for i in 0..48u64 {
            let mut s = [0.0f32; STATE_DIM];
            s[0] = (i % 8) as f32 / 8.0;
            s[29] = 0.5;
            a.invoke(s, 0.1 + (i % 3) as f64 * 0.1, i * 100).unwrap();
        }
        assert_eq!(a.stats.invocations, 48);
        assert!(a.replay.len() > 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AgentConfig;
    use crate::runtime::{LinearQ, STATE_DIM};

    fn agent(cfg: AgentConfig) -> AimmAgent {
        AimmAgent::new(Box::new(LinearQ::new(0.01, 0.95, 7)), cfg, 42)
    }

    fn s(v: f32) -> StateVec {
        let mut out = [0.0; STATE_DIM];
        out[0] = v;
        out
    }

    #[test]
    fn interval_actions_move_index() {
        let mut cfg = AgentConfig::default();
        cfg.eps_start = 0.0;
        cfg.eps_end = 0.0;
        let mut a = agent(cfg.clone());
        let start = a.current_interval();
        // Force interval actions directly.
        a.interval_idx = 0;
        assert_eq!(a.current_interval(), cfg.intervals[0]);
        a.interval_idx = cfg.intervals.len() - 1;
        assert_eq!(a.current_interval(), *cfg.intervals.last().unwrap());
        assert!(start > 0);
    }

    #[test]
    fn transitions_accumulate_and_training_happens() {
        let mut cfg = AgentConfig::default();
        cfg.train_every = 1;
        let mut a = agent(cfg);
        for i in 0..100 {
            let opc = 0.1 + (i % 5) as f64 * 0.05;
            a.invoke(s(i as f32 / 100.0), opc, i as u64 * 100).unwrap();
        }
        assert_eq!(a.stats.invocations, 100);
        assert_eq!(a.replay.len(), 99); // first invocation has no prior (s, a)
        assert!(a.stats.train_steps > 0);
    }

    #[test]
    fn rewards_reflect_opc_delta() {
        let cfg = AgentConfig::default();
        let mut a = agent(cfg);
        a.invoke(s(0.0), 0.5, 0).unwrap();
        assert_eq!(a.reward(0.6), 1.0);
        assert_eq!(a.reward(0.4), -1.0);
        assert_eq!(a.reward(0.5005), 0.0); // inside deadband
    }

    #[test]
    fn epsilon_decays_to_floor() {
        let mut cfg = AgentConfig::default();
        cfg.eps_decay = 0.5;
        cfg.eps_end = 0.1;
        let mut a = agent(cfg);
        for i in 0..20 {
            a.invoke(s(0.0), 0.1, i).unwrap();
        }
        assert!((a.epsilon() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn finish_episode_marks_terminal() {
        let cfg = AgentConfig::default();
        let mut a = agent(cfg);
        a.invoke(s(0.1), 0.2, 0).unwrap();
        a.finish_episode(s(0.2), 0.3);
        assert_eq!(a.replay.len(), 1);
        // Internal control state cleared; model retained.
        a.start_episode();
        assert!(a.pending.is_none());
        assert_eq!(a.replay.len(), 1);
    }

    /// `AgentConfig.batch_size` is honored end-to-end: a smaller batch
    /// unlocks training as soon as the replay holds that many rows.
    #[test]
    fn smaller_batch_size_trains_earlier() {
        let mut small = AgentConfig::default();
        small.batch_size = 8;
        let mut a = agent(small);
        let mut b = agent(AgentConfig::default()); // batch 32
        for i in 0..12u64 {
            let opc = 0.1 + (i % 3) as f64 * 0.1;
            a.invoke(s(i as f32 / 12.0), opc, i * 100).unwrap();
            b.invoke(s(i as f32 / 12.0), opc, i * 100).unwrap();
        }
        // 11 stored transitions: enough for a batch of 8, not of 32.
        assert!(a.stats.train_steps > 0, "batch_size 8 must have trained");
        assert_eq!(b.stats.train_steps, 0, "batch_size 32 must still be waiting");
    }

    #[test]
    fn try_new_rejects_fixed_batch_mismatch() {
        struct FixedBatchQ;
        impl QFunction for FixedBatchQ {
            fn q_values(&mut self, _s: &[f32]) -> anyhow::Result<[f32; 8]> {
                Ok([0.0; 8])
            }
            fn train_batch(&mut self, _b: &crate::runtime::TrainBatch) -> anyhow::Result<f32> {
                Ok(0.0)
            }
            fn sync_target(&mut self) {}
            fn backend(&self) -> &'static str {
                "fixed-batch-test"
            }
            fn fixed_batch(&self) -> Option<usize> {
                Some(32)
            }
        }
        let mut cfg = AgentConfig::default();
        cfg.batch_size = 16;
        let err = AimmAgent::try_new(Box::new(FixedBatchQ), cfg, 1).unwrap_err().to_string();
        assert!(err.contains("fixed batch"), "{err}");
        // The matching size constructs fine.
        assert!(AimmAgent::try_new(Box::new(FixedBatchQ), AgentConfig::default(), 1).is_ok());
        // And an oversized batch relative to the replay is rejected too.
        let mut cfg = AgentConfig::default();
        cfg.replay_capacity = 16;
        cfg.batch_size = 32;
        assert!(AimmAgent::try_new(Box::new(FixedBatchQ), cfg, 1).is_err());
    }

    #[test]
    fn checkpoint_only_at_episode_boundary() {
        let mut a = agent(AgentConfig::default());
        assert!(a.checkpoint().is_ok(), "fresh agent is at a boundary");
        a.invoke(s(0.1), 0.2, 0).unwrap();
        assert!(a.checkpoint().is_err(), "transition in flight");
        a.finish_episode(s(0.2), 0.3);
        assert!(a.checkpoint().is_ok(), "boundary after finish_episode");
    }

    /// Capture → serialize → parse → rebuild → capture again must be
    /// byte-identical: the checkpoint carries the *complete* agent.
    #[test]
    fn checkpoint_roundtrip_is_byte_identical() {
        let mut cfg = AgentConfig::default();
        cfg.train_every = 1;
        let mut a = agent(cfg.clone());
        for i in 0..60u64 {
            let opc = 0.1 + (i % 5) as f64 * 0.05;
            a.invoke(s(i as f32 / 60.0), opc, i * 100).unwrap();
        }
        a.finish_episode(s(0.9), 0.2);
        assert!(a.stats.train_steps > 0, "test needs a trained network");
        let text = a.checkpoint().unwrap().to_json();

        let back = crate::agent::checkpoint::AgentCheckpoint::parse(&text).unwrap();
        let mut qf = Box::new(LinearQ::new(0.9, 0.1, 777)); // overwritten by restore
        qf.restore(&back.q).unwrap();
        let b = AimmAgent::from_checkpoint(qf, cfg.clone(), &back).unwrap();
        assert_eq!(b.checkpoint().unwrap().to_json(), text);
        assert_eq!(b.epsilon(), a.epsilon());
        assert_eq!(b.replay.len(), a.replay.len());
        assert_eq!(b.stats, a.stats);
        assert_eq!(b.current_interval(), a.current_interval());

        // A config that cannot resume bit-identically is rejected loudly —
        // capacity drift and dynamics drift (train_every) alike.
        let mut resized = cfg.clone();
        resized.replay_capacity = cfg.replay_capacity * 2;
        let mut qf = Box::new(LinearQ::new(0.9, 0.1, 777));
        qf.restore(&back.q).unwrap();
        assert!(AimmAgent::from_checkpoint(qf, resized, &back).is_err());
        let mut drifted = cfg.clone();
        drifted.train_every = cfg.train_every + 1;
        let mut qf = Box::new(LinearQ::new(0.9, 0.1, 777));
        qf.restore(&back.q).unwrap();
        let err = AimmAgent::from_checkpoint(qf, drifted, &back).unwrap_err().to_string();
        assert!(err.contains("different agent configuration"), "{err}");
    }

    #[test]
    fn greedy_exploits_learned_values() {
        let mut cfg = AgentConfig::default();
        cfg.eps_start = 0.0;
        cfg.eps_end = 0.0;
        cfg.train_every = 1;
        let mut a = agent(cfg);
        // Feed a cycle where OPC always improves: every action gets +1;
        // after training the greedy action must be a valid index and
        // stats must track it.
        for i in 0..200 {
            a.invoke(s(0.5), i as f64, i).unwrap();
        }
        let total: u64 = a.stats.action_counts.iter().sum();
        assert_eq!(total, 200);
    }
}
