//! The AIMM control loop (paper Fig 4): periodically pull state from the
//! MCs, compute the reward for the previous action from the OPC delta,
//! store the transition, ε-greedily pick the next action, and train the
//! dueling Q-network from replay.

use crate::config::AgentConfig;
use crate::runtime::QFunction;
use crate::sim::{Cycle, History, Rng};

use super::actions::Action;
use super::replay::{ReplayBuffer, Transition};
use super::state::StateVec;

/// What the system should do after an invocation.
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    pub action: Action,
    /// Interval (cycles) until the next invocation.
    pub next_interval: u64,
}

/// Agent bookkeeping surfaced in RunStats.
#[derive(Debug, Clone, Default)]
pub struct AgentStats {
    pub invocations: u64,
    pub train_steps: u64,
    pub loss_sum: f64,
    pub cumulative_reward: f64,
    pub action_counts: [u64; 8],
    /// Summed reward attributed to each action (diagnostics).
    pub action_reward_sum: [f64; 8],
    /// Energy events (§7.7): weight-matrix / replay / state-buffer.
    pub weight_accesses: u64,
    pub replay_accesses: u64,
    pub state_buf_accesses: u64,
}

/// The agent.
pub struct AimmAgent {
    qf: Box<dyn QFunction>,
    pub replay: ReplayBuffer,
    cfg: AgentConfig,
    rng: Rng,
    eps: f32,
    interval_idx: usize,
    pending: Option<(StateVec, Action)>,
    prev_opc: Option<f64>,
    invocations_since_train: u32,
    trains_since_sync: u32,
    /// Recent global actions (for the state histogram).
    pub action_history: History,
    pub stats: AgentStats,
}

impl AimmAgent {
    pub fn new(qf: Box<dyn QFunction>, cfg: AgentConfig, seed: u64) -> Self {
        let eps = cfg.eps_start;
        let interval_idx = cfg.initial_interval.min(cfg.intervals.len() - 1);
        Self {
            qf,
            replay: ReplayBuffer::new(cfg.replay_capacity),
            cfg,
            rng: Rng::new(seed),
            eps,
            interval_idx,
            pending: None,
            prev_opc: None,
            invocations_since_train: 0,
            trains_since_sync: 0,
            action_history: History::new(16),
            stats: AgentStats::default(),
        }
    }

    pub fn backend(&self) -> &'static str {
        self.qf.backend()
    }

    pub fn current_interval(&self) -> u64 {
        self.cfg.intervals[self.interval_idx]
    }

    /// Interval index normalised to [0, 1] for the state vector.
    pub fn interval_norm(&self) -> f32 {
        if self.cfg.intervals.len() <= 1 {
            0.0
        } else {
            self.interval_idx as f32 / (self.cfg.intervals.len() - 1) as f32
        }
    }

    /// Action histogram over the recent global history (state input).
    pub fn action_histogram(&self) -> [f32; 8] {
        let mut h = [0.0f32; 8];
        let n = self.action_history.len().max(1) as f32;
        for a in self.action_history.iter() {
            h[(a as usize).min(7)] += 1.0 / n;
        }
        h
    }

    pub fn epsilon(&self) -> f32 {
        self.eps
    }

    /// Reward from the OPC delta (paper §4.2: ±1 on improvement /
    /// degradation, 0 otherwise, with a small deadband).
    fn reward(&self, opc_now: f64) -> f32 {
        let Some(prev) = self.prev_opc else { return 0.0 };
        let band = self.cfg.reward_deadband * prev.max(1e-9);
        if opc_now > prev + band {
            1.0
        } else if opc_now < prev - band {
            -1.0
        } else {
            0.0
        }
    }

    /// One agent invocation. `state` is the freshly assembled state,
    /// `opc_now` the OPC observed over the elapsed interval.
    pub fn invoke(&mut self, state: StateVec, opc_now: f64, _now: Cycle) -> anyhow::Result<Decision> {
        self.stats.invocations += 1;
        self.stats.state_buf_accesses += 1;

        // Close out the previous (s, a) with its observed reward.
        let r = self.reward(opc_now);
        if let Some((s_prev, a_prev)) = self.pending.take() {
            self.stats.cumulative_reward += r as f64;
            self.stats.action_reward_sum[a_prev.index()] += r as f64;
            self.replay.push(Transition {
                s: s_prev,
                a: a_prev.index() as u8,
                r,
                s2: state,
                done: false,
            });
            self.stats.replay_accesses += 1;
        }

        // Train on schedule.
        self.invocations_since_train += 1;
        if self.invocations_since_train >= self.cfg.train_every && self.replay.has_batch() {
            self.invocations_since_train = 0;
            if let Some(batch) = self.replay.sample(&mut self.rng) {
                let loss = self.qf.train_batch(&batch)?;
                self.stats.train_steps += 1;
                self.stats.loss_sum += loss as f64;
                self.stats.weight_accesses += crate::runtime::BATCH as u64;
                self.stats.replay_accesses += crate::runtime::BATCH as u64;
                self.trains_since_sync += 1;
                if self.trains_since_sync >= self.cfg.target_sync {
                    self.trains_since_sync = 0;
                    self.qf.sync_target();
                }
            }
        }

        // ε-greedy action selection.
        let action = if self.rng.f32() < self.eps {
            Action::from_index(self.rng.index(8))
        } else {
            self.stats.weight_accesses += 1;
            let q = self.qf.q_values(&state)?;
            let mut best = 0;
            for i in 1..q.len() {
                if q[i] > q[best] {
                    best = i;
                }
            }
            Action::from_index(best)
        };
        self.eps = (self.eps * self.cfg.eps_decay).max(self.cfg.eps_end);
        self.stats.action_counts[action.index()] += 1;
        self.action_history.push(action.index() as f32);

        // Interval adjustment actions apply immediately (§4.2).
        match action {
            Action::IncreaseInterval => {
                self.interval_idx = (self.interval_idx + 1).min(self.cfg.intervals.len() - 1);
            }
            Action::DecreaseInterval => {
                self.interval_idx = self.interval_idx.saturating_sub(1);
            }
            _ => {}
        }

        self.pending = Some((state, action));
        self.prev_opc = Some(opc_now);
        Ok(Decision { action, next_interval: self.current_interval() })
    }

    /// Close the episode: final transition is terminal. The DNN model is
    /// deliberately retained (the paper re-runs episodes "where each time
    /// simulation states are cleared except the DNN model", §6.1).
    pub fn finish_episode(&mut self, final_state: StateVec, opc_now: f64) {
        let r = self.reward(opc_now);
        if let Some((s_prev, a_prev)) = self.pending.take() {
            self.stats.cumulative_reward += r as f64;
            self.replay.push(Transition {
                s: s_prev,
                a: a_prev.index() as u8,
                r,
                s2: final_state,
                done: true,
            });
            self.stats.replay_accesses += 1;
        }
        self.prev_opc = None;
    }

    /// Reset per-episode control state (keeps the learned network,
    /// replay memory and ε schedule — continual learning).
    pub fn start_episode(&mut self) {
        self.pending = None;
        self.prev_opc = None;
        self.interval_idx = self.cfg.initial_interval.min(self.cfg.intervals.len() - 1);
    }

    pub fn avg_loss(&self) -> f64 {
        if self.stats.train_steps == 0 {
            0.0
        } else {
            self.stats.loss_sum / self.stats.train_steps as f64
        }
    }
}

/// PJRT seam: the same control loop driven by the AOT-compiled dueling
/// network. Compiled only with `--features pjrt`; skips loudly when the
/// artifacts are absent or the build links the offline `xla` API stub
/// (whose client constructor errors instead of executing).
#[cfg(all(test, feature = "pjrt"))]
mod pjrt_tests {
    use super::*;
    use crate::config::AgentConfig;
    use crate::runtime::{artifacts_dir, PjrtQNet, STATE_DIM};

    #[test]
    fn agent_control_loop_drives_pjrt_backend() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let Ok(q) = PjrtQNet::load(&dir, 1e-3, 0.95) else {
            eprintln!("SKIP: artifacts present but PJRT unavailable (API-stub build)");
            return;
        };
        let mut a = AimmAgent::new(Box::new(q), AgentConfig::default(), 42);
        assert_eq!(a.backend(), "pjrt");
        for i in 0..48u64 {
            let mut s = [0.0f32; STATE_DIM];
            s[0] = (i % 8) as f32 / 8.0;
            s[29] = 0.5;
            a.invoke(s, 0.1 + (i % 3) as f64 * 0.1, i * 100).unwrap();
        }
        assert_eq!(a.stats.invocations, 48);
        assert!(a.replay.len() > 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AgentConfig;
    use crate::runtime::{LinearQ, STATE_DIM};

    fn agent(cfg: AgentConfig) -> AimmAgent {
        AimmAgent::new(Box::new(LinearQ::new(0.01, 0.95, 7)), cfg, 42)
    }

    fn s(v: f32) -> StateVec {
        let mut out = [0.0; STATE_DIM];
        out[0] = v;
        out
    }

    #[test]
    fn interval_actions_move_index() {
        let mut cfg = AgentConfig::default();
        cfg.eps_start = 0.0;
        cfg.eps_end = 0.0;
        let mut a = agent(cfg.clone());
        let start = a.current_interval();
        // Force interval actions directly.
        a.interval_idx = 0;
        assert_eq!(a.current_interval(), cfg.intervals[0]);
        a.interval_idx = cfg.intervals.len() - 1;
        assert_eq!(a.current_interval(), *cfg.intervals.last().unwrap());
        assert!(start > 0);
    }

    #[test]
    fn transitions_accumulate_and_training_happens() {
        let mut cfg = AgentConfig::default();
        cfg.train_every = 1;
        let mut a = agent(cfg);
        for i in 0..100 {
            let opc = 0.1 + (i % 5) as f64 * 0.05;
            a.invoke(s(i as f32 / 100.0), opc, i as u64 * 100).unwrap();
        }
        assert_eq!(a.stats.invocations, 100);
        assert_eq!(a.replay.len(), 99); // first invocation has no prior (s, a)
        assert!(a.stats.train_steps > 0);
    }

    #[test]
    fn rewards_reflect_opc_delta() {
        let cfg = AgentConfig::default();
        let mut a = agent(cfg);
        a.invoke(s(0.0), 0.5, 0).unwrap();
        assert_eq!(a.reward(0.6), 1.0);
        assert_eq!(a.reward(0.4), -1.0);
        assert_eq!(a.reward(0.5005), 0.0); // inside deadband
    }

    #[test]
    fn epsilon_decays_to_floor() {
        let mut cfg = AgentConfig::default();
        cfg.eps_decay = 0.5;
        cfg.eps_end = 0.1;
        let mut a = agent(cfg);
        for i in 0..20 {
            a.invoke(s(0.0), 0.1, i).unwrap();
        }
        assert!((a.epsilon() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn finish_episode_marks_terminal() {
        let cfg = AgentConfig::default();
        let mut a = agent(cfg);
        a.invoke(s(0.1), 0.2, 0).unwrap();
        a.finish_episode(s(0.2), 0.3);
        assert_eq!(a.replay.len(), 1);
        // Internal control state cleared; model retained.
        a.start_episode();
        assert!(a.pending.is_none());
        assert_eq!(a.replay.len(), 1);
    }

    #[test]
    fn greedy_exploits_learned_values() {
        let mut cfg = AgentConfig::default();
        cfg.eps_start = 0.0;
        cfg.eps_end = 0.0;
        cfg.train_every = 1;
        let mut a = agent(cfg);
        // Feed a cycle where OPC always improves: every action gets +1;
        // after training the greedy action must be a valid index and
        // stats must track it.
        for i in 0..200 {
            a.invoke(s(0.5), i as f64, i).unwrap();
        }
        let total: u64 = a.stats.action_counts.iter().sum();
        assert_eq!(total, 200);
    }
}
