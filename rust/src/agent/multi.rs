//! Per-MC multi-agent AIMM (DESIGN.md §15): instead of one global agent
//! observing the whole system, `--mapping aimm-mc` runs one lightweight
//! [`AimmAgent`] per memory controller. Each agent sees only its own
//! MC's counters and attached cubes (the masked state is assembled in
//! `mapping/policy.rs`); coordination happens through deterministic
//! round-robin **gossip**: every [`GOSSIP_EVERY`] invocations
//! system-wide, one agent hands its [`GOSSIP_BURST`] freshest replay
//! transitions to its ring neighbor. The shared replay schema
//! ([`Transition`](super::replay::Transition)) makes the exchange a
//! plain push — no translation layer, no weight averaging.
//!
//! Everything is seeded from `cfg.seed` through [`mc_seed`], so the
//! whole pool is bit-reproducible at any worker count: agent `i`'s RNG
//! stream depends only on the config seed and its MC index, and the
//! gossip schedule is a pure function of the (deterministic) invocation
//! count.

use crate::config::SystemConfig;
use crate::runtime::best_qfunction;

use super::aimm::AimmAgent;

/// System-wide invocations between gossip exchanges. Small enough that
/// neighbors see each other's fresh experience within a few intervals,
/// large enough that replay buffers stay dominated by local experience.
pub const GOSSIP_EVERY: u64 = 8;

/// Transitions handed over per exchange.
pub const GOSSIP_BURST: usize = 4;

/// The usual splitmix64 golden-ratio increment — used as a per-MC fold
/// so sibling agents land on well-separated RNG streams.
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Derive MC `mc`'s private seed from the config seed. `mc + 1` keeps
/// MC 0 off the raw config seed (which the single-agent path folds
/// differently), and the golden-ratio multiply separates the streams.
pub fn mc_seed(seed: u64, mc: usize) -> u64 {
    seed ^ GOLDEN.wrapping_mul(mc as u64 + 1)
}

/// Build the per-MC agent pool: one agent per memory controller, each
/// on its own [`mc_seed`]-derived Q-init and RNG stream (the `^ 0xA6E7`
/// fold mirrors the single-agent `fresh_agent` idiom). All agents share
/// the one [`crate::config::AgentConfig`] — they are deliberately
/// lightweight clones of the same architecture, differing only in what
/// they observe.
pub fn fresh_mc_agents(cfg: &SystemConfig) -> anyhow::Result<Vec<AimmAgent>> {
    (0..cfg.num_mcs())
        .map(|mc| {
            let s = mc_seed(cfg.seed, mc);
            AimmAgent::try_new(
                best_qfunction(cfg.agent.lr, cfg.agent.gamma, s, cfg.agent.batch_size),
                cfg.agent.clone(),
                s ^ 0xA6E7,
            )
        })
        .collect()
}

/// One gossip exchange: agent `from` pushes its `burst` freshest
/// transitions (oldest of those first, preserving push order) into its
/// ring successor's replay buffer. Returns how many transitions moved.
/// The receiver's replay-access counter moves (those are real buffer
/// writes, and the energy model should see them); the sender only
/// reads.
pub fn gossip_exchange(agents: &mut [AimmAgent], from: usize, burst: usize) -> usize {
    let n = agents.len();
    if n < 2 {
        return 0;
    }
    let to = (from + 1) % n;
    let payload = agents[from].replay.recent(burst);
    let moved = payload.len();
    for t in payload {
        agents[to].replay.push(t);
    }
    agents[to].stats.replay_accesses += moved as u64;
    moved
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::replay::Transition;
    use crate::config::MappingScheme;
    use crate::runtime::{LinearQ, STATE_DIM};

    fn pool(seed: u64) -> Vec<AimmAgent> {
        let mut cfg = SystemConfig::default();
        cfg.seed = seed;
        cfg.mapping = MappingScheme::AimmMc;
        fresh_mc_agents(&cfg).unwrap()
    }

    fn t(r: f32) -> Transition {
        Transition { s: [0.0; STATE_DIM], a: 1, r, s2: [0.0; STATE_DIM], done: false }
    }

    #[test]
    fn mc_seeds_are_distinct_and_stable() {
        let seeds: Vec<u64> = (0..4).map(|mc| mc_seed(42, mc)).collect();
        for i in 0..seeds.len() {
            assert_ne!(seeds[i], 42, "no agent rides the raw config seed");
            for j in i + 1..seeds.len() {
                assert_ne!(seeds[i], seeds[j]);
            }
        }
        assert_eq!(seeds, (0..4).map(|mc| mc_seed(42, mc)).collect::<Vec<_>>());
    }

    #[test]
    fn pool_is_one_agent_per_mc_with_separated_streams() {
        let mut agents = pool(7);
        assert_eq!(agents.len(), SystemConfig::default().num_mcs());
        // Distinct Q-inits: the same probe state answers differently.
        let mut qs = Vec::new();
        for a in &mut agents {
            let q = a.probe_q(&[0.25; STATE_DIM]).unwrap();
            qs.push(q.map(f32::to_bits));
        }
        for i in 0..qs.len() {
            for j in i + 1..qs.len() {
                assert_ne!(qs[i], qs[j], "agents {i} and {j} share a Q-init");
            }
        }
    }

    /// Satellite (c): the gossip-merge known answer. With fixed seeds the
    /// exchanged transition sequence is exact — the sender's newest
    /// `GOSSIP_BURST` in push order land appended to the receiver's
    /// buffer, and a re-run reproduces it byte for byte.
    #[test]
    fn gossip_known_answer_is_exact() {
        let run = || {
            let mut cfg = SystemConfig::default();
            cfg.seed = 3;
            let mut agents = vec![
                AimmAgent::new(Box::new(LinearQ::new(0.05, 0.9, 1)), cfg.agent.clone(), 10),
                AimmAgent::new(Box::new(LinearQ::new(0.05, 0.9, 2)), cfg.agent.clone(), 20),
                AimmAgent::new(Box::new(LinearQ::new(0.05, 0.9, 3)), cfg.agent.clone(), 30),
            ];
            for i in 0..6 {
                agents[0].replay.push(t(i as f32));
            }
            agents[1].replay.push(t(100.0));
            let moved = gossip_exchange(&mut agents, 0, GOSSIP_BURST);
            (moved, agents)
        };
        let (moved, agents) = run();
        assert_eq!(moved, GOSSIP_BURST);
        // Receiver = its own transition, then the sender's newest 4 in
        // push order: rewards 2, 3, 4, 5.
        let rewards: Vec<f32> = agents[1].replay.recent(99).iter().map(|x| x.r).collect();
        assert_eq!(rewards, vec![100.0, 2.0, 3.0, 4.0, 5.0]);
        // Sender and bystander untouched.
        assert_eq!(agents[0].replay.len(), 6);
        assert_eq!(agents[2].replay.len(), 0);
        assert_eq!(agents[1].stats.replay_accesses, GOSSIP_BURST as u64 + 1);
        // Bit-reproducible.
        let (moved2, agents2) = run();
        assert_eq!(moved2, moved);
        let again: Vec<u32> =
            agents2[1].replay.recent(99).iter().map(|x| x.r.to_bits()).collect();
        assert_eq!(again, rewards.iter().map(|r| r.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn gossip_ring_wraps_and_degenerates_safely() {
        let cfg = SystemConfig::default();
        let mk = |s| AimmAgent::new(Box::new(LinearQ::new(0.05, 0.9, s)), cfg.agent.clone(), s);
        let mut agents = vec![mk(1), mk(2)];
        agents[1].replay.push(t(7.0));
        // from = last index wraps to agent 0.
        assert_eq!(gossip_exchange(&mut agents, 1, GOSSIP_BURST), 1);
        assert_eq!(agents[0].replay.recent(99).last().unwrap().r, 7.0);
        // Fewer than `burst` available: sends what exists.
        let mut single = vec![mk(3)];
        single[0].replay.push(t(1.0));
        assert_eq!(gossip_exchange(&mut single, 0, GOSSIP_BURST), 0, "no self-gossip");
    }
}
