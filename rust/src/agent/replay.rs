//! Experience replay (paper §4.3 / §5.2): a ring buffer of transitions
//! `(s, a, r, s')` sampled uniformly at random into training batches,
//! consolidating past experience for a robust learning process.

use crate::runtime::{TrainBatch, BATCH, STATE_DIM};
use crate::sim::Rng;

use super::state::StateVec;

/// One transition.
#[derive(Debug, Clone)]
pub struct Transition {
    pub s: StateVec,
    pub a: u8,
    pub r: f32,
    pub s2: StateVec,
    pub done: bool,
}

/// Fixed-capacity ring buffer.
pub struct ReplayBuffer {
    buf: Vec<Transition>,
    capacity: usize,
    head: usize,
    /// Total pushes (energy accounting: one replay-buffer access each).
    pub pushes: u64,
    /// Total samples drawn.
    pub samples: u64,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= BATCH);
        Self { buf: Vec::with_capacity(capacity), capacity, head: 0, pushes: 0, samples: 0 }
    }

    pub fn push(&mut self, t: Transition) {
        self.pushes += 1;
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn has_batch(&self) -> bool {
        self.buf.len() >= BATCH
    }

    /// Draw a uniform batch (with replacement across draws, without
    /// within a batch when possible).
    pub fn sample(&mut self, rng: &mut Rng) -> Option<TrainBatch> {
        if !self.has_batch() {
            return None;
        }
        self.samples += BATCH as u64;
        let mut s = Vec::with_capacity(BATCH * STATE_DIM);
        let mut a = Vec::with_capacity(BATCH);
        let mut r = Vec::with_capacity(BATCH);
        let mut s2 = Vec::with_capacity(BATCH * STATE_DIM);
        let mut done = Vec::with_capacity(BATCH);
        for _ in 0..BATCH {
            let t = &self.buf[rng.index(self.buf.len())];
            s.extend_from_slice(&t.s);
            a.push(t.a as i32);
            r.push(t.r);
            s2.extend_from_slice(&t.s2);
            done.push(if t.done { 1.0 } else { 0.0 });
        }
        Some(TrainBatch { s, a, r, s2, done })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(r: f32) -> Transition {
        Transition { s: [0.0; STATE_DIM], a: 1, r, s2: [0.0; STATE_DIM], done: false }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(BATCH);
        for i in 0..BATCH + 5 {
            rb.push(t(i as f32));
        }
        assert_eq!(rb.len(), BATCH);
        // Oldest 5 rewards (0..5) must be gone.
        let rewards: Vec<f32> = rb.buf.iter().map(|x| x.r).collect();
        for old in 0..5 {
            assert!(!rewards.contains(&(old as f32)));
        }
    }

    #[test]
    fn sample_requires_batch() {
        let mut rb = ReplayBuffer::new(64);
        let mut rng = Rng::new(4);
        assert!(rb.sample(&mut rng).is_none());
        for i in 0..BATCH {
            rb.push(t(i as f32));
        }
        let b = rb.sample(&mut rng).unwrap();
        assert!(b.validate().is_ok());
        assert_eq!(b.r.len(), BATCH);
    }

    #[test]
    fn sampled_values_come_from_buffer() {
        let mut rb = ReplayBuffer::new(64);
        let mut rng = Rng::new(5);
        for i in 0..40 {
            rb.push(t(i as f32));
        }
        let b = rb.sample(&mut rng).unwrap();
        assert!(b.r.iter().all(|&r| (0.0..40.0).contains(&r)));
    }
}
