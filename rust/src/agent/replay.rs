//! Experience replay (paper §4.3 / §5.2): a ring buffer of transitions
//! `(s, a, r, s')` sampled uniformly at random into training batches,
//! consolidating past experience for a robust learning process.
//!
//! The batch size is the configured `AgentConfig.batch_size` — not the
//! compiled-in [`crate::runtime::BATCH`], which only pins the PJRT
//! artifact shapes (an agent on that backend is constructed with the
//! matching size or rejected, see `AimmAgent::try_new`).

use crate::runtime::{TrainBatch, STATE_DIM};
use crate::sim::Rng;

use super::state::StateVec;

/// One transition.
#[derive(Debug, Clone, PartialEq)]
pub struct Transition {
    pub s: StateVec,
    pub a: u8,
    pub r: f32,
    pub s2: StateVec,
    pub done: bool,
}

/// Fixed-capacity ring buffer.
pub struct ReplayBuffer {
    buf: Vec<Transition>,
    capacity: usize,
    /// Rows per sampled training batch.
    batch: usize,
    head: usize,
    /// Total pushes (energy accounting: one replay-buffer access each).
    pub pushes: u64,
    /// Total samples drawn.
    pub samples: u64,
}

impl ReplayBuffer {
    pub fn new(capacity: usize, batch: usize) -> Self {
        assert!(batch > 0, "replay batch size must be positive");
        assert!(
            capacity >= batch,
            "replay capacity {capacity} smaller than batch size {batch}"
        );
        Self { buf: Vec::with_capacity(capacity), capacity, batch, head: 0, pushes: 0, samples: 0 }
    }

    pub fn push(&mut self, t: Transition) {
        self.pushes += 1;
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn has_batch(&self) -> bool {
        self.buf.len() >= self.batch
    }

    /// Draw a uniform batch (with replacement across draws, without
    /// within a batch when possible).
    pub fn sample(&mut self, rng: &mut Rng) -> Option<TrainBatch> {
        if !self.has_batch() {
            return None;
        }
        self.samples += self.batch as u64;
        let n = self.batch;
        let mut s = Vec::with_capacity(n * STATE_DIM);
        let mut a = Vec::with_capacity(n);
        let mut r = Vec::with_capacity(n);
        let mut s2 = Vec::with_capacity(n * STATE_DIM);
        let mut done = Vec::with_capacity(n);
        for _ in 0..n {
            let t = &self.buf[rng.index(self.buf.len())];
            s.extend_from_slice(&t.s);
            a.push(t.a as i32);
            r.push(t.r);
            s2.extend_from_slice(&t.s2);
            done.push(if t.done { 1.0 } else { 0.0 });
        }
        Some(TrainBatch { s, a, r, s2, done })
    }

    /// The `n` most recently pushed transitions, oldest of those first
    /// (fewer when the buffer holds fewer). This is the gossip payload of
    /// the multi-agent policy (`agent/multi.rs`): each agent hands its
    /// freshest experience to its ring neighbor. Pure read — no counter
    /// moves, so gossip inspection never perturbs sampling.
    pub fn recent(&self, n: usize) -> Vec<Transition> {
        let take = n.min(self.buf.len());
        let mut out = Vec::with_capacity(take);
        // Newest element sits just before `head` once the ring has
        // wrapped, at `len - 1` before that.
        let newest =
            if self.buf.len() < self.capacity { self.buf.len() } else { self.head + self.capacity };
        for i in (newest - take)..newest {
            out.push(self.buf[i % self.capacity].clone());
        }
        out
    }

    /// Checkpoint export: the ring's *physical* layout. Sampling indexes
    /// `buf` directly and overwrites advance from `head`, so restoring
    /// the logical order alone would perturb every later RNG-indexed
    /// draw — bit-identical resume needs the exact physical state.
    pub fn export(&self) -> (Vec<Transition>, usize) {
        (self.buf.clone(), self.head)
    }

    /// Rebuild a buffer from checkpoint state. Validates the invariants
    /// `push` maintains: `head` stays 0 until the ring is full, and the
    /// buffer never exceeds its capacity.
    pub fn restore(
        capacity: usize,
        batch: usize,
        buf: Vec<Transition>,
        head: usize,
        pushes: u64,
        samples: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(batch > 0, "replay batch size must be positive");
        anyhow::ensure!(
            capacity >= batch,
            "replay capacity {capacity} smaller than batch size {batch}"
        );
        anyhow::ensure!(
            buf.len() <= capacity,
            "checkpoint holds {} transitions but capacity is {capacity}",
            buf.len()
        );
        anyhow::ensure!(
            if buf.len() < capacity { head == 0 } else { head < capacity },
            "checkpoint replay head {head} inconsistent with {} / {capacity} entries",
            buf.len()
        );
        let mut out = Self::new(capacity, batch);
        out.buf = buf;
        out.head = head;
        out.pushes = pushes;
        out.samples = samples;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::BATCH;

    fn t(r: f32) -> Transition {
        Transition { s: [0.0; STATE_DIM], a: 1, r, s2: [0.0; STATE_DIM], done: false }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(BATCH, BATCH);
        for i in 0..BATCH + 5 {
            rb.push(t(i as f32));
        }
        assert_eq!(rb.len(), BATCH);
        // Oldest 5 rewards (0..5) must be gone.
        let rewards: Vec<f32> = rb.buf.iter().map(|x| x.r).collect();
        for old in 0..5 {
            assert!(!rewards.contains(&(old as f32)));
        }
    }

    #[test]
    fn sample_requires_batch() {
        let mut rb = ReplayBuffer::new(64, BATCH);
        let mut rng = Rng::new(4);
        assert!(rb.sample(&mut rng).is_none());
        for i in 0..BATCH {
            rb.push(t(i as f32));
        }
        let b = rb.sample(&mut rng).unwrap();
        assert!(b.validate().is_ok());
        assert_eq!(b.r.len(), BATCH);
    }

    #[test]
    fn sampled_values_come_from_buffer() {
        let mut rb = ReplayBuffer::new(64, BATCH);
        let mut rng = Rng::new(5);
        for i in 0..40 {
            rb.push(t(i as f32));
        }
        let b = rb.sample(&mut rng).unwrap();
        assert!(b.r.iter().all(|&r| (0.0..40.0).contains(&r)));
    }

    /// `batch_size` is honored: a non-default batch changes when sampling
    /// unlocks and how many rows come back.
    #[test]
    fn configured_batch_size_drives_sampling() {
        let mut rb = ReplayBuffer::new(64, 8);
        let mut rng = Rng::new(6);
        for i in 0..7 {
            rb.push(t(i as f32));
        }
        assert!(!rb.has_batch());
        assert!(rb.sample(&mut rng).is_none());
        rb.push(t(7.0));
        assert!(rb.has_batch());
        let b = rb.sample(&mut rng).unwrap();
        assert_eq!(b.batch_len(), 8);
        assert!(b.validate().is_ok());
        assert_eq!(rb.samples, 8);
    }

    #[test]
    #[should_panic(expected = "smaller than batch size")]
    fn capacity_below_batch_rejected() {
        ReplayBuffer::new(4, 8);
    }

    #[test]
    fn export_restore_is_physically_exact() {
        let mut rb = ReplayBuffer::new(8, 4);
        for i in 0..11 {
            rb.push(t(i as f32)); // wraps: head advances 3 slots
        }
        let (buf, head) = rb.export();
        assert_eq!(head, 3);
        let mut restored =
            ReplayBuffer::restore(8, 4, buf, head, rb.pushes, rb.samples).unwrap();
        assert_eq!(restored.buf, rb.buf);
        // Identical RNG draws after restore: same physical indexing.
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let b1 = rb.sample(&mut r1).unwrap();
        let b2 = restored.sample(&mut r2).unwrap();
        assert_eq!(b1.r, b2.r);
        assert_eq!(b1.a, b2.a);
        // Further pushes overwrite the same slots.
        rb.push(t(99.0));
        restored.push(t(99.0));
        assert_eq!(rb.buf, restored.buf);
        assert_eq!(rb.head, restored.head);
    }

    /// `recent` walks the logical (push) order even across the ring's
    /// wrap point, and reads without touching the access counters.
    #[test]
    fn recent_returns_newest_in_push_order() {
        let mut rb = ReplayBuffer::new(8, 4);
        for i in 0..5 {
            rb.push(t(i as f32));
        }
        // Not yet wrapped.
        assert_eq!(rb.recent(3).iter().map(|x| x.r).collect::<Vec<_>>(), vec![2.0, 3.0, 4.0]);
        assert_eq!(rb.recent(99).len(), 5);
        for i in 5..11 {
            rb.push(t(i as f32)); // wraps: head now 3
        }
        let (_, head) = rb.export();
        assert_eq!(head, 3);
        assert_eq!(rb.recent(4).iter().map(|x| x.r).collect::<Vec<_>>(), vec![
            7.0, 8.0, 9.0, 10.0
        ]);
        let pushes_before = rb.pushes;
        let samples_before = rb.samples;
        let _ = rb.recent(2);
        assert_eq!((rb.pushes, rb.samples), (pushes_before, samples_before));
    }

    #[test]
    fn restore_rejects_inconsistent_state() {
        // More transitions than capacity.
        assert!(ReplayBuffer::restore(4, 4, (0..5).map(|i| t(i as f32)).collect(), 0, 5, 0)
            .is_err());
        // Non-zero head on a partially-filled ring.
        assert!(ReplayBuffer::restore(8, 4, (0..3).map(|i| t(i as f32)).collect(), 1, 3, 0)
            .is_err());
        // Head out of range on a full ring.
        assert!(ReplayBuffer::restore(4, 4, (0..4).map(|i| t(i as f32)).collect(), 4, 4, 0)
            .is_err());
        // Valid full ring.
        assert!(ReplayBuffer::restore(4, 4, (0..4).map(|i| t(i as f32)).collect(), 2, 9, 4)
            .is_ok());
    }
}
