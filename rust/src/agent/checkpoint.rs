//! Versioned continual-learning checkpoints (DESIGN.md §9).
//!
//! The paper's headline claim is that the DNN *persists*: episodes clear
//! every simulation state except the model (§6.1), and §7.4 warm-starts
//! new programs from a network trained on others. This module gives that
//! persistence a durable form: everything the agent needs to resume —
//! Q-parameters, target network, optimizer moments, replay memory,
//! ε/interval schedule, RNG stream and lifetime stats — round-trips
//! through a single JSON document written with the fixed-key-order
//! writer in [`crate::runtime::json::write`].
//!
//! ## Bit-identity
//!
//! The format is engineered so that *save at an episode boundary → load →
//! finish the protocol* produces byte-identical `RunStats` to the
//! uninterrupted run (enforced by `rust/tests/continual.rs`, under both
//! engines):
//!
//! * every `f32` is stored as its IEEE-754 bit pattern in a JSON integer
//!   (≤ 2^32, exact in a double), every `f64` and `u64` as a `0x`-hex
//!   *string* (doubles only carry 53 bits) — no decimal round-tripping
//!   anywhere;
//! * the replay ring is captured in **physical** order plus its head
//!   index — sampling indexes the ring directly, so logical order alone
//!   would perturb later draws;
//! * the agent's ε-greedy RNG resumes via [`crate::sim::Rng::from_state`].
//!
//! Checkpoints are only captured at episode boundaries (no transition in
//! flight); [`AimmAgent::checkpoint`] rejects anything else.
//!
//! ## v2: bundles
//!
//! PR 10's learning subsystem checkpoints as a [`CheckpointBundle`]
//! (`aimm-checkpoint-v2`): a list of per-agent documents — one for the
//! single-agent policy, one per MC for `--mapping aimm-mc` — plus the
//! warm-start provenance (`--warm-start`). Each entry in the `agents`
//! array is a complete v1 document, so the per-agent layout (and its
//! bit-identity guarantee) is unchanged; v1 files still load, as a
//! one-agent bundle with no warm-start recorded.
//! [`CheckpointBundle::ensure_resumable`] refuses resumes whose per-MC
//! agent count or warm-start mode drifted, naming the field.

use std::path::Path;

use crate::config::AgentConfig;
use crate::runtime::json::{self, parse_hex_u64, write, Json};
use crate::runtime::{best_qfunction, QSnapshot};

use super::aimm::{AgentStats, AimmAgent};
use super::distill::WarmStart;
use super::replay::Transition;

/// Format identifier; bump on any layout change.
pub const SCHEMA: &str = "aimm-checkpoint-v1";
/// Numeric format version carried alongside [`SCHEMA`].
pub const VERSION: u64 = 1;

/// Bundle format identifier (multi-agent + warm-start provenance).
pub const SCHEMA_V2: &str = "aimm-checkpoint-v2";
/// Numeric format version carried alongside [`SCHEMA_V2`].
pub const VERSION_V2: u64 = 2;

/// Exact physical state of the replay ring.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplaySnapshot {
    pub capacity: usize,
    pub batch: usize,
    pub head: usize,
    pub pushes: u64,
    pub samples: u64,
    /// Ring contents in physical (slot) order.
    pub transitions: Vec<Transition>,
}

/// Everything needed to resume the agent bit-identically at an episode
/// boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentCheckpoint {
    /// The full agent configuration the checkpoint was trained under.
    /// Resume validates the live config against this field-by-field and
    /// fails loudly on any drift (`AimmAgent::from_checkpoint`): a
    /// changed `train_every`, ε schedule or interval table would
    /// silently break the bit-identical-resume guarantee otherwise.
    pub cfg: AgentConfig,
    pub q: QSnapshot,
    pub eps: f32,
    pub interval_idx: usize,
    pub invocations_since_train: u32,
    pub trains_since_sync: u32,
    /// Raw ε-greedy RNG state ([`crate::sim::Rng::state`]).
    pub rng_state: u64,
    /// Recent global actions, oldest → newest (capacity 16 in the agent).
    pub action_history: Vec<f32>,
    pub replay: ReplaySnapshot,
    pub stats: AgentStats,
}

// ---------------------------------------------------------------------
// Serialization (fixed key order — the file is reproducible
// byte-for-byte for a given agent state).
// ---------------------------------------------------------------------

fn f32_bits(x: f32) -> String {
    x.to_bits().to_string()
}

fn f32_arr(xs: &[f32]) -> String {
    write::arr(&xs.iter().map(|&x| f32_bits(x)).collect::<Vec<_>>())
}

fn f64_bits(x: f64) -> String {
    write::hex_u64(x.to_bits())
}

fn transition_json(t: &Transition) -> String {
    write::obj(&[
        ("s", f32_arr(&t.s)),
        ("a", t.a.to_string()),
        ("r", f32_bits(t.r)),
        ("s2", f32_arr(&t.s2)),
        ("done", t.done.to_string()),
    ])
}

fn cfg_json(c: &AgentConfig) -> String {
    let intervals: Vec<String> = c.intervals.iter().map(|&v| write::hex_u64(v)).collect();
    write::obj(&[
        ("intervals", write::arr(&intervals)),
        ("initial_interval", c.initial_interval.to_string()),
        ("gamma", f32_bits(c.gamma)),
        ("lr", f32_bits(c.lr)),
        ("eps_start", f32_bits(c.eps_start)),
        ("eps_end", f32_bits(c.eps_end)),
        ("eps_decay", f32_bits(c.eps_decay)),
        ("replay_capacity", c.replay_capacity.to_string()),
        ("batch_size", c.batch_size.to_string()),
        ("train_every", c.train_every.to_string()),
        ("target_sync", c.target_sync.to_string()),
        ("reward_deadband", f64_bits(c.reward_deadband)),
    ])
}

fn q_json(q: &QSnapshot) -> String {
    write::obj(&[
        ("backend", write::string(&q.backend)),
        ("lr", f32_bits(q.lr)),
        ("gamma", f32_bits(q.gamma)),
        ("t", write::hex_u64(q.t)),
        ("train_steps", write::hex_u64(q.train_steps)),
        ("theta", f32_arr(&q.theta)),
        ("target_theta", f32_arr(&q.target_theta)),
        ("m", f32_arr(&q.m)),
        ("v", f32_arr(&q.v)),
    ])
}

fn replay_json(r: &ReplaySnapshot) -> String {
    let ts: Vec<String> = r.transitions.iter().map(transition_json).collect();
    write::obj(&[
        ("capacity", r.capacity.to_string()),
        ("batch", r.batch.to_string()),
        ("head", r.head.to_string()),
        ("pushes", write::hex_u64(r.pushes)),
        ("samples", write::hex_u64(r.samples)),
        ("transitions", write::arr(&ts)),
    ])
}

fn stats_json(s: &AgentStats) -> String {
    let counts: Vec<String> = s.action_counts.iter().map(|&c| write::hex_u64(c)).collect();
    let rewards: Vec<String> = s.action_reward_sum.iter().map(|&x| f64_bits(x)).collect();
    write::obj(&[
        ("invocations", write::hex_u64(s.invocations)),
        ("train_steps", write::hex_u64(s.train_steps)),
        ("loss_sum", f64_bits(s.loss_sum)),
        ("cumulative_reward", f64_bits(s.cumulative_reward)),
        ("action_counts", write::arr(&counts)),
        ("action_reward_sum", write::arr(&rewards)),
        ("weight_accesses", write::hex_u64(s.weight_accesses)),
        ("replay_accesses", write::hex_u64(s.replay_accesses)),
        ("state_buf_accesses", write::hex_u64(s.state_buf_accesses)),
    ])
}

impl AgentCheckpoint {
    /// Serialize with fixed key order.
    pub fn to_json(&self) -> String {
        write::obj(&[
            ("schema", write::string(SCHEMA)),
            ("version", VERSION.to_string()),
            ("agent_config", cfg_json(&self.cfg)),
            ("q", q_json(&self.q)),
            ("eps", f32_bits(self.eps)),
            ("interval_idx", self.interval_idx.to_string()),
            ("invocations_since_train", self.invocations_since_train.to_string()),
            ("trains_since_sync", self.trains_since_sync.to_string()),
            ("rng_state", write::hex_u64(self.rng_state)),
            ("action_history", f32_arr(&self.action_history)),
            ("replay", replay_json(&self.replay)),
            ("stats", stats_json(&self.stats)),
        ])
    }

    /// Parse a checkpoint document, verifying the schema version.
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        Self::from_json(&json::parse(text)?)
    }

    /// Parse one v1 document from its JSON tree — shared by [`parse`]
    /// (standalone v1 files) and [`CheckpointBundle::parse`] (each entry
    /// of a v2 bundle's `agents` array is a complete v1 document).
    ///
    /// [`parse`]: AgentCheckpoint::parse
    fn from_json(j: &Json) -> anyhow::Result<Self> {
        let schema = str_field(j, "schema")?;
        anyhow::ensure!(
            schema == SCHEMA,
            "unsupported checkpoint schema {schema:?} (this build reads {SCHEMA:?})"
        );
        let version = num_field(j, "version")? as u64;
        anyhow::ensure!(
            version == VERSION,
            "unsupported checkpoint version {version} (this build reads {VERSION})"
        );
        Ok(Self {
            cfg: parse_cfg(field(j, "agent_config")?)?,
            q: parse_q(field(j, "q")?)?,
            eps: f32_field(j, "eps")?,
            interval_idx: usize_field(j, "interval_idx")?,
            invocations_since_train: usize_field(j, "invocations_since_train")? as u32,
            trains_since_sync: usize_field(j, "trains_since_sync")? as u32,
            rng_state: u64_field(j, "rng_state")?,
            action_history: f32_vec(field(j, "action_history")?)?,
            replay: parse_replay(field(j, "replay")?)?,
            stats: parse_stats(field(j, "stats")?)?,
        })
    }

    /// Write to `path` (creating parent directories is the caller's job).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing checkpoint {}: {e}", path.display()))
    }

    /// Load from `path`.
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading checkpoint {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow::anyhow!("checkpoint {}: {e}", path.display()))
    }

    /// Rebuild a live agent: construct the best available Q-backend,
    /// restore the snapshotted parameters into it, and rehydrate the
    /// control state. Fails loudly when the checkpoint does not fit the
    /// backend (name and parameter layout) or when `cfg` differs in any
    /// field from the configuration the checkpoint was trained under —
    /// resume never silently mixes old and new hyperparameters.
    pub fn build_agent(&self, cfg: &AgentConfig) -> anyhow::Result<AimmAgent> {
        let mut qf = best_qfunction(self.q.lr, self.q.gamma, 0, self.cfg.batch_size);
        qf.restore(&self.q)?;
        AimmAgent::from_checkpoint(qf, cfg.clone(), self)
    }
}

/// A v2 checkpoint: every learned agent the run's policy carries — one
/// for `--mapping aimm` (exactly the old v1 content), one per MC for
/// `--mapping aimm-mc` — plus the warm-start mode the run was started
/// under. The agents appear in policy order (single agent, or MC 0..n),
/// and each serializes as a complete v1 document, so the per-agent
/// bit-identity machinery is reused unchanged.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointBundle {
    /// How the agents were initialized (`--warm-start`); a resume under
    /// a different mode is refused by [`ensure_resumable`].
    ///
    /// [`ensure_resumable`]: CheckpointBundle::ensure_resumable
    pub warm_start: WarmStart,
    pub agents: Vec<AgentCheckpoint>,
}

impl CheckpointBundle {
    /// Wrap a single-agent checkpoint (the `--mapping aimm` path).
    pub fn single(warm_start: WarmStart, agent: AgentCheckpoint) -> Self {
        Self { warm_start, agents: vec![agent] }
    }

    /// Serialize with fixed key order (deterministic byte-for-byte).
    pub fn to_json(&self) -> String {
        let agents: Vec<String> = self.agents.iter().map(|a| a.to_json()).collect();
        write::obj(&[
            ("schema", write::string(SCHEMA_V2)),
            ("version", VERSION_V2.to_string()),
            ("warm_start", write::string(self.warm_start.name())),
            ("agents", write::arr(&agents)),
        ])
    }

    /// Parse a v2 bundle — or, for compatibility, a standalone v1
    /// document, which loads as a one-agent bundle with no warm-start
    /// recorded (exactly what a v1-era run was).
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let j = json::parse(text)?;
        let schema = str_field(&j, "schema")?;
        if schema == SCHEMA {
            return Ok(Self::single(WarmStart::None, AgentCheckpoint::from_json(&j)?));
        }
        anyhow::ensure!(
            schema == SCHEMA_V2,
            "unsupported checkpoint schema {schema:?} \
             (this build reads {SCHEMA_V2:?} and legacy {SCHEMA:?})"
        );
        let version = num_field(&j, "version")? as u64;
        anyhow::ensure!(
            version == VERSION_V2,
            "unsupported checkpoint version {version} (this build reads {VERSION_V2})"
        );
        let ws = str_field(&j, "warm_start")?;
        let warm_start = WarmStart::from_name(ws).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown warm_start mode {ws:?} in checkpoint (this build knows {})",
                WarmStart::name_list()
            )
        })?;
        let agents = field(&j, "agents")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("checkpoint agents is not an array"))?
            .iter()
            .map(AgentCheckpoint::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(!agents.is_empty(), "checkpoint bundle carries no agents");
        Ok(Self { warm_start, agents })
    }

    /// Drift rejection (satellite of DESIGN.md §15): a bundle resumes
    /// only into a run shaped exactly like the one that saved it. Both
    /// checks name the drifted field — the whole point is a diagnosable
    /// refusal instead of a silently perturbed resume.
    pub fn ensure_resumable(
        &self,
        expected_agents: usize,
        requested: WarmStart,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.agents.len() == expected_agents,
            "checkpoint drift: per-MC agent count is {} but this run drives \
             {expected_agents} agent(s) — resume refused",
            self.agents.len()
        );
        anyhow::ensure!(
            self.warm_start == requested,
            "checkpoint drift: warm_start mode is {:?} but this run requested {:?} \
             — resume refused",
            self.warm_start.name(),
            requested.name()
        );
        Ok(())
    }

    /// Write to `path` (creating parent directories is the caller's job).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing checkpoint {}: {e}", path.display()))
    }

    /// Load from `path` (v2 bundle or legacy v1 document).
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading checkpoint {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow::anyhow!("checkpoint {}: {e}", path.display()))
    }
}

// ---------------------------------------------------------------------
// Parsing helpers (bit-exact inverses of the writers above).
// ---------------------------------------------------------------------

fn field<'a>(j: &'a Json, k: &str) -> anyhow::Result<&'a Json> {
    j.get(k).ok_or_else(|| anyhow::anyhow!("checkpoint missing key {k:?}"))
}

fn str_field<'a>(j: &'a Json, k: &str) -> anyhow::Result<&'a str> {
    field(j, k)?.as_str().ok_or_else(|| anyhow::anyhow!("checkpoint key {k:?} not a string"))
}

fn num_field(j: &Json, k: &str) -> anyhow::Result<f64> {
    field(j, k)?.as_f64().ok_or_else(|| anyhow::anyhow!("checkpoint key {k:?} not a number"))
}

fn usize_field(j: &Json, k: &str) -> anyhow::Result<usize> {
    let f = num_field(j, k)?;
    anyhow::ensure!(
        f >= 0.0 && f.fract() == 0.0 && f <= u32::MAX as f64,
        "checkpoint key {k:?} is not a small non-negative integer: {f}"
    );
    Ok(f as usize)
}

fn u64_field(j: &Json, k: &str) -> anyhow::Result<u64> {
    parse_hex_u64(str_field(j, k)?)
        .map_err(|e| anyhow::anyhow!("checkpoint key {k:?}: {e}"))
}

fn f64_field(j: &Json, k: &str) -> anyhow::Result<f64> {
    Ok(f64::from_bits(u64_field(j, k)?))
}

fn f32_of(j: &Json) -> anyhow::Result<f32> {
    let f = j.as_f64().ok_or_else(|| anyhow::anyhow!("expected f32 bit pattern"))?;
    anyhow::ensure!(
        f >= 0.0 && f.fract() == 0.0 && f <= u32::MAX as f64,
        "bad f32 bit pattern {f}"
    );
    Ok(f32::from_bits(f as u32))
}

fn f32_field(j: &Json, k: &str) -> anyhow::Result<f32> {
    f32_of(field(j, k)?).map_err(|e| anyhow::anyhow!("checkpoint key {k:?}: {e}"))
}

fn f32_vec(j: &Json) -> anyhow::Result<Vec<f32>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected f32 array"))?
        .iter()
        .map(f32_of)
        .collect()
}

fn hex_vec(j: &Json, k: &str) -> anyhow::Result<Vec<u64>> {
    field(j, k)?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{k:?} not an array"))?
        .iter()
        .map(|v| {
            parse_hex_u64(
                v.as_str().ok_or_else(|| anyhow::anyhow!("{k:?} entry not a hex string"))?,
            )
        })
        .collect()
}

fn parse_cfg(j: &Json) -> anyhow::Result<AgentConfig> {
    Ok(AgentConfig {
        intervals: hex_vec(j, "intervals")?,
        initial_interval: usize_field(j, "initial_interval")?,
        gamma: f32_field(j, "gamma")?,
        lr: f32_field(j, "lr")?,
        eps_start: f32_field(j, "eps_start")?,
        eps_end: f32_field(j, "eps_end")?,
        eps_decay: f32_field(j, "eps_decay")?,
        replay_capacity: usize_field(j, "replay_capacity")?,
        batch_size: usize_field(j, "batch_size")?,
        train_every: usize_field(j, "train_every")? as u32,
        target_sync: usize_field(j, "target_sync")? as u32,
        reward_deadband: f64_field(j, "reward_deadband")?,
    })
}

fn parse_q(j: &Json) -> anyhow::Result<QSnapshot> {
    Ok(QSnapshot {
        backend: str_field(j, "backend")?.to_string(),
        lr: f32_field(j, "lr")?,
        gamma: f32_field(j, "gamma")?,
        t: u64_field(j, "t")?,
        train_steps: u64_field(j, "train_steps")?,
        theta: f32_vec(field(j, "theta")?)?,
        target_theta: f32_vec(field(j, "target_theta")?)?,
        m: f32_vec(field(j, "m")?)?,
        v: f32_vec(field(j, "v")?)?,
    })
}

fn parse_transition(j: &Json) -> anyhow::Result<Transition> {
    let s = f32_vec(field(j, "s")?)?;
    let s2 = f32_vec(field(j, "s2")?)?;
    let dim = crate::runtime::STATE_DIM;
    anyhow::ensure!(
        s.len() == dim && s2.len() == dim,
        "transition state has {} / {} entries, expected {dim}",
        s.len(),
        s2.len()
    );
    let mut sa = [0.0f32; crate::runtime::STATE_DIM];
    sa.copy_from_slice(&s);
    let mut s2a = [0.0f32; crate::runtime::STATE_DIM];
    s2a.copy_from_slice(&s2);
    let a = usize_field(j, "a")?;
    anyhow::ensure!(a < crate::runtime::NUM_ACTIONS, "transition action {a} out of range");
    let done = match field(j, "done")? {
        Json::Bool(b) => *b,
        other => anyhow::bail!("transition done is not a bool: {other:?}"),
    };
    Ok(Transition { s: sa, a: a as u8, r: f32_field(j, "r")?, s2: s2a, done })
}

fn parse_replay(j: &Json) -> anyhow::Result<ReplaySnapshot> {
    let transitions = field(j, "transitions")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("replay transitions not an array"))?
        .iter()
        .map(parse_transition)
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(ReplaySnapshot {
        capacity: usize_field(j, "capacity")?,
        batch: usize_field(j, "batch")?,
        head: usize_field(j, "head")?,
        pushes: u64_field(j, "pushes")?,
        samples: u64_field(j, "samples")?,
        transitions,
    })
}

fn hex_arr(j: &Json, k: &str, n: usize) -> anyhow::Result<Vec<u64>> {
    let out = hex_vec(j, k)?;
    anyhow::ensure!(out.len() == n, "{k:?} has {} entries, expected {n}", out.len());
    Ok(out)
}

fn parse_stats(j: &Json) -> anyhow::Result<AgentStats> {
    let counts = hex_arr(j, "action_counts", 8)?;
    let rewards = hex_arr(j, "action_reward_sum", 8)?;
    let mut action_counts = [0u64; 8];
    action_counts.copy_from_slice(&counts);
    let mut action_reward_sum = [0.0f64; 8];
    for (out, bits) in action_reward_sum.iter_mut().zip(rewards) {
        *out = f64::from_bits(bits);
    }
    Ok(AgentStats {
        invocations: u64_field(j, "invocations")?,
        train_steps: u64_field(j, "train_steps")?,
        loss_sum: f64_field(j, "loss_sum")?,
        cumulative_reward: f64_field(j, "cumulative_reward")?,
        action_counts,
        action_reward_sum,
        weight_accesses: u64_field(j, "weight_accesses")?,
        replay_accesses: u64_field(j, "replay_accesses")?,
        state_buf_accesses: u64_field(j, "state_buf_accesses")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::STATE_DIM;

    fn probe_transition(k: u32) -> Transition {
        let mut s = [0.0f32; STATE_DIM];
        let mut s2 = [0.0f32; STATE_DIM];
        // Deliberately nasty values: NaN, -0.0, subnormals, infinities.
        s[0] = f32::NAN;
        s[1] = -0.0;
        s[2] = f32::MIN_POSITIVE / 2.0;
        s[3] = k as f32 * 0.1;
        s2[0] = f32::NEG_INFINITY;
        s2[1] = f32::MAX;
        Transition { s, a: (k % 8) as u8, r: -1.5e-8, s2, done: k % 2 == 0 }
    }

    fn sample_checkpoint() -> AgentCheckpoint {
        let mut cfg = AgentConfig::default();
        cfg.eps_decay = 0.7251; // non-default, exercises f32-bit round trip
        cfg.replay_capacity = 64;
        AgentCheckpoint {
            cfg,
            q: QSnapshot {
                backend: "linear-mock".to_string(),
                lr: 5e-4,
                gamma: 0.95,
                theta: vec![f32::NAN, -0.0, 1.0, f32::INFINITY],
                target_theta: vec![0.25, -3.5, f32::MIN_POSITIVE, 0.0],
                m: vec![],
                v: vec![],
                t: 0,
                train_steps: u64::MAX,
            },
            eps: 0.123456,
            interval_idx: 3,
            invocations_since_train: 2,
            trains_since_sync: 61,
            rng_state: 0xDEAD_BEEF_DEAD_BEEF,
            action_history: vec![0.0, 7.0, 3.0],
            replay: ReplaySnapshot {
                capacity: 64,
                batch: 32,
                head: 0,
                pushes: 3,
                samples: 0,
                transitions: (0..3).map(probe_transition).collect(),
            },
            stats: AgentStats {
                invocations: 100,
                train_steps: 40,
                loss_sum: 1.25e-300,
                cumulative_reward: -7.0,
                action_counts: [1, 2, 3, 4, 5, 6, 7, u64::MAX],
                action_reward_sum: [0.0, -0.0, f64::NAN, 1.5, -2.5, 0.1, 0.2, 0.3],
                weight_accesses: 9,
                replay_accesses: 8,
                state_buf_accesses: 7,
            },
        }
    }

    /// Bit-level equality that treats NaN by pattern, not by PartialEq.
    fn assert_bits_eq(a: &AgentCheckpoint, b: &AgentCheckpoint) {
        assert_eq!(a.to_json(), b.to_json());
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let ck = sample_checkpoint();
        let text = ck.to_json();
        let back = AgentCheckpoint::parse(&text).unwrap();
        assert_bits_eq(&ck, &back);
        // Fixed key order: serialization is deterministic.
        assert_eq!(text, AgentCheckpoint::parse(&text).unwrap().to_json());
        assert!(text.starts_with(&format!("{{\"schema\":\"{SCHEMA}\"")));
    }

    #[test]
    fn parse_rejects_wrong_schema_or_version() {
        let ck = sample_checkpoint();
        let text = ck.to_json();
        let wrong = text.replace(SCHEMA, "aimm-checkpoint-v0");
        assert!(AgentCheckpoint::parse(&wrong).is_err());
        let wrong = text.replace("\"version\":1", "\"version\":2");
        assert!(AgentCheckpoint::parse(&wrong).is_err());
        assert!(AgentCheckpoint::parse("{}").is_err());
        assert!(AgentCheckpoint::parse("not json").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let ck = sample_checkpoint();
        // detlint: allow(ambient-input) — unit-test scratch directory, not sim state
        let path = std::env::temp_dir().join("aimm_ckpt_unit_test.json");
        ck.save(&path).unwrap();
        let back = AgentCheckpoint::load(&path).unwrap();
        assert_bits_eq(&ck, &back);
        std::fs::remove_file(&path).ok();
        assert!(AgentCheckpoint::load(Path::new("/nonexistent/ckpt.json")).is_err());
    }

    fn sample_bundle(n: usize, warm_start: WarmStart) -> CheckpointBundle {
        let mut agents = Vec::new();
        for i in 0..n {
            let mut ck = sample_checkpoint();
            ck.rng_state = 0x1000 + i as u64; // distinguish the entries
            agents.push(ck);
        }
        CheckpointBundle { warm_start, agents }
    }

    #[test]
    fn bundle_roundtrip_is_bit_exact() {
        let b = sample_bundle(4, WarmStart::Oracle);
        let text = b.to_json();
        assert!(text.starts_with(&format!("{{\"schema\":\"{SCHEMA_V2}\"")));
        assert!(text.contains("\"warm_start\":\"oracle\""));
        let back = CheckpointBundle::parse(&text).unwrap();
        assert_eq!(back.warm_start, WarmStart::Oracle);
        assert_eq!(back.agents.len(), 4);
        assert_eq!(text, back.to_json());
    }

    /// Compatibility: a standalone v1 document still loads — as a
    /// one-agent bundle with no warm-start recorded.
    #[test]
    fn v1_document_loads_as_single_agent_bundle() {
        let ck = sample_checkpoint();
        let bundle = CheckpointBundle::parse(&ck.to_json()).unwrap();
        assert_eq!(bundle.warm_start, WarmStart::None);
        assert_eq!(bundle.agents.len(), 1);
        assert_eq!(bundle.agents[0].to_json(), ck.to_json());
        // And round-trips into the v2 envelope unchanged.
        let again = CheckpointBundle::parse(&bundle.to_json()).unwrap();
        assert_eq!(again.agents[0].to_json(), ck.to_json());
    }

    /// Satellite (b): drifted bundles refuse to resume, naming the field.
    #[test]
    fn drifted_agent_count_refuses_resume_by_name() {
        let b = sample_bundle(4, WarmStart::None);
        b.ensure_resumable(4, WarmStart::None).unwrap();
        let err = b.ensure_resumable(1, WarmStart::None).unwrap_err().to_string();
        assert!(err.contains("per-MC agent count"), "{err}");
        assert!(err.contains('4') && err.contains('1'), "{err}");
    }

    #[test]
    fn drifted_warm_start_mode_refuses_resume_by_name() {
        let b = sample_bundle(1, WarmStart::Oracle);
        b.ensure_resumable(1, WarmStart::Oracle).unwrap();
        let err = b.ensure_resumable(1, WarmStart::None).unwrap_err().to_string();
        assert!(err.contains("warm_start"), "{err}");
        assert!(err.contains("oracle") && err.contains("none"), "{err}");
    }

    #[test]
    fn bundle_parse_rejects_malformed_documents() {
        let b = sample_bundle(2, WarmStart::None);
        let text = b.to_json();
        // Unknown schema (neither v1 nor v2).
        let wrong = text.replace(SCHEMA_V2, "aimm-checkpoint-v9");
        assert!(CheckpointBundle::parse(&wrong).is_err());
        // Version drift under the v2 schema.
        let wrong = text.replacen("\"version\":2", "\"version\":3", 1);
        assert!(CheckpointBundle::parse(&wrong).is_err());
        // Unknown warm-start mode names the known list.
        let wrong = text.replace("\"warm_start\":\"none\"", "\"warm_start\":\"sgd\"");
        let err = CheckpointBundle::parse(&wrong).unwrap_err().to_string();
        assert!(err.contains("none|oracle"), "{err}");
        // Empty agent list.
        let empty = CheckpointBundle { warm_start: WarmStart::None, agents: vec![] };
        assert!(CheckpointBundle::parse(&empty.to_json()).is_err());
    }

    #[test]
    fn bundle_file_roundtrip() {
        let b = sample_bundle(2, WarmStart::Oracle);
        // detlint: allow(ambient-input) — unit-test scratch directory, not sim state
        let path = std::env::temp_dir().join("aimm_bundle_unit_test.json");
        b.save(&path).unwrap();
        let back = CheckpointBundle::load(&path).unwrap();
        assert_eq!(b.to_json(), back.to_json());
        std::fs::remove_file(&path).ok();
        assert!(CheckpointBundle::load(Path::new("/nonexistent/bundle.json")).is_err());
    }
}
