//! The AIMM reinforcement-learning agent (paper §4, §5.2): state
//! assembly, the eight-action space, the OPC reward, experience replay
//! and the ε-greedy deep-Q control loop driving page and computation
//! remapping — plus the versioned [`checkpoint`] format that carries
//! the learned model across programs and processes (the continual-
//! learning premise, §6.1).

pub mod actions;
pub mod aimm;
pub mod checkpoint;
pub mod replay;
pub mod state;

pub use actions::Action;
pub use aimm::{AgentStats, AimmAgent, Decision};
pub use checkpoint::{AgentCheckpoint, ReplaySnapshot};
pub use replay::ReplayBuffer;
pub use state::{build_state, hist4, hop_scale, PageSignals, PerMcSignals, StateVec, SysSignals};
