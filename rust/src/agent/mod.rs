//! The AIMM reinforcement-learning agent (paper §4, §5.2): state
//! assembly, the eight-action space, the OPC reward, experience replay
//! and the ε-greedy deep-Q control loop driving page and computation
//! remapping — plus the versioned [`checkpoint`] format that carries
//! the learned model across programs and processes (the continual-
//! learning premise, §6.1).
//!
//! Learning subsystem v2 (DESIGN.md §15) adds two optional layers on
//! top: [`distill`] — oracle-distillation warm-start that pre-trains
//! the Q-net on the oracle dry pass's placements before any RL episode
//! — and [`multi`] — the per-MC agent pool behind `--mapping aimm-mc`,
//! coordinated by deterministic replay gossip.

pub mod actions;
pub mod aimm;
pub mod checkpoint;
pub mod distill;
pub mod multi;
pub mod replay;
pub mod state;

pub use actions::Action;
pub use aimm::{AgentStats, AimmAgent, Decision};
pub use checkpoint::{AgentCheckpoint, CheckpointBundle, ReplaySnapshot};
pub use distill::{warm_start_agent, DistillStats, WarmStart};
pub use multi::{fresh_mc_agents, gossip_exchange, mc_seed, GOSSIP_BURST, GOSSIP_EVERY};
pub use replay::ReplayBuffer;
pub use state::{build_state, hist4, hop_scale, PageSignals, PerMcSignals, StateVec, SysSignals};
