//! The AIMM reinforcement-learning agent (paper §4, §5.2): state
//! assembly, the eight-action space, the OPC reward, experience replay
//! and the ε-greedy deep-Q control loop driving page and computation
//! remapping.

pub mod actions;
pub mod aimm;
pub mod replay;
pub mod state;

pub use actions::Action;
pub use aimm::{AgentStats, AimmAgent, Decision};
pub use replay::ReplayBuffer;
pub use state::{build_state, hist4, PageSignals, PerMcSignals, StateVec, SysSignals};
