//! Oracle distillation warm-start (DESIGN.md §15): before any RL
//! episode runs, replay the oracle's side-effect-free dry pass
//! ([`profile_assignment`]) over the upcoming op stream, convert its
//! placement decisions into labeled `(state, action)` pairs, and
//! pre-train the Q-network on them through the same
//! [`QFunction::train_batch`](crate::runtime::QFunction::train_batch)
//! seam RL uses. The agent then starts its first episode already biased
//! toward oracle-shaped placements instead of uniform ε-noise — the
//! continual-learning curriculum converges in fewer episodes
//! (benches/distill_convergence.rs measures exactly that).
//!
//! The whole pipeline is a pure function of `(cfg, ops)`: the oracle
//! pass is deterministic, the labels are derived from sorted page
//! orders, and the epoch shuffles draw from a seed folded from
//! `cfg.seed` — so warm-starting is bit-reproducible and never touches
//! simulator state.
//!
//! **What is distilled.** The oracle only ever makes *data placement*
//! decisions, so only the data-side actions appear as labels:
//!
//! * a page sitting on its oracle cube, compute co-located →
//!   [`Action::Default`] (leave it alone);
//! * the same page displaced to the far side of the network →
//!   [`Action::NearData`] (pull it back next to its compute);
//! * the page on its oracle cube but that cube saturated →
//!   [`Action::FarData`] (shed load — the balancing objective of the
//!   oracle's least-loaded pass).
//!
//! Compute-remap and interval actions have no oracle counterpart and
//! keep their cold Q-values; RL fine-tuning owns them.

use std::collections::HashMap;

use crate::config::{Pid, SystemConfig, VPage};
use crate::mapping::profile_assignment;
use crate::nmp::NmpOp;
use crate::noc::Mesh;
use crate::runtime::{TrainBatch, STATE_DIM};
use crate::sim::Rng;

use super::actions::Action;
use super::aimm::AimmAgent;
use super::state::{build_state, hop_scale, PageSignals, PerMcSignals, StateVec, SysSignals};

/// Passes over the labeled dataset during pre-training. Small on
/// purpose: distillation seeds the Q-surface, RL refines it — more
/// epochs mostly overfit the linear mock to its three label shapes.
pub const DISTILL_EPOCHS: usize = 4;

/// Seed fold for the epoch shuffles (distinct from the agent's `^0xA6E7`
/// and the policy's `^0x5157` folds so the streams never collide).
pub const DISTILL_SEED_FOLD: u64 = 0xD157;

/// Warm-start mode (`--warm-start <mode>`). Recorded in the v2
/// checkpoint bundle so a resume under a different mode is refused
/// (`CheckpointBundle::ensure_resumable`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WarmStart {
    /// Cold start: the Q-network begins at its seeded initialization.
    #[default]
    None,
    /// Oracle distillation: pre-train on the dry pass's placements.
    Oracle,
}

impl WarmStart {
    pub const ALL: [WarmStart; 2] = [WarmStart::None, WarmStart::Oracle];

    pub fn name(self) -> &'static str {
        match self {
            WarmStart::None => "none",
            WarmStart::Oracle => "oracle",
        }
    }

    pub fn from_name(s: &str) -> Option<WarmStart> {
        Self::ALL.into_iter().find(|w| w.name().eq_ignore_ascii_case(s))
    }

    /// `"none|oracle"` — for CLI usage strings.
    pub fn name_list() -> String {
        Self::ALL.map(|w| w.name()).join("|")
    }
}

/// What a warm-start did — surfaced on the CLI and in the convergence
/// bench so "pre-trained on N pages" is visible, not silent.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DistillStats {
    /// Distinct pages the oracle assigned.
    pub pages: usize,
    /// Labeled examples derived from them (3 per page).
    pub examples: usize,
    /// Training batches fed to the backend (all epochs).
    pub batches: usize,
    pub epochs: usize,
    /// Rows per batch (the backend's declared fixed batch).
    pub batch: usize,
    /// Mean per-batch loss over the whole pre-training run.
    pub mean_loss: f32,
}

/// Derive the labeled imitation dataset from the oracle's dry pass.
/// Deterministic: pages are emitted hottest-first with `(pid, vpage)`
/// tie-breaks — the same order the oracle's pass 1 assigns them in.
pub fn distill_dataset(cfg: &SystemConfig, ops: &[NmpOp]) -> Vec<(StateVec, Action)> {
    let n_cubes = cfg.num_cubes();
    let mesh = Mesh::new(cfg);
    let hops = hop_scale(mesh.diameter());
    let assignment = profile_assignment(ops, n_cubes);
    if assignment.is_empty() {
        return Vec::new();
    }

    // Page heat: every touch (dest + sources) counts one access.
    let mut touches: HashMap<(Pid, VPage), u64> = HashMap::new();
    for op in ops {
        let (pages, n) = op.vpages_arr();
        for &v in &pages[..n] {
            *touches.entry((op.pid, v)).or_insert(0) += 1;
        }
    }

    // detlint: allow(hash-iter) — drained into a fully sorted vector
    let mut order: Vec<((Pid, VPage), u64)> = assignment
        .iter()
        .map(|(k, _)| (*k, touches.get(k).copied().unwrap_or(0)))
        .collect();
    order.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let max_touch = order.first().map(|&(_, w)| w).unwrap_or(0).max(1);

    // Relative cube load under the oracle's placement, for the occupancy
    // slots of the synthetic states.
    let mut load = vec![0u64; n_cubes];
    for (k, w) in &order {
        load[assignment[k]] += *w;
    }
    let max_load = load.iter().copied().max().unwrap_or(0).max(1);
    let mean_load_frac =
        (load.iter().sum::<u64>() as f32 / n_cubes as f32) / max_load as f32;

    let norm = |cube: usize| cube as f32 / n_cubes as f32;
    let mut out = Vec::with_capacity(order.len() * 3);
    for (key, w) in order {
        let cube = assignment[&key];
        let access = w as f32 / max_touch as f32;
        let occ = load[cube] as f32 / max_load as f32;
        let calm = SysSignals {
            per_mc: vec![PerMcSignals::default(); cfg.num_mcs()],
            recent_opc: 0.5,
            cube_occ_mean: mean_load_frac,
            cube_occ_max: occ,
            ..SysSignals::default()
        };
        let page_home = |at: usize| PageSignals {
            access_rate: access,
            page_cube_norm: norm(at),
            compute_cube_norm: norm(cube),
            ..PageSignals::default()
        };

        // Placed where the oracle wants it: leave it alone.
        out.push((build_state(&calm, &page_home(cube), hops), Action::Default));
        // Displaced to the far side: pull it back next to its compute.
        let displaced = mesh.distant_cube(cube);
        out.push((build_state(&calm, &page_home(displaced), hops), Action::NearData));
        // On its cube but the cube is saturated: shed load, the
        // balancing objective of the oracle's least-loaded pass.
        let saturated =
            SysSignals { cube_occ_mean: 1.0, cube_occ_max: 1.0, ..calm.clone() };
        out.push((build_state(&saturated, &page_home(cube), hops), Action::FarData));
    }
    out
}

/// Pack the dataset into exact-`batch`-row [`TrainBatch`]es: `epochs`
/// seeded-shuffled passes, the final ragged chunk of each pass filled by
/// wrapping to that pass's shuffled start (so the backend's fixed batch
/// shape is always satisfied and every example appears at least once
/// per epoch).
pub fn distill_batches(
    examples: &[(StateVec, Action)],
    batch: usize,
    epochs: usize,
    seed: u64,
) -> Vec<TrainBatch> {
    assert!(batch > 0, "distillation batch size must be positive");
    if examples.is_empty() {
        return Vec::new();
    }
    let mut rng = Rng::new(seed);
    let n = examples.len();
    let per_epoch = n.div_ceil(batch);
    let mut out = Vec::with_capacity(epochs * per_epoch);
    let mut idx: Vec<usize> = (0..n).collect();
    for _ in 0..epochs {
        // Fisher–Yates on the shared stream: epoch order depends only on
        // the seed and the example count.
        for i in (1..n).rev() {
            idx.swap(i, rng.index(i + 1));
        }
        for chunk in 0..per_epoch {
            let mut s = Vec::with_capacity(batch * STATE_DIM);
            let mut a = Vec::with_capacity(batch);
            let mut r = Vec::with_capacity(batch);
            let mut s2 = Vec::with_capacity(batch * STATE_DIM);
            let mut done = Vec::with_capacity(batch);
            for row in 0..batch {
                let (state, action) = &examples[idx[(chunk * batch + row) % n]];
                s.extend_from_slice(state);
                a.push(action.index() as i32);
                // Terminal transition with reward +1: the DQN target
                // collapses to y = 1, regressing Q(s, label) toward +1 —
                // plain imitation through the existing training rule.
                r.push(1.0);
                s2.extend_from_slice(state);
                done.push(1.0);
            }
            out.push(TrainBatch { s, a, r, s2, done });
        }
    }
    out
}

/// Warm-start one agent for the given op stream: probe the backend's
/// fixed batch (loud config-time error when it declares none), build
/// the dataset and batches, pre-train. Pure given `(cfg, ops)` and the
/// agent's construction seed.
pub fn warm_start_agent(
    agent: &mut AimmAgent,
    cfg: &SystemConfig,
    ops: &[NmpOp],
) -> anyhow::Result<DistillStats> {
    let batch = agent.warm_start_batch()?;
    let examples = distill_dataset(cfg, ops);
    anyhow::ensure!(
        !examples.is_empty(),
        "--warm-start oracle found nothing to distill (empty op stream?)"
    );
    let batches =
        distill_batches(&examples, batch, DISTILL_EPOCHS, cfg.seed ^ DISTILL_SEED_FOLD);
    let mean_loss = agent.pretrain(&batches)?;
    Ok(DistillStats {
        pages: examples.len() / 3,
        examples: examples.len(),
        batches: batches.len(),
        epochs: DISTILL_EPOCHS,
        batch,
        mean_loss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AgentConfig;
    use crate::runtime::{LinearQ, NUM_ACTIONS, QFunction, QSnapshot};
    use crate::workloads::{generate, Benchmark};

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn agent_with_batch(c: &SystemConfig) -> AimmAgent {
        AimmAgent::new(
            Box::new(LinearQ::with_batch(0.05, 0.9, 7, c.agent.batch_size)),
            c.agent.clone(),
            11,
        )
    }

    #[test]
    fn warm_start_names_round_trip() {
        for w in WarmStart::ALL {
            assert_eq!(WarmStart::from_name(w.name()), Some(w));
        }
        assert_eq!(WarmStart::from_name("ORACLE"), Some(WarmStart::Oracle));
        assert_eq!(WarmStart::from_name("sgd"), None);
        assert_eq!(WarmStart::name_list(), "none|oracle");
        assert_eq!(WarmStart::default(), WarmStart::None);
    }

    #[test]
    fn dataset_is_deterministic_and_label_shaped() {
        let c = cfg();
        let trace = generate(Benchmark::Spmv, 1, 0.05, 3);
        let a = distill_dataset(&c, &trace.ops);
        let b = distill_dataset(&c, &trace.ops);
        assert!(!a.is_empty());
        assert_eq!(a.len() % 3, 0, "three examples per page");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y.0.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(x.1, y.1);
        }
        // Each page triple carries the documented label vocabulary.
        for triple in a.chunks(3) {
            assert_eq!(triple[0].1, Action::Default);
            assert_eq!(triple[1].1, Action::NearData);
            assert_eq!(triple[2].1, Action::FarData);
            // The displaced example really moves the page slot (s[51] is
            // page_cube_norm) while keeping the compute slot (s[52]).
            assert_ne!(triple[0].0[51].to_bits(), triple[1].0[51].to_bits());
            assert_eq!(triple[0].0[52].to_bits(), triple[1].0[52].to_bits());
        }
        assert!(distill_dataset(&c, &[]).is_empty());
    }

    #[test]
    fn batches_are_exact_sized_and_seeded() {
        let c = cfg();
        let trace = generate(Benchmark::Km, 1, 0.05, 5);
        let examples = distill_dataset(&c, &trace.ops);
        let batches = distill_batches(&examples, 32, DISTILL_EPOCHS, 99);
        assert_eq!(batches.len(), DISTILL_EPOCHS * examples.len().div_ceil(32));
        for b in &batches {
            assert_eq!(b.batch_len(), 32, "wrap-around fill keeps every batch exact");
            b.validate().unwrap();
            assert!(b.done.iter().all(|&d| d == 1.0));
            assert!(b.r.iter().all(|&r| r == 1.0));
        }
        // Same seed → identical batch stream; different seed → different
        // epoch order.
        let again = distill_batches(&examples, 32, DISTILL_EPOCHS, 99);
        assert_eq!(batches[0].a, again[0].a);
        let other = distill_batches(&examples, 32, DISTILL_EPOCHS, 100);
        assert!(batches.iter().zip(&other).any(|(x, y)| x.a != y.a));
    }

    #[test]
    fn warm_start_trains_the_labels_up() {
        let c = cfg();
        let trace = generate(Benchmark::Spmv, 1, 0.05, 3);
        let mut agent = agent_with_batch(&c);
        let stats = warm_start_agent(&mut agent, &c, &trace.ops).unwrap();
        assert_eq!(stats.examples, stats.pages * 3);
        assert_eq!(stats.epochs, DISTILL_EPOCHS);
        assert_eq!(stats.batch, c.agent.batch_size);
        // RL-phase stats stay untouched by pre-training.
        assert_eq!(agent.stats.train_steps, 0);
        // The co-located state now prefers Default over the other data
        // actions — the oracle's bias took.
        let (s, label) = distill_dataset(&c, &trace.ops).into_iter().next().unwrap();
        assert_eq!(label, Action::Default);
        let q = agent.probe_q(&s).unwrap();
        assert!(
            q[Action::Default.index()] > q[Action::NearData.index()],
            "q = {q:?}"
        );
    }

    /// Satellite (a): a backend that declares no fixed batch refuses
    /// `--warm-start` at configuration time, naming itself.
    #[test]
    fn warm_start_refuses_batchless_backend_by_name() {
        struct NoBatch;
        impl QFunction for NoBatch {
            fn q_values(&mut self, _s: &[f32]) -> anyhow::Result<[f32; NUM_ACTIONS]> {
                Ok([0.0; NUM_ACTIONS])
            }
            fn train_batch(&mut self, _b: &TrainBatch) -> anyhow::Result<f32> {
                Ok(0.0)
            }
            fn sync_target(&mut self) {}
            fn backend(&self) -> &'static str {
                "batchless-stub"
            }
            fn snapshot(&self) -> anyhow::Result<QSnapshot> {
                anyhow::bail!("stub")
            }
        }
        let c = cfg();
        let mut agent = AimmAgent::new(Box::new(NoBatch), c.agent.clone(), 11);
        let trace = generate(Benchmark::Spmv, 1, 0.05, 3);
        let err = warm_start_agent(&mut agent, &c, &trace.ops).unwrap_err().to_string();
        assert!(err.contains("batchless-stub"), "{err}");
        assert!(err.contains("fixed_batch"), "{err}");
        // An empty stream is refused even on a good backend.
        let mut ok_agent = agent_with_batch(&c);
        let err = warm_start_agent(&mut ok_agent, &c, &[]).unwrap_err().to_string();
        assert!(err.contains("nothing to distill"), "{err}");
    }
}
