//! Agent state representation (paper §4.2, Fig 3): system information
//! (per-MC NMP-table occupancy / row-buffer hit rate / queue occupancy,
//! global action history) concatenated with the selected page's
//! information (access rate, migrations per access, hop / latency /
//! migration-latency / action histories).
//!
//! The layout is pinned to `STATE_DIM = 64` and mirrored by
//! python/compile/model.py; DESIGN.md §5 documents every slot. Per-MC
//! statistics aggregate over each MC's nearest cubes so one artifact
//! serves both 4×4 and 8×8 meshes.

use crate::runtime::STATE_DIM;

/// A fully-assembled state vector.
pub type StateVec = [f32; STATE_DIM];

/// Normalisation scales for unbounded signals.
const LAT_SCALE: f32 = 1.0 / 512.0;
const MIG_LAT_SCALE: f32 = 1.0 / 4096.0;

/// Hop-history scale floor. The pre-topology simulator normalised hop
/// counts by a fixed 16, which comfortably covers the paper's meshes
/// (diameters 6 at 4×4, 14 at 8×8). Networks with larger diameters —
/// a 16×16 mesh (30) or a 16×16 ring (128) — would saturate every far
/// page at 1.0 under that constant, blinding the agent exactly where
/// hop-sensitive placement matters most, so [`hop_scale`] derives the
/// scale from the topology diameter instead. It never drops below this
/// legacy floor, keeping 4×4/8×8 mesh state vectors (and the golden
/// fixture pinned to them) bit-identical to the pre-topology
/// simulator.
pub const LEGACY_HOP_RANGE: u32 = 16;

/// The hop-history normalisation factor for a network of the given
/// diameter (see [`LEGACY_HOP_RANGE`]); pass the fabric's
/// `Mesh::diameter()`, as `System::assemble_state` does.
pub fn hop_scale(diameter: u32) -> f32 {
    1.0 / diameter.max(LEGACY_HOP_RANGE) as f32
}

/// Aggregated signals from one MC's system counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct PerMcSignals {
    pub occ_mean: f32,
    pub occ_max: f32,
    pub row_hit_mean: f32,
    pub row_hit_min: f32,
    pub queue_occ: f32,
}

/// System-wide signals.
#[derive(Debug, Clone, Default)]
pub struct SysSignals {
    pub per_mc: Vec<PerMcSignals>,
    /// Histogram of the last 16 global actions (8 bins, normalised).
    pub action_histogram: [f32; 8],
    /// Current invocation-interval index / (num intervals − 1).
    pub interval_norm: f32,
    /// OPC over the last agent interval (already ~[0, 1]).
    pub recent_opc: f32,
    /// Mesh-wide aggregates.
    pub cube_occ_mean: f32,
    pub cube_occ_max: f32,
    pub cube_row_hit_mean: f32,
}

/// Per-page signals for the selected (highly accessed) page.
#[derive(Debug, Clone, Default)]
pub struct PageSignals {
    pub access_rate: f32,
    pub migrations_per_access: f32,
    /// Zero-padded, oldest-first histories of length 4.
    pub hop_hist: [f32; 4],
    pub lat_hist: [f32; 4],
    pub mig_lat_hist: [f32; 4],
    pub action_hist: [f32; 4],
    /// Host cube and current compute cube, / num_cubes.
    pub page_cube_norm: f32,
    pub compute_cube_norm: f32,
}

fn clamp01(x: f32) -> f32 {
    x.clamp(0.0, 1.0)
}

/// Assemble the 64-wide state vector. Layout (DESIGN.md §5):
/// `[0..20)` per-MC (4×5), `[20..28)` action histogram, `[28..33)`
/// globals, `[33..53)` page info, `[53..64)` reserved zeros.
/// `hop_scale` normalises the raw hop histories — compute it with
/// [`hop_scale`] from the active topology's diameter.
pub fn build_state(sys: &SysSignals, page: &PageSignals, hop_scale: f32) -> StateVec {
    let mut s = [0.0f32; STATE_DIM];
    let mut i = 0;
    for mc in 0..4 {
        let m = sys.per_mc.get(mc).copied().unwrap_or_default();
        s[i] = clamp01(m.occ_mean);
        s[i + 1] = clamp01(m.occ_max);
        s[i + 2] = clamp01(m.row_hit_mean);
        s[i + 3] = clamp01(m.row_hit_min);
        s[i + 4] = clamp01(m.queue_occ);
        i += 5;
    }
    debug_assert_eq!(i, 20);
    for (j, v) in sys.action_histogram.iter().enumerate() {
        s[20 + j] = clamp01(*v);
    }
    s[28] = clamp01(sys.interval_norm);
    s[29] = clamp01(sys.recent_opc);
    s[30] = clamp01(sys.cube_occ_mean);
    s[31] = clamp01(sys.cube_occ_max);
    s[32] = clamp01(sys.cube_row_hit_mean);

    s[33] = clamp01(page.access_rate);
    s[34] = clamp01(page.migrations_per_access);
    for j in 0..4 {
        s[35 + j] = clamp01(page.hop_hist[j] * hop_scale);
        s[39 + j] = clamp01(page.lat_hist[j] * LAT_SCALE);
        s[43 + j] = clamp01(page.mig_lat_hist[j] * MIG_LAT_SCALE);
        s[47 + j] = clamp01(page.action_hist[j] / 8.0);
    }
    s[51] = clamp01(page.page_cube_norm);
    s[52] = clamp01(page.compute_cube_norm);
    // [53..64) reserved.
    s
}

/// Copy a `History::padded()` vector into a fixed `[f32; 4]`.
pub fn hist4(padded: &[f32]) -> [f32; 4] {
    let mut out = [0.0; 4];
    let n = padded.len().min(4);
    out[4 - n..].copy_from_slice(&padded[padded.len() - n..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_slots() {
        let mut sys = SysSignals::default();
        sys.per_mc = vec![
            PerMcSignals {
                occ_mean: 0.5,
                occ_max: 0.9,
                row_hit_mean: 0.7,
                row_hit_min: 0.2,
                queue_occ: 0.1,
            };
            4
        ];
        sys.action_histogram[3] = 0.25;
        sys.recent_opc = 0.4;
        let mut page = PageSignals::default();
        page.access_rate = 0.33;
        page.hop_hist = [0.0, 0.0, 4.0, 8.0];
        let s = build_state(&sys, &page, hop_scale(6)); // 4x4 mesh diameter
        assert_eq!(s[0], 0.5);
        assert_eq!(s[1], 0.9);
        assert_eq!(s[23], 0.25);
        assert_eq!(s[29], 0.4);
        assert_eq!(s[33], 0.33);
        assert!((s[37] - 0.25).abs() < 1e-6); // 4 hops / 16
        assert!((s[38] - 0.5).abs() < 1e-6); // 8 hops / 16
        assert!(s[53..].iter().all(|&v| v == 0.0), "reserved slots stay zero");
    }

    #[test]
    fn everything_clamped() {
        let mut sys = SysSignals::default();
        sys.per_mc = vec![
            PerMcSignals {
                occ_mean: 7.0,
                occ_max: -3.0,
                row_hit_mean: 2.0,
                row_hit_min: 0.5,
                queue_occ: 1.5,
            };
            4
        ];
        let mut page = PageSignals::default();
        page.lat_hist = [1e9; 4];
        let s = build_state(&sys, &page, hop_scale(6));
        assert!(s.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    /// Small diameters keep the legacy 1/16 scale (bit-identity with the
    /// pre-topology simulator on 4×4/8×8 meshes); large ones stretch the
    /// scale so far pages stay rankable instead of all saturating at 1.
    #[test]
    fn hop_scale_tracks_large_diameters() {
        assert_eq!(hop_scale(6), 1.0 / 16.0);
        assert_eq!(hop_scale(14), 1.0 / 16.0);
        assert_eq!(hop_scale(30), 1.0 / 30.0);
        assert_eq!(hop_scale(128), 1.0 / 128.0);
        let mut page = PageSignals::default();
        page.hop_hist = [0.0, 0.0, 17.0, 128.0];
        let s = build_state(&SysSignals::default(), &page, hop_scale(128));
        assert!(s[37] < s[38], "a 128-hop page must rank above a 17-hop page");
        assert!(s[38] <= 1.0);
    }

    #[test]
    fn hist4_pads_front() {
        assert_eq!(hist4(&[1.0, 2.0]), [0.0, 0.0, 1.0, 2.0]);
        assert_eq!(hist4(&[1.0, 2.0, 3.0, 4.0]), [1.0, 2.0, 3.0, 4.0]);
        assert_eq!(hist4(&[]), [0.0; 4]);
    }
}
