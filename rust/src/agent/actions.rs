//! The eight-action space (paper §4.2): six data/computation remapping
//! actions plus two invocation-interval adjustments.

use crate::config::CubeId;
use crate::noc::Mesh;
use crate::sim::Rng;

/// Agent actions, in artifact index order (mirrors the Q-head outputs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// (i) No change in the mapping.
    Default = 0,
    /// (ii) Remap the page to a random neighbour of the compute cube.
    NearData = 1,
    /// (iii) Remap the page to the topology's most distant cube from the
    /// compute cube (the mesh's diagonal opposite, generalized —
    /// [`crate::noc::topology::Topology::distant_cube`]).
    FarData = 2,
    /// (iv) Remap the computation to a neighbour of the compute cube.
    NearCompute = 3,
    /// (v) Remap the computation to the topology's most distant cube
    /// from the compute cube.
    FarCompute = 4,
    /// (vi) Remap the computation to the first source's host cube.
    SourceCompute = 5,
    /// (vii) Increase the agent invocation interval.
    IncreaseInterval = 6,
    /// (viii) Decrease the agent invocation interval.
    DecreaseInterval = 7,
}

pub const NUM_ACTIONS: usize = 8;

impl Action {
    pub const ALL: [Action; NUM_ACTIONS] = [
        Action::Default,
        Action::NearData,
        Action::FarData,
        Action::NearCompute,
        Action::FarCompute,
        Action::SourceCompute,
        Action::IncreaseInterval,
        Action::DecreaseInterval,
    ];

    pub fn from_index(i: usize) -> Action {
        Self::ALL[i]
    }

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Action::Default => "default",
            Action::NearData => "near-data",
            Action::FarData => "far-data",
            Action::NearCompute => "near-compute",
            Action::FarCompute => "far-compute",
            Action::SourceCompute => "source-compute",
            Action::IncreaseInterval => "interval++",
            Action::DecreaseInterval => "interval--",
        }
    }

    pub fn is_data_remap(self) -> bool {
        matches!(self, Action::NearData | Action::FarData)
    }

    pub fn is_compute_remap(self) -> bool {
        matches!(self, Action::NearCompute | Action::FarCompute | Action::SourceCompute)
    }

    pub fn is_interval(self) -> bool {
        matches!(self, Action::IncreaseInterval | Action::DecreaseInterval)
    }

    /// Resolve the target cube of a remapping action. `compute_cube` is
    /// the page's current compute location, `src1_cube` the host of the
    /// first source of its recent ops.
    pub fn target_cube(
        self,
        mesh: &Mesh,
        compute_cube: CubeId,
        src1_cube: CubeId,
        rng: &mut Rng,
    ) -> Option<CubeId> {
        match self {
            Action::NearData | Action::NearCompute => {
                let n = mesh.neighbors(compute_cube);
                Some(*rng.choice(&n))
            }
            Action::FarData | Action::FarCompute => Some(mesh.distant_cube(compute_cube)),
            Action::SourceCompute => Some(src1_cube),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    #[test]
    fn index_roundtrip() {
        for (i, a) in Action::ALL.iter().enumerate() {
            assert_eq!(a.index(), i);
            assert_eq!(Action::from_index(i), *a);
        }
    }

    #[test]
    fn classification_partition() {
        for a in Action::ALL {
            let kinds =
                [a.is_data_remap(), a.is_compute_remap(), a.is_interval(), a == Action::Default];
            assert_eq!(kinds.iter().filter(|&&k| k).count(), 1, "{a:?}");
        }
    }

    #[test]
    fn near_targets_are_neighbors() {
        let mesh = Mesh::new(&SystemConfig::default());
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let t = Action::NearData.target_cube(&mesh, 5, 0, &mut rng).unwrap();
            assert!(mesh.neighbors(5).contains(&t));
        }
    }

    #[test]
    fn far_target_is_diagonal() {
        let mesh = Mesh::new(&SystemConfig::default());
        let mut rng = Rng::new(1);
        assert_eq!(Action::FarCompute.target_cube(&mesh, 0, 0, &mut rng), Some(15));
        assert_eq!(Action::FarData.target_cube(&mesh, 5, 0, &mut rng), Some(10));
    }

    #[test]
    fn far_target_follows_the_topology() {
        use crate::config::TopologyKind;
        let mut cfg = SystemConfig::default();
        cfg.topology = TopologyKind::Torus;
        let torus = Mesh::new(&cfg);
        let mut rng = Rng::new(1);
        // Half a wrap in each dimension on the 4x4 torus.
        assert_eq!(Action::FarData.target_cube(&torus, 0, 0, &mut rng), Some(10));
        cfg.topology = TopologyKind::Ring;
        let ring = Mesh::new(&cfg);
        // Halfway around the 16-ring.
        assert_eq!(Action::FarCompute.target_cube(&ring, 3, 0, &mut rng), Some(11));
        // Near targets still come from the topology's link set.
        for _ in 0..10 {
            let t = Action::NearData.target_cube(&ring, 0, 0, &mut rng).unwrap();
            assert!([15, 1].contains(&t), "ring neighbours of 0, got {t}");
        }
    }

    #[test]
    fn source_compute_targets_src1() {
        let mesh = Mesh::new(&SystemConfig::default());
        let mut rng = Rng::new(1);
        assert_eq!(Action::SourceCompute.target_cube(&mesh, 3, 11, &mut rng), Some(11));
    }

    #[test]
    fn interval_actions_have_no_target() {
        let mesh = Mesh::new(&SystemConfig::default());
        let mut rng = Rng::new(1);
        assert_eq!(Action::IncreaseInterval.target_cube(&mesh, 3, 1, &mut rng), None);
        assert_eq!(Action::Default.target_cube(&mesh, 3, 1, &mut rng), None);
    }
}
