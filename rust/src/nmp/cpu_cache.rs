//! CPU-side cache model for PEI (paper §6.3): PEI "recognizes and tries
//! to simultaneously exploit the benefit of cache memory as well as NMP";
//! on a hit for at least one operand, the op is offloaded with that
//! operand's data to the other source's location.
//!
//! Model: one shared last-level view of the CMP's caches (16 × 32 KiB,
//! Table 1) — set-associative, 64 B lines, LRU.

use crate::config::VAddr;

const LINE_SHIFT: u32 = 6;
const WAYS: usize = 8;

#[derive(Debug, Clone, Copy)]
struct Line {
    tag: u64,
    valid: bool,
    used: u64,
}

/// Set-associative LRU cache over virtual line addresses. PEI's cache
/// check happens CPU-side, pre-translation, so virtual addresses are the
/// right key (per-process tags avoid aliasing).
#[derive(Debug)]
pub struct CpuCache {
    sets: Vec<[Line; WAYS]>,
    num_sets: usize,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl CpuCache {
    /// `lines` = total line capacity (rounded down to a power-of-two set
    /// count × 8 ways).
    pub fn new(lines: usize) -> Self {
        let num_sets = (lines / WAYS).next_power_of_two().max(1);
        let num_sets = if num_sets * WAYS > lines.max(WAYS) { num_sets / 2 } else { num_sets };
        let num_sets = num_sets.max(1);
        Self {
            sets: vec![[Line { tag: 0, valid: false, used: 0 }; WAYS]; num_sets],
            num_sets,
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    fn set_and_tag(&self, pid: u32, addr: VAddr) -> (usize, u64) {
        let line = addr >> LINE_SHIFT;
        let set = (line as usize ^ ((pid as usize) << 4)) & (self.num_sets - 1);
        let tag = (line << 8) | pid as u64;
        (set, tag)
    }

    /// Probe without fill.
    pub fn probe(&mut self, pid: u32, addr: VAddr) -> bool {
        self.clock += 1;
        let (set, tag) = self.set_and_tag(pid, addr);
        for l in self.sets[set].iter_mut() {
            if l.valid && l.tag == tag {
                l.used = self.clock;
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        false
    }

    /// Fill a line (CPU touched this data).
    pub fn fill(&mut self, pid: u32, addr: VAddr) {
        self.clock += 1;
        let (set, tag) = self.set_and_tag(pid, addr);
        // Already present → refresh.
        if let Some(l) = self.sets[set].iter_mut().find(|l| l.valid && l.tag == tag) {
            l.used = self.clock;
            return;
        }
        let victim = self.sets[set]
            .iter_mut()
            .min_by_key(|l| if l.valid { l.used } else { 0 })
            .unwrap();
        *victim = Line { tag, valid: true, used: self.clock };
    }

    pub fn hit_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.hits as f64 / t as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_miss_then_hit_after_fill() {
        let mut c = CpuCache::new(1024);
        assert!(!c.probe(1, 0x1000));
        c.fill(1, 0x1000);
        assert!(c.probe(1, 0x1000));
        // Same line, different offset.
        assert!(c.probe(1, 0x103f));
        // Different line.
        assert!(!c.probe(1, 0x1040));
    }

    #[test]
    fn pid_isolation() {
        let mut c = CpuCache::new(1024);
        c.fill(1, 0x1000);
        assert!(!c.probe(2, 0x1000));
    }

    #[test]
    fn lru_eviction_within_set() {
        let mut c = CpuCache::new(64); // 8 sets × 8 ways
        // Fill 9 lines mapping to the same set: line stride = num_sets.
        let stride = (c.num_sets as u64) << LINE_SHIFT;
        for i in 0..9u64 {
            c.fill(1, i * stride);
        }
        // Oldest line evicted.
        assert!(!c.probe(1, 0));
        assert!(c.probe(1, 8 * stride));
    }
}
