//! NMP-op scheduling: where does an operation compute? (paper §6.3)
//!
//! * **BNMP** — Active-Routing-style: compute at the destination page's
//!   host cube (the NMP-op table entry is made there; sources are fetched
//!   from their cubes).
//! * **LDB** — load balancing: most applications touch many more source
//!   pages than destination pages, so computing at the *first source's*
//!   cube spreads NMP-table load; the result is written back to the
//!   destination cube afterwards.
//! * **PEI** — cache-aware: if at least one operand hits in the CPU
//!   cache, offload the op *with* that operand's data to the other
//!   source's cube (one fetch saved); otherwise behave like BNMP. PEI
//!   also warms the cache with the operands it touches.

use crate::config::{CubeId, Technique};
use crate::cube::PhysAddr;

use super::cpu_cache::CpuCache;
use super::NmpOp;

/// Outcome of the scheduling decision for one op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScheduleDecision {
    /// Cube where the NMP-op table entry is allocated and the ALU runs.
    pub compute_cube: CubeId,
    /// Operands whose data rides in the dispatch packet (no fetch).
    pub carried_operands: u8,
}

/// Decide the compute cube per the technique. `dest/src1/src2` are the
/// post-translation physical locations of the operands.
pub fn schedule(
    technique: Technique,
    op: &NmpOp,
    dest: PhysAddr,
    src1: PhysAddr,
    src2: Option<PhysAddr>,
    cache: &mut CpuCache,
) -> ScheduleDecision {
    match technique {
        Technique::Bnmp => ScheduleDecision { compute_cube: dest.cube, carried_operands: 0 },
        Technique::Ldb => ScheduleDecision { compute_cube: src1.cube, carried_operands: 0 },
        Technique::Pei => {
            let hit1 = cache.probe(op.pid, op.src1);
            let hit2 = op.src2.map(|a| cache.probe(op.pid, a)).unwrap_or(false);
            // PEI warms the cache with what the CPU saw.
            cache.fill(op.pid, op.src1);
            if let Some(a) = op.src2 {
                cache.fill(op.pid, a);
            }
            match (hit1, hit2, src2) {
                // src1 cached → carry it, compute at the other source.
                (true, _, Some(s2)) => {
                    ScheduleDecision { compute_cube: s2.cube, carried_operands: 1 }
                }
                // only src2 cached → carry it, compute at src1's cube.
                (false, true, Some(_)) => {
                    ScheduleDecision { compute_cube: src1.cube, carried_operands: 1 }
                }
                // single-source op with the source cached → compute at the
                // destination, operand carried.
                (true, _, None) => {
                    ScheduleDecision { compute_cube: dest.cube, carried_operands: 1 }
                }
                // no hits → BNMP behaviour.
                _ => ScheduleDecision { compute_cube: dest.cube, carried_operands: 0 },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nmp::OpKind;

    fn op(src2: bool) -> NmpOp {
        NmpOp {
            pid: 1,
            kind: OpKind::Add,
            dest: 0x10_000,
            src1: 0x20_000,
            src2: src2.then_some(0x30_000),
        }
    }

    fn pa(cube: CubeId) -> PhysAddr {
        PhysAddr::new(cube, 0)
    }

    #[test]
    fn bnmp_computes_at_dest() {
        let mut cache = CpuCache::new(64);
        let d = schedule(Technique::Bnmp, &op(true), pa(3), pa(5), Some(pa(9)), &mut cache);
        assert_eq!(d, ScheduleDecision { compute_cube: 3, carried_operands: 0 });
    }

    #[test]
    fn ldb_computes_at_first_source() {
        let mut cache = CpuCache::new(64);
        let d = schedule(Technique::Ldb, &op(true), pa(3), pa(5), Some(pa(9)), &mut cache);
        assert_eq!(d.compute_cube, 5);
    }

    #[test]
    fn pei_cold_cache_behaves_like_bnmp() {
        let mut cache = CpuCache::new(64);
        let d = schedule(Technique::Pei, &op(true), pa(3), pa(5), Some(pa(9)), &mut cache);
        assert_eq!(d, ScheduleDecision { compute_cube: 3, carried_operands: 0 });
    }

    #[test]
    fn pei_hit_offloads_to_other_source() {
        let mut cache = CpuCache::new(64);
        // Warm src1.
        cache.fill(1, 0x20_000);
        let d = schedule(Technique::Pei, &op(true), pa(3), pa(5), Some(pa(9)), &mut cache);
        assert_eq!(d, ScheduleDecision { compute_cube: 9, carried_operands: 1 });
    }

    #[test]
    fn pei_second_use_hits_via_warming() {
        let mut cache = CpuCache::new(64);
        let _ = schedule(Technique::Pei, &op(true), pa(3), pa(5), Some(pa(9)), &mut cache);
        // First call warmed both sources; second probes must hit.
        let d = schedule(Technique::Pei, &op(true), pa(3), pa(5), Some(pa(9)), &mut cache);
        assert_eq!(d.carried_operands, 1);
    }

    #[test]
    fn pei_single_source_hit_computes_at_dest_carried() {
        let mut cache = CpuCache::new(64);
        cache.fill(1, 0x20_000);
        let d = schedule(Technique::Pei, &op(false), pa(3), pa(5), None, &mut cache);
        assert_eq!(d, ScheduleDecision { compute_cube: 3, carried_operands: 1 });
    }
}
