//! NMP operation format and the three offloading techniques the paper
//! evaluates (§6.3): BNMP, LDB and PEI.
//!
//! The op format follows the paper: `<&dest += &src1 OP &src2>` — a
//! destination accumulator page plus one or two source operands.

pub mod cpu_cache;
pub mod technique;

pub use cpu_cache::CpuCache;
pub use technique::{schedule, ScheduleDecision};

use crate::config::{Pid, VAddr, PAGE_SHIFT};

/// Arithmetic performed on the base die (latency-identical in the model;
/// kept for trace realism and analysis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Add,
    Mul,
    Mac,
    Max,
    Min,
}

impl OpKind {
    /// Every op kind, in declaration order — the registry the trace-file
    /// round trip leans on (`name` ↔ `from_name` must be total over it).
    pub const ALL: [OpKind; 5] = [OpKind::Add, OpKind::Mul, OpKind::Mac, OpKind::Max, OpKind::Min];

    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Add => "ADD",
            OpKind::Mul => "MUL",
            OpKind::Mac => "MAC",
            OpKind::Max => "MAX",
            OpKind::Min => "MIN",
        }
    }

    pub fn from_name(name: &str) -> Option<OpKind> {
        OpKind::ALL.iter().copied().find(|k| k.name().eq_ignore_ascii_case(name))
    }
}

/// One NMP operation from an application trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NmpOp {
    pub pid: Pid,
    pub kind: OpKind,
    pub dest: VAddr,
    pub src1: VAddr,
    pub src2: Option<VAddr>,
}

impl NmpOp {
    pub fn dest_vpage(&self) -> u64 {
        self.dest >> PAGE_SHIFT
    }

    pub fn src1_vpage(&self) -> u64 {
        self.src1 >> PAGE_SHIFT
    }

    pub fn src2_vpage(&self) -> Option<u64> {
        self.src2.map(|s| s >> PAGE_SHIFT)
    }

    /// All distinct virtual pages this op touches.
    pub fn vpages(&self) -> Vec<u64> {
        let (arr, n) = self.vpages_arr();
        arr[..n].to_vec()
    }

    /// Alloc-free variant for hot paths: distinct pages + count.
    #[inline]
    pub fn vpages_arr(&self) -> ([u64; 3], usize) {
        let d = self.dest_vpage();
        let s1 = self.src1_vpage();
        let mut arr = [d, 0, 0];
        let mut n = 1;
        if s1 != d {
            arr[n] = s1;
            n += 1;
        }
        if let Some(s2) = self.src2_vpage() {
            if s2 != d && s2 != s1 {
                arr[n] = s2;
                n += 1;
            }
        }
        arr[..n].sort_unstable();
        (arr, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vpages_dedup() {
        let op = NmpOp {
            pid: 1,
            kind: OpKind::Add,
            dest: 0x1000,
            src1: 0x1008, // same page as dest
            src2: Some(0x2000),
        };
        assert_eq!(op.vpages(), vec![1, 2]);
    }

    #[test]
    fn page_extraction() {
        let op = NmpOp { pid: 1, kind: OpKind::Mac, dest: 0x3040, src1: 0x5000, src2: None };
        assert_eq!(op.dest_vpage(), 3);
        assert_eq!(op.src1_vpage(), 5);
        assert_eq!(op.src2_vpage(), None);
    }

    #[test]
    fn op_kind_names_round_trip() {
        for k in OpKind::ALL {
            assert_eq!(OpKind::from_name(k.name()), Some(k), "{}", k.name());
            assert_eq!(OpKind::from_name(&k.name().to_lowercase()), Some(k));
        }
        assert_eq!(OpKind::from_name("XOR"), None);
    }
}
