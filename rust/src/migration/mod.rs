//! Migration management system (paper §5.3): a 128-entry migration queue
//! and a migration DMA (MDMA) engine that streams a page from its old
//! host cube to the new one in 256 B chunks, then reports the migration
//! latency back to the MC and interrupts the OS for the page-table update.
//!
//! Two modes, chosen by page permission:
//! * **blocking** (read-write pages): the page is locked — the MCs hold
//!   back every op touching it until the migration commits.
//! * **non-blocking** (read-only pages): the old frame keeps serving
//!   accesses during the copy; new accesses use the new mapping after the
//!   commit.

use std::collections::{HashMap, VecDeque};

use crate::config::{CubeId, Pid, SystemConfig, VPage, PAGE_SIZE};
use crate::mmu::Mmu;
use crate::noc::packet::{MigToken, NodeId, Packet, Payload};
use crate::sim::{BoundedQueue, Cycle};

/// Migration chunk size in bytes (a page moves in 16 chunks).
pub const CHUNK_BYTES: u64 = 256;
/// Outstanding chunk reads the MDMA keeps in flight per job.
pub const MDMA_WINDOW: u32 = 4;
/// Concurrent page migrations the MDMA engine sustains (its 1 KiB of
/// buffering = 4 in-flight 256 B chunks across jobs, §7.7).
pub const MDMA_JOBS: usize = 4;

/// A migration request from the agent's data-remapping action.
#[derive(Debug, Clone, Copy)]
pub struct MigRequest {
    pub pid: Pid,
    pub vpage: VPage,
    pub to_cube: CubeId,
    /// Blocking (read-write page) or non-blocking (read-only page).
    pub blocking: bool,
}

/// The active MDMA job.
#[derive(Debug)]
struct ActiveJob {
    token: MigToken,
    req: MigRequest,
    old_cube: CubeId,
    chunks_total: u32,
    reads_sent: u32,
    acks: u32,
    started: Cycle,
}

/// A committed migration, reported to the system for bookkeeping.
#[derive(Debug, Clone, Copy)]
pub struct CompletedMigration {
    pub pid: Pid,
    pub vpage: VPage,
    pub from_cube: CubeId,
    pub to_cube: CubeId,
    pub latency: u64,
}

/// Statistics for Fig 10 and the energy model.
#[derive(Debug, Clone, Default)]
pub struct MigrationStats {
    pub requested: u64,
    pub rejected_queue_full: u64,
    pub rejected_invalid: u64,
    pub completed: u64,
    pub total_latency: u64,
    /// Migration-queue touches (energy constant 0.02689 nJ).
    pub queue_touches: u64,
    /// MDMA buffer touches (energy constant 0.1062 nJ).
    pub mdma_touches: u64,
}

/// The migration management system. Lives beside MC 0 (its MDMA injects
/// and receives through `NodeId::Mc(0)`).
pub struct MigrationSystem {
    queue: BoundedQueue<MigRequest>,
    active: Vec<ActiveJob>,
    next_token: MigToken,
    /// Pages currently migrating, with their blocking flag.
    in_flight: HashMap<(Pid, VPage), bool>,
    /// Packets to inject (drained by the system).
    pub out: VecDeque<Packet>,
    /// Migrations committed this tick (drained by the system).
    pub completed: Vec<CompletedMigration>,
    pub stats: MigrationStats,
    home_mc: usize,
}

impl MigrationSystem {
    pub fn new(cfg: &SystemConfig) -> Self {
        Self {
            queue: BoundedQueue::new(cfg.migration_queue_cap),
            active: Vec::new(),
            next_token: 1,
            in_flight: HashMap::new(),
            out: VecDeque::new(),
            completed: Vec::new(),
            stats: MigrationStats::default(),
            home_mc: 0,
        }
    }

    /// Enqueue a migration (agent data-remap action). Fails when the
    /// migration queue is full or the page is already migrating.
    pub fn request(&mut self, req: MigRequest) -> bool {
        self.stats.requested += 1;
        if self.in_flight.contains_key(&(req.pid, req.vpage)) {
            self.stats.rejected_invalid += 1;
            return false;
        }
        self.stats.queue_touches += 1;
        match self.queue.push(req) {
            Ok(()) => {
                self.in_flight.insert((req.pid, req.vpage), req.blocking);
                true
            }
            Err(_) => {
                self.stats.rejected_queue_full += 1;
                false
            }
        }
    }

    /// Is this page locked by a blocking migration?
    pub fn is_blocked(&self, pid: Pid, vpage: VPage) -> bool {
        self.in_flight.get(&(pid, vpage)).copied().unwrap_or(false)
    }

    /// Is this page migrating at all (blocking or not)?
    pub fn is_migrating(&self, pid: Pid, vpage: VPage) -> bool {
        self.in_flight.contains_key(&(pid, vpage))
    }

    /// Does any page of `pid` have a migration queued or in flight?
    /// `in_flight` covers the full lifetime — inserted at `request` (so
    /// queued-but-unstarted jobs count) and removed only at commit or
    /// abort — so a `false` here means the MMU holds the only reference
    /// to the process's frames. Serve mode gates tenant departure on
    /// this before releasing the address space. The `any` over the map
    /// is a boolean fold: iteration order cannot affect the result, so
    /// determinism across worker counts is preserved.
    pub fn has_pid_in_flight(&self, pid: Pid) -> bool {
        // detlint: allow(hash-iter) — existential any(): order-independent boolean fold
        self.in_flight.keys().any(|(p, _)| *p == pid)
    }

    pub fn queue_occupancy(&self) -> f32 {
        self.queue.occupancy()
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_empty() && self.queue.is_empty() && self.out.is_empty()
    }

    /// Earliest cycle ≥ `now` at which [`tick`](Self::tick) or the
    /// injection retry can change state (event engine, DESIGN.md §8):
    /// queued requests start as soon as an MDMA job slot is free, and
    /// pending packets retry injection every cycle. With all slots busy
    /// the engine waits on chunk ACKs, which are delivery events of
    /// their own.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        let starts_job = !self.queue.is_empty() && self.active.len() < MDMA_JOBS;
        (starts_job || !self.out.is_empty()).then_some(now)
    }

    /// Bulk-apply `span` skipped cycles of per-cycle accounting (the
    /// `queue.observe()` each polled `tick` performs) — bit-identical
    /// to `span` consecutive quiescent ticks.
    pub fn observe_span(&mut self, span: u64) {
        self.queue.observe_n(span);
    }

    /// Handle a chunk ACK delivered to the MDMA.
    pub fn receive_ack(&mut self, token: MigToken, now: Cycle, mmu: &mut Mmu) {
        let Some(idx) = self.active.iter().position(|j| j.token == token) else {
            return;
        };
        self.stats.mdma_touches += 1;
        let job = &mut self.active[idx];
        job.acks += 1;
        // Keep the read window full.
        if job.reads_sent < job.chunks_total {
            let chunk = job.reads_sent;
            job.reads_sent += 1;
            let (old, new, tok) = (job.old_cube, job.req.to_cube, job.token);
            self.push_read(tok, chunk, old, new, now);
        } else if job.acks == job.chunks_total {
            // All chunks landed: commit the remap (OS page-table update).
            let job = self.active.swap_remove(idx);
            let latency = now - job.started;
            match mmu.commit_remap(job.req.pid, job.req.vpage) {
                Ok(pr) => {
                    self.in_flight.remove(&(job.req.pid, job.req.vpage));
                    self.stats.completed += 1;
                    self.stats.total_latency += latency;
                    self.completed.push(CompletedMigration {
                        pid: job.req.pid,
                        vpage: job.req.vpage,
                        from_cube: pr.old.cube,
                        to_cube: pr.new.cube,
                        latency,
                    });
                }
                Err(_) => {
                    self.in_flight.remove(&(job.req.pid, job.req.vpage));
                    self.stats.rejected_invalid += 1;
                }
            }
        }
    }

    fn push_read(&mut self, token: MigToken, chunk: u32, old: CubeId, new: CubeId, now: Cycle) {
        self.stats.mdma_touches += 1;
        self.out.push_back(Packet::new(
            token * 1000 + chunk as u64,
            NodeId::Mc(self.home_mc),
            NodeId::Cube(old),
            Payload::MigRead { token, chunk, old, new },
            now,
        ));
    }

    /// Advance the MDMA: start queued jobs while slots are free.
    pub fn tick(&mut self, now: Cycle, mmu: &mut Mmu) {
        self.queue.observe();
        while self.active.len() < MDMA_JOBS {
            let Some(req) = self.queue.pop() else { return };
            self.stats.queue_touches += 1;
            // Consult the OS for a frame in the new host cube (§5.3).
            match mmu.begin_remap(req.pid, req.vpage, req.to_cube) {
                Ok(pr) => {
                    let chunks_total = (PAGE_SIZE / CHUNK_BYTES) as u32;
                    let token = self.next_token;
                    self.next_token += 1;
                    let mut job = ActiveJob {
                        token,
                        req,
                        old_cube: pr.old.cube,
                        chunks_total,
                        reads_sent: 0,
                        acks: 0,
                        started: now,
                    };
                    let initial = MDMA_WINDOW.min(chunks_total);
                    for chunk in 0..initial {
                        job.reads_sent += 1;
                        self.push_read(token, chunk, pr.old.cube, req.to_cube, now);
                    }
                    self.active.push(job);
                }
                Err(_) => {
                    // Same cube / no frame / already pending: drop it.
                    self.in_flight.remove(&(req.pid, req.vpage));
                    self.stats.rejected_invalid += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn setup() -> (MigrationSystem, Mmu) {
        let mut cfg = SystemConfig::default();
        cfg.frames_per_cube = 64;
        let mut mmu = Mmu::new(&cfg);
        mmu.create_process(1);
        mmu.map_page(1, 10, 0).unwrap();
        (MigrationSystem::new(&cfg), mmu)
    }

    fn drain_acks(ms: &mut MigrationSystem, mmu: &mut Mmu, now: &mut Cycle) {
        // Answer every outstanding MigRead with an immediate ack.
        while let Some(pk) = ms.out.pop_front() {
            if let Payload::MigRead { token, .. } = pk.payload {
                *now += 1;
                ms.receive_ack(token, *now, mmu);
            }
        }
    }

    #[test]
    fn full_migration_lifecycle() {
        let (mut ms, mut mmu) = setup();
        assert!(ms.request(MigRequest { pid: 1, vpage: 10, to_cube: 5, blocking: true }));
        assert!(ms.is_blocked(1, 10));
        let mut now = 0;
        ms.tick(now, &mut mmu);
        // MDMA window of initial reads.
        assert_eq!(ms.out.len(), MDMA_WINDOW as usize);
        while ms.stats.completed == 0 {
            drain_acks(&mut ms, &mut mmu, &mut now);
            ms.tick(now, &mut mmu);
            assert!(now < 10_000);
        }
        assert!(!ms.is_migrating(1, 10));
        assert_eq!(mmu.translate(1, 10).unwrap().cube, 5);
        assert_eq!(ms.completed.len(), 1);
        assert_eq!(ms.completed[0].from_cube, 0);
        assert_eq!(ms.completed[0].to_cube, 5);
    }

    #[test]
    fn next_event_follows_queue_and_jobs() {
        let (mut ms, mut mmu) = setup();
        assert_eq!(ms.next_event(5), None, "idle MDMA is quiescent");
        ms.request(MigRequest { pid: 1, vpage: 10, to_cube: 5, blocking: true });
        assert_eq!(ms.next_event(5), Some(5), "queued request starts a job now");
        ms.tick(5, &mut mmu);
        // Job active, queue drained: chunk reads await injection.
        assert_eq!(ms.next_event(6), Some(6), "pending packets retry injection");
        ms.out.clear(); // the system would inject these
        assert_eq!(ms.next_event(7), None, "now waiting only on chunk ACK deliveries");
    }

    #[test]
    fn nonblocking_pages_not_locked() {
        let (mut ms, _mmu) = setup();
        ms.request(MigRequest { pid: 1, vpage: 10, to_cube: 5, blocking: false });
        assert!(!ms.is_blocked(1, 10));
        assert!(ms.is_migrating(1, 10));
    }

    #[test]
    fn duplicate_request_rejected() {
        let (mut ms, _mmu) = setup();
        assert!(ms.request(MigRequest { pid: 1, vpage: 10, to_cube: 5, blocking: true }));
        assert!(!ms.request(MigRequest { pid: 1, vpage: 10, to_cube: 6, blocking: true }));
    }

    #[test]
    fn queue_overflow_rejected() {
        let mut cfg = SystemConfig::default();
        cfg.migration_queue_cap = 2;
        let mut ms = MigrationSystem::new(&cfg);
        assert!(ms.request(MigRequest { pid: 1, vpage: 1, to_cube: 5, blocking: true }));
        assert!(ms.request(MigRequest { pid: 1, vpage: 2, to_cube: 5, blocking: true }));
        assert!(!ms.request(MigRequest { pid: 1, vpage: 3, to_cube: 5, blocking: true }));
        assert_eq!(ms.stats.rejected_queue_full, 1);
        // The page whose request overflowed must not stay marked.
        assert!(!ms.is_migrating(1, 3));
    }

    #[test]
    fn has_pid_in_flight_tracks_the_full_lifetime() {
        let (mut ms, mut mmu) = setup();
        assert!(!ms.has_pid_in_flight(1));
        // Counts from the moment of request — queued, not yet started.
        ms.request(MigRequest { pid: 1, vpage: 10, to_cube: 5, blocking: false });
        assert!(ms.has_pid_in_flight(1));
        assert!(!ms.has_pid_in_flight(2), "other pids unaffected");
        // …and clears only at commit.
        let mut now = 0;
        ms.tick(now, &mut mmu);
        assert!(ms.has_pid_in_flight(1), "active job still in flight");
        while ms.stats.completed == 0 {
            drain_acks(&mut ms, &mut mmu, &mut now);
            ms.tick(now, &mut mmu);
            assert!(now < 10_000);
        }
        assert!(!ms.has_pid_in_flight(1));
    }

    #[test]
    fn remap_to_same_cube_dropped() {
        let (mut ms, mut mmu) = setup();
        ms.request(MigRequest { pid: 1, vpage: 10, to_cube: 0, blocking: true });
        ms.tick(0, &mut mmu);
        assert_eq!(ms.stats.rejected_invalid, 1);
        assert!(!ms.is_migrating(1, 10));
        assert!(ms.is_idle());
    }
}
