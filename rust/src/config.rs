//! System configuration — the paper's Table 1 hardware parameters plus the
//! agent hyperparameters, with a small TOML-subset parser so deployments
//! can ship config files without any external dependency.
//!
//! Shared primitive types (`CubeId`, `VAddr`, …) also live here so the
//! substrate modules do not depend on one another for basic vocabulary.

use std::fmt;
use std::path::Path;

// Geometry delegation target of the MC-placement helpers below. An
// intra-crate module cycle (noc depends on config's vocabulary types) —
// fine in Rust, and it keeps every topology fact in one place.
use crate::noc::topology::{AnyTopology, Topology as _};
// Same deliberate cycle for the serve-mode arrival-process selector
// (workloads depends on config's vocabulary types): the enum lives with
// the interarrival samplers, the config only names it.
use crate::workloads::arrivals::ArrivalProcess;

/// Index of a memory cube in the mesh (row-major: `y * cols + x`).
pub type CubeId = usize;
/// Index of a memory controller (4, one per CMP corner — Table 1).
pub type McId = usize;
/// Process id for multi-program workloads.
pub type Pid = u32;
/// Virtual byte address within a process address space.
pub type VAddr = u64;
/// Virtual page number (`vaddr >> PAGE_SHIFT`).
pub type VPage = u64;

/// 4 KiB pages, as in a conventional 4-level paging system.
pub const PAGE_SHIFT: u32 = 12;
pub const PAGE_SIZE: u64 = 1 << PAGE_SHIFT;

/// NMP offloading technique under evaluation (paper §6.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Technique {
    /// Basic NMP following Active-Routing scheduling: compute at the cube
    /// hosting the destination operand.
    Bnmp,
    /// Load-balancing NMP: compute at the first source's cube, write the
    /// result back to the destination cube (and the CPU).
    Ldb,
    /// PIM-Enabled Instructions: exploit the CPU cache; on an operand
    /// cache hit, offload with one source to the other source's cube.
    Pei,
}

impl Technique {
    pub const ALL: [Technique; 3] = [Technique::Bnmp, Technique::Ldb, Technique::Pei];

    pub fn name(self) -> &'static str {
        match self {
            Technique::Bnmp => "BNMP",
            Technique::Ldb => "LDB",
            Technique::Pei => "PEI",
        }
    }

    /// Case-insensitive name lookup — the single parser shared by the
    /// CLI flags and the TOML config loader.
    pub fn from_name(s: &str) -> Option<Technique> {
        Self::ALL.into_iter().find(|t| t.name().eq_ignore_ascii_case(s))
    }

    /// `BNMP|LDB|PEI` — the valid-value list for parse-error messages,
    /// derived from [`Technique::ALL`] so it can never drift from what
    /// [`Technique::from_name`] actually accepts.
    pub fn name_list() -> String {
        Self::ALL.map(Self::name).join("|")
    }
}

impl fmt::Display for Technique {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Remapping scheme layered on top of a technique (paper §6.3) — the
/// configuration selector for a [`crate::mapping::MappingPolicy`]. The
/// decision logic itself lives in `mapping::policy`; this enum only
/// names the policy and parses it from flags and config files.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MappingScheme {
    /// "B" in the figures: the technique alone, no remapping support.
    Baseline,
    /// Transparent Offloading and Mapping: epoch-profiled physical-address
    /// remapping for best data co-location.
    Tom,
    /// The paper's contribution: RL-driven page + computation remapping.
    Aimm,
    /// Distributed AIMM: one lightweight agent per memory controller,
    /// each observing only its attached cubes, coordinated through a
    /// deterministic round-robin gossip exchange of replay transitions
    /// (`agent/multi.rs`). The paper's §hardware plugs an AIMM unit
    /// beside *each* MC; this scheme actually trains one there.
    AimmMc,
    /// CODA-style greedy co-location (Kim et al.): windowed per-page
    /// compute counters, hysteresis-gated migration toward the cube
    /// issuing the majority of a page's NMP ops. No learning.
    Coda,
    /// Perfect-knowledge upper bound: dry-run the op stream, derive the
    /// best static page→cube assignment, replay with it via first-touch
    /// placement.
    Oracle,
}

impl MappingScheme {
    /// Every selectable policy, in registry order — the source of truth
    /// for `from_name`, CLI error messages and `--mappings all`.
    pub const ALL: [MappingScheme; 6] = [
        MappingScheme::Baseline,
        MappingScheme::Tom,
        MappingScheme::Aimm,
        MappingScheme::AimmMc,
        MappingScheme::Coda,
        MappingScheme::Oracle,
    ];

    /// The paper's evaluated trio (Fig 6's B / TOM / AIMM columns) — the
    /// default sweep axis. Kept separate from [`MappingScheme::ALL`] so
    /// adding policies never silently grows the default grids (or the
    /// golden fixture pinned to them).
    pub const PAPER: [MappingScheme; 3] =
        [MappingScheme::Baseline, MappingScheme::Tom, MappingScheme::Aimm];

    pub fn name(self) -> &'static str {
        match self {
            MappingScheme::Baseline => "B",
            MappingScheme::Tom => "TOM",
            MappingScheme::Aimm => "AIMM",
            MappingScheme::AimmMc => "AIMM-MC",
            MappingScheme::Coda => "CODA",
            MappingScheme::Oracle => "ORACLE",
        }
    }

    /// Case-insensitive name lookup (accepts the figures' "B" shorthand
    /// and the long form "BASELINE") — shared by the CLI flags and the
    /// TOML config loader.
    pub fn from_name(s: &str) -> Option<MappingScheme> {
        if s.eq_ignore_ascii_case("BASELINE") {
            return Some(MappingScheme::Baseline);
        }
        Self::ALL.into_iter().find(|m| m.name().eq_ignore_ascii_case(s))
    }

    /// `B|TOM|AIMM|AIMM-MC|CODA|ORACLE` — the valid-value list for
    /// parse-error messages, derived from [`MappingScheme::ALL`] so new
    /// policies show up in CLI errors automatically.
    pub fn name_list() -> String {
        Self::ALL.map(Self::name).join("|")
    }

    /// Does this policy accept a caller-provided single agent carried
    /// across runs? Only AIMM does; AIMM-MC constructs and carries its
    /// own per-MC agents inside the policy object, and the others are
    /// stateless between episodes.
    pub fn uses_agent(self) -> bool {
        self == MappingScheme::Aimm
    }

    /// Can this policy be saved/resumed through the continual-learning
    /// checkpoint format? AIMM and AIMM-MC carry learned state worth
    /// persisting (one agent / one bundle of per-MC agents) —
    /// `--checkpoint`/`--resume` reject every other policy loudly.
    pub fn checkpointable(self) -> bool {
        matches!(self, MappingScheme::Aimm | MappingScheme::AimmMc)
    }
}

impl fmt::Display for MappingScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Simulation engine driving [`crate::coordinator::System::run`]. Both
/// engines produce bit-identical `RunStats` — enforced by
/// `rust/tests/engine_equivalence.rs` — so the choice is purely a
/// wall-clock trade (DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Engine {
    /// The original unconditional per-cycle polling loop. Kept as the
    /// differential-testing reference.
    Polled,
    /// Next-event time skipping: components report their next
    /// interesting cycle and the clock jumps straight to the earliest
    /// one, bulk-applying the skipped span's occupancy accounting.
    Event,
}

impl Engine {
    pub const ALL: [Engine; 2] = [Engine::Polled, Engine::Event];

    pub fn name(self) -> &'static str {
        match self {
            Engine::Polled => "polled",
            Engine::Event => "event",
        }
    }

    /// Case-insensitive name lookup — shared by the `--engine` CLI flag
    /// and the TOML config loader.
    pub fn from_name(s: &str) -> Option<Engine> {
        Self::ALL.into_iter().find(|e| e.name().eq_ignore_ascii_case(s))
    }

    /// `polled|event` — the valid-value list for parse-error messages.
    pub fn name_list() -> String {
        Self::ALL.map(Self::name).join("|")
    }
}

impl fmt::Display for Engine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Interconnect topology of the memory-cube network. The geometry itself
/// lives in [`crate::noc::topology`]; this enum is the configuration
/// selector, threaded through the `topology` TOML key, the `--topology`
/// CLI flag and the sweep grid's topology axis. The default (`Mesh`) is
/// bit-identical to the pre-topology simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TopologyKind {
    /// The paper's 2D mesh: 4 corner-attached MCs, XY routing (Table 1).
    Mesh,
    /// 2D torus: the mesh plus wraparound links — per-dimension diameter
    /// halves, the gentlest hop-distance structure.
    Torus,
    /// 1D ring over all cubes — the worst-case diameter stress topology
    /// for scale-out studies.
    Ring,
}

impl TopologyKind {
    pub const ALL: [TopologyKind; 3] =
        [TopologyKind::Mesh, TopologyKind::Torus, TopologyKind::Ring];

    pub fn name(self) -> &'static str {
        match self {
            TopologyKind::Mesh => "mesh",
            TopologyKind::Torus => "torus",
            TopologyKind::Ring => "ring",
        }
    }

    /// Case-insensitive name lookup — shared by the `--topology` CLI
    /// flag and the TOML config loader.
    pub fn from_name(s: &str) -> Option<TopologyKind> {
        Self::ALL.into_iter().find(|t| t.name().eq_ignore_ascii_case(s))
    }

    /// `mesh|torus|ring` — the valid-value list for parse-error messages.
    pub fn name_list() -> String {
        Self::ALL.map(Self::name).join("|")
    }
}

impl fmt::Display for TopologyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// DRAM / interconnect timing in memory-network cycles.
#[derive(Debug, Clone)]
pub struct TimingConfig {
    /// Row-buffer hit access latency (tCL).
    pub row_hit: u64,
    /// Row-buffer miss latency (tRP + tRCD + tCL).
    pub row_miss: u64,
    /// Router pipeline depth per hop (Table 1: 3-stage router).
    pub router_pipeline: u64,
    /// Link width in bits (Table 1: 128-bit links).
    pub link_bits: u64,
    /// ALU latency of one NMP operation on the cube's base die.
    pub nmp_compute: u64,
    /// Page-table walk penalty on a TLB miss (4 sequential accesses).
    pub pt_walk: u64,
}

impl Default for TimingConfig {
    fn default() -> Self {
        Self {
            row_hit: 14,
            row_miss: 42,
            router_pipeline: 3,
            link_bits: 128,
            nmp_compute: 4,
            pt_walk: 120,
        }
    }
}

/// RL agent hyperparameters (paper §4.2/§4.3; network dims must match the
/// AOT artifacts — see python/compile/model.py). `PartialEq` because the
/// continual-learning checkpoints record the config they were trained
/// under and resume refuses a drifted one (agent/checkpoint.rs).
#[derive(Debug, Clone, PartialEq)]
pub struct AgentConfig {
    /// Discrete agent invocation intervals in cycles (§4.2).
    pub intervals: Vec<u64>,
    /// Index into `intervals` at episode start.
    pub initial_interval: usize,
    /// Discount factor γ.
    pub gamma: f32,
    /// Adam learning rate.
    pub lr: f32,
    /// ε-greedy start / end / per-invocation decay.
    pub eps_start: f32,
    pub eps_end: f32,
    pub eps_decay: f32,
    /// Replay buffer capacity (transitions in the ring).
    pub replay_capacity: usize,
    /// Rows per DQN training batch. Honored end-to-end by the replay
    /// buffer and the `LinearQ` backend; the PJRT artifacts are
    /// shape-specialized to `runtime::BATCH`, so an agent on that
    /// backend rejects any other value at construction
    /// (`AimmAgent::try_new`) rather than silently ignoring the knob.
    pub batch_size: usize,
    /// Train every N agent invocations once the buffer holds a batch.
    pub train_every: u32,
    /// Copy θ → θ⁻ every N training steps.
    pub target_sync: u32,
    /// Reward deadband: |ΔOPC| below this fraction → 0 reward.
    pub reward_deadband: f64,
}

impl Default for AgentConfig {
    fn default() -> Self {
        Self {
            intervals: vec![100, 125, 167, 250],
            initial_interval: 1,
            gamma: 0.95,
            lr: 5e-4,
            eps_start: 0.4,
            eps_end: 0.02,
            eps_decay: 0.95,
            replay_capacity: 8192,
            batch_size: 32,
            train_every: 1,
            target_sync: 64,
            reward_deadband: 0.03,
        }
    }
}

/// Multi-tenant service mode (`aimm serve`,
/// [`crate::coordinator::serve`]): open-loop tenant churn with one
/// continually-learning agent surviving the whole service lifetime.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Tenants drawn from the benchmark mix over the service lifetime.
    pub tenants: usize,
    /// Mean interarrival gap in cycles (the arrival process shapes the
    /// actual gaps around this mean).
    pub mean_gap: u64,
    /// Interarrival process ([`ArrivalProcess`]).
    pub arrivals: ArrivalProcess,
    /// Compute slots: resident-tenant cap (admission control).
    pub slots: usize,
    /// Total pages resident tenants may lease at once.
    pub page_budget: u64,
    /// Service rounds; the agent carries across rounds exactly like the
    /// episode protocol, so later rounds show the learned service.
    pub rounds: usize,
    /// Per-tenant trace scale (passed to [`crate::workloads::generate`];
    /// small — tenants are many and arrive continuously).
    pub scale: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            tenants: 12,
            mean_gap: 400,
            arrivals: ArrivalProcess::Poisson,
            slots: 4,
            page_budget: 4096,
            rounds: 2,
            scale: 0.02,
        }
    }
}

/// Full system configuration (paper Table 1 defaults).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Grid dimensions (Table 1: 4×4; §7.5.1 scales to 8×8). Under the
    /// `Ring` topology the product is the cycle length; the names keep
    /// their `mesh_` prefix for config-file compatibility.
    pub mesh_cols: usize,
    pub mesh_rows: usize,
    /// Cube-network topology ([`TopologyKind`]; geometry in
    /// [`crate::noc::topology`]).
    pub topology: TopologyKind,
    /// Memory cube internals (Table 1: 1 GB, 32 vaults, 8 banks/vault).
    pub vaults_per_cube: usize,
    pub banks_per_vault: usize,
    /// Frames each cube can host (1 GB / 4 KiB = 262144; scaled down for
    /// simulation traces, which touch far fewer pages).
    pub frames_per_cube: usize,
    /// NMP-op table entries per cube (Table 1: 512).
    pub nmp_table_entries: usize,
    /// Page-info cache entries per MC (Table 1 lists 128; §7.6's
    /// sensitivity study empirically settles on 256 — our default).
    pub page_info_entries: usize,
    /// MC request queue capacity.
    pub mc_queue_cap: usize,
    /// Migration queue entries (Table 1: 128).
    pub migration_queue_cap: usize,
    /// Router input VC count (Table 1-adjacent: 5 VCs; we model 2 traffic
    /// classes with this much aggregate buffering per port).
    pub vcs: usize,
    /// Per-port, per-class router buffer capacity in packets.
    pub router_buf_cap: usize,
    /// Maximum outstanding NMP ops in the memory system. NMP offloads
    /// retire from core MSHRs at ACK-of-dispatch (PEI-style), so the
    /// in-memory concurrency far exceeds 16×16 MSHRs; this calibrates the
    /// system to the loaded operating point the paper's Fig 13 implies
    /// (NMP tables under real pressure).
    pub max_outstanding: usize,
    /// Ops the CPU side can issue per cycle across all MCs.
    pub issue_width: usize,
    /// Shared CPU last-level capacity modeled for PEI, in 64 B lines
    /// (16 cores × 32 KiB = 512 KiB → 8192 lines).
    pub cpu_cache_lines: usize,
    pub technique: Technique,
    pub mapping: MappingScheme,
    /// Simulation engine (next-event time skipping by default; the
    /// polled reference loop stays available for differential testing).
    pub engine: Engine,
    /// Use the NMP-aware HOARD frame allocator (multi-program baseline).
    pub hoard: bool,
    pub timing: TimingConfig,
    pub agent: AgentConfig,
    /// Multi-tenant service mode (`aimm serve`) knobs.
    pub serve: ServeConfig,
    /// Master seed; all subsystem RNG streams derive from it.
    pub seed: u64,
    /// Sample the OPC timeline every this many cycles.
    pub opc_sample_period: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            mesh_cols: 4,
            mesh_rows: 4,
            topology: TopologyKind::Mesh,
            vaults_per_cube: 32,
            banks_per_vault: 8,
            frames_per_cube: 262_144,
            nmp_table_entries: 512,
            page_info_entries: 256,
            mc_queue_cap: 64,
            migration_queue_cap: 128,
            vcs: 5,
            router_buf_cap: 8,
            max_outstanding: 1024,
            issue_width: 8,
            cpu_cache_lines: 8192,
            technique: Technique::Bnmp,
            mapping: MappingScheme::Baseline,
            engine: Engine::Event,
            hoard: false,
            timing: TimingConfig::default(),
            agent: AgentConfig::default(),
            serve: ServeConfig::default(),
            seed: 0xA133,
            opc_sample_period: 512,
        }
    }
}

impl SystemConfig {
    pub fn num_cubes(&self) -> usize {
        self.mesh_cols * self.mesh_rows
    }

    /// 4 MCs at the CMP corners; their attach cubes depend on the
    /// topology (corners on mesh/torus, quarter points on the ring).
    pub fn num_mcs(&self) -> usize {
        crate::noc::topology::NUM_MCS
    }

    /// The geometry object this config describes ([`crate::noc::topology`]).
    /// `Copy`-cheap: delegating per call allocates nothing.
    pub fn topology_obj(&self) -> AnyTopology {
        AnyTopology::of(self)
    }

    /// The cube each MC attaches to (topology-defined).
    pub fn mc_attach_cube(&self, mc: McId) -> CubeId {
        self.topology_obj().mc_attach_cube(mc)
    }

    /// Cubes "nearest" to an MC. Each MC aggregates occupancy/row-hit
    /// counters over these (paper §5.1). Always an exact partition of the
    /// cubes — including odd and rectangular grids, where the seed
    /// simulator's standalone quadrant rectangles silently overlapped.
    pub fn mc_nearest_cubes(&self, mc: McId) -> Vec<CubeId> {
        self.topology_obj().mc_nearest_cubes(mc)
    }

    /// The MC whose partition contains `cube` (the target of its
    /// periodic occupancy reports).
    pub fn cube_home_mc(&self, cube: CubeId) -> McId {
        self.topology_obj().cube_home_mc(cube)
    }

    /// Render as a TOML-subset document (round-trips through `parse`).
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        let kv = |s: &mut String, k: &str, v: String| {
            s.push_str(k);
            s.push_str(" = ");
            s.push_str(&v);
            s.push('\n');
        };
        kv(&mut s, "mesh_cols", self.mesh_cols.to_string());
        kv(&mut s, "mesh_rows", self.mesh_rows.to_string());
        kv(&mut s, "topology", format!("\"{}\"", self.topology.name()));
        kv(&mut s, "vaults_per_cube", self.vaults_per_cube.to_string());
        kv(&mut s, "banks_per_vault", self.banks_per_vault.to_string());
        kv(&mut s, "frames_per_cube", self.frames_per_cube.to_string());
        kv(&mut s, "nmp_table_entries", self.nmp_table_entries.to_string());
        kv(&mut s, "page_info_entries", self.page_info_entries.to_string());
        kv(&mut s, "mc_queue_cap", self.mc_queue_cap.to_string());
        kv(&mut s, "migration_queue_cap", self.migration_queue_cap.to_string());
        kv(&mut s, "vcs", self.vcs.to_string());
        kv(&mut s, "router_buf_cap", self.router_buf_cap.to_string());
        kv(&mut s, "max_outstanding", self.max_outstanding.to_string());
        kv(&mut s, "issue_width", self.issue_width.to_string());
        kv(&mut s, "cpu_cache_lines", self.cpu_cache_lines.to_string());
        kv(&mut s, "technique", format!("\"{}\"", self.technique.name()));
        kv(&mut s, "mapping", format!("\"{}\"", self.mapping.name()));
        kv(&mut s, "engine", format!("\"{}\"", self.engine.name()));
        kv(&mut s, "hoard", self.hoard.to_string());
        kv(&mut s, "seed", self.seed.to_string());
        kv(&mut s, "gamma", self.agent.gamma.to_string());
        kv(&mut s, "lr", self.agent.lr.to_string());
        kv(&mut s, "batch_size", self.agent.batch_size.to_string());
        kv(&mut s, "replay_capacity", self.agent.replay_capacity.to_string());
        kv(&mut s, "serve_tenants", self.serve.tenants.to_string());
        kv(&mut s, "serve_mean_gap", self.serve.mean_gap.to_string());
        kv(&mut s, "serve_arrivals", format!("\"{}\"", self.serve.arrivals.name()));
        kv(&mut s, "serve_slots", self.serve.slots.to_string());
        kv(&mut s, "serve_page_budget", self.serve.page_budget.to_string());
        kv(&mut s, "serve_rounds", self.serve.rounds.to_string());
        kv(&mut s, "serve_scale", self.serve.scale.to_string());
        s
    }

    /// Parse a TOML-subset document: `key = value` lines, `#` comments,
    /// string / integer / float / bool values. Unknown keys error.
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut cfg = SystemConfig::default();
        let kvs = parse_kv(text)?;
        for (k, v) in kvs {
            match k.as_str() {
                "mesh_cols" => cfg.mesh_cols = v.as_usize()?,
                "mesh_rows" => cfg.mesh_rows = v.as_usize()?,
                "vaults_per_cube" => cfg.vaults_per_cube = v.as_usize()?,
                "banks_per_vault" => cfg.banks_per_vault = v.as_usize()?,
                "frames_per_cube" => cfg.frames_per_cube = v.as_usize()?,
                "nmp_table_entries" => cfg.nmp_table_entries = v.as_usize()?,
                "page_info_entries" => cfg.page_info_entries = v.as_usize()?,
                "mc_queue_cap" => cfg.mc_queue_cap = v.as_usize()?,
                "migration_queue_cap" => cfg.migration_queue_cap = v.as_usize()?,
                "vcs" => cfg.vcs = v.as_usize()?,
                "router_buf_cap" => cfg.router_buf_cap = v.as_usize()?,
                "max_outstanding" => cfg.max_outstanding = v.as_usize()?,
                "issue_width" => cfg.issue_width = v.as_usize()?,
                "cpu_cache_lines" => cfg.cpu_cache_lines = v.as_usize()?,
                "seed" => cfg.seed = v.as_u64()?,
                "hoard" => cfg.hoard = v.as_bool()?,
                "gamma" => cfg.agent.gamma = v.as_f64()? as f32,
                "lr" => cfg.agent.lr = v.as_f64()? as f32,
                "batch_size" => cfg.agent.batch_size = v.as_usize()?,
                "replay_capacity" => cfg.agent.replay_capacity = v.as_usize()?,
                "serve_tenants" => cfg.serve.tenants = v.as_usize()?,
                "serve_mean_gap" => cfg.serve.mean_gap = v.as_u64()?,
                "serve_slots" => cfg.serve.slots = v.as_usize()?,
                "serve_page_budget" => cfg.serve.page_budget = v.as_u64()?,
                "serve_rounds" => cfg.serve.rounds = v.as_usize()?,
                "serve_scale" => cfg.serve.scale = v.as_f64()?,
                "serve_arrivals" => {
                    let name = v.as_str()?;
                    cfg.serve.arrivals = ArrivalProcess::from_name(name).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown serve_arrivals {name:?} (expected one of {})",
                            ArrivalProcess::name_list()
                        )
                    })?;
                }
                "technique" => {
                    let name = v.as_str()?;
                    cfg.technique = Technique::from_name(name).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown technique {name:?} (expected one of {})",
                            Technique::name_list()
                        )
                    })?;
                }
                "mapping" => {
                    let name = v.as_str()?;
                    cfg.mapping = MappingScheme::from_name(name).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown mapping {name:?} (expected one of {}, or BASELINE)",
                            MappingScheme::name_list()
                        )
                    })?;
                }
                "engine" => {
                    let name = v.as_str()?;
                    cfg.engine = Engine::from_name(name).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown engine {name:?} (expected one of {})",
                            Engine::name_list()
                        )
                    })?;
                }
                "topology" => {
                    let name = v.as_str()?;
                    cfg.topology = TopologyKind::from_name(name).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown topology {name:?} (expected one of {})",
                            TopologyKind::name_list()
                        )
                    })?;
                }
                other => anyhow::bail!("unknown config key {other:?}"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.mesh_cols >= 2 && self.mesh_rows >= 2, "mesh must be at least 2x2");
        // Topology sanity, checked loudly instead of producing wrong
        // quadrants/arcs at runtime: every MC needs its own attach cube
        // and a non-empty nearest-cubes partition (exact partitioning for
        // odd/rectangular grids is guaranteed by construction and tested
        // in noc/topology.rs).
        let topo = self.topology_obj();
        for mc in 0..self.num_mcs() {
            for other in mc + 1..self.num_mcs() {
                anyhow::ensure!(
                    topo.mc_attach_cube(mc) != topo.mc_attach_cube(other),
                    "{}x{} {} gives MCs {mc} and {other} the same attach cube {}",
                    self.mesh_cols,
                    self.mesh_rows,
                    self.topology,
                    topo.mc_attach_cube(mc)
                );
            }
            anyhow::ensure!(
                !topo.mc_nearest_cubes(mc).is_empty(),
                "{}x{} {} leaves MC {mc} with no nearest cubes",
                self.mesh_cols,
                self.mesh_rows,
                self.topology
            );
        }
        // Wraparound topologies run bubble flow control (noc/topology.rs
        // module docs): a packet entering a dimension ring must leave one
        // buffer slot free, which is impossible with single-slot buffers.
        anyhow::ensure!(
            !topo.wraparound() || self.router_buf_cap >= 2,
            "topology {} has wraparound links and needs router_buf_cap >= 2 \
             (bubble flow control), got {}",
            self.topology,
            self.router_buf_cap
        );
        anyhow::ensure!(self.vaults_per_cube.is_power_of_two(), "vaults must be a power of two");
        anyhow::ensure!(self.banks_per_vault.is_power_of_two(), "banks must be a power of two");
        anyhow::ensure!(self.nmp_table_entries > 0, "nmp table must be non-empty");
        anyhow::ensure!(self.page_info_entries > 0, "page info cache must be non-empty");
        anyhow::ensure!(!self.agent.intervals.is_empty(), "agent needs at least one interval");
        anyhow::ensure!(self.agent.batch_size > 0, "agent batch_size must be positive");
        anyhow::ensure!(
            self.agent.replay_capacity >= self.agent.batch_size,
            "replay_capacity {} smaller than batch_size {}",
            self.agent.replay_capacity,
            self.agent.batch_size
        );
        anyhow::ensure!(self.serve.tenants >= 1, "serve needs at least one tenant");
        anyhow::ensure!(self.serve.slots >= 1, "serve needs at least one compute slot");
        anyhow::ensure!(self.serve.mean_gap >= 1, "serve_mean_gap must be at least 1 cycle");
        anyhow::ensure!(self.serve.rounds >= 1, "serve needs at least one round");
        anyhow::ensure!(
            self.serve.scale > 0.0 && self.serve.scale.is_finite(),
            "serve_scale must be a positive finite number, got {}",
            self.serve.scale
        );
        Ok(())
    }
}

/// One parsed scalar value from the TOML subset.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    fn as_usize(&self) -> anyhow::Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            other => anyhow::bail!("expected non-negative integer, got {other:?}"),
        }
    }

    fn as_u64(&self) -> anyhow::Result<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as u64),
            other => anyhow::bail!("expected non-negative integer, got {other:?}"),
        }
    }

    fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => anyhow::bail!("expected number, got {other:?}"),
        }
    }

    fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => anyhow::bail!("expected bool, got {other:?}"),
        }
    }

    fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other:?}"),
        }
    }
}

/// Parse `key = value` lines (TOML subset: comments, strings, ints,
/// floats, bools). Section headers are rejected — the config is flat.
///
/// Pairs are returned in file order (duplicates keep every entry, so
/// later lines win when applied in sequence). A `HashMap` here would
/// make which-bad-key-errors-first depend on hash order.
pub fn parse_kv(text: &str) -> anyhow::Result<Vec<(String, TomlValue)>> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            // Don't strip '#' inside quoted strings.
            Some(pos) if !raw[..pos].contains('"') || raw[..pos].matches('"').count() % 2 == 0 => {
                &raw[..pos]
            }
            _ => raw,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        anyhow::ensure!(
            !line.starts_with('['),
            "line {}: sections are not supported in this TOML subset",
            lineno + 1
        );
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = k.trim().to_string();
        let vs = v.trim();
        let value = if let Some(stripped) = vs.strip_prefix('"') {
            let inner = stripped
                .strip_suffix('"')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated string", lineno + 1))?;
            TomlValue::Str(inner.to_string())
        } else if vs == "true" {
            TomlValue::Bool(true)
        } else if vs == "false" {
            TomlValue::Bool(false)
        } else if let Ok(i) = vs.parse::<i64>() {
            TomlValue::Int(i)
        } else if let Ok(f) = vs.parse::<f64>() {
            TomlValue::Float(f)
        } else {
            anyhow::bail!("line {}: cannot parse value {vs:?}", lineno + 1);
        };
        out.push((key, value));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let c = SystemConfig::default();
        assert_eq!(c.num_cubes(), 16);
        assert_eq!(c.vaults_per_cube, 32);
        assert_eq!(c.banks_per_vault, 8);
        assert_eq!(c.nmp_table_entries, 512);
        assert_eq!(c.page_info_entries, 256);
        assert_eq!(c.migration_queue_cap, 128);
        assert_eq!(c.num_mcs(), 4);
    }

    #[test]
    fn mc_attach_corners_4x4() {
        let c = SystemConfig::default();
        assert_eq!(c.mc_attach_cube(0), 0);
        assert_eq!(c.mc_attach_cube(1), 3);
        assert_eq!(c.mc_attach_cube(2), 12);
        assert_eq!(c.mc_attach_cube(3), 15);
    }

    #[test]
    fn nearest_cubes_partition_mesh() {
        let c = SystemConfig::default();
        let mut all: Vec<CubeId> = (0..4).flat_map(|m| c.mc_nearest_cubes(m)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn home_mc_consistent_with_quadrants() {
        let c = SystemConfig::default();
        for mc in 0..4 {
            for cube in c.mc_nearest_cubes(mc) {
                assert_eq!(c.cube_home_mc(cube), mc, "cube {cube}");
            }
        }
    }

    #[test]
    fn toml_roundtrip() {
        let mut c = SystemConfig::default();
        c.mesh_cols = 8;
        c.mesh_rows = 8;
        c.technique = Technique::Pei;
        c.mapping = MappingScheme::Aimm;
        c.engine = Engine::Polled;
        c.hoard = true;
        let parsed = SystemConfig::parse(&c.to_toml()).unwrap();
        assert_eq!(parsed.mesh_cols, 8);
        assert_eq!(parsed.technique, Technique::Pei);
        assert_eq!(parsed.mapping, MappingScheme::Aimm);
        assert_eq!(parsed.engine, Engine::Polled);
        assert!(parsed.hoard);
    }

    #[test]
    fn engine_names_roundtrip_and_default_is_event() {
        for e in Engine::ALL {
            assert_eq!(Engine::from_name(e.name()), Some(e));
        }
        assert_eq!(Engine::from_name("POLLED"), Some(Engine::Polled));
        assert_eq!(Engine::from_name("Event"), Some(Engine::Event));
        assert_eq!(Engine::from_name("nope"), None);
        assert_eq!(SystemConfig::default().engine, Engine::Event);
        assert!(SystemConfig::parse("engine = \"bogus\"").is_err());
    }

    #[test]
    fn parse_rejects_unknown_key() {
        assert!(SystemConfig::parse("bogus = 3").is_err());
    }

    /// `batch_size` is a live knob, not a silently-ignored field: it
    /// round-trips through TOML and bad values are rejected.
    #[test]
    fn batch_size_roundtrips_and_validates() {
        let mut c = SystemConfig::default();
        c.agent.batch_size = 16;
        c.agent.replay_capacity = 4096;
        let parsed = SystemConfig::parse(&c.to_toml()).unwrap();
        assert_eq!(parsed.agent.batch_size, 16);
        assert_eq!(parsed.agent.replay_capacity, 4096);
        assert!(SystemConfig::parse("batch_size = 0").is_err());
        // A batch larger than the replay ring can never be sampled.
        assert!(SystemConfig::parse("batch_size = 64\nreplay_capacity = 32").is_err());
    }

    #[test]
    fn names_roundtrip_through_from_name() {
        for t in Technique::ALL {
            assert_eq!(Technique::from_name(t.name()), Some(t));
        }
        for m in MappingScheme::ALL {
            assert_eq!(MappingScheme::from_name(m.name()), Some(m));
        }
        assert_eq!(MappingScheme::from_name("baseline"), Some(MappingScheme::Baseline));
        assert_eq!(MappingScheme::from_name("b"), Some(MappingScheme::Baseline));
        assert_eq!(MappingScheme::from_name("aimm-mc"), Some(MappingScheme::AimmMc));
        assert_eq!(MappingScheme::from_name("coda"), Some(MappingScheme::Coda));
        assert_eq!(MappingScheme::from_name("oracle"), Some(MappingScheme::Oracle));
        assert_eq!(Technique::from_name("ldb"), Some(Technique::Ldb));
        assert_eq!(Technique::from_name("nope"), None);
        assert_eq!(MappingScheme::from_name("nope"), None);
    }

    /// The registry split: ALL is the CLI-facing list (six policies),
    /// PAPER the default-grid trio — and every PAPER entry is in ALL.
    #[test]
    fn mapping_registries_are_consistent() {
        assert_eq!(MappingScheme::ALL.len(), 6);
        assert_eq!(
            MappingScheme::PAPER,
            [MappingScheme::Baseline, MappingScheme::Tom, MappingScheme::Aimm]
        );
        for m in MappingScheme::PAPER {
            assert!(MappingScheme::ALL.contains(&m));
        }
        assert!(MappingScheme::Aimm.uses_agent() && MappingScheme::Aimm.checkpointable());
        // AIMM-MC carries learned state (checkpointable) but constructs
        // its own per-MC agents — it never takes a caller-provided one.
        assert!(!MappingScheme::AimmMc.uses_agent());
        assert!(MappingScheme::AimmMc.checkpointable());
        for m in [
            MappingScheme::Baseline,
            MappingScheme::Tom,
            MappingScheme::Coda,
            MappingScheme::Oracle,
        ] {
            assert!(!m.uses_agent(), "{m}");
            assert!(!m.checkpointable(), "{m}");
        }
    }

    /// Parse errors list the valid names, derived from the same ALL
    /// registries from_name reads — new values show up automatically.
    #[test]
    fn parse_errors_list_valid_names() {
        assert_eq!(MappingScheme::name_list(), "B|TOM|AIMM|AIMM-MC|CODA|ORACLE");
        assert_eq!(Technique::name_list(), "BNMP|LDB|PEI");
        assert_eq!(Engine::name_list(), "polled|event");
        assert_eq!(TopologyKind::name_list(), "mesh|torus|ring");
        let err = SystemConfig::parse("mapping = \"bogus\"").unwrap_err().to_string();
        assert!(err.contains("B|TOM|AIMM|AIMM-MC|CODA|ORACLE"), "{err}");
        let err = SystemConfig::parse("technique = \"bogus\"").unwrap_err().to_string();
        assert!(err.contains("BNMP|LDB|PEI"), "{err}");
        let err = SystemConfig::parse("engine = \"bogus\"").unwrap_err().to_string();
        assert!(err.contains("polled|event"), "{err}");
        let err = SystemConfig::parse("topology = \"bogus\"").unwrap_err().to_string();
        assert!(err.contains("mesh|torus|ring"), "{err}");
    }

    /// The serve knobs are live config, not CLI-only state: they
    /// round-trip through TOML, bad arrival names list the valid ones,
    /// and degenerate values are rejected by validate().
    #[test]
    fn serve_config_roundtrips_and_validates() {
        let mut c = SystemConfig::default();
        c.serve.tenants = 7;
        c.serve.mean_gap = 123;
        c.serve.arrivals = ArrivalProcess::Diurnal;
        c.serve.slots = 3;
        c.serve.page_budget = 999;
        c.serve.rounds = 4;
        c.serve.scale = 0.5;
        let parsed = SystemConfig::parse(&c.to_toml()).unwrap();
        assert_eq!(parsed.serve.tenants, 7);
        assert_eq!(parsed.serve.mean_gap, 123);
        assert_eq!(parsed.serve.arrivals, ArrivalProcess::Diurnal);
        assert_eq!(parsed.serve.slots, 3);
        assert_eq!(parsed.serve.page_budget, 999);
        assert_eq!(parsed.serve.rounds, 4);
        assert_eq!(parsed.serve.scale, 0.5);
        let err = SystemConfig::parse("serve_arrivals = \"bogus\"").unwrap_err().to_string();
        assert!(err.contains("poisson|bursty|diurnal"), "{err}");
        assert!(SystemConfig::parse("serve_tenants = 0").is_err());
        assert!(SystemConfig::parse("serve_slots = 0").is_err());
        assert!(SystemConfig::parse("serve_mean_gap = 0").is_err());
        assert!(SystemConfig::parse("serve_rounds = 0").is_err());
        assert!(SystemConfig::parse("serve_scale = 0").is_err());
    }

    #[test]
    fn parse_comments_and_blanks() {
        let text = "# comment\n\nmesh_cols = 8 # inline\nmesh_rows = 8\n";
        let cfg = SystemConfig::parse(text).unwrap();
        assert_eq!(cfg.mesh_cols, 8);
    }

    #[test]
    fn validate_rejects_tiny_mesh() {
        let mut c = SystemConfig::default();
        c.mesh_rows = 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn topology_roundtrips_and_defaults_to_mesh() {
        assert_eq!(SystemConfig::default().topology, TopologyKind::Mesh);
        for t in TopologyKind::ALL {
            assert_eq!(TopologyKind::from_name(t.name()), Some(t));
            let mut c = SystemConfig::default();
            c.topology = t;
            assert_eq!(SystemConfig::parse(&c.to_toml()).unwrap().topology, t);
        }
        assert_eq!(TopologyKind::from_name("TORUS"), Some(TopologyKind::Torus));
        assert_eq!(TopologyKind::from_name("nope"), None);
        assert!(SystemConfig::parse("topology = \"hypercube\"").is_err());
    }

    /// The PR-4 bugfix: odd and rectangular grids used to get silently
    /// overlapping quadrant rectangles; through the topology path the MC
    /// partitions are exact for every shape, on every topology.
    #[test]
    fn odd_and_rectangular_grids_partition_exactly() {
        for topology in TopologyKind::ALL {
            for (cols, rows) in [(5, 5), (4, 2), (3, 5), (2, 7)] {
                let mut c = SystemConfig::default();
                c.mesh_cols = cols;
                c.mesh_rows = rows;
                c.topology = topology;
                c.validate().unwrap_or_else(|e| panic!("{topology} {cols}x{rows}: {e}"));
                let mut all: Vec<CubeId> =
                    (0..c.num_mcs()).flat_map(|m| c.mc_nearest_cubes(m)).collect();
                all.sort_unstable();
                assert_eq!(all, (0..cols * rows).collect::<Vec<_>>(), "{topology} {cols}x{rows}");
                for mc in 0..c.num_mcs() {
                    for cube in c.mc_nearest_cubes(mc) {
                        let home = c.cube_home_mc(cube);
                        assert_eq!(home, mc, "{topology} {cols}x{rows} cube {cube}");
                    }
                }
            }
        }
    }

    /// Bubble flow control (wraparound deadlock avoidance) needs a spare
    /// buffer slot; single-slot routers are rejected loudly on torus and
    /// ring, and stay legal on the mesh.
    #[test]
    fn wraparound_requires_two_buffer_slots() {
        for topology in [TopologyKind::Torus, TopologyKind::Ring] {
            let mut c = SystemConfig::default();
            c.topology = topology;
            c.router_buf_cap = 1;
            let err = c.validate().unwrap_err().to_string();
            assert!(err.contains("bubble flow control"), "{topology}: {err}");
            c.router_buf_cap = 2;
            c.validate().unwrap();
        }
        let mut mesh = SystemConfig::default();
        mesh.router_buf_cap = 1;
        mesh.validate().unwrap();
    }
}
