//! Nearest-rank percentiles and the Jain fairness index — the tail
//! metrics behind `aimm serve` (per-tenant slowdown distribution).
//!
//! Mean OPC hides exactly the behaviour tenant churn creates: a few
//! tenants admitted into a congested window can be slowed 10× while the
//! mean barely moves. The serve report therefore leads with p50/p99/p999
//! slowdown and Jain's index, both computed here with integer ranks so
//! the numbers in `BENCH_serve.json` are exact functions of the input
//! vector — no interpolation, no float-accumulation order dependence
//! beyond a single left-to-right sum.

/// Nearest-rank percentile of an **unsorted** sample (the helper sorts a
/// copy). `p` is in percent, e.g. `99.9` for p999. Empty input → 0.0.
///
/// Nearest-rank: rank = ⌈p/100 · n⌉ clamped to `[1, n]`, value =
/// `sorted[rank - 1]`. This is the classic definition (every returned
/// value is an actual sample point), which keeps the known-answer tests
/// hand-checkable and the JSON output free of interpolation artefacts.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("percentile input must not contain NaN"));
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Jain's fairness index: `(Σx)² / (n · Σx²)`, in `(0, 1]` for non-zero
/// inputs — 1.0 when every tenant is slowed equally, `1/n` when one
/// tenant absorbs all the slowdown. Empty or all-zero input → 0.0.
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let sum: f64 = xs.iter().sum();
    let sumsq: f64 = xs.iter().map(|x| x * x).sum();
    if sumsq == 0.0 {
        return 0.0;
    }
    (sum * sum) / (xs.len() as f64 * sumsq)
}

#[cfg(test)]
mod tests {
    use super::*;

    // -- nearest-rank known answers (hand-computed) ---------------------

    #[test]
    fn five_element_known_answers() {
        // Deliberately unsorted input: the helper sorts internally.
        let xs = [30.0, 10.0, 50.0, 20.0, 40.0];
        // n=5: p50 → rank ⌈2.5⌉=3 → 30; p99 → ⌈4.95⌉=5 → 50;
        // p99.9 → ⌈4.995⌉=5 → 50.
        assert_eq!(percentile(&xs, 50.0), 30.0);
        assert_eq!(percentile(&xs, 99.0), 50.0);
        assert_eq!(percentile(&xs, 99.9), 50.0);
        // Extremes: p0 clamps to rank 1, p100 is rank n.
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 50.0);
    }

    #[test]
    fn thousand_element_known_answers() {
        // xs[i] = i+1 so value == rank; 0.50·1000, 0.99·1000 and
        // 0.999·1000 are all exactly representable in f64 (500, 990,
        // 999), so ceil introduces no off-by-one here.
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 500.0);
        assert_eq!(percentile(&xs, 99.0), 990.0);
        assert_eq!(percentile(&xs, 99.9), 999.0);
        assert_eq!(percentile(&xs, 100.0), 1000.0);
    }

    #[test]
    fn empty_and_single_element_edges() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 99.9), 0.0);
        for p in [0.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(percentile(&[42.0], p), 42.0);
        }
    }

    #[test]
    fn percentile_does_not_mutate_input() {
        let xs = vec![3.0, 1.0, 2.0];
        percentile(&xs, 50.0);
        assert_eq!(xs, vec![3.0, 1.0, 2.0]);
    }

    // -- Jain fairness known answers ------------------------------------

    #[test]
    fn jain_known_answers() {
        // Perfect fairness.
        assert_eq!(jain_fairness(&[1.0, 1.0, 1.0, 1.0]), 1.0);
        // One tenant absorbs everything: 1/n.
        assert_eq!(jain_fairness(&[1.0, 0.0, 0.0, 0.0]), 0.25);
        // (2+4)² / (2 · (4+16)) = 36/40 = 0.9 exactly in f64.
        assert_eq!(jain_fairness(&[2.0, 4.0]), 0.9);
        // Scale invariance: Jain(kx) == Jain(x).
        assert_eq!(jain_fairness(&[20.0, 40.0]), 0.9);
    }

    #[test]
    fn jain_edges() {
        assert_eq!(jain_fairness(&[]), 0.0);
        assert_eq!(jain_fairness(&[0.0, 0.0]), 0.0);
        assert_eq!(jain_fairness(&[7.0]), 1.0);
    }

    // -- no float round-trip surprises in the serve report --------------

    #[test]
    fn report_values_survive_the_json_writer_exactly() {
        // The serve report writes these through jw::num; nearest-rank
        // values are actual sample points and Jain on small integer
        // vectors is an exact dyadic/decimal fraction, so the shortest
        // round-trip representation parses back to the identical bits.
        use crate::runtime::json::write as jw;
        for v in [0.9, 0.25, 1.0, 30.0, 999.0] {
            let text = jw::num(v);
            let back: f64 = text.parse().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{text}");
        }
    }
}
