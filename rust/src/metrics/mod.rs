//! Metrics: run statistics feeding every figure of the paper, plus the
//! dynamic-energy and area models of §7.7.

pub mod area;
pub mod energy;
pub mod percentiles;

pub use area::{area_report, AreaItem};
pub use energy::{EnergyBreakdown, EnergyCounts, EnergyModel};
pub use percentiles::{jain_fairness, percentile};

/// End-of-run statistics for one episode.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Execution time in cycles (Fig 6 / 11 / 12).
    pub cycles: u64,
    /// NMP operations completed.
    pub ops_completed: u64,
    /// Sampled operations-per-cycle timeline (Fig 9).
    pub opc_timeline: Vec<f32>,
    /// Average network hop count (Fig 7).
    pub avg_hops: f64,
    /// Average packet latency in cycles.
    pub avg_packet_latency: f64,
    /// Computation utilization: busy-ALU cycles / (cycles × cubes), Fig 7.
    pub compute_utilization: f64,
    /// Coefficient describing how evenly compute spread across cubes
    /// (1 = perfectly even; paper's "computation distribution").
    pub compute_balance: f64,
    /// Distinct pages migrated / distinct pages touched (Fig 10 major axis).
    pub fraction_pages_migrated: f64,
    /// Accesses landing on migrated pages / all accesses (Fig 10 minor).
    pub fraction_accesses_on_migrated: f64,
    /// Pages migrated (absolute).
    pub pages_migrated: u64,
    /// Migration count (can exceed pages when a page moves repeatedly).
    pub migrations: u64,
    /// Average DRAM row-buffer hit rate.
    pub row_hit_rate: f64,
    /// Agent bookkeeping (AIMM runs only).
    pub agent_invocations: u64,
    pub agent_train_steps: u64,
    pub agent_avg_loss: f64,
    pub agent_cumulative_reward: f64,
    /// Dynamic energy breakdown (Fig 14).
    pub energy: EnergyBreakdown,
    /// Per-tenant accounting, populated only by serve mode
    /// (`aimm serve`). Deliberately **not** serialized by
    /// [`crate::bench::sweep::stats_json`]: sweep/episode reports — and
    /// the committed golden fixture pinning their bytes — must not grow
    /// fields. Serve has its own fixed-key-order report.
    pub tenants: Vec<TenantStats>,
}

/// One tenant's lifetime through a serve run (all times in cycles).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TenantStats {
    /// Benchmark name the tenant was drawn as (e.g. `SPMV`).
    pub name: String,
    pub pid: u32,
    /// When the tenant arrived (joined the admission queue).
    pub arrival: u64,
    /// When it was admitted (pages + compute slot leased).
    pub admitted: u64,
    /// When its last op completed (0 if it never finished).
    pub finished: u64,
    /// Ops in its stream.
    pub ops: u64,
    /// Distinct pages it leases while resident.
    pub pages: u64,
}

impl RunStats {
    /// Overall operations per cycle (Fig 8).
    pub fn opc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.ops_completed as f64 / self.cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opc_division() {
        let s = RunStats { cycles: 1000, ops_completed: 250, ..Default::default() };
        assert!((s.opc() - 0.25).abs() < 1e-12);
        let z = RunStats::default();
        assert_eq!(z.opc(), 0.0);
    }
}
