//! Area report — the paper's §7.7 Cacti (45 nm) area estimates for every
//! structure AIMM adds. Printed by `aimm table --fig area`.

/// One hardware structure's area budget.
#[derive(Debug, Clone)]
pub struct AreaItem {
    pub module: &'static str,
    pub structure: &'static str,
    pub size: &'static str,
    pub area_mm2: f64,
    pub energy_nj_per_access: f64,
}

/// The paper's §7.7 inventory.
pub fn area_report() -> Vec<AreaItem> {
    vec![
        AreaItem {
            module: "Information Orchestration",
            structure: "page information cache",
            size: "64KB",
            area_mm2: 0.23,
            energy_nj_per_access: 0.05,
        },
        AreaItem {
            module: "Migration",
            structure: "NMP buffer",
            size: "512B",
            area_mm2: 0.14,
            energy_nj_per_access: 0.122,
        },
        AreaItem {
            module: "Migration",
            structure: "migration queue",
            size: "2KB",
            area_mm2: 0.04,
            energy_nj_per_access: 0.02689,
        },
        AreaItem {
            module: "Migration",
            structure: "MDMA buffers",
            size: "1KB",
            area_mm2: 0.124,
            energy_nj_per_access: 0.1062,
        },
        AreaItem {
            module: "RL Agent",
            structure: "weight matrix",
            size: "603KB",
            area_mm2: 2.095,
            energy_nj_per_access: 0.244,
        },
        AreaItem {
            module: "RL Agent",
            structure: "replay buffer",
            size: "36MB",
            area_mm2: 117.86,
            energy_nj_per_access: 2.3,
        },
        AreaItem {
            module: "RL Agent",
            structure: "state buffer",
            size: "576B",
            area_mm2: 0.12,
            energy_nj_per_access: 0.106,
        },
    ]
}

/// Total added area in mm² (dominated by the replay buffer, as §7.7 notes).
pub fn total_area_mm2() -> f64 {
    area_report().iter().map(|i| i.area_mm2).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_paper_sums() {
        let total = total_area_mm2();
        assert!((total - 120.609).abs() < 0.01, "total {total}");
        assert_eq!(area_report().len(), 7);
    }
}
