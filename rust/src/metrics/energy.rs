//! Dynamic-energy model — the constants are the paper's own Cacti-derived
//! per-access energies (§7.7) plus the published network (5 pJ/bit/hop,
//! Poremba et al.) and memory (12 pJ/bit/access, HMC) figures, so Fig 14
//! is regenerated from event counts exactly the way the paper computes it.

/// Per-access energies in nanojoules (§7.7).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Page information cache (64 KB): 0.05 nJ/access.
    pub page_info_nj: f64,
    /// NMP buffer (512 B): 0.122 nJ/access.
    pub nmp_buffer_nj: f64,
    /// Migration queue (2 KB): 0.02689 nJ/access.
    pub mig_queue_nj: f64,
    /// MDMA buffers (1 KB): 0.1062 nJ/access.
    pub mdma_nj: f64,
    /// RL-agent weight matrix (603 KB): 0.244 nJ/access.
    pub weights_nj: f64,
    /// RL-agent replay buffer (36 MB): 2.3 nJ/access.
    pub replay_nj: f64,
    /// RL-agent state buffer (576 B): 0.106 nJ/access.
    pub state_buf_nj: f64,
    /// Network: 5 pJ/bit/hop.
    pub network_pj_per_bit_hop: f64,
    /// Memory cube: 12 pJ/bit/access.
    pub memory_pj_per_bit: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            page_info_nj: 0.05,
            nmp_buffer_nj: 0.122,
            mig_queue_nj: 0.02689,
            mdma_nj: 0.1062,
            weights_nj: 0.244,
            replay_nj: 2.3,
            state_buf_nj: 0.106,
            network_pj_per_bit_hop: 5.0,
            memory_pj_per_bit: 12.0,
        }
    }
}

/// Raw event counts collected during a run.
#[derive(Debug, Clone, Default)]
pub struct EnergyCounts {
    pub page_info_accesses: u64,
    pub nmp_buffer_accesses: u64,
    pub mig_queue_accesses: u64,
    pub mdma_accesses: u64,
    /// One per layer-traversal per agent inference/train sample.
    pub weight_accesses: u64,
    pub replay_accesses: u64,
    pub state_buf_accesses: u64,
    /// Σ bits × hops over all network traversals.
    pub bit_hops: u64,
    /// Σ bits moved at DRAM banks (64 B per access → 512 bits).
    pub memory_bits: u64,
}

/// Energy totals in nanojoules, by contributor (Fig 14's stacked bars).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub aimm_hardware_nj: f64,
    pub network_nj: f64,
    pub memory_nj: f64,
}

impl EnergyBreakdown {
    pub fn total_nj(&self) -> f64 {
        self.aimm_hardware_nj + self.network_nj + self.memory_nj
    }
}

impl EnergyModel {
    /// Fold raw counts into the Fig 14 breakdown.
    pub fn breakdown(&self, c: &EnergyCounts) -> EnergyBreakdown {
        let aimm = c.page_info_accesses as f64 * self.page_info_nj
            + c.nmp_buffer_accesses as f64 * self.nmp_buffer_nj
            + c.mig_queue_accesses as f64 * self.mig_queue_nj
            + c.mdma_accesses as f64 * self.mdma_nj
            + c.weight_accesses as f64 * self.weights_nj
            + c.replay_accesses as f64 * self.replay_nj
            + c.state_buf_accesses as f64 * self.state_buf_nj;
        let network = c.bit_hops as f64 * self.network_pj_per_bit_hop / 1000.0;
        let memory = c.memory_bits as f64 * self.memory_pj_per_bit / 1000.0;
        EnergyBreakdown { aimm_hardware_nj: aimm, network_nj: network, memory_nj: memory }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_arithmetic() {
        let m = EnergyModel::default();
        let c = EnergyCounts {
            page_info_accesses: 100, // 5 nJ
            bit_hops: 2000,          // 10 nJ
            memory_bits: 1000,       // 12 nJ
            ..Default::default()
        };
        let b = m.breakdown(&c);
        assert!((b.aimm_hardware_nj - 5.0).abs() < 1e-9);
        assert!((b.network_nj - 10.0).abs() < 1e-9);
        assert!((b.memory_nj - 12.0).abs() < 1e-9);
        assert!((b.total_nj() - 27.0).abs() < 1e-9);
    }

    #[test]
    fn replay_buffer_dominates_per_access() {
        let m = EnergyModel::default();
        // Sanity against the paper's table: replay buffer is the most
        // expensive per-access structure.
        assert!(m.replay_nj > m.weights_nj);
        assert!(m.weights_nj > m.page_info_nj);
    }
}
