//! 6-port cube router: 4 network directions, a local (cube) port and an
//! MC port. Three-stage pipeline per hop, per-class input buffering,
//! credit flow control handled by the owning [`Mesh`](super::mesh::Mesh).
//! The same router serves every topology: the torus reuses all four
//! direction ports for its wraparound links, the ring uses only
//! East/West (see [`super::topology`]).

use crate::config::CubeId;
use crate::sim::{BoundedQueue, Cycle};

use super::packet::{Packet, NUM_CLASSES};

/// Router port directions. `Local` ejects/injects at the cube; `Mc` is the
/// dedicated memory-controller port present on corner routers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    North = 0,
    South = 1,
    East = 2,
    West = 3,
    Local = 4,
    Mc = 5,
}

pub const NUM_PORTS: usize = 6;

impl Dir {
    pub fn from_index(i: usize) -> Dir {
        match i {
            0 => Dir::North,
            1 => Dir::South,
            2 => Dir::East,
            3 => Dir::West,
            4 => Dir::Local,
            5 => Dir::Mc,
            _ => panic!("bad port index {i}"),
        }
    }

    /// Input port on the neighbouring router after leaving through `self`.
    pub fn opposite(self) -> Dir {
        match self {
            Dir::North => Dir::South,
            Dir::South => Dir::North,
            Dir::East => Dir::West,
            Dir::West => Dir::East,
            d => d,
        }
    }

    /// Which network dimension the port belongs to: `Some(0)` for X
    /// (East/West), `Some(1)` for Y (North/South), `None` for the
    /// Local/Mc endpoint ports. Bubble flow control compares the input
    /// and output dimensions to detect packets *entering* a wraparound
    /// ring (see `Mesh::try_forward` in [`super::mesh`]).
    pub fn dimension(self) -> Option<usize> {
        match self {
            Dir::East | Dir::West => Some(0),
            Dir::North | Dir::South => Some(1),
            Dir::Local | Dir::Mc => None,
        }
    }
}

/// Per-router state: input queues per (port, class), per-output link
/// serialization bookkeeping, and a round-robin arbitration pointer.
#[derive(Debug)]
pub struct Router {
    pub cube: CubeId,
    /// Input buffers, indexed `[port][class]`.
    pub in_q: Vec<[BoundedQueue<Packet>; NUM_CLASSES]>,
    /// Cycle until which each output link is serializing a packet.
    pub link_busy_until: [Cycle; NUM_PORTS],
    /// Round-robin start port for switch allocation fairness.
    pub rr: usize,
    /// Credits reserved by packets already in flight toward each
    /// `[port][class]` input buffer of *this* router.
    pub reserved: [[u32; NUM_CLASSES]; NUM_PORTS],
    /// Cached total buffered packets (fast-skip for idle routers).
    pub buffered_count: u32,
    /// Bitmask of non-empty input queues: bit `port * NUM_CLASSES + class`.
    pub occupied: u16,
}

impl Router {
    pub fn new(cube: CubeId, buf_cap: usize) -> Self {
        let in_q = (0..NUM_PORTS)
            .map(|_| [BoundedQueue::new(buf_cap), BoundedQueue::new(buf_cap)])
            .collect();
        Self {
            cube,
            in_q,
            link_busy_until: [0; NUM_PORTS],
            rr: 0,
            reserved: [[0; NUM_CLASSES]; NUM_PORTS],
            buffered_count: 0,
            occupied: 0,
        }
    }

    /// Free buffer slots for a given input port/class, accounting for
    /// in-flight reservations (credit check).
    pub fn free_slots(&self, port: usize, class: usize) -> u32 {
        let q = &self.in_q[port][class];
        let used = q.len() as u32 + self.reserved[port][class];
        (q.capacity() as u32).saturating_sub(used)
    }

    /// Total buffered packets (for congestion metrics).
    pub fn buffered(&self) -> usize {
        self.in_q.iter().flat_map(|p| p.iter()).map(|q| q.len()).sum()
    }

    #[inline]
    pub fn mark_queue(&mut self, port: usize, class: usize) {
        self.occupied |= 1 << (port * NUM_CLASSES + class);
    }

    #[inline]
    pub fn unmark_if_empty(&mut self, port: usize, class: usize) {
        if self.in_q[port][class].is_empty() {
            self.occupied &= !(1 << (port * NUM_CLASSES + class));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opposite_directions() {
        assert_eq!(Dir::North.opposite(), Dir::South);
        assert_eq!(Dir::East.opposite(), Dir::West);
        assert_eq!(Dir::Local.opposite(), Dir::Local);
    }

    #[test]
    fn dimensions_partition_the_ports() {
        assert_eq!(Dir::East.dimension(), Some(0));
        assert_eq!(Dir::West.dimension(), Some(0));
        assert_eq!(Dir::North.dimension(), Some(1));
        assert_eq!(Dir::South.dimension(), Some(1));
        assert_eq!(Dir::Local.dimension(), None);
        assert_eq!(Dir::Mc.dimension(), None);
    }

    #[test]
    fn credit_accounting() {
        let mut r = Router::new(0, 4);
        assert_eq!(r.free_slots(0, 0), 4);
        r.reserved[0][0] = 3;
        assert_eq!(r.free_slots(0, 0), 1);
        r.reserved[0][0] = 9; // over-reservation must saturate, not wrap
        assert_eq!(r.free_slots(0, 0), 0);
    }
}
