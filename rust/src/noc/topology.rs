//! Pluggable cube-network topologies.
//!
//! The paper evaluates AIMM on one fixed interconnect — a 2D mesh with
//! four corner-attached memory controllers (Table 1) — but its premise
//! is a *scalable memory-cube network*, and the learned remapping only
//! becomes interesting where hop-distance structure varies. This module
//! owns every geometric fact the rest of the simulator needs, behind the
//! [`Topology`] trait:
//!
//! * node coordinates and labels ([`Topology::coords`] / [`Topology::node_at`]),
//! * the physical link set ([`Topology::neighbor`] / [`Topology::neighbors`]),
//! * deterministic minimal routing ([`Topology::route`]) and
//!   [`Topology::hop_distance`],
//! * the "far" target of the agent's FarData/FarCompute actions
//!   ([`Topology::distant_cube`] — the mesh's diagonal opposite,
//!   generalized),
//! * MC placement: attach points ([`Topology::mc_attach_cube`]), the
//!   "nearest cubes" partition each MC aggregates counters over
//!   ([`Topology::mc_nearest_cubes`], paper §5.1), and the inverse map
//!   ([`Topology::cube_home_mc`]).
//!
//! Three implementations ship:
//!
//! * [`Mesh2D`] — the paper's network, bit-identical to the pre-topology
//!   simulator (the sweep golden fixture and the engine-equivalence grid
//!   both pin this),
//! * [`Torus2D`] — the mesh plus wraparound links: per-dimension diameter
//!   halves, so remapping pressure drops,
//! * [`Ring`] — all cubes on one cycle: the worst-case-diameter stress
//!   topology for scale-out studies.
//!
//! [`AnyTopology`] is the `Copy` enum the fabric and the config carry;
//! construction goes through [`AnyTopology::of`] /
//! [`SystemConfig::topology_obj`](crate::config::SystemConfig::topology_obj).
//!
//! ## Determinism
//!
//! Every method is a pure function of (kind, cols, rows) and its
//! arguments. Tie-breaks are fixed: torus routing prefers East/South when
//! both orientations of a dimension are equidistant, the ring prefers its
//! East (increasing-id) orientation. No RNG, no iteration over hash maps
//! — the sweep-determinism and golden-fixture tests depend on this.
//!
//! ## Deadlock freedom
//!
//! Dimension-ordered (XY) routing on the mesh is deadlock-free as is.
//! Wraparound links add cyclic channel dependencies *within* a dimension,
//! which the fabric breaks with bubble flow control (a packet may only
//! enter a dimension ring if it leaves a free slot behind — see
//! `Mesh::try_forward` in [`super::mesh`]); [`Topology::wraparound`]
//! tells the fabric whether that rule is needed, and
//! [`crate::config::SystemConfig::validate`] enforces the
//! `router_buf_cap >= 2` it requires.

use crate::config::{CubeId, McId, SystemConfig, TopologyKind};

use super::router::Dir;

/// Number of memory controllers — fixed at the paper's 4 CMP corners for
/// every topology (the *placement* of those 4 varies per topology).
pub const NUM_MCS: usize = 4;

/// Geometric contract of a cube network. Implementations must be pure:
/// same inputs, same outputs, forever (see the module docs on
/// determinism).
pub trait Topology {
    /// Which variant this is (for labels, reports and dispatch).
    fn kind(&self) -> TopologyKind;

    /// Total number of cubes (= routers).
    fn num_nodes(&self) -> usize;

    /// Grid label of a node: `(x, y)` with `id = y * cols + x`. The ring
    /// keeps the same row-major labelling; only its *links* differ.
    fn coords(&self, node: CubeId) -> (usize, usize);

    /// Inverse of [`coords`](Self::coords).
    fn node_at(&self, x: usize, y: usize) -> CubeId;

    /// The node reached by leaving `node` through port `dir`, if that
    /// physical link exists. `Local`/`Mc` ports never lead anywhere.
    fn neighbor(&self, node: CubeId, dir: Dir) -> Option<CubeId>;

    /// All link neighbours of `node`, in fixed North, South, West, East
    /// port order (matching the pre-topology mesh helper — the agent's
    /// NearData action draws from this list by index, so the order is
    /// part of the determinism contract). Duplicates collapse: on a
    /// 2-wide torus dimension both orientations reach the same node.
    fn neighbors(&self, node: CubeId) -> Vec<CubeId> {
        let mut out = Vec::with_capacity(4);
        for dir in [Dir::North, Dir::South, Dir::West, Dir::East] {
            if let Some(n) = self.neighbor(node, dir) {
                if !out.contains(&n) {
                    out.push(n);
                }
            }
        }
        out
    }

    /// Output port at `at` for a packet headed to `dst`, `at != dst`.
    /// Must be minimal (following it from any `at` reaches `dst` in
    /// exactly [`hop_distance`](Self::hop_distance) hops) and
    /// deterministic.
    fn route(&self, at: CubeId, dst: CubeId) -> Dir;

    /// Minimal hop count between two routers.
    fn hop_distance(&self, a: CubeId, b: CubeId) -> u32;

    /// Largest [`hop_distance`](Self::hop_distance) over all node pairs.
    fn diameter(&self) -> u32;

    /// The "far" cube the agent's FarData/FarCompute actions target. On
    /// the mesh this is the paper's definition — the diagonal opposite
    /// of the 2D array (diameter-distant from the corners, the
    /// array-wide reflection elsewhere); on the vertex-transitive torus
    /// and ring it is a diameter-distant cube from every node.
    fn distant_cube(&self, from: CubeId) -> CubeId;

    /// Whether any link wraps around (torus/ring): the fabric then
    /// applies bubble flow control (module docs).
    fn wraparound(&self) -> bool;

    /// Number of memory controllers (fixed at [`NUM_MCS`]).
    fn num_mcs(&self) -> usize {
        NUM_MCS
    }

    /// The cube whose router MC `mc` hangs off.
    fn mc_attach_cube(&self, mc: McId) -> CubeId;

    /// The MC that owns `cube`: the target of its periodic occupancy /
    /// row-hit reports (paper §5.1 "communicated to a cube's nearest
    /// memory controller periodically").
    fn cube_home_mc(&self, cube: CubeId) -> McId;

    /// The cubes MC `mc` aggregates counters over, in ascending cube-id
    /// order. Derived from [`cube_home_mc`](Self::cube_home_mc), so it is
    /// an exact partition for *any* dimensions — including odd and
    /// rectangular ones, where the seed simulator's standalone quadrant
    /// rectangles silently overlapped.
    fn mc_nearest_cubes(&self, mc: McId) -> Vec<CubeId> {
        (0..self.num_nodes()).filter(|&c| self.cube_home_mc(c) == mc).collect()
    }
}

/// The paper's 2D mesh: bounds-checked links, XY routing, MCs on the four
/// corner cubes, quadrant "nearest cubes" partitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mesh2D {
    cols: usize,
    rows: usize,
}

impl Mesh2D {
    pub fn new(cols: usize, rows: usize) -> Self {
        Self { cols, rows }
    }
}

impl Topology for Mesh2D {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Mesh
    }

    fn num_nodes(&self) -> usize {
        self.cols * self.rows
    }

    fn coords(&self, node: CubeId) -> (usize, usize) {
        (node % self.cols, node / self.cols)
    }

    fn node_at(&self, x: usize, y: usize) -> CubeId {
        y * self.cols + x
    }

    fn neighbor(&self, node: CubeId, dir: Dir) -> Option<CubeId> {
        let (x, y) = self.coords(node);
        match dir {
            Dir::North if y > 0 => Some(self.node_at(x, y - 1)),
            Dir::South if y + 1 < self.rows => Some(self.node_at(x, y + 1)),
            Dir::West if x > 0 => Some(self.node_at(x - 1, y)),
            Dir::East if x + 1 < self.cols => Some(self.node_at(x + 1, y)),
            _ => None,
        }
    }

    /// Dimension-ordered XY: resolve the X offset first, then Y —
    /// byte-identical to the pre-topology `Mesh::route`.
    fn route(&self, at: CubeId, dst: CubeId) -> Dir {
        debug_assert_ne!(at, dst, "route called at the destination router");
        let (x, y) = self.coords(at);
        let (dx, dy) = self.coords(dst);
        if x < dx {
            Dir::East
        } else if x > dx {
            Dir::West
        } else if y < dy {
            Dir::South
        } else {
            Dir::North
        }
    }

    fn hop_distance(&self, a: CubeId, b: CubeId) -> u32 {
        let (ax, ay) = self.coords(a);
        let (bx, by) = self.coords(b);
        (ax.abs_diff(bx) + ay.abs_diff(by)) as u32
    }

    fn diameter(&self) -> u32 {
        (self.cols - 1 + self.rows - 1) as u32
    }

    /// Diagonal opposite in the 2D array (the paper's "far" target).
    fn distant_cube(&self, from: CubeId) -> CubeId {
        let (x, y) = self.coords(from);
        self.node_at(self.cols - 1 - x, self.rows - 1 - y)
    }

    fn wraparound(&self) -> bool {
        false
    }

    /// MCs at the four corner cubes (Table 1).
    fn mc_attach_cube(&self, mc: McId) -> CubeId {
        let (c, r) = (self.cols, self.rows);
        match mc {
            0 => 0,
            1 => c - 1,
            2 => (r - 1) * c,
            3 => r * c - 1,
            _ => panic!("mc index out of range: {mc}"),
        }
    }

    /// Quadrant of the attach corner: left/right split at `cols / 2`,
    /// top/bottom at `rows / 2` (for even dimensions this reproduces the
    /// seed simulator's rectangles exactly; for odd dimensions the
    /// right/bottom quadrants take the middle row/column).
    fn cube_home_mc(&self, cube: CubeId) -> McId {
        let (x, y) = self.coords(cube);
        let right = x >= self.cols / 2;
        let bottom = y >= self.rows / 2;
        match (right, bottom) {
            (false, false) => 0,
            (true, false) => 1,
            (false, true) => 2,
            (true, true) => 3,
        }
    }
}

/// The mesh plus wraparound links in both dimensions: every router has
/// all four neighbours, per-dimension distance wraps, diameter halves.
/// MC placement and quadrant partitions match [`Mesh2D`] so mesh↔torus
/// comparisons isolate the link set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Torus2D {
    /// MC placement and labelling are shared with the mesh.
    grid: Mesh2D,
}

impl Torus2D {
    pub fn new(cols: usize, rows: usize) -> Self {
        Self { grid: Mesh2D::new(cols, rows) }
    }

    fn cols(&self) -> usize {
        self.grid.cols
    }

    fn rows(&self) -> usize {
        self.grid.rows
    }
}

impl Topology for Torus2D {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Torus
    }

    fn num_nodes(&self) -> usize {
        self.grid.num_nodes()
    }

    fn coords(&self, node: CubeId) -> (usize, usize) {
        self.grid.coords(node)
    }

    fn node_at(&self, x: usize, y: usize) -> CubeId {
        self.grid.node_at(x, y)
    }

    fn neighbor(&self, node: CubeId, dir: Dir) -> Option<CubeId> {
        let (c, r) = (self.cols(), self.rows());
        let (x, y) = self.coords(node);
        match dir {
            Dir::North => Some(self.node_at(x, (y + r - 1) % r)),
            Dir::South => Some(self.node_at(x, (y + 1) % r)),
            Dir::West => Some(self.node_at((x + c - 1) % c, y)),
            Dir::East => Some(self.node_at((x + 1) % c, y)),
            _ => None,
        }
    }

    /// Dimension-ordered XY with per-dimension shortest orientation;
    /// equidistant wraps tie-break East/South (fixed, so routes are
    /// deterministic).
    fn route(&self, at: CubeId, dst: CubeId) -> Dir {
        debug_assert_ne!(at, dst, "route called at the destination router");
        let (c, r) = (self.cols(), self.rows());
        let (x, y) = self.coords(at);
        let (dx, dy) = self.coords(dst);
        if x != dx {
            let east = (dx + c - x) % c;
            let west = (x + c - dx) % c;
            if east <= west {
                Dir::East
            } else {
                Dir::West
            }
        } else {
            let south = (dy + r - y) % r;
            let north = (y + r - dy) % r;
            if south <= north {
                Dir::South
            } else {
                Dir::North
            }
        }
    }

    fn hop_distance(&self, a: CubeId, b: CubeId) -> u32 {
        let (c, r) = (self.cols(), self.rows());
        let (ax, ay) = self.coords(a);
        let (bx, by) = self.coords(b);
        let dx = ax.abs_diff(bx);
        let dy = ay.abs_diff(by);
        (dx.min(c - dx) + dy.min(r - dy)) as u32
    }

    fn diameter(&self) -> u32 {
        (self.cols() / 2 + self.rows() / 2) as u32
    }

    /// Half a wrap in each dimension — a maximally distant node.
    fn distant_cube(&self, from: CubeId) -> CubeId {
        let (c, r) = (self.cols(), self.rows());
        let (x, y) = self.coords(from);
        self.node_at((x + c / 2) % c, (y + r / 2) % r)
    }

    fn wraparound(&self) -> bool {
        true
    }

    fn mc_attach_cube(&self, mc: McId) -> CubeId {
        self.grid.mc_attach_cube(mc)
    }

    fn cube_home_mc(&self, cube: CubeId) -> McId {
        self.grid.cube_home_mc(cube)
    }
}

/// All cubes on a single cycle in id order: node `i` links East to
/// `i + 1 (mod n)` and West to `i - 1 (mod n)`. The diameter grows as
/// `n / 2` — the stress case for hop-sensitive mapping. MCs sit at the
/// four quarter points and own the contiguous arc of cubes *nearest*
/// their attach point (ring distance, ties to the lower MC id) — the
/// §5.1 "nearest memory controller" contract, literally.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ring {
    nodes: usize,
    /// Retained only for the row-major `coords` labelling.
    cols: usize,
}

impl Ring {
    pub fn new(cols: usize, rows: usize) -> Self {
        Self { nodes: cols * rows, cols }
    }

    /// MC `mc`'s attach cube: the quarter points `mc * n / 4`, rounded
    /// down — distinct for every `n >= 4`, which
    /// `SystemConfig::validate` guarantees via the 2×2 minimum.
    fn attach(&self, mc: McId) -> CubeId {
        assert!(mc < NUM_MCS, "mc index out of range: {mc}");
        mc * self.nodes / NUM_MCS
    }
}

impl Topology for Ring {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Ring
    }

    fn num_nodes(&self) -> usize {
        self.nodes
    }

    fn coords(&self, node: CubeId) -> (usize, usize) {
        (node % self.cols, node / self.cols)
    }

    fn node_at(&self, x: usize, y: usize) -> CubeId {
        y * self.cols + x
    }

    fn neighbor(&self, node: CubeId, dir: Dir) -> Option<CubeId> {
        let n = self.nodes;
        match dir {
            Dir::East => Some((node + 1) % n),
            Dir::West => Some((node + n - 1) % n),
            _ => None,
        }
    }

    /// Shortest way around; equidistant (diametrically opposite on an
    /// even ring) tie-breaks East.
    fn route(&self, at: CubeId, dst: CubeId) -> Dir {
        debug_assert_ne!(at, dst, "route called at the destination router");
        let n = self.nodes;
        let east = (dst + n - at) % n;
        let west = n - east;
        if east <= west {
            Dir::East
        } else {
            Dir::West
        }
    }

    fn hop_distance(&self, a: CubeId, b: CubeId) -> u32 {
        let n = self.nodes;
        let d = (b + n - a) % n;
        d.min(n - d) as u32
    }

    fn diameter(&self) -> u32 {
        (self.nodes / 2) as u32
    }

    /// Halfway around the cycle.
    fn distant_cube(&self, from: CubeId) -> CubeId {
        (from + self.nodes / 2) % self.nodes
    }

    fn wraparound(&self) -> bool {
        true
    }

    fn mc_attach_cube(&self, mc: McId) -> CubeId {
        self.attach(mc)
    }

    /// The MC with the smallest ring distance to its attach cube; an
    /// equidistant tie (exactly between two quarter points) goes to the
    /// lower MC id. Each MC's set is a contiguous arc centred on its
    /// attach, so reports travel at most ~n/8 hops instead of up to
    /// n/4 − 1 under a start-of-arc assignment.
    fn cube_home_mc(&self, cube: CubeId) -> McId {
        let mut best = 0;
        let mut best_d = u32::MAX;
        for mc in 0..NUM_MCS {
            let d = self.hop_distance(cube, self.attach(mc));
            if d < best_d {
                best = mc;
                best_d = d;
            }
        }
        best
    }
}

/// The topology object carried by the fabric and the config: a `Copy`
/// enum (no allocation on construction) dispatching to the three
/// implementations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnyTopology {
    Mesh(Mesh2D),
    Torus(Torus2D),
    Ring(Ring),
}

impl AnyTopology {
    pub fn new(kind: TopologyKind, cols: usize, rows: usize) -> Self {
        match kind {
            TopologyKind::Mesh => AnyTopology::Mesh(Mesh2D::new(cols, rows)),
            TopologyKind::Torus => AnyTopology::Torus(Torus2D::new(cols, rows)),
            TopologyKind::Ring => AnyTopology::Ring(Ring::new(cols, rows)),
        }
    }

    /// The topology a configuration describes.
    pub fn of(cfg: &SystemConfig) -> Self {
        Self::new(cfg.topology, cfg.mesh_cols, cfg.mesh_rows)
    }

}

/// Static dispatch to the concrete variant — `route`/`neighbor`/
/// `wraparound` sit on the per-packet forwarding hot path, so the match
/// (fully inlinable) beats a `&dyn Topology` vtable hop.
macro_rules! dispatch {
    ($self:ident . $($call:tt)*) => {
        match $self {
            AnyTopology::Mesh(t) => t.$($call)*,
            AnyTopology::Torus(t) => t.$($call)*,
            AnyTopology::Ring(t) => t.$($call)*,
        }
    };
}

impl Topology for AnyTopology {
    fn kind(&self) -> TopologyKind {
        dispatch!(self.kind())
    }

    fn num_nodes(&self) -> usize {
        dispatch!(self.num_nodes())
    }

    fn coords(&self, node: CubeId) -> (usize, usize) {
        dispatch!(self.coords(node))
    }

    fn node_at(&self, x: usize, y: usize) -> CubeId {
        dispatch!(self.node_at(x, y))
    }

    fn neighbor(&self, node: CubeId, dir: Dir) -> Option<CubeId> {
        dispatch!(self.neighbor(node, dir))
    }

    fn neighbors(&self, node: CubeId) -> Vec<CubeId> {
        dispatch!(self.neighbors(node))
    }

    fn route(&self, at: CubeId, dst: CubeId) -> Dir {
        dispatch!(self.route(at, dst))
    }

    fn hop_distance(&self, a: CubeId, b: CubeId) -> u32 {
        dispatch!(self.hop_distance(a, b))
    }

    fn diameter(&self) -> u32 {
        dispatch!(self.diameter())
    }

    fn distant_cube(&self, from: CubeId) -> CubeId {
        dispatch!(self.distant_cube(from))
    }

    fn wraparound(&self) -> bool {
        dispatch!(self.wraparound())
    }

    fn mc_attach_cube(&self, mc: McId) -> CubeId {
        dispatch!(self.mc_attach_cube(mc))
    }

    fn cube_home_mc(&self, cube: CubeId) -> McId {
        dispatch!(self.cube_home_mc(cube))
    }

    fn mc_nearest_cubes(&self, mc: McId) -> Vec<CubeId> {
        dispatch!(self.mc_nearest_cubes(mc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_kinds(cols: usize, rows: usize) -> [AnyTopology; 3] {
        [
            AnyTopology::new(TopologyKind::Mesh, cols, rows),
            AnyTopology::new(TopologyKind::Torus, cols, rows),
            AnyTopology::new(TopologyKind::Ring, cols, rows),
        ]
    }

    /// Walk `route` from `a` to `b`, asserting minimality.
    fn walk(t: &AnyTopology, a: CubeId, b: CubeId) -> u32 {
        let mut at = a;
        let mut hops = 0;
        while at != b {
            let dir = t.route(at, b);
            at = t.neighbor(at, dir).expect("route must follow an existing link");
            hops += 1;
            assert!(hops <= t.diameter(), "{:?}: {a}->{b} not minimal", t.kind());
        }
        hops
    }

    #[test]
    fn routing_is_minimal_on_every_kind_and_shape() {
        for (c, r) in [(4, 4), (3, 5), (8, 8), (2, 2)] {
            for t in all_kinds(c, r) {
                for a in 0..t.num_nodes() {
                    for b in 0..t.num_nodes() {
                        if a != b {
                            assert_eq!(
                                walk(&t, a, b),
                                t.hop_distance(a, b),
                                "{:?} {c}x{r}: {a}->{b}",
                                t.kind()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mesh_matches_pre_refactor_helpers_on_4x4() {
        let t = AnyTopology::new(TopologyKind::Mesh, 4, 4);
        // Corner-to-corner Manhattan distance and the diagonal opposite,
        // as pinned by the seed simulator's tests.
        assert_eq!(t.hop_distance(0, 15), 6);
        assert_eq!(t.distant_cube(0), 15);
        assert_eq!(t.distant_cube(5), 10);
        // Neighbour sets in N, S, W, E order.
        assert_eq!(t.neighbors(0), vec![4, 1]);
        assert_eq!(t.neighbors(1), vec![5, 0, 2]);
        assert_eq!(t.neighbors(5), vec![1, 9, 4, 6]);
        // Corner MC attach + quadrants.
        assert_eq!((0..4).map(|m| t.mc_attach_cube(m)).collect::<Vec<_>>(), vec![0, 3, 12, 15]);
        assert_eq!(t.mc_nearest_cubes(0), vec![0, 1, 4, 5]);
        assert_eq!(t.mc_nearest_cubes(3), vec![10, 11, 14, 15]);
        assert_eq!(t.diameter(), 6);
        assert!(!t.wraparound());
    }

    #[test]
    fn torus_wraps_and_halves_the_diameter() {
        let t = AnyTopology::new(TopologyKind::Torus, 4, 4);
        // Corner to corner is two wraparound hops, not six.
        assert_eq!(t.hop_distance(0, 15), 2);
        assert_eq!(t.diameter(), 4);
        assert!(t.wraparound());
        // Every router has all four neighbours.
        for n in 0..16 {
            assert_eq!(t.neighbors(n).len(), 4, "node {n}");
        }
        // Wraparound links exist.
        assert_eq!(t.neighbor(0, Dir::West), Some(3));
        assert_eq!(t.neighbor(0, Dir::North), Some(12));
        // The far target is half a wrap in each dimension.
        assert_eq!(t.distant_cube(0), 10);
        assert_eq!(t.distant_cube(10), 0, "even torus: distant is an involution");
    }

    #[test]
    fn ring_is_a_single_cycle() {
        let t = AnyTopology::new(TopologyKind::Ring, 4, 4);
        assert_eq!(t.neighbor(15, Dir::East), Some(0));
        assert_eq!(t.neighbor(0, Dir::West), Some(15));
        assert_eq!(t.neighbor(0, Dir::North), None, "ring has no Y links");
        assert_eq!(t.neighbors(0), vec![15, 1]);
        assert_eq!(t.hop_distance(0, 15), 1);
        assert_eq!(t.hop_distance(0, 8), 8);
        assert_eq!(t.diameter(), 8);
        assert_eq!(t.distant_cube(0), 8);
        assert_eq!(t.distant_cube(3), 11);
        assert!(t.wraparound());
        // MCs at the quarter points, owning the contiguous arc centred
        // on their attach cube (equidistant ties → lower MC id: cube 2
        // sits 2 hops from both attach 0 and attach 4 and goes to MC 0).
        assert_eq!((0..4).map(|m| t.mc_attach_cube(m)).collect::<Vec<_>>(), vec![0, 4, 8, 12]);
        assert_eq!(t.mc_nearest_cubes(0), vec![0, 1, 2, 14, 15]);
        assert_eq!(t.mc_nearest_cubes(1), vec![3, 4, 5, 6]);
        assert_eq!(t.mc_nearest_cubes(3), vec![11, 12, 13]);
    }

    /// The §5.1 contract, literally: a ring cube reports to the MC whose
    /// attach point is at minimal ring distance.
    #[test]
    fn ring_homes_cubes_to_their_nearest_attach() {
        for (c, r) in [(4, 4), (3, 5), (8, 8)] {
            let t = AnyTopology::new(TopologyKind::Ring, c, r);
            for cube in 0..t.num_nodes() {
                let home_d =
                    t.hop_distance(cube, t.mc_attach_cube(t.cube_home_mc(cube)));
                let min_d = (0..4)
                    .map(|m| t.hop_distance(cube, t.mc_attach_cube(m)))
                    .min()
                    .unwrap();
                assert_eq!(home_d, min_d, "{c}x{r} cube {cube}");
            }
        }
    }

    #[test]
    fn route_tiebreaks_are_fixed() {
        // Torus 4 wide: x offset of exactly 2 can go either way — East wins.
        let t = AnyTopology::new(TopologyKind::Torus, 4, 4);
        assert_eq!(t.route(0, 2), Dir::East);
        assert_eq!(t.route(0, 8), Dir::South);
        // Even ring: the diametric opposite tie-breaks East.
        let r = AnyTopology::new(TopologyKind::Ring, 4, 4);
        assert_eq!(r.route(0, 8), Dir::East);
        assert_eq!(r.route(0, 9), Dir::West);
    }

    #[test]
    fn nearest_cubes_partition_every_kind_and_shape() {
        // Includes the odd and rectangular shapes whose pre-topology
        // quadrant rectangles overlapped (the PR-4 bugfix).
        for (c, r) in [(4, 4), (5, 5), (4, 2), (3, 5), (2, 7), (8, 8)] {
            for t in all_kinds(c, r) {
                let mut all: Vec<CubeId> =
                    (0..4).flat_map(|m| t.mc_nearest_cubes(m)).collect();
                all.sort_unstable();
                assert_eq!(
                    all,
                    (0..c * r).collect::<Vec<_>>(),
                    "{:?} {c}x{r}: nearest sets must partition the cubes",
                    t.kind()
                );
                for mc in 0..4 {
                    for cube in t.mc_nearest_cubes(mc) {
                        assert_eq!(t.cube_home_mc(cube), mc, "{:?} {c}x{r} cube {cube}", t.kind());
                    }
                    assert!(
                        t.mc_nearest_cubes(mc).contains(&t.mc_attach_cube(mc)),
                        "{:?} {c}x{r}: MC {mc} must own its attach cube",
                        t.kind()
                    );
                }
            }
        }
    }

    #[test]
    fn distant_cube_reaches_far() {
        // Torus and ring are vertex-transitive: the far target attains
        // the diameter from *every* node.
        for kind in [TopologyKind::Torus, TopologyKind::Ring] {
            let t = AnyTopology::new(kind, 4, 4);
            for n in 0..t.num_nodes() {
                assert_eq!(
                    t.hop_distance(n, t.distant_cube(n)),
                    t.diameter(),
                    "{kind:?} node {n}"
                );
            }
        }
        // The mesh's far target is the array-wide diagonal reflection
        // (the paper's definition): it attains the diameter from the
        // corners, and from an interior node it is the reflection, not
        // a diameter-distance node.
        let m = AnyTopology::new(TopologyKind::Mesh, 4, 4);
        for corner in [0, 3, 12, 15] {
            assert_eq!(m.hop_distance(corner, m.distant_cube(corner)), m.diameter());
        }
        assert_eq!(m.distant_cube(5), 10);
    }

    #[test]
    fn coords_roundtrip_on_all_kinds() {
        for t in all_kinds(3, 5) {
            for n in 0..t.num_nodes() {
                let (x, y) = t.coords(n);
                assert_eq!(t.node_at(x, y), n, "{:?}", t.kind());
            }
        }
    }

    #[test]
    fn neighbors_deduplicate_on_two_wide_wraps() {
        // 2-wide torus dimensions: East/West (and North/South) reach the
        // same node, which must appear once, not twice.
        let t = AnyTopology::new(TopologyKind::Torus, 2, 2);
        assert_eq!(t.neighbors(0), vec![2, 1]);
        assert_eq!(t.neighbors(3), vec![1, 2]);
    }
}
