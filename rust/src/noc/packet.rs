//! Packet model for the memory-cube network.
//!
//! Packets are the unit of switching; serialization over the 128-bit links
//! is charged as `ceil(size_bits / link_bits)` cycles of link occupancy per
//! hop. Payloads carry the simulation-level protocol: NMP-op dispatch,
//! operand fetches, write-backs, ACKs, and migration DMA traffic. The
//! vocabulary is topology-neutral — a packet names endpoints
//! ([`NodeId`]), never links; which wires it rides is decided hop by hop
//! by the fabric's routing function ([`super::topology`]).

use crate::config::{CubeId, McId, VAddr};
use crate::cube::PhysAddr;
use crate::sim::Cycle;

/// Endpoint of the network: a memory cube or a memory controller (MCs hang
/// off their attach cube's router through a dedicated port — corners on
/// mesh/torus, quarter points on the ring).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NodeId {
    Cube(CubeId),
    Mc(McId),
}

/// Request/response separation — disjoint buffer pools per class prevent
/// protocol deadlock (the paper's 5-VC routers serve the same purpose).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TrafficClass {
    Req = 0,
    Resp = 1,
}

pub const NUM_CLASSES: usize = 2;

/// Unique id of an in-flight NMP operation (assigned by the issuing MC).
pub type OpToken = u64;
/// Unique id of a migration job (assigned by the migration system).
pub type MigToken = u64;

/// Protocol payloads.
#[derive(Debug, Clone)]
pub enum Payload {
    /// MC → compute cube: start an NMP op. Operand physical addresses are
    /// resolved by the MC (post V→P translation and remapping decisions).
    NmpDispatch {
        token: OpToken,
        dest: PhysAddr,
        src1: PhysAddr,
        /// `None` for single-operand ops (e.g. reductions feeding an
        /// accumulator page, or PEI ops whose other operand rode along).
        src2: Option<PhysAddr>,
        /// Number of operands already satisfied at dispatch (PEI carries a
        /// cache-hit operand inline).
        carried_operands: u8,
        /// Virtual page of the destination, for page-info accounting.
        dest_vpage: VAddr,
    },
    /// Compute cube → source cube: fetch an operand.
    SourceReq { token: OpToken, addr: PhysAddr, reply_to: CubeId },
    /// Source cube → compute cube: operand data.
    SourceResp { token: OpToken, addr: PhysAddr },
    /// Compute cube → destination cube: write back a remotely-computed
    /// result (LDB and remapped-compute paths).
    WriteReq { token: OpToken, addr: PhysAddr, reply_to: CubeId },
    /// Destination cube → compute cube: write completed.
    WriteAck { token: OpToken },
    /// Compute cube → issuing MC: op finished (carries network latency
    /// info the MC folds into the page-info cache, §5.1).
    NmpAck { token: OpToken, compute_cube: CubeId },
    /// MDMA → old host cube: read one migration chunk.
    MigRead { token: MigToken, chunk: u32, old: CubeId, new: CubeId },
    /// Old host cube → new host cube: one chunk of page data.
    MigChunk { token: MigToken, chunk: u32, new: CubeId },
    /// New host cube → MDMA: chunk landed.
    MigChunkAck { token: MigToken, chunk: u32 },
}

impl Payload {
    /// Traffic class for deadlock-free buffer separation.
    pub fn class(&self) -> TrafficClass {
        match self {
            Payload::NmpDispatch { .. }
            | Payload::SourceReq { .. }
            | Payload::WriteReq { .. }
            | Payload::MigRead { .. }
            | Payload::MigChunk { .. } => TrafficClass::Req,
            Payload::SourceResp { .. }
            | Payload::WriteAck { .. }
            | Payload::NmpAck { .. }
            | Payload::MigChunkAck { .. } => TrafficClass::Resp,
        }
    }

    /// Packet size in bits: header (128) plus any data beat.
    /// Operand/result transfers move a 64 B beat (512 bits); migration
    /// chunks move 256 B (2048 bits).
    pub fn size_bits(&self) -> u64 {
        const HDR: u64 = 128;
        match self {
            Payload::NmpDispatch { carried_operands, .. } => {
                HDR + 128 + (*carried_operands as u64) * 512
            }
            Payload::SourceReq { .. } => HDR,
            Payload::SourceResp { .. } => HDR + 512,
            Payload::WriteReq { .. } => HDR + 512,
            Payload::WriteAck { .. } => HDR,
            Payload::NmpAck { .. } => HDR,
            Payload::MigRead { .. } => HDR,
            Payload::MigChunk { .. } => HDR + 2048,
            Payload::MigChunkAck { .. } => HDR,
        }
    }
}

/// A packet in flight through the mesh.
#[derive(Debug, Clone)]
pub struct Packet {
    pub id: u64,
    pub src: NodeId,
    pub dst: NodeId,
    pub payload: Payload,
    pub size_bits: u64,
    pub injected_at: Cycle,
    /// Cycle this packet entered its current router input buffer
    /// (queue-wait accounting).
    pub queued_at: Cycle,
    pub hops: u32,
}

impl Packet {
    pub fn new(id: u64, src: NodeId, dst: NodeId, payload: Payload, now: Cycle) -> Self {
        let size_bits = payload.size_bits();
        Self { id, src, dst, payload, size_bits, injected_at: now, queued_at: now, hops: 0 }
    }

    pub fn class(&self) -> TrafficClass {
        self.payload.class()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_partition_protocol() {
        let req = Payload::SourceReq { token: 1, addr: PhysAddr::new(0, 0), reply_to: 0 };
        let resp = Payload::SourceResp { token: 1, addr: PhysAddr::new(0, 0) };
        assert_eq!(req.class(), TrafficClass::Req);
        assert_eq!(resp.class(), TrafficClass::Resp);
    }

    #[test]
    fn dispatch_with_carried_operand_is_bigger() {
        let bare = Payload::NmpDispatch {
            token: 0,
            dest: PhysAddr::new(0, 0),
            src1: PhysAddr::new(0, 64),
            src2: None,
            carried_operands: 0,
            dest_vpage: 0,
        };
        let carried = Payload::NmpDispatch {
            token: 0,
            dest: PhysAddr::new(0, 0),
            src1: PhysAddr::new(0, 64),
            src2: None,
            carried_operands: 1,
            dest_vpage: 0,
        };
        assert!(carried.size_bits() > bare.size_bits());
    }
}
