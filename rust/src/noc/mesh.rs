//! The network fabric: routers, credit flow control, link serialization
//! and per-node delivery queues over any cube topology
//! ([`super::topology`] — mesh, torus or ring, per
//! `SystemConfig::topology`).
//!
//! Model granularity: packets (not individual flits) are the switched
//! unit; a packet occupies an output link for `ceil(size/link_bits)`
//! cycles (serialization) and reaches the neighbouring router's input
//! buffer after the 3-cycle router pipeline. Finite input buffers plus
//! credit checks create the backpressure and congestion the paper's
//! hop-count/latency analysis (§7.4) depends on.
//!
//! Routing is the topology's deterministic minimal function; on
//! wraparound topologies (torus/ring) the fabric additionally applies
//! **bubble flow control**: a packet entering a dimension ring must
//! leave one free slot in the downstream buffer, so the ring can never
//! fill into a circular wait (packets already travelling within the
//! dimension are exempt and keep draining). The mesh path skips the rule
//! entirely and stays bit-identical to the pre-topology fabric.

use crate::config::{CubeId, McId, SystemConfig};
use crate::sim::Cycle;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::packet::{NodeId, Packet, NUM_CLASSES};
use super::router::{Dir, Router, NUM_PORTS};
use super::topology::{AnyTopology, Topology};

/// A packet traversing a link, due to arrive at `arrival`.
#[derive(Debug)]
struct InFlight {
    arrival: Cycle,
    seq: u64,
    /// Boxed: heap sift operations move 16 bytes instead of ~140.
    packet: Box<Packet>,
    router: usize,
    port: usize,
    class: usize,
}

impl PartialEq for InFlight {
    fn eq(&self, other: &Self) -> bool {
        (self.arrival, self.seq) == (other.arrival, other.seq)
    }
}
impl Eq for InFlight {}
impl PartialOrd for InFlight {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for InFlight {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.arrival, self.seq).cmp(&(other.arrival, other.seq))
    }
}

/// Aggregate network statistics (feed Fig 7 and the energy model).
#[derive(Debug, Clone, Default)]
pub struct NocStats {
    pub delivered: u64,
    pub total_hops: u64,
    pub total_latency: u64,
    /// Σ cycles packets spent waiting in router input buffers.
    pub total_queue_wait: u64,
    /// Forward events (denominator for per-hop queue wait).
    pub forwards: u64,
    /// Σ size_bits × hops — ×5 pJ/bit/hop gives network energy (§7.7).
    pub bit_hops: u64,
    pub injected: u64,
    pub inject_rejected: u64,
}

impl NocStats {
    pub fn avg_hops(&self) -> f64 {
        if self.delivered == 0 {
            0.0
        } else {
            self.total_hops as f64 / self.delivered as f64
        }
    }

    pub fn avg_latency(&self) -> f64 {
        if self.delivered == 0 {
            0.0
        } else {
            self.total_latency as f64 / self.delivered as f64
        }
    }
}

/// The network connecting memory cubes and MCs. Despite the historical
/// name it runs any [`AnyTopology`] — the mesh is just the default.
/// All geometry questions (dimensions, links, routes) go through
/// [`Mesh::topology`]; the fabric itself holds no duplicate geometry.
pub struct Mesh {
    topo: AnyTopology,
    routers: Vec<Router>,
    wire: BinaryHeap<Reverse<InFlight>>,
    seq: u64,
    next_packet_id: u64,
    router_pipeline: u64,
    link_bits: u64,
    /// Cube each MC hangs off (index = MC id).
    mc_attach: Vec<CubeId>,
    /// Per-cube and per-MC delivery queues (drained by owners each cycle).
    pub delivered_cube: Vec<Vec<Packet>>,
    pub delivered_mc: Vec<Vec<Packet>>,
    pub stats: NocStats,
}

impl Mesh {
    pub fn new(cfg: &SystemConfig) -> Self {
        let n = cfg.num_cubes();
        let routers = (0..n).map(|c| Router::new(c, cfg.router_buf_cap)).collect();
        let mc_attach = (0..cfg.num_mcs()).map(|m| cfg.mc_attach_cube(m)).collect();
        Self {
            topo: cfg.topology_obj(),
            routers,
            wire: BinaryHeap::new(),
            seq: 0,
            next_packet_id: 0,
            router_pipeline: cfg.timing.router_pipeline,
            link_bits: cfg.timing.link_bits,
            mc_attach,
            delivered_cube: vec![Vec::new(); n],
            delivered_mc: vec![Vec::new(); cfg.num_mcs()],
            stats: NocStats::default(),
        }
    }

    /// The geometry this fabric is switching over.
    pub fn topology(&self) -> &AnyTopology {
        &self.topo
    }

    pub fn num_cubes(&self) -> usize {
        self.topo.num_nodes()
    }

    /// Largest hop distance in the network ([`Topology::diameter`]) —
    /// the agent's hop-history normaliser derives from this.
    pub fn diameter(&self) -> u32 {
        self.topo.diameter()
    }

    pub fn xy(&self, cube: CubeId) -> (usize, usize) {
        self.topo.coords(cube)
    }

    pub fn cube_at(&self, x: usize, y: usize) -> CubeId {
        self.topo.node_at(x, y)
    }

    /// Link neighbours of a cube (2–4, in fixed N/S/W/E port order —
    /// see [`Topology::neighbors`]).
    pub fn neighbors(&self, cube: CubeId) -> Vec<CubeId> {
        self.topo.neighbors(cube)
    }

    /// The topology's "far" cube (the paper's mesh diagonal opposite,
    /// generalized — [`Topology::distant_cube`]).
    pub fn distant_cube(&self, cube: CubeId) -> CubeId {
        self.topo.distant_cube(cube)
    }

    /// Minimal hop distance between two nodes' routers.
    pub fn hop_distance(&self, a: NodeId, b: NodeId) -> u32 {
        self.topo.hop_distance(self.router_of(a), self.router_of(b))
    }

    pub fn router_of(&self, node: NodeId) -> CubeId {
        match node {
            NodeId::Cube(c) => c,
            NodeId::Mc(m) => self.mc_attach[m],
        }
    }

    pub fn mc_attach_cube(&self, mc: McId) -> CubeId {
        self.mc_attach[mc]
    }

    pub fn fresh_packet_id(&mut self) -> u64 {
        self.next_packet_id += 1;
        self.next_packet_id
    }

    /// Output port at router `at` toward destination router `dst`:
    /// ejection at the destination, else the topology's deterministic
    /// minimal route ([`Topology::route`]).
    fn route(&self, at: CubeId, dst_router: CubeId, dst: NodeId) -> Dir {
        if at == dst_router {
            return match dst {
                NodeId::Cube(_) => Dir::Local,
                NodeId::Mc(_) => Dir::Mc,
            };
        }
        self.topo.route(at, dst_router)
    }

    /// Inject a packet at its source node. Fails (backpressure) when the
    /// source router's injection buffer has no credit.
    pub fn inject(&mut self, packet: Packet) -> Result<(), Packet> {
        let router = self.router_of(packet.src);
        let port = match packet.src {
            NodeId::Cube(_) => Dir::Local as usize,
            NodeId::Mc(_) => Dir::Mc as usize,
        };
        let class = packet.class() as usize;
        let r = &mut self.routers[router];
        match r.in_q[port][class].push(packet) {
            Ok(()) => {
                r.buffered_count += 1;
                r.mark_queue(port, class);
                self.stats.injected += 1;
                Ok(())
            }
            Err(p) => {
                self.stats.inject_rejected += 1;
                Err(p)
            }
        }
    }

    /// Advance the fabric one cycle.
    pub fn tick(&mut self, now: Cycle) {
        // 1. Land matured in-flight packets into their reserved buffers.
        while let Some(Reverse(head)) = self.wire.peek() {
            if head.arrival > now {
                break;
            }
            let Reverse(f) = self.wire.pop().unwrap();
            let r = &mut self.routers[f.router];
            r.reserved[f.port][f.class] -= 1;
            let mut pk = *f.packet;
            pk.queued_at = f.arrival;
            r.in_q[f.port][f.class]
                .push(pk)
                .unwrap_or_else(|_| panic!("credit flow control violated"));
            r.buffered_count += 1;
            r.mark_queue(f.port, f.class);
        }

        // 2. Switch allocation per router: response class first (drain),
        //    one forward per input port, one acceptance per output port.
        for ri in 0..self.routers.len() {
            if self.routers[ri].buffered_count == 0 {
                continue; // idle router fast path
            }
            let mut out_used = [false; NUM_PORTS];
            let rr = self.routers[ri].rr;
            let occupied = self.routers[ri].occupied;
            for class in (0..NUM_CLASSES).rev() {
                for p in 0..NUM_PORTS {
                    let port = (p + rr) % NUM_PORTS;
                    if occupied & (1 << (port * NUM_CLASSES + class)) != 0 {
                        self.try_forward(ri, port, class, &mut out_used, now);
                    }
                }
            }
            self.routers[ri].rr = (rr + 1) % NUM_PORTS;
        }
    }

    fn try_forward(
        &mut self,
        ri: usize,
        port: usize,
        class: usize,
        out_used: &mut [bool; NUM_PORTS],
        now: Cycle,
    ) {
        let (dst, dst_router) = {
            let r = &self.routers[ri];
            match r.in_q[port][class].peek() {
                Some(pk) => (pk.dst, self.router_of(pk.dst)),
                None => return,
            }
        };
        let at = self.routers[ri].cube;
        let out = self.route(at, dst_router, dst);
        let out_idx = out as usize;
        if out_used[out_idx] {
            return;
        }

        match out {
            Dir::Local => {
                let pk = self.routers[ri].in_q[port][class].pop().unwrap();
                self.routers[ri].buffered_count -= 1;
                self.routers[ri].unmark_if_empty(port, class);
                out_used[out_idx] = true;
                self.stats.total_queue_wait += now.saturating_sub(pk.queued_at);
                self.stats.forwards += 1;
                self.record_delivery(&pk, now);
                self.delivered_cube[at].push(pk);
            }
            Dir::Mc => {
                let pk = self.routers[ri].in_q[port][class].pop().unwrap();
                self.routers[ri].buffered_count -= 1;
                self.routers[ri].unmark_if_empty(port, class);
                out_used[out_idx] = true;
                self.stats.total_queue_wait += now.saturating_sub(pk.queued_at);
                self.stats.forwards += 1;
                let mc = self
                    .mc_attach
                    .iter()
                    .position(|&c| c == at)
                    .expect("Mc-port ejection at a router with no attached MC");
                self.record_delivery(&pk, now);
                self.delivered_mc[mc].push(pk);
            }
            dir => {
                // Network hop: check link availability + downstream credit.
                if self.routers[ri].link_busy_until[out_idx] > now {
                    return;
                }
                let next = self
                    .topo
                    .neighbor(at, dir)
                    .expect("minimal route follows an existing link");
                let in_port = dir.opposite() as usize;
                // Bubble flow control on wraparound topologies: a packet
                // *entering* a dimension ring (from the Local/Mc port or
                // after a dimension turn) must leave one slot free, so
                // the ring's buffers can never fill into a circular
                // wait; packets continuing within the dimension keep the
                // ordinary one-slot credit check and drain the ring. On
                // the mesh (no wraparound) this is exactly the original
                // credit check — bit-identical behavior.
                let entering = Dir::from_index(port).dimension() != dir.dimension();
                let needed = if self.topo.wraparound() && entering { 2 } else { 1 };
                if self.routers[next].free_slots(in_port, class) < needed {
                    return;
                }
                let mut pk = self.routers[ri].in_q[port][class].pop().unwrap();
                self.routers[ri].buffered_count -= 1;
                self.routers[ri].unmark_if_empty(port, class);
                out_used[out_idx] = true;
                self.stats.total_queue_wait += now.saturating_sub(pk.queued_at);
                self.stats.forwards += 1;
                pk.hops += 1;
                self.stats.bit_hops += pk.size_bits;
                let ser = pk.size_bits.div_ceil(self.link_bits).max(1);
                self.routers[ri].link_busy_until[out_idx] = now + ser;
                self.routers[next].reserved[in_port][class] += 1;
                self.seq += 1;
                self.wire.push(Reverse(InFlight {
                    arrival: now + self.router_pipeline + ser,
                    seq: self.seq,
                    packet: Box::new(pk),
                    router: next,
                    port: in_port,
                    class,
                }));
            }
        }
    }

    fn record_delivery(&mut self, pk: &Packet, now: Cycle) {
        self.stats.delivered += 1;
        self.stats.total_hops += pk.hops as u64;
        self.stats.total_latency += now.saturating_sub(pk.injected_at);
    }

    /// Earliest cycle ≥ `now` at which [`tick`](Self::tick) can change
    /// state (event engine, DESIGN.md §8). Any buffered packet
    /// arbitrates — and rotates round-robin pointers — every cycle, so
    /// a non-empty router forces the next cycle; otherwise the fabric
    /// sleeps until the earliest in-flight wire arrival. This argument
    /// is purely occupancy-based — which links packets ride (including
    /// torus/ring wraparound wires) never enters it — so the skip stays
    /// legal on every topology.
    pub fn next_event(&self, now: Cycle) -> Option<Cycle> {
        if self.routers.iter().any(|r| r.buffered_count > 0) {
            return Some(now);
        }
        self.wire.peek().map(|r| now.max(r.0.arrival))
    }

    /// True when no packet is buffered or in flight anywhere.
    pub fn is_idle(&self) -> bool {
        self.wire.is_empty() && self.routers.iter().all(|r| r.buffered() == 0)
    }

    /// Total buffered packets across all routers (congestion signal).
    pub fn total_buffered(&self) -> usize {
        self.routers.iter().map(|r| r.buffered()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cube::PhysAddr;
    use crate::noc::packet::Payload;

    fn test_cfg() -> SystemConfig {
        SystemConfig::default()
    }

    fn mk_packet(mesh: &mut Mesh, src: NodeId, dst: NodeId, now: Cycle) -> Packet {
        let id = mesh.fresh_packet_id();
        Packet::new(
            id,
            src,
            dst,
            Payload::SourceReq { token: id, addr: PhysAddr::new(0, 0), reply_to: 0 },
            now,
        )
    }

    /// Drive the mesh until idle or a cycle limit.
    fn run_until_idle(mesh: &mut Mesh, mut now: Cycle, limit: u64) -> Cycle {
        for _ in 0..limit {
            mesh.tick(now);
            if mesh.is_idle() {
                break;
            }
            now += 1;
        }
        now
    }

    #[test]
    fn delivers_across_mesh() {
        let cfg = test_cfg();
        let mut mesh = Mesh::new(&cfg);
        let pk = mk_packet(&mut mesh, NodeId::Cube(0), NodeId::Cube(15), 0);
        mesh.inject(pk).unwrap();
        run_until_idle(&mut mesh, 0, 1000);
        assert_eq!(mesh.delivered_cube[15].len(), 1);
        // 4x4 corner-to-corner = 3 + 3 hops.
        assert_eq!(mesh.delivered_cube[15][0].hops, 6);
    }

    #[test]
    fn local_delivery_zero_hops() {
        let cfg = test_cfg();
        let mut mesh = Mesh::new(&cfg);
        let pk = mk_packet(&mut mesh, NodeId::Cube(5), NodeId::Cube(5), 0);
        mesh.inject(pk).unwrap();
        run_until_idle(&mut mesh, 0, 100);
        assert_eq!(mesh.delivered_cube[5].len(), 1);
        assert_eq!(mesh.delivered_cube[5][0].hops, 0);
    }

    #[test]
    fn mc_port_delivery() {
        let cfg = test_cfg();
        let mut mesh = Mesh::new(&cfg);
        let pk = mk_packet(&mut mesh, NodeId::Cube(10), NodeId::Mc(3), 0);
        mesh.inject(pk).unwrap();
        run_until_idle(&mut mesh, 0, 1000);
        assert_eq!(mesh.delivered_mc[3].len(), 1);
    }

    #[test]
    fn hop_distance_matches_manhattan() {
        let cfg = test_cfg();
        let mesh = Mesh::new(&cfg);
        assert_eq!(mesh.hop_distance(NodeId::Cube(0), NodeId::Cube(15)), 6);
        assert_eq!(mesh.hop_distance(NodeId::Cube(0), NodeId::Cube(1)), 1);
        assert_eq!(mesh.hop_distance(NodeId::Cube(7), NodeId::Cube(7)), 0);
    }

    #[test]
    fn distant_cube_is_diagonal_involution_on_mesh() {
        let cfg = test_cfg();
        let mesh = Mesh::new(&cfg);
        for cube in 0..16 {
            let opp = mesh.distant_cube(cube);
            assert_eq!(mesh.distant_cube(opp), cube);
        }
        assert_eq!(mesh.distant_cube(0), 15);
        assert_eq!(mesh.distant_cube(5), 10);
    }

    #[test]
    fn neighbors_counts() {
        let cfg = test_cfg();
        let mesh = Mesh::new(&cfg);
        assert_eq!(mesh.neighbors(0).len(), 2); // corner
        assert_eq!(mesh.neighbors(1).len(), 3); // edge
        assert_eq!(mesh.neighbors(5).len(), 4); // interior
    }

    #[test]
    fn next_event_sleeps_until_wire_arrival() {
        let cfg = test_cfg();
        let mut mesh = Mesh::new(&cfg);
        assert_eq!(mesh.next_event(0), None, "idle fabric has no event");
        let pk = mk_packet(&mut mesh, NodeId::Cube(0), NodeId::Cube(15), 0);
        mesh.inject(pk).unwrap();
        assert_eq!(mesh.next_event(0), Some(0), "buffered packet arbitrates now");
        mesh.tick(0); // forwards onto the wire (3-stage pipeline + serialization)
        let at = mesh.next_event(1).expect("packet in flight");
        assert!(at > 1, "wire arrival is in the future, got {at}");
        run_until_idle(&mut mesh, 1, 1000);
        assert_eq!(mesh.next_event(1000), None);
    }

    #[test]
    fn many_packets_all_delivered() {
        let cfg = test_cfg();
        let mut mesh = Mesh::new(&cfg);
        let mut now: Cycle = 0;
        let mut to_send: Vec<Packet> = (0..64)
            .map(|i| {
                let src = NodeId::Cube((i * 3) % 16);
                let dst = NodeId::Cube((i * 7 + 5) % 16);
                mk_packet(&mut mesh, src, dst, 0)
            })
            .collect();
        let mut sent = 0u64;
        while sent < 64 || !mesh.is_idle() {
            while let Some(pk) = to_send.pop() {
                match mesh.inject(pk) {
                    Ok(()) => sent += 1,
                    Err(pk) => {
                        to_send.push(pk);
                        break;
                    }
                }
            }
            mesh.tick(now);
            now += 1;
            assert!(now < 100_000, "network did not drain");
        }
        let total: usize = mesh.delivered_cube.iter().map(|v| v.len()).sum();
        assert_eq!(total, 64);
        assert_eq!(mesh.stats.delivered, 64);
    }

    #[test]
    fn congestion_backpressures_injection() {
        let mut cfg = test_cfg();
        cfg.router_buf_cap = 1;
        let mut mesh = Mesh::new(&cfg);
        // Flood one router's injection port without ticking: the second or
        // third packet must be rejected (finite buffering).
        let mut rejected = false;
        for _ in 0..8 {
            let pk = mk_packet(&mut mesh, NodeId::Cube(0), NodeId::Cube(15), 0);
            if mesh.inject(pk).is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected);
        assert!(mesh.stats.inject_rejected > 0);
    }

    #[test]
    fn bit_hops_accumulate() {
        let cfg = test_cfg();
        let mut mesh = Mesh::new(&cfg);
        let pk = mk_packet(&mut mesh, NodeId::Cube(0), NodeId::Cube(3), 0);
        let bits = pk.size_bits;
        mesh.inject(pk).unwrap();
        run_until_idle(&mut mesh, 0, 1000);
        assert_eq!(mesh.stats.bit_hops, bits * 3);
    }

    // ----- non-mesh topologies through the same fabric -----

    use crate::config::TopologyKind;

    fn topo_cfg(kind: TopologyKind) -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.topology = kind;
        cfg
    }

    #[test]
    fn torus_delivers_corner_to_corner_over_wraparound() {
        let mut mesh = Mesh::new(&topo_cfg(TopologyKind::Torus));
        let pk = mk_packet(&mut mesh, NodeId::Cube(0), NodeId::Cube(15), 0);
        mesh.inject(pk).unwrap();
        run_until_idle(&mut mesh, 0, 1000);
        assert_eq!(mesh.delivered_cube[15].len(), 1);
        // (0,0) → (3,3) on a 4x4 torus: one West wrap + one North wrap.
        assert_eq!(mesh.delivered_cube[15][0].hops, 2);
    }

    #[test]
    fn ring_delivers_along_the_shorter_arc() {
        let mut mesh = Mesh::new(&topo_cfg(TopologyKind::Ring));
        let near = mk_packet(&mut mesh, NodeId::Cube(0), NodeId::Cube(15), 0);
        mesh.inject(near).unwrap();
        run_until_idle(&mut mesh, 0, 1000);
        assert_eq!(mesh.delivered_cube[15].len(), 1);
        assert_eq!(mesh.delivered_cube[15][0].hops, 1, "0 → 15 wraps West");
        let far = mk_packet(&mut mesh, NodeId::Cube(0), NodeId::Cube(8), 0);
        mesh.inject(far).unwrap();
        run_until_idle(&mut mesh, 0, 2000);
        assert_eq!(mesh.delivered_cube[8].len(), 1);
        assert_eq!(mesh.delivered_cube[8][0].hops, 8, "0 → 8 is the diameter");
    }

    #[test]
    fn ring_mc_ports_sit_at_quarter_points() {
        let mut mesh = Mesh::new(&topo_cfg(TopologyKind::Ring));
        assert_eq!(mesh.mc_attach_cube(2), 8);
        let pk = mk_packet(&mut mesh, NodeId::Cube(5), NodeId::Mc(2), 0);
        mesh.inject(pk).unwrap();
        run_until_idle(&mut mesh, 0, 1000);
        assert_eq!(mesh.delivered_mc[2].len(), 1);
        assert_eq!(mesh.delivered_mc[2][0].hops, 3);
    }

    /// Storm test under minimal legal buffering: bubble flow control must
    /// keep the wraparound dimension rings draining (a full circular wait
    /// would show up here as a never-idle fabric).
    #[test]
    fn wraparound_storms_drain_with_min_buffers() {
        for kind in [TopologyKind::Torus, TopologyKind::Ring] {
            let mut cfg = topo_cfg(kind);
            cfg.router_buf_cap = 2;
            cfg.validate().unwrap();
            let mut mesh = Mesh::new(&cfg);
            let mut to_send: Vec<Packet> = (0..96)
                .map(|i| {
                    let src = NodeId::Cube((i * 5) % 16);
                    let dst = NodeId::Cube((i * 11 + 7) % 16);
                    mk_packet(&mut mesh, src, dst, 0)
                })
                .collect();
            let mut now: Cycle = 0;
            let mut sent = 0u64;
            while sent < 96 || !mesh.is_idle() {
                while let Some(pk) = to_send.pop() {
                    match mesh.inject(pk) {
                        Ok(()) => sent += 1,
                        Err(pk) => {
                            to_send.push(pk);
                            break;
                        }
                    }
                }
                mesh.tick(now);
                now += 1;
                assert!(now < 200_000, "{kind:?} network did not drain");
            }
            assert_eq!(mesh.stats.delivered, 96, "{kind:?}");
        }
    }

    #[test]
    fn next_event_sleeps_on_wraparound_wire_arrivals() {
        let mut mesh = Mesh::new(&topo_cfg(TopologyKind::Torus));
        let pk = mk_packet(&mut mesh, NodeId::Cube(0), NodeId::Cube(12), 0);
        mesh.inject(pk).unwrap();
        assert_eq!(mesh.next_event(0), Some(0), "buffered packet arbitrates now");
        mesh.tick(0); // forwards onto the North wraparound wire
        let at = mesh.next_event(1).expect("packet in flight on a wrap link");
        assert!(at > 1, "wire arrival is in the future, got {at}");
        run_until_idle(&mut mesh, 1, 1000);
        assert_eq!(mesh.delivered_cube[12].len(), 1);
        assert_eq!(mesh.delivered_cube[12][0].hops, 1);
        assert_eq!(mesh.next_event(1000), None);
    }
}
