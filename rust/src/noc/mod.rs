//! Memory-cube network: a 2D mesh of 6-port, 3-stage-pipeline routers with
//! virtual-channel buffering, credit (token) flow control and static XY
//! routing — Table 1's "4×4 mesh, 3 stage router, 128 bit link bandwidth".
//!
//! Two traffic classes (request / response) ride disjoint buffer pools,
//! which is how the real design uses its 5 VCs to rule out protocol
//! deadlock (§6.2); within a class, XY routing is deadlock-free.

pub mod mesh;
pub mod packet;
pub mod router;

pub use mesh::{Mesh, NocStats};
pub use packet::{NodeId, Packet, Payload, TrafficClass};
pub use router::{Dir, Router};
