//! The memory-cube network: 6-port, 3-stage-pipeline routers with
//! per-class buffering, credit (token) flow control, link serialization
//! and deterministic minimal routing over a **pluggable topology** —
//! Table 1's "4×4 mesh, 3 stage router, 128 bit link bandwidth" by
//! default, with torus and ring alternatives for scale-out studies
//! (`SystemConfig::topology`, EXPERIMENTS.md §Topology).
//!
//! Layout of the module:
//!
//! * [`topology`] — the geometric contract ([`topology::Topology`]):
//!   coordinates, link sets, minimal routing, hop distances, MC
//!   placement and the agent's "far cube". Three implementations:
//!   [`topology::Mesh2D`] (the paper's network, bit-identical to the
//!   pre-topology simulator), [`topology::Torus2D`] (wraparound links,
//!   half the diameter) and [`topology::Ring`] (worst-case diameter).
//! * [`router`] — per-router state: input queues per (port, class),
//!   link-serialization bookkeeping, round-robin arbitration pointer.
//! * [`packet`] — the protocol vocabulary: NMP dispatch, operand
//!   fetch/response, write-back, ACKs and migration DMA, with per-payload
//!   sizes feeding serialization and the §7.7 energy model.
//! * [`mesh`] — the fabric itself: injection, switch allocation,
//!   in-flight wires, delivery queues and [`mesh::NocStats`] (hops,
//!   latency, queue wait, bit-hops for Fig 7 and the energy model).
//!
//! Two traffic classes (request / response) ride disjoint buffer pools,
//! which is how the real design uses its 5 VCs to rule out protocol
//! deadlock (§6.2). Within a class, dimension-ordered routing is
//! deadlock-free on the mesh; the wraparound topologies additionally run
//! bubble flow control (see [`mesh`]'s module docs) so their dimension
//! rings can never fill into a circular wait.

pub mod mesh;
pub mod packet;
pub mod router;
pub mod topology;

pub use mesh::{Mesh, NocStats};
pub use packet::{NodeId, Packet, Payload, TrafficClass};
pub use router::{Dir, Router};
pub use topology::{AnyTopology, Mesh2D, Ring, Topology, Torus2D};
