//! Bounded FIFO queue with occupancy accounting.
//!
//! Used for MC request queues, vault controller queues, router VC buffers
//! and the migration queue. Rejecting on full is what creates backpressure
//! in the cycle-level model.

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct BoundedQueue<T> {
    items: VecDeque<T>,
    capacity: usize,
    /// Cumulative occupancy integral (sum of len over observed cycles),
    /// for average-occupancy metrics.
    occupancy_acc: u64,
    observations: u64,
    rejected: u64,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            items: VecDeque::with_capacity(capacity.min(1024)),
            capacity,
            occupancy_acc: 0,
            observations: 0,
            rejected: 0,
        }
    }

    pub fn push(&mut self, item: T) -> Result<(), T> {
        if self.items.len() >= self.capacity {
            self.rejected += 1;
            Err(item)
        } else {
            self.items.push_back(item);
            Ok(())
        }
    }

    pub fn pop(&mut self) -> Option<T> {
        self.items.pop_front()
    }

    pub fn peek(&self) -> Option<&T> {
        self.items.front()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fractional occupancy in [0, 1] — fed into the agent state.
    pub fn occupancy(&self) -> f32 {
        self.items.len() as f32 / self.capacity as f32
    }

    /// Record one occupancy observation (call once per cycle).
    pub fn observe(&mut self) {
        self.observe_n(1);
    }

    /// Record `n` identical occupancy observations at once — what the
    /// event engine applies for a span of skipped cycles in which the
    /// queue provably cannot change. Integer arithmetic, so the integral
    /// is bit-identical to `n` consecutive [`observe`](Self::observe)s.
    pub fn observe_n(&mut self, n: u64) {
        self.occupancy_acc += self.items.len() as u64 * n;
        self.observations += n;
    }

    pub fn avg_occupancy(&self) -> f64 {
        if self.observations == 0 {
            0.0
        } else {
            self.occupancy_acc as f64 / (self.observations as f64 * self.capacity as f64)
        }
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.items.iter()
    }

    /// Remove and return the first element matching `pred`.
    pub fn remove_first<F: Fn(&T) -> bool>(&mut self, pred: F) -> Option<T> {
        let pos = self.items.iter().position(|x| pred(x))?;
        self.items.remove(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = BoundedQueue::new(3);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn rejects_when_full() {
        let mut q = BoundedQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(3));
        assert_eq!(q.rejected(), 1);
        assert!(q.is_full());
    }

    #[test]
    fn occupancy_accounting() {
        let mut q = BoundedQueue::new(4);
        q.push(()).unwrap();
        q.push(()).unwrap();
        q.observe();
        q.observe();
        assert!((q.avg_occupancy() - 0.5).abs() < 1e-9);
        assert!((q.occupancy() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn observe_n_matches_repeated_observe() {
        let mut a = BoundedQueue::new(8);
        let mut b = BoundedQueue::new(8);
        for q in [&mut a, &mut b] {
            q.push(1).unwrap();
            q.push(2).unwrap();
            q.push(3).unwrap();
        }
        for _ in 0..37 {
            a.observe();
        }
        b.observe_n(37);
        assert_eq!(a.observations, b.observations);
        assert_eq!(a.occupancy_acc, b.occupancy_acc);
        assert_eq!(a.avg_occupancy().to_bits(), b.avg_occupancy().to_bits());
    }

    /// Property test: drive the queue with random push/pop/observe
    /// sequences against a plain model and check every invariant the
    /// event engine depends on (DESIGN.md §8).
    #[test]
    fn random_op_sequences_match_model() {
        use crate::sim::Rng;
        let mut rng = Rng::new(0xB0B);
        for round in 0..50 {
            let cap = 1 + rng.index(16);
            let mut q: BoundedQueue<u64> = BoundedQueue::new(cap);
            let mut model: std::collections::VecDeque<u64> = Default::default();
            let (mut rejected, mut observations, mut occ_acc) = (0u64, 0u64, 0u64);
            for step in 0..400u64 {
                match rng.index(4) {
                    0 | 1 => {
                        // Push: accepted iff the model is below capacity.
                        let accepted = q.push(step).is_ok();
                        if model.len() < cap {
                            assert!(accepted, "round {round} step {step}");
                            model.push_back(step);
                        } else {
                            assert!(!accepted, "round {round} step {step}");
                            rejected += 1;
                        }
                    }
                    2 => {
                        // Pop: strict FIFO against the model.
                        assert_eq!(q.pop(), model.pop_front());
                    }
                    _ => {
                        let n = 1 + rng.below(5);
                        q.observe_n(n);
                        observations += n;
                        occ_acc += model.len() as u64 * n;
                    }
                }
                // Occupancy invariants hold after every operation.
                assert_eq!(q.len(), model.len());
                assert_eq!(q.is_empty(), model.is_empty());
                assert_eq!(q.is_full(), model.len() >= cap);
                assert!(q.len() <= q.capacity());
                assert_eq!(q.peek(), model.front());
                let occ = q.occupancy();
                assert!((0.0..=1.0).contains(&occ));
            }
            assert_eq!(q.rejected(), rejected, "round {round}");
            assert_eq!(q.observations, observations, "round {round}");
            assert_eq!(q.occupancy_acc, occ_acc, "round {round}");
        }
    }

    #[test]
    fn remove_first_matching() {
        let mut q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.remove_first(|&x| x == 3), Some(3));
        assert_eq!(q.len(), 4);
        assert_eq!(q.remove_first(|&x| x == 3), None);
    }
}
