//! Bounded FIFO queue with occupancy accounting.
//!
//! Used for MC request queues, vault controller queues, router VC buffers
//! and the migration queue. Rejecting on full is what creates backpressure
//! in the cycle-level model.

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct BoundedQueue<T> {
    items: VecDeque<T>,
    capacity: usize,
    /// Cumulative occupancy integral (sum of len over observed cycles),
    /// for average-occupancy metrics.
    occupancy_acc: u64,
    observations: u64,
    rejected: u64,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            items: VecDeque::with_capacity(capacity.min(1024)),
            capacity,
            occupancy_acc: 0,
            observations: 0,
            rejected: 0,
        }
    }

    pub fn push(&mut self, item: T) -> Result<(), T> {
        if self.items.len() >= self.capacity {
            self.rejected += 1;
            Err(item)
        } else {
            self.items.push_back(item);
            Ok(())
        }
    }

    pub fn pop(&mut self) -> Option<T> {
        self.items.pop_front()
    }

    pub fn peek(&self) -> Option<&T> {
        self.items.front()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fractional occupancy in [0, 1] — fed into the agent state.
    pub fn occupancy(&self) -> f32 {
        self.items.len() as f32 / self.capacity as f32
    }

    /// Record one occupancy observation (call once per cycle).
    pub fn observe(&mut self) {
        self.occupancy_acc += self.items.len() as u64;
        self.observations += 1;
    }

    pub fn avg_occupancy(&self) -> f64 {
        if self.observations == 0 {
            0.0
        } else {
            self.occupancy_acc as f64 / (self.observations as f64 * self.capacity as f64)
        }
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.items.iter()
    }

    /// Remove and return the first element matching `pred`.
    pub fn remove_first<F: Fn(&T) -> bool>(&mut self, pred: F) -> Option<T> {
        let pos = self.items.iter().position(|x| pred(x))?;
        self.items.remove(pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = BoundedQueue::new(3);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn rejects_when_full() {
        let mut q = BoundedQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(3));
        assert_eq!(q.rejected(), 1);
        assert!(q.is_full());
    }

    #[test]
    fn occupancy_accounting() {
        let mut q = BoundedQueue::new(4);
        q.push(()).unwrap();
        q.push(()).unwrap();
        q.observe();
        q.observe();
        assert!((q.avg_occupancy() - 0.5).abs() < 1e-9);
        assert!((q.occupancy() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn remove_first_matching() {
        let mut q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.remove_first(|&x| x == 3), Some(3));
        assert_eq!(q.len(), 4);
        assert_eq!(q.remove_first(|&x| x == 3), None);
    }
}
