//! Hierarchical timing wheel for the next-event simulation engine
//! (DESIGN.md §8).
//!
//! Each loop iteration of the event engine collects every component's
//! next-interesting cycle and asks for the earliest one; the clock then
//! jumps straight there instead of polling the cycles in between. The
//! wheel keeps three 64-slot levels of geometrically coarser resolution
//! (1, 64 and 4096 cycles per slot) over the current base cycle, with an
//! overflow minimum beyond the ~262k-cycle horizon. Occupancy is a bitmap
//! per level and each occupied slot stores the exact minimum cycle filed
//! into it, so [`EventWheel::earliest`] is exact — never rounded to slot
//! granularity — in O(levels) time.

use super::Cycle;

/// Slots per level (one `u64` occupancy bitmap each).
pub const SLOTS: usize = 64;
/// Wheel levels; level `l` slots span `64^l` cycles.
const LEVELS: usize = 3;

/// A min-query timing wheel over cycles `>= base`.
#[derive(Debug, Clone)]
pub struct EventWheel {
    base: Cycle,
    /// Bitmap of occupied slots per level (bit `s` = slot `s`).
    occupied: [u64; LEVELS],
    /// Exact minimum cycle filed into each occupied slot. Stale values
    /// from before the last [`EventWheel::reset`] are gated out by the
    /// bitmap and never read.
    slot_min: [[Cycle; SLOTS]; LEVELS],
    /// Minimum scheduled cycle beyond the last level's horizon.
    overflow: Option<Cycle>,
    scheduled: u64,
}

impl EventWheel {
    /// An empty wheel whose time origin is `base`.
    pub fn new(base: Cycle) -> Self {
        Self {
            base,
            occupied: [0; LEVELS],
            slot_min: [[0; SLOTS]; LEVELS],
            overflow: None,
            scheduled: 0,
        }
    }

    /// Drop every scheduled event and move the time origin to `base`.
    pub fn reset(&mut self, base: Cycle) {
        self.base = base;
        self.occupied = [0; LEVELS];
        self.overflow = None;
        self.scheduled = 0;
    }

    /// First cycle past the finest-through-coarsest levels; events at or
    /// beyond this land in the overflow minimum.
    pub fn horizon(&self) -> Cycle {
        self.base.saturating_add((SLOTS as u64).pow(LEVELS as u32))
    }

    /// Number of `schedule` calls since the last reset.
    pub fn len(&self) -> u64 {
        self.scheduled
    }

    pub fn is_empty(&self) -> bool {
        self.scheduled == 0
    }

    /// File an event at cycle `at`. Cycles before the base clamp to the
    /// base (an already-due event fires now, never in the past).
    pub fn schedule(&mut self, at: Cycle) {
        let at = at.max(self.base);
        self.scheduled += 1;
        let d = at - self.base;
        // SLOTS = 64 = 2^6: level `l` covers d < 2^(6(l+1)) with slot
        // index d >> 6l — shifts, not divisions, on the hot loop.
        for level in 0..LEVELS {
            if d < 1 << (6 * (level + 1)) {
                let slot = (d >> (6 * level)) as usize;
                let bit = 1u64 << slot;
                if self.occupied[level] & bit == 0 {
                    self.occupied[level] |= bit;
                    self.slot_min[level][slot] = at;
                } else if at < self.slot_min[level][slot] {
                    self.slot_min[level][slot] = at;
                }
                return;
            }
        }
        self.overflow = Some(self.overflow.map_or(at, |o| o.min(at)));
    }

    /// The exact earliest scheduled cycle, if any.
    ///
    /// Level `l` only ever holds distances in `[64^l, 64^(l+1))` (level 0
    /// from zero), so levels partition the time axis in ascending order
    /// and, within a level, lower slots cover strictly earlier spans: the
    /// lowest occupied slot of the first non-empty level holds the global
    /// minimum.
    pub fn earliest(&self) -> Option<Cycle> {
        for level in 0..LEVELS {
            let occ = self.occupied[level];
            if occ != 0 {
                let slot = occ.trailing_zeros() as usize;
                return Some(self.slot_min[level][slot]);
            }
        }
        self.overflow
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;

    #[test]
    fn empty_wheel_has_no_event() {
        let w = EventWheel::new(100);
        assert!(w.is_empty());
        assert_eq!(w.earliest(), None);
    }

    #[test]
    fn single_event_round_trips_exactly() {
        for offset in [0u64, 1, 63, 64, 65, 4095, 4096, 262_143, 262_144, 10_000_000] {
            let mut w = EventWheel::new(1000);
            w.schedule(1000 + offset);
            assert_eq!(w.earliest(), Some(1000 + offset), "offset {offset}");
        }
    }

    #[test]
    fn past_events_clamp_to_base() {
        let mut w = EventWheel::new(500);
        w.schedule(7);
        assert_eq!(w.earliest(), Some(500));
    }

    #[test]
    fn earliest_is_exact_minimum_not_slot_granular() {
        let mut w = EventWheel::new(0);
        // Same level-1 slot (d in [64, 128)): min must be exact.
        w.schedule(100);
        w.schedule(70);
        w.schedule(127);
        assert_eq!(w.earliest(), Some(70));
    }

    #[test]
    fn finer_levels_win_over_coarser() {
        let mut w = EventWheel::new(0);
        w.schedule(300_000); // overflow
        w.schedule(5000); // level 2
        assert_eq!(w.earliest(), Some(5000));
        w.schedule(200); // level 1
        assert_eq!(w.earliest(), Some(200));
        w.schedule(3); // level 0
        assert_eq!(w.earliest(), Some(3));
    }

    #[test]
    fn reset_clears_and_rebases() {
        let mut w = EventWheel::new(0);
        w.schedule(10);
        w.schedule(999_999);
        w.reset(2000);
        assert!(w.is_empty());
        assert_eq!(w.earliest(), None);
        w.schedule(2048);
        assert_eq!(w.earliest(), Some(2048));
        // Slot minima from before the reset are never resurrected.
        w.schedule(2100);
        assert_eq!(w.earliest(), Some(2048));
    }

    #[test]
    fn matches_naive_minimum_on_random_schedules() {
        let mut rng = Rng::new(0xEE1);
        for round in 0..200 {
            let base = rng.below(1 << 20);
            let mut w = EventWheel::new(base);
            let n = 1 + rng.index(40);
            let mut naive: Option<u64> = None;
            for _ in 0..n {
                // Mix short, medium, long and overflow horizons.
                let offset = match rng.index(4) {
                    0 => rng.below(64),
                    1 => rng.below(4096),
                    2 => rng.below(262_144),
                    _ => rng.below(1 << 40),
                };
                let at = base + offset;
                w.schedule(at);
                naive = Some(naive.map_or(at, |m: u64| m.min(at)));
            }
            assert_eq!(w.earliest(), naive, "round {round} base {base}");
            assert_eq!(w.len(), n as u64);
        }
    }
}
