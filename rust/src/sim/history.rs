//! Fixed-length histories and running averages.
//!
//! The paper's state representation (§4.2) carries several fixed-length
//! histories (hop count, packet latency, migration latency, actions) and
//! the MCs keep *running averages* of cube-reported counters (§5.1).

/// Fixed-capacity history that keeps the most recent `cap` samples in
/// insertion order (oldest first when iterated).
#[derive(Debug, Clone)]
pub struct History {
    buf: Vec<f32>,
    cap: usize,
    head: usize,
    len: usize,
}

impl History {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self { buf: vec![0.0; cap], cap, head: 0, len: 0 }
    }

    pub fn push(&mut self, v: f32) {
        self.buf[self.head] = v;
        self.head = (self.head + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Most-recent-last snapshot, zero-padded at the front to `cap`.
    /// This is exactly the fixed-width encoding the agent state expects.
    pub fn padded(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.cap - self.len];
        out.extend(self.iter());
        out
    }

    /// Iterate oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = f32> + '_ {
        (0..self.len).map(move |i| {
            let idx = (self.head + self.cap - self.len + i) % self.cap;
            self.buf[idx]
        })
    }

    pub fn last(&self) -> Option<f32> {
        if self.len == 0 {
            None
        } else {
            Some(self.buf[(self.head + self.cap - 1) % self.cap])
        }
    }

    pub fn mean(&self) -> f32 {
        if self.len == 0 {
            0.0
        } else {
            self.iter().sum::<f32>() / self.len as f32
        }
    }

    pub fn clear(&mut self) {
        self.head = 0;
        self.len = 0;
    }
}

/// Exponentially-weighted running average (the MCs' "running average of the
/// received value", §5.1). `alpha` is the weight of the new sample.
#[derive(Debug, Clone)]
pub struct RunningAvg {
    value: f64,
    alpha: f64,
    samples: u64,
}

impl RunningAvg {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { value: 0.0, alpha, samples: 0 }
    }

    pub fn update(&mut self, sample: f64) {
        if self.samples == 0 {
            self.value = sample;
        } else {
            self.value = self.alpha * sample + (1.0 - self.alpha) * self.value;
        }
        self.samples += 1;
    }

    pub fn get(&self) -> f64 {
        self.value
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    pub fn reset(&mut self) {
        self.value = 0.0;
        self.samples = 0;
    }
}

/// Plain arithmetic-mean accumulator for end-of-run statistics.
#[derive(Debug, Clone, Default)]
pub struct MeanAcc {
    sum: f64,
    n: u64,
}

impl MeanAcc {
    pub fn add(&mut self, v: f64) {
        self.sum += v;
        self.n += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_keeps_latest() {
        let mut h = History::new(4);
        for i in 0..10 {
            h.push(i as f32);
        }
        let snap: Vec<f32> = h.iter().collect();
        assert_eq!(snap, vec![6.0, 7.0, 8.0, 9.0]);
        assert_eq!(h.last(), Some(9.0));
    }

    #[test]
    fn history_padded_front_zeros() {
        let mut h = History::new(4);
        h.push(5.0);
        assert_eq!(h.padded(), vec![0.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn history_mean() {
        let mut h = History::new(3);
        h.push(1.0);
        h.push(2.0);
        h.push(3.0);
        h.push(4.0); // evicts 1.0
        assert!((h.mean() - 3.0).abs() < 1e-6);
    }

    #[test]
    fn running_avg_first_sample_exact() {
        let mut r = RunningAvg::new(0.25);
        r.update(8.0);
        assert_eq!(r.get(), 8.0);
        r.update(0.0);
        assert!((r.get() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn mean_acc() {
        let mut m = MeanAcc::default();
        for v in [1.0, 2.0, 3.0] {
            m.add(v);
        }
        assert!((m.mean() - 2.0).abs() < 1e-12);
        assert_eq!(m.count(), 3);
    }
}
