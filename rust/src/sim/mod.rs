//! Cycle-level simulation core: deterministic RNG, cycle bookkeeping,
//! fixed-length histories, running averages and bounded queues.
//!
//! Everything in the simulator is deterministic given a seed — there is no
//! wall-clock or OS entropy anywhere on the simulation path, which is what
//! makes episodes reproducible across the paper's repeated runs (§6.1).

pub mod history;
pub mod queue;
pub mod rng;
pub mod wheel;

pub use history::{History, RunningAvg};
pub use queue::BoundedQueue;
pub use rng::Rng;
pub use wheel::EventWheel;

/// Simulation time, in memory-network clock cycles.
pub type Cycle = u64;
